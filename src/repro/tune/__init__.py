"""repro.tune — compiled-mode kernel autotuning (DESIGN.md §5).

Public surface:

* :class:`TunedConfig` / ``DEFAULT_TUNED`` — the kernel-engine knob vector
  (tune/config.py);
* ``TUNED_CACHE`` / :func:`corpus_signature` — the per-process winning-
  config cache keyed by shape/skew signature (tune/cache.py);
* :func:`search_tuned_config` / :func:`ensure_tuned` / ``SearchBudget`` —
  the roofline-pruned search (tune/search.py);
* the cost model lives in tune/cost.py.

``search`` pulls in the kernel wrappers (which themselves import
tune.config), so it is re-exported lazily to keep the package import-cycle
free and cheap to load.
"""
from __future__ import annotations

from repro.tune.cache import TUNED_CACHE, corpus_signature
from repro.tune.config import (DEFAULT_TUNED, DEFAULT_XLA_TUNED, ENGINES,
                               TunedConfig, default_tuned)

__all__ = [
    "TunedConfig", "DEFAULT_TUNED", "DEFAULT_XLA_TUNED", "ENGINES",
    "default_tuned", "TUNED_CACHE", "corpus_signature",
    "SearchBudget", "SearchStats", "search_tuned_config", "ensure_tuned",
    "candidate_space",
]

_LAZY = {"SearchBudget", "SearchStats", "search_tuned_config",
         "ensure_tuned", "candidate_space"}


def __getattr__(name: str):
    if name in _LAZY:
        from repro.tune import search

        return getattr(search, name)
    raise AttributeError(f"module 'repro.tune' has no attribute {name!r}")
