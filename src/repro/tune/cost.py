"""Analytic kernel cost model — the autotuner's pruning oracle.

Mirrors the paper's parameter determination: instead of timing every point
of the knob space, estimate the *approximate work* of each candidate —
FLOPs and bytes of the superblock launch it would produce — and convert the
estimate to a roofline lower bound through the existing
:func:`repro.roofline.analysis.roofline_terms`.  Candidates whose lower
bound already loses to the incumbent's are discarded without ever running;
only the analytically-plausible survivors get wall-clock time.

The model is deliberately coarse (ranking consistency is what pruning
needs, not absolute accuracy):

* densify work: one one-hot walk of ``b_blk × P × d_blk`` compare/FMA lanes
  per *live* grid cell per K-superblock revisit, minus the head-cached
  trailing blocks;
* MXU work: ``2 · b_blk · d_blk · k_sup`` per live cell visit (×2 for the
  two-accumulator ES gather);
* bytes: operand fetches per revisit (ids/vals per superblock pass, the
  means block per B-tile, cached head slabs per visit) plus one output
  write;
* a per-executed-grid-step overhead term — 0 on real hardware, dominant in
  interpret mode, where each step costs Python-level dispatch.  This is
  what lets the same model rank candidates honestly on CPU runners.

A VMEM feasibility gate (``fits_vmem``) removes configs whose blocks cannot
co-reside on a TPU core at all; those count as analytically pruned too.
"""
from __future__ import annotations

import dataclasses

from repro.roofline.analysis import HW, roofline_terms
from repro.tune.config import TunedConfig

#: TPU-core VMEM budget the resident blocks must fit (bytes, conservative).
VMEM_BUDGET = 16 << 20

#: Per-executed-grid-step dispatch cost of the Pallas interpreter (seconds).
#: Calibration is rough by design — it only needs to dominate the roofline
#: terms the way real interpret-mode dispatch dominates real compute.
INTERPRET_STEP_OVERHEAD = 5e-4

KERNELS = ("sparse_sim", "esicp_gather", "segment_update", "rho_gather")


@dataclasses.dataclass(frozen=True)
class KernelShape:
    """Logical shape of one clustering-kernel call."""
    b: int
    p: int
    d: int
    k: int


def _ceil_to(n: int, m: int) -> int:
    return n + (-n) % m


def launch_geometry(cfg: TunedConfig, shape: KernelShape) -> dict:
    """Padded sizes + grid of the launch ``cfg`` produces at ``shape``."""
    from repro.kernels.ops import _pick_k_sup
    from repro.kernels.plan import pick_n_head

    bp = _ceil_to(shape.b, cfg.b_blk)
    kp = _ceil_to(shape.k, cfg.k_blk)
    dp = _ceil_to(shape.d, cfg.d_blk)
    pp = _ceil_to(shape.p, 8)
    ks = _pick_k_sup(kp, cfg.k_blk, None, cap=cfg.k_sup_cap)
    nd = dp // cfg.d_blk
    n_head = min(nd, pick_n_head(bp, shape.d, d_blk=cfg.d_blk,
                                 head_bytes=cfg.head_bytes))
    return {"bp": bp, "kp": kp, "dp": dp, "pp": pp, "ks": ks,
            "nb": bp // cfg.b_blk, "nk": kp // ks, "nd": nd,
            "n_head": n_head}


def fits_vmem(cfg: TunedConfig, shape: KernelShape, *,
              budget: int = VMEM_BUDGET) -> bool:
    """Can the resident blocks of one grid step co-exist in VMEM?

    slab (+count twin) + means block + two (B, K_sup) accumulators +
    the ids/vals tile + one cached head block.  The XLA-blocked engine has
    no VMEM-resident grid step — XLA tiles its own programs — so every
    config is feasible there.
    """
    if cfg.engine == "xla_blocked":
        return True
    g = launch_geometry(cfg, shape)
    slab = cfg.b_blk * cfg.d_blk * 4 * 2          # value + count twin
    means = cfg.d_blk * g["ks"] * 4
    out = cfg.b_blk * g["ks"] * 4 * 2             # sims + counts
    tuples = cfg.b_blk * g["pp"] * (4 + 4)
    head = (cfg.b_blk * cfg.d_blk * 4 * 2) if g["n_head"] else 0
    return slab + means + out + tuples + head <= budget


def kernel_flops_bytes(kernel: str, cfg: TunedConfig, shape: KernelShape,
                       occ_frac: float) -> tuple[float, float, float]:
    """(flops, bytes, executed_grid_steps) estimate for one kernel launch.

    ``occ_frac`` is the live fraction of (B-tile, D-block) cells at this
    config's geometry (tune/cache.occupancy_fraction); occupancy pruning
    skips the work — but not the grid step — of the dead cells.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; one of {KERNELS}")
    if cfg.engine == "xla_blocked":
        return _xla_flops_bytes(kernel, cfg, shape)
    g = launch_geometry(cfg, shape)
    bb, db = cfg.b_blk, cfg.d_blk
    grid_steps = g["nb"] * g["nk"] * g["nd"]
    live_frac = min(1.0, max(float(occ_frac), g["n_head"] / max(g["nd"], 1)))
    live_cells = g["nb"] * g["nd"] * live_frac           # per superblock pass
    live_visits = live_cells * g["nk"]

    # Densify: the one-hot walk, skipped for head-cached trailing blocks.
    head_share = g["n_head"] / max(g["nd"], 1)
    densify_visits = live_visits * max(0.0, 1.0 - head_share)
    densify_flops = densify_visits * bb * g["pp"] * db * 3.0

    # MXU: slab @ means_blk per live visit; the ES gather accumulates two
    # outputs (rho12, y) plus the fused sims off the same slab.
    mxu_per_visit = 2.0 * bb * db * g["ks"]
    mxu_factor = {"sparse_sim": 1.0, "esicp_gather": 2.5,
                  "segment_update": 1.0, "rho_gather": 1.0}[kernel]
    mxu_flops = live_visits * mxu_per_visit * mxu_factor

    tuple_bytes = g["nk"] * g["bp"] * g["pp"] * 8.0      # ids+vals per pass
    means_bytes = 0.0 if kernel == "segment_update" else \
        g["nb"] * g["dp"] * g["kp"] * 4.0                # means per B-tile
    head_bytes_rw = live_visits * head_share * bb * db * 4.0
    out_bytes = {"sparse_sim": g["bp"] * g["kp"] * 4.0,
                 "esicp_gather": 3.0 * g["bp"] * g["kp"] * 4.0,
                 "segment_update": g["kp"] * g["dp"] * 4.0,
                 "rho_gather": g["bp"] * 4.0}[kernel]

    flops = densify_flops + mxu_flops
    nbytes = tuple_bytes + means_bytes + head_bytes_rw + out_bytes
    return flops, nbytes, float(grid_steps)


def _xla_flops_bytes(kernel: str, cfg: TunedConfig,
                     shape: KernelShape) -> tuple[float, float, float]:
    """(flops, bytes, steps) for the gather-formulation XLA engine.

    Work is proportional to *postings*, not the (B, D) grid: each of the
    ``bp·pp`` postings gathers a K-row and folds it (occupancy skipping in
    its limiting form).  A head budget moves the head-share of postings out
    of the gather and into one ``bp × (n_head·d_blk) × kp`` GEMM per call —
    dense FLOPs the matmul units must amortise, which is exactly the
    trade-off the measured pass decides.  ``steps = 0``: the engine always
    compiles, so no interpreter dispatch term applies.
    """
    from repro.kernels.plan import pick_n_head

    bp = float(shape.b)
    kp = float(shape.k)
    pp = float(_ceil_to(shape.p, 8))
    nd = max(1, -(-shape.d // cfg.d_blk))
    n_head = min(nd, pick_n_head(shape.b, shape.d, d_blk=cfg.d_blk,
                                 head_bytes=cfg.head_bytes))
    head_share = n_head / nd
    h = float(n_head * cfg.d_blk)

    if kernel == "segment_update":
        # Scatter-add: one read-modify-write lane per posting.
        flops = bp * pp
        nbytes = bp * pp * 8.0 + shape.k * shape.d * 4.0
        return flops, nbytes, 0.0
    if kernel == "rho_gather":
        flops = 2.0 * bp * pp
        nbytes = bp * pp * 8.0 + bp * pp * 4.0 + bp * 4.0
        return flops, nbytes, 0.0

    acc_factor = {"sparse_sim": 1.0, "esicp_gather": 2.5}[kernel]
    tail_pp = pp * max(0.0, 1.0 - head_share)
    gather_flops = 2.0 * bp * tail_pp * kp * acc_factor
    gemm_flops = 2.0 * bp * h * kp * acc_factor
    gather_bytes = bp * tail_pp * (8.0 + kp * 4.0)     # tuples + K-rows
    head_bytes_r = bp * h * 4.0 * (2.0 if kernel == "esicp_gather" else 1.0)
    means_bytes = float(shape.d) * kp * 4.0
    out_bytes = bp * kp * 4.0 * acc_factor
    return (gather_flops + gemm_flops,
            gather_bytes + head_bytes_r + means_bytes + out_bytes, 0.0)


def lower_bound_seconds(cfg: TunedConfig, shape: KernelShape,
                        occ_frac: float, *, kernels=KERNELS,
                        hw: HW | None = None,
                        step_overhead_s: float = 0.0) -> float:
    """Roofline lower bound on the summed runtime of ``kernels`` under
    ``cfg`` — max(compute term, memory term) via roofline_terms, plus the
    per-step dispatch overhead (interpret-mode platforms)."""
    hw = hw or HW()
    total = 0.0
    for kernel in kernels:
        flops, nbytes, steps = kernel_flops_bytes(kernel, cfg, shape,
                                                  occ_frac)
        terms = roofline_terms({"flops": flops, "bytes accessed": nbytes},
                               {"total": 0}, hw)
        total += max(terms["t_compute_s"], terms["t_memory_s"])
        total += steps * step_overhead_s
    return total
