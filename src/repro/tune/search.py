"""Cost-model-pruned autotuner over the kernel-engine knob space.

The paper fixes its two structural parameters by minimizing an *estimated*
multiplication count before touching the data (EstParams); the TPU engine
does the same one level down.  For a given corpus regime (shape + skew) the
search:

1. enumerates the knob space (``candidate_space``) — block geometry,
   K-superblock cap, head-cache budget — deduplicated by the *effective*
   launch geometry each candidate produces;
2. prunes analytically: every candidate gets a roofline lower bound from
   :mod:`repro.tune.cost` (FLOPs/bytes through ``roofline/analysis.py``);
   candidates whose bound already loses to the incumbent default config are
   discarded, and only the ``budget.max_timed`` best-bounded survivors are
   ever timed — the paper's minimize-approximate-Mult move;
3. times the survivors on a probe workload (all four kernels, prepared
   plans included, best-of-``repeat`` wall clock) and crowns the winner.

The search is deterministic under a fixed seed and budget: candidate
enumeration, costing and tie-breaking are pure functions of the corpus
statistics, and the probe means/assignment are drawn from a seeded PRNG.
(Wall-clock noise can flip *measured* winners between runs; tests pin the
``measure`` hook to the cost model itself to assert end-to-end determinism,
and production runs cache the first winner per signature.)

``REPRO_BENCH_SMOKE=1`` shrinks the default budget (fewer timed candidates,
single repeat, smaller probe) so CI smoke runs stay under a minute.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.tune.cache import (TUNED_CACHE, corpus_signature,
                              occupancy_fraction)
from repro.tune.config import DEFAULT_TUNED, TunedConfig, default_tuned
from repro.tune.cost import (INTERPRET_STEP_OVERHEAD, KERNELS, KernelShape,
                             fits_vmem, lower_bound_seconds)

#: A candidate whose roofline lower bound exceeds ``slack ×`` the incumbent
#: default's bound has analytically lost — no amount of timing noise will
#: recover a 2× modeled deficit.
PRUNE_SLACK = 2.0

#: Candidate axes.  Kept deliberately coarse: the effective-geometry dedup
#: collapses equivalent points, and the roofline pruning pass is what turns
#: the cross product into a handful of timed configs.
_B_BLKS = (64, 128, 256, 512)
_D_BLKS = (128, 256, 512, 1024)
_K_BLKS = (128, 256)
_K_SUP_CAPS = (256, 512, 1024, 2048)
_HEAD_BYTES = (0, 32 << 20)


@dataclasses.dataclass(frozen=True)
class SearchBudget:
    """How much wall clock the tuner may spend (timing only — enumeration
    and pruning are always exhaustive and cheap)."""

    max_timed: int = 8      # candidates that get wall-clock time
    repeat: int = 2         # best-of-N steady-state timing per candidate
    probe_rows: int = 512   # corpus rows the probe workload uses

    @classmethod
    def default(cls) -> "SearchBudget":
        if os.environ.get("REPRO_BENCH_SMOKE"):
            return cls(max_timed=2, repeat=1, probe_rows=256)
        return cls()


@dataclasses.dataclass
class SearchStats:
    """What the search did — the bench suite's autotuner meta-row and the
    pruning-fraction acceptance tests read these."""

    n_candidates: int = 0
    n_pruned: int = 0
    n_timed: int = 0
    default_bound_s: float = 0.0
    best_bound_s: float = 0.0
    default_measured_s: float = 0.0
    best_measured_s: float = 0.0
    timed: list = dataclasses.field(default_factory=list)

    @property
    def pruned_fraction(self) -> float:
        return self.n_pruned / self.n_candidates if self.n_candidates else 0.0

    def to_dict(self) -> dict:
        return {"n_candidates": self.n_candidates, "n_pruned": self.n_pruned,
                "n_timed": self.n_timed,
                "pruned_fraction": round(self.pruned_fraction, 4),
                "default_measured_s": round(self.default_measured_s, 6),
                "best_measured_s": round(self.best_measured_s, 6)}


def candidate_space(shape: KernelShape,
                    engine: str = "pallas") -> list[TunedConfig]:
    """Enumerate the knob grid, deduplicated by effective launch geometry.

    The engine's hard-coded default config is always candidates[0] — it is
    the incumbent every other candidate must beat analytically before it
    earns wall-clock time.  The XLA-blocked engine's geometry key collapses
    the grid knobs (it has no launch grid), so its space dedups to the
    head-split points (d_blk × head budget) automatically."""
    incumbent = default_tuned(engine)
    cands = [incumbent]
    seen = {incumbent.geometry_key(b=shape.b, p=shape.p, d=shape.d,
                                   k=shape.k)}
    for bb in _B_BLKS:
        for db in _D_BLKS:
            for kb in _K_BLKS:
                for cap in _K_SUP_CAPS:
                    if cap < kb:
                        continue
                    for hb in _HEAD_BYTES:
                        cfg = TunedConfig(b_blk=bb, d_blk=db, k_blk=kb,
                                          k_sup_cap=cap, head_bytes=hb,
                                          engine=engine, source="search")
                        key = cfg.geometry_key(b=shape.b, p=shape.p,
                                               d=shape.d, k=shape.k)
                        if key in seen:
                            continue
                        seen.add(key)
                        cands.append(cfg)
    return cands


def _probe_workload(ids, vals, *, dim: int, k: int, rows: int, seed: int):
    """Deterministic probe the survivors are timed on: a row prefix of the
    corpus plus synthetic means/assignments with corpus-matched density."""
    import jax.numpy as jnp

    ids = np.asarray(ids)
    vals = np.asarray(vals)
    b = min(ids.shape[0], rows)
    ids, vals = ids[:b], vals[:b]
    rng = np.random.default_rng(seed)
    nnz_per_col = max(1.0, (b / max(k, 1)) * (vals != 0).sum(1).mean())
    density = min(1.0, nnz_per_col / max(dim, 1))
    means_t = np.where(rng.random((dim, k)) < density,
                       rng.random((dim, k)), 0.0).astype(np.float32)
    assign = rng.integers(0, k, b).astype(np.int32)
    return (jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(means_t),
            jnp.asarray(assign))


def _measure_config(cfg: TunedConfig, probe, *, dim: int, k: int,
                    repeat: int) -> float:
    """Summed best-of-``repeat`` seconds over the four kernels under ``cfg``
    with a matching prepared plan — the quantity production fits pay.
    Dispatches on ``cfg.engine``: Pallas wrappers or their XLA twins."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.plan import prepare_plan

    if cfg.engine == "xla_blocked":
        from repro.kernels import xla_blocked as ops
    else:
        from repro.kernels import ops

    ids, vals, means_t, assign = probe
    plan = prepare_plan(ids, vals, dim=dim, b_blk=cfg.b_blk,
                        d_blk=cfg.d_blk, head_bytes=cfg.head_bytes,
                        tuned=cfg)
    t_th = jnp.asarray(int(0.8 * dim), jnp.int32)
    v_th = jnp.asarray(0.1, jnp.float32)
    calls = {
        "sparse_sim": lambda: ops.sparse_sim(ids, vals, means_t, plan=plan,
                                             tuned=cfg),
        "esicp_gather": lambda: ops.esicp_gather(ids, vals, means_t, t_th,
                                                 v_th, plan=plan, tuned=cfg),
        "segment_update": lambda: ops.segment_update(assign, ids, vals, k=k,
                                                     d=dim, plan=plan,
                                                     tuned=cfg),
        "rho_gather": lambda: ops.rho_gather(assign, ids, vals, means_t,
                                             plan=plan, tuned=cfg),
    }
    total = 0.0
    for fn in calls.values():
        jax.block_until_ready(fn())                      # compile + warm
        best = float("inf")
        for _ in range(max(repeat, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        total += best
    return total


def search_tuned_config(ids, vals, *, dim: int, k: int,
                        budget: SearchBudget | int | None = None,
                        seed: int = 0, measure=None, hw=None,
                        step_overhead_s: float | None = None,
                        prune_slack: float = PRUNE_SLACK,
                        engine: str = "pallas",
                        ) -> tuple[TunedConfig, SearchStats]:
    """Find the kernel-engine config that wins at this corpus regime.

    ``measure`` (candidate -> seconds) defaults to wall-clock timing of the
    four kernels on a probe workload; tests inject a counting or analytic
    stub to assert pruning fractions and determinism.  ``engine`` selects
    the knob space, cost model and measured ops — each engine is searched
    (and cached) independently.
    """
    if budget is None:
        budget = SearchBudget.default()
    elif isinstance(budget, int):
        budget = dataclasses.replace(SearchBudget.default(),
                                     max_timed=budget)
    if step_overhead_s is None:
        if engine == "xla_blocked":
            step_overhead_s = 0.0        # always compiled, no dispatch term
        else:
            import jax

            step_overhead_s = (0.0 if jax.default_backend() == "tpu"
                               else INTERPRET_STEP_OVERHEAD)

    b = int(np.asarray(ids).shape[0])
    shape = KernelShape(b=min(b, budget.probe_rows),
                        p=int(np.asarray(ids).shape[1]), d=dim, k=k)
    cands = candidate_space(shape, engine)
    stats = SearchStats(n_candidates=len(cands))

    # --- analytic pass: feasibility + roofline lower bounds ---------------
    bounds = []
    for cfg in cands:
        if not fits_vmem(cfg, shape):
            bounds.append(float("inf"))
            continue
        occ = occupancy_fraction(ids, vals, dim=dim, b_blk=cfg.b_blk,
                                 d_blk=cfg.d_blk)
        kw = {} if hw is None else {"hw": hw}
        bounds.append(lower_bound_seconds(cfg, shape, occ,
                                          step_overhead_s=step_overhead_s,
                                          **kw))
    stats.default_bound_s = bounds[0]

    # Discard candidates whose bound already loses to the incumbent; rank
    # the rest by bound and keep only the budgeted head.  The incumbent
    # itself is always timed — it is the baseline tuned rows report against.
    order = sorted(range(len(cands)), key=lambda i: (bounds[i], i))
    survivors = [i for i in order
                 if bounds[i] <= prune_slack * bounds[0]][:budget.max_timed]
    if 0 not in survivors:
        survivors = survivors[:max(budget.max_timed - 1, 1) ] + [0] \
            if survivors else [0]
    stats.best_bound_s = min(bounds[i] for i in survivors)
    stats.n_timed = len(survivors)
    stats.n_pruned = stats.n_candidates - stats.n_timed

    # --- timing pass: only the survivors ----------------------------------
    if measure is None:
        probe = _probe_workload(ids, vals, dim=dim, k=k,
                                rows=budget.probe_rows, seed=seed)

        def measure(cfg):
            return _measure_config(cfg, probe, dim=dim, k=k,
                                   repeat=budget.repeat)

    measured = {i: float(measure(cands[i])) for i in survivors}
    stats.default_measured_s = measured[0]
    stats.timed = [(cands[i].to_dict(), measured[i]) for i in survivors]
    win = min(survivors, key=lambda i: (measured[i], bounds[i], i))
    stats.best_measured_s = measured[win]
    winner = cands[win].replace(source="search" if win else "default")
    return winner, stats


def ensure_tuned(docs, *, k: int | None, mode: str = "cached",
                 budget: SearchBudget | int | None = None,
                 seed: int = 0, engine: str = "pallas") -> TunedConfig | None:
    """Resolve the tuned config for a corpus through the process cache.

    mode 'cached' — return the cached winner for this corpus signature, or
    None (caller falls back to defaults).  mode 'search' — on a cache miss,
    run the pruned search under ``budget`` and cache the winner.  Returns
    None when ``k`` is unknown (nothing to tune against).  The signature is
    engine-qualified: each backend resolves (and caches) its own winner.
    """
    if mode not in ("cached", "search"):
        raise ValueError(f"tune mode must be 'cached' or 'search', "
                         f"got {mode!r}")
    if k is None:
        return None
    sig = corpus_signature(docs.ids, docs.vals, dim=docs.dim, k=k,
                           engine=engine)
    hit = TUNED_CACHE.get(sig)
    if hit is not None or mode == "cached":
        return hit
    winner, _ = search_tuned_config(docs.ids, docs.vals, dim=docs.dim, k=k,
                                    budget=budget, seed=seed, engine=engine)
    return TUNED_CACHE.put(sig, winner)
