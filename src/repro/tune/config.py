"""The kernel-engine knob vector the autotuner searches over.

The paper determines its two structural parameters by *minimizing the
approximate number of multiplications* (§EstParams); the TPU engine has the
same shape of problem one level down: the kernel wrappers in
:mod:`repro.kernels.ops` expose a handful of structural knobs — block
geometry, the K-superblock VMEM cap, the head-slab byte budget — that were
hard-coded until ISSUE 6.  A :class:`TunedConfig` is one point in that knob
space, hashable (it rides jit static args and the :class:`~repro.kernels.
plan.KernelPlan` aux data) and JSON-serializable (it round-trips through
``FittedModel.save/load`` and the per-process cache).

``DEFAULT_TUNED`` reproduces the pre-tuner hard-coded behaviour exactly —
every wrapper called without a config resolves to it, so tuning is strictly
opt-in.
"""
from __future__ import annotations

import dataclasses

from repro.kernels.plan import DEFAULT_B_BLK, DEFAULT_D_BLK, DEFAULT_HEAD_BYTES

# Pre-tuner hard-coded values (kernels/ops.py v2 engine).
DEFAULT_K_BLK = 128
DEFAULT_K_SUP_CAP = 1024

#: Kernel engines a config can be tuned for.  The knob vector is shared,
#: but the knobs *mean* different things per engine (ISSUE 10): the Pallas
#: grid launches with the full geometry, while the XLA-blocked engine has
#: no grid — only ``d_blk`` (head-block granularity) and ``head_bytes``
#: (the head-slab GEMM budget, default **0** there) change its programs.
ENGINES = ("pallas", "xla_blocked")


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One candidate (or winning) kernel-engine configuration.

    b_blk/d_blk: (B-tile, D-block) geometry shared by all four kernels AND
        the prepared :class:`~repro.kernels.plan.KernelPlan` (occupancy map,
        head slabs) — the plan's layout contract is why these are one knob,
        not four.
    k_blk:      K padding multiple (and superblock granularity).
    k_sup_cap:  VMEM budget on the K-superblock width; ``ops._pick_k_sup``
        picks the widest ``k_blk`` multiple under it that divides padded K.
    head_bytes: per-chunk byte budget for the cached high-df head slabs
        (0 disables the head cache entirely).
    engine:     which kernel engine the config was tuned for — one of
        :data:`ENGINES`.  A Pallas winner must never drive an XLA-blocked
        fit (or vice versa): the cost structures differ, so the cache key
        and the candidate space are both engine-qualified.
    source:     provenance — 'default' | 'search' | 'cache' | 'manual'.
    signature:  the corpus/shape signature the config was tuned for
        (tune/cache.py); '' for untuned configs.
    """

    b_blk: int = DEFAULT_B_BLK
    d_blk: int = DEFAULT_D_BLK
    k_blk: int = DEFAULT_K_BLK
    k_sup_cap: int = DEFAULT_K_SUP_CAP
    head_bytes: int = DEFAULT_HEAD_BYTES
    engine: str = "pallas"
    source: str = "default"
    signature: str = ""

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {self.engine!r}")
        if self.b_blk < 8 or self.b_blk % 8:
            raise ValueError(f"b_blk must be a positive multiple of 8, "
                             f"got {self.b_blk}")
        if self.d_blk < 128 or self.d_blk % 128:
            raise ValueError(f"d_blk must be a positive multiple of 128, "
                             f"got {self.d_blk}")
        if self.k_blk < 8 or self.k_blk % 8:
            raise ValueError(f"k_blk must be a positive multiple of 8, "
                             f"got {self.k_blk}")
        if self.k_sup_cap < self.k_blk:
            raise ValueError(f"k_sup_cap ({self.k_sup_cap}) must be >= "
                             f"k_blk ({self.k_blk})")
        if self.head_bytes < 0:
            raise ValueError("head_bytes must be >= 0")

    def replace(self, **changes) -> "TunedConfig":
        return dataclasses.replace(self, **changes)

    # -- serialization (FittedModel extra sidecar, cache files) -------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TunedConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    def geometry_key(self, *, b: int, p: int, d: int, k: int) -> tuple:
        """The *effective* launch parameters this config produces at a
        shape — two configs with the same key launch identical programs, so
        the search deduplicates on it before costing/timing.  The XLA
        engine has no launch grid: only the head split (d_blk, n_head)
        changes its programs, so the grid knobs collapse out of its key and
        the candidate space dedups to a handful of head-budget points."""
        from repro.kernels.ops import _pick_k_sup
        from repro.kernels.plan import pick_n_head

        bp = b + (-b) % self.b_blk
        kp = k + (-k) % self.k_blk
        dp = d + (-d) % self.d_blk
        n_head = pick_n_head(bp, d, d_blk=self.d_blk,
                             head_bytes=self.head_bytes)
        if self.engine == "xla_blocked":
            return (self.engine, self.d_blk, dp, n_head)
        ks = _pick_k_sup(kp, self.k_blk, None, cap=self.k_sup_cap)
        return (self.engine, self.b_blk, self.d_blk, kp, ks, dp, n_head)


DEFAULT_TUNED = TunedConfig()

#: The XLA-blocked engine's untuned behaviour: head cache off (gather-only;
#: see kernels/xla_blocked.py — the slab GEMM must *earn* its FLOPs).
DEFAULT_XLA_TUNED = TunedConfig(engine="xla_blocked", head_bytes=0)


def default_tuned(engine: str = "pallas") -> TunedConfig:
    """The engine's hard-coded (search-incumbent) configuration."""
    return DEFAULT_XLA_TUNED if engine == "xla_blocked" else DEFAULT_TUNED
