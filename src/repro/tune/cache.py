"""TunedConfig cache: winning configs keyed by a shape/skew signature.

A tuned config is a property of the *regime* a corpus puts the kernels in —
batch geometry, tuple width, vocabulary size, K, and how skewed the
occupancy is — not of the individual corpus.  The cache key therefore
buckets exactly those quantities: two corpora with the same signature reuse
one search, across fits and (through the ``FittedModel`` extra sidecar)
across processes.

The cache is deliberately a plain in-process dict: ``Backend.prepare``
consults it on every fit with ``tune != 'off'``, a search populates it on
miss, and ``FittedModel.load`` re-seeds it from a saved artifact — no
daemon, no file locking, no global config file.
"""
from __future__ import annotations

import numpy as np

from repro.tune.config import TunedConfig


def _pow2_bucket(n: int) -> int:
    """Round up to the next power of two — batch/row counts land in stable
    buckets regardless of padding residue."""
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


def occupancy_fraction(ids, vals, *, dim: int, b_blk: int = 128,
                       d_blk: int = 256) -> float:
    """Fraction of (b_blk row-group, d_blk D-block) cells holding at least
    one live tuple — the skew statistic the kernels' occupancy pruning
    exploits, computed host-side in one pass."""
    ids = np.asarray(ids)
    vals = np.asarray(vals)
    b, p = ids.shape
    nb = -(-b // b_blk)
    nd = -(-dim // d_blk)
    occ = np.zeros((nb, nd), np.bool_)
    grp = np.repeat(np.arange(nb), b_blk)[:b]
    blk = np.minimum(ids // d_blk, nd - 1)
    live = vals != 0.0
    occ[np.broadcast_to(grp[:, None], blk.shape)[live], blk[live]] = True
    return float(occ.mean()) if occ.size else 0.0


def corpus_signature(ids, vals, *, dim: int, k: int,
                     platform: str | None = None,
                     engine: str = "pallas") -> str:
    """Cache key: platform / bucketed-B / P / D / K / bucketed occupancy /
    engine.

    Occupancy is measured at the *default* geometry and bucketed to 0.05 so
    minor corpus perturbations (reshuffles, small appends) still hit.  The
    engine suffix keeps the regimes disjoint per kernel engine: a config
    tuned under interpret-mode Pallas must never be handed to an XLA-blocked
    fit at the same corpus signature (ISSUE 10 satellite)."""
    if platform is None:
        import jax

        platform = jax.default_backend()
    b, p = np.asarray(ids).shape
    occ = occupancy_fraction(ids, vals, dim=dim)
    occ_bucket = round(round(occ / 0.05) * 0.05, 2)
    return (f"{platform}/b{_pow2_bucket(b)}/p{_pow2_bucket(p)}/"
            f"d{dim}/k{k}/occ{occ_bucket:.2f}/{engine}")


class TunedConfigCache:
    """signature -> TunedConfig, with dict round-trip for persistence."""

    def __init__(self):
        self._store: dict[str, TunedConfig] = {}

    def get(self, signature: str) -> TunedConfig | None:
        return self._store.get(signature)

    def put(self, signature: str, cfg: TunedConfig) -> TunedConfig:
        cfg = cfg.replace(signature=signature)
        self._store[signature] = cfg
        return cfg

    def clear(self) -> None:
        self._store.clear()

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, signature: str) -> bool:
        return signature in self._store

    def to_dict(self) -> dict:
        return {sig: cfg.to_dict() for sig, cfg in self._store.items()}

    def from_dict(self, d: dict) -> None:
        for sig, cfg in d.items():
            self._store[sig] = TunedConfig.from_dict(cfg)


#: The process-wide cache every ``Backend.prepare`` consults.
TUNED_CACHE = TunedConfigCache()
