from repro.roofline.analysis import (
    HW,
    collective_bytes,
    roofline_terms,
    model_flops,
)

__all__ = ["HW", "collective_bytes", "roofline_terms", "model_flops"]
