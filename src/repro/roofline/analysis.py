"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` on the host backend reports *per-device*
FLOPs/bytes for the SPMD program (verified against hand counts in
tests/test_roofline.py), so the per-chip terms divide by peak only.
collective_bytes is parsed from the optimized HLO: operand bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re


def cost_dict(cost) -> dict:
    """Normalise ``compiled.cost_analysis()`` across jax versions.

    Older jax returns a one-element list of dicts (per executable program);
    newer jax returns the dict directly.  Missing/empty analyses -> {}.
    """
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e-class chip."""
    peak_flops: float = 197e12     # bf16 FLOP/s
    hbm_bw: float = 819e9          # B/s
    ici_bw: float = 50e9           # B/s per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind from optimized HLO text.

    '-start' ops are counted, '-done' duplicates are skipped (async pairs).
    Returns {kind: bytes, ..., 'total': bytes, 'count': n}.
    """
    out: dict = {}
    count = 0
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done.{" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
        count += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["count"] = count
    return out


def roofline_terms(cost: dict, coll: dict, hw: HW = HW()) -> dict:
    """Per-chip roofline seconds (cost_analysis is already per-device)."""
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.get("total", 0))
    t_compute = flops / hw.peak_flops
    t_memory = bytes_acc / hw.hbm_bw
    t_coll = cbytes / hw.ici_bw
    terms = {"t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": t_coll,
             "flops_per_dev": flops, "bytes_per_dev": bytes_acc,
             "coll_bytes_per_dev": cbytes}
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    terms["bottleneck"] = dom[0]
    bound = max(t_compute, t_memory, t_coll)
    terms["roofline_frac_compute"] = (t_compute / bound) if bound > 0 else 0.0
    return terms


def model_flops(cfg, shape, n_chips: int) -> dict:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), D = tokens.

    For decode shapes D = batch tokens (one step).  Returns per-device
    numbers for direct comparison with cost_analysis flops."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = shape.batch
        factor = 2.0
    total = factor * n_active * tokens
    return {"model_flops_total": total,
            "model_flops_per_dev": total / n_chips}
