from repro.data.synthetic import make_corpus, CorpusSpec
from repro.data.loader import load_uci_bow
from repro.data.pipeline import ShardedBatches

__all__ = ["make_corpus", "CorpusSpec", "load_uci_bow", "ShardedBatches"]
