"""UC-faithful synthetic corpus generator.

PubMed / NYT are not shipped offline, so benchmarks and tests run on synthetic
corpora engineered to reproduce the paper's universal characteristics (§III):

* Zipf's law on term frequency *and* document frequency (Fig. 2a) — term draws
  follow ``p(s) ∝ rank^-alpha``;
* high dimensionality with (nt̂/D) << 1 sparsity;
* tf-idf weighting + L2 normalisation (Eq. 15) which, combined with the Zipf
  draw, yields the feature-value concentration phenomenon in cluster means
  (Fig. 4/9) — verified by ``benchmarks/fig2_ucs.py``;
* a latent topic mixture so that K-means finds real structure (clusters are
  annotated by a few dominant terms, exactly the paper's observation).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.sparse import SparseDocs, tf_idf, l2_normalize_rows, remap_terms_by_df, df_counts, with_df


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    n_docs: int = 20_000
    vocab: int = 8_192
    nt_mean: float = 60.0        # paper PubMed: 58.96 distinct terms / doc
    zipf_alpha: float = 1.05     # exponent of the rank-frequency law
    n_topics: int = 64           # latent clusters (drives mean concentration)
    # Calibrated so clustering means reproduce the paper's feature-value
    # concentration + Pareto CPS (benchmarks/fig4_cps.py: CPS(0.1) ≈ 0.91
    # vs paper 0.92 on PubMed).
    topic_sharpness: float = 200.0
    pad_to: int | None = None
    seed: int = 0


def _zipf_probs(vocab: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return p / p.sum()


def make_corpus(spec: CorpusSpec):
    """Returns (docs: SparseDocs tf-idf L2-normalised df-rank-remapped,
    df: (D,) int32, perm: new->old term permutation, topics: (N,) labels)."""
    rng = np.random.default_rng(spec.seed)
    base = _zipf_probs(spec.vocab, spec.zipf_alpha)

    # Topic-specific distributions: boost a random "head set" per topic so each
    # cluster mean concentrates on a few dominant terms (paper Fig. 4a).
    n_head = max(4, spec.vocab // 256)
    topic_boost = np.ones((spec.n_topics, spec.vocab))
    for t in range(spec.n_topics):
        head = rng.choice(spec.vocab, size=n_head, replace=False)
        topic_boost[t, head] *= spec.topic_sharpness
    topic_p = base[None, :] * topic_boost
    topic_p /= topic_p.sum(axis=1, keepdims=True)

    topics = rng.integers(0, spec.n_topics, size=spec.n_docs)
    lengths = np.clip(rng.poisson(spec.nt_mean * 1.6, size=spec.n_docs), 8, None)

    pad = spec.pad_to or int(np.quantile(lengths, 0.999) + 8)
    ids = np.zeros((spec.n_docs, pad), np.int32)
    vals = np.zeros((spec.n_docs, pad), np.float32)
    nnz = np.zeros((spec.n_docs,), np.int32)

    # Vectorised batched multinomial per topic for speed.
    for t in range(spec.n_topics):
        (docs_t,) = np.nonzero(topics == t)
        if docs_t.size == 0:
            continue
        for i in docs_t:
            draws = rng.choice(spec.vocab, size=lengths[i], replace=True, p=topic_p[t])
            terms, counts = np.unique(draws, return_counts=True)
            k = min(len(terms), pad)
            ids[i, :k] = terms[:k]
            vals[i, :k] = counts[:k].astype(np.float32)
            nnz[i] = k

    docs = SparseDocs(ids=jnp.asarray(ids), vals=jnp.asarray(vals), nnz=jnp.asarray(nnz), dim=spec.vocab)
    df = df_counts(docs)
    docs = tf_idf(docs, df=df)
    docs = l2_normalize_rows(docs)
    docs, perm = remap_terms_by_df(docs, df=df)
    df_sorted = df[perm]
    # The permuted counts ARE the remapped corpus's df: seed the .df cache
    # so the fit path (EstParams, tf-idf consumers) never recounts.
    docs = with_df(docs, df_sorted)
    return docs, df_sorted, perm, jnp.asarray(topics)
