"""Sharded, prefetching batch pipeline.

Deterministic: batch b of epoch e is a pure function of (seed, e, b) so a
restarted job resumes mid-epoch from the checkpointed (epoch, batch) cursor —
the fault-tolerance contract used by launch/train.py.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse import SparseDocs


class ShardedBatches:
    """Iterates padded SparseDocs minibatches, optionally device-sharded.

    Objects are sharded along the batch dim over the mesh's data axes; the
    centroid state lives on the model axis, so the iterator never needs to
    know about it.
    """

    def __init__(self, docs: SparseDocs, batch: int, *, seed: int = 0,
                 shuffle: bool = True, drop_remainder: bool = True,
                 sharding: jax.sharding.Sharding | None = None,
                 prefetch: int = 2):
        if drop_remainder and docs.n_docs < batch:
            raise ValueError(f"batch {batch} > corpus {docs.n_docs}")
        self.docs = docs
        self.batch = batch
        self.seed = seed
        self.shuffle = shuffle
        self.drop_remainder = drop_remainder
        self.sharding = sharding
        self.prefetch = prefetch
        self._ids = np.asarray(docs.ids)
        self._vals = np.asarray(docs.vals)
        self._nnz = np.asarray(docs.nnz)

    def __len__(self) -> int:
        n = self.docs.n_docs
        return n // self.batch if self.drop_remainder else -(-n // self.batch)

    def _order(self, epoch: int) -> np.ndarray:
        if not self.shuffle:
            return np.arange(self.docs.n_docs)
        return np.random.default_rng((self.seed, epoch)).permutation(self.docs.n_docs)

    def _make(self, order: np.ndarray, b: int) -> SparseDocs:
        sel = order[b * self.batch : (b + 1) * self.batch]
        if len(sel) < self.batch:  # pad the ragged final batch with doc 0, nnz 0
            pad = np.zeros(self.batch - len(sel), dtype=sel.dtype)
            ids = np.concatenate([self._ids[sel], self._ids[pad] * 0])
            vals = np.concatenate([self._vals[sel], self._vals[pad] * 0])
            nnz = np.concatenate([self._nnz[sel], pad.astype(np.int32) * 0])
        else:
            ids, vals, nnz = self._ids[sel], self._vals[sel], self._nnz[sel]
        put = (lambda a: jax.device_put(a, self.sharding)) if self.sharding else jnp.asarray
        return SparseDocs(ids=put(ids), vals=put(vals), nnz=put(nnz), dim=self.docs.dim)

    def epoch(self, epoch: int = 0, start_batch: int = 0) -> Iterator[SparseDocs]:
        """Prefetching iterator over one epoch, resumable at start_batch."""
        order = self._order(epoch)
        nb = len(self)
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def producer():
            try:
                for b in range(start_batch, nb):
                    q.put(self._make(order, b))
            finally:
                q.put(stop)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item
