"""Loader for the UCI "bag of words" format the paper's PubMed set uses.

Format (docword.<name>.txt, optionally gzipped)::

    N
    D
    NNZ
    docID termID count     # 1-based ids, one triple per line

Returns the same tf-idf / L2 / df-rank pipeline output as the synthetic
generator so benchmarks can run on the real corpora when available.
"""
from __future__ import annotations

import gzip

import numpy as np
import jax.numpy as jnp

from repro.sparse import SparseDocs, tf_idf, l2_normalize_rows, remap_terms_by_df, df_counts, with_df


def load_uci_bow(path: str, max_docs: int | None = None, pad_to: int | None = None):
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        n = int(f.readline())
        d = int(f.readline())
        _nnz = int(f.readline())
        triples = np.loadtxt(f, dtype=np.int64)
    if max_docs is not None:
        triples = triples[triples[:, 0] <= max_docs]
        n = min(n, max_docs)
    doc = triples[:, 0] - 1
    term = triples[:, 1] - 1
    cnt = triples[:, 2].astype(np.float32)

    order = np.lexsort((term, doc))
    doc, term, cnt = doc[order], term[order], cnt[order]
    nnz = np.bincount(doc, minlength=n).astype(np.int32)
    pad = pad_to or int(nnz.max(initial=1))
    ids = np.zeros((n, pad), np.int32)
    vals = np.zeros((n, pad), np.float32)
    starts = np.concatenate([[0], np.cumsum(nnz)[:-1]])
    for i in range(n):
        k = min(nnz[i], pad)
        ids[i, :k] = term[starts[i] : starts[i] + k]
        vals[i, :k] = cnt[starts[i] : starts[i] + k]
    docs = SparseDocs(ids=jnp.asarray(ids), vals=jnp.asarray(vals),
                      nnz=jnp.asarray(np.minimum(nnz, pad)), dim=d)
    df = df_counts(docs)
    docs = tf_idf(docs, df=df)
    docs = l2_normalize_rows(docs)
    docs, perm = remap_terms_by_df(docs, df=df)
    dfp = df[perm]                   # permuted counts == remapped corpus df
    return with_df(docs, dfp), dfp, perm
