"""ServableClusterModel: a FittedModel prepared for continuous batching.

The saxml ``ServableMethod``/``ServableModel`` shape (DESIGN.md §12): a
servable owns the three stages a request batch moves through —

  * ``pre_process``  — host-side: coalesce request rows, fit them to the
    servable's static tuple width, pick a padded batch-size *bucket* from
    ``sorted_batch_sizes`` (``get_padded_batch_size``-style selection) and
    pad with dead rows, so every device launch hits a shape that is already
    compiled after its first use;
  * ``device_compute`` — the jitted fused classify epoch (the SAME
    ``repro/cluster/classify._classify_fused`` behind ``predict`` and
    ``ClusterEngine.classify``, so server results are bit-identical to the
    direct path by construction).  Dispatch is async: the call returns
    device arrays without a host sync, which is what lets one device thread
    stay ahead of the post-processing workers;
  * ``post_process`` — host-side: block on the device result, trim the
    dead-row padding, split back per request.

Compile discipline: ``_serving_classify`` wraps the fused epoch in one
module-level jit whose trace-time side effect counts compilations per
(backend, dim, K, bucket).  Hot-swapping a refreshed index of the same
geometry therefore costs ZERO recompiles (the index is a traced argument),
and the serving benchmark ratchets per-bucket compile counts
(benchmarks/ratchet.py check_serving: no steady-state recompilation).

The servable also re-seeds the process-wide autotuner cache from the
artifact's ``tuned`` winner (repro.tune), so the serving plane inherits the
fit-time kernel configuration without re-searching.
"""
from __future__ import annotations

import bisect
import collections
import threading
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BATCH_SIZES = (8, 16, 32, 64, 128, 256)

# (backend, dim, K, bucket) -> number of jit traces.  The body of a jitted
# function runs exactly once per compilation, so incrementing here counts
# real (re)compiles — the serving ratchet's ground truth.
TRACE_COUNTS: collections.Counter = collections.Counter()
_TRACE_LOCK = threading.Lock()


@partial(jax.jit, static_argnames=("backend", "dim", "bs"))
def _serving_classify(backend: str, ids, vals, nnz, dim: int, index, bs: int):
    from repro.cluster.classify import _classify_fused

    with _TRACE_LOCK:
        TRACE_COUNTS[(backend, dim, int(index.means_t.shape[1]), bs)] += 1
    return _classify_fused(backend, ids, vals, nnz, dim, index, bs)


@partial(jax.jit, static_argnames=("backend", "dim", "bs", "cmax", "n_probe"))
def _serving_classify_routed(backend: str, ids, vals, nnz, dim: int,
                             coarse_index, means_ext, starts, sizes, bs: int,
                             cmax: int, n_probe: int):
    """The coarse-routed twin of :func:`_serving_classify` (two-level
    models, DESIGN.md §13): same trace-count key (backend, dim, K_eff,
    bucket), so the per-bucket compile ratchet covers routed serving with
    no special-casing — a two-level model costs the same one compile per
    bucket as a flat one.  The routed operands (means_ext, starts, sizes)
    are traced arguments, so a hot-swap of a same-geometry nested model
    also costs zero recompiles."""
    from repro.cluster.classify import _routed_fused

    with _TRACE_LOCK:
        TRACE_COUNTS[(backend, dim, int(means_ext.shape[1]) - 1, bs)] += 1
    a, s, _ = _routed_fused(backend, ids, vals, nnz, dim, coarse_index,
                            means_ext, starts, sizes, bs, cmax, n_probe)
    return a, s


class PreparedBatch:
    """One pre-processed request batch, ready for the device thread."""

    __slots__ = ("ids", "vals", "nnz", "n_rows", "bucket")

    def __init__(self, ids, vals, nnz, n_rows: int, bucket: int):
        self.ids, self.vals, self.nnz = ids, vals, nnz
        self.n_rows = n_rows              # live rows (<= bucket)
        self.bucket = bucket              # padded batch size actually run

    @property
    def occupancy(self) -> float:
        return self.n_rows / self.bucket


class ServableClusterModel:
    """A FittedModel wrapped for the continuous-batching service plane.

    model:       the :class:`repro.cluster.FittedModel` artifact to serve.
    batch_sizes: the padded batch-size buckets, any order (stored sorted
                 ascending as ``sorted_batch_sizes``); the largest bucket is
                 the per-launch row ceiling.
    pad_width:   static tuple width P every request is fitted to.  ``None``
                 (default) locks to the first batch's width; requests with
                 live tuples beyond the locked width fail with an error
                 naming the construction-time fix.
    backend:     accumulator engine override (defaults to the artifact's).
    """

    def __init__(self, model, *, batch_sizes=DEFAULT_BATCH_SIZES,
                 pad_width: int | None = None, backend: str | None = None):
        from repro.core.backends import resolve_backend

        sizes = tuple(sorted({int(b) for b in batch_sizes}))
        if not sizes or sizes[0] < 1:
            raise ValueError(f"batch_sizes must be positive, got {batch_sizes}")
        self.model = model
        self.index = model.index
        self.sorted_batch_sizes = sizes
        self.backend = backend or model.backend
        resolve_backend(self.backend)
        self._pad_width = None if pad_width is None else int(pad_width)
        self.dim = int(self.index.dim)
        self.k = int(self.index.k)
        # Serving inherits the fit's autotuned kernel config: reseed the
        # process-wide cache from the artifact (the same reseed
        # FittedModel.load performs — repeated here so in-memory hand-offs
        # fit→serve get it too).
        tuned = getattr(model, "tuned", None)
        if tuned and tuned.get("signature"):
            from repro.tune import TUNED_CACHE, TunedConfig

            TUNED_CACHE.put(tuned["signature"], TunedConfig.from_dict(tuned))
        # Two-level artifacts serve through the coarse-routed epoch unless
        # they probe every cell (n_probe = K_c IS the flat scan — run it on
        # the flat fast path, which is also what keeps it bit-identical to
        # flat serving on every backend).
        self._routed_ops = None
        self.n_probe = int(getattr(model, "n_probe", 0) or 0)
        if (getattr(model, "coarse_index", None) is not None
                and self.n_probe < model.coarse_k):
            self._routed_ops = model._routed_operands()

    # -- bucket selection ---------------------------------------------------
    @property
    def max_batch_size(self) -> int:
        return self.sorted_batch_sizes[-1]

    @property
    def pad_width(self) -> int | None:
        return self._pad_width

    def get_padded_batch_size(self, n_rows: int) -> int:
        """Smallest bucket >= n_rows (the saxml selection rule).  The
        batcher never assembles past ``max_batch_size``, so a larger n is a
        caller bug and raises."""
        if n_rows < 1:
            raise ValueError(f"n_rows must be >= 1, got {n_rows}")
        i = bisect.bisect_left(self.sorted_batch_sizes, n_rows)
        if i == len(self.sorted_batch_sizes):
            raise ValueError(
                f"{n_rows} rows exceed the largest bucket "
                f"{self.max_batch_size}; split the request or construct the "
                f"servable with a larger batch_sizes ceiling")
        return self.sorted_batch_sizes[i]

    # -- the three stages -----------------------------------------------------
    def _fit_width(self, ids, vals, nnz):
        """Fit (r, P_in) rows to the servable's static width (host-side)."""
        p_in = ids.shape[1]
        if self._pad_width is None:
            self._pad_width = p_in
        p = self._pad_width
        if p_in == p:
            return ids, vals
        if p_in < p:
            wide_i = np.zeros((ids.shape[0], p), np.int32)
            wide_v = np.zeros((ids.shape[0], p), np.float32)
            wide_i[:, :p_in], wide_v[:, :p_in] = ids, vals
            return wide_i, wide_v
        if int(nnz.max(initial=0)) > p:
            raise ValueError(
                f"request rows carry up to {int(nnz.max())} live tuples but "
                f"this servable is locked to pad_width={p}; construct it "
                f"with pad_width>={int(nnz.max())}")
        # Rows are prefix-packed (live tuples occupy slots [0, nnz)), so a
        # narrowing slice only drops dead padding.
        return ids[:, :p], vals[:, :p]

    def pre_process(self, rows) -> PreparedBatch:
        """rows: list of (ids (r_i, P_i) int32, vals (r_i, P_i) float32,
        nnz (r_i,) int32) numpy triples (one per request) → PreparedBatch
        padded to the selected bucket with dead rows (nnz = 0, the repo-wide
        inert-row convention)."""
        fitted = [self._fit_width(np.asarray(i, np.int32),
                                  np.asarray(v, np.float32),
                                  np.asarray(z, np.int32)) + (np.asarray(z, np.int32),)
                  for i, v, z in rows]
        ids = np.concatenate([f[0] for f in fitted])
        vals = np.concatenate([f[1] for f in fitted])
        nnz = np.concatenate([f[2] for f in fitted])
        n = ids.shape[0]
        bucket = self.get_padded_batch_size(n)
        if n < bucket:
            pad = bucket - n
            ids = np.concatenate([ids, np.zeros((pad, ids.shape[1]), np.int32)])
            vals = np.concatenate([vals,
                                   np.zeros((pad, vals.shape[1]), np.float32)])
            nnz = np.concatenate([nnz, np.zeros((pad,), np.int32)])
        return PreparedBatch(ids, vals, nnz, n, bucket)

    def device_compute(self, batch: PreparedBatch):
        """Launch the fused classify epoch for one prepared batch.  Returns
        the (assign, sims) DEVICE arrays without a host sync — jax dispatch
        is async, so the device thread moves on to the next batch while this
        one computes.  Two-level models launch the coarse-routed twin
        instead (same async discipline, same one-compile-per-bucket)."""
        if self._routed_ops is not None:
            coarse_index, means_ext, starts, sizes, cmax = self._routed_ops
            return _serving_classify_routed(
                self.backend, jnp.asarray(batch.ids),
                jnp.asarray(batch.vals), jnp.asarray(batch.nnz), self.dim,
                coarse_index, means_ext, starts, sizes, batch.bucket,
                cmax, self.n_probe)
        return _serving_classify(self.backend, jnp.asarray(batch.ids),
                                 jnp.asarray(batch.vals),
                                 jnp.asarray(batch.nnz), self.dim,
                                 self.index, batch.bucket)

    def post_process(self, out, n_rows: int):
        """Block on the device result and trim the dead-row padding."""
        a, s = out
        return (np.asarray(a)[:n_rows].astype(np.int32),
                np.asarray(s)[:n_rows].astype(np.float32))

    # -- introspection --------------------------------------------------------
    def compile_counts(self) -> dict[int, int]:
        """{bucket: jit traces} for this servable's geometry.  Steady-state
        serving must keep every bucket at <= 1 (ratcheted by
        ``check_serving``); a hot-swap of same-geometry means costs zero."""
        with _TRACE_LOCK:
            return {b: TRACE_COUNTS[(self.backend, self.dim, self.k, b)]
                    for b in self.sorted_batch_sizes}
