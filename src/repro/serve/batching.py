"""Request queue + continuous batcher for the cluster serving plane.

Thread discipline (DESIGN.md §12) — per loaded model, ONE batching thread;
per server, ONE device thread and a small post-processing pool:

  batching thread   pulls requests off the model's bounded queue, coalesces
                    them greedily (up to the largest bucket, waiting at most
                    ``batch_timeout_s`` after the first request), snapshots
                    the model's *current* servable ONCE per batch (the
                    hot-swap atomicity point), acquires a live-batch slot
                    (``max_live_batches`` admission control — the thread
                    blocks here while the device is saturated, which is what
                    backpressures the queue), pre-processes on the host, and
                    hands the batch to the device thread;
  device thread     launches ``servable.device_compute`` — an *async* jax
                    dispatch, no host sync — so it is never the stage that
                    waits for results;
  post workers      block on the device arrays (the only host syncs in the
                    plane), split them back per request, resolve the
                    caller futures, and release the live-batch slot.

A batch carries a reference to the exact servable it was assembled against,
so a registry hot-swap mid-flight is invisible: in-flight batches complete
on the pre-swap index while newly assembled batches route to the new one —
no request ever observes a torn index (tests/test_serving.py).
"""
from __future__ import annotations

import queue
import threading
import time

import numpy as np


class ServerClosed(RuntimeError):
    """Raised into futures whose request can no longer be served."""


class ClassifyFuture:
    """Caller-side handle for one submitted classify request.

    A large request may be split across several batches (parts); the future
    resolves when every part has.  ``result`` returns (assign (N,) int32,
    sims (N,) float32) in the request's row order.
    """

    def __init__(self, n_parts: int = 1):
        self._n_parts = n_parts
        self._parts: dict[int, tuple] = {}
        self._exc: BaseException | None = None
        self._event = threading.Event()
        self._lock = threading.Lock()

    def _set_part(self, i: int, assign, sims):
        with self._lock:
            self._parts[i] = (assign, sims)
            if len(self._parts) == self._n_parts and self._exc is None:
                self._event.set()

    def _set_exception(self, exc: BaseException):
        with self._lock:
            if self._exc is None:
                self._exc = exc
            self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError("classify request did not complete in time")
        if self._exc is not None:
            raise self._exc
        parts = [self._parts[i] for i in range(self._n_parts)]
        if self._n_parts == 1:
            return parts[0]
        return (np.concatenate([p[0] for p in parts]),
                np.concatenate([p[1] for p in parts]))


class _Request:
    """One batchable unit: <= max bucket rows bound for one future part."""

    __slots__ = ("ids", "vals", "nnz", "n_rows", "future", "part", "t_enq")

    def __init__(self, ids, vals, nnz, future: ClassifyFuture, part: int):
        self.ids, self.vals, self.nnz = ids, vals, nnz
        self.n_rows = int(ids.shape[0])
        self.future = future
        self.part = part
        self.t_enq = time.monotonic()


class _LiveBatch:
    """A batch in flight: the servable it was assembled against + payload."""

    __slots__ = ("batcher", "servable", "prepared", "requests", "out")

    def __init__(self, batcher, servable, prepared, requests):
        self.batcher = batcher
        self.servable = servable
        self.prepared = prepared
        self.requests = requests
        self.out = None


class ServingStats:
    """Lock-protected serving counters (snapshot() for the benchmark)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.n_requests = 0
        self.n_rows = 0
        self.n_failures = 0
        self.n_batches = 0
        self.live_batches = 0
        self.peak_live_batches = 0
        self._buckets: dict[int, list] = {}   # bucket -> [batches, sum_occ]
        self._lat_sum = 0.0

    def batch_started(self, bucket: int, occupancy: float):
        with self._lock:
            self.n_batches += 1
            self.live_batches += 1
            self.peak_live_batches = max(self.peak_live_batches,
                                         self.live_batches)
            b = self._buckets.setdefault(bucket, [0, 0.0])
            b[0] += 1
            b[1] += occupancy

    def batch_finished(self, requests, failed: bool):
        now = time.monotonic()
        with self._lock:
            self.live_batches -= 1
            for r in requests:
                self.n_requests += 1
                self.n_rows += r.n_rows
                self._lat_sum += now - r.t_enq
                if failed:
                    self.n_failures += 1

    def requests_failed(self, requests):
        """Requests that died before their batch was ever recorded live."""
        with self._lock:
            for r in requests:
                self.n_requests += 1
                self.n_rows += r.n_rows
                self.n_failures += 1

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "n_requests": self.n_requests,
                "n_rows": self.n_rows,
                "n_failures": self.n_failures,
                "n_batches": self.n_batches,
                "live_batches": self.live_batches,
                "peak_live_batches": self.peak_live_batches,
                "mean_server_latency_ms": (
                    1e3 * self._lat_sum / self.n_requests
                    if self.n_requests else 0.0),
                "occupancy": {
                    str(b): {"batches": n, "mean_occupancy": s / n}
                    for b, (n, s) in sorted(self._buckets.items())},
            }


_STOP = object()


class ContinuousBatcher:
    """Per-model request queue + batching thread (see module docstring).

    get_servable:     zero-arg callable returning the model's CURRENT
                      servable (the registry's atomic read) — called once
                      per assembled batch.
    dispatch:         callable(_LiveBatch) handing the pre-processed batch
                      to the server's device thread.
    max_live_batches: admission control — at most this many batches between
                      slot-acquire (batch assembly) and slot-release (post
                      processing done).
    queue_depth:      bounded request queue; a full queue blocks (or, with
                      ``submit(block=False)``, rejects) new admissions.
    """

    def __init__(self, name: str, get_servable, dispatch, *,
                 max_live_batches: int = 4, batch_timeout_s: float = 0.002,
                 queue_depth: int = 1024):
        if max_live_batches < 1:
            raise ValueError(f"max_live_batches must be >= 1, "
                             f"got {max_live_batches}")
        self.name = name
        self.get_servable = get_servable
        self.dispatch = dispatch
        self.batch_timeout_s = float(batch_timeout_s)
        self.queue = queue.Queue(maxsize=queue_depth)
        self.slots = threading.Semaphore(max_live_batches)
        self.max_live_batches = max_live_batches
        self.stats = ServingStats()
        self._carry: _Request | None = None
        self._stopped = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"batcher:{name}")
        self._thread.start()

    # -- admission ----------------------------------------------------------
    def submit(self, request: _Request, *, block: bool = True,
               timeout: float | None = None):
        if self._stopped.is_set():
            raise ServerClosed(f"model {self.name!r} is no longer served")
        try:
            self.queue.put(request, block=block, timeout=timeout)
        except queue.Full:
            raise ServerClosed(
                f"model {self.name!r}: request queue full "
                f"({self.queue.maxsize} pending) — the server is "
                f"backpressuring; retry or raise queue_depth") from None

    # -- batch assembly -----------------------------------------------------
    def _next_request(self, deadline: float | None):
        if self._carry is not None:
            r, self._carry = self._carry, None
            return r
        try:
            if deadline is None:
                return self.queue.get(timeout=0.05)
            left = deadline - time.monotonic()
            if left <= 0:
                return self.queue.get_nowait()
            return self.queue.get(timeout=left)
        except queue.Empty:
            return None

    def _run(self):
        while not self._stopped.is_set():
            first = self._next_request(None)
            if first is None:
                continue
            if first is _STOP:
                break
            servable = self.get_servable()     # hot-swap atomicity point
            max_rows = servable.max_batch_size
            reqs, rows = [first], first.n_rows
            deadline = time.monotonic() + self.batch_timeout_s
            while rows < max_rows:
                nxt = self._next_request(deadline)
                if nxt is None:
                    break
                if nxt is _STOP:
                    self._stopped.set()
                    break
                if rows + nxt.n_rows > max_rows:
                    self._carry = nxt          # head-of-line for next batch
                    break
                reqs.append(nxt)
                rows += nxt.n_rows
            self.slots.acquire()               # max_live_batches admission
            try:
                prepared = servable.pre_process(
                    [(r.ids, r.vals, r.nnz) for r in reqs])
                self.stats.batch_started(prepared.bucket, prepared.occupancy)
                self.dispatch(_LiveBatch(self, servable, prepared, reqs))
            except BaseException as e:
                self.fail_batch(reqs, e, started=False)
        self._drain()

    # -- completion paths (called from the post workers / device thread) ----
    def finish_batch(self, live: _LiveBatch):
        try:
            a, s = live.servable.post_process(live.out, live.prepared.n_rows)
            off = 0
            for r in live.requests:
                r.future._set_part(r.part, a[off:off + r.n_rows],
                                   s[off:off + r.n_rows])
                off += r.n_rows
            self.stats.batch_finished(live.requests, failed=False)
        except BaseException as e:
            for r in live.requests:
                r.future._set_exception(e)
            self.stats.batch_finished(live.requests, failed=True)
        finally:
            self.slots.release()

    def fail_batch(self, requests, exc: BaseException, *,
                   started: bool = True):
        """Fail every request of a batch; ``started`` says whether the batch
        was already recorded live (post-assembly failure) or died during
        pre-processing (never counted a live slot in the stats)."""
        for r in requests:
            r.future._set_exception(exc)
        if started:
            self.stats.batch_finished(requests, failed=True)
        else:
            self.stats.requests_failed(requests)
        self.slots.release()

    # -- shutdown -----------------------------------------------------------
    def _drain(self):
        """Fail whatever is still queued once the batcher stops."""
        leftovers = [] if self._carry is None else [self._carry]
        self._carry = None
        while True:
            try:
                r = self.queue.get_nowait()
            except queue.Empty:
                break
            if r is not _STOP:
                leftovers.append(r)
        exc = ServerClosed(f"model {self.name!r} unloaded before the "
                           f"request was batched")
        for r in leftovers:
            r.future._set_exception(exc)

    def stop(self):
        """Stop assembling batches (in-flight batches still complete)."""
        self._stopped.set()
        self.queue.put(_STOP)
        self._thread.join()
        self._drain()
