"""Serving engines: LM prefill/decode steps and cluster classification.

LM shapes contract (matches the assigned input-shape grid):
  prefill_*  → prefill_fn(params, tokens (B, S))            -> logits (B, V)
  decode_* / long_* → decode_fn(params, cache, tok (B,1), pos) -> (logits, cache)

The decode cache is pre-allocated at seq_len (rotating window caches stay at
min(window, seq_len)); the dry-run lowers decode_fn against cache_specs, so
full-size caches are never allocated on the host.

:class:`ClusterEngine` is the k-means analogue: a frozen mean-inverted index
served as a lookup service, with the assignment accumulators produced by a
pluggable backend (core/backends.py) — the same engine the Lloyd loop uses,
and the same fused classify path (repro/cluster/classify.py) behind
``SphericalKMeans.predict``.  ``refit`` treats index (re)construction as a
first-class serving operation (the SIVF companion paper's stance): one
backend-owned update phase rebuilds the frozen index from a fresh corpus
without a full training fit.  ``ClusterEngine.from_model(model)`` /
``engine.to_model()`` close the train→serve→refit loop on the one
:class:`repro.cluster.FittedModel` artifact.
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forward, decode_forward, init_cache
from repro.models.config import ModelConfig
from repro.models.transformer import _logits


def make_prefill_fn(cfg: ModelConfig):
    def prefill(params, tokens, frontend_embeds=None):
        h = forward(params, tokens, cfg, frontend_embeds=frontend_embeds,
                    remat=False)
        logits = _logits(params, h[:, -1:, :], cfg)      # next-token head only
        return logits[:, 0, :cfg.vocab]
    return prefill


def make_decode_fn(cfg: ModelConfig):
    def decode(params, cache, token, pos):
        return decode_forward(params, cache, token, pos, cfg)
    return decode


def _classify_fused(backend, ids, vals, nnz, dim, index, bs):
    """The fused classification epoch, now shared with predict/transform —
    see repro/cluster/classify.py (imported lazily: repro.cluster re-exports
    this module's ClusterEngine, so a module-level import would cycle)."""
    from repro.cluster.classify import _classify_fused as impl
    return impl(backend, ids, vals, nnz, dim, index, bs)


@partial(jax.jit, static_argnames=("backend", "k", "dim"))
def _rebuild_index(backend: str, ids, vals, nnz, assign, dim: int, index,
                   k: int):
    """Backend-owned update phase against a frozen index: cluster sums →
    unit-norm means → fresh MeanIndex (+ refreshed per-doc ρ), one jitted
    call, no host round-trips between the phases."""
    from repro.core.backends import resolve_backend
    from repro.core.meanindex import build_mean_index, normalized_means

    bk = resolve_backend(backend)
    live = jnp.arange(ids.shape[1])[None, :] < nnz[:, None]
    mvals = jnp.where(live, vals, 0.0)
    lam = bk.accumulate_means(ids, mvals, assign, k=k, dim=dim)
    means = normalized_means(lam, index.means_t)
    # A rebuild is a fresh index: every centroid is 'moving' (no ICP history
    # carries across corpora), matching build_mean_index's default.
    rebuilt = build_mean_index(means, index.params)
    rho = bk.self_sims(ids, mvals, assign, rebuilt.means_t)
    return rebuilt, rho


class ClusterEngine:
    """Classify documents against a frozen MeanIndex (serving mode).

    The single-host sibling of ``distributed.kmeans.make_assign_fn``: no
    update step, no ICP state, one device→host sync per request batch.

    Construct from the fitted-model artifact —
    ``ClusterEngine.from_model(model)`` — which also inherits the model's
    backend.  Passing a raw MeanIndex still works but is deprecated: an
    index without provenance cannot round-trip through ``to_model``'s
    save/refit loop losslessly.

    backend: 'reference' | 'pallas' | 'auto' — accumulator engine,
    identical semantics to ``SphericalKMeans(backend=...)``.
    """

    def __init__(self, index=None, *, model=None, backend: str | None = None,
                 batch_size: int = 4096):
        from repro.cluster.model import FittedModel

        if model is None and isinstance(index, FittedModel):
            model, index = index, None
        if model is not None:
            if index is not None:
                raise TypeError("pass a FittedModel or an index, not both")
            self._source = model
            self.index = model.index
            backend = backend or model.backend
        else:
            if index is None:
                raise TypeError("ClusterEngine needs a FittedModel (or, "
                                "deprecated, a raw MeanIndex)")
            warnings.warn(
                "ClusterEngine(index) is deprecated: build the engine from "
                "the fitted-model artifact — ClusterEngine.from_model(model) "
                "(repro.cluster).", DeprecationWarning, stacklevel=2)
            self._source = None
            self.index = index
        # Front-door validation, the serving twin of ClusterConfig.validate():
        # an unknown backend or a degenerate batch fails at construction,
        # not on the first classify/refit request.
        from repro.core.backends import resolve_backend

        self.backend = backend or "auto"
        resolve_backend(self.backend)
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.batch_size = batch_size
        self._last_assign = None
        self._last_rho = None

    @classmethod
    def from_model(cls, model, *, backend: str | None = None,
                   batch_size: int = 4096) -> ClusterEngine:
        """The serving runtime over a FittedModel artifact (train→serve)."""
        return cls(model=model, backend=backend, batch_size=batch_size)

    def to_model(self):
        """Export the engine's current index as a FittedModel (serve→refit):
        after ``refit``, the artifact carries the rebuilt index plus the last
        refit's membership/ρ — ready to ``save`` or to seed another runtime.
        """
        import dataclasses as _dc

        from repro.cluster.model import FittedModel

        labels = (self._last_assign if self._last_assign is not None
                  else np.zeros((0,), np.int32))
        rho = (self._last_rho if self._last_rho is not None
               else np.zeros((0,), np.float32))
        if self._source is not None:
            if self._last_assign is None:
                labels, rho = self._source.labels, self._source.rho_self
            return _dc.replace(self._source, index=self.index, labels=labels,
                               rho_self=rho, backend=self.backend)
        return FittedModel(index=self.index, labels=labels, rho_self=rho,
                           backend=self.backend, strategy="serving")

    def classify(self, docs):
        """docs: SparseDocs | DocStore -> (assign (N,) int32, sims (N,)).

        The same fused path as ``SphericalKMeans.predict`` /
        ``FittedModel.predict`` (repro/cluster/classify.py).  An
        out-of-core :class:`repro.sparse.DocStore` streams chunk by chunk
        through the prefetcher — the engine can classify corpora larger
        than device memory."""
        from repro.cluster.classify import classify_docs

        return classify_docs(self.index, docs, backend=self.backend,
                             batch_size=self.batch_size)

    def refit(self, docs, *, n_iter: int = 1):
        """Rebuild the frozen index from a fresh corpus (SIVF-style index
        reconstruction): classify → backend-owned update phase (cluster
        sums, L2 normalise, index rebuild) — per round.

        Empty clusters keep their previous centroid, so a small refit batch
        cannot wipe out the index.  Returns (assign (N,) int32, rho (N,)
        float32): ``assign`` is the membership the final rebuild consumed
        (classified against the pre-rebuild index, the Lloyd convention);
        ``rho`` is each document's similarity refreshed against the
        *rebuilt* means — exactly what the update step hands the next
        assignment as its pruning threshold.
        """
        from repro.sparse import pad_rows

        if docs.n_docs == 0:
            raise ValueError("refit needs a non-empty corpus")
        bs = min(self.batch_size, docs.n_docs)
        pdocs = pad_rows(docs, bs)
        n = docs.n_docs
        rho = None
        for _ in range(max(n_iter, 1)):
            a, _ = _classify_fused(self.backend, pdocs.ids, pdocs.vals,
                                   pdocs.nnz, pdocs.dim, self.index, bs)
            # Padding rows carry assign = K: they select no centroid column
            # in either backend's update accumulator.
            a = jnp.where(jnp.arange(pdocs.n_docs) < n, a, self.index.k)
            self.index, rho = _rebuild_index(self.backend, pdocs.ids,
                                             pdocs.vals, pdocs.nnz, a,
                                             pdocs.dim, self.index,
                                             self.index.k)
        self._last_assign = np.asarray(a)[:n]
        self._last_rho = np.asarray(rho)[:n]
        return self._last_assign, self._last_rho


class ServeLoop:
    """Minimal batched serving driver (greedy) for the runnable examples."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill_fn(cfg))
        self._decode = jax.jit(make_decode_fn(cfg))

    def generate(self, prompts: jnp.ndarray, n_new: int = 16):
        """prompts: (B, S0) int32 -> (B, S0 + n_new) greedy continuation."""
        b, s0 = prompts.shape
        cache = init_cache(self.cfg, b, self.max_len)
        # teacher-forced cache warmup via the decode path (exact, if slow);
        # a fused prefill-with-cache is the §Perf hillclimb variant.
        tok = prompts[:, :1]
        out = [prompts]
        for pos in range(s0 + n_new - 1):
            logits, cache = self._decode(self.params, cache, tok, jnp.asarray(pos))
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            tok = prompts[:, pos + 1:pos + 2] if pos + 1 < s0 else nxt
            if pos + 1 >= s0:
                out.append(nxt)
        return jnp.concatenate(out, axis=1)
