"""Cluster serving engine: classify/refit against a frozen MeanIndex.

:class:`ClusterEngine` is the k-means serving runtime: a frozen
mean-inverted index served as a lookup service, with the assignment
accumulators produced by a pluggable backend (core/backends.py) — the same
engine the Lloyd loop uses, and the same fused classify path
(repro/cluster/classify.py) behind ``SphericalKMeans.predict``.  ``refit``
treats index (re)construction as a first-class serving operation (the SIVF
companion paper's stance): one backend-owned update phase rebuilds the
frozen index from a fresh corpus — resident SparseDocs or a chunk-streamed
DocStore — without a full training fit.  ``ClusterEngine.from_model(model)``
/ ``engine.to_model()`` close the train→serve→refit loop on the one
:class:`repro.cluster.FittedModel` artifact, and ``engine.serve()`` lifts
the artifact into the continuous-batching service plane
(serve/server.py, DESIGN.md §12).

The LM template surfaces (``ServeLoop``/``make_prefill_fn``/
``make_decode_fn``) live in :mod:`repro.serve.lm`; this module imports no
``repro.models`` code.
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _classify_fused(backend, ids, vals, nnz, dim, index, bs):
    """The fused classification epoch, now shared with predict/transform —
    see repro/cluster/classify.py (imported lazily: repro.cluster re-exports
    this module's ClusterEngine, so a module-level import would cycle)."""
    from repro.cluster.classify import _classify_fused as impl
    return impl(backend, ids, vals, nnz, dim, index, bs)


@partial(jax.jit, static_argnames=("backend", "k", "dim"))
def _rebuild_index(backend: str, ids, vals, nnz, assign, dim: int, index,
                   k: int):
    """Backend-owned update phase against a frozen index: cluster sums →
    unit-norm means → fresh MeanIndex (+ refreshed per-doc ρ), one jitted
    call, no host round-trips between the phases."""
    from repro.core.backends import resolve_backend
    from repro.core.meanindex import build_mean_index, normalized_means

    bk = resolve_backend(backend)
    live = jnp.arange(ids.shape[1])[None, :] < nnz[:, None]
    mvals = jnp.where(live, vals, 0.0)
    lam = bk.accumulate_means(ids, mvals, assign, k=k, dim=dim)
    means = normalized_means(lam, index.means_t)
    # A rebuild is a fresh index: every centroid is 'moving' (no ICP history
    # carries across corpora), matching build_mean_index's default.
    rebuilt = build_mean_index(means, index.params)
    rho = bk.self_sims(ids, mvals, assign, rebuilt.means_t)
    return rebuilt, rho


@partial(jax.jit, static_argnames=("backend", "k", "dim", "bs"))
def _refit_chunk_accumulate(backend: str, ids, vals, nnz, valid, dim: int,
                            index, bs: int, k: int, lam):
    """One streaming-refit chunk: classify vs the pre-round index, mask the
    dead tail (assign = K selects no centroid column in either backend's
    accumulator), fold the chunk's cluster sums into the running λ."""
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    a, _ = _classify_fused(backend, ids, vals, nnz, dim, index, bs)
    a = jnp.where(valid, a, k)
    live = jnp.arange(ids.shape[1])[None, :] < nnz[:, None]
    mvals = jnp.where(live, vals, 0.0)
    return a, bk.accumulate_means(ids, mvals, a, k=k, dim=dim, init=lam)


@partial(jax.jit, static_argnames=("backend",))
def _refit_chunk_rho(backend: str, ids, vals, nnz, assign, means_t):
    """ρ refresh for one chunk vs the *rebuilt* means (Alg. 6 lines 6–7)."""
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    live = jnp.arange(ids.shape[1])[None, :] < nnz[:, None]
    return bk.self_sims(ids, jnp.where(live, vals, 0.0), assign, means_t)


@jax.jit
def _rebuild_from_sums(lam, index):
    """λ (K, D) cluster sums → fresh MeanIndex (empty clusters keep their
    previous unit-norm centroid, the streaming twin of _rebuild_index)."""
    from repro.core.meanindex import build_mean_index, normalized_means

    return build_mean_index(normalized_means(lam, index.means_t),
                            index.params)


class ClusterEngine:
    """Classify documents against a frozen MeanIndex (serving mode).

    The single-host sibling of ``distributed.kmeans.make_assign_fn``: no
    update step, no ICP state, one device→host sync per request batch.

    Construct from the fitted-model artifact —
    ``ClusterEngine.from_model(model)`` — which also inherits the model's
    backend.  Passing a raw MeanIndex still works but is deprecated: an
    index without provenance cannot round-trip through ``to_model``'s
    save/refit loop losslessly.

    backend: 'reference' | 'pallas' | 'auto' — accumulator engine,
    identical semantics to ``SphericalKMeans(backend=...)``.
    """

    def __init__(self, index=None, *, model=None, backend: str | None = None,
                 batch_size: int = 4096):
        from repro.cluster.model import FittedModel

        if model is None and isinstance(index, FittedModel):
            model, index = index, None
        if model is not None:
            if index is not None:
                raise TypeError("pass a FittedModel or an index, not both")
            self._source = model
            self.index = model.index
            backend = backend or model.backend
        else:
            if index is None:
                raise TypeError("ClusterEngine needs a FittedModel (or, "
                                "deprecated, a raw MeanIndex)")
            warnings.warn(
                "ClusterEngine(index) is deprecated: build the engine from "
                "the fitted-model artifact — ClusterEngine.from_model(model) "
                "(repro.cluster).", DeprecationWarning, stacklevel=2)
            self._source = None
            self.index = index
        # Front-door validation, the serving twin of ClusterConfig.validate():
        # an unknown backend or a degenerate batch fails at construction,
        # not on the first classify/refit request.
        from repro.core.backends import resolve_backend

        self.backend = backend or "auto"
        resolve_backend(self.backend)
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.batch_size = batch_size
        self._last_assign = None
        self._last_rho = None

    @classmethod
    def from_model(cls, model, *, backend: str | None = None,
                   batch_size: int = 4096) -> ClusterEngine:
        """The serving runtime over a FittedModel artifact (train→serve)."""
        return cls(model=model, backend=backend, batch_size=batch_size)

    def to_model(self):
        """Export the engine's current index as a FittedModel (serve→refit):
        after ``refit``, the artifact carries the rebuilt index plus the last
        refit's membership/ρ — ready to ``save`` or to seed another runtime.
        """
        import dataclasses as _dc

        from repro.cluster.model import FittedModel

        labels = (self._last_assign if self._last_assign is not None
                  else np.zeros((0,), np.int32))
        rho = (self._last_rho if self._last_rho is not None
               else np.zeros((0,), np.float32))
        if self._source is not None:
            if self._last_assign is None:
                labels, rho = self._source.labels, self._source.rho_self
            return _dc.replace(self._source, index=self.index, labels=labels,
                               rho_self=rho, backend=self.backend)
        return FittedModel(index=self.index, labels=labels, rho_self=rho,
                           backend=self.backend, strategy="serving")

    def serve(self, *, name: str = "default", **server_kw):
        """Lift this engine's artifact into a running continuous-batching
        :class:`repro.serve.ClusterServer` hosting it under ``name``
        (DESIGN.md §12).  Extra kwargs reach the server constructor
        (``max_live_batches``, ``batch_timeout_s``, …); the servable
        inherits this engine's backend and batch ceiling.  Callers own the
        returned server's lifecycle (``close()`` / context manager)."""
        from repro.serve.server import ClusterServer

        server = ClusterServer(**server_kw)
        try:
            server.load(name, self.to_model(), backend=self.backend)
        except BaseException:
            server.close()
            raise
        return server

    def _two_level_source(self):
        """The nested artifact when this engine serves one, else None
        (duck-typed on the coarse index so the engine stays import-light)."""
        return (self._source if getattr(self._source, "coarse_index", None)
                is not None else None)

    def classify(self, docs, *, n_probe: int | None = None):
        """docs: SparseDocs | DocStore -> (assign (N,) int32, sims (N,)).

        The same fused path as ``SphericalKMeans.predict`` /
        ``FittedModel.predict`` (repro/cluster/classify.py).  An
        out-of-core :class:`repro.sparse.DocStore` streams chunk by chunk
        through the prefetcher — the engine can classify corpora larger
        than device memory.

        An engine built from a nested :class:`TwoLevelFittedModel` routes
        through the coarse level (classify_docs_routed, DESIGN.md §13):
        per object it scores K_c coarse means plus only the probed cells'
        fine means — the web-scale ANN path.  ``n_probe`` overrides the
        model's probe width for this call (n_probe = K_c is exact and IS
        the flat scan); flat engines reject the override."""
        from repro.cluster.classify import classify_docs, classify_docs_routed

        two_level = self._two_level_source()
        if two_level is not None:
            return classify_docs_routed(two_level, docs, n_probe=n_probe,
                                        backend=self.backend,
                                        batch_size=self.batch_size)
        if n_probe is not None:
            raise ValueError("n_probe only applies to an engine serving a "
                             "two-level model")
        return classify_docs(self.index, docs, backend=self.backend,
                             batch_size=self.batch_size)

    def refit(self, docs, *, n_iter: int = 1):
        """Rebuild the frozen index from a fresh corpus (SIVF-style index
        reconstruction): classify → backend-owned update phase (cluster
        sums, L2 normalise, index rebuild) — per round.

        ``docs`` may be a resident SparseDocs or an out-of-core
        :class:`repro.sparse.DocStore`: a store streams chunk by chunk
        (classify + λ accumulation per chunk, ONE index rebuild per round,
        then a ρ-refresh pass vs the rebuilt means) exactly like
        ``classify`` already does, so a refit corpus need not fit on the
        device either.

        Empty clusters keep their previous centroid, so a small refit batch
        cannot wipe out the index.  Returns (assign (N,) int32, rho (N,)
        float32): ``assign`` is the membership the final rebuild consumed
        (classified against the pre-rebuild index, the Lloyd convention);
        ``rho`` is each document's similarity refreshed against the
        *rebuilt* means — exactly what the update step hands the next
        assignment as its pruning threshold.
        """
        from repro.sparse import pad_rows
        from repro.sparse.store import DocStore

        if self._two_level_source() is not None:
            # A flat rebuild would move fine means out from under the frozen
            # coarse quantizer (and the routed operand cache), silently
            # degrading routing; re-fit through the two_level strategy
            # instead of corrupting the nesting in place.
            raise NotImplementedError(
                "refit is not supported on a two-level model: the flat "
                "update phase cannot maintain the coarse level; run a fresh "
                "fit with ClusterConfig(coarse_k=...) and hot-swap it")
        if isinstance(docs, DocStore):
            return self._refit_store(docs, n_iter=n_iter)
        if docs.n_docs == 0:
            raise ValueError("refit needs a non-empty corpus")
        bs = min(self.batch_size, docs.n_docs)
        pdocs = pad_rows(docs, bs)
        n = docs.n_docs
        rho = None
        for _ in range(max(n_iter, 1)):
            a, _ = _classify_fused(self.backend, pdocs.ids, pdocs.vals,
                                   pdocs.nnz, pdocs.dim, self.index, bs)
            # Padding rows carry assign = K: they select no centroid column
            # in either backend's update accumulator.
            a = jnp.where(jnp.arange(pdocs.n_docs) < n, a, self.index.k)
            self.index, rho = _rebuild_index(self.backend, pdocs.ids,
                                             pdocs.vals, pdocs.nnz, a,
                                             pdocs.dim, self.index,
                                             self.index.k)
        self._last_assign = np.asarray(a)[:n]
        self._last_rho = np.asarray(rho)[:n]
        return self._last_assign, self._last_rho

    def _refit_store(self, store, *, n_iter: int = 1):
        """Chunk-streamed refit over a DocStore: per round, one prefetched
        pass classifies each chunk against the pre-round index and folds its
        cluster sums into λ on device; the index rebuilds ONCE from the full
        λ; a second prefetched pass refreshes ρ against the rebuilt means.
        Between the passes only the per-document assignment (4 bytes/doc)
        stays on the host — chunks never pile up on device.  Chunk-order
        independent by construction (λ accumulation commutes), and
        bitwise-identical to the resident ``refit`` for a one-chunk store
        (parity-tested in tests/test_serving.py)."""
        from repro.cluster.classify import _store_tiles
        from repro.sparse.store import ChunkPrefetcher

        if store.n_docs == 0:
            raise ValueError("refit needs a non-empty corpus")
        k, n = self.index.k, store.n_docs
        bs, padder = _store_tiles(store, self.batch_size)
        assigns = None
        for _ in range(max(n_iter, 1)):
            lam = jnp.zeros((k, self.index.dim), jnp.float32)
            chunk_assign = []           # host-side (padded-C,) per chunk
            for ci, cdocs in ChunkPrefetcher(store):
                cdocs = padder(cdocs)
                valid = np.zeros((cdocs.n_docs,), bool)
                valid[:store.chunk_size] = store.chunk_valid(ci)
                a, lam = _refit_chunk_accumulate(
                    self.backend, cdocs.ids, cdocs.vals, cdocs.nnz,
                    jnp.asarray(valid), store.dim, self.index, bs, k, lam)
                chunk_assign.append(np.asarray(a))
            self.index = _rebuild_from_sums(lam, self.index)
            assigns, rhos = [], []
            for ci, cdocs in ChunkPrefetcher(store):
                cdocs = padder(cdocs)
                a = chunk_assign[ci]
                rho = _refit_chunk_rho(self.backend, cdocs.ids, cdocs.vals,
                                       cdocs.nnz, jnp.asarray(a),
                                       self.index.means_t)
                assigns.append(a[:store.chunk_size])
                rhos.append(np.asarray(rho)[:store.chunk_size])
        self._last_assign = np.concatenate(assigns)[:n]
        self._last_rho = np.concatenate(rhos)[:n]
        return self._last_assign, self._last_rho
