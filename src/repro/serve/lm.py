"""LM serving surfaces: prefill/decode step builders and the greedy loop.

These are the seed template's language-model serving pieces (DESIGN.md §11
"out-of-scope seed-template surfaces"), split out of the cluster serving
path so that ``import repro.serve`` never pulls in ``repro.models``: the
clustering plane (engine/servable/batching/registry/server) has no LM
dependency, and this module is only imported when one of the three LM names
is actually requested (lazy ``__getattr__`` in ``repro/serve/__init__.py``).

LM shapes contract (matches the assigned input-shape grid):
  prefill_*  → prefill_fn(params, tokens (B, S))            -> logits (B, V)
  decode_* / long_* → decode_fn(params, cache, tok (B,1), pos) -> (logits, cache)

The decode cache is pre-allocated at seq_len (rotating window caches stay at
min(window, seq_len)); the dry-run lowers decode_fn against cache_specs, so
full-size caches are never allocated on the host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import forward, decode_forward, init_cache
from repro.models.config import ModelConfig
from repro.models.transformer import _logits


def make_prefill_fn(cfg: ModelConfig):
    def prefill(params, tokens, frontend_embeds=None):
        h = forward(params, tokens, cfg, frontend_embeds=frontend_embeds,
                    remat=False)
        logits = _logits(params, h[:, -1:, :], cfg)      # next-token head only
        return logits[:, 0, :cfg.vocab]
    return prefill


def make_decode_fn(cfg: ModelConfig):
    def decode(params, cache, token, pos):
        return decode_forward(params, cache, token, pos, cfg)
    return decode


class ServeLoop:
    """Minimal batched serving driver (greedy) for the runnable examples."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill_fn(cfg))
        self._decode = jax.jit(make_decode_fn(cfg))

    def generate(self, prompts: jnp.ndarray, n_new: int = 16):
        """prompts: (B, S0) int32 -> (B, S0 + n_new) greedy continuation."""
        b, s0 = prompts.shape
        cache = init_cache(self.cfg, b, self.max_len)
        # teacher-forced cache warmup via the decode path (exact, if slow);
        # a fused prefill-with-cache is the §Perf hillclimb variant.
        tok = prompts[:, :1]
        out = [prompts]
        for pos in range(s0 + n_new - 1):
            logits, cache = self._decode(self.params, cache, tok, jnp.asarray(pos))
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            tok = prompts[:, pos + 1:pos + 2] if pos + 1 < s0 else nxt
            if pos + 1 >= s0:
                out.append(nxt)
        return jnp.concatenate(out, axis=1)
