"""``repro.serve`` — the serving plane over fitted clustering artifacts.

Two layers (DESIGN.md §12):

  * :class:`ClusterEngine` (engine.py) — the in-process serving object:
    classify/refit against a frozen MeanIndex, one caller at a time; its
    ``refit`` streams DocStores chunk by chunk and its ``serve()`` lifts
    the artifact into the service below.
  * :class:`ClusterServer` (server.py) — the continuous-batching classify
    *service*: per-model request queues and batching threads
    (batching.py), padded batch-size buckets so every launch hits a
    compiled shape (servable.py), ``max_live_batches`` admission control,
    one async device thread decoupled from pre/post-processing workers,
    and a :class:`ModelRegistry` (registry.py) hosting several
    FittedModels on one device with load/unload and zero-downtime
    hot-swap after a refit.

The LM template surfaces (``ServeLoop``/``make_prefill_fn``/
``make_decode_fn``) moved to :mod:`repro.serve.lm` and load lazily: simply
importing ``repro.serve`` no longer imports ``repro.models`` (the
clustering plane has no LM dependency — DESIGN.md §11).
"""
from repro.serve.batching import ClassifyFuture, ServerClosed
from repro.serve.engine import ClusterEngine
from repro.serve.registry import ModelRegistry
from repro.serve.servable import ServableClusterModel
from repro.serve.server import ClusterServer

_LM_NAMES = ("make_prefill_fn", "make_decode_fn", "ServeLoop")

__all__ = ["ClassifyFuture", "ClusterEngine", "ClusterServer",
           "ModelRegistry", "ServableClusterModel", "ServerClosed",
           *_LM_NAMES]


def __getattr__(name):
    # Lazy LM surface: pulled in only when actually requested, so the
    # cluster serving plane never drags repro.models into the process.
    if name in _LM_NAMES:
        import repro.serve.lm as _lm

        return getattr(_lm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
