from repro.serve.engine import (make_prefill_fn, make_decode_fn, ServeLoop,
                                ClusterEngine)

__all__ = ["make_prefill_fn", "make_decode_fn", "ServeLoop", "ClusterEngine"]
