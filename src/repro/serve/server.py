"""ClusterServer: the continuous-batching classify service front door.

One server hosts any number of FittedModels on the one local device
(DESIGN.md §12):

    server = ClusterServer(max_live_batches=4)
    server.load("news", model)                       # FittedModel artifact
    fut = server.submit("news", docs)                # non-blocking future
    assign, sims = fut.result()                      #   … or …
    assign, sims = server.classify("news", docs)     # synchronous helper
    server.swap("news", engine.to_model())           # zero-downtime refresh
    server.close()

Threads: one batching thread per model (batching.ContinuousBatcher), one
shared device thread (async jax dispatch only — never a host sync), and a
small post-processing pool (the only threads that block on device→host
transfers).  ``submit`` transparently splits requests larger than the
servable's biggest bucket into parts of one future.  Results are
bit-identical to ``ClusterEngine.classify`` on the same docs: the device
stage runs the same fused epoch (cluster/classify.py) against the same
index (parity-ratcheted in CI via benchmarks/serving_suite.py).
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.serve.batching import (ClassifyFuture, ContinuousBatcher,
                                  ServerClosed, _Request)
from repro.serve.registry import ModelRegistry
from repro.serve.servable import DEFAULT_BATCH_SIZES, ServableClusterModel

_STOP = object()


def _coerce_rows(docs):
    """SparseDocs | (ids, vals, nnz) triple → numpy (ids, vals, nnz)."""
    if isinstance(docs, tuple) and len(docs) == 3:
        ids, vals, nnz = docs
    else:
        ids, vals, nnz = docs.ids, docs.vals, docs.nnz
    ids = np.asarray(ids, np.int32)
    vals = np.asarray(vals, np.float32)
    nnz = np.asarray(nnz, np.int32)
    if ids.ndim != 2 or ids.shape != vals.shape or nnz.shape != ids.shape[:1]:
        raise ValueError("classify request needs ids/vals (N, P) and nnz (N,)")
    return ids, vals, nnz


class ClusterServer:
    """Continuous-batching classify service over FittedModel artifacts.

    max_live_batches: per-model admission control — batches between
                      assembly and post-processing completion.
    batch_timeout_s:  how long a batching thread waits for more requests
                      after the first before launching a partial batch.
    queue_depth:      per-model bounded request queue (backpressure).
    n_post_workers:   host-sync worker threads shared by all models.
    """

    def __init__(self, *, max_live_batches: int = 4,
                 batch_timeout_s: float = 0.002, queue_depth: int = 1024,
                 n_post_workers: int = 2):
        self.registry = ModelRegistry()
        self._batcher_kw = dict(max_live_batches=max_live_batches,
                                batch_timeout_s=batch_timeout_s,
                                queue_depth=queue_depth)
        self._batchers: dict[str, ContinuousBatcher] = {}
        self._lock = threading.Lock()
        self._closed = False
        self._device_q: queue.Queue = queue.Queue()
        self._post_q: queue.Queue = queue.Queue()
        self._device_thread = threading.Thread(
            target=self._device_loop, daemon=True, name="serve:device")
        self._device_thread.start()
        self._post_threads = [
            threading.Thread(target=self._post_loop, daemon=True,
                             name=f"serve:post{i}")
            for i in range(max(1, n_post_workers))]
        for t in self._post_threads:
            t.start()

    # -- device / post loops ------------------------------------------------
    def _device_loop(self):
        while True:
            live = self._device_q.get()
            if live is _STOP:
                break
            try:
                # Async dispatch: returns device arrays immediately; the
                # post workers pay the host sync.
                live.out = live.servable.device_compute(live.prepared)
            except BaseException as e:
                live.batcher.fail_batch(live.requests, e)
                continue
            self._post_q.put(live)

    def _post_loop(self):
        while True:
            live = self._post_q.get()
            if live is _STOP:
                break
            live.batcher.finish_batch(live)

    # -- model lifecycle ----------------------------------------------------
    def _servable(self, model, batch_sizes, pad_width, backend):
        if isinstance(model, ServableClusterModel):
            return model
        return ServableClusterModel(model, batch_sizes=batch_sizes,
                                    pad_width=pad_width, backend=backend)

    def load(self, name: str, model, *, batch_sizes=DEFAULT_BATCH_SIZES,
             pad_width: int | None = None, backend: str | None = None):
        """Admit a FittedModel (or prebuilt servable) under ``name`` and
        start batching traffic for it."""
        with self._lock:
            if self._closed:
                raise ServerClosed("server is closed")
            servable = self._servable(model, batch_sizes, pad_width, backend)
            self.registry.load(name, servable)
            self._batchers[name] = ContinuousBatcher(
                name, lambda: self.registry.get(name), self._device_q.put,
                **self._batcher_kw)
            return servable

    def unload(self, name: str):
        """Retire ``name``: stop batching (queued-but-unbatched requests
        fail with ServerClosed; in-flight batches complete), drop the
        servable.  Returns the retired servable."""
        with self._lock:
            batcher = self._batchers.pop(name, None)
        if batcher is None:
            raise self.registry._missing(name)
        batcher.stop()
        return self.registry.unload(name)

    def swap(self, name: str, model, *, batch_sizes=DEFAULT_BATCH_SIZES,
             pad_width: int | None = None, backend: str | None = None):
        """Zero-downtime hot-swap: new batches for ``name`` route to
        ``model`` atomically; in-flight batches finish on the old index;
        no request fails.  Returns the previous servable."""
        with self._lock:
            if self._closed:
                raise ServerClosed("server is closed")
            old = self.registry.get(name)
            if pad_width is None:
                # Inherit the locked width so mixed old/new batches keep
                # hitting the already-compiled shapes.
                pad_width = old.pad_width
            servable = self._servable(model, batch_sizes, pad_width, backend)
            return self.registry.swap(name, servable)

    # -- request path -------------------------------------------------------
    def submit(self, name: str, docs, *, block: bool = True,
               timeout: float | None = None) -> ClassifyFuture:
        """Enqueue a classify request; returns a :class:`ClassifyFuture`
        resolving to (assign (N,) int32, sims (N,) float32).  Requests
        larger than the model's biggest bucket are split into parts of one
        future.  ``block=False`` raises :class:`ServerClosed` instead of
        waiting when the queue is full (admission backpressure)."""
        with self._lock:
            batcher = self._batchers.get(name)
        if batcher is None:
            raise self.registry._missing(name)
        servable = self.registry.get(name)
        ids, vals, nnz = _coerce_rows(docs)
        n = ids.shape[0]
        if n == 0:
            raise ValueError("classify request needs at least one row")
        cap = servable.max_batch_size
        bounds = list(range(0, n, cap)) + [n]
        future = ClassifyFuture(n_parts=len(bounds) - 1)
        for part, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
            batcher.submit(_Request(ids[lo:hi], vals[lo:hi], nnz[lo:hi],
                                    future, part),
                           block=block, timeout=timeout)
        return future

    def classify(self, name: str, docs, *, timeout: float | None = None):
        """Synchronous submit + wait."""
        return self.submit(name, docs).result(timeout)

    # -- introspection ------------------------------------------------------
    def stats(self, name: str) -> dict:
        """Batcher counters + occupancy histogram + per-bucket compile
        counts for one hosted model (the serving benchmark's raw feed)."""
        with self._lock:
            batcher = self._batchers.get(name)
        if batcher is None:
            raise self.registry._missing(name)
        servable = self.registry.get(name)
        out = batcher.stats.snapshot()
        out["max_live_batches"] = batcher.max_live_batches
        out["buckets"] = list(servable.sorted_batch_sizes)
        out["compile_counts"] = {str(b): c for b, c
                                 in servable.compile_counts().items()}
        return out

    # -- lifecycle ----------------------------------------------------------
    def close(self):
        """Stop batching, let in-flight batches complete, join threads."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            batchers = list(self._batchers.values())
            self._batchers.clear()
        for b in batchers:
            b.stop()
        self._device_q.put(_STOP)
        self._device_thread.join()
        for _ in self._post_threads:
            self._post_q.put(_STOP)
        for t in self._post_threads:
            t.join()

    def __enter__(self) -> "ClusterServer":
        return self

    def __exit__(self, *exc):
        self.close()
        return False
