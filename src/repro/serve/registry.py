"""Model registry: several FittedModels hosted on one device, hot-swappable.

The saxml ``ServableModel`` hosting story reduced to its essentials: a
name → :class:`~repro.serve.servable.ServableClusterModel` map with

  * ``load`` / ``unload`` — admit / retire a model;
  * ``get`` — the batching thread's per-batch snapshot read;
  * ``swap`` — **zero-downtime hot-swap**: atomically replace the servable
    behind a name (e.g. after ``ClusterEngine.refit`` produced a rebuilt
    index).  The replacement is one reference assignment under the registry
    lock, so a reader sees either the old servable or the new one, never a
    torn mix; batches already assembled keep their reference to the old
    servable and complete against the pre-swap index (batching.py).

Swapping same-geometry models (same dim/K/buckets/backend) costs zero
recompiles: the jitted classify epoch takes the index as a traced argument
(servable.py), so the new means hit the existing executable.
"""
from __future__ import annotations

import threading

from repro.serve.servable import ServableClusterModel


class ModelRegistry:
    """Thread-safe name → servable map with atomic replacement."""

    def __init__(self):
        self._lock = threading.Lock()
        self._models: dict[str, ServableClusterModel] = {}

    def _missing(self, name: str) -> KeyError:
        return KeyError(f"no model {name!r} is loaded; "
                        f"serving: {sorted(self._models) or '(none)'}")

    def load(self, name: str, servable: ServableClusterModel):
        with self._lock:
            if name in self._models:
                raise ValueError(f"model {name!r} is already loaded; use "
                                 f"swap() to replace it atomically")
            self._models[name] = servable

    def unload(self, name: str) -> ServableClusterModel:
        with self._lock:
            if name not in self._models:
                raise self._missing(name)
            return self._models.pop(name)

    def get(self, name: str) -> ServableClusterModel:
        with self._lock:
            try:
                return self._models[name]
            except KeyError:
                raise self._missing(name) from None

    def swap(self, name: str,
             servable: ServableClusterModel) -> ServableClusterModel:
        """Atomically route new batches for ``name`` to ``servable``;
        returns the previous servable (still referenced by any in-flight
        batches, which finish against it)."""
        with self._lock:
            if name not in self._models:
                raise self._missing(name)
            old, self._models[name] = self._models[name], servable
            return old

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._models)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._models
