"""The fused classification path every runtime shares.

``SphericalKMeans.predict``, ``FittedModel.predict``, and
``serve.ClusterEngine.classify`` all route through :func:`classify_docs`:
one jitted ``lax.map`` epoch over padded batches, exact similarities from
the pluggable backend (core/backends.py), top-1 on device, one device→host
sync per request.  A parity bug can therefore only exist in one place.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("backend", "bs", "dim"))
def _classify_fused(backend: str, ids, vals, nnz, dim: int, index, bs: int):
    """Fused classification epoch: lax.map over reshaped batches, exact
    similarities from the chosen backend, top-1 on device."""
    from repro.sparse import SparseDocs
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    n = ids.shape[0]
    nb = n // bs
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])

    def batch_fn(args):
        bids, bvals, bnnz = args
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=dim)
        out = bk.accumulate(bdocs, index, jnp.zeros((bs,), bool), mode="exact",
                            diag=False)   # serving never reads Mult
        sims = out["sims"]
        best = jnp.argmax(sims, axis=1).astype(jnp.int32)
        return best, jnp.take_along_axis(sims, best[:, None], axis=1)[:, 0]

    a, s = jax.lax.map(batch_fn, (resh(ids), resh(vals), resh(nnz)))
    return a.reshape(n), s.reshape(n)


@partial(jax.jit, static_argnames=("backend", "bs", "dim"))
def _transform_fused(backend: str, ids, vals, nnz, dim: int, index, bs: int):
    """Fused similarity epoch: the full (N, K) cosine matrix vs the index."""
    from repro.sparse import SparseDocs
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    n = ids.shape[0]
    nb = n // bs
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])

    def batch_fn(args):
        bids, bvals, bnnz = args
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=dim)
        return bk.accumulate(bdocs, index, jnp.zeros((bs,), bool),
                             mode="exact", diag=False)["sims"]

    s = jax.lax.map(batch_fn, (resh(ids), resh(vals), resh(nnz)))
    return s.reshape(n, -1)


def _store_tiles(store, batch_size: int):
    """(tile size, per-chunk padder) for scanning a store's (C, P) chunks —
    the SAME tile policy as the streaming fit (core/lloyd._tile_bs): an
    unaligned chunk is padded with dead rows (inert by the ρ_self = 0
    convention) rather than shrinking the tile, and callers trim per-chunk
    outputs back to C."""
    from repro.core.lloyd import _tile_bs
    from repro.sparse import pad_rows

    bs = _tile_bs(store.chunk_size, batch_size)
    padder = ((lambda d: pad_rows(d, bs)) if store.chunk_size % bs
              else (lambda d: d))
    return bs, padder


def classify_docs(index, docs, *, backend: str = "auto",
                  batch_size: int = 4096):
    """docs vs a frozen MeanIndex -> (assign (N,) int32, sims (N,) float32).

    ``docs`` may be a resident SparseDocs or an out-of-core DocStore: store
    chunks stream through the double-buffered prefetcher and the SAME fused
    per-chunk epoch, so serving stays chunk-for-chunk identical to the
    resident path (parity-tested).
    """
    from repro.sparse import pad_rows
    from repro.sparse.store import ChunkPrefetcher, DocStore

    if isinstance(docs, DocStore):
        store = docs
        bs, padder = _store_tiles(store, batch_size)
        parts_a, parts_s = [], []
        for ci, cdocs in ChunkPrefetcher(store):
            cdocs = padder(cdocs)
            a, s = _classify_fused(backend, cdocs.ids, cdocs.vals, cdocs.nnz,
                                   store.dim, index, bs)
            parts_a.append(np.asarray(a)[:store.chunk_size])
            parts_s.append(np.asarray(s)[:store.chunk_size])
        return (np.concatenate(parts_a)[:store.n_docs],
                np.concatenate(parts_s)[:store.n_docs])

    n = docs.n_docs
    if n == 0:
        return (np.zeros((0,), np.int32), np.zeros((0,), np.float32))
    bs = min(batch_size, n)
    pdocs = pad_rows(docs, bs)
    a, s = _classify_fused(backend, pdocs.ids, pdocs.vals, pdocs.nnz,
                           pdocs.dim, index, bs)
    return np.asarray(a)[:n], np.asarray(s)[:n]


def transform_docs(index, docs, *, backend: str = "auto",
                   batch_size: int = 4096):
    """docs vs a frozen MeanIndex -> dense (N, K) cosine similarities.

    Accepts a DocStore like :func:`classify_docs` (chunk-streamed)."""
    from repro.sparse import pad_rows
    from repro.sparse.store import ChunkPrefetcher, DocStore

    if isinstance(docs, DocStore):
        store = docs
        bs, padder = _store_tiles(store, batch_size)
        parts = []
        for ci, cdocs in ChunkPrefetcher(store):
            cdocs = padder(cdocs)
            parts.append(np.asarray(_transform_fused(
                backend, cdocs.ids, cdocs.vals, cdocs.nnz, store.dim,
                index, bs))[:store.chunk_size])
        return np.concatenate(parts)[:store.n_docs]

    n = docs.n_docs
    if n == 0:
        return np.zeros((0, index.k), np.float32)
    bs = min(batch_size, n)
    pdocs = pad_rows(docs, bs)
    s = _transform_fused(backend, pdocs.ids, pdocs.vals, pdocs.nnz,
                         pdocs.dim, index, bs)
    return np.asarray(s)[:n]
