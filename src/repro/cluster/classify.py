"""The fused classification path every runtime shares.

``SphericalKMeans.predict``, ``FittedModel.predict``, and
``serve.ClusterEngine.classify`` all route through :func:`classify_docs`:
one jitted ``lax.map`` epoch over padded batches, exact similarities from
the pluggable backend (core/backends.py), top-1 on device, one device→host
sync per request.  A parity bug can therefore only exist in one place.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("backend", "bs", "dim"))
def _classify_fused(backend: str, ids, vals, nnz, dim: int, index, bs: int):
    """Fused classification epoch: lax.map over reshaped batches, exact
    similarities from the chosen backend, top-1 on device."""
    from repro.sparse import SparseDocs
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    n = ids.shape[0]
    nb = n // bs
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])

    def batch_fn(args):
        bids, bvals, bnnz = args
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=dim)
        out = bk.accumulate(bdocs, index, jnp.zeros((bs,), bool), mode="exact",
                            diag=False)   # serving never reads Mult
        sims = out["sims"]
        best = jnp.argmax(sims, axis=1).astype(jnp.int32)
        return best, jnp.take_along_axis(sims, best[:, None], axis=1)[:, 0]

    a, s = jax.lax.map(batch_fn, (resh(ids), resh(vals), resh(nnz)))
    return a.reshape(n), s.reshape(n)


@partial(jax.jit, static_argnames=("backend", "bs", "dim"))
def _transform_fused(backend: str, ids, vals, nnz, dim: int, index, bs: int):
    """Fused similarity epoch: the full (N, K) cosine matrix vs the index."""
    from repro.sparse import SparseDocs
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    n = ids.shape[0]
    nb = n // bs
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])

    def batch_fn(args):
        bids, bvals, bnnz = args
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=dim)
        return bk.accumulate(bdocs, index, jnp.zeros((bs,), bool),
                             mode="exact", diag=False)["sims"]

    s = jax.lax.map(batch_fn, (resh(ids), resh(vals), resh(nnz)))
    return s.reshape(n, -1)


@partial(jax.jit, static_argnames=("backend", "bs", "dim", "cmax", "n_probe"))
def _routed_fused(backend: str, ids, vals, nnz, dim: int, coarse_index,
                  means_ext, starts, sizes, bs: int, cmax: int, n_probe: int):
    """Coarse-routed classification epoch (two-level IVF — DESIGN.md §13).

    Per batch: (1) score the K_c coarse means through the pluggable backend
    (exactly the flat epoch at K = K_c); (2) ``lax.top_k`` the ``n_probe``
    best cells; (3) score ONLY those cells' fine means with a gather-TAAT
    scan over the P tuple slots — each step is one ``(bs, J)`` 2-D gather
    from the sentinel-extended ``means_ext (D, K_eff + 1)`` at the candidate
    columns, ``J = n_probe * cmax``, so per-object work is K_c + Σ probed
    cell sizes instead of K_eff.

    Exactness: the scan accumulates ``vals[:, p] * means_ext[ids[:, p],
    col]`` in ascending-p order — element-for-element the same float32
    additions, in the same order, as the reference flat TAAT scan
    (``core.backends.reference_scan`` at p_block=1) performs for those
    columns.  When the routed candidate set contains the true argmax (always
    at n_probe = K_c; measured as recall@1 below it), the winning similarity
    is therefore *bitwise* equal to the flat path's.

    Dead candidate slots (past a cell's size) point at the all-zero sentinel
    column K_eff and are masked to -inf before the argmax; dead *rows*
    (nnz = 0 tail padding) follow the repo-wide ρ_self = 0 convention and
    are trimmed by callers.  Returns (assign, best-sim, scored) where
    ``scored`` is the per-object count of centroids scored (K_c + Σ probed
    sizes) — the Mult-counter hook the IVF benchmark and tests assert on.
    """
    from repro.sparse import SparseDocs
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    n = ids.shape[0]
    nb = n // bs
    k_c = starts.shape[0]
    k_eff = means_ext.shape[1] - 1
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])
    slot = jnp.arange(cmax, dtype=jnp.int32)

    def batch_fn(args):
        bids, bvals, bnnz = args
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=dim)
        csims = bk.accumulate(bdocs, coarse_index, jnp.zeros((bs,), bool),
                              mode="exact", diag=False)["sims"]
        _, cells = jax.lax.top_k(csims, n_probe)          # (bs, n_probe)
        psizes = sizes[cells]                             # (bs, n_probe)
        cols = starts[cells][:, :, None] + slot[None, None, :]
        cols = jnp.where(slot[None, None, :] < psizes[:, :, None],
                         cols, k_eff).reshape(bs, n_probe * cmax)

        def p_step(sims, xs):
            idp, vp = xs                                  # (bs,), (bs,)
            return sims + vp[:, None] * means_ext[idp[:, None], cols], None

        sims, _ = jax.lax.scan(
            p_step, jnp.zeros((bs, n_probe * cmax), jnp.float32),
            (bids.T, bvals.T))
        sims = jnp.where(cols == k_eff, -jnp.inf, sims)
        bestj = jnp.argmax(sims, axis=1)
        assign = jnp.take_along_axis(cols, bestj[:, None], 1)[:, 0]
        best = jnp.take_along_axis(sims, bestj[:, None], 1)[:, 0]
        scored = (k_c + jnp.sum(psizes, axis=1)).astype(jnp.int32)
        return assign.astype(jnp.int32), best, scored

    a, s, sc = jax.lax.map(batch_fn, (resh(ids), resh(vals), resh(nnz)))
    return a.reshape(n), s.reshape(n), sc.reshape(n)


def classify_docs_routed(model, docs, *, n_probe: int | None = None,
                         backend: str | None = None, batch_size: int = 4096,
                         with_stats: bool = False):
    """docs vs a two-level model -> (assign, sims[, scored]) — the routed
    ANN classify.

    ``model`` is a :class:`repro.cluster.model.TwoLevelFittedModel` (duck-
    typed: anything with ``_routed_operands()`` / ``index`` / ``coarse_k``).
    ``assign`` is in the GLOBAL fine-label space (same ids as the flat
    path over ``model.index``).  ``n_probe`` defaults to the model's
    setting; ``n_probe >= K_c`` probes every cell and delegates to the flat
    :func:`classify_docs` — provably exact and bitwise-identical to the
    flat path on every backend, since it IS the flat path.  With
    ``with_stats=True`` also returns ``scored`` (N,) int32 — centroids
    scored per object (K_c + Σ probed cell sizes; K_eff when delegating).

    Accepts a resident SparseDocs or an out-of-core DocStore (chunk-
    streamed like :func:`classify_docs`).
    """
    from repro.sparse import pad_rows
    from repro.sparse.store import ChunkPrefetcher, DocStore

    backend = model.backend if backend is None else backend
    n_probe = model.n_probe if n_probe is None else int(n_probe)
    k_c = model.coarse_k
    if not 1 <= n_probe <= k_c:
        raise ValueError(f"n_probe must be in [1, coarse_k={k_c}], "
                         f"got {n_probe}")
    if n_probe >= k_c:          # probe everything == the flat scan
        a, s = classify_docs(model.index, docs, backend=backend,
                             batch_size=batch_size)
        if not with_stats:
            return a, s
        return a, s, np.full(a.shape, model.index.k, np.int32)

    coarse_index, means_ext, starts, sizes, cmax = model._routed_operands()

    def run(ids, vals, nnz, dim, bs):
        return _routed_fused(backend, ids, vals, nnz, dim, coarse_index,
                             means_ext, starts, sizes, bs, cmax, n_probe)

    if isinstance(docs, DocStore):
        store = docs
        bs, padder = _store_tiles(store, batch_size)
        parts = ([], [], [])
        for ci, cdocs in ChunkPrefetcher(store):
            cdocs = padder(cdocs)
            out = run(cdocs.ids, cdocs.vals, cdocs.nnz, store.dim, bs)
            for part, arr in zip(parts, out):
                part.append(np.asarray(arr)[:store.chunk_size])
        a, s, sc = (np.concatenate(p)[:store.n_docs] for p in parts)
        return (a, s, sc) if with_stats else (a, s)

    n = docs.n_docs
    if n == 0:
        out = (np.zeros((0,), np.int32), np.zeros((0,), np.float32),
               np.zeros((0,), np.int32))
        return out if with_stats else out[:2]
    bs = min(batch_size, n)
    pdocs = pad_rows(docs, bs)
    a, s, sc = run(pdocs.ids, pdocs.vals, pdocs.nnz, pdocs.dim, bs)
    out = (np.asarray(a)[:n], np.asarray(s)[:n], np.asarray(sc)[:n])
    return out if with_stats else out[:2]


def _store_tiles(store, batch_size: int):
    """(tile size, per-chunk padder) for scanning a store's (C, P) chunks —
    the SAME tile policy as the streaming fit (core/lloyd._tile_bs): an
    unaligned chunk is padded with dead rows (inert by the ρ_self = 0
    convention) rather than shrinking the tile, and callers trim per-chunk
    outputs back to C."""
    from repro.core.lloyd import _tile_bs
    from repro.sparse import pad_rows

    bs = _tile_bs(store.chunk_size, batch_size)
    padder = ((lambda d: pad_rows(d, bs)) if store.chunk_size % bs
              else (lambda d: d))
    return bs, padder


def classify_docs(index, docs, *, backend: str = "auto",
                  batch_size: int = 4096):
    """docs vs a frozen MeanIndex -> (assign (N,) int32, sims (N,) float32).

    ``docs`` may be a resident SparseDocs or an out-of-core DocStore: store
    chunks stream through the double-buffered prefetcher and the SAME fused
    per-chunk epoch, so serving stays chunk-for-chunk identical to the
    resident path (parity-tested).
    """
    from repro.sparse import pad_rows
    from repro.sparse.store import ChunkPrefetcher, DocStore

    if isinstance(docs, DocStore):
        store = docs
        bs, padder = _store_tiles(store, batch_size)
        parts_a, parts_s = [], []
        for ci, cdocs in ChunkPrefetcher(store):
            cdocs = padder(cdocs)
            a, s = _classify_fused(backend, cdocs.ids, cdocs.vals, cdocs.nnz,
                                   store.dim, index, bs)
            parts_a.append(np.asarray(a)[:store.chunk_size])
            parts_s.append(np.asarray(s)[:store.chunk_size])
        return (np.concatenate(parts_a)[:store.n_docs],
                np.concatenate(parts_s)[:store.n_docs])

    n = docs.n_docs
    if n == 0:
        return (np.zeros((0,), np.int32), np.zeros((0,), np.float32))
    bs = min(batch_size, n)
    pdocs = pad_rows(docs, bs)
    a, s = _classify_fused(backend, pdocs.ids, pdocs.vals, pdocs.nnz,
                           pdocs.dim, index, bs)
    return np.asarray(a)[:n], np.asarray(s)[:n]


def transform_docs(index, docs, *, backend: str = "auto",
                   batch_size: int = 4096):
    """docs vs a frozen MeanIndex -> dense (N, K) cosine similarities.

    Accepts a DocStore like :func:`classify_docs` (chunk-streamed)."""
    from repro.sparse import pad_rows
    from repro.sparse.store import ChunkPrefetcher, DocStore

    if isinstance(docs, DocStore):
        store = docs
        bs, padder = _store_tiles(store, batch_size)
        parts = []
        for ci, cdocs in ChunkPrefetcher(store):
            cdocs = padder(cdocs)
            parts.append(np.asarray(_transform_fused(
                backend, cdocs.ids, cdocs.vals, cdocs.nnz, store.dim,
                index, bs))[:store.chunk_size])
        return np.concatenate(parts)[:store.n_docs]

    n = docs.n_docs
    if n == 0:
        return np.zeros((0, index.k), np.float32)
    bs = min(batch_size, n)
    pdocs = pad_rows(docs, bs)
    s = _transform_fused(backend, pdocs.ids, pdocs.vals, pdocs.nnz,
                         pdocs.dim, index, bs)
    return np.asarray(s)[:n]
