"""The fused classification path every runtime shares.

``SphericalKMeans.predict``, ``FittedModel.predict``, and
``serve.ClusterEngine.classify`` all route through :func:`classify_docs`:
one jitted ``lax.map`` epoch over padded batches, exact similarities from
the pluggable backend (core/backends.py), top-1 on device, one device→host
sync per request.  A parity bug can therefore only exist in one place.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("backend", "bs", "dim"))
def _classify_fused(backend: str, ids, vals, nnz, dim: int, index, bs: int):
    """Fused classification epoch: lax.map over reshaped batches, exact
    similarities from the chosen backend, top-1 on device."""
    from repro.sparse import SparseDocs
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    n = ids.shape[0]
    nb = n // bs
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])

    def batch_fn(args):
        bids, bvals, bnnz = args
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=dim)
        out = bk.accumulate(bdocs, index, jnp.zeros((bs,), bool), mode="exact",
                            diag=False)   # serving never reads Mult
        sims = out["sims"]
        best = jnp.argmax(sims, axis=1).astype(jnp.int32)
        return best, jnp.take_along_axis(sims, best[:, None], axis=1)[:, 0]

    a, s = jax.lax.map(batch_fn, (resh(ids), resh(vals), resh(nnz)))
    return a.reshape(n), s.reshape(n)


@partial(jax.jit, static_argnames=("backend", "bs", "dim"))
def _transform_fused(backend: str, ids, vals, nnz, dim: int, index, bs: int):
    """Fused similarity epoch: the full (N, K) cosine matrix vs the index."""
    from repro.sparse import SparseDocs
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    n = ids.shape[0]
    nb = n // bs
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])

    def batch_fn(args):
        bids, bvals, bnnz = args
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=dim)
        return bk.accumulate(bdocs, index, jnp.zeros((bs,), bool),
                             mode="exact", diag=False)["sims"]

    s = jax.lax.map(batch_fn, (resh(ids), resh(vals), resh(nnz)))
    return s.reshape(n, -1)


def classify_docs(index, docs, *, backend: str = "auto",
                  batch_size: int = 4096):
    """docs vs a frozen MeanIndex -> (assign (N,) int32, sims (N,) float32)."""
    from repro.sparse import pad_rows

    n = docs.n_docs
    if n == 0:
        return (np.zeros((0,), np.int32), np.zeros((0,), np.float32))
    bs = min(batch_size, n)
    pdocs = pad_rows(docs, bs)
    a, s = _classify_fused(backend, pdocs.ids, pdocs.vals, pdocs.nnz,
                           pdocs.dim, index, bs)
    return np.asarray(a)[:n], np.asarray(s)[:n]


def transform_docs(index, docs, *, backend: str = "auto",
                   batch_size: int = 4096):
    """docs vs a frozen MeanIndex -> dense (N, K) cosine similarities."""
    from repro.sparse import pad_rows

    n = docs.n_docs
    if n == 0:
        return np.zeros((0, index.k), np.float32)
    bs = min(batch_size, n)
    pdocs = pad_rows(docs, bs)
    s = _transform_fused(backend, pdocs.ids, pdocs.vals, pdocs.nnz,
                         pdocs.dim, index, bs)
    return np.asarray(s)[:n]
