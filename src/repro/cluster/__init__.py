"""``repro.cluster`` — the one public front door to spherical k-means.

Two nouns and one verb:

  * :class:`ClusterConfig` — declarative *what/where*: k, algo, backend,
    thresholds, batch/chunk sizes, seed, optional ``mesh=`` target;
  * :class:`FittedModel` — serializable *result*: mean-inverted index +
    structural params + labels + history + provenance, with ``save``/
    ``load`` on the fault-tolerant checkpoint store;
  * :func:`fit` (or the sklearn-style :class:`SphericalKMeans` estimator) —
    turns (docs, config) into a FittedModel through a pluggable execution
    strategy.

One artifact drives all three runtimes::

    model = repro.cluster.fit(docs, ClusterConfig(k=64))      # train
    model.save("gs://…/model")                                 #   ↓
    engine = ClusterEngine.from_model(FittedModel.load(...))  # serve
    engine.refit(fresh_docs); model2 = engine.to_model()      # refit

and ``ClusterConfig(mesh=...)`` runs the *same* estimator through the
distributed loop.  DESIGN.md §9 documents the surface and the deprecation
policy; tests/test_api_surface.py snapshots it so future PRs change it
deliberately, never accidentally.
"""
from __future__ import annotations

from repro.cluster.config import ClusterConfig
from repro.cluster.classify import (classify_docs, classify_docs_routed,
                                    transform_docs)
from repro.cluster.model import FittedModel, TwoLevelFittedModel, load_model
from repro.cluster.estimator import SphericalKMeans
from repro.cluster.strategies import (STRATEGIES, MeshStrategy,
                                      SingleHostStrategy, StreamingStrategy,
                                      TwoLevelStrategy, resolve_strategy)
from repro.cluster.two_level import two_level_from_means
from repro.serve.engine import ClusterEngine


def fit(docs, config: ClusterConfig, *, df=None) -> FittedModel:
    """One-call front door: (docs, ClusterConfig) -> FittedModel."""
    return SphericalKMeans.from_config(config).fit(docs, df=df).model_


__all__ = [
    "ClusterConfig",
    "ClusterEngine",
    "FittedModel",
    "MeshStrategy",
    "STRATEGIES",
    "SingleHostStrategy",
    "SphericalKMeans",
    "StreamingStrategy",
    "TwoLevelFittedModel",
    "TwoLevelStrategy",
    "classify_docs",
    "classify_docs_routed",
    "fit",
    "load_model",
    "resolve_strategy",
    "transform_docs",
    "two_level_from_means",
]
