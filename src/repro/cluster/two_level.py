"""Two-level IVF spherical k-means — the million-cluster fit (DESIGN.md §13).

The paper's pruning machinery assumes K in the thousands; at web scale even
the mean-inverted index stops fitting and every classify still scores all K
centroids.  Aoyama & Saito's IVF (arxiv_2002.09094) / SIVF (arxiv_2103.16141)
lineage fixes the asymptotics with one level of nesting:

  1. **Coarse fit** — ordinary flat spherical k-means over K_c cells,
     through the UNCHANGED flat strategies (``core/lloyd.lloyd_fit`` for
     resident corpora, ``streaming_fit`` for DocStores): the coarse level
     is just a small flat fit.
  2. **Partition** — split the corpus by coarse assignment.  Resident
     corpora gather rows; a DocStore routes through
     :func:`repro.sparse.partition_store`'s lazy :class:`SubsetStore`
     views, so the 8.7M-doc regime never materialises per-cell corpora.
  3. **Fine fits** — per non-empty cell, another flat fit (k_i centroids
     allocated ∝ cell size by largest remainder, every cell >= 1 and
     <= its population) with the SAME backends / pruning algos / tuner.
     Empty cells keep their coarse mean as a single fine centroid, so a
     routed argmax always has a live candidate.  Fine fits receive the
     *global* df: the df-rank term order and t_th thresholds live in
     global-df space, and a partition's local df would silently skew them.
  4. **Nested artifact** — a :class:`TwoLevelFittedModel`: the coarse
     index on top of the CONCATENATED fine index (cell blocks in order),
     global labels, and per-cell provenance; classify routes through the
     coarse level (``cluster/classify.classify_docs_routed``) and scores
     K_c + Σ probed cell sizes centroids instead of K_eff.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.cluster.config import ClusterConfig
from repro.cluster.model import TwoLevelFittedModel
from repro.core.meanindex import StructuralParams, build_mean_index
from repro.core.update import KMeansState, n_ub_groups
from repro.sparse import SparseDocs
from repro.sparse.store import DocStore, partition_store


def _allocate_fine_k(sizes, k: int) -> np.ndarray:
    """Fine-cluster budget per coarse cell: (K_c,) int64 with every cell
    >= 1 (empty cells keep their coarse mean), no cell over its population
    (``max(n_i, 1)``), Σ = min(k, Σ caps), remainder spread ∝ cell size by
    largest remainder — deterministic, order-stable on ties."""
    sizes = np.asarray(sizes, np.int64)
    cap = np.maximum(sizes, 1)
    alloc = np.ones(sizes.shape, np.int64)
    rem = int(min(int(k), int(cap.sum())) - alloc.sum())
    while rem > 0:
        room = cap - alloc
        w = np.where(room > 0, np.maximum(sizes, 1), 0).astype(np.float64)
        ideal = rem * w / w.sum()
        add = np.minimum(np.floor(ideal).astype(np.int64), room)
        if int(add.sum()) == 0:
            # All floors are zero: hand the last units to the largest
            # fractional shares that still have room.
            frac = np.where(room > 0, ideal, -1.0)
            take = np.argsort(-frac, kind="stable")[:rem]
            add = np.zeros_like(alloc)
            add[take[room[take] > 0]] = 1
        alloc += add
        rem -= int(add.sum())
    return alloc


def _gather_rows_docs(docs: SparseDocs, rows: np.ndarray) -> SparseDocs:
    """Resident partition: the given corpus rows as one SparseDocs."""
    ids = np.asarray(docs.ids)
    vals = np.asarray(docs.vals)
    nnz = np.asarray(docs.nnz)
    return SparseDocs(ids=jnp.asarray(ids[rows]), vals=jnp.asarray(vals[rows]),
                      nnz=jnp.asarray(nnz[rows]), dim=docs.dim)


@dataclasses.dataclass
class TwoLevelResult:
    """Duck-typed LloydResult the estimator consumes, plus the ready-made
    nested artifact (``model``) the estimator adopts instead of building a
    flat FittedModel."""

    model: TwoLevelFittedModel
    state: KMeansState
    assign: np.ndarray
    history: list
    params: StructuralParams
    converged: bool
    n_iter: int
    cursor: tuple | None = None
    tuned: object = None

    @property
    def objective(self) -> float:
        return float(np.sum(np.asarray(self.state.rho_self)))


def two_level_fit(docs, config: ClusterConfig, df=None) -> TwoLevelResult:
    """(docs, ClusterConfig(coarse_k=K_c)) -> TwoLevelResult.

    ``docs`` is a resident SparseDocs or an out-of-core DocStore; every
    sub-fit routes through :func:`repro.cluster.strategies.resolve_strategy`
    with a FLAT sub-config, so the coarse and fine levels reuse the
    single-host / streaming runtimes (and their backends, pruning modes and
    tuner) unchanged.
    """
    from repro.core.backends import resolve_backend
    from repro.cluster.strategies import resolve_strategy

    k_c = config.coarse_k
    k = config.k
    is_store = isinstance(docs, DocStore)
    n = docs.n_docs
    dim = docs.dim
    # Resolve the GLOBAL df up front: per-cell fits must estimate their
    # structural thresholds in global-df space, and letting a sub-fit
    # default to its partition's local df would silently skew the df-rank
    # term order (see SubsetStore's docstring).  Gated like streaming_fit's
    # need_df so a params=None fit never triggers a full corpus scan.
    need_df = (config.algo_mode == "full" and config.params == "auto"
               and bool(config.est_iters))
    if df is None and need_df:
        df = docs.df

    def run_flat(sub_docs, sub_cfg):
        strat = resolve_strategy(sub_cfg, sub_docs)
        return strat.fit(sub_docs, sub_cfg, df=df)

    # 1. Coarse fit: a plain flat fit at k = K_c.
    coarse_cfg = config.replace(k=k_c, coarse_k=None, n_probe=1)
    coarse_res = run_flat(docs, coarse_cfg)
    coarse_index = coarse_res.state.index
    coarse_labels = np.asarray(coarse_res.assign, np.int64)[:n]

    # 2. Partition by coarse assignment + 3. per-cell fine fits.
    sizes = np.bincount(coarse_labels, minlength=k_c)
    fine_k = _allocate_fine_k(sizes, k)
    starts = np.concatenate([[0], np.cumsum(fine_k)[:-1]])
    if is_store:
        views = partition_store(docs, coarse_labels, k_c,
                                chunk_size=config.chunk_size)
    else:
        order = np.argsort(coarse_labels, kind="stable")
    coarse_means = np.asarray(coarse_index.means_t).T    # (K_c, D)

    labels = np.zeros((n,), np.int64)
    rho = np.zeros((n,), np.float32)
    fine_means = []
    cell_meta = []
    all_converged = bool(coarse_res.converged)
    row_start = 0
    for c in range(k_c):
        n_c = int(sizes[c])
        if n_c == 0:
            # Empty cell: its coarse mean stands in as the one fine
            # centroid, so routing into it still has a candidate.
            fine_means.append(coarse_means[c:c + 1])
            cell_meta.append({"n_docs": 0, "k": 1, "n_iter": 0,
                              "converged": True})
            continue
        if is_store:
            cell_docs = views[c]
            rows = np.asarray(cell_docs.rows)
        else:
            rows = order[row_start:row_start + n_c]
            row_start += n_c
            cell_docs = _gather_rows_docs(docs, rows)
        k_i = int(fine_k[c])
        cell_cfg = config.replace(
            k=k_i, coarse_k=None, n_probe=1, seed=config.seed + c + 1,
            checkpoint_dir=None)   # cells share no checkpoint namespace
        res = run_flat(cell_docs, cell_cfg)
        fine_means.append(np.asarray(res.state.index.means_t).T)
        labels[rows] = starts[c] + np.asarray(res.assign, np.int64)[:n_c]
        rho[rows] = np.asarray(res.state.rho_self, np.float32)[:n_c]
        all_converged &= bool(res.converged)
        cell_meta.append({"n_docs": n_c, "k": k_i,
                          "n_iter": int(res.n_iter),
                          "converged": bool(res.converged)})

    # 4. Nested artifact over the concatenated fine index.  The flat
    # surface only runs exact-mode classifies, which never read the
    # structural thresholds — trivial params keep the artifact honest
    # about that (per-cell fits estimated their own, recorded in history).
    means_all = np.concatenate(fine_means, axis=0)       # (K_eff, D)
    k_eff = means_all.shape[0]
    index = build_mean_index(jnp.asarray(means_all, jnp.float32),
                             StructuralParams.trivial(dim))
    cell_sizes = np.asarray([m.shape[0] for m in fine_means], np.int32)
    model = TwoLevelFittedModel(
        index=index,
        coarse_index=coarse_index,
        cell_sizes=cell_sizes,
        n_probe=config.n_probe,
        cell_meta=cell_meta,
        labels=labels.astype(np.int32),
        rho_self=rho,
        history=list(coarse_res.history),
        converged=all_converged,
        n_iter=int(coarse_res.n_iter),
        algo=config.algo,
        backend=resolve_backend(config.backend).name,
        strategy="two_level",
        tuned=None,
    )
    state = KMeansState(
        index=index,
        assign=jnp.asarray(labels, jnp.int32),
        rho_self=jnp.asarray(rho),
        rho_self_prev=jnp.asarray(rho),
        iteration=jnp.asarray(model.n_iter, jnp.int32),
        ub=jnp.zeros((n, n_ub_groups(k_eff)), jnp.float32),
    )
    return TwoLevelResult(
        model=model, state=state, assign=model.labels,
        history=model.history, params=index.params,
        converged=model.converged, n_iter=model.n_iter)


def two_level_from_means(mean_docs: SparseDocs, coarse_k: int, *,
                         n_probe: int = 1, backend: str = "reference",
                         algo: str = "mivi", seed: int = 0,
                         max_iter: int = 10,
                         batch_size: int = 4096) -> TwoLevelFittedModel:
    """Wrap K given unit-norm sparse vectors as the FINE means of a nested
    model, coarse-clustering the means themselves into K_c cells.

    This is the benchmark's (and any warm-start's) entry point to the
    routed classify at large effective K without paying a K-cluster corpus
    fit: the vectors (e.g. sampled documents standing in for centroids)
    become the fine level verbatim — only reordered cell-block-contiguously
    — and a small flat fit over them builds the coarse level.  Empty coarse
    cells keep their coarse mean, so K_eff = K + (# empty cells).
    """
    from repro.cluster.strategies import resolve_strategy
    from repro.sparse import to_dense

    k = mean_docs.n_docs
    dim = mean_docs.dim
    cfg = ClusterConfig(k=coarse_k, algo=algo, backend=backend, params=None,
                        seed=seed, max_iter=max_iter, batch_size=batch_size,
                        n_probe=1).validate()
    res = resolve_strategy(cfg, mean_docs).fit(mean_docs, cfg, df=None)
    coarse_index = res.state.index
    labels = np.asarray(res.assign, np.int64)
    sizes = np.bincount(labels, minlength=coarse_k)
    order = np.argsort(labels, kind="stable")
    dense = np.asarray(to_dense(mean_docs), np.float32)[order]
    coarse_means = np.asarray(coarse_index.means_t).T
    blocks, cell_sizes, start = [], [], 0
    for c in range(coarse_k):
        n_c = int(sizes[c])
        if n_c == 0:
            blocks.append(coarse_means[c:c + 1])
            cell_sizes.append(1)
            continue
        blocks.append(dense[start:start + n_c])
        cell_sizes.append(n_c)
        start += n_c
    means_all = np.concatenate(blocks, axis=0)
    index = build_mean_index(jnp.asarray(means_all),
                             StructuralParams.trivial(dim))
    return TwoLevelFittedModel(
        index=index, coarse_index=coarse_index,
        cell_sizes=np.asarray(cell_sizes, np.int32), n_probe=n_probe,
        cell_meta=[], backend=backend, algo=algo, strategy="two_level")
