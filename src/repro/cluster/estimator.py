"""``SphericalKMeans`` — a real sklearn-style estimator over the paper's fit.

Contract (Knittel et al., arXiv:2108.00895, make the case that a drop-in
estimator is what drives adoption of accelerated sparse spherical k-means):

  * ``fit`` returns ``self`` and populates trailing-underscore attributes:
    ``model_`` (the serializable FittedModel artifact), ``labels_``,
    ``history_``, ``state_``, ``params_``, ``n_iter_``, ``converged_``;
  * ``predict`` / ``transform`` / ``score`` share the fused classify path
    with ``serve.ClusterEngine`` (cluster/classify.py) — train and serve
    cannot disagree;
  * execution routes through pluggable strategies: ``mesh=`` dispatches the
    *same* estimator through the distributed loop (cluster/strategies.py).

Legacy surface (pre-redesign) stays importable behind deprecation shims:
``fit_result()`` returns the old LloydResult, and the old result attributes
(``.assign``, ``.history``, ``.state``, ``.objective``, ``.converged``,
``.n_iter``) forward from the estimator with a DeprecationWarning.  The one
exception is ``.params`` — it now always means the *constructor* threshold
spec; read the fitted thresholds from ``params_``.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.cluster.config import ClusterConfig
from repro.cluster.model import FittedModel
from repro.cluster.strategies import resolve_strategy
from repro.core.backends import resolve_backend
from repro.core.estparams import EstGrid
from repro.core.lloyd import LloydResult

# Pre-redesign LloydResult fields readable straight off the fitted estimator.
_LEGACY_RESULT_ATTRS = {
    "assign": "labels_",
    "history": "history_",
    "state": "state_",
    "objective": "objective_",
    "converged": "converged_",
    "n_iter": "n_iter_",
}

# Attributes fit() populates — named in the not-fitted-yet error.
_FITTED_ATTRS = frozenset({
    "model_", "labels_", "history_", "state_", "params_", "n_iter_",
    "converged_", "objective_",
})


class SphericalKMeans:
    """sklearn-style front door over every runtime (see module docstring).

    algo: 'mivi' | 'icp' | 'es' | 'esicp' | 'ta-icp' | 'cs-icp'
    backend: 'reference' | 'pallas' | 'auto' — accumulator engine for the
            assignment AND update steps (core/backends.py; 'auto' = pallas
            on TPU).
    params: 'auto' (EstParams at iterations 1–2, the paper's default),
            StructuralParams for fixed thresholds, or None -> trivial.
    mesh:   optional jax Mesh — routes the fit through the distributed
            strategy; chunk_size is that runtime's per-shard object chunk.
    coarse_k / n_probe: the two-level IVF knobs (DESIGN.md §13) — a
            non-None coarse_k routes the fit through the 'two_level'
            strategy and ``model_`` becomes a nested TwoLevelFittedModel
            whose predict routes through the coarse level.
    """

    def __init__(self, k: int, *, algo: str = "esicp", params="auto",
                 backend: str = "reference", batch_size: int = 4096,
                 max_iter: int = 60, est_grid: EstGrid | None = None,
                 est_iters=(1, 2), seed: int = 0, mesh=None,
                 chunk_size: int = 1024, algo_mode: str = "full",
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 5, tune: str = "off",
                 tune_budget=None, coarse_k: int | None = None,
                 n_probe: int = 1):
        self.k = k
        self.algo = algo
        self.backend = backend
        self.params = params
        self.batch_size = batch_size
        self.max_iter = max_iter
        self.est_grid = est_grid or EstGrid()
        self.est_iters = tuple(est_iters)
        self.seed = seed
        self.mesh = mesh
        self.chunk_size = chunk_size
        self.algo_mode = algo_mode
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.tune = tune
        self.tune_budget = tune_budget
        self.coarse_k = coarse_k
        self.n_probe = n_probe

    # -- config plumbing ---------------------------------------------------
    @property
    def config(self) -> ClusterConfig:
        """The declarative view of this estimator (rebuilt per access, so
        sklearn-style attribute mutation is honoured)."""
        return ClusterConfig(
            k=self.k, algo=self.algo, backend=self.backend,
            params=self.params, batch_size=self.batch_size,
            chunk_size=self.chunk_size, max_iter=self.max_iter,
            est_grid=self.est_grid, est_iters=self.est_iters,
            seed=self.seed, mesh=self.mesh, algo_mode=self.algo_mode,
            checkpoint_dir=self.checkpoint_dir,
            checkpoint_every=self.checkpoint_every, tune=self.tune,
            tune_budget=self.tune_budget, coarse_k=self.coarse_k,
            n_probe=self.n_probe)

    @classmethod
    def from_config(cls, config: ClusterConfig) -> SphericalKMeans:
        return cls(config.k, algo=config.algo, params=config.params,
                   backend=config.backend, batch_size=config.batch_size,
                   max_iter=config.max_iter, est_grid=config.est_grid,
                   est_iters=config.est_iters, seed=config.seed,
                   mesh=config.mesh, chunk_size=config.chunk_size,
                   algo_mode=config.algo_mode,
                   checkpoint_dir=config.checkpoint_dir,
                   checkpoint_every=config.checkpoint_every,
                   tune=config.tune, tune_budget=config.tune_budget,
                   coarse_k=config.coarse_k, n_probe=config.n_probe)

    # -- the estimator surface ---------------------------------------------
    def fit(self, docs, df=None) -> SphericalKMeans:
        """Cluster ``docs`` — a resident :class:`repro.sparse.SparseDocs`
        OR an out-of-core :class:`repro.sparse.DocStore` (which routes the
        fit through the streaming strategy); returns ``self`` (sklearn
        contract)."""
        cfg = self.config.validate()
        strategy = resolve_strategy(cfg, docs)
        result = strategy.fit(docs, cfg, df=df)
        self._fit_result = result
        tuned = getattr(result, "tuned", None)
        # Strategies that assemble their own artifact (two_level's nested
        # TwoLevelFittedModel) hand it over via ``result.model``; everyone
        # else gets the flat FittedModel built here.
        model = getattr(result, "model", None)
        self.model_ = model if model is not None else FittedModel(
            index=result.state.index,
            labels=np.asarray(result.assign, np.int32),
            rho_self=np.asarray(result.state.rho_self, np.float32),
            history=list(result.history),
            converged=result.converged,
            n_iter=result.n_iter,
            algo=cfg.algo,
            backend=resolve_backend(cfg.backend).name,
            strategy=strategy.name,
            cursor=getattr(result, "cursor", None),
            tuned=None if tuned is None else tuned.to_dict(),
        )
        self.labels_ = self.model_.labels
        self.history_ = self.model_.history
        self.state_ = result.state
        self.params_ = result.params
        self.n_iter_ = result.n_iter
        self.converged_ = result.converged
        self.objective_ = result.objective   # J = Σ_i ρ_self(i) (Eq. 47)
        return self

    def fit_predict(self, docs, df=None) -> np.ndarray:
        return self.fit(docs, df=df).labels_

    def predict(self, docs) -> np.ndarray:
        """(N,) cluster ids vs the fitted index (shared classify path)."""
        return self._model().predict(docs, batch_size=self.batch_size)

    def transform(self, docs) -> np.ndarray:
        """(N, K) cosine similarities vs the fitted means."""
        return self._model().transform(docs, batch_size=self.batch_size)

    def score(self, docs) -> float:
        """Σ_i max_j cos(x_i, μ_j) (higher is better)."""
        return self._model().score(docs, batch_size=self.batch_size)

    # -- internals / legacy ------------------------------------------------
    def _model(self) -> FittedModel:
        if not hasattr(self, "model_"):
            raise AttributeError(
                "This SphericalKMeans instance is not fitted yet; "
                "call fit() first.")
        return self.model_

    def _result(self) -> LloydResult:
        if "_fit_result" not in self.__dict__:
            raise AttributeError(
                "This SphericalKMeans instance is not fitted yet; "
                "call fit() first.")
        return self._fit_result

    def fit_result(self) -> LloydResult:
        """Deprecated accessor for the pre-redesign ``fit`` return value."""
        warnings.warn(
            "SphericalKMeans.fit() now returns the estimator; read model_/"
            "labels_/history_/state_, or fit_result() during migration.",
            DeprecationWarning, stacklevel=2)
        return self._result()

    def __getattr__(self, name):
        new = _LEGACY_RESULT_ATTRS.get(name)
        if new is not None and "_fit_result" in self.__dict__:
            warnings.warn(
                f"SphericalKMeans.{name} is deprecated (fit() returns the "
                f"estimator since the repro.cluster redesign); use {new}.",
                DeprecationWarning, stacklevel=2)
            return getattr(self._fit_result, name)
        if name in _FITTED_ATTRS or new is not None:
            raise AttributeError(
                f"SphericalKMeans.{name} is only available after fit(); "
                "this instance is not fitted yet.")
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")
