"""The fitted-model artifact — one serializable noun for all three runtimes.

A :class:`FittedModel` is what a fit *produces* and what serving *consumes*:
the structured mean-inverted index (the SIVF stance: the index is a
first-class, reusable structure), the training labels and refreshed ρ_self,
the per-iteration diagnostic history, and enough metadata (algo, backend,
strategy) to reconstruct any runtime around it.  ``save``/``load`` ride the
fault-tolerant checkpoint store (checkpoint/store.py): the payload commits
atomically with a JSON metadata sidecar, so a crashed writer never leaves a
readable-but-half model on disk.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (load_extra, restore_checkpoint,
                                    save_checkpoint)
from repro.cluster.classify import classify_docs, transform_docs
from repro.core.meanindex import (MeanIndex, StructuralParams,
                                  build_mean_index)

MODEL_FORMAT = "repro.cluster/fitted-model-v1"


@dataclasses.dataclass
class FittedModel:
    """Fit output = index + labels + history + provenance.

    index:    MeanIndex — means, structural thresholds (t_th, v_th), ICP
              moving flags; everything assignment needs.
    labels:   (N,) int32 — final training assignment (empty for artifacts
              exported from a pure serving engine).
    rho_self: (N,) float32 — each doc's similarity to its own centroid, the
              next assignment step's pruning threshold ρ_max.
    history:  per-iteration diagnostics (mult, cpr, n_changed, objective, …).
    algo/backend/strategy: provenance — which algorithm, accumulator engine,
              and execution runtime produced the artifact.
    cursor:   streaming fits only — (next_epoch, next_chunk) where a
              resumed fit would continue; None for converged/resident
              fits.  A non-None cursor marks a usable-but-unconverged
              artifact (e.g. a max_iter-capped streaming fit).
    tuned:    the autotuned kernel-engine config the fit ran with, as the
              ``repro.tune.TunedConfig.to_dict()`` dict (None when tuning
              was off / missed).  ``load`` reseeds the process-wide
              ``TUNED_CACHE`` from it, so a later fit on the same corpus
              regime reuses the winner without re-searching.
    """

    index: MeanIndex
    labels: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int32))
    rho_self: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.float32))
    history: list = dataclasses.field(default_factory=list)
    converged: bool = True
    n_iter: int = 0
    algo: str = "esicp"
    backend: str = "auto"
    strategy: str = "single_host"
    cursor: tuple | None = None
    tuned: dict | None = None

    # -- derived -----------------------------------------------------------
    @property
    def k(self) -> int:
        return self.index.k

    @property
    def dim(self) -> int:
        return self.index.dim

    @property
    def params(self) -> StructuralParams:
        return self.index.params

    @property
    def objective(self) -> float:
        """J = Σ_i ρ_self(i) (Eq. 47) over the training corpus."""
        return float(np.sum(self.rho_self))

    # -- inference (the shared fused classify path) ------------------------
    def predict(self, docs, *, batch_size: int = 4096) -> np.ndarray:
        """(N,) int32 cluster ids — identical to
        ``ClusterEngine.from_model(self).classify(docs)[0]`` by construction
        (same path: cluster/classify.py)."""
        a, _ = classify_docs(self.index, docs, backend=self.backend,
                             batch_size=batch_size)
        return a

    def transform(self, docs, *, batch_size: int = 4096) -> np.ndarray:
        """(N, K) dense cosine similarities to every mean."""
        return transform_docs(self.index, docs, backend=self.backend,
                              batch_size=batch_size)

    def score(self, docs, *, batch_size: int = 4096) -> float:
        """Σ_i max_j cos(x_i, μ_j) — the spherical k-means objective of the
        best assignment (higher is better)."""
        _, sims = classify_docs(self.index, docs, backend=self.backend,
                                batch_size=batch_size)
        return float(np.sum(sims))

    def servable(self, **kw):
        """Wrap the artifact for the continuous-batching service plane —
        ``repro.serve.ServableClusterModel(self, **kw)`` (DESIGN.md §12).
        The servable inherits this model's backend and re-seeds the
        process-wide autotuner cache from ``tuned``, so the server runs the
        fit's kernel-engine winner without re-searching.  Load it (or the
        model directly) with ``ClusterServer.load``."""
        from repro.serve.servable import ServableClusterModel

        return ServableClusterModel(self, **kw)

    # -- persistence -------------------------------------------------------
    def save(self, directory: str, *, step: int = 0) -> str:
        """Atomically persist the artifact; returns the committed path."""
        tree = {
            "labels": np.asarray(self.labels, np.int32),
            "means_t": np.asarray(self.index.means_t, np.float32),
            "moving": np.asarray(self.index.moving, bool),
            "rho_self": np.asarray(self.rho_self, np.float32),
            "t_th": np.asarray(self.index.params.t_th, np.int32),
            "v_th": np.asarray(self.index.params.v_th, np.float32),
        }
        extra = {
            "format": MODEL_FORMAT,
            "algo": self.algo,
            "backend": self.backend,
            "strategy": self.strategy,
            "k": int(self.k),
            "dim": int(self.dim),
            "n_docs": int(np.shape(self.labels)[0]),
            "converged": bool(self.converged),
            "n_iter": int(self.n_iter),
            "history": self.history,
            "cursor": None if self.cursor is None else list(self.cursor),
            "tuned": self.tuned,
        }
        # keep=None: an artifact writer must never garbage-collect other
        # steps sharing the directory (e.g. a fit's training checkpoints).
        return save_checkpoint(directory, tree, step=step, keep=None,
                               extra=extra)

    @classmethod
    def load(cls, directory: str, *, step: int | None = None) -> FittedModel:
        extra = load_extra(directory, step=step)
        if not extra or extra.get("format") != MODEL_FORMAT:
            raise ValueError(
                f"{directory} holds no {MODEL_FORMAT} artifact "
                f"(found {extra.get('format') if extra else None!r})")
        n, d, k = extra["n_docs"], extra["dim"], extra["k"]
        example = {
            "labels": np.zeros((n,), np.int32),
            "means_t": np.zeros((d, k), np.float32),
            "moving": np.zeros((k,), bool),
            "rho_self": np.zeros((n,), np.float32),
            "t_th": np.asarray(0, np.int32),
            "v_th": np.asarray(0.0, np.float32),
        }
        tree, _ = restore_checkpoint(directory, example, step=step)
        tuned = extra.get("tuned")
        if tuned is not None and tuned.get("signature"):
            # Reseed the process cache: a fit on the same corpus regime in
            # this process reuses the artifact's winner without searching.
            from repro.tune import TUNED_CACHE, TunedConfig

            TUNED_CACHE.put(tuned["signature"], TunedConfig.from_dict(tuned))
        params = StructuralParams(t_th=jnp.asarray(tree["t_th"], jnp.int32),
                                  v_th=jnp.asarray(tree["v_th"], jnp.float32))
        index = build_mean_index(jnp.asarray(tree["means_t"]).T, params,
                                 moving=jnp.asarray(tree["moving"]))
        return cls(index=index,
                   labels=np.asarray(tree["labels"], np.int32),
                   rho_self=np.asarray(tree["rho_self"], np.float32),
                   history=list(extra["history"]),
                   converged=extra["converged"],
                   n_iter=extra["n_iter"],
                   algo=extra["algo"],
                   backend=extra["backend"],
                   strategy=extra["strategy"],
                   cursor=(None if extra.get("cursor") is None
                           else tuple(extra["cursor"])),
                   tuned=tuned)


def load_model(directory: str, *, step: int | None = None) -> FittedModel:
    """Module-level alias for :meth:`FittedModel.load`."""
    return FittedModel.load(directory, step=step)
