"""The fitted-model artifact — one serializable noun for all three runtimes.

A :class:`FittedModel` is what a fit *produces* and what serving *consumes*:
the structured mean-inverted index (the SIVF stance: the index is a
first-class, reusable structure), the training labels and refreshed ρ_self,
the per-iteration diagnostic history, and enough metadata (algo, backend,
strategy) to reconstruct any runtime around it.  ``save``/``load`` ride the
fault-tolerant checkpoint store (checkpoint/store.py): the payload commits
atomically with a JSON metadata sidecar, so a crashed writer never leaves a
readable-but-half model on disk.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (load_extra, restore_checkpoint,
                                    save_checkpoint)
from repro.cluster.classify import (classify_docs, classify_docs_routed,
                                    transform_docs)
from repro.core.meanindex import (MeanIndex, StructuralParams,
                                  build_mean_index)

MODEL_FORMAT = "repro.cluster/fitted-model-v1"
TWO_LEVEL_FORMAT = "repro.cluster/fitted-two-level-v1"


@dataclasses.dataclass
class FittedModel:
    """Fit output = index + labels + history + provenance.

    index:    MeanIndex — means, structural thresholds (t_th, v_th), ICP
              moving flags; everything assignment needs.
    labels:   (N,) int32 — final training assignment (empty for artifacts
              exported from a pure serving engine).
    rho_self: (N,) float32 — each doc's similarity to its own centroid, the
              next assignment step's pruning threshold ρ_max.
    history:  per-iteration diagnostics (mult, cpr, n_changed, objective, …).
    algo/backend/strategy: provenance — which algorithm, accumulator engine,
              and execution runtime produced the artifact.
    cursor:   streaming fits only — (next_epoch, next_chunk) where a
              resumed fit would continue; None for converged/resident
              fits.  A non-None cursor marks a usable-but-unconverged
              artifact (e.g. a max_iter-capped streaming fit).
    tuned:    the autotuned kernel-engine config the fit ran with, as the
              ``repro.tune.TunedConfig.to_dict()`` dict (None when tuning
              was off / missed).  ``load`` reseeds the process-wide
              ``TUNED_CACHE`` from it, so a later fit on the same corpus
              regime reuses the winner without re-searching.
    """

    index: MeanIndex
    labels: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int32))
    rho_self: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.float32))
    history: list = dataclasses.field(default_factory=list)
    converged: bool = True
    n_iter: int = 0
    algo: str = "esicp"
    backend: str = "auto"
    strategy: str = "single_host"
    cursor: tuple | None = None
    tuned: dict | None = None

    # -- derived -----------------------------------------------------------
    @property
    def k(self) -> int:
        return self.index.k

    @property
    def dim(self) -> int:
        return self.index.dim

    @property
    def params(self) -> StructuralParams:
        return self.index.params

    @property
    def objective(self) -> float:
        """J = Σ_i ρ_self(i) (Eq. 47) over the training corpus."""
        return float(np.sum(self.rho_self))

    # -- inference (the shared fused classify path) ------------------------
    def predict(self, docs, *, batch_size: int = 4096) -> np.ndarray:
        """(N,) int32 cluster ids — identical to
        ``ClusterEngine.from_model(self).classify(docs)[0]`` by construction
        (same path: cluster/classify.py)."""
        a, _ = classify_docs(self.index, docs, backend=self.backend,
                             batch_size=batch_size)
        return a

    def transform(self, docs, *, batch_size: int = 4096) -> np.ndarray:
        """(N, K) dense cosine similarities to every mean."""
        return transform_docs(self.index, docs, backend=self.backend,
                              batch_size=batch_size)

    def score(self, docs, *, batch_size: int = 4096) -> float:
        """Σ_i max_j cos(x_i, μ_j) — the spherical k-means objective of the
        best assignment (higher is better)."""
        _, sims = classify_docs(self.index, docs, backend=self.backend,
                                batch_size=batch_size)
        return float(np.sum(sims))

    def servable(self, **kw):
        """Wrap the artifact for the continuous-batching service plane —
        ``repro.serve.ServableClusterModel(self, **kw)`` (DESIGN.md §12).
        The servable inherits this model's backend and re-seeds the
        process-wide autotuner cache from ``tuned``, so the server runs the
        fit's kernel-engine winner without re-searching.  Load it (or the
        model directly) with ``ClusterServer.load``."""
        from repro.serve.servable import ServableClusterModel

        return ServableClusterModel(self, **kw)

    # -- persistence -------------------------------------------------------
    def save(self, directory: str, *, step: int = 0) -> str:
        """Atomically persist the artifact; returns the committed path."""
        tree = {
            "labels": np.asarray(self.labels, np.int32),
            "means_t": np.asarray(self.index.means_t, np.float32),
            "moving": np.asarray(self.index.moving, bool),
            "rho_self": np.asarray(self.rho_self, np.float32),
            "t_th": np.asarray(self.index.params.t_th, np.int32),
            "v_th": np.asarray(self.index.params.v_th, np.float32),
        }
        extra = {
            "format": MODEL_FORMAT,
            "algo": self.algo,
            "backend": self.backend,
            "strategy": self.strategy,
            "k": int(self.k),
            "dim": int(self.dim),
            "n_docs": int(np.shape(self.labels)[0]),
            "converged": bool(self.converged),
            "n_iter": int(self.n_iter),
            "history": self.history,
            "cursor": None if self.cursor is None else list(self.cursor),
            "tuned": self.tuned,
        }
        # keep=None: an artifact writer must never garbage-collect other
        # steps sharing the directory (e.g. a fit's training checkpoints).
        return save_checkpoint(directory, tree, step=step, keep=None,
                               extra=extra)

    @classmethod
    def load(cls, directory: str, *, step: int | None = None) -> FittedModel:
        extra = load_extra(directory, step=step)
        if (extra and extra.get("format") == TWO_LEVEL_FORMAT
                and cls is FittedModel):
            # Format dispatch: a flat loader pointed at a nested artifact
            # gets the nested model back (its flat surface is a superset).
            return TwoLevelFittedModel.load(directory, step=step)
        if not extra or extra.get("format") != MODEL_FORMAT:
            raise ValueError(
                f"{directory} holds no {MODEL_FORMAT} artifact "
                f"(found {extra.get('format') if extra else None!r})")
        n, d, k = extra["n_docs"], extra["dim"], extra["k"]
        example = {
            "labels": np.zeros((n,), np.int32),
            "means_t": np.zeros((d, k), np.float32),
            "moving": np.zeros((k,), bool),
            "rho_self": np.zeros((n,), np.float32),
            "t_th": np.asarray(0, np.int32),
            "v_th": np.asarray(0.0, np.float32),
        }
        tree, _ = restore_checkpoint(directory, example, step=step)
        tuned = extra.get("tuned")
        if tuned is not None and tuned.get("signature"):
            # Reseed the process cache: a fit on the same corpus regime in
            # this process reuses the artifact's winner without searching.
            from repro.tune import TUNED_CACHE, TunedConfig

            TUNED_CACHE.put(tuned["signature"], TunedConfig.from_dict(tuned))
        params = StructuralParams(t_th=jnp.asarray(tree["t_th"], jnp.int32),
                                  v_th=jnp.asarray(tree["v_th"], jnp.float32))
        index = build_mean_index(jnp.asarray(tree["means_t"]).T, params,
                                 moving=jnp.asarray(tree["moving"]))
        return cls(index=index,
                   labels=np.asarray(tree["labels"], np.int32),
                   rho_self=np.asarray(tree["rho_self"], np.float32),
                   history=list(extra["history"]),
                   converged=extra["converged"],
                   n_iter=extra["n_iter"],
                   algo=extra["algo"],
                   backend=extra["backend"],
                   strategy=extra["strategy"],
                   cursor=(None if extra.get("cursor") is None
                           else tuple(extra["cursor"])),
                   tuned=tuned)


@dataclasses.dataclass
class TwoLevelFittedModel(FittedModel):
    """The nested two-level IVF artifact (DESIGN.md §13).

    Extends the flat artifact — ``index`` holds the CONCATENATED fine means
    (cell 0's clusters first, then cell 1's, …), so every flat surface
    (``transform``, flat ``classify_docs``, geometry, serving buckets)
    works unchanged and ``labels`` live in that global fine space — with
    the coarse level on top:

    coarse_index: MeanIndex over the K_c coarse cell means.
    cell_sizes:   (K_c,) int32 — fine clusters per cell; ``cell_starts``
                  (the offsets of each cell's block in ``index``) derive
                  as the exclusive cumsum.  Every cell holds >= 1 fine
                  centroid (empty coarse cells keep their coarse mean), so
                  a routed argmax always has a live candidate.
    n_probe:      default probe width for ``predict`` / serving (overridable
                  per call; n_probe = K_c is exactly the flat scan).
    cell_meta:    per-cell fit provenance ({n_docs, k, n_iter, converged}).
    """

    coarse_index: MeanIndex | None = None
    cell_sizes: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int32))
    n_probe: int = 1
    cell_meta: list = dataclasses.field(default_factory=list)

    # -- derived -----------------------------------------------------------
    @property
    def coarse_k(self) -> int:
        return self.coarse_index.k

    @property
    def cell_starts(self) -> np.ndarray:
        """(K_c,) int32 — offset of each cell's block in ``index``."""
        sizes = np.asarray(self.cell_sizes, np.int64)
        return np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int32)

    def _routed_operands(self):
        """Device operands of the routed classify, built once per model:
        (coarse_index, means_ext (D, K_eff+1) with the all-zero sentinel
        column, starts (K_c,), sizes (K_c,), cmax).  Cached so serving and
        repeated predicts re-trace nothing and re-upload nothing."""
        ops = self.__dict__.get("_routed_cache")
        if ops is None:
            sizes = jnp.asarray(np.asarray(self.cell_sizes), jnp.int32)
            starts = jnp.asarray(self.cell_starts, jnp.int32)
            means_ext = jnp.concatenate(
                [self.index.means_t,
                 jnp.zeros((self.dim, 1), jnp.float32)], axis=1)
            cmax = int(np.max(np.asarray(self.cell_sizes)))
            ops = (self.coarse_index, means_ext, starts, sizes, cmax)
            self.__dict__["_routed_cache"] = ops
        return ops

    # -- inference (coarse-routed) -----------------------------------------
    def predict(self, docs, *, batch_size: int = 4096,
                n_probe: int | None = None) -> np.ndarray:
        """(N,) global fine-cluster ids via the coarse-routed classify —
        scores K_c + Σ probed cell sizes centroids per object instead of
        K_eff (exact at n_probe = K_c; ANN below it)."""
        a, _ = classify_docs_routed(self, docs, n_probe=n_probe,
                                    batch_size=batch_size)
        return a

    def score(self, docs, *, batch_size: int = 4096,
              n_probe: int | None = None) -> float:
        _, sims = classify_docs_routed(self, docs, n_probe=n_probe,
                                       batch_size=batch_size)
        return float(np.sum(sims))

    # -- persistence -------------------------------------------------------
    def save(self, directory: str, *, step: int = 0) -> str:
        tree = {
            "labels": np.asarray(self.labels, np.int32),
            "means_t": np.asarray(self.index.means_t, np.float32),
            "moving": np.asarray(self.index.moving, bool),
            "rho_self": np.asarray(self.rho_self, np.float32),
            "t_th": np.asarray(self.index.params.t_th, np.int32),
            "v_th": np.asarray(self.index.params.v_th, np.float32),
            "coarse_means_t": np.asarray(self.coarse_index.means_t,
                                         np.float32),
            "coarse_t_th": np.asarray(self.coarse_index.params.t_th,
                                      np.int32),
            "coarse_v_th": np.asarray(self.coarse_index.params.v_th,
                                      np.float32),
            "cell_sizes": np.asarray(self.cell_sizes, np.int32),
        }
        extra = {
            "format": TWO_LEVEL_FORMAT,
            "algo": self.algo,
            "backend": self.backend,
            "strategy": self.strategy,
            "k": int(self.k),
            "dim": int(self.dim),
            "coarse_k": int(self.coarse_k),
            "n_probe": int(self.n_probe),
            "n_docs": int(np.shape(self.labels)[0]),
            "converged": bool(self.converged),
            "n_iter": int(self.n_iter),
            "history": self.history,
            "cell_meta": self.cell_meta,
            "cursor": None if self.cursor is None else list(self.cursor),
            "tuned": self.tuned,
        }
        return save_checkpoint(directory, tree, step=step, keep=None,
                               extra=extra)

    @classmethod
    def load(cls, directory: str, *,
             step: int | None = None) -> "TwoLevelFittedModel":
        extra = load_extra(directory, step=step)
        if not extra or extra.get("format") != TWO_LEVEL_FORMAT:
            raise ValueError(
                f"{directory} holds no {TWO_LEVEL_FORMAT} artifact "
                f"(found {extra.get('format') if extra else None!r})")
        n, d, k, k_c = (extra["n_docs"], extra["dim"], extra["k"],
                        extra["coarse_k"])
        example = {
            "labels": np.zeros((n,), np.int32),
            "means_t": np.zeros((d, k), np.float32),
            "moving": np.zeros((k,), bool),
            "rho_self": np.zeros((n,), np.float32),
            "t_th": np.asarray(0, np.int32),
            "v_th": np.asarray(0.0, np.float32),
            "coarse_means_t": np.zeros((d, k_c), np.float32),
            "coarse_t_th": np.asarray(0, np.int32),
            "coarse_v_th": np.asarray(0.0, np.float32),
            "cell_sizes": np.zeros((k_c,), np.int32),
        }
        tree, _ = restore_checkpoint(directory, example, step=step)
        tuned = extra.get("tuned")
        if tuned is not None and tuned.get("signature"):
            from repro.tune import TUNED_CACHE, TunedConfig

            TUNED_CACHE.put(tuned["signature"], TunedConfig.from_dict(tuned))
        params = StructuralParams(t_th=jnp.asarray(tree["t_th"], jnp.int32),
                                  v_th=jnp.asarray(tree["v_th"], jnp.float32))
        cparams = StructuralParams(
            t_th=jnp.asarray(tree["coarse_t_th"], jnp.int32),
            v_th=jnp.asarray(tree["coarse_v_th"], jnp.float32))
        return cls(
            index=build_mean_index(jnp.asarray(tree["means_t"]).T, params,
                                   moving=jnp.asarray(tree["moving"])),
            coarse_index=build_mean_index(
                jnp.asarray(tree["coarse_means_t"]).T, cparams),
            cell_sizes=np.asarray(tree["cell_sizes"], np.int32),
            n_probe=int(extra["n_probe"]),
            cell_meta=list(extra.get("cell_meta") or []),
            labels=np.asarray(tree["labels"], np.int32),
            rho_self=np.asarray(tree["rho_self"], np.float32),
            history=list(extra["history"]),
            converged=extra["converged"],
            n_iter=extra["n_iter"],
            algo=extra["algo"],
            backend=extra["backend"],
            strategy=extra["strategy"],
            cursor=(None if extra.get("cursor") is None
                    else tuple(extra["cursor"])),
            tuned=tuned)


def load_model(directory: str, *, step: int | None = None) -> FittedModel:
    """Module-level alias for :meth:`FittedModel.load` (format-dispatching:
    a nested two-level artifact loads as :class:`TwoLevelFittedModel`)."""
    return FittedModel.load(directory, step=step)
