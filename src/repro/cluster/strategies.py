"""Pluggable execution strategies: one estimator, several runtimes.

A strategy turns (docs, ClusterConfig) into a :class:`LloydResult`; the
estimator wraps that into the :class:`FittedModel` artifact.  Every built-in
strategy runs the *same* algorithm and the *same* backend accumulators
(core/backends.py) — they differ only in where the arrays live:

``single_host``
    The fused on-device Lloyd fit (core/lloyd.py): one jitted while_loop,
    O(1) host syncs per fit.  Requires the corpus resident on device.

``streaming``
    The out-of-core chunk-scan fit (core/lloyd.streaming_fit) over a
    :class:`repro.sparse.DocStore`: chunks stream host→device through the
    double-buffered prefetcher, O(1) host syncs per epoch, resumable from
    mid-epoch checkpoints.  Selected by passing a DocStore to ``fit`` or
    by ``ClusterConfig(algo_mode='minibatch')``.

``mesh``
    The pod-mesh loop (distributed/kmeans.py): objects sharded over the
    object axes, the mean-inverted index over 'model', shard-local
    accumulators from the shared backend protocol, one (max, argmin-id)
    all-reduce per assignment.  Selected by ``ClusterConfig(mesh=...)``;
    also accepts a DocStore (chunks stream into the sharded object arrays).

``two_level``
    The nested IVF fit (cluster/two_level.py, DESIGN.md §13): coarse
    spherical k-means over ``ClusterConfig.coarse_k`` cells, corpus
    partitioned by coarse assignment (lazy :class:`SubsetStore` views for
    DocStores), then per-cell fine fits — each sub-fit re-entering this
    registry with a flat sub-config, so both levels run on single_host /
    streaming unchanged.  Selected by ``ClusterConfig(coarse_k=...)``;
    emits a nested :class:`repro.cluster.model.TwoLevelFittedModel`.

The registry is open: registering a new runtime (e.g. multi-pod pipelined,
async parameter-server) is one class with a ``fit`` method — no new front
door.
"""
from __future__ import annotations

from typing import Protocol

import numpy as np

from repro.cluster.config import ClusterConfig
from repro.core.lloyd import LloydResult, lloyd_fit, streaming_fit
from repro.core.meanindex import build_mean_index
from repro.core.update import KMeansState
from repro.sparse.store import DocStore, as_store


class Strategy(Protocol):
    name: str

    def fit(self, docs, config: ClusterConfig, df=None) -> LloydResult: ...


class SingleHostStrategy:
    """The fused single-host Lloyd fit (DESIGN.md §8)."""

    name = "single_host"

    def fit(self, docs, config: ClusterConfig, df=None) -> LloydResult:
        return lloyd_fit(
            docs, k=config.k, algo=config.algo, backend=config.backend,
            params=config.params, batch_size=config.batch_size,
            max_iter=config.max_iter, est_grid=config.est_grid,
            est_iters=config.est_iters, seed=config.seed, df=df,
            tune=config.tune, tune_budget=config.tune_budget)


class StreamingStrategy:
    """The out-of-core chunk-scan fit over a DocStore (DESIGN.md §10).

    Resident SparseDocs are wrapped as an in-memory store
    (``config.chunk_size`` rows per chunk) — which is how
    ``algo_mode='minibatch'`` runs on ordinary corpora too.
    """

    name = "streaming"

    def fit(self, docs, config: ClusterConfig, df=None) -> LloydResult:
        store = as_store(docs, chunk_size=config.chunk_size)
        return streaming_fit(
            store, k=config.k, algo=config.algo, backend=config.backend,
            params=config.params, algo_mode=config.algo_mode,
            batch_size=config.batch_size, max_iter=config.max_iter,
            est_grid=config.est_grid, est_iters=config.est_iters,
            seed=config.seed, df=df,
            checkpoint_dir=config.checkpoint_dir,
            checkpoint_every=config.checkpoint_every,
            tune=config.tune, tune_budget=config.tune_budget)


class MeshStrategy:
    """The distributed loop behind the same estimator (DESIGN.md §4).

    The mesh state (sharded arrays, padded tails) stays an implementation
    detail: the strategy trims padding and repackages the final shard state
    as an ordinary :class:`KMeansState`, so everything downstream — the
    FittedModel artifact, predict/classify, save/load — is runtime-blind.
    """

    name = "mesh"

    def fit(self, docs, config: ClusterConfig, df=None) -> LloydResult:
        from repro.distributed.kmeans import mesh_fit

        if config.mesh is None:
            raise ValueError("MeshStrategy needs ClusterConfig(mesh=...)")
        state, history, converged, params = mesh_fit(
            docs, config.k, config.mesh, algo=config.algo,
            backend=config.backend, max_iter=config.max_iter,
            obj_chunk=config.chunk_size, seed=config.seed,
            est_iters=config.est_iters, df=df,
            checkpoint_dir=config.checkpoint_dir,
            checkpoint_every=config.checkpoint_every,
            tune=config.tune)
        n = docs.n_docs
        index = build_mean_index(state.means_t.T, params, moving=state.moving)
        core_state = KMeansState(
            index=index,
            assign=state.assign[:n],
            rho_self=state.rho_self[:n],
            rho_self_prev=state.rho_prev[:n],
            iteration=state.iteration,
            ub=state.ub[:n],
        )
        return LloydResult(
            state=core_state,
            assign=np.asarray(core_state.assign),
            history=history,
            params=params,
            converged=converged,
            n_iter=len(history),
        )


class TwoLevelStrategy:
    """The nested IVF fit (DESIGN.md §13) — coarse cells, then per-cell
    fine fits, every sub-fit re-entering this registry with a flat
    sub-config.  Returns a duck-typed result whose ``model`` attribute
    carries the ready-made nested artifact; the estimator adopts it
    instead of assembling a flat FittedModel."""

    name = "two_level"

    def fit(self, docs, config: ClusterConfig, df=None):
        from repro.cluster.two_level import two_level_fit

        if config.coarse_k is None:
            raise ValueError("TwoLevelStrategy needs ClusterConfig("
                             "coarse_k=...)")
        return two_level_fit(docs, config, df=df)


STRATEGIES: dict[str, Strategy] = {
    "single_host": SingleHostStrategy(),
    "streaming": StreamingStrategy(),
    "mesh": MeshStrategy(),
    "two_level": TwoLevelStrategy(),
}


def resolve_strategy(config: ClusterConfig, docs=None) -> Strategy:
    """(ClusterConfig, optional input corpus) -> execution strategy.

    The config picks the name (``mesh=`` → 'mesh', ``algo_mode='minibatch'``
    → 'streaming', else 'single_host'); an out-of-core :class:`DocStore`
    input promotes 'single_host' to 'streaming', since the fused resident
    fit cannot hold the corpus on device.
    """
    if isinstance(config, ClusterConfig):
        # Every front door fails fast on an unrunnable config (duck-typed
        # registry extensions validate — or not — on their own terms).
        config.validate()
    name = config.strategy
    if name == "single_host" and isinstance(docs, DocStore):
        name = "streaming"
    try:
        return STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown execution strategy {name!r}; "
            f"valid strategies: {sorted(STRATEGIES)}") from None
