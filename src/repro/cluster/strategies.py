"""Pluggable execution strategies: one estimator, several runtimes.

A strategy turns (docs, ClusterConfig) into a :class:`LloydResult`; the
estimator wraps that into the :class:`FittedModel` artifact.  Both built-in
strategies run the *same* algorithm and the *same* backend accumulators
(core/backends.py) — they differ only in where the arrays live:

``single_host``
    The fused on-device Lloyd fit (core/lloyd.py): one jitted while_loop,
    O(1) host syncs per fit.

``mesh``
    The pod-mesh loop (distributed/kmeans.py): objects sharded over the
    object axes, the mean-inverted index over 'model', shard-local
    accumulators from the shared backend protocol, one (max, argmin-id)
    all-reduce per assignment.  Selected by ``ClusterConfig(mesh=...)``.

The registry is open: registering a new runtime (e.g. multi-pod pipelined,
async parameter-server) is one class with a ``fit`` method — no new front
door.
"""
from __future__ import annotations

from typing import Protocol

import numpy as np

from repro.cluster.config import ClusterConfig
from repro.core.lloyd import LloydResult, lloyd_fit
from repro.core.meanindex import build_mean_index
from repro.core.update import KMeansState


class Strategy(Protocol):
    name: str

    def fit(self, docs, config: ClusterConfig, df=None) -> LloydResult: ...


class SingleHostStrategy:
    """The fused single-host Lloyd fit (DESIGN.md §8)."""

    name = "single_host"

    def fit(self, docs, config: ClusterConfig, df=None) -> LloydResult:
        return lloyd_fit(
            docs, k=config.k, algo=config.algo, backend=config.backend,
            params=config.params, batch_size=config.batch_size,
            max_iter=config.max_iter, est_grid=config.est_grid,
            est_iters=config.est_iters, seed=config.seed, df=df)


class MeshStrategy:
    """The distributed loop behind the same estimator (DESIGN.md §4).

    The mesh state (sharded arrays, padded tails) stays an implementation
    detail: the strategy trims padding and repackages the final shard state
    as an ordinary :class:`KMeansState`, so everything downstream — the
    FittedModel artifact, predict/classify, save/load — is runtime-blind.
    """

    name = "mesh"

    def fit(self, docs, config: ClusterConfig, df=None) -> LloydResult:
        from repro.distributed.kmeans import mesh_fit

        if config.mesh is None:
            raise ValueError("MeshStrategy needs ClusterConfig(mesh=...)")
        state, history, converged, params = mesh_fit(
            docs, config.k, config.mesh, algo=config.algo,
            backend=config.backend, max_iter=config.max_iter,
            obj_chunk=config.chunk_size, seed=config.seed,
            est_iters=config.est_iters, df=df,
            checkpoint_dir=config.checkpoint_dir,
            checkpoint_every=config.checkpoint_every)
        n = docs.n_docs
        index = build_mean_index(state.means_t.T, params, moving=state.moving)
        core_state = KMeansState(
            index=index,
            assign=state.assign[:n],
            rho_self=state.rho_self[:n],
            rho_self_prev=state.rho_prev[:n],
            iteration=state.iteration,
        )
        return LloydResult(
            state=core_state,
            assign=np.asarray(core_state.assign),
            history=history,
            params=params,
            converged=converged,
            n_iter=len(history),
        )


STRATEGIES: dict[str, Strategy] = {
    "single_host": SingleHostStrategy(),
    "mesh": MeshStrategy(),
}


def resolve_strategy(config: ClusterConfig) -> Strategy:
    """ClusterConfig -> the strategy its ``mesh`` field selects."""
    return STRATEGIES[config.strategy]
