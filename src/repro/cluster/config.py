"""Declarative clustering configuration — the single front door's one noun.

A :class:`ClusterConfig` says *what* to fit (k, algorithm, thresholds) and
*where* to run it (backend, batch/chunk sizes, optional ``mesh=`` execution
target); it never holds fitted state.  The estimator, the module-level
:func:`repro.cluster.fit`, and the execution strategies all consume the same
config, so single-host, mesh-distributed, and serving runtimes cannot drift
apart kwarg by kwarg (the divergence this PR deletes).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.estparams import EstGrid
from repro.core.meanindex import StructuralParams


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Everything a spherical k-means fit needs, declared up front.

    k:          number of clusters.
    algo:       'mivi' | 'icp' | 'es' | 'esicp' | 'ta-icp' | 'cs-icp'
                | 'bounds' | 'sketch' | 'bounds-esicp' (the compounded
                pruning modes — core/assignment.py).
    algo_mode:  'full' (exact Lloyd, the paper's setting) | 'minibatch'
                (Sculley-style streaming updates over DocStore chunks —
                always runs on the 'streaming' strategy).
    backend:    'reference' | 'pallas' | 'xla_blocked' | 'auto' —
                accumulator engine for assignment AND update
                (core/backends.py; 'auto' = the compiled engine for the
                platform: pallas on TPU, xla_blocked elsewhere).
    params:     'auto' (EstParams at ``est_iters``, the paper's default),
                a StructuralParams for fixed thresholds, or None (trivial).
    batch_size: single-host fused-epoch batch (rows per scan tile).
    chunk_size: object-chunk rows: per-shard chunk on the mesh runtime
                (``obj_chunk`` in distributed/kmeans.py) and the DocStore
                chunk when the streaming strategy wraps resident docs.
    est_grid:   EstParams candidate grid (None -> EstGrid()).
    est_iters:  iterations that re-estimate (t_th, v_th).
    seed:       centroid-seeding PRNG seed.
    mesh:       optional jax Mesh — set it and the *same* estimator runs
                through the distributed loop (the 'mesh' strategy).
    checkpoint_dir/checkpoint_every: optional fault-tolerant checkpointing:
                every N iterations on the mesh runtime, every N chunks
                (mid-epoch, resumable) on the streaming runtime.
    tune:       'off' (defaults) | 'cached' (reuse a previously found
                winner for this corpus regime, fall back to defaults on a
                miss) | 'search' (run the roofline-pruned autotuner on a
                miss and cache the winner — repro.tune).  No-op on the
                reference backend; the mesh runtime resolves cache-only.
    tune_budget: optional repro.tune.SearchBudget (or int max timed
                candidates) for 'search' mode.
    coarse_k:   None (flat fit, the default) or K_c >= 2 — the two-level
                IVF regime (DESIGN.md §13): a coarse spherical k-means over
                K_c cells partitions the corpus, then the K fine clusters
                are fitted per cell, so fit AND classify scale with one
                cell instead of K.  Requires 2 <= coarse_k < k; runs on the
                'two_level' strategy (mesh= is not supported there yet).
    n_probe:    coarse cells the routed classify scores per object
                (1 <= n_probe <= coarse_k).  n_probe=1 is the fast ANN
                setting; n_probe=coarse_k probes every cell and is exact —
                it IS the flat scan.  Ignored for flat fits.
    """

    k: int
    algo: str = "esicp"
    backend: str = "reference"
    params: Any = "auto"
    batch_size: int = 4096
    chunk_size: int = 1024
    max_iter: int = 60
    est_grid: EstGrid | None = None
    est_iters: tuple = (1, 2)
    seed: int = 0
    mesh: Any = None
    algo_mode: str = "full"
    checkpoint_dir: str | None = None
    checkpoint_every: int = 5
    tune: str = "off"
    tune_budget: Any = None
    coarse_k: int | None = None
    n_probe: int = 1

    def __post_init__(self):
        object.__setattr__(self, "est_iters", tuple(self.est_iters))

    @property
    def strategy(self) -> str:
        """Execution-strategy name this config resolves to.  A DocStore
        input additionally promotes 'single_host' to 'streaming' at
        ``resolve_strategy`` time (the data's residency, not the config,
        decides)."""
        if self.coarse_k is not None:
            return "two_level"
        if self.mesh is not None:
            return "mesh"
        return "streaming" if self.algo_mode == "minibatch" else "single_host"

    def replace(self, **changes) -> ClusterConfig:
        return dataclasses.replace(self, **changes)

    def validate(self) -> ClusterConfig:
        """Fail fast on a config no strategy could run.  Returns self."""
        from repro.core.assignment import ALGORITHMS
        from repro.core.backends import resolve_backend

        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.algo not in ALGORITHMS:
            raise ValueError(
                f"unknown algorithm {self.algo!r}; one of {sorted(ALGORITHMS)}")
        resolve_backend(self.backend)          # raises on unknown backends
        if not (self.params == "auto" or self.params is None
                or isinstance(self.params, StructuralParams)):
            raise ValueError(
                "params must be 'auto', None, or a StructuralParams; "
                f"got {self.params!r}")
        if self.batch_size < 1 or self.chunk_size < 1 or self.max_iter < 1:
            raise ValueError("batch_size, chunk_size, max_iter must be >= 1")
        if self.algo_mode not in ("full", "minibatch"):
            raise ValueError(f"algo_mode must be 'full' or 'minibatch', "
                             f"got {self.algo_mode!r}")
        if self.tune not in ("off", "cached", "search"):
            raise ValueError(f"tune must be 'off', 'cached' or 'search', "
                             f"got {self.tune!r}")
        if self.coarse_k is not None:
            # The two-level IVF knobs (DESIGN.md §13) — same fail-fast
            # discipline as the flat knobs above: every front door
            # (estimator, module-level fit, resolve_strategy) rejects an
            # unrunnable nesting before any coarse fit starts.
            if self.coarse_k < 2:
                raise ValueError(
                    f"coarse_k must be >= 2 (a one-cell coarse level is the "
                    f"flat fit; pass coarse_k=None for that), got "
                    f"{self.coarse_k}")
            if self.coarse_k >= self.k:
                raise ValueError(
                    f"coarse_k must be < k (each coarse cell holds at least "
                    f"one fine cluster), got coarse_k={self.coarse_k} >= "
                    f"k={self.k}")
            if self.mesh is not None:
                raise ValueError(
                    "coarse_k (the two-level strategy) cannot be combined "
                    "with mesh= yet; run the coarse/fine fits single-host "
                    "or streaming")
        if not 1 <= self.n_probe <= (self.coarse_k or self.n_probe):
            raise ValueError(
                f"n_probe must be in [1, coarse_k={self.coarse_k}], got "
                f"{self.n_probe}")
        if self.algo_mode == "minibatch" and self.mesh is not None:
            raise ValueError(
                "algo_mode='minibatch' runs on the streaming strategy; "
                "it cannot be combined with mesh=")
        if self.mesh is not None:
            # The shard-local step implements the shared-bound algorithms
            # only (distributed/kmeans.py); fail here, not deep inside
            # shard_map tracing.
            mesh_algos = ("esicp", "mivi", "icp",
                          "bounds", "sketch", "bounds-esicp")
            if self.algo not in mesh_algos:
                raise ValueError(
                    f"algo {self.algo!r} is not available on the mesh "
                    f"strategy; one of {mesh_algos}")
            n_model = dict(self.mesh.shape).get("model", 1)
            if self.k % n_model:
                raise ValueError(
                    f"K={self.k} must divide over the mesh's model axis "
                    f"({n_model})")
        return self
