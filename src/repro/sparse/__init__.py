"""Padded sparse representations for document-feature vectors.

The paper stores each object as a tuple array ``[(term_id, value)] * nt_i`` with
term IDs sorted ascending by document frequency (df).  On TPU we keep exactly
that layout, padded to a fixed ``nt_max`` per batch so every shape is static:
``ids[(N, P)] int32`` / ``vals[(N, P)] float32`` with ``val == 0`` on padding.

Padding uses term id 0 with value 0 so any gather stays in bounds and any
multiply contributes nothing.
"""
from repro.sparse.matrix import (
    SparseDocs,
    from_dense,
    to_dense,
    df_counts,
    with_df,
    tf_idf,
    l2_normalize_rows,
    remap_terms_by_df,
    l1_tail,
    pad_rows,
)
from repro.sparse.store import (
    ChunkPrefetcher,
    DocStore,
    DocStoreBuilder,
    SubsetStore,
    as_store,
    partition_store,
)

__all__ = [
    "SparseDocs",
    "from_dense",
    "to_dense",
    "df_counts",
    "with_df",
    "tf_idf",
    "l2_normalize_rows",
    "remap_terms_by_df",
    "l1_tail",
    "pad_rows",
    "ChunkPrefetcher",
    "DocStore",
    "DocStoreBuilder",
    "SubsetStore",
    "as_store",
    "partition_store",
]
