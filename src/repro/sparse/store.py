"""Out-of-core corpus store: fixed-shape SparseDocs chunks (DESIGN.md §10).

The paper's regime is 8.7M-document PubMed — far beyond one device's HBM as
a resident ``(N, P)`` padded array.  A :class:`DocStore` keeps the corpus on
the host (memmapped ``.npy`` chunk files, or plain numpy arrays for small
corpora) as a sequence of *uniform* ``(C, P)`` chunks:

  * every chunk has the identical static shape, so ONE jitted per-chunk
    step serves the whole corpus — no shape-polymorphic retracing;
  * the final chunk is padded with dead rows (``nnz = 0``, ids/vals 0) under
    the repo-wide ``ρ_self = 0`` tail convention (core/lloyd.py): dead rows
    accumulate nothing and are valid-masked out of every diagnostic;
  * only the small per-document state (assign, ρ_self — 4 bytes/doc each)
    stays device-resident during a fit; the ``(N, P)`` tuple arrays stream
    through a double-buffered host→device prefetcher.

:class:`DocStoreBuilder` is the one-pass streaming ingest: callers append
raw (term-id, value) rows in any number of batches; the builder spills raw
chunks to disk while accumulating the global document frequencies, then
``finalize`` streams each spilled chunk once more through the paper's
preprocessing — tf-idf (Eq. 15), the df-rank term remap (Table I), L2
normalisation — without ever materialising the corpus in memory.

``DocStore.from_docs(docs)`` wraps a resident :class:`SparseDocs` as a
trivial in-memory store (one chunk by default), which is how
``SphericalKMeans.fit(docs)`` keeps its exact semantics on the chunked
code path (bitwise-parity-tested in tests/test_store.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.matrix import SparseDocs

_META = "store.json"
STORE_FORMAT = "repro.sparse/doc-store-v1"


def _chunk_paths(directory: str, ci: int) -> dict:
    stem = os.path.join(directory, f"chunk_{ci:05d}")
    return {name: f"{stem}.{name}.npy" for name in ("ids", "vals", "nnz")}


class DocStore:
    """N documents as ``ceil(N / C)`` uniform ``(C, P)`` host chunks.

    Two backings share one interface:

      * **memory** — a list of ``(ids, vals, nnz)`` numpy chunk tuples
        (``from_docs``): full chunks are views into the resident arrays;
        only the padded final chunk is copied;
      * **disk** — a directory of per-chunk ``.npy`` files plus a
        ``store.json`` manifest (``open`` / ``DocStoreBuilder``); chunk
        arrays are memmapped, so reading chunk *i* touches only its bytes.

    ``chunk(i)`` returns the chunk as a host-backed :class:`SparseDocs`;
    :class:`ChunkPrefetcher` overlaps the host read + H2D copy of chunk
    *i+1* with the device compute on chunk *i*.
    """

    def __init__(self, *, n_docs: int, dim: int, chunk_size: int,
                 pad_width: int, chunks: list | None = None,
                 directory: str | None = None, df: np.ndarray | None = None):
        if (chunks is None) == (directory is None):
            raise ValueError("exactly one of chunks= / directory= backs a store")
        self.n_docs = int(n_docs)
        self.dim = int(dim)
        self.chunk_size = int(chunk_size)
        self.pad_width = int(pad_width)
        self._chunks = chunks
        self.directory = directory
        self._df = None if df is None else np.asarray(df)
        self.n_chunks = -(-self.n_docs // self.chunk_size)
        if self.n_chunks < 1:
            raise ValueError("a DocStore needs at least one document")

    # -- geometry ----------------------------------------------------------
    @property
    def n_rows(self) -> int:
        """Total rows including the dead tail of the final chunk."""
        return self.n_chunks * self.chunk_size

    @property
    def df(self) -> np.ndarray:
        """(D,) global document frequencies (counted once if not stored)."""
        if self._df is None:
            df = np.zeros((self.dim,), np.int64)
            for ci in range(self.n_chunks):
                ids, vals, nnz = self.host_chunk(ci)
                live = np.arange(self.pad_width)[None, :] < nnz[:, None]
                df += np.bincount(ids[live].ravel(), minlength=self.dim)
            self._df = df.astype(np.int32)
        return self._df

    def chunk_valid(self, ci: int) -> np.ndarray:
        """(C,) bool — True on rows backed by a real document."""
        start = ci * self.chunk_size
        return (start + np.arange(self.chunk_size)) < self.n_docs

    # -- chunk access ------------------------------------------------------
    def host_chunk(self, ci: int):
        """(ids, vals, nnz) numpy arrays of chunk ``ci`` (memmapped on disk
        stores — reading is lazy per chunk)."""
        if not 0 <= ci < self.n_chunks:
            raise IndexError(f"chunk {ci} out of range [0, {self.n_chunks})")
        if self._chunks is not None:
            return self._chunks[ci]
        paths = _chunk_paths(self.directory, ci)
        return tuple(np.load(paths[k], mmap_mode="r")
                     for k in ("ids", "vals", "nnz"))

    def chunk(self, ci: int) -> SparseDocs:
        """Chunk ``ci`` as a SparseDocs (host → default-device arrays)."""
        ids, vals, nnz = self.host_chunk(ci)
        return SparseDocs(ids=jnp.asarray(ids, jnp.int32),
                          vals=jnp.asarray(vals, jnp.float32),
                          nnz=jnp.asarray(nnz, jnp.int32), dim=self.dim)

    def __iter__(self):
        for ci in range(self.n_chunks):
            yield ci, self.chunk(ci)

    def gather_rows(self, indices) -> SparseDocs:
        """The given global rows as one small SparseDocs (host gather) —
        centroid seeding reads K rows without touching the other chunks."""
        indices = np.asarray(indices)
        ids = np.zeros((len(indices), self.pad_width), np.int32)
        vals = np.zeros((len(indices), self.pad_width), np.float32)
        nnz = np.zeros((len(indices),), np.int32)
        order = np.argsort(indices // self.chunk_size, kind="stable")
        ci_prev, chunk = -1, None
        for pos in order:
            gi = int(indices[pos])
            if not 0 <= gi < self.n_docs:
                raise IndexError(f"row {gi} out of range [0, {self.n_docs})")
            ci, ri = divmod(gi, self.chunk_size)
            if ci != ci_prev:
                chunk, ci_prev = self.host_chunk(ci), ci
            ids[pos], vals[pos], nnz[pos] = (chunk[0][ri], chunk[1][ri],
                                             chunk[2][ri])
        return SparseDocs(ids=jnp.asarray(ids), vals=jnp.asarray(vals),
                          nnz=jnp.asarray(nnz), dim=self.dim)

    def to_docs(self) -> SparseDocs:
        """Concatenate every chunk into one resident SparseDocs (small
        stores / tests only — this is exactly what a DocStore avoids)."""
        parts = [self.host_chunk(ci) for ci in range(self.n_chunks)]
        docs = SparseDocs(
            ids=jnp.asarray(np.concatenate([p[0] for p in parts])[:self.n_docs]),
            vals=jnp.asarray(np.concatenate([p[1] for p in parts])[:self.n_docs]),
            nnz=jnp.asarray(np.concatenate([p[2] for p in parts])[:self.n_docs]),
            dim=self.dim)
        return dataclasses.replace(docs, _df=jnp.asarray(self.df))

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_docs(cls, docs: SparseDocs, *, chunk_size: int | None = None,
                  df=None) -> "DocStore":
        """Wrap a resident corpus as an in-memory store.

        chunk_size=None (the default) yields ONE chunk covering the whole
        corpus — the trivial store through which ``fit(docs)`` keeps its
        exact resident semantics on the chunked code path.
        """
        n, p = docs.ids.shape
        c = int(chunk_size or n)
        ids = np.asarray(docs.ids, np.int32)
        vals = np.asarray(docs.vals, np.float32)
        nnz = np.asarray(docs.nnz, np.int32)
        chunks = []
        for start in range(0, n, c):
            m = min(c, n - start)
            if m == c:           # full chunk: a view, no copy
                chunks.append((ids[start:start + c], vals[start:start + c],
                               nnz[start:start + c]))
                continue
            cidx = np.zeros((c, p), np.int32)
            cval = np.zeros((c, p), np.float32)
            cnnz = np.zeros((c,), np.int32)
            cidx[:m], cval[:m], cnnz[:m] = (ids[start:start + m],
                                            vals[start:start + m],
                                            nnz[start:start + m])
            chunks.append((cidx, cval, cnnz))
        if df is None and docs._df is not None:
            df = docs._df
        return cls(n_docs=n, dim=docs.dim, chunk_size=c, pad_width=p,
                   chunks=chunks,
                   df=None if df is None else np.asarray(df))

    def subset(self, rows, *, chunk_size: int | None = None) -> "SubsetStore":
        """A read-only row-subset *view* of this store (DESIGN.md §13).

        The two-level fit partitions an out-of-core corpus by coarse
        assignment; a :class:`SubsetStore` presents one partition as a
        first-class DocStore — same uniform-chunk interface, same dead-row
        tail convention — while reading rows lazily from the parent's
        chunks, so a per-cell corpus is never materialised densely.
        """
        return SubsetStore(self, rows, chunk_size=chunk_size)

    @classmethod
    def open(cls, directory: str) -> "DocStore":
        with open(os.path.join(directory, _META)) as f:
            meta = json.load(f)
        if meta.get("format") != STORE_FORMAT:
            raise ValueError(f"{directory} holds no {STORE_FORMAT} store "
                             f"(found {meta.get('format')!r})")
        df_path = os.path.join(directory, "df.npy")
        df = np.load(df_path) if os.path.exists(df_path) else None
        return cls(n_docs=meta["n_docs"], dim=meta["dim"],
                   chunk_size=meta["chunk_size"], pad_width=meta["pad_width"],
                   directory=directory, df=df)

    def save(self, directory: str) -> "DocStore":
        """Persist an in-memory store as a disk store (chunk files + df +
        manifest); returns the reopened disk-backed store."""
        os.makedirs(directory, exist_ok=True)
        for ci in range(self.n_chunks):
            ids, vals, nnz = self.host_chunk(ci)
            paths = _chunk_paths(directory, ci)
            np.save(paths["ids"], np.asarray(ids, np.int32))
            np.save(paths["vals"], np.asarray(vals, np.float32))
            np.save(paths["nnz"], np.asarray(nnz, np.int32))
        np.save(os.path.join(directory, "df.npy"), np.asarray(self.df))
        with open(os.path.join(directory, _META), "w") as f:
            json.dump({"format": STORE_FORMAT, "n_docs": self.n_docs,
                       "dim": self.dim, "chunk_size": self.chunk_size,
                       "pad_width": self.pad_width,
                       "n_chunks": self.n_chunks}, f)
        return DocStore.open(directory)


# ---------------------------------------------------------------------------
# Partitioned sub-store views (two-level IVF fits — DESIGN.md §13).
# ---------------------------------------------------------------------------

class SubsetStore(DocStore):
    """A lazy row-subset view over a parent :class:`DocStore`.

    Holds only the (n_sub,) global row indices; every ``host_chunk`` call
    gathers its rows from the parent's chunks on demand (grouped so each
    parent chunk is touched once per sub-chunk, a memmap page-in on disk
    parents).  The view is a full DocStore: uniform ``(C, P)`` chunks, a
    dead-row-padded tail (``nnz = 0`` under the repo-wide ``ρ_self = 0``
    convention), ``gather_rows`` seeding reads, and the prefetcher — so the
    streaming fit runs on a partition exactly as it runs on the parent,
    without the 8.7M-doc regime ever materialising a per-cell corpus.

    ``df`` is NOT inherited from the parent: a partition's document
    frequencies differ from the corpus's.  Reading ``.df`` counts the
    subset lazily; two-level fits pass the *global* df explicitly instead
    (the df-rank term order and t_th thresholds live in global-df space).
    """

    def __init__(self, parent: DocStore, rows, *, chunk_size: int | None = None):
        rows = np.asarray(rows, np.int64).ravel()
        if rows.size and not ((rows >= 0) & (rows < parent.n_docs)).all():
            raise IndexError(
                f"subset rows out of range [0, {parent.n_docs})")
        if rows.size == 0:
            raise ValueError("a SubsetStore needs at least one row")
        self.parent = parent
        self.rows = rows
        self.n_docs = int(rows.size)
        self.dim = parent.dim
        self.chunk_size = int(min(chunk_size or parent.chunk_size,
                                  self.n_docs))
        self.pad_width = parent.pad_width
        self._chunks = None
        self.directory = None
        self._df = None
        self.n_chunks = -(-self.n_docs // self.chunk_size)

    def host_chunk(self, ci: int):
        if not 0 <= ci < self.n_chunks:
            raise IndexError(f"chunk {ci} out of range [0, {self.n_chunks})")
        g = self.rows[ci * self.chunk_size:(ci + 1) * self.chunk_size]
        c, p = self.chunk_size, self.pad_width
        ids = np.zeros((c, p), np.int32)
        vals = np.zeros((c, p), np.float32)
        nnz = np.zeros((c,), np.int32)
        # Group the gather by parent chunk so each parent chunk is read
        # once; the trailing [len(g), c) rows stay dead (tail padding).
        order = np.argsort(g // self.parent.chunk_size, kind="stable")
        prev, chunk = -1, None
        for pos in order:
            pc, pr = divmod(int(g[pos]), self.parent.chunk_size)
            if pc != prev:
                chunk, prev = self.parent.host_chunk(pc), pc
            ids[pos], vals[pos], nnz[pos] = (chunk[0][pr], chunk[1][pr],
                                             chunk[2][pr])
        return ids, vals, nnz

    def save(self, directory: str) -> DocStore:
        raise NotImplementedError(
            "a SubsetStore is a transient fit-time view; save the parent "
            "store (or subset.to_docs() for small partitions) instead")


def partition_store(store: DocStore, labels, n_cells: int, *,
                    chunk_size: int | None = None) -> list:
    """Partition a store by per-row cell labels → one view per cell.

    labels: (n_docs,) int — cell id per corpus row (e.g. the coarse
    assignment).  Returns a list of ``n_cells`` entries: a
    :class:`SubsetStore` view (rows in corpus order) for non-empty cells,
    ``None`` for empty ones — a two-level fit gives those a single fine
    centroid (the coarse mean) rather than fitting nothing.
    """
    labels = np.asarray(labels)
    if labels.shape != (store.n_docs,):
        raise ValueError(f"labels must be ({store.n_docs},), got "
                         f"{labels.shape}")
    order = np.argsort(labels, kind="stable")     # corpus order within cells
    counts = np.bincount(labels, minlength=n_cells)
    views, start = [], 0
    for c in range(n_cells):
        stop = start + int(counts[c])
        views.append(None if stop == start else
                     store.subset(order[start:stop], chunk_size=chunk_size))
        start = stop
    return views


# ---------------------------------------------------------------------------
# Streaming ingest.
# ---------------------------------------------------------------------------

class DocStoreBuilder:
    """One-pass streaming corpus ingest → preprocessed on-disk DocStore.

    ``append`` takes raw (ids, vals) row batches in corpus order, spilling
    full raw chunks to ``<directory>/raw_*`` while folding their live ids
    into the global df counts — the corpus is never resident.  ``finalize``
    then streams every raw chunk once through the paper's preprocessing
    with the now-known global statistics:

      1. tf-idf:  ``val *= log(N / df_term)``          (Eq. 15);
      2. df-rank remap: ids → ascending-df rank, rows re-sorted so the
         ``id >= t_th`` suffix is contiguous            (Table I);
      3. L2 normalisation onto the unit sphere;
      4. tail padding: the final chunk is topped up with dead rows
         (nnz = 0) under the ρ_self = 0 convention.

    The raw spill files are deleted on successful finalize.
    """

    def __init__(self, directory: str, *, dim: int, chunk_size: int,
                 pad_width: int):
        self.directory = directory
        self.dim = int(dim)
        self.chunk_size = int(chunk_size)
        self.pad_width = int(pad_width)
        os.makedirs(directory, exist_ok=True)
        self._df = np.zeros((dim,), np.int64)
        self._buf = []            # pending rows: list of (ids, vals, nnz)
        self._buffered = 0
        self._n_docs = 0
        self._n_raw = 0
        self._finalized = False

    def append(self, ids, vals, nnz=None) -> "DocStoreBuilder":
        """Add a batch of rows: ids (B, p<=P) int, vals (B, p) float; nnz
        defaults to the per-row count of non-zero vals."""
        if self._finalized:
            raise RuntimeError("builder already finalized")
        ids = np.asarray(ids, np.int32)
        vals = np.asarray(vals, np.float32)
        if ids.shape != vals.shape or ids.ndim != 2:
            raise ValueError("ids/vals must be matching (B, p) arrays")
        if ids.shape[1] > self.pad_width:
            raise ValueError(f"rows have {ids.shape[1]} tuple slots > "
                             f"pad_width {self.pad_width}")
        nnz = (np.sum(vals != 0.0, axis=1).astype(np.int32)
               if nnz is None else np.asarray(nnz, np.int32))
        b, p = ids.shape
        wide_i = np.zeros((b, self.pad_width), np.int32)
        wide_v = np.zeros((b, self.pad_width), np.float32)
        wide_i[:, :p], wide_v[:, :p] = ids, vals
        live = np.arange(self.pad_width)[None, :] < nnz[:, None]
        if int(wide_i[live].max(initial=0)) >= self.dim:
            raise ValueError("term id out of range for dim")
        self._df += np.bincount(wide_i[live].ravel(), minlength=self.dim)
        self._buf.append((wide_i, np.where(live, wide_v, 0.0), nnz))
        self._buffered += b
        self._n_docs += b
        while self._buffered >= self.chunk_size:
            self._spill()
        return self

    def _take(self, n: int):
        out, taken = [], 0
        while taken < n:
            ids, vals, nnz = self._buf[0]
            take = min(n - taken, len(nnz))
            out.append((ids[:take], vals[:take], nnz[:take]))
            if take == len(nnz):
                self._buf.pop(0)
            else:
                self._buf[0] = (ids[take:], vals[take:], nnz[take:])
            taken += take
        self._buffered -= n
        return (np.concatenate([o[0] for o in out]),
                np.concatenate([o[1] for o in out]),
                np.concatenate([o[2] for o in out]))

    def _spill(self):
        ids, vals, nnz = self._take(min(self.chunk_size, self._buffered))
        stem = os.path.join(self.directory, f"raw_{self._n_raw:05d}")
        np.save(f"{stem}.ids.npy", ids)
        np.save(f"{stem}.vals.npy", vals)
        np.save(f"{stem}.nnz.npy", nnz)
        self._n_raw += 1

    def finalize(self, *, tf_idf: bool = True, normalize: bool = True,
                 remap: bool = True) -> DocStore:
        """Stream the spilled chunks through preprocessing; returns the
        opened disk-backed DocStore (ids ascend by df-rank per row when
        ``remap``, matching :func:`repro.sparse.remap_terms_by_df`)."""
        if self._finalized:
            raise RuntimeError("builder already finalized")
        if self._n_docs == 0:
            raise ValueError("no documents appended")
        if self._buffered:
            self._spill()
        self._finalized = True

        df = self._df
        perm = np.argsort(df, kind="stable")       # perm[new] = old
        inv = np.argsort(perm, kind="stable")      # inv[old] = new
        idf = np.log(float(self._n_docs)
                     / np.maximum(df.astype(np.float64), 1.0)).astype(np.float32)
        c, p = self.chunk_size, self.pad_width

        n_out = 0
        for ri in range(self._n_raw):
            stem = os.path.join(self.directory, f"raw_{ri:05d}")
            ids = np.load(f"{stem}.ids.npy")
            vals = np.load(f"{stem}.vals.npy")
            nnz = np.load(f"{stem}.nnz.npy")
            live = np.arange(p)[None, :] < nnz[:, None]
            if tf_idf:
                vals = np.where(live, vals * idf[ids], 0.0).astype(np.float32)
            if remap:
                new_ids = inv[ids]
                key = np.where(live, new_ids, self.dim)
                order = np.argsort(key, axis=1, kind="stable")
                ids = np.take_along_axis(
                    np.where(live, new_ids, 0), order, axis=1).astype(np.int32)
                vals = np.take_along_axis(
                    np.where(live, vals, np.float32(0.0)), order, axis=1)
            if normalize:
                norm = np.sqrt(np.sum(vals.astype(np.float64) ** 2, axis=1)
                               + 1e-12)
                vals = (vals / norm[:, None].astype(np.float32)).astype(
                    np.float32)
            if len(nnz) < c:                         # dead-row tail padding
                pad = c - len(nnz)
                ids = np.concatenate([ids, np.zeros((pad, p), np.int32)])
                vals = np.concatenate([vals, np.zeros((pad, p), np.float32)])
                nnz = np.concatenate([nnz, np.zeros((pad,), np.int32)])
            paths = _chunk_paths(self.directory, ri)
            np.save(paths["ids"], ids)
            np.save(paths["vals"], vals)
            np.save(paths["nnz"], nnz)
            n_out += 1
            for name in ("ids", "vals", "nnz"):
                os.remove(f"{stem}.{name}.npy")

        np.save(os.path.join(self.directory, "df.npy"),
                (df[perm] if remap else df).astype(np.int32))
        with open(os.path.join(self.directory, _META), "w") as f:
            json.dump({"format": STORE_FORMAT, "n_docs": self._n_docs,
                       "dim": self.dim, "chunk_size": c, "pad_width": p,
                       "n_chunks": n_out}, f)
        return DocStore.open(self.directory)

    def abort(self):
        """Delete everything the builder wrote (crash-cleanup helper)."""
        shutil.rmtree(self.directory, ignore_errors=True)


# ---------------------------------------------------------------------------
# Async host→device prefetch.
# ---------------------------------------------------------------------------

class ChunkPrefetcher:
    """Double-buffered host→device chunk feed.

    A background thread reads chunk ``i+1`` from the store (a memmap page-in
    on disk stores) and enqueues its ``jax.device_put`` — an *async* H2D
    copy — while the consumer computes on chunk ``i``; ``depth`` bounds the
    number of chunks resident on device at once (default 2 = classic double
    buffering).  Iterating yields ``(chunk_index, SparseDocs-on-device)`` in
    ``order`` (default: sequential).  Producer exceptions re-raise at the
    consumer's next pull, so a torn disk read cannot hang the fit.

    ``prepare`` — an optional ``(chunk_index, docs) -> extra`` callable run
    on the producer thread; its result (e.g. the chunk's prepared kernel
    plan, see ``core/lloyd._ChunkPlanCache``) rides the queue beside the
    chunk, so prepared slabs overlap H2D with the consumer's compute just
    like the raw tuples do.  With ``prepare`` set, iteration yields
    ``(chunk_index, docs, extra)`` triples.
    """

    def __init__(self, store: DocStore, *, depth: int = 2, order=None,
                 device=None, prepare=None):
        self.store = store
        self.depth = max(int(depth), 1)
        self.order = list(range(store.n_chunks)) if order is None else list(order)
        self.device = device
        self.prepare = prepare

    def __iter__(self):
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        stop = threading.Event()
        _END, _ERR = object(), object()

        def put(item) -> bool:
            # Bounded-wait puts so an abandoned consumer (exception or
            # early break in the driving loop) cannot park this thread on
            # a full queue forever, pinning `depth` prefetched chunks.
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def produce():
            try:
                for ci in self.order:
                    if stop.is_set():
                        return
                    docs = self.store.chunk(ci)
                    if self.device is not None:
                        docs = jax.device_put(docs, self.device)
                    item = ((ci, docs) if self.prepare is None
                            else (ci, docs, self.prepare(ci, docs)))
                    if not put(item):
                        return
                put(_END)
            except BaseException as e:          # rethrown at the consumer
                put((_ERR, e))

        t = threading.Thread(target=produce, daemon=True)
        t.start()
        try:
            while True:
                item = q.get()
                if item is _END:
                    break
                if isinstance(item, tuple) and item[0] is _ERR:
                    raise item[1]
                yield item
        finally:
            # Runs on exhaustion AND on generator close (consumer bailed):
            # unblock the producer, then drop whatever it already staged.
            stop.set()
            t.join()
            while not q.empty():
                q.get_nowait()


def as_store(docs, *, chunk_size: int | None = None) -> DocStore:
    """Coerce SparseDocs | DocStore → DocStore (the strategies' front gate)."""
    if isinstance(docs, DocStore):
        return docs
    return DocStore.from_docs(docs, chunk_size=chunk_size)
