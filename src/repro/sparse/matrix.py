"""Sparse document matrix: fixed-width padded (ids, vals) rows.

All functions are pure JAX unless noted ``host_``; the host builders use numpy
because corpus construction happens once, off the accelerator.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseDocs:
    """N documents, each a padded list of (term id, feature value) tuples.

    ids:  (N, P) int32, term IDs ascending within a row (df-rank order once
          :func:`remap_terms_by_df` has been applied); 0 on padding.
    vals: (N, P) float32, 0.0 on padding.
    nnz:  (N,) int32, number of live tuples per row.
    dim:  vocabulary size D (static).
    _df:  optional (D,) int32 document frequencies — an explicit pytree leaf
          (None when unknown), so a df seeded by :func:`with_df` survives
          every jit boundary / donation round-trip.  Read through the ``df``
          property, which falls back to counting.
    """

    ids: jax.Array
    vals: jax.Array
    nnz: jax.Array
    dim: int
    _df: jax.Array | None = None

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        # _df rides as a child: None flattens to the empty subtree, an array
        # to one leaf — either way tree_unflatten hands it straight back, so
        # (unlike the old cached_property, whose instance-__dict__ cache was
        # silently dropped by every unflatten) the seeded df is carried
        # through jit, scan, and donation.
        return (self.ids, self.vals, self.nnz, self._df), self.dim

    @classmethod
    def tree_unflatten(cls, dim, leaves):
        ids, vals, nnz, df = leaves
        return cls(ids=ids, vals=vals, nnz=nnz, dim=dim, _df=df)

    # -- conveniences ------------------------------------------------------
    @property
    def n_docs(self) -> int:
        return self.ids.shape[0]

    @property
    def pad_width(self) -> int:
        return self.ids.shape[1]

    def row_mask(self) -> jax.Array:
        """(N, P) bool — True on live tuples."""
        return jnp.arange(self.pad_width)[None, :] < self.nnz[:, None]

    @property
    def df(self) -> jax.Array:
        """(D,) document frequency of each term.

        Returns the explicit ``_df`` leaf when one was carried in (corpus
        builders seed it via :func:`with_df`; it survives jit round-trips as
        a pytree child).  Otherwise counts once and memoises the result in
        the instance ``__dict__`` — a host-side convenience cache only, never
        relied on across pytree boundaries.
        """
        if self._df is not None:
            return self._df
        cached = self.__dict__.get("_df_cache")
        if cached is None:
            cached = df_counts(self)
            self.__dict__["_df_cache"] = cached
        return cached

    def slice_rows(self, start: int, size: int) -> "SparseDocs":
        # _df deliberately NOT carried: a row subset has its own document
        # frequencies, and consumers that want the corpus-level counts pass
        # them explicitly (df=...).
        return SparseDocs(
            ids=jax.lax.dynamic_slice_in_dim(self.ids, start, size, 0),
            vals=jax.lax.dynamic_slice_in_dim(self.vals, start, size, 0),
            nnz=jax.lax.dynamic_slice_in_dim(self.nnz, start, size, 0),
            dim=self.dim,
        )


def from_dense(x: np.ndarray | jax.Array, pad_to: int | None = None) -> SparseDocs:
    """Host-side: dense (N, D) -> SparseDocs (deterministic, ascending ids)."""
    x = np.asarray(x)
    n, d = x.shape
    nnz = (x != 0).sum(axis=1).astype(np.int32)
    p = int(pad_to if pad_to is not None else max(int(nnz.max(initial=1)), 1))
    ids = np.zeros((n, p), dtype=np.int32)
    vals = np.zeros((n, p), dtype=np.float32)
    for i in range(n):
        (cols,) = np.nonzero(x[i])
        cols = cols[:p]
        ids[i, : len(cols)] = cols
        vals[i, : len(cols)] = x[i, cols]
    nnz = np.minimum(nnz, p)
    return SparseDocs(ids=jnp.asarray(ids), vals=jnp.asarray(vals), nnz=jnp.asarray(nnz), dim=d)


def to_dense(docs: SparseDocs) -> jax.Array:
    """(N, D) dense reconstruction (jnp; scatter-add per row)."""
    n, p = docs.ids.shape
    out = jnp.zeros((n, docs.dim), dtype=docs.vals.dtype)
    rows = jnp.repeat(jnp.arange(n), p)
    return out.at[rows, docs.ids.reshape(-1)].add(
        jnp.where(docs.row_mask(), docs.vals, 0.0).reshape(-1)
    )


def with_df(docs: SparseDocs, df: jax.Array) -> SparseDocs:
    """Attach counts the caller already holds as the explicit ``_df`` leaf
    (corpus builders compute df before the df-rank remap; the permuted
    counts are exactly the remapped corpus's df).  Returns a new SparseDocs
    whose df survives jit/donation round-trips (regression-tested)."""
    return dataclasses.replace(docs, _df=jnp.asarray(df))


def df_counts(docs: SparseDocs) -> jax.Array:
    """(D,) document frequency of each term."""
    live = docs.row_mask()
    flat_ids = jnp.where(live, docs.ids, docs.dim)  # park padding out of range
    counts = jnp.zeros((docs.dim + 1,), jnp.int32).at[flat_ids.reshape(-1)].add(1)
    return counts[: docs.dim]


def tf_idf(docs: SparseDocs, df: jax.Array | None = None, n_total: int | None = None) -> SparseDocs:
    """Classic tf-idf re-weighting (paper Eq. 15): tf * log(N / df_s)."""
    if df is None:
        df = docs.df
    n = float(n_total if n_total is not None else docs.n_docs)
    idf = jnp.log(n / jnp.maximum(df.astype(jnp.float32), 1.0))
    vals = docs.vals * idf[docs.ids]
    vals = jnp.where(docs.row_mask(), vals, 0.0)
    return dataclasses.replace(docs, vals=vals)


def l2_normalize_rows(docs: SparseDocs, eps: float = 1e-12) -> SparseDocs:
    """Project each document onto the unit hypersphere (paper setting)."""
    norm = jnp.sqrt(jnp.sum(docs.vals**2, axis=1) + eps)
    return dataclasses.replace(docs, vals=docs.vals / norm[:, None])


def remap_terms_by_df(docs: SparseDocs, df: jax.Array | None = None):
    """Permute term IDs into ascending-df rank order (paper Table I).

    Returns (docs', perm) where ``perm[new_id] = old_id`` and term ``D-1`` is
    the highest-df term.  Object tuples are re-sorted ascending by new id so
    a contiguous suffix of each row is exactly the ``s >= t_th`` tail the ES
    filter needs.
    """
    if df is None:
        df = docs.df
    perm = jnp.argsort(df, stable=True)          # perm[new] = old
    inv = jnp.argsort(perm, stable=True)         # inv[old] = new
    new_ids = inv[docs.ids]
    # keep padding sorted to the end: give dead slots id = dim
    live = docs.row_mask()
    sort_key = jnp.where(live, new_ids, docs.dim)
    order = jnp.argsort(sort_key, axis=1, stable=True)
    new_ids = jnp.take_along_axis(jnp.where(live, new_ids, 0), order, axis=1)
    new_vals = jnp.take_along_axis(jnp.where(live, docs.vals, 0.0), order, axis=1)
    # The permuted counts ARE the remapped corpus's df — carry them as the
    # explicit leaf so downstream consumers never recount.
    docs2 = dataclasses.replace(docs, ids=new_ids, vals=new_vals,
                                _df=jnp.asarray(df)[perm])
    return docs2, perm


def pad_rows(docs: SparseDocs, multiple: int) -> SparseDocs:
    """Pad N up to a multiple with dead rows (nnz = 0, vals = 0).

    Dead rows accumulate nothing anywhere downstream: no live tuples, so
    they contribute 0 to similarities, cluster sums, and diagnostics.
    Callers that batch over rows (the fused Lloyd epoch, the serving
    engine) mask them out of per-row outputs.
    """
    n = docs.n_docs
    pad = (-n) % multiple
    if pad == 0:
        return docs
    zpad = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    # Dead rows hold no live tuples, so the corpus df is unchanged — carry
    # the explicit leaf through the padding.
    return SparseDocs(ids=zpad(docs.ids), vals=zpad(docs.vals),
                      nnz=zpad(docs.nnz), dim=docs.dim, _df=docs._df)


@partial(jax.jit, static_argnames=())
def l1_tail(docs: SparseDocs, t_th: jax.Array) -> jax.Array:
    """(N,) partial L1 norm over tuples with term id >= t_th (paper y init)."""
    tail = (docs.ids >= t_th) & docs.row_mask()
    return jnp.sum(jnp.where(tail, docs.vals, 0.0), axis=1)
