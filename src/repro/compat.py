"""Version-compat bridges over moving jax APIs.

The repo targets the newest TPU toolchain but must degrade gracefully on the
older CPU-only jax found in CI images.  Everything here is a thin signature
adapter — no behavioural changes.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5.x
    _shard_map_new = jax.shard_map
except AttributeError:
    _shard_map_new = None
    from jax.experimental.shard_map import shard_map as _shard_map_old


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checks off, on any jax version."""
    if _shard_map_new is not None:
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
    return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
