"""Pallas kernel: flash attention (online-softmax, banded-causal).

The LM-side hot spot: the q-chunked jnp path (models/layers.py) still
materialises (q_chunk × S) score rows in HBM; this kernel keeps the running
max/denominator and the output tile in VMEM and streams K/V blocks, so HBM
traffic is O(S·d) instead of O(S²) per head.

    grid = (B·H, Sq tiles, Sk tiles)             # Sk sequential → online
    m_i, l_i, acc carried in VMEM scratch across the Sk dimension
    banded-causal mask: k <= q and q - k < window (window < 0 → full)

Sliding-window layers get tile-level work skipping for free: fully-masked
K/V tiles still stream (uniform grid — the AFM no-branch rule) but
contribute zeros; a production grid would prune them via index remapping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
                  sk_blk: int, sq_blk: int, window: int, scale: float,
                  sk_real: int):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (Sq_blk, hd)
    k = k_ref[0]                                   # (Sk_blk, hd)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = q_idx * sq_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kv_idx * sk_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    w = jnp.iinfo(jnp.int32).max if window < 0 else window
    mask = (k_pos <= q_pos) & ((q_pos - k_pos) < w) & (k_pos < sk_real)
    s = jnp.where(mask, s, -1e30)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # masked lanes contribute exactly zero (fully-masked rows output 0)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)   # (Sq_blk, Sk_blk)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finish():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def flash_attention_pallas(q, k, v, *, window: int = -1,
                           sq_blk: int = 128, sk_blk: int = 128,
                           interpret: bool = False, sk_real: int | None = None):
    """q: (BH, Sq, hd); k/v: (BH, Sk, hd) — heads pre-folded into batch.
    Returns (BH, Sq, hd) float32. Causal with optional sliding window.
    sk_real: logical key length (padded key positions are masked out)."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    sk_real = sk if sk_real is None else sk_real
    assert sq % sq_blk == 0 and sk % sk_blk == 0, (sq, sk, sq_blk, sk_blk)
    grid = (bh, sq // sq_blk, sk // sk_blk)
    scale = 1.0 / (hd ** 0.5)
    return pl.pallas_call(
        functools.partial(_flash_kernel, sk_blk=sk_blk, sq_blk=sq_blk,
                          window=window, scale=scale, sk_real=sk_real),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, sq_blk, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, sk_blk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, sk_blk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq_blk, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((sq_blk, 1), jnp.float32),   # running max m_i
            pltpu.VMEM((sq_blk, 1), jnp.float32),   # running denom l_i
            pltpu.VMEM((sq_blk, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
