"""Pallas kernel: fused ES upper bound + survivor mask + |Z_i| count.

Implements Eq. (4) + the filter comparison (Alg. 3 lines 7–10) in one VPU
pass — the bound, the compare, and the per-object candidate count never
round-trip to HBM.  The moving-centroid (ICP) lane mask is an operand, so
G_0 vs G_1 is the same kernel with a different mask (no code divergence,
exactly the paper's shared-structure trick).

    ub[b,k]    = rho12 + y · v_th
    mask[b,k]  = (ub > rho_max[b]) & col_ok[b,k]
    count[b]   = Σ_k mask
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _filter_kernel(vth_ref, rho_ref, y_ref, rhomax_ref, colok_ref,
                   mask_ref, count_ref):
    k_idx = pl.program_id(1)
    v_th = vth_ref[0]
    ub = rho_ref[...] + y_ref[...] * v_th
    ok = (ub > rhomax_ref[...]) & (colok_ref[...] != 0)
    mask_ref[...] = ok.astype(jnp.int8)
    partial = jnp.sum(ok.astype(jnp.int32), axis=1, keepdims=True)

    @pl.when(k_idx == 0)
    def _init():
        count_ref[...] = partial

    @pl.when(k_idx > 0)
    def _acc():
        count_ref[...] += partial


def esicp_filter_pallas(rho12, y, rho_max, col_ok, v_th, *,
                        b_blk: int = 128, k_blk: int = 256,
                        interpret: bool = False):
    """rho12/y: (B, K); rho_max: (B,); col_ok: (B, K) int8/bool.
    Returns (mask (B, K) int8, count (B,) int32)."""
    b, k = rho12.shape
    assert b % b_blk == 0 and k % k_blk == 0
    grid = (b // b_blk, k // k_blk)
    vth = jnp.reshape(jnp.asarray(v_th, jnp.float32), (1,))
    rho_max2 = rho_max[:, None]                       # (B, 1) for broadcasting
    mask, count = pl.pallas_call(
        _filter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((b_blk, k_blk), lambda i, j: (i, j)),
            pl.BlockSpec((b_blk, k_blk), lambda i, j: (i, j)),
            pl.BlockSpec((b_blk, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((b_blk, k_blk), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((b_blk, k_blk), lambda i, j: (i, j)),
            pl.BlockSpec((b_blk, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int8),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(vth, rho12, y, rho_max2, col_ok.astype(jnp.int8))
    return mask, count[:, 0]
