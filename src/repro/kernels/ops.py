"""jit'd public wrappers over the Pallas kernels.

Handles block-alignment padding (to MXU-friendly multiples), dispatches to
interpret mode off-TPU, and slices results back to logical shapes.  Callers
see plain jnp-like functions; the kernels see only aligned shapes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import sparse_sim as _ss
from repro.kernels import esicp_gather as _eg
from repro.kernels import esicp_filter as _ef
from repro.kernels import segment_update as _su
from repro.kernels import rho_gather as _rg
from repro.kernels import flash_attention as _fa


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult, axis, value=0):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def _align(ids, vals, means_t, b_blk, k_blk, d_blk):
    ids = _pad_to(_pad_to(ids, 8, 1), b_blk, 0)
    vals = _pad_to(_pad_to(vals, 8, 1), b_blk, 0)
    means_t = _pad_to(_pad_to(means_t, d_blk, 0), k_blk, 1)
    return ids, vals, means_t


@partial(jax.jit, static_argnames=("b_blk", "k_blk", "d_blk", "interpret"))
def sparse_sim(ids, vals, means_t, *, b_blk=128, k_blk=128, d_blk=256,
               interpret: bool | None = None):
    """(B, K) exact similarities of padded sparse objects vs dense means."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, k = ids.shape[0], means_t.shape[1]
    pi, pv, pm = _align(ids, vals, means_t, b_blk, k_blk, d_blk)
    out = _ss.sparse_sim_pallas(pi, pv, pm, b_blk=b_blk, k_blk=k_blk,
                                d_blk=d_blk, interpret=interpret)
    return out[:b, :k]


@partial(jax.jit, static_argnames=("b_blk", "k_blk", "d_blk", "interpret"))
def esicp_gather(ids, vals, means_t, t_th, v_th, *, b_blk=128, k_blk=128,
                 d_blk=256, interpret: bool | None = None):
    """(rho12, y): fused Region-1/2 exact similarity + Region-3 L1 mass."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, k = ids.shape[0], means_t.shape[1]
    pi, pv, pm = _align(ids, vals, means_t, b_blk, k_blk, d_blk)
    rho12, y = _eg.esicp_gather_pallas(pi, pv, pm, t_th, v_th, b_blk=b_blk,
                                       k_blk=k_blk, d_blk=d_blk,
                                       interpret=interpret)
    return rho12[:b, :k], y[:b, :k]


@partial(jax.jit, static_argnames=("b_blk", "k_blk", "interpret"))
def esicp_filter(rho12, y, rho_max, col_ok, v_th, *, b_blk=128, k_blk=256,
                 interpret: bool | None = None):
    """(survivor mask int8 (B,K), |Z_i| counts (B,))."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, k = rho12.shape
    pr = _pad_to(_pad_to(rho12, k_blk, 1), b_blk, 0)
    py = _pad_to(_pad_to(y, k_blk, 1), b_blk, 0)
    pm = _pad_to(rho_max, b_blk, 0, value=jnp.inf)  # padding rows prune all
    pc = _pad_to(_pad_to(col_ok.astype(jnp.int8), k_blk, 1), b_blk, 0)
    mask, count = _ef.esicp_filter_pallas(pr, py, pm, pc, v_th, b_blk=b_blk,
                                          k_blk=k_blk, interpret=interpret)
    return mask[:b, :k], count[:b]


@partial(jax.jit, static_argnames=("k", "d", "b_blk", "k_blk", "d_blk", "interpret"))
def segment_update(assign, ids, vals, *, k: int, d: int, b_blk=128, k_blk=128,
                   d_blk=256, interpret: bool | None = None):
    """(K, D) cluster sums λ. Padding objects get assign = k (out of range)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    # Padded rows get assign = k: when k is block-aligned that index falls
    # past the last tile's iota range, otherwise into a padding column —
    # either way it contributes nothing to the sliced result.
    pa = _pad_to(assign, b_blk, 0, value=k)
    pi = _pad_to(_pad_to(ids, 8, 1), b_blk, 0)
    pv = _pad_to(_pad_to(vals, 8, 1), b_blk, 0)
    kp = k + ((-k) % k_blk)
    dp = d + ((-d) % d_blk)
    out = _su.segment_update_pallas(pa, pi, pv, kp, dp, b_blk=b_blk,
                                    k_blk=k_blk, d_blk=d_blk,
                                    interpret=interpret)
    return out[:k, :d]


@partial(jax.jit, static_argnames=("b_blk", "k_blk", "d_blk", "interpret"))
def rho_gather(assign, ids, vals, means_t, *, b_blk=128, k_blk=128, d_blk=256,
               interpret: bool | None = None):
    """(B,) ρ_self refresh: each object's similarity vs its own centroid.

    Padding objects get assign = k (out of range) and read back ρ = 0.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    b = ids.shape[0]
    k = means_t.shape[1]
    pa = _pad_to(assign, b_blk, 0, value=k)
    pi, pv, pm = _align(ids, vals, means_t, b_blk, k_blk, d_blk)
    out = _rg.rho_gather_pallas(pa, pi, pv, pm, b_blk=b_blk, k_blk=k_blk,
                                d_blk=d_blk, interpret=interpret)
    return out[:b]


@partial(jax.jit, static_argnames=("window", "sq_blk", "sk_blk", "interpret"))
def flash_attention(q, k, v, *, window: int = -1, sq_blk=128, sk_blk=128,
                    interpret: bool | None = None):
    """Banded-causal flash attention; heads folded into the batch dim."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    bh, sq, hd = q.shape
    sk = k.shape[1]
    pq = _pad_to(q, sq_blk, 1)
    pk = _pad_to(k, sk_blk, 1)
    pv = _pad_to(v, sk_blk, 1)
    out = _fa.flash_attention_pallas(pq, pk, pv, window=window,
                                     sq_blk=sq_blk, sk_blk=sk_blk,
                                     interpret=interpret, sk_real=sk)
    return out[:, :sq]
