"""jit'd public wrappers over the Pallas kernels.

Handles block-alignment padding (to MXU-friendly multiples), dispatches to
interpret mode off-TPU, and slices results back to logical shapes.  Callers
see plain jnp-like functions; the kernels see only aligned shapes.

Kernel engine v2 plumbing (ISSUE 5):

* **K superblocks** — K is padded to ``k_blk`` and then covered by
  ``k_sup``-wide grid blocks (the whole padded K when it fits the VMEM
  budget), so the (B_blk, D_blk) densified slab is built once per (B, D)
  block instead of once per (B, K, D) step.
* **Occupancy** — every clustering-kernel call carries a
  (B-tile, D-block) live-cell map; a prepared :class:`~repro.kernels.plan.
  KernelPlan` supplies it precomputed, otherwise it is computed inline here
  (one cheap scatter-max, amortised by the densify work it prunes).
* **Prepared plans** — ``plan=`` threads the epoch-invariant cache
  (occupancy + densified high-df head slabs) from ``Backend.prepare``
  down to the kernels.  A plan whose block geometry or row layout does not
  match the call is ignored for the mismatching part: plans are an
  optimisation, never a correctness input.
* **Fused diagnostics** — ``diag=True`` on ``sparse_sim`` /
  ``esicp_gather`` returns the visited-pair counts as an extra accumulator
  of the same launch; ``with_sims=True`` on ``esicp_gather`` adds the full
  exact similarity, so one launch serves the whole ES assignment gather.
* **Tuned configs** — every structural knob (block geometry, the
  K-superblock cap) resolves through a :class:`repro.tune.config.
  TunedConfig`: explicit kwargs win, then ``tuned=``, then the config the
  prepared plan was built for (``plan.tuned``), then the hard-coded
  defaults.  The autotuner (repro/tune/search.py) searches this knob space
  per corpus regime; untouched callers get exactly the pre-tuner behaviour.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import sparse_sim as _ss
from repro.kernels import esicp_gather as _eg
from repro.kernels import esicp_filter as _ef
from repro.kernels import segment_update as _su
from repro.kernels import rho_gather as _rg
from repro.kernels import sketch_sim as _sk
from repro.kernels import flash_attention as _fa

# Widest K superblock the default auto policy will pick: bounds the
# (d_blk, k_sup) means block and the (b_blk, k_sup) accumulator blocks in
# VMEM.  TunedConfig.k_sup_cap overrides it per call.
K_SUP_CAP = 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Resolve the interpret-mode default for a kernel launch.

    ``REPRO_KERNEL_MODE`` overrides the platform policy (DESIGN.md §7):
      auto (or unset) — compiled on TPU, interpret elsewhere;
      interpret       — force interpret mode (semantics debugging on TPU);
      compiled        — force compiled Pallas (off-TPU this fails at lower
                        time unless the platform grew a Pallas lowering —
                        the honest way to *probe* for one).

    Caveat: the env var is read when a wrapper TRACES, and the jit cache
    keys on the resolved static value — flipping the env mid-process only
    affects shapes that have not been traced yet.  Set it before the first
    kernel call (the bench suite reads it at startup for this reason).
    """
    mode = __import__("os").environ.get("REPRO_KERNEL_MODE", "auto")
    if mode == "interpret":
        return True
    if mode == "compiled":
        return False
    if mode not in ("", "auto"):
        raise ValueError(
            f"REPRO_KERNEL_MODE must be auto|interpret|compiled, got {mode!r}")
    return not _on_tpu()


def _resolve_cfg(tuned, plan, b_blk, k_blk, d_blk):
    """(TunedConfig, b_blk, k_blk, d_blk) for a call — explicit kwargs win,
    then ``tuned``, then the plan's embedded config, then defaults."""
    # Lazy: tune.config imports kernels/plan.py geometry constants, so the
    # dependency must point tune -> kernels at module-import time.
    from repro.tune.config import DEFAULT_TUNED

    cfg = tuned
    if cfg is None and plan is not None and plan.tuned is not None:
        cfg = plan.tuned
    if cfg is None:
        cfg = DEFAULT_TUNED
    return (cfg,
            cfg.b_blk if b_blk is None else b_blk,
            cfg.k_blk if k_blk is None else k_blk,
            cfg.d_blk if d_blk is None else d_blk)


def _pad_to(x, mult, axis, value=0):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def _align(ids, vals, means_t, b_blk, k_blk, d_blk):
    ids = _pad_to(_pad_to(ids, 8, 1), b_blk, 0)
    vals = _pad_to(_pad_to(vals, 8, 1), b_blk, 0)
    means_t = _pad_to(_pad_to(means_t, d_blk, 0), k_blk, 1)
    return ids, vals, means_t


def _pick_k_sup(kp: int, k_blk: int, k_sup: int | None,
                cap: int | None = None) -> int:
    """Exact auto K-superblock width: the *largest* multiple of ``k_blk``
    that is ≤ ``cap`` and divides padded K (the whole padded K when it fits
    the cap).

    The scan starts from ``(cap // k_blk) * k_blk`` — the true largest
    multiple — so an awkward ``cap % k_blk`` residue can never shift the
    candidate ladder off the valid widths and silently degrade the pick.
    When no multiple of ``k_blk`` in (0, cap] divides ``kp`` (``k_blk``
    wider than the cap, or a caller-supplied ``kp`` that is not ``k_blk``-
    aligned) the fallback is ``gcd(kp, k_blk)``: the widest width that is
    still guaranteed to divide ``kp``, i.e. never an invalid grid.
    """
    import math

    cap = K_SUP_CAP if cap is None else cap
    if k_sup is not None:
        assert kp % k_sup == 0, f"k_sup={k_sup} must divide padded K={kp}"
        return k_sup
    if kp <= cap:
        return kp
    for ks in range((cap // k_blk) * k_blk, 0, -k_blk):
        if kp % ks == 0:
            return ks
    return math.gcd(kp, k_blk) or k_blk


def _inline_occ(ids, vals, dp: int, d_blk: int, b_blk: int):
    """Flat-layout occupancy from the padded call operands themselves —
    the fallback when no prepared plan (or a mismatching one) is passed.
    Computed by the ONE occupancy definition (kernels/plan.py) from the
    *actual* vals operand, so callers that substitute synthetic weights
    (binarised / region-masked values) stay exact."""
    from repro.kernels.plan import occupancy_map

    return occupancy_map(ids, vals, dim=dp, b_blk=b_blk, d_blk=d_blk)


def _plan_operands(plan, pi, pv, b: int, d: int, dp: int, b_blk: int,
                   d_blk: int, *, need_counts: bool):
    """Resolve (occ, head, headc, n_head) for a padded call.

    ``b``/``d`` are the call's *logical* row count and dim; ``pi``/``pv``
    the padded operands.  Layout mismatches degrade gracefully: a stale occ
    is replaced by the inline one, an unusable head cache by densification.
    """
    nd = dp // d_blk
    nbb = pi.shape[0] // b_blk
    occ = head = headc = None
    n_head = 0
    if plan is not None and plan.b_blk == b_blk and plan.d_blk == d_blk:
        if plan.occ is not None and plan.occ.shape == (nbb, nd):
            occ = plan.occ
        usable_head = (plan.head is not None and plan.n_head > 0
                       and plan.dim == d and plan.head.shape[0] == b
                       and plan.head.shape[1] == plan.n_head * d_blk)
        if usable_head and need_counts and plan.headc is None:
            usable_head = False          # diag needs the count twin too
        if usable_head:
            n_head = plan.n_head
            head = _pad_to(plan.head, b_blk, 0)
            headc = _pad_to(plan.headc, b_blk, 0) if need_counts else None
    if occ is None:
        occ = _inline_occ(pi, pv, dp, d_blk, b_blk)
    return occ, head, headc, n_head


@partial(jax.jit, static_argnames=("b_blk", "k_blk", "d_blk", "k_sup",
                                   "tuned", "diag", "interpret"))
def sparse_sim(ids, vals, means_t, *, plan=None, tuned=None,
               diag: bool = False, b_blk=None, k_blk=None, d_blk=None,
               k_sup: int | None = None, interpret: bool | None = None):
    """(B, K) exact similarities of padded sparse objects vs dense means.

    ``diag=True`` additionally returns the (B, K) visited-pair counts
    (live slots × nonzero mean entries) from the same launch.
    """
    interpret = default_interpret() if interpret is None else interpret
    cfg, b_blk, k_blk, d_blk = _resolve_cfg(tuned, plan, b_blk, k_blk, d_blk)
    b, k = ids.shape[0], means_t.shape[1]
    d = means_t.shape[0]
    pi, pv, pm = _align(ids, vals, means_t, b_blk, k_blk, d_blk)
    ks = _pick_k_sup(pm.shape[1], k_blk, k_sup, cap=cfg.k_sup_cap)
    occ, head, headc, n_head = _plan_operands(
        plan, pi, pv, b, d, pm.shape[0], b_blk, d_blk, need_counts=diag)
    out = _ss.sparse_sim_pallas(pi, pv, pm, occ, head, headc, b_blk=b_blk,
                                k_sup=ks, d_blk=d_blk, n_head=n_head,
                                diag=diag, interpret=interpret)
    if diag:
        sims, counts = out
        return sims[:b, :k], counts[:b, :k]
    return out[:b, :k]


@partial(jax.jit, static_argnames=("b_blk", "k_blk", "d_blk", "k_sup",
                                   "tuned", "with_sims", "diag",
                                   "interpret"))
def esicp_gather(ids, vals, means_t, t_th, v_th, *, plan=None, tuned=None,
                 with_sims: bool = False, diag: bool = False, b_blk=None,
                 k_blk=None, d_blk=None, k_sup: int | None = None,
                 interpret: bool | None = None):
    """Fused Region-1/2 exact similarity + Region-3 L1 mass.

    Returns ``(rho12, y)``, extended by the full exact similarity when
    ``with_sims`` and by the exact-region visited-pair counts when ``diag``
    — all accumulated off one densified slab per (B, D) block.
    """
    interpret = default_interpret() if interpret is None else interpret
    cfg, b_blk, k_blk, d_blk = _resolve_cfg(tuned, plan, b_blk, k_blk, d_blk)
    b, k = ids.shape[0], means_t.shape[1]
    d = means_t.shape[0]
    pi, pv, pm = _align(ids, vals, means_t, b_blk, k_blk, d_blk)
    ks = _pick_k_sup(pm.shape[1], k_blk, k_sup, cap=cfg.k_sup_cap)
    occ, head, headc, n_head = _plan_operands(
        plan, pi, pv, b, d, pm.shape[0], b_blk, d_blk, need_counts=diag)
    out = _eg.esicp_gather_pallas(pi, pv, pm, t_th, v_th, occ, head, headc,
                                  b_blk=b_blk, k_sup=ks, d_blk=d_blk,
                                  n_head=n_head, with_sims=with_sims,
                                  diag=diag, interpret=interpret)
    return tuple(o[:b, :k] for o in out)


@partial(jax.jit, static_argnames=("b_blk", "k_blk", "tuned", "interpret"))
def sketch_sim(sk_docs, sketch_t, *, plan=None, tuned=None, b_blk=None,
               k_blk=None, interpret: bool | None = None):
    """(B, K) block-vector sketch similarity — the sketch gate's dense pass.

    Zero-padding S to the 128-lane tile and K to ``k_blk`` leaves every
    retained dot product bitwise equal to the unpadded reference matmul
    (kernels/ref.py sketch_sim), which the backend parity matrix relies on.
    """
    interpret = default_interpret() if interpret is None else interpret
    cfg, b_blk, k_blk, _ = _resolve_cfg(tuned, plan, b_blk, k_blk, None)
    b, s = sk_docs.shape
    k = sketch_t.shape[1]
    px = _pad_to(_pad_to(sk_docs, 128, 1), b_blk, 0)
    pm = _pad_to(_pad_to(sketch_t, 128, 0), k_blk, 1)
    out = _sk.sketch_sim_pallas(px, pm, b_blk=b_blk, interpret=interpret)
    return out[:b, :k]


@partial(jax.jit, static_argnames=("b_blk", "k_blk", "interpret"))
def esicp_filter(rho12, y, rho_max, col_ok, v_th, *, b_blk=128, k_blk=256,
                 interpret: bool | None = None):
    """(survivor mask int8 (B,K), |Z_i| counts (B,))."""
    interpret = default_interpret() if interpret is None else interpret
    b, k = rho12.shape
    pr = _pad_to(_pad_to(rho12, k_blk, 1), b_blk, 0)
    py = _pad_to(_pad_to(y, k_blk, 1), b_blk, 0)
    pm = _pad_to(rho_max, b_blk, 0, value=jnp.inf)  # padding rows prune all
    pc = _pad_to(_pad_to(col_ok.astype(jnp.int8), k_blk, 1), b_blk, 0)
    mask, count = _ef.esicp_filter_pallas(pr, py, pm, pc, v_th, b_blk=b_blk,
                                          k_blk=k_blk, interpret=interpret)
    return mask[:b, :k], count[:b]


@partial(jax.jit, static_argnames=("k", "d", "b_blk", "k_blk", "d_blk",
                                   "k_sup", "tuned", "interpret"))
def segment_update(assign, ids, vals, *, k: int, d: int, plan=None,
                   tuned=None, b_blk=None, k_blk=None, d_blk=None,
                   k_sup: int | None = None,
                   interpret: bool | None = None):
    """(K, D) cluster sums λ. Padding objects get assign = k (out of range)."""
    interpret = default_interpret() if interpret is None else interpret
    cfg, b_blk, k_blk, d_blk = _resolve_cfg(tuned, plan, b_blk, k_blk, d_blk)
    # Padded rows get assign = k: when k is block-aligned that index falls
    # past the last superblock's iota range, otherwise into a padding
    # column — either way it contributes nothing to the sliced result.
    b = ids.shape[0]
    pa = _pad_to(assign, b_blk, 0, value=k)
    pi = _pad_to(_pad_to(ids, 8, 1), b_blk, 0)
    pv = _pad_to(_pad_to(vals, 8, 1), b_blk, 0)
    kp = k + ((-k) % k_blk)
    dp = d + ((-d) % d_blk)
    ks = _pick_k_sup(kp, k_blk, k_sup, cap=cfg.k_sup_cap)
    occ, head, _, n_head = _plan_operands(
        plan, pi, pv, b, d, dp, b_blk, d_blk, need_counts=False)
    out = _su.segment_update_pallas(pa, pi, pv, kp, dp, occ, head,
                                    b_blk=b_blk, k_sup=ks, d_blk=d_blk,
                                    n_head=n_head, interpret=interpret)
    return out[:k, :d]


@partial(jax.jit, static_argnames=("b_blk", "k_blk", "d_blk", "k_sup",
                                   "tuned", "interpret"))
def rho_gather(assign, ids, vals, means_t, *, plan=None, tuned=None,
               b_blk=None, k_blk=None, d_blk=None, k_sup: int | None = None,
               interpret: bool | None = None):
    """(B,) ρ_self refresh: each object's similarity vs its own centroid.

    Padding objects get assign = k (out of range) and read back ρ = 0.
    """
    interpret = default_interpret() if interpret is None else interpret
    cfg, b_blk, k_blk, d_blk = _resolve_cfg(tuned, plan, b_blk, k_blk, d_blk)
    b = ids.shape[0]
    k = means_t.shape[1]
    d = means_t.shape[0]
    pa = _pad_to(assign, b_blk, 0, value=k)
    pi, pv, pm = _align(ids, vals, means_t, b_blk, k_blk, d_blk)
    ks = _pick_k_sup(pm.shape[1], k_blk, k_sup, cap=cfg.k_sup_cap)
    occ, head, _, n_head = _plan_operands(
        plan, pi, pv, b, d, pm.shape[0], b_blk, d_blk, need_counts=False)
    out = _rg.rho_gather_pallas(pa, pi, pv, pm, occ, head, b_blk=b_blk,
                                k_sup=ks, d_blk=d_blk, n_head=n_head,
                                interpret=interpret)
    return out[:b]


@partial(jax.jit, static_argnames=("window", "sq_blk", "sk_blk", "interpret"))
def flash_attention(q, k, v, *, window: int = -1, sq_blk=128, sk_blk=128,
                    interpret: bool | None = None):
    """Banded-causal flash attention; heads folded into the batch dim."""
    interpret = default_interpret() if interpret is None else interpret
    bh, sq, hd = q.shape
    sk = k.shape[1]
    pq = _pad_to(q, sq_blk, 1)
    pk = _pad_to(k, sk_blk, 1)
    pv = _pad_to(v, sk_blk, 1)
    out = _fa.flash_attention_pallas(pq, pk, pv, window=window,
                                     sq_blk=sq_blk, sk_blk=sk_blk,
                                     interpret=interpret, sk_real=sk)
    return out[:, :sq]
