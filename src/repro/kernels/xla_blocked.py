"""Compiled skew-aware XLA twins of the clustering kernels (DESIGN.md §3/§5).

The Pallas kernels in this package only *compile* on TPU; everywhere else
they run in interpret mode, which validates semantics but loses every race
(BENCH_kernels.json showed 0.1–0.9× the reference scan on CPU).  This module
re-expresses the same skew-aware execution plan — high-df head slab reused
across the epoch, cheap Zipf tail, fused diagnostics — as pure jit-compiled
XLA programs, so the engine wins on the hardware CI actually has:

* **Zipf tail → gather + posting-sum.**  Each op gathers only the mean rows
  its live postings name (``means_t[ids]`` → (B, P-chunk, K)) and folds them
  with one einsum per chunk.  Work is proportional to *postings*, not to the
  dense (B, D) grid — this is the limiting case of the occupancy map: an
  empty (B-tile, D-block) cell is simply never touched, exactly, so the ops
  do not consume ``plan.occ`` at all (SIVF's skip list degenerates to "only
  walk the postings you have" once there is no dense grid to mask).

* **High-df head → one densified slab matmul.**  When a :class:`repro.
  kernels.plan.KernelPlan` carries cached head slabs, postings in the
  trailing (high-df) D-blocks leave the gather and ride a single dense
  ``head @ means_head`` GEMM per call — the dense-head/sparse-tail split of
  Knittel, Koch & Ertl (arxiv 2108.00895), amortised across the fused-epoch
  scan because the slab is densified once per chunk per fit.  The count twin
  ``headc`` feeds the fused Mult diagnostic the same way.  Note the engine
  *default* is head-less (``XLA_HEAD_BYTES = 0``): on CPU the slab GEMM
  costs ``B·H·K`` FLOPs against the gather's ``B·p_head·K``, so it only
  pays off when the autotuner's measured search says so.

* **Fused diagnostics.**  ``diag=True`` returns the raw visited-pair counts
  off the same gather/GEMM pass — identical semantics to the Pallas fused
  accumulator and the reference scan (live postings × nonzero mean entries,
  exact-region-masked for esicp/ta).

* **Update phase.**  ``segment_update`` is the native scatter-add
  (out-of-range assignments dropped), ``rho_gather`` the own-centroid
  gather — both already proportional to nnz, no plan needed.

Exactness contract: identical to the other backends — integer accumulators
(Mult, counts, y for unit vals) are bit-exact; float sums agree to
reduction-order tolerance; assignments are bit-identical in the parity
matrix.  The head split changes the *addition order* of the similarity sums
(slab GEMM + tail gather vs one posting walk), which is why the head is an
explicit opt-in rather than silently on.

Signature compatibility: the wrappers accept and ignore the Pallas launch
geometry kwargs (``b_blk`` / ``k_blk`` / ``d_blk`` / ``k_sup`` / ``tuned``
/ ``interpret``) so call sites, tests and the autotuner can drive either
engine with one argument vocabulary — XLA has no grid to shape; the only
plan-derived knob that matters here is the head split.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

f32 = jnp.float32

# Byte budget for the gathered (B, P-chunk, K) mean-row block per fold step.
# Shapes are static, so the chunk count is resolved at trace time; one chunk
# (a single einsum, no scan) is the common case at bench/fit shapes.
ROWS_BUDGET = 32 << 20


def _chunks(ids, vals, k: int):
    """Split the posting axis into gather-budget chunks.

    Returns ``(nc, xs)`` where each of ``xs = (ids, vals, real)`` is shaped
    (nc, B, pc).  ``real`` marks caller-provided slots: chunk padding must
    stay invisible even to accumulators with the reference scan's dead-slot
    semantics (CS counts ``id >= t_th`` slots whether live or not)."""
    b, p = ids.shape
    pc = int(max(1, min(p, ROWS_BUDGET // max(1, b * k * 4))))
    rem = (-p) % pc
    real = jnp.broadcast_to(jnp.arange(p + rem)[None, :] < p, (b, p + rem))
    if rem:
        ids = jnp.pad(ids, ((0, 0), (0, rem)))
        vals = jnp.pad(vals, ((0, 0), (0, rem)))
    nc = (p + rem) // pc
    resh = lambda a: a.reshape(b, nc, pc).transpose(1, 0, 2)
    return nc, (resh(ids), resh(vals), resh(real))


def _gather_fold(ids, vals, means_t, fold, init):
    """Fold ``fold(acc, idp, vp, real, rows)`` over P-chunks of the postings,
    gathering ``rows = means_t[idp]`` per chunk.  Single-chunk calls skip
    the scan entirely (one gather + one fold in straight-line HLO)."""
    nc, (cids, cvals, creal) = _chunks(ids, vals, means_t.shape[1])
    if nc == 1:
        return fold(init, cids[0], cvals[0], creal[0], means_t[cids[0]])

    def body(acc, xs):
        idp, vp, rl = xs
        return fold(acc, idp, vp, rl, means_t[idp]), None

    acc, _ = jax.lax.scan(body, init, (cids, cvals, creal))
    return acc


def _head_split(plan, b: int, d: int, means_t, *, need_counts: bool):
    """Resolve the plan's head cache against this call's geometry.

    Returns ``(d0, head, headc, means_h)`` — ``d0`` the first head term id
    in the plan's padded D space, ``means_h`` the zero-padded head rows of
    the mean matrix — or all-``None`` when the plan is absent or was built
    for a different layout (plans are an optimisation, never a correctness
    input: a mismatched plan is ignored, not an error)."""
    none = (None, None, None, None)
    if plan is None or plan.head is None or plan.n_head <= 0:
        return none
    if plan.dim != d or plan.head.shape[0] != b:
        return none
    if plan.head.shape[1] != plan.n_head * plan.d_blk:
        return none
    if need_counts and plan.headc is None:
        return none
    d_pad = (-(-d // plan.d_blk)) * plan.d_blk
    d0 = d_pad - plan.n_head * plan.d_blk
    means_h = jnp.pad(means_t, ((0, d_pad - d), (0, 0)))[d0:]
    return d0, plan.head, plan.headc if need_counts else None, means_h


def _mask_head(ids, vals, d0):
    """Zero out postings the head slab already covers (ids >= d0) so they
    leave the gather; liveness-derived counts vanish with the value."""
    return vals if d0 is None else jnp.where(ids < d0, vals, 0.0)


def _dot(a, b):
    return jnp.dot(a, b, preferred_element_type=f32)


@partial(jax.jit, static_argnames=("diag", "tuned", "b_blk", "k_blk",
                                  "d_blk", "k_sup", "interpret"))
def sparse_sim(ids, vals, means_t, *, plan=None, tuned=None, diag=False,
               b_blk=None, k_blk=None, d_blk=None, k_sup=None,
               interpret=None):
    """(B, K) exact similarities x·μ; ``diag=True`` adds the raw visited-pair
    counts (live postings × nonzero mean entries) off the same pass."""
    b = ids.shape[0]
    d, k = means_t.shape
    d0, head, headc, means_h = _head_split(plan, b, d, means_t,
                                           need_counts=diag)
    tvals = _mask_head(ids, vals, d0)

    def fold(acc, idp, vp, rl, rows):
        sims = acc[0] + jnp.einsum("bp,bpk->bk", vp, rows,
                                   preferred_element_type=f32)
        if not diag:
            return (sims,)
        live = (vp != 0.0).astype(f32)
        cnt = acc[1] + jnp.einsum("bp,bpk->bk", live,
                                  (rows > 0).astype(f32),
                                  preferred_element_type=f32)
        return (sims, cnt)

    init = (jnp.zeros((b, k), f32),) * (2 if diag else 1)
    out = _gather_fold(ids, tvals, means_t, fold, init)
    sims = out[0]
    if head is not None:
        sims = sims + _dot(head, means_h)
    if not diag:
        return sims
    counts = out[1]
    if head is not None:
        counts = counts + _dot(headc, (means_h > 0).astype(f32))
    return sims, counts


@partial(jax.jit, static_argnames=("with_sims", "diag", "tuned", "b_blk",
                                   "k_blk", "d_blk", "k_sup", "interpret"))
def esicp_gather(ids, vals, means_t, t_th, v_th, *, v_ta=None, plan=None,
                 tuned=None, with_sims=False, diag=False, b_blk=None,
                 k_blk=None, d_blk=None, k_sup=None, interpret=None):
    """ES/ICP gathering phase: (rho12, y[, sims][, counts]) in ONE pass.

    ``v_ta`` switches the exact-region test from the shared ``v_th`` to the
    per-object TA threshold (Eq. 16) — natively compiled here, where the
    Pallas backend must delegate TA to the reference scan (a per-object
    threshold cannot mask a shared (D_blk, K_sup) means block).  The head
    slab only applies to the shared-threshold form: its region masks depend
    on (term, mean) alone, so they commute with the per-term value sums the
    slab caches; a per-object threshold does not.
    """
    b = ids.shape[0]
    d, k = means_t.shape
    per_object = v_ta is not None
    if per_object:
        d0 = head = headc = means_h = None
    else:
        d0, head, headc, means_h = _head_split(plan, b, d, means_t,
                                               need_counts=diag)
    tvals = _mask_head(ids, vals, d0)
    thr = v_ta[:, None, None] if per_object else v_th

    def fold(acc, idp, vp, rl, rows):
        tail = (idp >= t_th)[..., None]
        hi = rows >= thr
        exact = jnp.where(tail, hi, True)
        contrib = vp[..., None] * rows
        out = {"rho12": acc["rho12"]
               + jnp.sum(jnp.where(exact, contrib, 0.0), 1),
               "y": acc["y"]
               + jnp.sum(jnp.where(tail & ~hi, vp[..., None], 0.0), 1)}
        if with_sims:
            out["sims"] = acc["sims"] + jnp.sum(contrib, 1)
        if diag:
            live = (vp != 0.0)[..., None]
            out["counts"] = acc["counts"] + jnp.sum(
                (rows > 0) & live & exact, 1, dtype=f32)
        return out

    init = {"rho12": jnp.zeros((b, k), f32), "y": jnp.zeros((b, k), f32)}
    if with_sims:
        init["sims"] = jnp.zeros((b, k), f32)
    if diag:
        init["counts"] = jnp.zeros((b, k), f32)
    out = _gather_fold(ids, tvals, means_t, fold, init)
    if head is not None:
        # Term-indexed region masks: every posting of head term t shares
        # tail/hi status, so the per-term value sums in ``head`` (and live
        # counts in ``headc``) distribute over them exactly.
        term = jnp.arange(d0, d0 + means_h.shape[0])[:, None]
        tail_h = term >= t_th
        hi_h = means_h >= v_th
        exact_h = jnp.where(tail_h, hi_h, True)
        out["rho12"] = out["rho12"] + _dot(head,
                                           jnp.where(exact_h, means_h, 0.0))
        out["y"] = out["y"] + _dot(head, (tail_h & ~hi_h).astype(f32))
        if with_sims:
            out["sims"] = out["sims"] + _dot(head, means_h)
        if diag:
            out["counts"] = out["counts"] + _dot(
                headc, ((means_h > 0) & exact_h).astype(f32))
    res = (out["rho12"], out["y"])
    if with_sims:
        res += (out["sims"],)
    if diag:
        res += (out["counts"],)
    return res


@partial(jax.jit, static_argnames=("diag", "tuned", "interpret"))
def cs_gather(ids, vals, means_t, t_th, *, plan=None, tuned=None, diag=False,
              interpret=None):
    """CS partials (sims, rho1, sq[, counts]) in ONE fused pass — the Pallas
    backend needs three ``sparse_sim`` launches for the same accumulators.

    No head split: ``sq`` follows the reference scan's per-*slot* semantics
    (every slot with ``id >= t_th`` contributes means², live or not — the
    dead-slot quirk), which the live-count slab cannot express; precedent is
    the Pallas backend bypassing its head cache for CS too."""
    b = ids.shape[0]
    k = means_t.shape[1]

    def fold(acc, idp, vp, rl, rows):
        tail = ((idp >= t_th) & rl)[..., None]   # rl: chunk padding is unreal
        contrib = vp[..., None] * rows
        out = {"sims": acc["sims"] + jnp.sum(contrib, 1),
               "rho1": acc["rho1"] + jnp.sum(jnp.where(tail, 0.0, contrib), 1),
               "sq": acc["sq"] + jnp.sum(jnp.where(tail, rows * rows, 0.0), 1)}
        if diag:
            live = (vp != 0.0)[..., None]
            out["counts"] = acc["counts"] + jnp.sum(
                (rows > 0) & live, 1, dtype=f32)
        return out

    init = {kk: jnp.zeros((b, k), f32) for kk in
            (("sims", "rho1", "sq", "counts") if diag
             else ("sims", "rho1", "sq"))}
    out = _gather_fold(ids, vals, means_t, fold, init)
    res = (out["sims"], out["rho1"], out["sq"])
    return res + (out["counts"],) if diag else res


@partial(jax.jit, static_argnames=("k", "d", "tuned", "b_blk", "k_blk",
                                   "d_blk", "k_sup", "interpret"))
def segment_update(assign, ids, vals, *, k: int, d: int, plan=None,
                   tuned=None, b_blk=None, k_blk=None, d_blk=None,
                   k_sup=None, interpret=None):
    """(K, D) cluster sums λ_j = Σ_{x∈C_j} x as a native scatter-add —
    already proportional to nnz, so there is nothing for a plan to cache.
    Out-of-range assignments are dropped (Alg. 6 lines 2–5)."""
    rows = jnp.broadcast_to(assign[:, None], ids.shape)
    return jnp.zeros((k, d), f32).at[rows, ids].add(vals, mode="drop")


@partial(jax.jit, static_argnames=("tuned", "b_blk", "k_blk", "d_blk",
                                   "k_sup", "interpret"))
def rho_gather(assign, ids, vals, means_t, *, plan=None, tuned=None,
               b_blk=None, k_blk=None, d_blk=None, k_sup=None,
               interpret=None):
    """(B,) ρ_self refresh: own-centroid gather over each row's postings;
    out-of-range assignments read ρ = 0 (Alg. 6 lines 6–7)."""
    k = means_t.shape[1]
    picked = means_t[ids, jnp.minimum(assign, k - 1)[:, None]]
    return jnp.sum(jnp.where((assign < k)[:, None], vals * picked, 0.0),
                   axis=1)
