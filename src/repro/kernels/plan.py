"""Prepared kernel plans: epoch-invariant densification cache + occupancy.

Documents are constant across Lloyd iterations, yet the Pallas kernels used
to rebuild every ``(B_blk, D_blk)`` one-hot slab from the raw tuples on
every call of every epoch.  A :class:`KernelPlan` captures the two facts
about a corpus (chunk) that cannot change during a fit:

* **occupancy** — which (B-tile, D-block) cells contain at least one live
  tuple.  Term ids are df-rank sorted (paper Table I), so Zipf skew
  concentrates the mass in the high-df trailing blocks and leaves most
  low-df cells empty; the kernels skip the densify + MXU work of an empty
  cell entirely.  The bookkeeping cost is one SMEM scalar read per grid
  step — far cheaper than the work it saves (the Schubert et al. bound
  discipline), and skipping is *exact*: an empty cell's slab is all zeros
  and contributes nothing to any accumulator, value or count.

* **head slabs** — the densified high-df head region.  Under ascending
  df-rank order the head of the Zipf distribution lives at the HIGHEST term
  ids, i.e. the trailing ``n_head`` D-blocks; nearly every tile visits them
  every epoch.  Caching their dense form once per chunk per fit is the TPU
  analogue of SIVF keeping the frequently-reused index region hot across
  iterations.  The cache holds the value slab *and* the live-count slab
  (both fall out of one one-hot walk, see ``_densify_pair``) so the fused
  Mult diagnostics reuse it too.

Layout contract: plans are built against the *padded* geometry the kernel
wrappers produce — D rounded up to a ``d_blk`` multiple, rows padded to a
``tile_rows`` multiple and, within each tile, grouped into ``b_blk`` rows.
``occ`` therefore has one row per ``b_blk`` group *in tile order*, which is
exactly how a tiled epoch (``core/lloyd._fused_epoch``, the distributed
``lax.map`` chunking) slices it.  A wrapper that receives a plan whose
layout does not match the call falls back to inline occupancy (cheap) and
raw densification — plans are an optimisation, never a correctness input.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

DEFAULT_HEAD_BYTES = 32 << 20   # per-chunk budget for the cached head slabs

# The one source of truth for the clustering kernels' block geometry: the
# ops.py wrappers, the plan builders, and the distributed PlanMeta all
# derive their defaults from here, so a plan built with defaults always
# matches a call made with defaults.
DEFAULT_B_BLK = 128
DEFAULT_D_BLK = 256


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Epoch-invariant operands for the clustering kernels.

    occ:    (T, ND) int32 — nonzero where the (b_blk-group, D-block) cell
            holds at least one live tuple; None → wrappers compute inline.
    head:   (B, n_head·d_blk) float32 — densified trailing (high-df) blocks;
            None → kernels densify every block.
    headc:  (B, n_head·d_blk) float32 — live-count twin of ``head`` for the
            fused Mult accumulator; None when diagnostics are off.
    tuned:  optional :class:`repro.tune.config.TunedConfig` the plan was
            built for — the autotuner's winning knob vector, serialized
            alongside the occupancy maps so ``Backend.prepare`` reuses it
            across fits.  Rides the static aux data (it is hashable and
            changes the launch geometry, i.e. the trace).
    """

    occ: jax.Array | None
    head: jax.Array | None
    headc: jax.Array | None
    b_blk: int = DEFAULT_B_BLK
    d_blk: int = DEFAULT_D_BLK
    n_head: int = 0
    dim: int = 0
    tuned: object | None = None

    def tree_flatten(self):
        return ((self.occ, self.head, self.headc),
                (self.b_blk, self.d_blk, self.n_head, self.dim, self.tuned))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        occ, head, headc = leaves
        b_blk, d_blk, n_head, dim, tuned = aux
        return cls(occ=occ, head=head, headc=headc, b_blk=b_blk,
                   d_blk=d_blk, n_head=n_head, dim=dim, tuned=tuned)

    def without_occ(self) -> "KernelPlan":
        """Drop the occupancy map (kept: head cache).  Used when the call's
        row grouping differs from the plan's tile layout — e.g. the resident
        update phase runs over the whole corpus while the plan's occ was
        grouped per epoch tile; inline occupancy is recomputed instead."""
        return dataclasses.replace(self, occ=None)

    def without_head(self) -> "KernelPlan":
        """Drop the cached slabs (kept: occupancy).  Used for calls whose
        value operands differ from the raw tuples the cache was built from
        (e.g. the CS head/tail partial passes)."""
        return dataclasses.replace(self, head=None, headc=None, n_head=0)

    def slice_rows(self, n: int) -> "KernelPlan":
        """First ``n`` rows of the cached slabs, occupancy dropped — for
        calls on a row prefix of the plan's corpus (ρ_self refresh over an
        unpadded chunk)."""
        return dataclasses.replace(
            self, occ=None,
            head=None if self.head is None else self.head[:n],
            headc=None if self.headc is None else self.headc[:n])


def _pad_rows(x, multiple: int):
    rem = (-x.shape[0]) % multiple
    if rem == 0:
        return x
    return jnp.pad(x, ((0, rem),) + ((0, 0),) * (x.ndim - 1))


def occupancy_map(ids, vals, *, dim: int, b_blk: int = DEFAULT_B_BLK,
                  d_blk: int = DEFAULT_D_BLK,
                  tile_rows: int | None = None):
    """(T, ND) int32 live-cell map over ``b_blk`` row groups × D-blocks.

    Rows are first padded to a ``tile_rows`` multiple (dead rows are never
    occupied), then each tile is independently grouped into ``b_blk`` rows —
    the grouping a tiled caller's per-tile kernel launches will use.  With
    ``tile_rows=None`` the whole array is one tile (flat layout).
    """
    n, p = ids.shape
    nd = -(-dim // d_blk)
    tile_rows = n if tile_rows is None else int(tile_rows)
    ids = _pad_rows(ids, tile_rows)
    vals = _pad_rows(vals, tile_rows)
    nt = ids.shape[0] // tile_rows
    gpt = -(-tile_rows // b_blk)
    ids_t = _pad_rows(ids.reshape(nt, tile_rows, p).swapaxes(0, 1),
                      gpt * b_blk).swapaxes(0, 1) \
        if tile_rows % b_blk else ids.reshape(nt, tile_rows, p)
    vals_t = _pad_rows(vals.reshape(nt, tile_rows, p).swapaxes(0, 1),
                       gpt * b_blk).swapaxes(0, 1) \
        if tile_rows % b_blk else vals.reshape(nt, tile_rows, p)
    t = nt * gpt
    blk = (ids_t // d_blk).reshape(t, b_blk * p).astype(jnp.int32)
    live = (vals_t != 0.0).reshape(t, b_blk * p).astype(jnp.int32)
    occ = jnp.zeros((t, nd), jnp.int32)
    return occ.at[jnp.arange(t)[:, None], blk].max(live)


def pick_n_head(n_rows: int, dim: int, *, d_blk: int = DEFAULT_D_BLK,
                head_bytes: int = DEFAULT_HEAD_BYTES,
                with_counts: bool = True) -> int:
    """How many trailing (high-df) D-blocks the byte budget can cache."""
    nd = -(-dim // d_blk)
    per_block = n_rows * d_blk * 4 * (2 if with_counts else 1)
    if per_block <= 0:
        return 0
    return max(0, min(nd, head_bytes // per_block))


def head_slabs(ids, vals, *, dim: int, d_blk: int = DEFAULT_D_BLK,
               n_head: int = 0,
               with_counts: bool = True):
    """Densify the trailing ``n_head`` D-blocks once: (head, headc).

    Built with the kernels' own ``_densify_pair`` walk so the cached slab is
    operation-for-operation what the kernel would have recomputed.
    """
    from repro.kernels.sparse_sim import _densify, _densify_pair

    if n_head <= 0:
        return None, None
    rem = (-ids.shape[1]) % 8            # the wrappers' P alignment
    if rem:
        ids = jnp.pad(ids, ((0, 0), (0, rem)))
        vals = jnp.pad(vals, ((0, 0), (0, rem)))
    d_pad = (-(-dim // d_blk)) * d_blk
    parts_v, parts_c = [], []
    for h in range(n_head):
        d0 = d_pad - (n_head - h) * d_blk
        if with_counts:
            slab, cslab = _densify_pair(ids, vals, d0, d_blk)
            parts_c.append(cslab)
        else:
            slab = _densify(ids, vals, d0, d_blk)
        parts_v.append(slab)
    head = jnp.concatenate(parts_v, axis=1)
    return head, (jnp.concatenate(parts_c, axis=1) if with_counts else None)


def prepare_plan(ids, vals, *, dim: int, b_blk: int | None = None,
                 d_blk: int | None = None,
                 tile_rows: int | None = None,
                 head_bytes: int | None = None,
                 with_counts: bool = True,
                 tuned=None) -> KernelPlan:
    """Build the full plan for a corpus (chunk): tiled occupancy + cached
    head slabs.  Rows are padded to the tile multiple so the plan arrays
    reshape per tile exactly like the data arrays they ride beside.

    ``tuned`` (a :class:`repro.tune.config.TunedConfig`) supplies the block
    geometry and head budget when the explicit kwargs are omitted, and is
    carried on the returned plan so every kernel consuming it launches with
    the same tuned parameters the plan was laid out for."""
    if b_blk is None:
        b_blk = tuned.b_blk if tuned is not None else DEFAULT_B_BLK
    if d_blk is None:
        d_blk = tuned.d_blk if tuned is not None else DEFAULT_D_BLK
    if head_bytes is None:
        head_bytes = (tuned.head_bytes if tuned is not None
                      else DEFAULT_HEAD_BYTES)
    ids = jnp.asarray(ids)
    vals = jnp.asarray(vals)
    if tile_rows:
        ids = _pad_rows(ids, tile_rows)
        vals = _pad_rows(vals, tile_rows)
    occ = occupancy_map(ids, vals, dim=dim, b_blk=b_blk, d_blk=d_blk,
                        tile_rows=tile_rows)
    n_head = pick_n_head(ids.shape[0], dim, d_blk=d_blk,
                         head_bytes=head_bytes, with_counts=with_counts)
    head, headc = head_slabs(ids, vals, dim=dim, d_blk=d_blk, n_head=n_head,
                             with_counts=with_counts)
    return KernelPlan(occ=occ, head=head, headc=headc, b_blk=b_blk,
                      d_blk=d_blk, n_head=0 if head is None else n_head,
                      dim=dim, tuned=tuned)
