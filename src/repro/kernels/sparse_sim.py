"""Pallas kernel: sparse-object × dense-mean-block similarity.

TPU adaptation of the paper's TAAT inner loop (Alg. 1 lines 3–5).  The CPU
algorithm chases posting lists; on TPU we instead *densify* each object tile
into a (B_blk, D_blk) slab — one D-block at a time, exploiting the df-sorted
term layout — and feed the MXU:

    grid = (B tiles, K tiles, D tiles)           # D sequential → accumulate
    slab[b, d]  = Σ_p vals[b,p] · [ids[b,p] == d0+d]      (VPU one-hot build)
    out[b, k]  += slab @ means_blk                         (MXU matmul)

VMEM per step: ids/vals (B_blk·P), slab (B_blk·D_blk), means (D_blk·K_blk),
out (B_blk·K_blk) — all 128-aligned, chosen to stay well under ~16 MiB.

The one-hot densification is the paper's inverted-index walk with the
branch-misprediction hazard replaced by uniform lane masks — the AFM
translation from DESIGN.md §2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _densify(ids, vals, d0, d_blk: int, p_chunk: int = 8):
    """(B, P) sparse tuples -> (B, D_blk) dense slab for terms [d0, d0+d_blk)."""
    b, p = ids.shape
    local = ids - d0
    in_blk = (local >= 0) & (local < d_blk)
    w = jnp.where(in_blk, vals, 0.0)
    lid = jnp.where(in_blk, local, 0)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, p_chunk, d_blk), 2)

    def body(c, acc):
        sl_id = jax.lax.dynamic_slice_in_dim(lid, c * p_chunk, p_chunk, 1)
        sl_w = jax.lax.dynamic_slice_in_dim(w, c * p_chunk, p_chunk, 1)
        onehot = (sl_id[:, :, None] == iota).astype(vals.dtype)
        return acc + jnp.einsum("bp,bpd->bd", sl_w, onehot,
                                preferred_element_type=jnp.float32)

    acc0 = jnp.zeros((b, d_blk), jnp.float32)
    return jax.lax.fori_loop(0, p // p_chunk, body, acc0)


def _sim_kernel(ids_ref, vals_ref, means_ref, out_ref, *, d_blk: int):
    d_idx = pl.program_id(2)
    slab = _densify(ids_ref[...], vals_ref[...], d_idx * d_blk, d_blk)
    acc = jnp.dot(slab, means_ref[...], preferred_element_type=jnp.float32)

    @pl.when(d_idx == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(d_idx > 0)
    def _acc():
        out_ref[...] += acc


def sparse_sim_pallas(ids: jax.Array, vals: jax.Array, means_t: jax.Array, *,
                      b_blk: int = 128, k_blk: int = 128, d_blk: int = 256,
                      interpret: bool = False) -> jax.Array:
    """ids/vals: (B, P) padded sparse objects; means_t: (D, K). -> (B, K)."""
    b, p = ids.shape
    d, k = means_t.shape
    assert b % b_blk == 0 and k % k_blk == 0 and d % d_blk == 0 and p % 8 == 0, (
        f"shapes must be block-aligned: B={b}/{b_blk} K={k}/{k_blk} D={d}/{d_blk} P={p}/8")
    grid = (b // b_blk, k // k_blk, d // d_blk)
    return pl.pallas_call(
        functools.partial(_sim_kernel, d_blk=d_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_blk, p), lambda i, j, l: (i, 0)),
            pl.BlockSpec((b_blk, p), lambda i, j, l: (i, 0)),
            pl.BlockSpec((d_blk, k_blk), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((b_blk, k_blk), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(ids, vals, means_t)
