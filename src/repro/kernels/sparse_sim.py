"""Pallas kernel: sparse-object × dense-mean-block similarity.

TPU adaptation of the paper's TAAT inner loop (Alg. 1 lines 3–5).  The CPU
algorithm chases posting lists; on TPU we instead *densify* each object tile
into a (B_blk, D_blk) slab — one D-block at a time, exploiting the df-sorted
term layout — and feed the MXU:

    grid = (B tiles, K superblocks, D tiles)     # D sequential → accumulate
    slab[b, d]  = Σ_p vals[b,p] · [ids[b,p] == d0+d]      (VPU one-hot build)
    out[b, k]  += slab @ means_blk                         (MXU matmul)

Skew-aware engine (v2):

* **Slab reuse across K.**  K rides in ``k_sup``-wide superblocks (the whole
  padded K when it fits the VMEM budget), so the expensive densification
  runs once per (B, D) block instead of once per (B, K, D) step — a
  K/k_blk× cut in one-hot work.  The D loop stays innermost: each output
  block is revisited only on consecutive grid steps, the safe accumulation
  pattern.
* **Occupancy pruning.**  A scalar-prefetch (SMEM) map says which
  (B-tile, D-block) cells hold live tuples; empty cells — most of the
  low-df range, by Zipf skew — skip densify and matmul entirely.  Exact:
  an empty cell's slab is all zeros.
* **Cached head slabs.**  The trailing high-df blocks can arrive
  pre-densified (``kernels/plan.py``); the kernel reads the cached slab
  instead of rebuilding it every epoch.
* **Fused Mult diagnostics.**  With ``diag`` the kernel carries a second
  accumulator ``counts[b,k] = Σ_p live[b,p]·[means[ids[b,p],k] > 0]`` —
  the paper's visited-pair count — off the same one-hot walk
  (``_densify_pair``), so diagnostics no longer cost extra kernel launches.

The one-hot densification is the paper's inverted-index walk with the
branch-misprediction hazard replaced by uniform lane masks — the AFM
translation from DESIGN.md §2/§3.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _densify(ids, vals, d0, d_blk: int, p_chunk: int = 8):
    """(B, P) sparse tuples -> (B, D_blk) dense slab for terms [d0, d0+d_blk)."""
    b, p = ids.shape
    local = ids - d0
    in_blk = (local >= 0) & (local < d_blk)
    w = jnp.where(in_blk, vals, 0.0)
    lid = jnp.where(in_blk, local, 0)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, p_chunk, d_blk), 2)

    def body(c, acc):
        sl_id = jax.lax.dynamic_slice_in_dim(lid, c * p_chunk, p_chunk, 1)
        sl_w = jax.lax.dynamic_slice_in_dim(w, c * p_chunk, p_chunk, 1)
        onehot = (sl_id[:, :, None] == iota).astype(vals.dtype)
        return acc + jnp.einsum("bp,bpd->bd", sl_w, onehot,
                                preferred_element_type=jnp.float32)

    acc0 = jnp.zeros((b, d_blk), jnp.float32)
    return jax.lax.fori_loop(0, p // p_chunk, body, acc0)


def _densify_pair(ids, vals, d0, d_blk: int, p_chunk: int = 8):
    """One one-hot walk, two slabs: (value slab, live-count slab).

    The count slab weights every live slot (``vals != 0``) 1.0 — the operand
    of the fused Mult accumulator.  Sharing the walk is what makes the
    diagnostic effectively free: the onehot tensor is the expensive part.
    """
    b, p = ids.shape
    local = ids - d0
    in_blk = (local >= 0) & (local < d_blk)
    w = jnp.where(in_blk, vals, 0.0)
    lw = jnp.where(in_blk & (vals != 0.0), 1.0, 0.0).astype(vals.dtype)
    lid = jnp.where(in_blk, local, 0)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, p_chunk, d_blk), 2)

    def body(c, accs):
        acc, cacc = accs
        sl_id = jax.lax.dynamic_slice_in_dim(lid, c * p_chunk, p_chunk, 1)
        sl_w = jax.lax.dynamic_slice_in_dim(w, c * p_chunk, p_chunk, 1)
        sl_l = jax.lax.dynamic_slice_in_dim(lw, c * p_chunk, p_chunk, 1)
        onehot = (sl_id[:, :, None] == iota).astype(vals.dtype)
        return (acc + jnp.einsum("bp,bpd->bd", sl_w, onehot,
                                 preferred_element_type=jnp.float32),
                cacc + jnp.einsum("bp,bpd->bd", sl_l, onehot,
                                  preferred_element_type=jnp.float32))

    z = jnp.zeros((b, d_blk), jnp.float32)
    return jax.lax.fori_loop(0, p // p_chunk, body, (z, z))


def _slab(ids_ref, vals_ref, head_ref, headc_ref, l, *, d_blk, nd, n_head,
          diag):
    """The (B_blk, D_blk) slab(s) for D-block ``l``: cached for the trailing
    high-df blocks, densified otherwise."""
    if diag:
        build = lambda: _densify_pair(ids_ref[...], vals_ref[...],
                                      l * d_blk, d_blk)
        if n_head == 0:
            return build()
        return jax.lax.cond(l >= nd - n_head,
                            lambda: (head_ref[...], headc_ref[...]), build)
    build = lambda: _densify(ids_ref[...], vals_ref[...], l * d_blk, d_blk)
    if n_head == 0:
        return build()
    return jax.lax.cond(l >= nd - n_head, lambda: head_ref[...], build)


def _head_index(nd: int, n_head: int):
    """Index map for the cached-head operand: clamped so pre-head D steps
    keep pointing at block 0 (an unchanged index between consecutive grid
    steps costs no re-fetch)."""
    return lambda i, j, l, occ: (i, jnp.maximum(l - (nd - n_head), 0))


def _sim_kernel(occ_ref, *refs, d_blk: int, nd: int, n_head: int, diag: bool):
    ins = 2 + 1 + (1 if n_head else 0) + (1 if n_head and diag else 0)
    ids_ref, vals_ref, means_ref = refs[0], refs[1], refs[2]
    head_ref = refs[3] if n_head else None
    headc_ref = refs[4] if n_head and diag else None
    out_ref = refs[ins]
    cnt_ref = refs[ins + 1] if diag else None

    i = pl.program_id(0)
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        if diag:
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when(occ_ref[i, l] != 0)
    def _work():
        means = means_ref[...]
        if diag:
            slab, cslab = _slab(ids_ref, vals_ref, head_ref, headc_ref, l,
                                d_blk=d_blk, nd=nd, n_head=n_head, diag=True)
            cnt_ref[...] += jnp.dot(cslab, (means > 0).astype(jnp.float32),
                                    preferred_element_type=jnp.float32)
        else:
            slab = _slab(ids_ref, vals_ref, head_ref, headc_ref, l,
                         d_blk=d_blk, nd=nd, n_head=n_head, diag=False)
        out_ref[...] += jnp.dot(slab, means,
                                preferred_element_type=jnp.float32)


def sparse_sim_pallas(ids, vals, means_t, occ, head=None, headc=None, *,
                      b_blk: int = 128, k_sup: int = 128, d_blk: int = 256,
                      n_head: int = 0, diag: bool = False,
                      interpret: bool = False):
    """ids/vals: (B, P) padded sparse objects; means_t: (D, K); occ: the
    (B//b_blk, D//d_blk) occupancy map.  -> (B, K) sims [, (B, K) counts].
    """
    b, p = ids.shape
    d, k = means_t.shape
    nd = d // d_blk
    assert b % b_blk == 0 and k % k_sup == 0 and d % d_blk == 0 and p % 8 == 0, (
        f"shapes must be block-aligned: B={b}/{b_blk} K={k}/{k_sup} "
        f"D={d}/{d_blk} P={p}/8")
    assert occ.shape == (b // b_blk, nd), (occ.shape, (b // b_blk, nd))
    grid = (b // b_blk, k // k_sup, nd)

    in_specs = [
        pl.BlockSpec((b_blk, p), lambda i, j, l, occ: (i, 0)),
        pl.BlockSpec((b_blk, p), lambda i, j, l, occ: (i, 0)),
        pl.BlockSpec((d_blk, k_sup), lambda i, j, l, occ: (l, j)),
    ]
    inputs = [ids, vals, means_t]
    if n_head:
        in_specs.append(pl.BlockSpec((b_blk, d_blk), _head_index(nd, n_head)))
        inputs.append(head)
        if diag:
            in_specs.append(pl.BlockSpec((b_blk, d_blk),
                                         _head_index(nd, n_head)))
            inputs.append(headc)
    out_specs = [pl.BlockSpec((b_blk, k_sup), lambda i, j, l, occ: (i, j))]
    out_shape = [jax.ShapeDtypeStruct((b, k), jnp.float32)]
    if diag:
        out_specs.append(pl.BlockSpec((b_blk, k_sup),
                                      lambda i, j, l, occ: (i, j)))
        out_shape.append(jax.ShapeDtypeStruct((b, k), jnp.float32))

    out = pl.pallas_call(
        functools.partial(_sim_kernel, d_blk=d_blk, nd=nd, n_head=n_head,
                          diag=diag),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=out_specs),
        out_shape=out_shape,
        interpret=interpret,
    )(occ, *inputs)
    return tuple(out) if diag else out[0]
