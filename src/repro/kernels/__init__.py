"""Pallas TPU kernels for the assignment-step hot spots.

The paper's whole contribution lives in the assignment inner loop, so that is
where the kernels are:

  sparse_sim      — sparse-object × dense-mean-block similarities (MIVI core)
  esicp_gather    — fused Region-1/2 partial similarity + Region-3 L1 mass
  esicp_filter    — fused upper bound + survivor mask + |Z_i| count
  segment_update  — assignment scatter-add of sparse objects into mean sums
  rho_gather      — ρ_self refresh: per-object own-centroid similarity
  flash_attention — online-softmax banded-causal attention (LM hot spot)

Every kernel is written for TPU (pl.pallas_call + BlockSpec VMEM tiling,
MXU-shaped matmuls) and validated on CPU in interpret mode against the pure
jnp oracles in ``ref.py``.  ``xla_blocked.py`` holds the compiled XLA twins
of the clustering ops — the same KernelPlan-driven skew-aware execution
plan (head-slab GEMM + gather-formulated Zipf tail + fused diagnostics) as
jit-compiled XLA programs for platforms where Pallas only interprets; the
``xla_blocked`` backend (core/backends.py) and the ``auto`` off-TPU
resolution run on them.
"""
from repro.kernels.ops import (
    sparse_sim,
    esicp_gather,
    esicp_filter,
    segment_update,
    rho_gather,
    flash_attention,
)
from repro.kernels.plan import KernelPlan, occupancy_map, prepare_plan
from repro.kernels import ref

__all__ = ["sparse_sim", "esicp_gather", "esicp_filter", "segment_update",
           "rho_gather", "flash_attention", "ref",
           "KernelPlan", "occupancy_map", "prepare_plan"]
