"""Pallas kernel: update-step scatter-add (paper Alg. 6 lines 2–5) as MXU work.

λ[k, d] += Σ_b onehot(assign[b] == k) · x[b, d] — the cluster-sum accumulation
expressed as two one-hot densifications feeding a single matmul:

    grid = (K superblocks, D tiles, B tiles)     # B sequential → accumulate
    slab   = densify(ids, vals)                   (B_blk, D_blk)
    sel    = onehot(assign − k0)                  (B_blk, K_sup)
    out   += selᵀ @ slab                          (MXU)

A CPU implementation scatters; a TPU implementation must not (serialised
HBM read-modify-write) — this is the update-step half of the AFM adaptation.

Kernel engine v2 (see sparse_sim.py): K rides in ``k_sup``-wide superblocks
so the slab is built once per (B, D) block, not once per (K, D, B) step;
the occupancy map skips empty (B-tile, D-block) cells; the trailing high-df
blocks read the cached head slab instead of re-densifying.  Rows whose
``sel`` column is out of range contribute zero whatever the slab holds, so
cached slabs stay exact under the shard-local masking conventions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sparse_sim import _slab


def _update_kernel(occ_ref, *refs, d_blk: int, k_sup: int, nd: int,
                   n_head: int):
    ins = 3 + (1 if n_head else 0)
    assign_ref, ids_ref, vals_ref = refs[0], refs[1], refs[2]
    head_ref = refs[3] if n_head else None
    out_ref = refs[ins]

    j = pl.program_id(0)
    l = pl.program_id(1)
    m = pl.program_id(2)
    k0 = j * k_sup

    @pl.when(m == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(occ_ref[m, l] != 0)
    def _work():
        slab = _slab(ids_ref, vals_ref, head_ref, None, l, d_blk=d_blk,
                     nd=nd, n_head=n_head, diag=False)
        local = assign_ref[...][:, 0] - k0                    # (B_blk,)
        iota = jax.lax.broadcasted_iota(jnp.int32,
                                        (local.shape[0], k_sup), 1)
        sel = (local[:, None] == iota).astype(jnp.float32)    # (B_blk, K_sup)
        out_ref[...] += jnp.dot(sel.T, slab,
                                preferred_element_type=jnp.float32)


def segment_update_pallas(assign, ids, vals, k: int, d: int, occ,
                          head=None, *, b_blk: int = 128, k_sup: int = 128,
                          d_blk: int = 256, n_head: int = 0,
                          interpret: bool = False):
    """assign: (B,) int32; ids/vals: (B, P). Returns (K, D) float32 sums."""
    b, p = ids.shape
    nd = d // d_blk
    assert b % b_blk == 0 and k % k_sup == 0 and d % d_blk == 0 and p % 8 == 0
    assert occ.shape == (b // b_blk, nd)
    grid = (k // k_sup, nd, b // b_blk)

    def head_idx(j, l, m, occ):
        return (m, jnp.maximum(l - (nd - n_head), 0))

    in_specs = [
        pl.BlockSpec((b_blk, 1), lambda j, l, m, occ: (m, 0)),
        pl.BlockSpec((b_blk, p), lambda j, l, m, occ: (m, 0)),
        pl.BlockSpec((b_blk, p), lambda j, l, m, occ: (m, 0)),
    ]
    inputs = [assign[:, None], ids, vals]
    if n_head:
        in_specs.append(pl.BlockSpec((b_blk, d_blk), head_idx))
        inputs.append(head)

    return pl.pallas_call(
        functools.partial(_update_kernel, d_blk=d_blk, k_sup=k_sup, nd=nd,
                          n_head=n_head),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((k_sup, d_blk),
                                   lambda j, l, m, occ: (j, l))),
        out_shape=jax.ShapeDtypeStruct((k, d), jnp.float32),
        interpret=interpret,
    )(occ, *inputs)
