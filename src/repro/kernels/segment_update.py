"""Pallas kernel: update-step scatter-add (paper Alg. 6 lines 2–5) as MXU work.

λ[k, d] += Σ_b onehot(assign[b] == k) · x[b, d] — the cluster-sum accumulation
expressed as two one-hot densifications feeding a single matmul:

    grid = (K tiles, D tiles, B tiles)           # B sequential → accumulate
    slab   = densify(ids, vals)                   (B_blk, D_blk)
    sel    = onehot(assign − k0)                  (B_blk, K_blk)
    out   += selᵀ @ slab                          (MXU)

A CPU implementation scatters; a TPU implementation must not (serialised
HBM read-modify-write) — this is the update-step half of the AFM adaptation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sparse_sim import _densify


def _update_kernel(assign_ref, ids_ref, vals_ref, out_ref, *,
                   d_blk: int, k_blk: int):
    b_idx = pl.program_id(2)
    k0 = pl.program_id(0) * k_blk
    d0 = pl.program_id(1) * d_blk

    slab = _densify(ids_ref[...], vals_ref[...], d0, d_blk)   # (B_blk, D_blk)
    local = assign_ref[...][:, 0] - k0                        # (B_blk,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], k_blk), 1)
    sel = (local[:, None] == iota).astype(jnp.float32)        # (B_blk, K_blk)
    acc = jnp.dot(sel.T, slab, preferred_element_type=jnp.float32)

    @pl.when(b_idx == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(b_idx > 0)
    def _acc():
        out_ref[...] += acc


def segment_update_pallas(assign, ids, vals, k: int, d: int, *,
                          b_blk: int = 128, k_blk: int = 128, d_blk: int = 256,
                          interpret: bool = False):
    """assign: (B,) int32; ids/vals: (B, P). Returns (K, D) float32 sums."""
    b, p = ids.shape
    assert b % b_blk == 0 and k % k_blk == 0 and d % d_blk == 0 and p % 8 == 0
    grid = (k // k_blk, d // d_blk, b // b_blk)
    return pl.pallas_call(
        functools.partial(_update_kernel, d_blk=d_blk, k_blk=k_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_blk, 1), lambda i, j, l: (l, 0)),
            pl.BlockSpec((b_blk, p), lambda i, j, l: (l, 0)),
            pl.BlockSpec((b_blk, p), lambda i, j, l: (l, 0)),
        ],
        out_specs=pl.BlockSpec((k_blk, d_blk), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, d), jnp.float32),
        interpret=interpret,
    )(assign[:, None], ids, vals)
