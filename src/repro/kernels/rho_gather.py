"""Pallas kernel: ρ_self refresh — per-object gather of its own centroid.

The update step's lines 6–7 (Alg. 6) compute ρ_{a(i)} = x_i · μ_{a(i)} for
every object against its *new* centroid.  A CPU implementation gathers the
assigned column per object tuple; on TPU a data-dependent column gather from
``means_t (D, K)`` would serialise, so the gather is expressed as a one-hot
matmul over the centroid tile — the ρ_self half of the AFM update adaptation
(the scatter half is :mod:`repro.kernels.segment_update`):

    grid = (B tiles, D tiles, K tiles)           # D, K sequential → accumulate
    slab     = densify(ids, vals)                 (B_blk, D_blk)
    sel      = onehot(assign − k0)                (B_blk, K_blk)
    gathered = sel @ means_blkᵀ                   (MXU)  — own-centroid columns
    out[b]  += Σ_d slab[b, d] · gathered[b, d]    (VPU row reduce)

The output rides a 128-lane block (every lane carries the same partial) so
the (B,) result stays tile-aligned; the wrapper slices lane 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sparse_sim import _densify


def _rho_kernel(assign_ref, ids_ref, vals_ref, means_ref, out_ref, *,
                d_blk: int, k_blk: int):
    d_idx = pl.program_id(1)
    k_idx = pl.program_id(2)
    d0 = d_idx * d_blk
    k0 = k_idx * k_blk

    slab = _densify(ids_ref[...], vals_ref[...], d0, d_blk)   # (B_blk, D_blk)
    local = assign_ref[...][:, 0] - k0                        # (B_blk,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], k_blk), 1)
    sel = (local[:, None] == iota).astype(jnp.float32)        # (B_blk, K_blk)
    gathered = jnp.dot(sel, means_ref[...].T,
                       preferred_element_type=jnp.float32)    # (B_blk, D_blk)
    part = jnp.sum(slab * gathered, axis=1, keepdims=True)    # (B_blk, 1)
    acc = jnp.broadcast_to(part, (part.shape[0], 128))

    @pl.when((d_idx == 0) & (k_idx == 0))
    def _init():
        out_ref[...] = acc

    @pl.when((d_idx > 0) | (k_idx > 0))
    def _acc():
        out_ref[...] += acc


def rho_gather_pallas(assign, ids, vals, means_t, *,
                      b_blk: int = 128, k_blk: int = 128, d_blk: int = 256,
                      interpret: bool = False):
    """assign: (B,) int32; ids/vals: (B, P); means_t: (D, K). -> (B,) float32.

    Out-of-range assignments (padding rows use ``assign = K``) select no
    centroid column and produce ρ = 0.
    """
    b, p = ids.shape
    d, k = means_t.shape
    assert b % b_blk == 0 and k % k_blk == 0 and d % d_blk == 0 and p % 8 == 0
    grid = (b // b_blk, d // d_blk, k // k_blk)
    out = pl.pallas_call(
        functools.partial(_rho_kernel, d_blk=d_blk, k_blk=k_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_blk, 1), lambda i, j, l: (i, 0)),
            pl.BlockSpec((b_blk, p), lambda i, j, l: (i, 0)),
            pl.BlockSpec((b_blk, p), lambda i, j, l: (i, 0)),
            pl.BlockSpec((d_blk, k_blk), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((b_blk, 128), lambda i, j, l: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 128), jnp.float32),
        interpret=interpret,
    )(assign[:, None], ids, vals, means_t)
    return out[:, 0]
