"""Pallas kernel: ρ_self refresh — per-object gather of its own centroid.

The update step's lines 6–7 (Alg. 6) compute ρ_{a(i)} = x_i · μ_{a(i)} for
every object against its *new* centroid.  A CPU implementation gathers the
assigned column per object tuple; on TPU a data-dependent column gather from
``means_t (D, K)`` would serialise, so the gather is expressed as a one-hot
matmul over the centroid tile — the ρ_self half of the AFM update adaptation
(the scatter half is :mod:`repro.kernels.segment_update`):

    grid = (B tiles, K superblocks, D tiles)     # K, D sequential → accumulate
    slab     = densify(ids, vals)                 (B_blk, D_blk)
    sel      = onehot(assign − k0)                (B_blk, K_sup)
    gathered = sel @ means_blkᵀ                   (MXU)  — own-centroid columns
    out[b]  += Σ_d slab[b, d] · gathered[b, d]    (VPU row reduce)

The output rides a 128-lane block (every lane carries the same partial) so
the (B,) result stays tile-aligned; the wrapper slices lane 0.

Kernel engine v2 (see sparse_sim.py): K rides in ``k_sup``-wide superblocks
(densify once per (B, D) block), the occupancy map skips empty cells, and
the trailing high-df blocks read the cached head slab.  Out-of-range
assignments still select no centroid column, so cached slabs are inert for
masked rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sparse_sim import _head_index, _slab


def _rho_kernel(occ_ref, *refs, d_blk: int, k_sup: int, nd: int, n_head: int):
    ins = 4 + (1 if n_head else 0)
    assign_ref, ids_ref, vals_ref, means_ref = refs[:4]
    head_ref = refs[4] if n_head else None
    out_ref = refs[ins]

    i = pl.program_id(0)
    j = pl.program_id(1)
    l = pl.program_id(2)
    k0 = j * k_sup

    @pl.when((j == 0) & (l == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(occ_ref[i, l] != 0)
    def _work():
        slab = _slab(ids_ref, vals_ref, head_ref, None, l, d_blk=d_blk,
                     nd=nd, n_head=n_head, diag=False)
        local = assign_ref[...][:, 0] - k0                    # (B_blk,)
        iota = jax.lax.broadcasted_iota(jnp.int32,
                                        (local.shape[0], k_sup), 1)
        sel = (local[:, None] == iota).astype(jnp.float32)    # (B_blk, K_sup)
        gathered = jnp.dot(sel, means_ref[...].T,
                           preferred_element_type=jnp.float32)  # (B_blk, D_blk)
        part = jnp.sum(slab * gathered, axis=1, keepdims=True)  # (B_blk, 1)
        out_ref[...] += jnp.broadcast_to(part, (part.shape[0], 128))


def rho_gather_pallas(assign, ids, vals, means_t, occ, head=None, *,
                      b_blk: int = 128, k_sup: int = 128, d_blk: int = 256,
                      n_head: int = 0, interpret: bool = False):
    """assign: (B,) int32; ids/vals: (B, P); means_t: (D, K). -> (B,) float32.

    Out-of-range assignments (padding rows use ``assign = K``) select no
    centroid column and produce ρ = 0.
    """
    b, p = ids.shape
    d, k = means_t.shape
    nd = d // d_blk
    assert b % b_blk == 0 and k % k_sup == 0 and d % d_blk == 0 and p % 8 == 0
    assert occ.shape == (b // b_blk, nd)
    grid = (b // b_blk, k // k_sup, nd)

    in_specs = [
        pl.BlockSpec((b_blk, 1), lambda i, j, l, occ: (i, 0)),
        pl.BlockSpec((b_blk, p), lambda i, j, l, occ: (i, 0)),
        pl.BlockSpec((b_blk, p), lambda i, j, l, occ: (i, 0)),
        pl.BlockSpec((d_blk, k_sup), lambda i, j, l, occ: (l, j)),
    ]
    inputs = [assign[:, None], ids, vals, means_t]
    if n_head:
        in_specs.append(pl.BlockSpec((b_blk, d_blk), _head_index(nd, n_head)))
        inputs.append(head)

    out = pl.pallas_call(
        functools.partial(_rho_kernel, d_blk=d_blk, k_sup=k_sup, nd=nd,
                          n_head=n_head),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((b_blk, 128),
                                   lambda i, j, l, occ: (i, 0))),
        out_shape=jax.ShapeDtypeStruct((b, 128), jnp.float32),
        interpret=interpret,
    )(occ, *inputs)
    return out[:, 0]
