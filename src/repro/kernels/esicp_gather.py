"""Pallas kernel: fused ES-filter gathering phase (paper Alg. 3 / G_0, G_1).

One pass over the mean-inverted index producing, per (object, centroid):

    rho12[b,k] = Σ_{s<t_th} u·v  +  Σ_{s≥t_th, v≥v_th} u·v     (exact part)
    y[b,k]     = Σ_{s≥t_th, v<v_th} u                          (Region-3 mass)

The three-region classification is two uniform masks over the means block —
the shared (t_th, v_th) thresholds are scalar-prefetch operands living in
SMEM, so the kernel body has no data-dependent branches at all (the paper's
AFM requirement, realised as TPU select lanes).

Same densify-then-MXU structure as sparse_sim; both matmuls (rho12, y) reuse
one slab, doubling arithmetic intensity per HBM byte of object data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sparse_sim import _densify


def _gather_kernel(scalars_ref, ids_ref, vals_ref, means_ref,
                   rho_ref, y_ref, *, d_blk: int):
    d_idx = pl.program_id(2)
    d0 = d_idx * d_blk
    t_th = scalars_ref[0]
    v_th = scalars_ref[1]

    slab = _densify(ids_ref[...], vals_ref[...], d0, d_blk)
    means = means_ref[...]                                   # (D_blk, K_blk)

    term = d0 + jax.lax.broadcasted_iota(jnp.int32, means.shape, 0)
    tail = (term.astype(jnp.float32) >= t_th)
    hi = means >= v_th
    exact = jnp.where(tail, hi, True)

    rho = jnp.dot(slab, jnp.where(exact, means, 0.0),
                  preferred_element_type=jnp.float32)
    yac = jnp.dot(slab, (tail & ~hi).astype(jnp.float32),
                  preferred_element_type=jnp.float32)

    @pl.when(d_idx == 0)
    def _init():
        rho_ref[...] = rho
        y_ref[...] = yac

    @pl.when(d_idx > 0)
    def _acc():
        rho_ref[...] += rho
        y_ref[...] += yac


def esicp_gather_pallas(ids, vals, means_t, t_th, v_th, *,
                        b_blk: int = 128, k_blk: int = 128, d_blk: int = 256,
                        interpret: bool = False):
    """Returns (rho12, y), each (B, K) float32."""
    b, p = ids.shape
    d, k = means_t.shape
    assert b % b_blk == 0 and k % k_blk == 0 and d % d_blk == 0 and p % 8 == 0
    grid = (b // b_blk, k // k_blk, d // d_blk)
    scalars = jnp.stack([jnp.asarray(t_th, jnp.float32),
                         jnp.asarray(v_th, jnp.float32)])
    return pl.pallas_call(
        functools.partial(_gather_kernel, d_blk=d_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i, j, l: (0,)),        # shared thresholds
            pl.BlockSpec((b_blk, p), lambda i, j, l: (i, 0)),
            pl.BlockSpec((b_blk, p), lambda i, j, l: (i, 0)),
            pl.BlockSpec((d_blk, k_blk), lambda i, j, l: (l, j)),
        ],
        out_specs=[
            pl.BlockSpec((b_blk, k_blk), lambda i, j, l: (i, j)),
            pl.BlockSpec((b_blk, k_blk), lambda i, j, l: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, ids, vals, means_t)
