"""Pallas kernel: fused ES-filter gathering phase (paper Alg. 3 / G_0, G_1).

One pass over the mean-inverted index producing, per (object, centroid):

    rho12[b,k] = Σ_{s<t_th} u·v  +  Σ_{s≥t_th, v≥v_th} u·v     (exact part)
    y[b,k]     = Σ_{s≥t_th, v<v_th} u                          (Region-3 mass)

The three-region classification is two uniform masks over the means block —
the shared (t_th, v_th) thresholds are scalar-prefetch operands living in
SMEM, so the kernel body has no data-dependent branches at all (the paper's
AFM requirement, realised as TPU select lanes).

Everything the ES assignment step needs comes off ONE densified slab per
(B, D) block (kernel engine v2, see sparse_sim.py for the grid order,
occupancy pruning and head-cache mechanics):

  * rho12 and y — the bound operands (always);
  * ``with_sims`` — the full exact similarity ``slab @ means`` as a third
    accumulator, deleting the separate ``sparse_sim`` launch the backend
    used to pay per batch;
  * ``diag`` — the fused Mult count over the *exact region*
    (``nz & where(tail, v ≥ v_th, True)``), off the live-count twin slab,
    deleting the binarised side-launches and the host-side (D, K) region
    mask they needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sparse_sim import _densify, _densify_pair, _head_index, _slab


def _gather_kernel(occ_ref, scalars_ref, *refs, d_blk: int, nd: int,
                   n_head: int, with_sims: bool, diag: bool):
    ins = 3 + (1 if n_head else 0) + (1 if n_head and diag else 0)
    ids_ref, vals_ref, means_ref = refs[0], refs[1], refs[2]
    head_ref = refs[3] if n_head else None
    headc_ref = refs[4] if n_head and diag else None
    outs = refs[ins:]
    rho_ref, y_ref = outs[0], outs[1]
    sims_ref = outs[2] if with_sims else None
    cnt_ref = outs[-1] if diag else None

    i = pl.program_id(0)
    l = pl.program_id(2)
    d0 = l * d_blk
    t_th = scalars_ref[0]
    v_th = scalars_ref[1]

    @pl.when(l == 0)
    def _init():
        rho_ref[...] = jnp.zeros_like(rho_ref)
        y_ref[...] = jnp.zeros_like(y_ref)
        if with_sims:
            sims_ref[...] = jnp.zeros_like(sims_ref)
        if diag:
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when(occ_ref[i, l] != 0)
    def _work():
        means = means_ref[...]                               # (D_blk, K_sup)
        term = d0 + jax.lax.broadcasted_iota(jnp.int32, means.shape, 0)
        tail = (term.astype(jnp.float32) >= t_th)
        hi = means >= v_th
        exact = jnp.where(tail, hi, True)

        if diag:
            slab, cslab = _slab(ids_ref, vals_ref, head_ref, headc_ref, l,
                                d_blk=d_blk, nd=nd, n_head=n_head, diag=True)
            w_cnt = ((means > 0) & exact).astype(jnp.float32)
            cnt_ref[...] += jnp.dot(cslab, w_cnt,
                                    preferred_element_type=jnp.float32)
        else:
            slab = _slab(ids_ref, vals_ref, head_ref, headc_ref, l,
                         d_blk=d_blk, nd=nd, n_head=n_head, diag=False)

        rho_ref[...] += jnp.dot(slab, jnp.where(exact, means, 0.0),
                                preferred_element_type=jnp.float32)
        y_ref[...] += jnp.dot(slab, (tail & ~hi).astype(jnp.float32),
                              preferred_element_type=jnp.float32)
        if with_sims:
            sims_ref[...] += jnp.dot(slab, means,
                                     preferred_element_type=jnp.float32)


def esicp_gather_pallas(ids, vals, means_t, t_th, v_th, occ, head=None,
                        headc=None, *, b_blk: int = 128, k_sup: int = 128,
                        d_blk: int = 256, n_head: int = 0,
                        with_sims: bool = False, diag: bool = False,
                        interpret: bool = False):
    """Returns (rho12, y[, sims][, counts]), each (B, K) float32."""
    b, p = ids.shape
    d, k = means_t.shape
    nd = d // d_blk
    assert b % b_blk == 0 and k % k_sup == 0 and d % d_blk == 0 and p % 8 == 0
    assert occ.shape == (b // b_blk, nd)
    grid = (b // b_blk, k // k_sup, nd)
    scalars = jnp.stack([jnp.asarray(t_th, jnp.float32),
                         jnp.asarray(v_th, jnp.float32)])

    in_specs = [
        pl.BlockSpec((2,), lambda i, j, l, occ: (0,)),   # shared thresholds
        pl.BlockSpec((b_blk, p), lambda i, j, l, occ: (i, 0)),
        pl.BlockSpec((b_blk, p), lambda i, j, l, occ: (i, 0)),
        pl.BlockSpec((d_blk, k_sup), lambda i, j, l, occ: (l, j)),
    ]
    inputs = [scalars, ids, vals, means_t]
    if n_head:
        in_specs.append(pl.BlockSpec((b_blk, d_blk), _head_index(nd, n_head)))
        inputs.append(head)
        if diag:
            in_specs.append(pl.BlockSpec((b_blk, d_blk),
                                         _head_index(nd, n_head)))
            inputs.append(headc)
    n_out = 2 + int(with_sims) + int(diag)
    out_specs = [pl.BlockSpec((b_blk, k_sup), lambda i, j, l, occ: (i, j))
                 for _ in range(n_out)]
    out_shape = [jax.ShapeDtypeStruct((b, k), jnp.float32)
                 for _ in range(n_out)]

    out = pl.pallas_call(
        functools.partial(_gather_kernel, d_blk=d_blk, nd=nd, n_head=n_head,
                          with_sims=with_sims, diag=diag),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=out_specs),
        out_shape=out_shape,
        interpret=interpret,
    )(occ, *inputs)
    return tuple(out)
