"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def densify(ids, vals, d: int):
    """(B, P) sparse tuples -> (B, D) dense (padding has val == 0)."""
    b, p = ids.shape
    out = jnp.zeros((b, d), vals.dtype)
    rows = jnp.repeat(jnp.arange(b), p)
    return out.at[rows, ids.reshape(-1)].add(vals.reshape(-1))


def sparse_sim(ids, vals, means_t):
    """(B, K) exact similarities."""
    x = densify(ids, vals, means_t.shape[0])
    return x @ means_t


def esicp_gather(ids, vals, means_t, t_th, v_th):
    """(rho12, y) per Eq. (4) decomposition."""
    d, k = means_t.shape
    x = densify(ids, vals, d)
    term = jnp.arange(d)[:, None]
    tail = term >= t_th
    hi = means_t >= v_th
    exact = jnp.where(tail, hi, True)
    rho12 = x @ jnp.where(exact, means_t, 0.0)
    y = x @ (tail & ~hi).astype(x.dtype)
    return rho12, y


def esicp_filter(rho12, y, rho_max, col_ok, v_th):
    ub = rho12 + y * v_th
    mask = (ub > rho_max[:, None]) & col_ok.astype(bool)
    return mask.astype(jnp.int8), jnp.sum(mask, axis=1).astype(jnp.int32)


def sketch_sim(sk_docs, sketch_t):
    """(B, S) doc sketches × (S, K) mean sketches -> (B, K) sketch bounds.

    A plain dense matmul: each entry upper-bounds the exact cosine similarity
    for non-negative data (per-group Cauchy-Schwarz)."""
    return jnp.dot(sk_docs, sketch_t, preferred_element_type=jnp.float32)


def segment_update(assign, ids, vals, k: int, d: int):
    x = densify(ids, vals, d)
    out = jnp.zeros((k, d), jnp.float32)
    return out.at[assign].add(x)


def rho_gather(assign, ids, vals, means_t):
    """(B,) each object's similarity vs its assigned centroid; out-of-range
    assignments (padding) read 0."""
    d, k = means_t.shape
    x = densify(ids, vals, d)
    cols = jnp.where(assign < k, assign, 0)
    picked = jnp.where((assign < k)[:, None], means_t.T[cols], 0.0)
    return jnp.sum(x * picked, axis=1)


def flash_attention(q, k, v, window: int = -1):
    """(BH, Sq, hd) × (BH, Sk, hd) banded-causal attention, f32."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(float(hd))
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    w = jnp.iinfo(jnp.int32).max if window < 0 else window
    mask = (kp <= qp) & ((qp - kp) < w)
    s = jnp.where(mask[None], s, -1e30)
    probs = jax.nn.softmax(s, axis=-1)
    probs = jnp.where(mask.any(axis=1)[None, :, None], probs, 0.0)
    return jnp.einsum("bqk,bkd->bqd", probs, v)
