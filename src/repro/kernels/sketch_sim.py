"""Dense block-vector sketch similarity kernel (sketch pre-filter pass).

The sketch gate (DESIGN.md §11) reduces every doc and mean to an S-dim
block-vector of group L2 norms (S <= meanindex.SKETCH_DIM), so the gating
similarity is a tiny dense matmul: (B, S) @ (S, K).  One grid axis over B
tiles; S and K ride whole in each block (S is at most 64, padded to the
128-lane tile by the ops wrapper with zeros, which leave the dot product
bit-identical to the unpadded reference matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sketch_kernel(x_ref, m_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], m_ref[...],
                         preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("b_blk", "interpret"))
def sketch_sim_pallas(sk_docs, sketch_t, *, b_blk: int = 128,
                      interpret: bool = True):
    """(B, S) doc sketches × (S, K) mean sketches -> (B, K) sketch bounds.

    B must be a multiple of b_blk; S and K must be lane-aligned (the ops
    wrapper pads with zeros, which do not perturb the dot product).
    """
    b, s = sk_docs.shape
    s2, k = sketch_t.shape
    assert s == s2, (s, s2)
    assert b % b_blk == 0, (b, b_blk)

    grid = (b // b_blk,)
    return pl.pallas_call(
        _sketch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_blk, s), lambda i: (i, 0)),
            pl.BlockSpec((s, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b_blk, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(sk_docs, sketch_t)
