from repro.checkpoint.store import (
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    load_extra,
    AsyncCheckpointer,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "load_extra", "AsyncCheckpointer"]
