"""Fault-tolerant checkpoint store: npz payload + JSON manifest.

Design (no orbax dependency):
  * a checkpoint is ``step_<n>/payload.npz`` + ``step_<n>/manifest.json``;
  * writes go to ``step_<n>.tmp`` then ``os.rename`` — the manifest is the
    commit record, so a crashed writer never leaves a readable-but-corrupt
    checkpoint (rename is atomic on POSIX);
  * ``keep`` retention prunes old steps only after a successful commit;
  * ``AsyncCheckpointer`` overlaps serialisation with the next training step
    (one in-flight save; the training loop only blocks if it laps the saver);
  * restore targets any mesh: arrays are loaded host-side and re-placed by
    the caller (see distributed.elastic.reshard_state) — that is what makes
    elastic restart-on-fewer-hosts work.

Multi-host posture: every host writes only addressable shards of each array
(`_to_host` gathers per-shard data; on a single-host run that is the whole
array).  The manifest stores the global shape/dtype so a restore on a
different topology can validate before re-sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _to_host(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def save_checkpoint(directory: str, tree, *, step: int, keep: int | None = 3,
                    extra: dict | None = None) -> str:
    """Atomically persist `tree` (any pytree of arrays/scalars) at `step`.

    ``keep=None`` disables retention pruning entirely — for artifact-style
    writers (FittedModel.save) that must never garbage-collect unrelated
    steps already in the directory.

    ``extra`` is an optional JSON-serialisable sidecar (model metadata,
    fit history, …) committed atomically with the payload — it rides the
    same tmp-then-rename transaction, so a reader never sees a payload
    without its metadata or vice versa.  Read it back with
    :func:`load_extra`.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _to_host(tree)
    np.savez(os.path.join(tmp, "payload.npz"),
             **{f"leaf_{i}": leaf for i, leaf in enumerate(leaves)})
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "format": 1,
    }
    if extra is not None:
        with open(os.path.join(tmp, "extra.json"), "w") as f:
            json.dump(extra, f)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # commit point

    if keep is not None:
        steps = sorted(all_steps(directory))
        for old in steps[:-keep]:
            shutil.rmtree(os.path.join(directory, f"step_{old:08d}"),
                          ignore_errors=True)
    return final


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def load_extra(directory: str, *, step: int | None = None) -> dict | None:
    """The JSON sidecar committed with `step` (None -> latest), or None if
    the checkpoint exists but was written without one.  A missing step —
    like the step=None path with an empty directory — raises
    FileNotFoundError rather than masquerading as a sidecar-less save."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    step_dir = os.path.join(directory, f"step_{step:08d}")
    if not os.path.isdir(step_dir):
        raise FileNotFoundError(f"no checkpoint step {step} under {directory}")
    path = os.path.join(step_dir, "extra.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def restore_checkpoint(directory: str, example_tree, *, step: int | None = None):
    """Restore into the structure of `example_tree` (shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    payload = np.load(os.path.join(path, "payload.npz"))
    leaves = [payload[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    _, treedef = jax.tree_util.tree_flatten(example_tree)
    example_leaves = jax.tree_util.tree_leaves(example_tree)
    if len(example_leaves) != len(leaves):
        raise ValueError(f"leaf count mismatch: ckpt {len(leaves)} vs "
                         f"example {len(example_leaves)}")
    for i, (got, want) in enumerate(zip(leaves, example_leaves)):
        if tuple(np.shape(got)) != tuple(np.shape(want)):
            raise ValueError(f"leaf {i} shape {np.shape(got)} != {np.shape(want)}")
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """One-in-flight async saver: serialise off the critical path."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, tree, *, step: int, extra: dict | None = None):
        """``extra`` rides the same commit as the payload (see
        :func:`save_checkpoint`) — e.g. the streaming fit's resume cursor
        sidecar — snapshotted here so later caller mutation can't tear it."""
        self.wait()                       # one in-flight save max
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now
        extra = None if extra is None else dict(extra)

        def work():
            try:
                save_checkpoint(self.directory, host_tree, step=step,
                                keep=self.keep, extra=extra)
            except BaseException as e:    # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
