"""AdamW with global-norm clipping, from scratch (no optax).

Optimizer state mirrors the parameter tree; shardings therefore inherit from
the parameter shardings (ZeRO-style placement falls out of the FSDP param
specs in launch/sharding.py — mu/nu live wherever the weight shard lives).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = _schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    new = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    params_new = jax.tree_util.tree_unflatten(treedef, [t[0] for t in new])
    mu_new = jax.tree_util.tree_unflatten(treedef, [t[1] for t in new])
    nu_new = jax.tree_util.tree_unflatten(treedef, [t[2] for t in new])
    return params_new, {"mu": mu_new, "nu": nu_new, "count": count}, {"grad_norm": gnorm, "lr": lr}
