"""Training step builder: value_and_grad + microbatch accumulation + AdamW.

Microbatching is a lax.scan over gradient accumulation slices — the knob
that trades activation memory (the §Roofline memory term) for step latency.
Remat is applied per segment-scan step inside ``forward`` (jax.checkpoint),
so live activations are one layer deep per microbatch.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import lm_loss
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    loss_chunk: int = 512
    optimizer: AdamWConfig = AdamWConfig()


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    """Returns train_step(params, opt_state, tokens, labels[, frontend]) ->
    (params, opt_state, metrics).  tokens/labels: (B, S) int32."""

    def loss_fn(params, tokens, labels, fe):
        return lm_loss(params, tokens, labels, cfg, loss_chunk=tcfg.loss_chunk,
                       frontend_embeds=fe)

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, tokens, labels, frontend_embeds=None):
        mb = tcfg.microbatches
        b = tokens.shape[0]
        assert b % mb == 0, (b, mb)

        if mb == 1:
            loss, grads = grad_fn(params, tokens, labels, frontend_embeds)
        else:
            shard = lambda a: (None if a is None else
                               a.reshape((mb, b // mb) + a.shape[1:]))
            tk, lb = shard(tokens), shard(labels)
            fe = shard(frontend_embeds)

            def body(acc, inp):
                loss_acc, grads_acc = acc
                if fe is None:
                    t, l = inp
                    f = None
                else:
                    t, l, f = inp
                loss, grads = grad_fn(params, t, l, f)
                return (loss_acc + loss,
                        jax.tree_util.tree_map(jnp.add, grads_acc, grads)), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (tk, lb) if fe is None else (tk, lb, fe)
            from repro.models.config import scan_unroll
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), xs,
                                            unroll=scan_unroll())
            loss = loss / mb
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)

        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params,
                                                      tcfg.optimizer)
        metrics = {"loss": loss, **opt_metrics}
        return params, opt_state, metrics

    return train_step
