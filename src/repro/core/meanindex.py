"""Structured mean-inverted index (paper §IV-A, Fig. 5/6) — TPU adaptation.

The paper's index is a ragged array of postings ξ_s per term, partitioned by
two shared structural parameters into

    Region 1:  s <  t_th                      (exact, short postings)
    Region 2:  s >= t_th and v >= v_th        (exact, the VMEM-hot block)
    Region 3:  s >= t_th and v <  v_th        (upper-bounded by y * v_th)

On TPU we keep the *transposed dense* mean matrix ``means_t (D, K)`` — row s
is exactly the posting list ξ_s in full expression (the paper's own M^p uses
full expression for O(1) centroid addressing).  Regions are realised as
masks/counts over this matrix, so the three-region logic is branch-free:
shared (t_th, v_th) thresholds become uniform select masks — the TPU analogue
of the paper's "no irregular conditional branches".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Block-vector sketch width (Knittel, Koch & Ertl, arxiv_2108.00895): the
# vocabulary is split into S contiguous groups of g dimensions and each
# vector is summarised by the per-group L2 norms.  For non-negative data
# every group satisfies Cauchy-Schwarz, so the S-dim dense dot of two
# sketches upper-bounds the exact D-dim dot — a sound pre-filter for the
# sparse_sim pass.  S is capped at SKETCH_DIM so the sketch similarity is
# a tiny dense matmul regardless of vocabulary size.
SKETCH_DIM = 64


def sketch_group_width(dim: int) -> int:
    """Group width g so that ceil(dim / g) <= SKETCH_DIM."""
    return -(-dim // SKETCH_DIM)


def sketch_size(dim: int) -> int:
    """Number of sketch slots S = ceil(dim / g) (<= SKETCH_DIM)."""
    g = sketch_group_width(dim)
    return -(-dim // g)


def sketch_means(means_t: jax.Array) -> jax.Array:
    """(D, K) transposed means -> (S, K) block-vector sketch.

    Slot s holds the L2 norm of rows [s*g, (s+1)*g) of means_t per centroid.
    """
    d = means_t.shape[0]
    g = sketch_group_width(d)
    s = sketch_size(d)
    seg = jnp.arange(d, dtype=jnp.int32) // g
    sq = jax.ops.segment_sum(means_t * means_t, seg, num_segments=s)
    return jnp.sqrt(sq)


def doc_sketch(ids: jax.Array, vals: jax.Array, dim: int) -> jax.Array:
    """(B, P) padded sparse docs -> (B, S) block-vector sketch.

    Dead slots carry val 0 and contribute nothing regardless of their id,
    so the padding convention needs no special-casing.  Shared verbatim by
    both backends so the sketches are bitwise identical across them.
    """
    g = sketch_group_width(dim)
    s = sketch_size(dim)
    seg = jnp.clip(ids.astype(jnp.int32) // g, 0, s - 1)
    sq = jax.vmap(
        lambda sg, v: jax.ops.segment_sum(v * v, sg, num_segments=s)
    )(seg, vals)
    return jnp.sqrt(sq)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class StructuralParams:
    """Shared thresholds (t_th, v_th) — paper Table III."""

    t_th: jax.Array  # () int32 — term-ID threshold (df-rank space)
    v_th: jax.Array  # () float32 — mean-feature-value threshold

    def tree_flatten(self):
        return (self.t_th, self.v_th), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)

    @staticmethod
    def trivial(dim: int) -> "StructuralParams":
        """t_th = 0, v_th = 1: Region 1 empty, Region 2 empty — degenerates to
        a pure L1 bound (the ThT ablation of App. D)."""
        return StructuralParams(t_th=jnp.asarray(0, jnp.int32), v_th=jnp.asarray(1.0, jnp.float32))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class MeanIndex:
    """Mean set + the derived statistics every filter needs.

    means_t: (D, K) float32 — transposed means; row s = posting list ξ_s.
    mf:      (D,) int32     — mean frequency of term s (nonzeros in row s).
    moving:  (K,) bool      — centroid moved at the last update (ICP state).
    n_moving:() int32       — number of moving centroids (nMv).
    params:  StructuralParams.
    mf_h:    (D,) int32     — (mfH)_s: entries with v >= v_th (Region-2 width).
    sketch_t:(S, K) float32 — block-vector sketch of the means (sketch modes).
    """

    means_t: jax.Array
    mf: jax.Array
    moving: jax.Array
    n_moving: jax.Array
    params: StructuralParams
    mf_h: jax.Array
    sketch_t: jax.Array

    def tree_flatten(self):
        return (self.means_t, self.mf, self.moving, self.n_moving,
                self.params, self.mf_h, self.sketch_t), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)

    @property
    def dim(self) -> int:
        return self.means_t.shape[0]

    @property
    def k(self) -> int:
        return self.means_t.shape[1]

    def region2_mask(self) -> jax.Array:
        """(D, K) bool — Region-2 membership."""
        s_tail = jnp.arange(self.dim)[:, None] >= self.params.t_th
        return s_tail & (self.means_t >= self.params.v_th)

    def with_params(self, params: StructuralParams) -> "MeanIndex":
        return build_mean_index(self.means_t.T, params, moving=self.moving)


def _mf_counts(means_t: jax.Array) -> jax.Array:
    return jnp.sum(means_t > 0, axis=1).astype(jnp.int32)


def build_mean_index(means: jax.Array, params: StructuralParams,
                     moving: jax.Array | None = None) -> MeanIndex:
    """means: (K, D) L2-normalised centroid matrix -> MeanIndex.

    The paper's update step (Alg. 6 steps 3–5) constructs ξ_s arrays and the
    moving/invariant block split; here both collapse to cheap column stats
    because the index is dense-blocked (DESIGN.md §2).
    """
    k, d = means.shape
    means_t = means.T
    mf = _mf_counts(means_t)
    if moving is None:
        moving = jnp.ones((k,), bool)
    mf_h = jnp.sum((means_t >= params.v_th)
                   & (jnp.arange(d)[:, None] >= params.t_th), axis=1).astype(jnp.int32)
    return MeanIndex(
        means_t=means_t,
        mf=mf,
        moving=moving,
        n_moving=jnp.sum(moving).astype(jnp.int32),
        params=params,
        mf_h=mf_h,
        sketch_t=sketch_means(means_t),
    )


def normalized_means(lam: jax.Array, fallback_means_t: jax.Array) -> jax.Array:
    """(K, D) unit-norm means from cluster sums λ (Alg. 6 step 2→3).

    Empty clusters keep their previous mean (still a unit vector) so the
    exactness property vs Lloyd from identical states is preserved.  Shared
    by the single-device update step, the shard-local distributed update,
    and the serving engine's index rebuild.
    """
    norms = jnp.sqrt(jnp.sum(lam * lam, axis=1, keepdims=True))
    empty = norms[:, 0] == 0.0
    fallback = fallback_means_t.T.astype(jnp.float32)
    return jnp.where(empty[:, None], fallback, lam / jnp.maximum(norms, 1e-12))


def mean_value_stats(means_t: jax.Array, t_th: jax.Array):
    """Row statistics used by EstParams:

    col_sum:  (D,)  Σ_k v_{s,k}         (Eq. 32 inner sum)
    Returns (col_sum,).
    """
    return (jnp.sum(means_t, axis=1),)


def delta_v_bar(means_t: jax.Array, v_grid: jax.Array) -> jax.Array:
    """Δv̄_{s,h} = (1/K) Σ_k relu(v_h − v_{s,k})  — Eq. (39).

    Includes absent centroids (v = 0), matching the (K − mf_s)·v_h term.
    Returns (D, H) float32.
    """
    d, k = means_t.shape

    def per_h(v_h):
        return jnp.mean(jnp.maximum(v_h - means_t, 0.0), axis=1)

    return jax.vmap(per_h, out_axes=1)(v_grid)


def mfh_table(means_t: jax.Array, v_grid: jax.Array) -> jax.Array:
    """(mfH)_{s,h} for every v_th candidate — (D, H) int32 (Eq. 9)."""

    def per_h(v_h):
        return jnp.sum(means_t >= v_h, axis=1).astype(jnp.int32)

    return jax.vmap(per_h, out_axes=1)(v_grid)
