"""Assignment step for all compared algorithms (paper Algs. 1–5, App. F).

The algorithms here are pure selection logic: they consume accumulators
(exact similarities, region-wise partial sums, survivor masks) produced by a
pluggable :class:`repro.core.backends.Backend` — ``reference`` (the TAAT
``lax.scan``, the paper's MIVI loop order and this repo's exactness oracle)
or ``pallas`` (the TPU kernels in :mod:`repro.kernels.ops`, interpret mode
off-TPU).  See backends.py / DESIGN.md §5 for the split.

Exactness contract (tested): every algorithm returns *identical* assignments
to MIVI from the same state, under every backend.  Filters only change the
Mult/CPR diagnostics, which are counted as the paper counts them — the
number of multiply-adds a CPU implementation would execute, i.e. pairs
(object-term, posting-entry) actually visited.

Tie policy (paper Algs. 1/2 line "if ρ_j > ρ_max"): strict improvement over
the refreshed self-similarity; among equal improvers the lowest centroid ID
wins (sequential scan order == jnp.argmax first-occurrence).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.sparse import SparseDocs
from repro.core.meanindex import MeanIndex
from repro.core.backends import col_ok_mask, reference_scan, resolve_backend

# Back-compat alias: property/kernel tests exercise the oracle scan directly.
_scan = reference_scan
_col_ok = col_ok_mask


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AssignResult:
    assign: jax.Array        # (B,) int32 — new a(i)
    rho: jax.Array           # (B,) float32 — similarity to the winner
    n_candidates: jax.Array  # (B,) int32 — |Z_i| (CPR numerator)
    mult: jax.Array          # () float32 — multiply-adds the CPU algo executes
    changed: jax.Array       # (B,) bool — assignment changed

    def tree_flatten(self):
        return (self.assign, self.rho, self.n_candidates, self.mult, self.changed), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)


def _finalize(sims_masked, prev_assign, rho_self):
    """Sequential 'if ρ_j > ρ_max' semantics, vectorised."""
    best_j = jnp.argmax(sims_masked, axis=1).astype(jnp.int32)
    best = jnp.take_along_axis(sims_masked, best_j[:, None], axis=1)[:, 0]
    improve = best > rho_self
    assign = jnp.where(improve, best_j, prev_assign)
    rho = jnp.where(improve, best, rho_self)
    return assign, rho


def _nt_tail(docs: SparseDocs, t_th) -> jax.Array:
    """(B,) — (ntH)_i: live tuples with term id >= t_th."""
    return jnp.sum((docs.ids >= t_th) & docs.row_mask(), axis=1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Algorithms.  Each takes the backend as its first argument.
# ---------------------------------------------------------------------------

def _mivi(bk, docs, index, prev_assign, rho_self, xstate, plan=None):
    """Alg. 1 — exact TAAT over the mean-inverted index, no filters."""
    no_icp = jnp.zeros_like(xstate)
    out = bk.accumulate(docs, index, no_icp, mode="exact", plan=plan)
    assign, rho = _finalize(out["sims"], prev_assign, rho_self)
    k = index.k
    return AssignResult(assign, rho,
                        n_candidates=jnp.full(assign.shape, k, jnp.int32),
                        mult=out["mult"], changed=assign != prev_assign)


def _icp(bk, docs, index, prev_assign, rho_self, xstate, plan=None):
    """Auxiliary filter only (Kaukoranta+): skip invariant centroids for
    'more similar' objects."""
    out = bk.accumulate(docs, index, xstate, mode="exact", plan=plan)
    col_ok = col_ok_mask(index, xstate)
    sims = jnp.where(col_ok, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    n_cand = jnp.sum(col_ok, axis=1).astype(jnp.int32)
    return AssignResult(assign, rho, n_cand, out["mult"], assign != prev_assign)


def _es_core(bk, docs, index, prev_assign, rho_self, xstate, plan=None):
    """ES upper bound + optional ICP: Algs. 2/3 (and 4/5 with scaling)."""
    out = bk.accumulate(docs, index, xstate, mode="esicp", plan=plan)
    v_th = index.params.v_th
    col_ok = col_ok_mask(index, xstate)
    survivors, n_cand = bk.es_filter(out["rho12"], out["y"], rho_self,
                                     col_ok, v_th)
    sims = jnp.where(survivors, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    # Verification phase cost: |Z_i| exact Region-3 partials, (ntH)_i mults each.
    verify_mult = jnp.sum(n_cand.astype(jnp.float32) * _nt_tail(docs, index.params.t_th))
    return AssignResult(assign, rho, n_cand, out["mult"] + verify_mult,
                        assign != prev_assign)


def _esicp(bk, docs, index, prev_assign, rho_self, xstate, plan=None):
    return _es_core(bk, docs, index, prev_assign, rho_self, xstate, plan)


def _es(bk, docs, index, prev_assign, rho_self, xstate, plan=None):
    """Ablation: ES main filter without ICP (App. D)."""
    return _es_core(bk, docs, index, prev_assign, rho_self,
                    jnp.zeros_like(xstate), plan)


def _ta_icp(bk, docs, index, prev_assign, rho_self, xstate, plan=None):
    """TA-ICP (App. F-A): per-object threshold v_ta = ρ_max / ||x||_1."""
    l1 = jnp.sum(docs.vals, axis=1)                       # ||x_i||_1 (vals >= 0)
    # ρ_max = -inf encodes "no history" (iteration 1): clamp to 0 so the
    # threshold degenerates to v_ta = 0 (everything exact, nothing pruned)
    # instead of poisoning the bound with 0·(-inf) = NaN.
    v_ta = jnp.maximum(rho_self, 0.0) / jnp.maximum(l1, 1e-12)
    out = bk.accumulate(docs, index, xstate, mode="ta", v_ta=v_ta,
                        plan=plan)
    col_ok = col_ok_mask(index, xstate)
    ub = out["rho12"] + out["y"] * v_ta[:, None]
    # G_(ta) line 10: centroids with zero partial similarity are skipped —
    # their bound v_ta·y <= v_ta·||x||_1 = ρ_max can never strictly win.
    survivors = (out["rho12"] > 0.0) & (ub > rho_self[:, None]) & col_ok
    sims = jnp.where(survivors, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    n_cand = jnp.sum(survivors, axis=1).astype(jnp.int32)
    verify_mult = jnp.sum(n_cand.astype(jnp.float32) * _nt_tail(docs, index.params.t_th))
    return AssignResult(assign, rho, n_cand, out["mult"] + verify_mult,
                        assign != prev_assign)


def _cs_icp(bk, docs, index, prev_assign, rho_self, xstate, plan=None):
    """CS-ICP (App. F-B): Cauchy–Schwarz bound on the tail subspace."""
    tail_mask = (docs.ids >= index.params.t_th) & docs.row_mask()
    x_tail_l2 = jnp.sqrt(jnp.sum(jnp.where(tail_mask, docs.vals, 0.0) ** 2, axis=1))
    out = bk.accumulate(docs, index, xstate, mode="cs", plan=plan)
    col_ok = col_ok_mask(index, xstate)
    ub = out["rho1"] + x_tail_l2[:, None] * jnp.sqrt(out["sq"])
    survivors = (ub > rho_self[:, None]) & col_ok
    sims = jnp.where(survivors, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    n_cand = jnp.sum(survivors, axis=1).astype(jnp.int32)
    verify_mult = jnp.sum(n_cand.astype(jnp.float32) * _nt_tail(docs, index.params.t_th))
    return AssignResult(assign, rho, n_cand, out["mult"] + verify_mult,
                        assign != prev_assign)


ALGORITHMS = {
    "mivi": _mivi,
    "icp": _icp,
    "es": _es,
    "esicp": _esicp,
    "ta-icp": _ta_icp,
    "cs-icp": _cs_icp,
}


def assign_batch(algo: str, backend, docs: SparseDocs, index: MeanIndex,
                 prev_assign: jax.Array, rho_self: jax.Array,
                 xstate: jax.Array, plan=None) -> AssignResult:
    """Un-jitted dispatch — the traceable core shared by ``assignment_step``
    and the fused epoch in :mod:`repro.core.lloyd`.

    ``plan`` is the backend's prepared epoch-invariant cache
    (``Backend.prepare``) for exactly these ``docs``; None is always valid.
    """
    if algo not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algo!r}; one of {sorted(ALGORITHMS)}")
    bk = resolve_backend(backend)
    return ALGORITHMS[algo](bk, docs, index, prev_assign, rho_self, xstate,
                            plan)


@partial(jax.jit, static_argnames=("algo", "backend"))
def assignment_step(algo: str, docs: SparseDocs, index: MeanIndex,
                    prev_assign: jax.Array, rho_self: jax.Array,
                    xstate: jax.Array, backend: str = "reference",
                    plan=None) -> AssignResult:
    """One assignment step over a batch of objects.

    prev_assign: (B,) int32 — a(i) from the previous iteration.
    rho_self:    (B,) float32 — ρ_{a(i)}^{[r-1]}, refreshed at the last update
                 step (Alg. 6 lines 6–7), the shared pruning threshold ρ_max.
    xstate:      (B,) bool — Eq. (5) 'more similar' flag for the ICP filter.
    backend:     'reference' | 'pallas' | 'auto' (see core/backends.py).
    plan:        optional prepared kernel plan for these docs
                 (``Backend.prepare``; see kernels/plan.py).
    """
    return assign_batch(algo, backend, docs, index, prev_assign, rho_self,
                        xstate, plan)
