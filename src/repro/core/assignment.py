"""Assignment step for all compared algorithms (paper Algs. 1–5, App. F).

The algorithms here are pure selection logic: they consume accumulators
(exact similarities, region-wise partial sums, survivor masks) produced by a
pluggable :class:`repro.core.backends.Backend` — ``reference`` (the TAAT
``lax.scan``, the paper's MIVI loop order and this repo's exactness oracle)
or ``pallas`` (the TPU kernels in :mod:`repro.kernels.ops`, interpret mode
off-TPU).  See backends.py / DESIGN.md §5 for the split.

Exactness contract (tested): every algorithm returns *identical* assignments
to MIVI from the same state, under every backend.  Filters only change the
Mult/CPR diagnostics, which are counted as the paper counts them — the
number of multiply-adds a CPU implementation would execute, i.e. pairs
(object-term, posting-entry) actually visited.

Tie policy (paper Algs. 1/2 line "if ρ_j > ρ_max"): strict improvement over
the refreshed self-similarity; among equal improvers the lowest centroid ID
wins (sequential scan order == jnp.argmax first-occurrence).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.sparse import SparseDocs
from repro.core.meanindex import (MeanIndex, doc_sketch, sketch_group_width,
                                  sketch_size)
from repro.core.backends import col_ok_mask, reference_scan, resolve_backend
from repro.core.update import n_ub_groups, ub_group_of, ub_group_size

# Back-compat alias: property/kernel tests exercise the oracle scan directly.
_scan = reference_scan
_col_ok = col_ok_mask


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AssignResult:
    assign: jax.Array        # (B,) int32 — new a(i)
    rho: jax.Array           # (B,) float32 — similarity to the winner
    n_candidates: jax.Array  # (B,) int32 — |Z_i| (CPR numerator)
    mult: jax.Array          # () float32 — multiply-adds the CPU algo executes
    changed: jax.Array       # (B,) bool — assignment changed
    ub: jax.Array            # (B, G) float32 — refreshed per-bound-group
    #                          upper bounds on the best non-assigned
    #                          similarity (bounds modes; other algorithms
    #                          pass the caller's value through).  G =
    #                          n_ub_groups(k), see core/update.py.

    def tree_flatten(self):
        return (self.assign, self.rho, self.n_candidates, self.mult,
                self.changed, self.ub), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)


def _finalize(sims_masked, prev_assign, rho_self):
    """Sequential 'if ρ_j > ρ_max' semantics, vectorised."""
    best_j = jnp.argmax(sims_masked, axis=1).astype(jnp.int32)
    best = jnp.take_along_axis(sims_masked, best_j[:, None], axis=1)[:, 0]
    improve = best > rho_self
    assign = jnp.where(improve, best_j, prev_assign)
    rho = jnp.where(improve, best, rho_self)
    return assign, rho


def _nt_tail(docs: SparseDocs, t_th) -> jax.Array:
    """(B,) — (ntH)_i: live tuples with term id >= t_th."""
    return jnp.sum((docs.ids >= t_th) & docs.row_mask(), axis=1).astype(jnp.int32)


def default_ub(rho_self: jax.Array, k: int) -> jax.Array:
    """(B, G) 'no bound known' upper bounds: +inf (never prune, never loosen).

    Dead/padding rows follow the ρ_self = 0 convention in the *state* (see
    core/update.py init), but as an algorithm input +inf is always sound.
    """
    return jnp.full((rho_self.shape[0], n_ub_groups(k)), jnp.inf, jnp.float32)


def _second_best(sims: jax.Array, assign: jax.Array) -> jax.Array:
    """(B,) — max_{j != assign_i} sims[i, j]: the tight bound refresh."""
    cols = jnp.arange(sims.shape[1], dtype=jnp.int32)[None, :]
    masked = jnp.where(cols == assign[:, None], -jnp.inf, sims)
    return jnp.max(masked, axis=1)


def _group_bounds(b: jax.Array, assign: jax.Array, k: int) -> jax.Array:
    """(B, G) — per-bound-group max of the per-centroid bound matrix ``b``
    (B, K), with each object's ASSIGNED centroid excluded (the group bound
    is on the best *non-assigned* similarity).  The ragged final group pads
    with -inf, so phantom centroids never inflate a bound; a singleton
    group holding only the assigned centroid refreshes to -inf — soundly
    'nothing to find here' (non-finite, so drift never loosens it)."""
    cols = jnp.arange(k, dtype=jnp.int32)[None, :]
    masked = jnp.where(cols == assign[:, None], -jnp.inf, b)
    gsz = ub_group_size(k)
    g = n_ub_groups(k)
    masked = jnp.pad(masked, ((0, 0), (0, g * gsz - k)),
                     constant_values=-jnp.inf)
    return jnp.max(masked.reshape(masked.shape[0], g, gsz), axis=2)


def _sketch_pairs(docs: SparseDocs, index: MeanIndex) -> jax.Array:
    """(B, K) f32 — sketch-product multiplications per (object, centroid).

    The paper's Mult convention counts pairs actually visited; a sparse
    implementation of the sketch product Σ_g ||x_g||·||c_g|| multiplies only
    groups where BOTH sketches are nonzero — a short document touches at
    most nnz_i groups, so the sketch check costs ≤ min(nnz_i, S) per
    centroid, never the dense S.  Backend-independent by construction
    (shared ``doc_sketch`` + the index's ``sketch_t``), so Mult parity
    across backends is preserved bit-for-bit.
    """
    dsk = doc_sketch(docs.ids, docs.vals, index.dim) > 0.0
    csk = index.sketch_t > 0.0
    return jnp.dot(dsk.astype(jnp.float32), csk.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


# The compound mode refines the ES bound with a Region-3 sketch check only
# when the crude bound sits within striking distance of the threshold:
# rho12 + BETA·y·v_th <= ρ_self.  Fat-margin survivors are real candidates
# that no bound refinement can prune (measured: ~0 prune rate), so paying
# the sketch check on them is a guaranteed net loss; thin-margin survivors
# are exactly where the per-group Cauchy–Schwarz bound can beat y·v_th.
SKETCH_MARGIN_BETA = 0.5


def _region3_bound(docs: SparseDocs, index: MeanIndex):
    """Sketch-refined Region-3 bound: ((B, K) bound, (B, K) check cost).

    The block-vector sketch applied *within* the index's region structure
    (sketch × index regions): per-group L2 norms of the document tail
    (ids >= t_th) against per-group norms of each centroid's Region-3
    entries (id >= t_th and v < v_th).  Per-group Cauchy–Schwarz bounds the
    exact Region-3 partial — usually far tighter than the paper's y·v_th,
    which prices every Region-3 entry at the threshold.  The cost twin
    counts group pairs where both sketches are live (the sparse-product
    convention of :func:`_sketch_pairs`).  Shared jnp code on both backends,
    so Mult parity is bitwise.
    """
    d = index.dim
    g = sketch_group_width(d)
    s = sketch_size(d)
    t_th = index.params.t_th
    v_th = index.params.v_th
    seg = jnp.clip(docs.ids.astype(jnp.int32) // g, 0, s - 1)
    tv = jnp.where((docs.ids >= t_th) & docs.row_mask(), docs.vals, 0.0)
    dsk = jnp.sqrt(jax.vmap(
        lambda sg, v: jax.ops.segment_sum(v * v, sg, num_segments=s))(seg, tv))
    rows = jnp.arange(d, dtype=jnp.int32)
    r3 = jnp.where((rows[:, None] >= t_th) & (index.means_t < v_th),
                   index.means_t, 0.0)
    csk = jnp.sqrt(jax.ops.segment_sum(r3 * r3, rows // g, num_segments=s))
    bound = jnp.dot(dsk, csk, preferred_element_type=jnp.float32)
    pairs = jnp.dot((dsk > 0.0).astype(jnp.float32),
                    (csk > 0.0).astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    return bound, pairs


# ---------------------------------------------------------------------------
# Algorithms.  Each takes the backend as its first argument.
# ---------------------------------------------------------------------------

def _mivi(bk, docs, index, prev_assign, rho_self, xstate, plan=None, ub=None):
    """Alg. 1 — exact TAAT over the mean-inverted index, no filters."""
    ub = default_ub(rho_self, index.k) if ub is None else ub
    no_icp = jnp.zeros_like(xstate)
    out = bk.accumulate(docs, index, no_icp, mode="exact", plan=plan)
    assign, rho = _finalize(out["sims"], prev_assign, rho_self)
    k = index.k
    return AssignResult(assign, rho,
                        n_candidates=jnp.full(assign.shape, k, jnp.int32),
                        mult=out["mult"], changed=assign != prev_assign,
                        ub=ub)


def _icp(bk, docs, index, prev_assign, rho_self, xstate, plan=None, ub=None):
    """Auxiliary filter only (Kaukoranta+): skip invariant centroids for
    'more similar' objects."""
    ub = default_ub(rho_self, index.k) if ub is None else ub
    out = bk.accumulate(docs, index, xstate, mode="exact", plan=plan)
    col_ok = col_ok_mask(index, xstate)
    sims = jnp.where(col_ok, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    n_cand = jnp.sum(col_ok, axis=1).astype(jnp.int32)
    return AssignResult(assign, rho, n_cand, out["mult"],
                        assign != prev_assign, ub)


def _es_core(bk, docs, index, prev_assign, rho_self, xstate, plan=None,
             ub=None):
    """ES upper bound + optional ICP: Algs. 2/3 (and 4/5 with scaling)."""
    ub = default_ub(rho_self, index.k) if ub is None else ub
    out = bk.accumulate(docs, index, xstate, mode="esicp", plan=plan)
    v_th = index.params.v_th
    col_ok = col_ok_mask(index, xstate)
    survivors, n_cand = bk.es_filter(out["rho12"], out["y"], rho_self,
                                     col_ok, v_th)
    sims = jnp.where(survivors, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    # Verification phase cost: |Z_i| exact Region-3 partials, (ntH)_i mults each.
    verify_mult = jnp.sum(n_cand.astype(jnp.float32) * _nt_tail(docs, index.params.t_th))
    return AssignResult(assign, rho, n_cand, out["mult"] + verify_mult,
                        assign != prev_assign, ub)


def _esicp(bk, docs, index, prev_assign, rho_self, xstate, plan=None, ub=None):
    return _es_core(bk, docs, index, prev_assign, rho_self, xstate, plan, ub)


def _es(bk, docs, index, prev_assign, rho_self, xstate, plan=None, ub=None):
    """Ablation: ES main filter without ICP (App. D)."""
    return _es_core(bk, docs, index, prev_assign, rho_self,
                    jnp.zeros_like(xstate), plan, ub)


def _ta_icp(bk, docs, index, prev_assign, rho_self, xstate, plan=None,
            ub=None):
    """TA-ICP (App. F-A): per-object threshold v_ta = ρ_max / ||x||_1."""
    ub_in = default_ub(rho_self, index.k) if ub is None else ub
    l1 = jnp.sum(docs.vals, axis=1)                       # ||x_i||_1 (vals >= 0)
    # ρ_max = -inf encodes "no history" (iteration 1): clamp to 0 so the
    # threshold degenerates to v_ta = 0 (everything exact, nothing pruned)
    # instead of poisoning the bound with 0·(-inf) = NaN.
    v_ta = jnp.maximum(rho_self, 0.0) / jnp.maximum(l1, 1e-12)
    out = bk.accumulate(docs, index, xstate, mode="ta", v_ta=v_ta,
                        plan=plan)
    col_ok = col_ok_mask(index, xstate)
    ub = out["rho12"] + out["y"] * v_ta[:, None]
    # G_(ta) line 10: centroids with zero partial similarity are skipped —
    # their bound v_ta·y <= v_ta·||x||_1 = ρ_max can never strictly win.
    survivors = (out["rho12"] > 0.0) & (ub > rho_self[:, None]) & col_ok
    sims = jnp.where(survivors, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    n_cand = jnp.sum(survivors, axis=1).astype(jnp.int32)
    verify_mult = jnp.sum(n_cand.astype(jnp.float32) * _nt_tail(docs, index.params.t_th))
    return AssignResult(assign, rho, n_cand, out["mult"] + verify_mult,
                        assign != prev_assign, ub_in)


def _cs_icp(bk, docs, index, prev_assign, rho_self, xstate, plan=None,
            ub=None):
    """CS-ICP (App. F-B): Cauchy–Schwarz bound on the tail subspace."""
    ub_in = default_ub(rho_self, index.k) if ub is None else ub
    tail_mask = (docs.ids >= index.params.t_th) & docs.row_mask()
    x_tail_l2 = jnp.sqrt(jnp.sum(jnp.where(tail_mask, docs.vals, 0.0) ** 2, axis=1))
    out = bk.accumulate(docs, index, xstate, mode="cs", plan=plan)
    col_ok = col_ok_mask(index, xstate)
    ub = out["rho1"] + x_tail_l2[:, None] * jnp.sqrt(out["sq"])
    survivors = (ub > rho_self[:, None]) & col_ok
    sims = jnp.where(survivors, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    n_cand = jnp.sum(survivors, axis=1).astype(jnp.int32)
    verify_mult = jnp.sum(n_cand.astype(jnp.float32) * _nt_tail(docs, index.params.t_th))
    return AssignResult(assign, rho, n_cand, out["mult"] + verify_mult,
                        assign != prev_assign, ub_in)


# ---------------------------------------------------------------------------
# Bound-maintenance / sketch-gated modes (ISSUE 7; DESIGN.md §11).
#
# All three compute the FULL exact similarity matrix and finalize over it
# unmasked — assignments are bit-identical to `mivi` per backend by
# construction, unconditionally.  The bounds/sketch machinery drives only
# the honest Mult / |Z_i| accounting (what a CPU implementation exploiting
# the same pruning would pay) and the maintained `ub` state.
# ---------------------------------------------------------------------------

def _bounds(bk, docs, index, prev_assign, rho_self, xstate, plan=None,
            ub=None):
    """Cosine-adapted Elkan/Hamerly bound maintenance (arxiv_2107.04074),
    per centroid GROUP (Yinyang-style: core/update.py's UB_GROUPS tiers).

    A bound group whose drift-loosened upper bound is <= the object's
    refreshed ρ_self cannot hold a strict improver, so the CPU algorithm
    skips every posting entry of that group's centroids; an object with NO
    active group skips the scan outright.  Active groups pay their exact
    gather cost and refresh to the true per-group max non-assigned
    similarity; skipped groups carry the loosened bound forward
    (update_step loosens each group by its own centroids' worst drift).
    """
    k = index.k
    ub = default_ub(rho_self, k) if ub is None else ub
    no_icp = jnp.zeros_like(xstate)
    out = bk.accumulate(docs, index, no_icp, mode="exact", plan=plan,
                        with_counts=True)
    assign, rho = _finalize(out["sims"], prev_assign, rho_self)
    ga = ub > rho_self[:, None]                           # (B, G) group active
    pa = jnp.take(ga, ub_group_of(k), axis=1)             # (B, K) per-centroid
    mult = jnp.sum(jnp.where(pa, out["counts"], 0.0))
    n_cand = jnp.sum(pa, axis=1).astype(jnp.int32)
    ub_new = jnp.where(ga, _group_bounds(out["sims"], assign, k), ub)
    return AssignResult(assign, rho, n_cand, mult, assign != prev_assign,
                        ub_new)


def _sketch(bk, docs, index, prev_assign, rho_self, xstate, plan=None,
            ub=None):
    """Block-vector sketch pre-filter (arxiv_2108.00895).

    A (B, S) x (S, K) sketch similarity — an upper bound on the exact
    cosine for non-negative data — gates the exact pass: only centroids
    whose sketch bound beats ρ_self are scanned exactly.  The sketch check
    itself is charged sparsely (:func:`_sketch_pairs`): a document's sketch
    has at most nnz_i live groups, so the pre-filter costs a fraction of
    the exact row scan it screens.  Rows with ρ_self <= 0 cannot prune
    (every bound beats the threshold), so the CPU algorithm skips the
    sketch pass for them and pays the plain MIVI cost — iteration-1 Mult
    is exactly MIVI's.
    """
    ub = default_ub(rho_self, index.k) if ub is None else ub
    no_icp = jnp.zeros_like(xstate)
    out = bk.accumulate(docs, index, no_icp, mode="exact", plan=plan,
                        with_counts=True)
    sk_sims = bk.sketch_sim(docs, index, plan=plan)
    assign, rho = _finalize(out["sims"], prev_assign, rho_self)
    k = index.k
    rho_pos = rho_self > 0.0
    surv = sk_sims > rho_self[:, None]
    gathered = jnp.sum(jnp.where(surv, out["counts"], 0.0), axis=1)
    full = jnp.sum(out["counts"], axis=1)
    sk_cost = jnp.sum(_sketch_pairs(docs, index), axis=1)
    mult = jnp.sum(jnp.where(rho_pos, sk_cost + gathered, full))
    n_cand = jnp.where(rho_pos, jnp.sum(surv, axis=1), k).astype(jnp.int32)
    return AssignResult(assign, rho, n_cand, mult, assign != prev_assign, ub)


def _bounds_esicp(bk, docs, index, prev_assign, rho_self, xstate, plan=None,
                  ub=None):
    """Compounded pruning: bounds x index regions (ES + ICP) x sketch.

    Gate order a CPU implementation would run, cheapest first:
      1. bounds  — drift-loosened ub <= ρ_self: skip the object outright;
      2. ICP     — invariant centroids for 'more similar' objects (free:
                   reuses last iteration's membership deltas);
      3. ES      — Region-1/2 partial + Region-3 L1 bound (the paper's
                   main filter, at its EstParams operating point);
      4. sketch  — margin-gated Region-3 sketch refinement: thin-margin
                   ES survivors get the tighter per-group Cauchy–Schwarz
                   bound before their verify window is paid;
      5. verify  — exact Region-3 partial for the |Z_i| final survivors.
    The sketch layer composes *inside* the region structure rather than in
    front of it: a full-vector sketch check costs about as much as the ES
    Region-1/2 scan it would gate (measured), so the only placement with
    positive expected value is refining the crude y·v_th tail bound — and
    only where the crude margin is thin (SKETCH_MARGIN_BETA).

    The refreshed ub is assembled honestly from what each gate actually
    knows per centroid (exact sim / refined bound / ES bound / ρ_self for
    ICP-skipped columns) — never from similarities a pruned scan would not
    have computed.
    """
    k = index.k
    ub = default_ub(rho_self, k) if ub is None else ub
    out = bk.accumulate(docs, index, xstate, mode="esicp", plan=plan,
                        with_counts=True)
    v_th = index.params.v_th
    col_ok = col_ok_mask(index, xstate)
    ga = ub > rho_self[:, None]                           # (B, G) group active
    pa = jnp.take(ga, ub_group_of(k), axis=1)             # (B, K) per-centroid
    gate = col_ok & pa
    crude, _ = bk.es_filter(out["rho12"], out["y"], rho_self, gate, v_th)
    r3_bound, r3_pairs = _region3_bound(docs, index)
    es_ub = out["rho12"] + out["y"] * v_th
    ref_ub = out["rho12"] + jnp.minimum(out["y"] * v_th, r3_bound)
    checked = crude & (out["rho12"] + SKETCH_MARGIN_BETA * out["y"] * v_th
                       <= rho_self[:, None])
    survivors = crude & jnp.where(checked, ref_ub > rho_self[:, None], True)
    n_cand = jnp.sum(survivors, axis=1).astype(jnp.int32)
    assign, rho = _finalize(out["sims"], prev_assign, rho_self)
    gather_mult = jnp.sum(jnp.where(gate, out["counts"], 0.0))
    sketch_mult = jnp.sum(jnp.where(checked, r3_pairs, 0.0))
    verify_mult = jnp.sum(n_cand.astype(jnp.float32)
                          * _nt_tail(docs, index.params.t_th))
    # Honest per-centroid bound from whichever gate pruned it (centroids in
    # inactive groups keep +inf here; their group's old bound is retained
    # by the jnp.where(ga, ...) below, so the +inf never escapes).
    b = jnp.where(survivors, out["sims"], jnp.inf)
    b = jnp.minimum(b, jnp.where(checked, ref_ub, jnp.inf))
    b = jnp.minimum(b, jnp.where(gate, es_ub, jnp.inf))
    b = jnp.minimum(b, jnp.where(pa & ~col_ok, rho_self[:, None], jnp.inf))
    ub_new = jnp.where(ga, _group_bounds(b, assign, k), ub)
    return AssignResult(assign, rho, n_cand,
                        gather_mult + sketch_mult + verify_mult,
                        assign != prev_assign, ub_new)


ALGORITHMS = {
    "mivi": _mivi,
    "icp": _icp,
    "es": _es,
    "esicp": _esicp,
    "ta-icp": _ta_icp,
    "cs-icp": _cs_icp,
    "bounds": _bounds,
    "sketch": _sketch,
    "bounds-esicp": _bounds_esicp,
}


def assign_batch(algo: str, backend, docs: SparseDocs, index: MeanIndex,
                 prev_assign: jax.Array, rho_self: jax.Array,
                 xstate: jax.Array, plan=None, ub=None) -> AssignResult:
    """Un-jitted dispatch — the traceable core shared by ``assignment_step``
    and the fused epoch in :mod:`repro.core.lloyd`.

    ``plan`` is the backend's prepared epoch-invariant cache
    (``Backend.prepare``) for exactly these ``docs``; None is always valid.
    ``ub`` is the maintained (B, G) per-object, per-bound-group upper bound
    (bounds modes); None means 'no bound known' (+inf — never prunes).
    """
    if algo not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algo!r}; one of {sorted(ALGORITHMS)}")
    bk = resolve_backend(backend)
    return ALGORITHMS[algo](bk, docs, index, prev_assign, rho_self, xstate,
                            plan, ub)


@partial(jax.jit, static_argnames=("algo", "backend"))
def assignment_step(algo: str, docs: SparseDocs, index: MeanIndex,
                    prev_assign: jax.Array, rho_self: jax.Array,
                    xstate: jax.Array, backend: str = "reference",
                    plan=None, ub=None) -> AssignResult:
    """One assignment step over a batch of objects.

    prev_assign: (B,) int32 — a(i) from the previous iteration.
    rho_self:    (B,) float32 — ρ_{a(i)}^{[r-1]}, refreshed at the last update
                 step (Alg. 6 lines 6–7), the shared pruning threshold ρ_max.
    xstate:      (B,) bool — Eq. (5) 'more similar' flag for the ICP filter.
    backend:     'reference' | 'pallas' | 'auto' (see core/backends.py).
    plan:        optional prepared kernel plan for these docs
                 (``Backend.prepare``; see kernels/plan.py).
    ub:          optional (B, G) maintained per-group upper bound (bounds
                 modes; G = n_ub_groups(k), core/update.py).
    """
    return assign_batch(algo, backend, docs, index, prev_assign, rho_self,
                        xstate, plan, ub)
