"""Assignment step for all compared algorithms (paper Algs. 1–5, App. F).

Every algorithm is expressed as a term-at-a-time (TAAT) scan over the padded
object tuples — the paper's MIVI loop order (Alg. 1 lines 1–5), which it shows
is the architecture-friendly orientation.  On TPU each scan step is one
(B,)-gather of a posting row ξ_s block plus a rank-1 multiply-add on the
(B, K) accumulator: no data-dependent branches, shared thresholds as masks.

Exactness contract (tested): every algorithm returns *identical* assignments
to MIVI from the same state.  Filters only change the Mult/CPR diagnostics,
which are counted as the paper counts them — the number of multiply-adds a
CPU implementation would execute, i.e. pairs (object-term, posting-entry)
actually visited.

Tie policy (paper Algs. 1/2 line "if ρ_j > ρ_max"): strict improvement over
the refreshed self-similarity; among equal improvers the lowest centroid ID
wins (sequential scan order == jnp.argmax first-occurrence).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.sparse import SparseDocs
from repro.core.meanindex import MeanIndex


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AssignResult:
    assign: jax.Array        # (B,) int32 — new a(i)
    rho: jax.Array           # (B,) float32 — similarity to the winner
    n_candidates: jax.Array  # (B,) int32 — |Z_i| (CPR numerator)
    mult: jax.Array          # () float32 — multiply-adds the CPU algo executes
    changed: jax.Array       # (B,) bool — assignment changed

    def tree_flatten(self):
        return (self.assign, self.rho, self.n_candidates, self.mult, self.changed), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)


def _col_ok(index: MeanIndex, xstate: jax.Array) -> jax.Array:
    """(B, K) — centroids the ICP filter allows: moving ones always; invariant
    ones only for objects that are not 'more similar' (Eq. 5)."""
    return index.moving[None, :] | ~xstate[:, None]


def _finalize(sims_masked, prev_assign, rho_self):
    """Sequential 'if ρ_j > ρ_max' semantics, vectorised."""
    best_j = jnp.argmax(sims_masked, axis=1).astype(jnp.int32)
    best = jnp.take_along_axis(sims_masked, best_j[:, None], axis=1)[:, 0]
    improve = best > rho_self
    assign = jnp.where(improve, best_j, prev_assign)
    rho = jnp.where(improve, best, rho_self)
    return assign, rho


# ---------------------------------------------------------------------------
# TAAT scan cores.  Each returns the per-object accumulators + a mult counter.
# ---------------------------------------------------------------------------

def _scan(docs: SparseDocs, index: MeanIndex, xstate, *, mode: str,
          v_ta: jax.Array | None = None):
    """One fused TAAT pass.

    mode:
      'exact'  -> sims, mult                                  (MIVI / ICP)
      'esicp'  -> sims, rho12, y, mult1+2 (region-aware)      (ES / ES-ICP)
      'ta'     -> sims, rho12', y', mult                      (TA-ICP)
      'cs'     -> sims, rho1, sq (Σ v² over tail), mult       (CS-ICP)

    ``sims`` is always the full exact similarity (reference semantics); the
    CPU algorithm would only compute it for survivors — that cost is what the
    verify-mult term in the caller accounts for.
    """
    b, p = docs.ids.shape
    k = index.k
    t_th = index.params.t_th
    v_th = index.params.v_th
    means_t = index.means_t
    col_ok = _col_ok(index, xstate)          # (B, K) — ICP lane mask
    f32 = jnp.float32

    def body(carry, xs):
        idp, vp = xs                          # (B,), (B,)
        rows = means_t[idp]                   # (B, K) posting block
        live = vp != 0.0
        nz = (rows > 0) & col_ok & live[:, None]
        contrib = vp[:, None] * rows
        sims = carry["sims"] + contrib
        out = {"sims": sims}
        if mode == "exact":
            out["mult"] = carry["mult"] + jnp.sum(nz, dtype=f32)
        elif mode == "esicp":
            tail = (idp >= t_th)[:, None]     # (B, 1)
            hi = rows >= v_th
            exact_mask = jnp.where(tail, hi, True)
            out["rho12"] = carry["rho12"] + jnp.where(exact_mask, contrib, 0.0)
            out["y"] = carry["y"] + jnp.where(tail & ~hi, vp[:, None], 0.0)
            out["mult"] = carry["mult"] + jnp.sum(nz & exact_mask, dtype=f32)
        elif mode == "ta":
            tail = (idp >= t_th)[:, None]
            hi = rows >= v_ta[:, None]        # per-object threshold (Eq. 16)
            exact_mask = jnp.where(tail, hi, True)
            out["rho12"] = carry["rho12"] + jnp.where(exact_mask, contrib, 0.0)
            out["y"] = carry["y"] + jnp.where(tail & ~hi, vp[:, None], 0.0)
            # TA walks each sorted posting until v < v_ta: visits hi entries
            # plus one terminator comparison; mults are the hi entries.
            out["mult"] = carry["mult"] + jnp.sum(nz & exact_mask, dtype=f32)
        elif mode == "cs":
            tail = (idp >= t_th)[:, None]
            out["rho1"] = carry["rho1"] + jnp.where(tail, 0.0, contrib)
            out["sq"] = carry["sq"] + jnp.where(tail, rows * rows, 0.0)
            out["mult"] = carry["mult"] + jnp.sum(nz, dtype=f32)
        else:
            raise ValueError(mode)
        return out, None

    carry = {"sims": jnp.zeros((b, k), f32), "mult": jnp.zeros((), f32)}
    if mode == "esicp" or mode == "ta":
        carry["rho12"] = jnp.zeros((b, k), f32)
        carry["y"] = jnp.zeros((b, k), f32)
    elif mode == "cs":
        carry["rho1"] = jnp.zeros((b, k), f32)
        carry["sq"] = jnp.zeros((b, k), f32)
    out, _ = jax.lax.scan(body, carry, (docs.ids.T, docs.vals.T))
    return out


def _nt_tail(docs: SparseDocs, t_th) -> jax.Array:
    """(B,) — (ntH)_i: live tuples with term id >= t_th."""
    return jnp.sum((docs.ids >= t_th) & docs.row_mask(), axis=1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Algorithms.
# ---------------------------------------------------------------------------

def _mivi(docs, index, prev_assign, rho_self, xstate):
    """Alg. 1 — exact TAAT over the mean-inverted index, no filters."""
    no_icp = jnp.zeros_like(xstate)
    out = _scan(docs, index, no_icp, mode="exact")
    assign, rho = _finalize(out["sims"], prev_assign, rho_self)
    k = index.k
    return AssignResult(assign, rho,
                        n_candidates=jnp.full(assign.shape, k, jnp.int32),
                        mult=out["mult"], changed=assign != prev_assign)


def _icp(docs, index, prev_assign, rho_self, xstate):
    """Auxiliary filter only (Kaukoranta+): skip invariant centroids for
    'more similar' objects."""
    out = _scan(docs, index, xstate, mode="exact")
    col_ok = _col_ok(index, xstate)
    sims = jnp.where(col_ok, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    n_cand = jnp.sum(col_ok, axis=1).astype(jnp.int32)
    return AssignResult(assign, rho, n_cand, out["mult"], assign != prev_assign)


def _es_core(docs, index, prev_assign, rho_self, xstate):
    """ES upper bound + optional ICP: Algs. 2/3 (and 4/5 with scaling)."""
    out = _scan(docs, index, xstate, mode="esicp")
    v_th = index.params.v_th
    col_ok = _col_ok(index, xstate)
    # Upper bound (Eq. 4): rho12 + y·v_th.  The paper's App.-A scaling removes
    # this multiply on CPU; on TPU it is a fused multiply-add — free either way.
    ub = out["rho12"] + out["y"] * v_th
    survivors = (ub > rho_self[:, None]) & col_ok
    sims = jnp.where(survivors, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    n_cand = jnp.sum(survivors, axis=1).astype(jnp.int32)
    # Verification phase cost: |Z_i| exact Region-3 partials, (ntH)_i mults each.
    verify_mult = jnp.sum(n_cand.astype(jnp.float32) * _nt_tail(docs, index.params.t_th))
    return AssignResult(assign, rho, n_cand, out["mult"] + verify_mult,
                        assign != prev_assign)


def _esicp(docs, index, prev_assign, rho_self, xstate):
    return _es_core(docs, index, prev_assign, rho_self, xstate)


def _es(docs, index, prev_assign, rho_self, xstate):
    """Ablation: ES main filter without ICP (App. D)."""
    return _es_core(docs, index, prev_assign, rho_self, jnp.zeros_like(xstate))


def _ta_icp(docs, index, prev_assign, rho_self, xstate):
    """TA-ICP (App. F-A): per-object threshold v_ta = ρ_max / ||x||_1."""
    l1 = jnp.sum(docs.vals, axis=1)                       # ||x_i||_1 (vals >= 0)
    # ρ_max = -inf encodes "no history" (iteration 1): clamp to 0 so the
    # threshold degenerates to v_ta = 0 (everything exact, nothing pruned)
    # instead of poisoning the bound with 0·(-inf) = NaN.
    v_ta = jnp.maximum(rho_self, 0.0) / jnp.maximum(l1, 1e-12)
    out = _scan(docs, index, xstate, mode="ta", v_ta=v_ta)
    col_ok = _col_ok(index, xstate)
    ub = out["rho12"] + out["y"] * v_ta[:, None]
    # G_(ta) line 10: centroids with zero partial similarity are skipped —
    # their bound v_ta·y <= v_ta·||x||_1 = ρ_max can never strictly win.
    survivors = (out["rho12"] > 0.0) & (ub > rho_self[:, None]) & col_ok
    sims = jnp.where(survivors, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    n_cand = jnp.sum(survivors, axis=1).astype(jnp.int32)
    verify_mult = jnp.sum(n_cand.astype(jnp.float32) * _nt_tail(docs, index.params.t_th))
    return AssignResult(assign, rho, n_cand, out["mult"] + verify_mult,
                        assign != prev_assign)


def _cs_icp(docs, index, prev_assign, rho_self, xstate):
    """CS-ICP (App. F-B): Cauchy–Schwarz bound on the tail subspace."""
    tail_mask = (docs.ids >= index.params.t_th) & docs.row_mask()
    x_tail_l2 = jnp.sqrt(jnp.sum(jnp.where(tail_mask, docs.vals, 0.0) ** 2, axis=1))
    out = _scan(docs, index, xstate, mode="cs")
    col_ok = _col_ok(index, xstate)
    ub = out["rho1"] + x_tail_l2[:, None] * jnp.sqrt(out["sq"])
    survivors = (ub > rho_self[:, None]) & col_ok
    sims = jnp.where(survivors, out["sims"], -jnp.inf)
    assign, rho = _finalize(sims, prev_assign, rho_self)
    n_cand = jnp.sum(survivors, axis=1).astype(jnp.int32)
    verify_mult = jnp.sum(n_cand.astype(jnp.float32) * _nt_tail(docs, index.params.t_th))
    return AssignResult(assign, rho, n_cand, out["mult"] + verify_mult,
                        assign != prev_assign)


ALGORITHMS = {
    "mivi": _mivi,
    "icp": _icp,
    "es": _es,
    "esicp": _esicp,
    "ta-icp": _ta_icp,
    "cs-icp": _cs_icp,
}


@partial(jax.jit, static_argnames=("algo",))
def assignment_step(algo: str, docs: SparseDocs, index: MeanIndex,
                    prev_assign: jax.Array, rho_self: jax.Array,
                    xstate: jax.Array) -> AssignResult:
    """One assignment step over a batch of objects.

    prev_assign: (B,) int32 — a(i) from the previous iteration.
    rho_self:    (B,) float32 — ρ_{a(i)}^{[r-1]}, refreshed at the last update
                 step (Alg. 6 lines 6–7), the shared pruning threshold ρ_max.
    xstate:      (B,) bool — Eq. (5) 'more similar' flag for the ICP filter.
    """
    if algo not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algo!r}; one of {sorted(ALGORITHMS)}")
    return ALGORITHMS[algo](docs, index, prev_assign, rho_self, xstate)
