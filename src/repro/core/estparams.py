"""EstParams — structural-parameter estimation (paper §V, App. B–C, Alg. 7).

Minimises J(s', v_h) = φ1 + φ2 + φ̃3, the approximate number of multiply-adds:

    φ1(s')    = Σ_{s<s'} df_s·mf_s                       (Region-1 exact cost)
    φ2(s',h)  = Σ_{s≥s'} df_s·(mfH)_{s,h}                (Region-2 exact cost)
    φ̃3(s',h)  = Σ_i (ntH)_{i,s'} · (K/e)^{Δρ̄/(ρ_a−ρ̄_i)}  (expected verify cost,
                exponential-family model of the similarity distribution,
                Eqs. 10–13 / 23–31)

with Δρ̄(i,s',h) = Σ_{p: id_p ≥ s'} u_p · Δv̄_{id_p,h} and
Δv̄_{s,h} = (1/K) Σ_k relu(v_h − v_{s,k})  (Eq. 39, counting absent centroids).

Hardware adaptation: the paper evaluates all s' via a descending recurrence
over a partial *object*-inverted index — a CPU-AFM trick to touch each
posting once.  On TPU the architecture-friendly evaluation is a dense grid:
suffix-sums over each object's (df-sorted) tuple positions give Δρ̄ for every
s' candidate in one vectorised pass, chunked over objects.  Same objective,
same minimiser; DESIGN.md §2 records the substitution.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse import SparseDocs
from repro.core.meanindex import StructuralParams, delta_v_bar, mfh_table


@dataclasses.dataclass(frozen=True)
class EstGrid:
    n_v: int = 24            # |V^[th]| candidates
    n_s: int = 48            # t_th candidates
    s_min_frac: float = 0.80  # s_(min) = frac · D (paper: t_th lands near 0.9 D)
    v_quantile_lo: float = 0.50
    v_quantile_hi: float = 0.999
    chunk: int = 2048        # objects per φ̃3 chunk


def _v_candidates(means_t: jax.Array, s_min: int, grid: EstGrid) -> jax.Array:
    """v_th candidates from quantiles of the positive tail-region values."""
    tail = means_t[s_min:]
    masked = jnp.where(tail > 0, tail, jnp.nan)   # static shape; zeros ignored
    qs = jnp.linspace(grid.v_quantile_lo, grid.v_quantile_hi, grid.n_v)
    cand = jnp.nanquantile(masked, qs)
    cand = jnp.where(jnp.isnan(cand), 1.0, cand)  # degenerate tail -> vacuous
    return jnp.maximum(cand, 1e-6)


@partial(jax.jit, static_argnames=("k",))
def _phi3_chunk(ids, vals, nnz, dvbar, colsum, rho_a, s_grid, *, k: int):
    """φ̃3 contribution of one object chunk → (S', H)."""
    c, p = ids.shape
    h = dvbar.shape[1]
    live = jnp.arange(p)[None, :] < nnz[:, None]
    u = jnp.where(live, vals, 0.0)

    w = u[:, :, None] * dvbar[ids]                      # (C, P, H)
    w = jnp.where(live[:, :, None], w, 0.0)
    suf = jnp.flip(jnp.cumsum(jnp.flip(w, 1), axis=1), 1)  # suffix sums
    suf = jnp.concatenate([suf, jnp.zeros((c, 1, h))], axis=1)

    rho_bar = jnp.sum(u * colsum[ids], axis=1) / k      # Eq. 32
    denom = jnp.maximum(rho_a - rho_bar, 1e-9)          # ρ_a(i) − ρ̄_i

    # p* = first tuple position with id >= s'  (ids ascend within a row)
    pstar = jnp.sum(live[:, :, None] & (ids[:, :, None] < s_grid[None, None, :]),
                    axis=1)                              # (C, S')
    nt_h = (nnz[:, None] - pstar).astype(jnp.float32)    # (ntH)_{i,s'}

    dr = jnp.take_along_axis(suf, pstar[:, :, None], axis=1)  # (C, S', H)
    x = dr / denom[:, None, None]
    log_ke = jnp.log(k / jnp.e)
    factor = jnp.minimum(jnp.exp(x * log_ke), float(k))  # K·Prob ≤ K
    return jnp.sum(nt_h[:, :, None] * factor, axis=0)    # (S', H)


def _est_tables(df: jax.Array, means_t: jax.Array, grid: EstGrid):
    """The corpus-independent half of Alg. 7: candidate grids + φ1/φ2 from
    the df/mean statistics, and the per-term tables φ̃3 consumes."""
    d = means_t.shape[0]
    s_min = int(grid.s_min_frac * d)
    s_grid = jnp.unique(jnp.linspace(s_min, d, grid.n_s).astype(jnp.int32))
    v_grid = _v_candidates(means_t, s_min, grid)

    mf = jnp.sum(means_t > 0, axis=1).astype(jnp.float32)
    dff = df.astype(jnp.float32)

    # φ1: prefix sums of df·mf
    c1 = jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(dff * mf)])
    phi1 = c1[s_grid]                                      # (S',)

    # φ2: suffix sums of df·mfH per candidate v_h
    mfh = mfh_table(means_t, v_grid).astype(jnp.float32)   # (D, H)
    sfx = jnp.flip(jnp.cumsum(jnp.flip(dff[:, None] * mfh, 0), axis=0), 0)
    sfx = jnp.concatenate([sfx, jnp.zeros((1, len(v_grid)))], axis=0)
    phi2 = sfx[s_grid]                                     # (S', H)

    dvbar = delta_v_bar(means_t, v_grid)                   # (D, H)
    colsum = jnp.sum(means_t, axis=1)                      # (D,)
    return s_grid, v_grid, phi1, phi2, dvbar, colsum


def _est_minimize(s_grid, v_grid, phi1, phi2, phi3):
    j_table = phi1[:, None] + phi2 + phi3
    flat = int(jnp.argmin(j_table))
    si, hi = np.unravel_index(flat, j_table.shape)
    params = StructuralParams(t_th=s_grid[si].astype(jnp.int32),
                              v_th=v_grid[hi].astype(jnp.float32))
    aux = {"J": j_table, "s_grid": s_grid, "v_grid": v_grid,
           "phi1": phi1, "phi2": phi2, "phi3": phi3}
    return params, aux


def estimate_params(docs: SparseDocs, df: jax.Array, means_t: jax.Array,
                    rho_self: jax.Array, *, k: int,
                    grid: EstGrid = EstGrid()) -> tuple[StructuralParams, dict]:
    """Returns the minimising (t_th, v_th) and an aux dict with the J table.

    rho_self: (N,) ρ_{a(i)} against the current means — the update step's
    refreshed self-similarities (Alg. 6), exactly what Alg. 7 consumes.
    """
    s_grid, v_grid, phi1, phi2, dvbar, colsum = _est_tables(df, means_t, grid)

    # φ̃3: chunked over objects
    n = docs.n_docs
    phi3 = jnp.zeros((len(s_grid), len(v_grid)))
    for start in range(0, n, grid.chunk):
        end = min(start + grid.chunk, n)
        phi3 = phi3 + _phi3_chunk(docs.ids[start:end], docs.vals[start:end],
                                  docs.nnz[start:end], dvbar, colsum,
                                  rho_self[start:end], s_grid, k=k)

    return _est_minimize(s_grid, v_grid, phi1, phi2, phi3)


def estimate_params_store(store, df: jax.Array, means_t: jax.Array,
                          rho_self: jax.Array, *, k: int,
                          grid: EstGrid = EstGrid()):
    """Alg. 7 over an out-of-core :class:`repro.sparse.DocStore`.

    φ1/φ2 need only the df/mean statistics; φ̃3 — already an object-chunked
    sum in the resident path — accumulates store chunk by store chunk, so
    the estimate uses the ENTIRE corpus without it ever being resident.
    Dead tail rows contribute exactly 0 (no live tuples ⇒ zero suffix sums
    and (ntH) = 0), so whole chunks are fed as-is.  A one-chunk store
    reproduces :func:`estimate_params` on the resident corpus bit for bit.

    rho_self: (store.n_rows,) — the streaming fit's refreshed ρ, pad rows
    at the 0 convention.
    """
    s_grid, v_grid, phi1, phi2, dvbar, colsum = _est_tables(df, means_t, grid)

    c = store.chunk_size
    phi3 = jnp.zeros((len(s_grid), len(v_grid)))
    for ci in range(store.n_chunks):
        cdocs = store.chunk(ci)
        rho_c = rho_self[ci * c:(ci + 1) * c]
        for start in range(0, c, grid.chunk):
            end = min(start + grid.chunk, c)
            phi3 = phi3 + _phi3_chunk(cdocs.ids[start:end],
                                      cdocs.vals[start:end],
                                      cdocs.nnz[start:end], dvbar, colsum,
                                      rho_c[start:end], s_grid, k=k)

    return _est_minimize(s_grid, v_grid, phi1, phi2, phi3)
