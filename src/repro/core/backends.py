"""Backend-pluggable clustering primitives (DESIGN.md §5).

The six algorithms in :mod:`repro.core.assignment` are pure selection logic
over a small set of accumulators (exact similarities, region-wise partial
sums, filter survivor masks), and the update phase (Alg. 6) is two segment
reductions (cluster sums, ρ_self refresh).  This module owns *how* both
phases' accumulators are produced:

``reference``
    Assignment: the TAAT ``lax.scan`` over padded object tuples.  Update:
    the dense ``at[].add`` scatter and the own-centroid gather.  Runs
    everywhere, no alignment constraints, and is the exactness oracle every
    other backend is tested against.

``pallas``
    Assignment: the TPU Pallas kernels in :mod:`repro.kernels.ops`
    (``sparse_sim`` / ``esicp_gather`` / ``esicp_filter``).  Update:
    ``segment_update`` (scatter-add as one-hot-selection MXU matmuls) and
    ``rho_gather`` (ρ_self refresh as a one-hot own-centroid gather).
    Off-TPU the kernels run in interpret mode (handled inside
    ``kernels.ops``), so the backend is selectable — and tested — on CPU.
    The TA bound needs a *per-object* value threshold, which the
    shared-threshold gather kernel cannot express; that one mode delegates
    to the reference scan (see the AFM translation table in DESIGN.md §3).
    ``prepare`` builds the epoch-invariant :class:`repro.kernels.plan.
    KernelPlan` (occupancy map + cached high-df head slabs) that every
    kernel of a fit reuses — documents never change across Lloyd
    iterations, so their densified form is computed once per chunk per fit.

Exactness contract: for every algorithm, both backends produce identical
assignments and moving flags from identical state.  ``mult`` diagnostics are
kept exactly equal too — the kernels carry the visited (object-term,
posting-entry) pair count as a fused accumulator off the same one-hot walk
that builds the value slab, so ``diag=True`` costs no extra kernel launch.
Means and ρ_self agree to float32 reduction-order tolerance (the MXU
accumulates in a different order than the sequential scatter).

``xla_blocked``
    The same skew-aware plan expressed as pure jit-compiled XLA programs
    (:mod:`repro.kernels.xla_blocked`): Zipf tail as gather + posting-sum
    (work ∝ postings — the limiting case of occupancy skipping), optional
    high-df head region as one cached dense slab GEMM per call, and all
    four algo-mode accumulators fused into a single pass each — including
    TA (per-object threshold, natively compiled here) and CS (one
    ``cs_gather`` where Pallas needs three launches).  This is the engine
    that actually *compiles* off-TPU, so it is what ``auto`` picks on
    CPU/GPU and what the CI compiled ratchet enforces.

Selection: pass ``backend="reference" | "pallas" | "xla_blocked" | "auto"``
anywhere a ``backend=`` argument is threaded (``SphericalKMeans``,
``assignment_step``, ``update_step``, ``distributed.kmeans``,
``serve.ClusterEngine``, ``benchmarks.common``).  ``auto`` resolves to
``pallas`` on TPU and ``xla_blocked`` elsewhere.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.sparse import SparseDocs
from repro.core.meanindex import MeanIndex, doc_sketch


def col_ok_mask(index: MeanIndex, xstate: jax.Array) -> jax.Array:
    """(B, K) — centroids the ICP filter allows: moving ones always; invariant
    ones only for objects that are not 'more similar' (Eq. 5)."""
    return index.moving[None, :] | ~xstate[:, None]


@runtime_checkable
class Backend(Protocol):
    """Producer of the assignment-step and update-step accumulators.

    Assignment phase — ``accumulate`` returns the same dict the reference
    TAAT scan produces:

      mode 'exact'  -> {sims, mult}
      mode 'esicp'  -> {sims, rho12, y, mult}
      mode 'ta'     -> {sims, rho12, y, mult}   (per-object v_ta threshold)
      mode 'cs'     -> {sims, rho1, sq, mult}

    ``with_counts=True`` (diag required) additionally returns ``counts`` —
    the RAW per-(object, centroid) visited-pair counts of the mode's exact
    region, *without* the ICP ``col_ok`` mask (``mult`` keeps applying it).
    The bounds/sketch algo modes re-weight these per-row for their honest
    Mult accounting.

    ``es_filter`` evaluates the ES upper bound (Eq. 4) and returns the
    survivor mask and per-object candidate counts |Z_i|.

    ``sketch_sim`` produces the (B, K) block-vector sketch similarity used
    by the sketch gate: each entry upper-bounds the exact cosine similarity
    (per-group Cauchy-Schwarz on non-negative data).  The doc sketches come
    from the shared :func:`repro.core.meanindex.doc_sketch`, so both
    backends gate on bitwise-identical sketches.

    Update phase (Alg. 6) — both methods take raw padded tuple arrays so the
    single-device driver and the shard-local distributed step share them;
    callers pre-mask dead slots / invalid rows to ``vals == 0``:

    ``accumulate_means`` — (K, dim) tentative cluster sums λ_j = Σ_{x∈C_j} x
    (lines 2–5).  Out-of-range assignments contribute nothing.  ``init``
    lets chunked callers fold partial sums in place.

    ``self_sims`` — (B,) refreshed ρ_{a(i)} vs each object's own (new)
    centroid (lines 6–7); out-of-range assignments read ρ = 0.

    Prepared plans — ``prepare`` builds whatever per-corpus(-chunk) cache
    the backend can exploit across the iterations of one fit; every other
    method accepts it back as ``plan=``.  Documents are constant across
    Lloyd iterations, so anything derived from the tuples alone (dense
    slabs, occupancy) is epoch-invariant.  ``None`` (the reference
    backend's answer) means "nothing to cache"; callers pass it straight
    through, and a plan built for a different row layout is ignored by the
    consumer — plans are an optimisation, never a correctness input.

    Tuned configs — ``prepare`` additionally consults the process-wide
    :data:`repro.tune.TUNED_CACHE` when ``tune != "off"``: ``"cached"``
    reuses a previously found winner for this corpus regime (falling back
    to defaults on a miss), ``"search"`` runs the roofline-pruned autotuner
    on a miss under the opt-in ``tune_budget`` and caches the winner.  The
    winning :class:`repro.tune.TunedConfig` rides the returned plan, so
    every kernel of the fit launches with the tuned geometry.  ``k`` (the
    cluster count the fit will use) keys the signature; without it there is
    nothing to tune against and the knob is a no-op.
    """

    name: str

    def prepare(self, docs: SparseDocs, *, tile_rows: int | None = None,
                with_counts: bool = True, k: int | None = None,
                tune: str = "off", tune_budget=None): ...

    def accumulate(self, docs: SparseDocs, index: MeanIndex, xstate: jax.Array,
                   *, mode: str, v_ta: jax.Array | None = None,
                   diag: bool = True, unroll: bool | int = False,
                   p_block: int = 1, plan=None,
                   with_counts: bool = False) -> dict: ...

    def es_filter(self, rho12: jax.Array, y: jax.Array, rho_self: jax.Array,
                  col_ok: jax.Array, v_th: jax.Array): ...

    def sketch_sim(self, docs: SparseDocs, index: MeanIndex, *,
                   plan=None) -> jax.Array: ...

    def accumulate_means(self, ids: jax.Array, vals: jax.Array,
                         assign: jax.Array, *, k: int, dim: int,
                         init: jax.Array | None = None,
                         plan=None) -> jax.Array: ...

    def self_sims(self, ids: jax.Array, vals: jax.Array, assign: jax.Array,
                  means_t: jax.Array, *, plan=None) -> jax.Array: ...


# ---------------------------------------------------------------------------
# Reference backend: the TAAT lax.scan (moved verbatim from assignment.py).
# ---------------------------------------------------------------------------

def _pad_p(ids, vals, pb: int):
    """Pad the tuple-width axis to a ``pb`` multiple with dead (id 0, val 0)
    slots — dead slots are ``live == False`` everywhere downstream."""
    p = ids.shape[1]
    rem = (-p) % pb
    if rem:
        ids = jnp.pad(ids, ((0, 0), (0, rem)))
        vals = jnp.pad(vals, ((0, 0), (0, rem)))
    return ids, vals


def reference_scan(docs: SparseDocs, index: MeanIndex, xstate, *, mode: str,
                   v_ta: jax.Array | None = None, diag: bool = True,
                   unroll: bool | int = False, p_block: int = 1,
                   with_counts: bool = False):
    """One fused TAAT pass — the paper's MIVI loop order (Alg. 1 lines 1–5).

    On TPU each scan step is one (B,)-gather of a posting row ξ_s block plus
    a rank-1 multiply-add on the (B, K) accumulator: no data-dependent
    branches, shared thresholds as masks.

    ``sims`` is always the full exact similarity (reference semantics); the
    CPU algorithm would only compute it for survivors — that cost is what the
    verify-mult term in the caller accounts for.

    Perf knobs (§Perf; the distributed step and the dry-run coster thread
    them through):
      diag=False  — skip the Mult count (``mult`` is returned as 0);
      p_block>1   — gather ``p_block`` posting rows per scan step and fold
                    them before touching the (B, K) accumulators: accumulator
                    read/write traffic drops ~p_block× at unchanged gather
                    traffic;
      unroll      — unroll the scan (dry-run exact-FLOPs costing).
    """
    b, p = docs.ids.shape
    k = index.k
    t_th = index.params.t_th
    v_th = index.params.v_th
    means_t = index.means_t
    col_ok = col_ok_mask(index, xstate)      # (B, K) — ICP lane mask
    f32 = jnp.float32
    pb = max(int(p_block), 1)
    assert not with_counts or diag, "with_counts requires diag=True"

    def body(carry, xs):
        idp, vp = xs                          # (pb, B), (pb, B)
        rows = means_t[idp]                   # (pb, B, K) posting block
        contrib = vp[..., None] * rows
        sims = carry["sims"] + jnp.sum(contrib, 0)
        out = {"sims": sims, "mult": carry["mult"]}
        if diag:
            live = vp != 0.0
            nz = (rows > 0) & col_ok[None] & live[..., None]
            # Raw visited pairs (no ICP mask) — the per-(B, K) twin the
            # Pallas diag accumulator produces; ``mult`` keeps col_ok.
            nzr = (rows > 0) & live[..., None]
        if mode == "exact":
            if diag:
                out["mult"] = carry["mult"] + jnp.sum(nz, dtype=f32)
                if with_counts:
                    out["counts"] = carry["counts"] + jnp.sum(nzr, 0, dtype=f32)
        elif mode == "esicp":
            tail = (idp >= t_th)[..., None]   # (pb, B, 1)
            hi = rows >= v_th
            exact_mask = jnp.where(tail, hi, True)
            out["rho12"] = carry["rho12"] + jnp.sum(
                jnp.where(exact_mask, contrib, 0.0), 0)
            out["y"] = carry["y"] + jnp.sum(
                jnp.where(tail & ~hi, vp[..., None], 0.0), 0)
            if diag:
                out["mult"] = carry["mult"] + jnp.sum(nz & exact_mask, dtype=f32)
                if with_counts:
                    out["counts"] = carry["counts"] + jnp.sum(
                        nzr & exact_mask, 0, dtype=f32)
        elif mode == "ta":
            tail = (idp >= t_th)[..., None]
            hi = rows >= v_ta[None, :, None]  # per-object threshold (Eq. 16)
            exact_mask = jnp.where(tail, hi, True)
            out["rho12"] = carry["rho12"] + jnp.sum(
                jnp.where(exact_mask, contrib, 0.0), 0)
            out["y"] = carry["y"] + jnp.sum(
                jnp.where(tail & ~hi, vp[..., None], 0.0), 0)
            # TA walks each sorted posting until v < v_ta: visits hi entries
            # plus one terminator comparison; mults are the hi entries.
            if diag:
                out["mult"] = carry["mult"] + jnp.sum(nz & exact_mask, dtype=f32)
        elif mode == "cs":
            tail = (idp >= t_th)[..., None]
            out["rho1"] = carry["rho1"] + jnp.sum(
                jnp.where(tail, 0.0, contrib), 0)
            out["sq"] = carry["sq"] + jnp.sum(
                jnp.where(tail, rows * rows, 0.0), 0)
            if diag:
                out["mult"] = carry["mult"] + jnp.sum(nz, dtype=f32)
        else:
            raise ValueError(mode)
        return out, None

    carry = {"sims": jnp.zeros((b, k), f32), "mult": jnp.zeros((), f32)}
    if with_counts:
        assert mode in ("exact", "esicp"), mode
        carry["counts"] = jnp.zeros((b, k), f32)
    if mode == "esicp" or mode == "ta":
        carry["rho12"] = jnp.zeros((b, k), f32)
        carry["y"] = jnp.zeros((b, k), f32)
    elif mode == "cs":
        carry["rho1"] = jnp.zeros((b, k), f32)
        carry["sq"] = jnp.zeros((b, k), f32)
    ids, vals = (docs.ids, docs.vals) if pb == 1 else _pad_p(docs.ids,
                                                             docs.vals, pb)
    pp = ids.shape[1]
    xs = (ids.T.reshape(pp // pb, pb, b), vals.T.reshape(pp // pb, pb, b))
    out, _ = jax.lax.scan(body, carry, xs, unroll=unroll)
    return out


def gather_verify_scan(ids, vals, nnz, means_t, t_th, v_th, rho_max, col_ok,
                       *, unroll: bool | int = False, p_block: int = 1,
                       p_tail: int = 16):
    """Paper-faithful two-phase ES assignment (§Perf variant, Algs. 2–3) —
    the reference backend's gather/verify scan, shared with the distributed
    shard-local step.

    Phase G: one TAAT pass accumulating only (rho12, y) — the full exact
    similarity is NOT computed for every centroid (that is MIVI's cost).
    Phase V: the exact Region-3 partial from a second pass over a compacted
    live-suffix window.  ids ascend by df-rank within a row, so the >= t_th
    entries are the last (ntH)_i LIVE positions; the caller guarantees
    max_i (ntH)_i <= p_tail (computed after EstParams fixes t_th — the same
    moment the paper restructures its index).  Exactness is preserved:
    windows that reach below position 0 are validity-masked.

    Returns (exact_masked, survivors).
    """
    c, p = ids.shape
    k_loc = means_t.shape[1]
    pb = max(int(p_block), 1)
    z = jnp.zeros((c, k_loc), jnp.float32)

    def g_body(carry, xs):
        rho12, y = carry
        idp, vp = xs
        rows = means_t[idp]
        contrib = vp[..., None] * rows
        tail = (idp >= t_th)[..., None]
        hi = rows >= v_th
        exact = jnp.where(tail, hi, True)
        return (rho12 + jnp.sum(jnp.where(exact, contrib, 0.0), 0),
                y + jnp.sum(jnp.where(tail & ~hi, vp[..., None], 0.0), 0)), None

    gi, gv = _pad_p(ids, vals, pb)
    pp = gi.shape[1]
    xs = (gi.T.reshape(pp // pb, pb, c), gv.T.reshape(pp // pb, pb, c))
    (rho12, y), _ = jax.lax.scan(g_body, (z, z), xs, unroll=unroll)
    surv = ((rho12 + y * v_th) > rho_max[:, None]) & col_ok

    # compacted live-suffix window [nnz - p_tail, nnz)
    off = nnz[:, None] - p_tail + jnp.arange(p_tail)[None, :]
    okw = off >= 0
    idx = jnp.clip(off, 0, p - 1)
    tids = jnp.take_along_axis(ids, idx, axis=1)
    tvals = jnp.where(okw, jnp.take_along_axis(vals, idx, axis=1), 0.0)

    def v_body(rho3, xs):
        idp, vp = xs
        rows = means_t[idp]
        tail = (idp >= t_th)[..., None]
        lo = rows < v_th
        add = jnp.where(tail & lo, vp[..., None] * rows, 0.0)
        return rho3 + jnp.sum(add, 0), None

    ti, tv = _pad_p(tids, tvals, pb)
    pt = ti.shape[1]
    xsv = (ti.T.reshape(pt // pb, pb, c), tv.T.reshape(pt // pb, pb, c))
    rho3, _ = jax.lax.scan(v_body, z, xsv, unroll=unroll)
    exact = jnp.where(surv, rho12 + rho3, -jnp.inf)
    return exact, surv


class ReferenceBackend:
    """Pure-jnp TAAT scan — runs anywhere, defines the exactness contract."""

    name = "reference"

    def prepare(self, docs, *, tile_rows=None, with_counts=True, k=None,
                tune="off", tune_budget=None):
        # The scan gathers posting rows directly from the sparse tuples —
        # there is no densified intermediate to cache, and no launch
        # geometry to tune.
        return None

    def accumulate(self, docs, index, xstate, *, mode, v_ta=None, diag=True,
                   unroll=False, p_block=1, plan=None, with_counts=False):
        return reference_scan(docs, index, xstate, mode=mode, v_ta=v_ta,
                              diag=diag, unroll=unroll, p_block=p_block,
                              with_counts=with_counts)

    def es_filter(self, rho12, y, rho_self, col_ok, v_th):
        # Upper bound (Eq. 4): rho12 + y·v_th.  The paper's App.-A scaling
        # removes this multiply on CPU; on TPU it is a fused multiply-add.
        ub = rho12 + y * v_th
        survivors = (ub > rho_self[:, None]) & col_ok
        return survivors, jnp.sum(survivors, axis=1).astype(jnp.int32)

    def sketch_sim(self, docs, index, *, plan=None):
        sk = doc_sketch(docs.ids, docs.vals, index.dim)
        return jnp.dot(sk, index.sketch_t, preferred_element_type=jnp.float32)

    def accumulate_means(self, ids, vals, assign, *, k, dim, init=None,
                         plan=None):
        # The dense scatter-add (Alg. 6 lines 2–5).  XLA drops out-of-bounds
        # scatter updates, so out-of-range assignments contribute nothing.
        acc = jnp.zeros((k, dim), jnp.float32) if init is None else init
        return acc.at[assign[:, None], ids].add(vals)

    def self_sims(self, ids, vals, assign, means_t, *, plan=None):
        # Own-centroid gather (Alg. 6 lines 6–7); gathers clamp out-of-range
        # assignments, so they are masked to ρ = 0 explicitly.
        k = means_t.shape[1]
        picked = means_t[ids, jnp.minimum(assign, k - 1)[:, None]]
        return jnp.sum(jnp.where((assign < k)[:, None], vals * picked, 0.0),
                       axis=1)


# ---------------------------------------------------------------------------
# Pallas backend: kernels for the hot accumulators.
# ---------------------------------------------------------------------------

class PallasBackend:
    """Kernel-dispatching backend (interpret mode off-TPU).

    The similarity/gather accumulators become densify-then-MXU kernels.  The
    Mult diagnostic — a *count* of posting entries a CPU implementation
    would visit — rides the SAME launches as a fused accumulator

        count[b, k] = Σ_p live[b, p] · W[ids[b, p], k]

    (W the region/nonzero indicator of the mean matrix, built in-kernel from
    the means block): the one-hot walk that densifies the value slab yields
    the live-count slab for free, so ``diag=True`` issues no extra kernel
    launch and no host-side (D, K) region mask exists anymore.  The ES mode
    also pulls the full exact similarity out of the same gather launch.

    ``prepare`` densifies the high-df head region once per chunk per fit and
    precomputes the (B-tile, D-block) occupancy map (kernels/plan.py) —
    the caches every kernel of the fit then reuses via ``plan=``.
    """

    name = "pallas"

    def prepare(self, docs, *, tile_rows=None, with_counts=True, k=None,
                tune="off", tune_budget=None):
        from repro.kernels.plan import prepare_plan

        tuned = None
        if tune != "off":
            from repro.tune import ensure_tuned

            tuned = ensure_tuned(docs, k=k, mode=tune, budget=tune_budget)
        # The cache is built from row_mask()-masked vals — the operand
        # convention of the update phase.  The assignment phase feeds the
        # kernels raw docs.vals; the two coincide under the repo-wide
        # invariant that slots at index >= nnz hold val 0 (corpus builders,
        # pad_rows and the DocStoreBuilder all enforce it), which is the
        # precondition for one cached slab serving both phases.
        vals = jnp.where(docs.row_mask(), docs.vals, 0.0)
        return prepare_plan(docs.ids, vals, dim=docs.dim,
                            tile_rows=tile_rows, with_counts=with_counts,
                            tuned=tuned)

    def accumulate(self, docs, index, xstate, *, mode, v_ta=None, diag=True,
                   unroll=False, p_block=1, plan=None, with_counts=False):
        # unroll / p_block are reference-scan tiling knobs; the kernels tile
        # via their own block specs, so both are accepted and ignored here.
        from repro.kernels import ops

        assert not with_counts or diag, "with_counts requires diag=True"
        if mode == "ta":
            # Per-object v_ta threshold: not expressible as a shared-threshold
            # mask over the (D_blk, K_sup) means block, so no kernel exists.
            return reference_scan(docs, index, xstate, mode="ta", v_ta=v_ta)

        means_t = index.means_t
        t_th = index.params.t_th
        v_th = index.params.v_th
        col_ok = col_ok_mask(index, xstate)

        out = {}
        if not diag:
            out["mult"] = jnp.zeros((), jnp.float32)
        if mode == "exact" or mode == "cs":
            res = ops.sparse_sim(docs.ids, docs.vals, means_t, diag=diag,
                                 plan=plan)
            if diag:
                out["sims"], counts = res
                out["mult"] = jnp.sum(jnp.where(col_ok, counts, 0.0))
                if with_counts:
                    # The fused diag accumulator is already the raw
                    # per-(B, K) count — same launch, no extra kernel.
                    out["counts"] = counts
            else:
                out["sims"] = res
            if mode == "cs":
                # These substitute synthetic weights for the raw vals, so the
                # cached head slabs do not apply (occupancy is re-derived
                # from the actual operands inside the wrapper); the tuned
                # launch geometry still does.
                tuned = plan.tuned if plan is not None else None
                # Head-only partial: mask on the object side (ids < t_th) —
                # identical sums to masking rows of the mean matrix.
                head_vals = jnp.where(docs.ids < t_th, docs.vals, 0.0)
                out["rho1"] = ops.sparse_sim(docs.ids, head_vals, means_t,
                                             tuned=tuned)
                # Σ over slots of means², including the reference scan's
                # dead-slot quirk (padding ids are 0, counted iff t_th == 0).
                tail_ones = (docs.ids >= t_th).astype(jnp.float32)
                out["sq"] = ops.sparse_sim(docs.ids, tail_ones,
                                           means_t * means_t, tuned=tuned)
        elif mode == "esicp":
            # ONE launch for the whole gathering phase: bound operands, the
            # exact similarities, and (under diag) the exact-region visited-
            # pair counts, all off one densified slab per (B, D) block.
            res = ops.esicp_gather(docs.ids, docs.vals, means_t, t_th, v_th,
                                   with_sims=True, diag=diag, plan=plan)
            if diag:
                out["rho12"], out["y"], out["sims"], counts = res
                out["mult"] = jnp.sum(jnp.where(col_ok, counts, 0.0))
                if with_counts:
                    out["counts"] = counts
            else:
                out["rho12"], out["y"], out["sims"] = res
        else:
            raise ValueError(mode)
        return out

    def es_filter(self, rho12, y, rho_self, col_ok, v_th):
        from repro.kernels import ops

        mask, count = ops.esicp_filter(rho12, y, rho_self, col_ok, v_th)
        return mask.astype(bool), count

    def sketch_sim(self, docs, index, *, plan=None):
        from repro.kernels import ops

        sk = doc_sketch(docs.ids, docs.vals, index.dim)
        return ops.sketch_sim(sk, index.sketch_t, plan=plan)

    def accumulate_means(self, ids, vals, assign, *, k, dim, init=None,
                         plan=None):
        # Scatter-add as one-hot-selection MXU matmuls: a TPU must not
        # read-modify-write HBM per object (kernels/segment_update.py).
        from repro.kernels import ops

        lam = ops.segment_update(assign, ids, vals, k=k, d=dim, plan=plan)
        return lam if init is None else init + lam

    def self_sims(self, ids, vals, assign, means_t, *, plan=None):
        from repro.kernels import ops

        return ops.rho_gather(assign, ids, vals, means_t, plan=plan)


# ---------------------------------------------------------------------------
# XLA-blocked backend: the compiled skew-aware engine for non-TPU hardware.
# ---------------------------------------------------------------------------

class XlaBlockedBackend:
    """Pure-XLA kernel twins (:mod:`repro.kernels.xla_blocked`).

    Same plan vocabulary as the Pallas backend — ``prepare`` returns a
    :class:`repro.kernels.plan.KernelPlan` and every accumulator accepts it
    back — but the engine consumes only the head-slab cache (the gather
    formulation makes ``occ`` redundant: empty cells are never touched).
    The engine *default* is head-less (``head_bytes=0``): on CPU the slab
    GEMM costs B·H·K FLOPs against the gather's B·p_head·K, so caching head
    blocks is an autotuner decision (``tune != "off"`` with an
    ``engine="xla_blocked"`` winner), not a reflex.

    Every algo mode is a single fused launch here: exact/esicp via the
    shared-threshold ops, TA natively (the per-object threshold rides the
    gather, no reference-scan delegation), CS via the one-pass
    ``cs_gather`` (sims + rho1 + sq + counts together).
    """

    name = "xla_blocked"

    def prepare(self, docs, *, tile_rows=None, with_counts=True, k=None,
                tune="off", tune_budget=None):
        from repro.kernels.plan import prepare_plan

        tuned = None
        if tune != "off":
            from repro.tune import ensure_tuned

            tuned = ensure_tuned(docs, k=k, mode=tune, budget=tune_budget,
                                 engine=self.name)
        # Same masked-vals convention as the Pallas prepare (one cached slab
        # serves both phases); head_bytes=0 unless a tuned config says
        # otherwise, see the class docstring.
        vals = jnp.where(docs.row_mask(), docs.vals, 0.0)
        head_bytes = tuned.head_bytes if tuned is not None else 0
        return prepare_plan(docs.ids, vals, dim=docs.dim,
                            tile_rows=tile_rows, with_counts=with_counts,
                            head_bytes=head_bytes, tuned=tuned)

    def accumulate(self, docs, index, xstate, *, mode, v_ta=None, diag=True,
                   unroll=False, p_block=1, plan=None, with_counts=False):
        # unroll / p_block are reference-scan tiling knobs; the XLA ops
        # chunk the posting axis themselves, so both are accepted + ignored.
        from repro.kernels import xla_blocked as xb

        assert not with_counts or diag, "with_counts requires diag=True"
        means_t = index.means_t
        t_th = index.params.t_th
        v_th = index.params.v_th
        col_ok = col_ok_mask(index, xstate)

        out = {}
        if not diag:
            out["mult"] = jnp.zeros((), jnp.float32)
        if mode == "exact":
            res = xb.sparse_sim(docs.ids, docs.vals, means_t, diag=diag,
                                plan=plan)
            if diag:
                out["sims"], counts = res
                out["mult"] = jnp.sum(jnp.where(col_ok, counts, 0.0))
                if with_counts:
                    out["counts"] = counts
            else:
                out["sims"] = res
        elif mode == "cs":
            res = xb.cs_gather(docs.ids, docs.vals, means_t, t_th, diag=diag)
            if diag:
                out["sims"], out["rho1"], out["sq"], counts = res
                out["mult"] = jnp.sum(jnp.where(col_ok, counts, 0.0))
            else:
                out["sims"], out["rho1"], out["sq"] = res
        elif mode in ("esicp", "ta"):
            res = xb.esicp_gather(docs.ids, docs.vals, means_t, t_th, v_th,
                                  v_ta=v_ta if mode == "ta" else None,
                                  with_sims=True, diag=diag, plan=plan)
            if diag:
                out["rho12"], out["y"], out["sims"], counts = res
                out["mult"] = jnp.sum(jnp.where(col_ok, counts, 0.0))
                if with_counts:
                    out["counts"] = counts
            else:
                out["rho12"], out["y"], out["sims"] = res
        else:
            raise ValueError(mode)
        return out

    # The filter and sketch phases are already single fused XLA expressions
    # in the reference backend — reuse them verbatim.
    es_filter = ReferenceBackend.es_filter
    sketch_sim = ReferenceBackend.sketch_sim

    def accumulate_means(self, ids, vals, assign, *, k, dim, init=None,
                         plan=None):
        from repro.kernels import xla_blocked as xb

        lam = xb.segment_update(assign, ids, vals, k=k, d=dim, plan=plan)
        return lam if init is None else init + lam

    def self_sims(self, ids, vals, assign, means_t, *, plan=None):
        from repro.kernels import xla_blocked as xb

        return xb.rho_gather(assign, ids, vals, means_t, plan=plan)


# ---------------------------------------------------------------------------
# Registry / resolution.
# ---------------------------------------------------------------------------

BACKENDS: dict[str, Backend] = {
    "reference": ReferenceBackend(),
    "pallas": PallasBackend(),
    "xla_blocked": XlaBlockedBackend(),
}


def resolve_backend(spec) -> Backend:
    """'reference' | 'pallas' | 'xla_blocked' | 'auto' | Backend -> Backend.

    'auto' picks the engine that actually compiles on the local hardware:
    the Pallas kernels on TPU, the XLA-blocked twins everywhere else
    (interpret-mode Pallas is for correctness testing, not speed, and the
    reference scan is the oracle, not the fast path).
    """
    if isinstance(spec, Backend) and not isinstance(spec, str):
        return spec
    if spec == "auto":
        return BACKENDS["pallas" if jax.default_backend() == "tpu"
                        else "xla_blocked"]
    if spec not in BACKENDS:
        raise ValueError(
            f"unknown backend {spec!r}; one of {sorted(BACKENDS)} or 'auto'")
    return BACKENDS[spec]
