"""Core: the paper's contribution — ES-ICP accelerated spherical K-means.

Public API:
    MeanIndex            — structured mean set (the paper's mean-inverted index)
    BACKENDS             — assignment accumulator engines (reference | pallas)
    StructuralParams     — (t_th, v_th) shared thresholds
    estimate_params      — EstParams (paper §V / App. B–C)
    assignment_step      — one assignment step under a chosen algorithm
    update_step          — mean update + moving-centroid detection
    SphericalKMeans      — Lloyd-iteration driver with diagnostics
"""
from repro.core.meanindex import MeanIndex, StructuralParams, build_mean_index
from repro.core.assignment import assignment_step, ALGORITHMS
from repro.core.backends import BACKENDS, Backend, resolve_backend
from repro.core.update import update_step, init_state, KMeansState
from repro.core.estparams import estimate_params, EstGrid
from repro.core.lloyd import LloydResult, lloyd_fit
from repro.core import metrics


def __getattr__(name):
    # Lazy re-export: the estimator lives in the repro.cluster facade (PR 3's
    # API redesign), whose submodules import repro.core right back — resolving
    # it at attribute-access time keeps the package initialisations acyclic.
    if name == "SphericalKMeans":
        from repro.cluster.estimator import SphericalKMeans
        return SphericalKMeans
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "MeanIndex", "StructuralParams", "build_mean_index",
    "assignment_step", "ALGORITHMS",
    "BACKENDS", "Backend", "resolve_backend",
    "update_step", "init_state", "KMeansState",
    "estimate_params", "EstGrid",
    "SphericalKMeans", "LloydResult", "lloyd_fit", "metrics",
]
