"""Clustering quality + UC diagnostics (paper App. H–I, Figs. 2–4).

NMI / objective-J power the initial-state-independence study (App. H);
the CPS curve reproduces the Pareto-principle-like phenomenon (App. I);
zipf_fit / mean_value_skew check the synthetic corpus matches the UCs.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.sparse import SparseDocs


def objective(rho_self) -> float:
    """J(C) = Σ_i x_i·μ_{a(i)} (Eq. 47)."""
    return float(jnp.sum(rho_self))


def nmi(a: np.ndarray, b: np.ndarray) -> float:
    """Normalized mutual information (Eq. 49), sparse contingency."""
    a = np.asarray(a); b = np.asarray(b)
    n = len(a)
    pairs = a.astype(np.int64) * (b.max() + 1) + b
    _, counts = np.unique(pairs, return_counts=True)
    pab = counts / n
    _, ca = np.unique(a, return_counts=True)
    _, cb = np.unique(b, return_counts=True)
    pa = ca / n
    pb = cb / n
    ha = -np.sum(pa * np.log(pa))
    hb = -np.sum(pb * np.log(pb))
    # I = H(a) + H(b) - H(a,b)
    hab = -np.sum(pab * np.log(pab))
    i = ha + hb - hab
    denom = np.sqrt(ha * hb)
    return float(i / denom) if denom > 0 else 1.0


def pairwise_nmi(assignments: list[np.ndarray]) -> tuple[float, float]:
    """Mean/std of NMI over all pairs (Eq. 50)."""
    vals = []
    for i in range(len(assignments)):
        for j in range(i + 1, len(assignments)):
            vals.append(nmi(assignments[i], assignments[j]))
    return float(np.mean(vals)), float(np.std(vals))


def coefficient_of_variation(xs) -> float:
    xs = np.asarray(xs, dtype=np.float64)
    m = xs.mean()
    return float(xs.std() / m) if m != 0 else 0.0


def cps_curve(docs: SparseDocs, means_t, assign, n_bins: int = 100):
    """Average cumulative partial similarity vs normalized rank (App. I).

    Returns (nr, cps_mean, cps_std): the paper reports CPS(0.1) ≈ 0.92 for
    PubMed — 10% of the multiplications give 92% of the similarity.
    """
    picked = means_t[docs.ids, jnp.asarray(assign)[:, None]]      # (N, P)
    partial = jnp.where(docs.row_mask(), docs.vals * picked, 0.0)
    part_sorted = -jnp.sort(-partial, axis=1)                      # descending
    csum = jnp.cumsum(part_sorted, axis=1)
    total = jnp.maximum(csum[:, -1:], 1e-12)
    frac = csum / total                                            # (N, P)

    nr = jnp.linspace(0.0, 1.0, n_bins + 1)
    # index into each row at h = ceil(nr * nnz) - 1 (clipped)
    idx = jnp.ceil(nr[None, :] * docs.nnz[:, None]).astype(jnp.int32) - 1
    idx = jnp.clip(idx, 0, docs.pad_width - 1)
    sampled = jnp.take_along_axis(frac, idx, axis=1)
    sampled = jnp.where(nr[None, :] == 0.0, 0.0, sampled)
    return np.asarray(nr), np.asarray(jnp.mean(sampled, axis=0)), np.asarray(jnp.std(sampled, axis=0))


def zipf_fit(freqs: np.ndarray) -> float:
    """OLS slope of log-freq vs log-rank (descending) — Zipf exponent α."""
    f = np.sort(np.asarray(freqs, dtype=np.float64))[::-1]
    f = f[f > 0]
    r = np.arange(1, len(f) + 1)
    lo, hi = int(0.01 * len(f)), int(0.7 * len(f))  # fit the body, not the tails
    x = np.log(r[lo:hi]); y = np.log(f[lo:hi])
    slope = np.polyfit(x, y, 1)[0]
    return float(-slope)


def mean_value_skew(means_t) -> dict:
    """Feature-value concentration stats (Fig. 4a / 9): fraction of centroids
    whose largest feature value exceeds 1/sqrt(2), and top-1/total mass."""
    col_max = jnp.max(means_t, axis=0)                 # (K,)
    col_sum = jnp.maximum(jnp.sum(means_t, axis=0), 1e-12)
    return {
        "frac_dominant": float(jnp.mean(col_max > (1.0 / np.sqrt(2.0)))),
        "top1_mass_mean": float(jnp.mean(col_max / col_sum)),
    }
