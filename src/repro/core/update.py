"""Update step (paper Alg. 6) + clustering state.

Responsibilities, matching the paper's five update-phase duties:
  (1) accumulate tentative means λ_j = Σ_{x∈C_j} x (sparse segment sum);
  (2) refresh every object's self-similarity ρ_{a(i)} against its *new*
      centroid — the shared pruning threshold of the next assignment step;
  (3)–(5) rebuild the structured index (here: column stats + moving flags).

Both segment reductions — (1) and (2) — are produced by the pluggable
:class:`repro.core.backends.Backend` (``reference``: dense scatter / gather,
the exactness oracle; ``pallas``: the ``segment_update`` / ``rho_gather``
MXU kernels).  Invariant-centroid detection uses exact set semantics
(C_j^{[r]} == C_j^{[r-1]}) — a centroid is invariant iff no object moved
into or out of its cluster — rather than a float tolerance, so ICP pruning
is exactly the paper's under every backend.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.sparse import SparseDocs
from repro.core.meanindex import (MeanIndex, StructuralParams,
                                  build_mean_index, normalized_means)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KMeansState:
    index: MeanIndex
    assign: jax.Array       # (N,) int32
    rho_self: jax.Array     # (N,) float32 — ρ_{a(i)} vs the current means
    rho_self_prev: jax.Array  # (N,) float32 — previous refresh (Eq. 5 input)
    iteration: jax.Array    # () int32
    ub: jax.Array           # (N, G) float32 — drift-loosened upper bounds on
    #                         the best non-assigned similarity per centroid
    #                         BOUND GROUP (bounds modes; +inf = no bound
    #                         known, the init value).  G = n_ub_groups(k):
    #                         per-center when k <= UB_GROUPS, else centroids
    #                         tier into ceil(k/G)-wide groups so one fast-
    #                         moving outlier center only voids its own
    #                         group's bound (Yinyang-style group filter,
    #                         cosine-adapted).

    def tree_flatten(self):
        return (self.index, self.assign, self.rho_self, self.rho_self_prev,
                self.iteration, self.ub), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)

    @property
    def xstate(self) -> jax.Array:
        """Eq. (5): object is 'more similar' if its refreshed self-similarity
        did not decrease.  False on the first two iterations (no history)."""
        return (self.rho_self >= self.rho_self_prev) & (self.iteration >= 2)


# Additive slack on the drift-loosened bound: absorbs the float32 rounding
# of the arccos/cos round trip so the loosened bound stays a TRUE upper
# bound on the drifted similarity (hypothesis-tested in test_pruning.py).
UB_DRIFT_EPS = 1e-5

# Bound-group cap: per-object bounds are maintained per centroid GROUP, one
# bound per center up to this many, then ceil(k/UB_GROUPS)-wide tiers.  The
# scalar (Hamerly-style) bound dies the moment ANY center moves fast — and
# early Lloyd iterations always have a few outlier movers (measured: median
# drift 5–10°, max 55–70° at iteration 2).  Grouping confines an outlier's
# drift to its own group, so the other groups' bounds keep pruning.
UB_GROUPS = 16


def ub_group_size(k: int) -> int:
    """Centroids per bound group: 1 while k <= UB_GROUPS (true per-center
    bounds), else the smallest tier width that fits UB_GROUPS groups."""
    return -(-k // min(k, UB_GROUPS))


def n_ub_groups(k: int) -> int:
    """G — number of bound groups (= state width of ``KMeansState.ub``)."""
    return -(-k // ub_group_size(k))


def ub_group_of(k: int) -> jax.Array:
    """(K,) int32 — static centroid-id → bound-group map (contiguous tiers,
    matching the 'model'-axis column sharding so a mesh shard's centroids
    land in contiguous groups)."""
    return jnp.arange(k, dtype=jnp.int32) // ub_group_size(k)


def max_center_drift(means_t_new: jax.Array,
                     means_t_old: jax.Array) -> jax.Array:
    """() float32 — max_j angular drift arccos(<c_j_new, c_j_old>).

    Both operands are unit columns ((D, K) transposed means); empty clusters
    keep their previous mean (normalized_means), so their drift is exactly
    zero and never loosens anyone's bound.
    """
    dots = jnp.sum(means_t_new * means_t_old, axis=0)
    return jnp.max(jnp.arccos(jnp.clip(dots, -1.0, 1.0)))


def group_drift(means_t_new: jax.Array,
                means_t_old: jax.Array) -> jax.Array:
    """(G,) float32 — per-bound-group max angular drift (the per-center
    drift aggregated over each group's centroids).  Pads with zero drift,
    so a ragged final group is never loosened by phantom centroids."""
    dots = jnp.sum(means_t_new * means_t_old, axis=0)
    d = jnp.arccos(jnp.clip(dots, -1.0, 1.0))
    k = d.shape[0]
    gsz = ub_group_size(k)
    g = n_ub_groups(k)
    d = jnp.pad(d, (0, g * gsz - k))
    return jnp.max(d.reshape(g, gsz), axis=1)


def drift_loosen(ub: jax.Array, delta_max: jax.Array) -> jax.Array:
    """Loosen per-object similarity upper bounds by the center drift.

    Spherical triangle inequality: if ρ(x, c_old) <= u = cos(θ) then
    ρ(x, c_new) <= cos(max(0, θ − δ)) for any center that rotated by at
    most δ.  Non-finite bounds (+inf 'unknown') pass through unchanged;
    finite ones gain UB_DRIFT_EPS so float rounding never tightens them.

    Elementwise with broadcasting: a (N, G) bound matrix against a (G,)
    per-group drift loosens each group by its own centroids' worst drift.
    """
    theta = jnp.arccos(jnp.clip(ub, -1.0, 1.0))
    loose = jnp.cos(jnp.maximum(theta - delta_max, 0.0)) + UB_DRIFT_EPS
    return jnp.where(jnp.isfinite(ub), loose, ub)


def moving_flags(assign: jax.Array, prev_assign: jax.Array, k: int) -> jax.Array:
    """(K,) bool — exact invariance: a centroid moved iff its membership
    changed (an object entered or left its cluster)."""
    changed = assign != prev_assign
    moving = jnp.zeros((k,), jnp.int32)
    moving = moving.at[assign].max(changed.astype(jnp.int32))
    moving = moving.at[prev_assign].max(changed.astype(jnp.int32))
    return moving.astype(bool)


@partial(jax.jit, static_argnames=("k", "backend"))
def update_step(docs: SparseDocs, assign: jax.Array, prev_assign: jax.Array,
                prev_state: KMeansState, params: StructuralParams, *, k: int,
                backend: str = "reference", plan=None,
                ub: jax.Array | None = None) -> KMeansState:
    """Full update: new means, moving flags, refreshed ρ_self, xstate shift.

    ``plan`` is the backend's prepared epoch-invariant cache for ``docs``
    (``Backend.prepare``; the Lloyd drivers build it once per fit).

    ``ub`` is the assignment step's refreshed per-object bound (bounds
    modes); None keeps the previous state's.  Either way the stored bound
    is loosened by the max per-center angular drift of THIS update, so it
    remains a true upper bound against the new means.
    """
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    vals = jnp.where(docs.row_mask(), docs.vals, 0.0)
    lam = bk.accumulate_means(docs.ids, vals, assign, k=k, dim=docs.dim,
                              plan=plan)
    means = normalized_means(lam, prev_state.index.means_t)
    index = build_mean_index(means, params,
                             moving=moving_flags(assign, prev_assign, k))
    rho_self = bk.self_sims(docs.ids, vals, assign, index.means_t, plan=plan)
    ub = prev_state.ub if ub is None else ub
    delta = group_drift(index.means_t, prev_state.index.means_t)
    return KMeansState(
        index=index,
        assign=assign,
        rho_self=rho_self,
        rho_self_prev=prev_state.rho_self,
        iteration=prev_state.iteration + 1,
        ub=drift_loosen(ub, delta),
    )


def seed_rows(n_docs: int, k: int, *, seed: int = 0) -> jax.Array:
    """(K,) distinct document indices — THE seeding draw.  Shared by the
    resident and the DocStore paths so a one-chunk store fit starts from
    the bitwise-identical centroids as ``fit(docs)``."""
    key = jax.random.PRNGKey(seed)
    return jax.random.choice(key, n_docs, shape=(k,), replace=False)


def seed_centroids(sel: SparseDocs, k: int) -> jax.Array:
    """(K, D) unit-norm means from K seed documents (scatter + L2)."""
    means = jnp.zeros((k, sel.dim), jnp.float32)
    rows = jnp.arange(k)[:, None]
    means = means.at[rows, sel.ids].add(jnp.where(sel.row_mask(), sel.vals, 0.0))
    norms = jnp.sqrt(jnp.sum(means**2, axis=1, keepdims=True))
    return means / jnp.maximum(norms, 1e-12)


def init_state(docs: SparseDocs, k: int, params: StructuralParams, *, seed: int = 0) -> KMeansState:
    """Random seeding: K distinct documents as initial centroids.

    App. H shows clustering results in this regime are initial-state
    independent, so random seeding matches k-means++ quality at far lower
    cost; seeding strategies are explicitly out of the paper's scope (§I).
    """
    pick = seed_rows(docs.n_docs, k, seed=seed)
    sel = SparseDocs(ids=docs.ids[pick], vals=docs.vals[pick], nnz=docs.nnz[pick], dim=docs.dim)
    means = seed_centroids(sel, k)
    index = build_mean_index(means, params)
    n = docs.n_docs
    return KMeansState(
        index=index,
        assign=jnp.zeros((n,), jnp.int32),
        rho_self=jnp.full((n,), -jnp.inf, jnp.float32),
        rho_self_prev=jnp.full((n,), -jnp.inf, jnp.float32),
        iteration=jnp.asarray(0, jnp.int32),
        ub=jnp.full((n, n_ub_groups(k)), jnp.inf, jnp.float32),
    )


def init_state_from_store(store, k: int, params: StructuralParams, *,
                          seed: int = 0) -> KMeansState:
    """:func:`init_state` for an out-of-core corpus: the same PRNG draw and
    the same centroid construction, but the K seed rows are gathered from
    the store's chunks (a host gather touching only their chunks) and the
    per-document arrays cover every store row — real rows start at
    ρ_self = -inf, the dead tail rows at the repo-wide pad value 0."""
    import numpy as np

    pick = seed_rows(store.n_docs, k, seed=seed)
    sel = store.gather_rows(np.asarray(pick))
    index = build_mean_index(seed_centroids(sel, k), params)
    n_rows = store.n_rows
    valid = jnp.arange(n_rows) < store.n_docs
    rho0 = jnp.where(valid, -jnp.inf, 0.0).astype(jnp.float32)
    return KMeansState(
        index=index,
        assign=jnp.zeros((n_rows,), jnp.int32),
        rho_self=rho0,
        rho_self_prev=rho0,
        iteration=jnp.asarray(0, jnp.int32),
        # Dead tail rows get ub = 0 (the ρ_self pad convention's twin):
        # their bound drifting is harmless (zero counts), and a finite pad
        # keeps the padded state free of inf-arithmetic surprises.
        ub=jnp.broadcast_to(
            jnp.where(valid, jnp.inf, 0.0).astype(jnp.float32)[:, None],
            (n_rows, n_ub_groups(k))),
    )
