"""Update step (paper Alg. 6) + clustering state.

Responsibilities, matching the paper's five update-phase duties:
  (1) accumulate tentative means λ_j = Σ_{x∈C_j} x (sparse segment sum);
  (2) refresh every object's self-similarity ρ_{a(i)} against its *new*
      centroid — the shared pruning threshold of the next assignment step;
  (3)–(5) rebuild the structured index (here: column stats + moving flags).

Both segment reductions — (1) and (2) — are produced by the pluggable
:class:`repro.core.backends.Backend` (``reference``: dense scatter / gather,
the exactness oracle; ``pallas``: the ``segment_update`` / ``rho_gather``
MXU kernels).  Invariant-centroid detection uses exact set semantics
(C_j^{[r]} == C_j^{[r-1]}) — a centroid is invariant iff no object moved
into or out of its cluster — rather than a float tolerance, so ICP pruning
is exactly the paper's under every backend.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.sparse import SparseDocs
from repro.core.meanindex import (MeanIndex, StructuralParams,
                                  build_mean_index, normalized_means)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KMeansState:
    index: MeanIndex
    assign: jax.Array       # (N,) int32
    rho_self: jax.Array     # (N,) float32 — ρ_{a(i)} vs the current means
    rho_self_prev: jax.Array  # (N,) float32 — previous refresh (Eq. 5 input)
    iteration: jax.Array    # () int32

    def tree_flatten(self):
        return (self.index, self.assign, self.rho_self, self.rho_self_prev, self.iteration), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)

    @property
    def xstate(self) -> jax.Array:
        """Eq. (5): object is 'more similar' if its refreshed self-similarity
        did not decrease.  False on the first two iterations (no history)."""
        return (self.rho_self >= self.rho_self_prev) & (self.iteration >= 2)


def moving_flags(assign: jax.Array, prev_assign: jax.Array, k: int) -> jax.Array:
    """(K,) bool — exact invariance: a centroid moved iff its membership
    changed (an object entered or left its cluster)."""
    changed = assign != prev_assign
    moving = jnp.zeros((k,), jnp.int32)
    moving = moving.at[assign].max(changed.astype(jnp.int32))
    moving = moving.at[prev_assign].max(changed.astype(jnp.int32))
    return moving.astype(bool)


@partial(jax.jit, static_argnames=("k", "backend"))
def update_step(docs: SparseDocs, assign: jax.Array, prev_assign: jax.Array,
                prev_state: KMeansState, params: StructuralParams, *, k: int,
                backend: str = "reference", plan=None) -> KMeansState:
    """Full update: new means, moving flags, refreshed ρ_self, xstate shift.

    ``plan`` is the backend's prepared epoch-invariant cache for ``docs``
    (``Backend.prepare``; the Lloyd drivers build it once per fit)."""
    from repro.core.backends import resolve_backend

    bk = resolve_backend(backend)
    vals = jnp.where(docs.row_mask(), docs.vals, 0.0)
    lam = bk.accumulate_means(docs.ids, vals, assign, k=k, dim=docs.dim,
                              plan=plan)
    means = normalized_means(lam, prev_state.index.means_t)
    index = build_mean_index(means, params,
                             moving=moving_flags(assign, prev_assign, k))
    rho_self = bk.self_sims(docs.ids, vals, assign, index.means_t, plan=plan)
    return KMeansState(
        index=index,
        assign=assign,
        rho_self=rho_self,
        rho_self_prev=prev_state.rho_self,
        iteration=prev_state.iteration + 1,
    )


def seed_rows(n_docs: int, k: int, *, seed: int = 0) -> jax.Array:
    """(K,) distinct document indices — THE seeding draw.  Shared by the
    resident and the DocStore paths so a one-chunk store fit starts from
    the bitwise-identical centroids as ``fit(docs)``."""
    key = jax.random.PRNGKey(seed)
    return jax.random.choice(key, n_docs, shape=(k,), replace=False)


def seed_centroids(sel: SparseDocs, k: int) -> jax.Array:
    """(K, D) unit-norm means from K seed documents (scatter + L2)."""
    means = jnp.zeros((k, sel.dim), jnp.float32)
    rows = jnp.arange(k)[:, None]
    means = means.at[rows, sel.ids].add(jnp.where(sel.row_mask(), sel.vals, 0.0))
    norms = jnp.sqrt(jnp.sum(means**2, axis=1, keepdims=True))
    return means / jnp.maximum(norms, 1e-12)


def init_state(docs: SparseDocs, k: int, params: StructuralParams, *, seed: int = 0) -> KMeansState:
    """Random seeding: K distinct documents as initial centroids.

    App. H shows clustering results in this regime are initial-state
    independent, so random seeding matches k-means++ quality at far lower
    cost; seeding strategies are explicitly out of the paper's scope (§I).
    """
    pick = seed_rows(docs.n_docs, k, seed=seed)
    sel = SparseDocs(ids=docs.ids[pick], vals=docs.vals[pick], nnz=docs.nnz[pick], dim=docs.dim)
    means = seed_centroids(sel, k)
    index = build_mean_index(means, params)
    n = docs.n_docs
    return KMeansState(
        index=index,
        assign=jnp.zeros((n,), jnp.int32),
        rho_self=jnp.full((n,), -jnp.inf, jnp.float32),
        rho_self_prev=jnp.full((n,), -jnp.inf, jnp.float32),
        iteration=jnp.asarray(0, jnp.int32),
    )


def init_state_from_store(store, k: int, params: StructuralParams, *,
                          seed: int = 0) -> KMeansState:
    """:func:`init_state` for an out-of-core corpus: the same PRNG draw and
    the same centroid construction, but the K seed rows are gathered from
    the store's chunks (a host gather touching only their chunks) and the
    per-document arrays cover every store row — real rows start at
    ρ_self = -inf, the dead tail rows at the repo-wide pad value 0."""
    import numpy as np

    pick = seed_rows(store.n_docs, k, seed=seed)
    sel = store.gather_rows(np.asarray(pick))
    index = build_mean_index(seed_centroids(sel, k), params)
    n_rows = store.n_rows
    valid = jnp.arange(n_rows) < store.n_docs
    rho0 = jnp.where(valid, -jnp.inf, 0.0).astype(jnp.float32)
    return KMeansState(
        index=index,
        assign=jnp.zeros((n_rows,), jnp.int32),
        rho_self=rho0,
        rho_self_prev=rho0,
        iteration=jnp.asarray(0, jnp.int32),
    )
