"""Update step (paper Alg. 6) + clustering state.

Responsibilities, matching the paper's five update-phase duties:
  (1) accumulate tentative means λ_j = Σ_{x∈C_j} x (sparse scatter-add);
  (2) refresh every object's self-similarity ρ_{a(i)} against its *new*
      centroid — the shared pruning threshold of the next assignment step;
  (3)–(5) rebuild the structured index (here: column stats + moving flags).

Invariant-centroid detection uses exact set semantics (C_j^{[r]} == C_j^{[r-1]})
— a centroid is invariant iff no object moved into or out of its cluster —
rather than a float tolerance, so ICP pruning is exactly the paper's.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.sparse import SparseDocs
from repro.core.meanindex import MeanIndex, StructuralParams, build_mean_index


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KMeansState:
    index: MeanIndex
    assign: jax.Array       # (N,) int32
    rho_self: jax.Array     # (N,) float32 — ρ_{a(i)} vs the current means
    rho_self_prev: jax.Array  # (N,) float32 — previous refresh (Eq. 5 input)
    iteration: jax.Array    # () int32

    def tree_flatten(self):
        return (self.index, self.assign, self.rho_self, self.rho_self_prev, self.iteration), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)

    @property
    def xstate(self) -> jax.Array:
        """Eq. (5): object is 'more similar' if its refreshed self-similarity
        did not decrease.  False on the first two iterations (no history)."""
        return (self.rho_self >= self.rho_self_prev) & (self.iteration >= 2)


def _accumulate_means(docs: SparseDocs, assign: jax.Array, k: int) -> jax.Array:
    """(K, D) tentative means λ via sparse scatter-add (Alg. 6 lines 2–5)."""
    acc = jnp.zeros((k, docs.dim), jnp.float32)
    vals = jnp.where(docs.row_mask(), docs.vals, 0.0)
    return acc.at[assign[:, None], docs.ids].add(vals)


def _self_sims(docs: SparseDocs, means_t: jax.Array, assign: jax.Array) -> jax.Array:
    """ρ_{a(i)} for every object vs its own centroid (Alg. 6 lines 6–7)."""
    picked = means_t[docs.ids, assign[:, None]]  # (N, P)
    return jnp.sum(jnp.where(docs.row_mask(), docs.vals * picked, 0.0), axis=1)


@partial(jax.jit, static_argnames=("k",))
def update_step(docs: SparseDocs, assign: jax.Array, prev_assign: jax.Array,
                prev_state: KMeansState, params: StructuralParams, *, k: int) -> KMeansState:
    """Full update: new means, moving flags, refreshed ρ_self, xstate shift."""
    lam = _accumulate_means(docs, assign, k)
    norms = jnp.sqrt(jnp.sum(lam * lam, axis=1, keepdims=True))
    empty = norms[:, 0] == 0.0
    # Empty clusters keep their previous mean (still a unit vector) so the
    # exactness property vs Lloyd from identical states is preserved.
    means = jnp.where(empty[:, None], prev_state.index.means_t.T, lam / jnp.maximum(norms, 1e-12))

    # Exact invariance: a centroid moved iff its membership changed.
    changed = assign != prev_assign
    moving = jnp.zeros((k,), jnp.int32)
    moving = moving.at[assign].max(changed.astype(jnp.int32))
    moving = moving.at[prev_assign].max(changed.astype(jnp.int32))
    moving = moving.astype(bool)

    index = build_mean_index(means, params, moving=moving)
    rho_self = _self_sims(docs, index.means_t, assign)
    return KMeansState(
        index=index,
        assign=assign,
        rho_self=rho_self,
        rho_self_prev=prev_state.rho_self,
        iteration=prev_state.iteration + 1,
    )


def init_state(docs: SparseDocs, k: int, params: StructuralParams, *, seed: int = 0) -> KMeansState:
    """Random seeding: K distinct documents as initial centroids.

    App. H shows clustering results in this regime are initial-state
    independent, so random seeding matches k-means++ quality at far lower
    cost; seeding strategies are explicitly out of the paper's scope (§I).
    """
    key = jax.random.PRNGKey(seed)
    pick = jax.random.choice(key, docs.n_docs, shape=(k,), replace=False)
    sel = SparseDocs(ids=docs.ids[pick], vals=docs.vals[pick], nnz=docs.nnz[pick], dim=docs.dim)
    means = jnp.zeros((k, docs.dim), jnp.float32)
    rows = jnp.arange(k)[:, None]
    means = means.at[rows, sel.ids].add(jnp.where(sel.row_mask(), sel.vals, 0.0))
    norms = jnp.sqrt(jnp.sum(means**2, axis=1, keepdims=True))
    means = means / jnp.maximum(norms, 1e-12)
    index = build_mean_index(means, params)
    n = docs.n_docs
    return KMeansState(
        index=index,
        assign=jnp.zeros((n,), jnp.int32),
        rho_self=jnp.full((n,), -jnp.inf, jnp.float32),
        rho_self_prev=jnp.full((n,), -jnp.inf, jnp.float32),
        iteration=jnp.asarray(0, jnp.int32),
    )
