"""Lloyd-iteration driver for accelerated spherical K-means.

Runs assignment (selected algorithm × backend) → update → [EstParams at
iterations 1–2] until no assignment changes, collecting the paper's
diagnostics per iteration: Mult (multiply-adds), CPR (complementary pruning
rate, Eq. 22), #changed, objective J (Eq. 47).  All algorithms converge to
the identical fixed point from the same seed — the acceleration contract.

Host-sync discipline (DESIGN.md §8): the fit is an *unrolled prologue*
covering the EstParams iterations (estimating (t_th, v_th) needs host-side
grid bookkeeping) followed by ONE jitted, buffer-donated call that runs the
rest of the fit as a ``lax.while_loop`` on device — assignment epoch →
update → ρ_self refresh → convergence test per trip, with every diagnostic
written into a per-iteration ring buffer carried through the loop.  The
host pulls diagnostics once per prologue iteration (≤ 2) and once for the
whole fused remainder: O(1) syncs per *fit*, independent of n_iter.

Each assignment epoch is a ``lax.map`` over reshaped batches: documents are
padded to a batch-size multiple with dead rows (nnz = 0, ρ_self = 0) that
are masked out of every diagnostic; the tail batch therefore runs through
the identical code path as full batches (tested in tests/test_backends.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.sparse import SparseDocs, pad_rows
from repro.core.meanindex import StructuralParams
from repro.core.assignment import assign_batch
from repro.core.update import update_step, init_state, KMeansState
from repro.core.estparams import estimate_params, EstGrid

# Single host-sync points — module-level so tests can wrap them and count
# device→host transfers.
_host_pull = jax.device_get


@partial(jax.jit, static_argnames=("algo", "backend", "bs"))
def _fused_epoch(algo: str, backend: str, docs: SparseDocs, index,
                 assign, rho_self, xstate, valid, bs: int):
    """One full assignment epoch, on device.

    Returns (assign (N,), mult (), cand_sum (), n_changed ()) — the
    per-batch Python loop and its per-batch host syncs collapse into a
    single ``lax.map`` whose scalar diagnostics are reduced on device.
    (Per-object ρ is not returned: the update step refreshes ρ_self against
    the *new* means anyway.)
    """
    n = docs.ids.shape[0]
    nb = n // bs
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])

    def batch_fn(args):
        bids, bvals, bnnz, bassign, brho, bxs, bvalid = args
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=docs.dim)
        res = assign_batch(algo, backend, bdocs, index, bassign, brho, bxs)
        cand = jnp.where(bvalid, res.n_candidates, 0)
        changed = res.changed & bvalid
        return (res.assign, jnp.sum(cand), jnp.sum(changed), res.mult)

    a, cand, changed, mult = lax.map(
        batch_fn, (resh(docs.ids), resh(docs.vals), resh(docs.nnz),
                   resh(assign), resh(rho_self), resh(xstate), resh(valid)))
    return a.reshape(n), jnp.sum(mult), jnp.sum(cand), jnp.sum(changed)


def _device_iteration(algo, backend, docs, state, valid, *, bs, k):
    """One full Lloyd iteration (epoch + update), traceable on device.

    Returns (state', (mult, cand_sum, n_changed, objective)).  Shared by the
    host-stepped prologue and the fused while_loop body, so both paths run
    the identical computation graph.
    """
    prev_assign = state.assign
    assign, mult, cand_sum, n_changed = _fused_epoch(
        algo, backend, docs, state.index, state.assign, state.rho_self,
        state.xstate, valid, bs)
    state = update_step(docs, assign, prev_assign, state,
                        state.index.params, k=k, backend=backend)
    objective = jnp.sum(jnp.where(valid, state.rho_self, 0.0))
    return state, (mult, cand_sum, n_changed, objective)


def _fused_fit_body(state, docs, valid, last_changed, *, algo, backend, bs,
                    k, max_steps):
    """The fused remainder of the fit: a ``lax.while_loop`` over iterations.

    Carries (state, step counter, #changed of the previous iteration, ring
    buffer).  The ring buffer holds one slot per potential iteration for
    every diagnostic; slots past the executed step count stay zero and are
    discarded on the host.  Entering with ``last_changed == 0`` (the
    prologue already converged) runs zero trips.
    """
    zf = jnp.zeros((max_steps,), jnp.float32)
    zi = jnp.zeros((max_steps,), jnp.int32)
    ring = {"mult": zf, "cand": zf, "changed": zi, "objective": zf,
            "n_moving": zi, "t_th": zi, "v_th": zf}

    def cond(carry):
        _, it, changed, _ = carry
        return (it < max_steps) & (changed != 0)

    def body(carry):
        state, it, _, ring = carry
        state, (mult, cand, changed, obj) = _device_iteration(
            algo, backend, docs, state, valid, bs=bs, k=k)
        changed = changed.astype(jnp.int32)
        ring = {
            "mult": ring["mult"].at[it].set(mult),
            "cand": ring["cand"].at[it].set(cand.astype(jnp.float32)),
            "changed": ring["changed"].at[it].set(changed),
            "objective": ring["objective"].at[it].set(obj),
            "n_moving": ring["n_moving"].at[it].set(state.index.n_moving),
            "t_th": ring["t_th"].at[it].set(state.index.params.t_th),
            "v_th": ring["v_th"].at[it].set(state.index.params.v_th),
        }
        return (state, it + 1, changed, ring)

    state, n_steps, _, ring = lax.while_loop(
        cond, body,
        (state, jnp.asarray(0, jnp.int32), last_changed, ring))
    return state, n_steps, ring


@functools.lru_cache(maxsize=None)
def _fused_fit_fn(algo: str, backend: str, bs: int, k: int, max_steps: int):
    """Jitted fused-fit entry, donated state buffers (donation is a no-op on
    CPU, where XLA has no aliasing support — skipped to avoid the warning)."""
    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(partial(_fused_fit_body, algo=algo, backend=backend,
                           bs=bs, k=k, max_steps=max_steps),
                   donate_argnums=donate)


def _run_fused(algo, backend, bs, k, max_steps, state, docs, valid,
               last_changed):
    """Indirection point for tests asserting the fused path is one call."""
    fn = _fused_fit_fn(algo, backend, bs, k, max_steps)
    return fn(state, docs, valid, last_changed)


@dataclasses.dataclass
class LloydResult:
    state: KMeansState
    assign: np.ndarray
    history: list
    params: StructuralParams
    converged: bool
    n_iter: int

    @property
    def objective(self) -> float:
        """J = Σ_i x_i·μ_{a(i)} (Eq. 47) at the final state."""
        return float(jnp.sum(self.state.rho_self))


def initial_params(spec, dim: int) -> StructuralParams:
    """'auto' / None / StructuralParams -> the fit's starting thresholds.

    'auto' and None start trivial: t_th=0, v_th=1 puts everything in
    Region 3 under a vacuous bound, i.e. iteration 1 behaves like the
    unfiltered baseline — exactly the paper (EstParams runs at r=1,2).
    """
    if isinstance(spec, StructuralParams):
        return spec
    return StructuralParams.trivial(dim)


def _history_row(r: int, n: int, k: int, mult, cand, changed, obj, nmov,
                 t_th, v_th, elapsed: float) -> dict:
    return {
        "iteration": r,
        "mult": float(mult),
        "cpr": float(cand) / (n * k),
        "n_changed": int(changed),
        "objective": float(obj),
        "n_moving": int(nmov),
        "elapsed_s": elapsed,
        "t_th": int(t_th),
        "v_th": float(v_th),
    }


def lloyd_fit(docs: SparseDocs, *, k: int, algo: str = "esicp",
              backend: str = "reference", params="auto",
              batch_size: int = 4096, max_iter: int = 60,
              est_grid: EstGrid | None = None, est_iters=(1, 2),
              seed: int = 0, df: jax.Array | None = None) -> LloydResult:
    """Single-host Lloyd fit: the paper's pipeline as one function.

    algo: 'mivi' | 'icp' | 'es' | 'esicp' | 'ta-icp' | 'cs-icp'
    backend: 'reference' | 'pallas' | 'auto' — accumulator engine for the
            assignment AND update steps (core/backends.py; 'auto' = pallas
            on TPU).
    params: 'auto' (EstParams at iterations 1–2, the paper's default),
            StructuralParams for fixed thresholds, or None -> trivial.

    This is the ``single_host`` execution strategy behind the
    :class:`repro.cluster.SphericalKMeans` estimator; call the estimator for
    the artifact-producing front door, this for the raw :class:`LloydResult`.
    """
    est_grid = est_grid or EstGrid()
    est_iters = tuple(est_iters)
    n = docs.n_docs
    init_params = initial_params(params, docs.dim)
    # Seeding picks centroids among the *real* documents, before padding.
    state = init_state(docs, k, init_params, seed=seed)
    if df is None:
        df = docs.df            # cached on the corpus (sparse/matrix.py)

    bs = min(batch_size, n)
    pdocs = pad_rows(docs, bs)
    n_pad = pdocs.n_docs
    valid = jnp.arange(n_pad) < n
    if n_pad != n:
        pad = n_pad - n
        # Dead rows carry ρ_self = 0 — exactly the value every update
        # step recomputes for them (no live tuples ⇒ zero similarity) —
        # and the objective reduction masks on `valid` regardless, so
        # padding never leaks into the history.
        state = dataclasses.replace(
            state,
            assign=jnp.pad(state.assign, (0, pad)),
            rho_self=jnp.pad(state.rho_self, (0, pad)),
            rho_self_prev=jnp.pad(state.rho_self_prev, (0, pad)),
        )

    history = []
    converged = False

    # --- Prologue: the EstParams iterations, host-stepped -------------
    # estimate_params needs host-side grid bookkeeping (dynamic-shape
    # candidate grids), so iterations 1..max(est_iters) run outside the
    # fused loop: still fully on device per step, with one diagnostic
    # pull each — a constant ≤ max(est_iters) syncs.
    prologue = 0
    if params == "auto" and est_iters:
        prologue = min(max(est_iters), max_iter)
    for r in range(1, prologue + 1):
        t0 = time.perf_counter()
        state, (mult, cand_sum, n_changed, _) = _device_iteration(
            algo, backend, pdocs, state, valid, bs=bs, k=k)
        if r in est_iters:
            # EstParams sees only the real rows (padding would skew the
            # Mult-estimate tables).
            new_params, _ = estimate_params(docs, df, state.index.means_t,
                                            state.rho_self[:n], k=k,
                                            grid=est_grid)
            state = dataclasses.replace(
                state, index=state.index.with_params(new_params))
        diag = _host_pull(
            (mult, cand_sum, n_changed,
             jnp.sum(jnp.where(valid, state.rho_self, 0.0)),
             state.index.n_moving, state.index.params.t_th,
             state.index.params.v_th))
        history.append(_history_row(
            r, n, k, *diag, time.perf_counter() - t0))
        if history[-1]["n_changed"] == 0:
            converged = True
            break

    # --- Fused remainder: one jitted call, O(1) host syncs ------------
    max_steps = max_iter - len(history)
    if not converged and max_steps > 0:
        last_changed = jnp.asarray(
            history[-1]["n_changed"] if history else 1, jnp.int32)
        t0 = time.perf_counter()
        state, n_steps, ring = _run_fused(
            algo, backend, bs, k, max_steps,
            state, pdocs, valid, last_changed)
        # The one device→host sync of the fused remainder: the executed
        # step count and every diagnostic ring cross in a single pull.
        steps, ring_h = _host_pull((n_steps, ring))
        steps = int(steps)
        per_iter = (time.perf_counter() - t0) / max(steps, 1)
        for i in range(steps):
            history.append(_history_row(
                len(history) + 1, n, k, ring_h["mult"][i], ring_h["cand"][i],
                ring_h["changed"][i], ring_h["objective"][i],
                ring_h["n_moving"][i], ring_h["t_th"][i],
                ring_h["v_th"][i], per_iter))
        converged = steps > 0 and int(ring_h["changed"][steps - 1]) == 0

    if n_pad != n:
        # Trim the padding rows so state arrays pair with the caller's
        # docs again (dead rows carry ρ_self = 0, so Σ ρ_self — the
        # objective — is identical before and after the trim).
        state = dataclasses.replace(
            state,
            assign=state.assign[:n],
            rho_self=state.rho_self[:n],
            rho_self_prev=state.rho_self_prev[:n],
        )
    return LloydResult(
        state=state,
        assign=np.asarray(state.assign),
        history=history,
        params=state.index.params,
        converged=converged,
        n_iter=len(history),
    )


def __getattr__(name):
    # Back-compat: the estimator moved to repro.cluster.estimator (PR 3's
    # API redesign); ``from repro.core.lloyd import SphericalKMeans`` keeps
    # resolving without dragging the cluster facade into this module's
    # import graph.
    if name == "SphericalKMeans":
        from repro.cluster.estimator import SphericalKMeans
        return SphericalKMeans
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
