"""Lloyd-iteration driver for accelerated spherical K-means.

Runs assignment (selected algorithm × backend) → update → [EstParams at
iterations 1–2] until no assignment changes, collecting the paper's
diagnostics per iteration: Mult (multiply-adds), CPR (complementary pruning
rate, Eq. 22), #changed, objective J (Eq. 47).  All algorithms converge to
the identical fixed point from the same seed — the acceleration contract.

Host-sync discipline (DESIGN.md §8): the fit is an *unrolled prologue*
covering the EstParams iterations (estimating (t_th, v_th) needs host-side
grid bookkeeping) followed by ONE jitted, buffer-donated call that runs the
rest of the fit as a ``lax.while_loop`` on device — assignment epoch →
update → ρ_self refresh → convergence test per trip, with every diagnostic
written into a per-iteration ring buffer carried through the loop.  The
host pulls diagnostics once per prologue iteration (≤ 2) and once for the
whole fused remainder: O(1) syncs per *fit*, independent of n_iter.

Each assignment epoch is a ``lax.map`` over reshaped batches: documents are
padded to a batch-size multiple with dead rows (nnz = 0, ρ_self = 0) that
are masked out of every diagnostic; the tail batch therefore runs through
the identical code path as full batches (tested in tests/test_backends.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.sparse import SparseDocs, pad_rows
from repro.core.backends import resolve_backend
from repro.core.meanindex import (StructuralParams, build_mean_index,
                                  normalized_means)
from repro.core.assignment import assign_batch
from repro.core.update import (KMeansState, drift_loosen, group_drift,
                               init_state, init_state_from_store,
                               n_ub_groups, moving_flags, update_step)
from repro.core.estparams import estimate_params, EstGrid

# Single host-sync points — module-level so tests can wrap them and count
# device→host transfers.
_host_pull = jax.device_get


def _plan_tiles(plan, nb: int, bs: int):
    """A tiled :class:`~repro.kernels.plan.KernelPlan`'s leaves reshaped for
    a (nb, bs)-tile ``lax.scan`` — the per-tile xs the epoch scans beside
    the data tiles.  None plan (reference backend) → None."""
    if plan is None:
        return None
    resh2 = lambda a: None if a is None else a.reshape((nb, -1) + a.shape[1:])
    return (resh2(plan.occ), resh2(plan.head), resh2(plan.headc))


def _tile_plan(plan, xs_plan):
    """Rebuild the per-tile plan from a scan step's sliced leaves."""
    if plan is None or xs_plan is None:
        return None
    occ, head, headc = xs_plan
    return dataclasses.replace(plan, occ=occ, head=head, headc=headc)


def _update_plan(plan, bs: int):
    """The plan as the full-array update phase may consume it: the cached
    head slabs always apply, but a per-``bs``-tile occupancy grouping only
    coincides with the flat call's ``b_blk`` grouping when the tile size is
    a ``b_blk`` multiple — otherwise drop occ (recomputed inline)."""
    if plan is None:
        return None
    return plan if bs % plan.b_blk == 0 else plan.without_occ()


@partial(jax.jit, static_argnames=("algo", "backend", "bs"))
def _fused_epoch(algo: str, backend: str, docs: SparseDocs, index,
                 assign, rho_self, xstate, valid, bs: int, plan=None,
                 ub=None):
    """One full assignment epoch over a resident slab, on device.

    A chunk-scan: ``lax.scan`` over ``bs``-row tiles whose *carry* is the
    scalar diagnostic accumulators (Mult, |Z| sum, #changed) and whose
    stacked output is the per-tile assignment + refreshed per-object bound —
    no per-batch host syncs, and no (nb,)-shaped diagnostic intermediates to
    reduce afterwards.  The same scan body serves every tile (uniform
    shapes), which is what lets the streaming fit reuse this function per
    DocStore chunk.  (Per-object ρ is not returned: the update step
    refreshes ρ_self against the *new* means anyway.)

    ``plan`` is the backend's prepared epoch-invariant cache built with
    ``tile_rows=bs`` (``Backend.prepare``); its occupancy/head-slab arrays
    ride the scan as per-tile xs beside the data tiles.  ``ub`` is the
    maintained per-object bound (bounds modes; None → +inf 'unknown').
    """
    n = docs.ids.shape[0]
    nb = n // bs
    if ub is None:
        ub = jnp.full((n, n_ub_groups(index.k)), jnp.inf, jnp.float32)
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])

    def tile_fn(carry, xs):
        (bids, bvals, bnnz, bassign, brho, bxs, bvalid, bub), xs_plan = xs
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=docs.dim)
        res = assign_batch(algo, backend, bdocs, index, bassign, brho, bxs,
                           _tile_plan(plan, xs_plan), bub)
        mult, cand, changed = carry
        carry = (mult + res.mult,
                 cand + jnp.sum(jnp.where(bvalid, res.n_candidates, 0)),
                 changed + jnp.sum(res.changed & bvalid))
        return carry, (res.assign, res.ub)

    carry0 = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32),
              jnp.zeros((), jnp.int32))
    (mult, cand, changed), (a, u) = lax.scan(
        tile_fn, carry0,
        ((resh(docs.ids), resh(docs.vals), resh(docs.nnz),
          resh(assign), resh(rho_self), resh(xstate), resh(valid),
          resh(ub)),
         _plan_tiles(plan, nb, bs)))
    return a.reshape(n), u.reshape((n,) + u.shape[2:]), mult, cand, changed


def _device_iteration(algo, backend, docs, state, valid, *, bs, k,
                      plan=None):
    """One full Lloyd iteration (epoch + update), traceable on device.

    Returns (state', (mult, cand_sum, n_changed, objective)).  Shared by the
    host-stepped prologue and the fused while_loop body, so both paths run
    the identical computation graph.
    """
    prev_assign = state.assign
    assign, ub, mult, cand_sum, n_changed = _fused_epoch(
        algo, backend, docs, state.index, state.assign, state.rho_self,
        state.xstate, valid, bs, plan, state.ub)
    state = update_step(docs, assign, prev_assign, state,
                        state.index.params, k=k, backend=backend,
                        plan=_update_plan(plan, bs), ub=ub)
    objective = jnp.sum(jnp.where(valid, state.rho_self, 0.0))
    return state, (mult, cand_sum, n_changed, objective)


def _fused_fit_body(state, docs, valid, last_changed, plan, *, algo, backend,
                    bs, k, max_steps):
    """The fused remainder of the fit: a ``lax.while_loop`` over iterations.

    Carries (state, step counter, #changed of the previous iteration, ring
    buffer).  The ring buffer holds one slot per potential iteration for
    every diagnostic; slots past the executed step count stay zero and are
    discarded on the host.  Entering with ``last_changed == 0`` (the
    prologue already converged) runs zero trips.
    """
    zf = jnp.zeros((max_steps,), jnp.float32)
    zi = jnp.zeros((max_steps,), jnp.int32)
    ring = {"mult": zf, "cand": zf, "changed": zi, "objective": zf,
            "n_moving": zi, "t_th": zi, "v_th": zf}

    def cond(carry):
        _, it, changed, _ = carry
        return (it < max_steps) & (changed != 0)

    def body(carry):
        state, it, _, ring = carry
        state, (mult, cand, changed, obj) = _device_iteration(
            algo, backend, docs, state, valid, bs=bs, k=k, plan=plan)
        changed = changed.astype(jnp.int32)
        ring = {
            "mult": ring["mult"].at[it].set(mult),
            "cand": ring["cand"].at[it].set(cand.astype(jnp.float32)),
            "changed": ring["changed"].at[it].set(changed),
            "objective": ring["objective"].at[it].set(obj),
            "n_moving": ring["n_moving"].at[it].set(state.index.n_moving),
            "t_th": ring["t_th"].at[it].set(state.index.params.t_th),
            "v_th": ring["v_th"].at[it].set(state.index.params.v_th),
        }
        return (state, it + 1, changed, ring)

    state, n_steps, _, ring = lax.while_loop(
        cond, body,
        (state, jnp.asarray(0, jnp.int32), last_changed, ring))
    return state, n_steps, ring


@functools.lru_cache(maxsize=None)
def _fused_fit_fn(algo: str, backend: str, bs: int, k: int, max_steps: int):
    """Jitted fused-fit entry, donated state buffers (donation is a no-op on
    CPU, where XLA has no aliasing support — skipped to avoid the warning)."""
    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(partial(_fused_fit_body, algo=algo, backend=backend,
                           bs=bs, k=k, max_steps=max_steps),
                   donate_argnums=donate)


def _run_fused(algo, backend, bs, k, max_steps, state, docs, valid,
               last_changed, plan=None):
    """Indirection point for tests asserting the fused path is one call."""
    fn = _fused_fit_fn(algo, backend, bs, k, max_steps)
    return fn(state, docs, valid, last_changed, plan)


@dataclasses.dataclass
class LloydResult:
    state: KMeansState
    assign: np.ndarray
    history: list
    params: StructuralParams
    converged: bool
    n_iter: int
    # Streaming fits only: (next_epoch, next_chunk) where a resumed fit
    # would continue — None for converged / resident fits.
    cursor: tuple | None = None
    # The autotuned kernel config the fit's plans were built with
    # (repro.tune.TunedConfig), or None when tuning was off / missed.
    # Rides into FittedModel so save/load round-trips the winner.
    tuned: object | None = None

    @property
    def objective(self) -> float:
        """J = Σ_i x_i·μ_{a(i)} (Eq. 47) at the final state."""
        return float(jnp.sum(self.state.rho_self))


def initial_params(spec, dim: int) -> StructuralParams:
    """'auto' / None / StructuralParams -> the fit's starting thresholds.

    'auto' and None start trivial: t_th=0, v_th=1 puts everything in
    Region 3 under a vacuous bound, i.e. iteration 1 behaves like the
    unfiltered baseline — exactly the paper (EstParams runs at r=1,2).
    """
    if isinstance(spec, StructuralParams):
        return spec
    return StructuralParams.trivial(dim)


def _history_row(r: int, n: int, k: int, mult, cand, changed, obj, nmov,
                 t_th, v_th, elapsed: float) -> dict:
    return {
        "iteration": r,
        "mult": float(mult),
        "cpr": float(cand) / (n * k),
        "n_changed": int(changed),
        "objective": float(obj),
        "n_moving": int(nmov),
        "elapsed_s": elapsed,
        "t_th": int(t_th),
        "v_th": float(v_th),
    }


def lloyd_fit(docs: SparseDocs, *, k: int, algo: str = "esicp",
              backend: str = "reference", params="auto",
              batch_size: int = 4096, max_iter: int = 60,
              est_grid: EstGrid | None = None, est_iters=(1, 2),
              seed: int = 0, df: jax.Array | None = None,
              tune: str = "off", tune_budget=None) -> LloydResult:
    """Single-host Lloyd fit: the paper's pipeline as one function.

    algo: 'mivi' | 'icp' | 'es' | 'esicp' | 'ta-icp' | 'cs-icp'
    backend: 'reference' | 'pallas' | 'xla_blocked' | 'auto' — accumulator
            engine for the assignment AND update steps (core/backends.py;
            'auto' = pallas on TPU, the compiled xla_blocked twins
            elsewhere).
    params: 'auto' (EstParams at iterations 1–2, the paper's default),
            StructuralParams for fixed thresholds, or None -> trivial.
    tune: 'off' | 'cached' | 'search' — kernel-engine autotuning
            (``Backend.prepare``; no-op on the reference backend).
            ``tune_budget`` is a :class:`repro.tune.SearchBudget` (or int
            max-timed-candidates) for 'search' mode.

    This is the ``single_host`` execution strategy behind the
    :class:`repro.cluster.SphericalKMeans` estimator; call the estimator for
    the artifact-producing front door, this for the raw :class:`LloydResult`.
    """
    est_grid = est_grid or EstGrid()
    est_iters = tuple(est_iters)
    n = docs.n_docs
    init_params = initial_params(params, docs.dim)
    # Seeding picks centroids among the *real* documents, before padding.
    state = init_state(docs, k, init_params, seed=seed)
    if df is None:
        df = docs.df            # cached on the corpus (sparse/matrix.py)

    bs = min(batch_size, n)
    pdocs = pad_rows(docs, bs)
    n_pad = pdocs.n_docs
    valid = jnp.arange(n_pad) < n
    # Epoch-invariant kernel plan (occupancy + cached high-df head slabs):
    # documents never change across Lloyd iterations, so the pallas
    # backend densifies the head region and maps the live cells exactly
    # once per fit; the reference backend has nothing to cache (None).
    plan = resolve_backend(backend).prepare(pdocs, tile_rows=bs, k=k,
                                            tune=tune,
                                            tune_budget=tune_budget)
    if n_pad != n:
        pad = n_pad - n
        # Dead rows carry ρ_self = 0 — exactly the value every update
        # step recomputes for them (no live tuples ⇒ zero similarity) —
        # and the objective reduction masks on `valid` regardless, so
        # padding never leaks into the history.
        state = dataclasses.replace(
            state,
            assign=jnp.pad(state.assign, (0, pad)),
            rho_self=jnp.pad(state.rho_self, (0, pad)),
            rho_self_prev=jnp.pad(state.rho_self_prev, (0, pad)),
            # Dead rows pad ub = 0 (the ρ_self convention's twin): their
            # bound may drift upward across updates, which is harmless —
            # dead rows have no live tuples, so they contribute zero Mult
            # and are valid-masked out of |Z| / #changed.
            ub=jnp.pad(state.ub, ((0, pad), (0, 0))),
        )

    history = []
    converged = False

    # --- Prologue: the EstParams iterations, host-stepped -------------
    # estimate_params needs host-side grid bookkeeping (dynamic-shape
    # candidate grids), so iterations 1..max(est_iters) run outside the
    # fused loop: still fully on device per step, with one diagnostic
    # pull each — a constant ≤ max(est_iters) syncs.
    prologue = 0
    if params == "auto" and est_iters:
        prologue = min(max(est_iters), max_iter)
    for r in range(1, prologue + 1):
        t0 = time.perf_counter()
        state, (mult, cand_sum, n_changed, _) = _device_iteration(
            algo, backend, pdocs, state, valid, bs=bs, k=k, plan=plan)
        if r in est_iters:
            # EstParams sees only the real rows (padding would skew the
            # Mult-estimate tables).
            new_params, _ = estimate_params(docs, df, state.index.means_t,
                                            state.rho_self[:n], k=k,
                                            grid=est_grid)
            state = dataclasses.replace(
                state, index=state.index.with_params(new_params))
        diag = _host_pull(
            (mult, cand_sum, n_changed,
             jnp.sum(jnp.where(valid, state.rho_self, 0.0)),
             state.index.n_moving, state.index.params.t_th,
             state.index.params.v_th))
        history.append(_history_row(
            r, n, k, *diag, time.perf_counter() - t0))
        if history[-1]["n_changed"] == 0:
            converged = True
            break

    # --- Fused remainder: one jitted call, O(1) host syncs ------------
    max_steps = max_iter - len(history)
    if not converged and max_steps > 0:
        last_changed = jnp.asarray(
            history[-1]["n_changed"] if history else 1, jnp.int32)
        t0 = time.perf_counter()
        state, n_steps, ring = _run_fused(
            algo, backend, bs, k, max_steps,
            state, pdocs, valid, last_changed, plan)
        # The one device→host sync of the fused remainder: the executed
        # step count and every diagnostic ring cross in a single pull.
        steps, ring_h = _host_pull((n_steps, ring))
        steps = int(steps)
        per_iter = (time.perf_counter() - t0) / max(steps, 1)
        for i in range(steps):
            history.append(_history_row(
                len(history) + 1, n, k, ring_h["mult"][i], ring_h["cand"][i],
                ring_h["changed"][i], ring_h["objective"][i],
                ring_h["n_moving"][i], ring_h["t_th"][i],
                ring_h["v_th"][i], per_iter))
        converged = steps > 0 and int(ring_h["changed"][steps - 1]) == 0

    if n_pad != n:
        # Trim the padding rows so state arrays pair with the caller's
        # docs again (dead rows carry ρ_self = 0, so Σ ρ_self — the
        # objective — is identical before and after the trim).
        state = dataclasses.replace(
            state,
            assign=state.assign[:n],
            rho_self=state.rho_self[:n],
            rho_self_prev=state.rho_self_prev[:n],
            ub=state.ub[:n],
        )
    return LloydResult(
        state=state,
        assign=np.asarray(state.assign),
        history=history,
        params=state.index.params,
        converged=converged,
        n_iter=len(history),
        tuned=None if plan is None else plan.tuned,
    )


# ---------------------------------------------------------------------------
# Streaming (out-of-core) fit over a DocStore — DESIGN.md §10.
#
# The corpus never becomes one resident (N, P) array: each epoch is a
# chunk-scan over the store's uniform (C, P) chunks, fed by the async
# double-buffered prefetcher.  Only the small per-document state (assign,
# ρ_self, ρ_prev — one scalar each) and the (K, D) accumulators stay on
# device.  Host-sync discipline: every per-chunk call is an async dispatch;
# the ONE `_host_pull` per epoch reads the epoch diagnostics + convergence
# flag (O(1) syncs per epoch — the streaming analogue of §8's O(1) per fit,
# and the floor once the host must feed chunks).
# ---------------------------------------------------------------------------

# v2 added the per-object bound state (ub / ub_work) for the bounds algo
# modes; v1 checkpoints are rejected loudly by the format check below.
STREAM_CKPT_FORMAT = "repro.cluster/stream-ckpt-v2"

# Host-memory ceiling for cached per-chunk kernel plans (occupancy + head
# slabs).  Chunks over budget are re-prepared each epoch instead of cached —
# a compute/memory trade, never a correctness one.
STREAM_PLAN_CACHE_BYTES = 512 << 20


class _ChunkPlanCache:
    """Once-per-chunk-per-fit kernel plans for the streaming fit.

    Epoch 1 builds each chunk's :class:`~repro.kernels.plan.KernelPlan`
    (occupancy + densified head slabs) on the prefetcher's producer thread
    and parks a host copy; later epochs ``device_put`` the cached copy so
    the prepared slabs ride H2D beside the raw chunk instead of being
    re-densified.  A byte budget bounds host residency: chunks past it are
    simply re-prepared every epoch.  ``None`` plans (reference backend:
    nothing to cache) cost nothing and short-circuit.
    """

    def __init__(self, backend, tile_rows: int,
                 max_bytes: int = STREAM_PLAN_CACHE_BYTES,
                 k: int | None = None, tune: str = "off", tune_budget=None):
        self._bk = backend
        self._tile_rows = tile_rows
        self._max_bytes = max_bytes
        self._host: dict[int, object] = {}
        self._bytes = 0
        self._k = k
        self._tune = tune
        self._tune_budget = tune_budget
        # Winning TunedConfig of the fit's chunks, surfaced on LloydResult.
        # Uniform chunks share a corpus signature, so the first chunk's
        # search is every later chunk's TUNED_CACHE hit.
        self.tuned = None

    @staticmethod
    def _nbytes(plan) -> int:
        return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(plan))

    def __call__(self, ci: int, cdocs):
        if ci in self._host:
            cached = self._host[ci]
            return None if cached is None else jax.device_put(cached)
        plan = self._bk.prepare(cdocs, tile_rows=self._tile_rows, k=self._k,
                                tune=self._tune,
                                tune_budget=self._tune_budget)
        if plan is not None and self.tuned is None:
            self.tuned = plan.tuned
        if plan is None:
            self._host[ci] = None
            return None
        size = self._nbytes(plan)
        if self._bytes + size <= self._max_bytes:
            self._host[ci] = jax.device_get(plan)
            self._bytes += size
        return plan


def _tile_bs(chunk_size: int, batch_size: int) -> int:
    """Tile size for scanning a (C, P) chunk: min(batch_size, C).  When the
    chunk is not a tile multiple, the chunk STEPS pad it with dead rows
    (ρ_self = 0 convention, valid-masked) rather than shrinking the tile —
    a prime chunk_size must not silently degrade into a per-row scan."""
    return max(min(batch_size, chunk_size), 1)


def _pad_chunk(cdocs: SparseDocs, extras: tuple, bs: int):
    """Pad a chunk (and its per-row companions) to a ``bs`` row multiple
    with dead rows; no-op when already aligned.  Static shapes only, so
    this folds into the jitted chunk step."""
    c = cdocs.ids.shape[0]
    pad = (-c) % bs
    if pad == 0:
        return cdocs, extras
    return (pad_rows(cdocs, bs),
            tuple(jnp.pad(e, ((0, pad),) + ((0, 0),) * (e.ndim - 1))
                  for e in extras))


# One jitted slice-writer shared by every per-document array update: `start`
# is traced, so all chunks of a fit share a single compiled program.
_set_slice = jax.jit(
    lambda buf, val, start: lax.dynamic_update_slice_in_dim(buf, val, start, 0))


@partial(jax.jit, static_argnames=("algo", "backend", "bs", "k"))
def _stream_chunk_step(algo: str, backend: str, cdocs: SparseDocs, index,
                       a_c, rho_c, xs_c, valid_c, ub_c, lam, mult, cand,
                       changed, *, bs: int, k: int, plan=None):
    """Full-batch streaming: one chunk's share of the epoch.

    Runs the identical chunk-scan `_fused_epoch` on the (C, P) tile and
    folds the chunk's cluster sums into the epoch λ accumulator via the
    backend (``init=`` is the chunked-caller hook on
    ``Backend.accumulate_means``).  One chunk == the whole corpus is the
    resident ``update_step`` bit for bit (parity-tested).  ``plan`` is the
    chunk's prepared kernel cache, carried H2D beside the chunk by the
    prefetcher (built once per chunk per fit)."""
    n_c = cdocs.ids.shape[0]
    cdocs, (a_c, rho_c, xs_c, valid_c, ub_c) = _pad_chunk(
        cdocs, (a_c, rho_c, xs_c, valid_c, ub_c), bs)
    a_new, ub_new, m, c, ch = _fused_epoch(algo, backend, cdocs, index, a_c,
                                           rho_c, xs_c, valid_c, bs, plan,
                                           ub_c)
    mvals = jnp.where(cdocs.row_mask(), cdocs.vals, 0.0)
    bk = resolve_backend(backend)
    lam = bk.accumulate_means(cdocs.ids, mvals, a_new, k=k, dim=cdocs.dim,
                              init=lam, plan=_update_plan(plan, bs))
    return a_new[:n_c], ub_new[:n_c], lam, mult + m, cand + c, changed + ch


@partial(jax.jit, static_argnames=("k",))
def _stream_update_index(lam, means_t_prev, assign, prev_assign, params, *,
                         k: int):
    """Epoch finalize: λ → unit means → fresh index + exact ICP flags (the
    non-chunked half of ``update_step``)."""
    means = normalized_means(lam, means_t_prev)
    return build_mean_index(means, params,
                            moving=moving_flags(assign, prev_assign, k))


@partial(jax.jit, static_argnames=("backend",))
def _stream_rho_chunk(backend: str, cdocs: SparseDocs, a_c, means_t,
                      plan=None):
    """ρ_self refresh for one chunk vs the NEW means (Alg. 6 lines 6–7) —
    row-independent, so the chunked refresh equals the resident one.  The
    chunk plan's cached head slabs apply after slicing to the unpadded
    chunk rows (occupancy is re-derived inline)."""
    bk = resolve_backend(backend)
    mvals = jnp.where(cdocs.row_mask(), cdocs.vals, 0.0)
    rplan = None if plan is None else plan.slice_rows(cdocs.ids.shape[0])
    return bk.self_sims(cdocs.ids, mvals, a_c, means_t, plan=rplan)


@partial(jax.jit, static_argnames=("backend", "bs", "k"))
def _stream_minibatch_chunk(backend: str, cdocs: SparseDocs, index, a_old,
                            valid_c, m_mean, counts, *, bs: int, k: int,
                            plan=None):
    """Sculley-style mini-batch step on one chunk.

    Exact nearest-centroid assignment (the shared classify accumulators),
    then per-center running means with per-center counts: applying the
    per-sample rule c ← (1−η)c + ηx, η = 1/N_c, over a batch telescopes to

        M_j ← (N_j·M_j + Σ_{x∈chunk, a(x)=j} x) / (N_j + n_j)

    — the batched form reuses ``Backend.accumulate_means`` for the sums.
    Centers the chunk never touched keep their running mean; the served
    index is the L2-projection of M onto the unit sphere."""
    bk = resolve_backend(backend)
    n_c = cdocs.ids.shape[0]
    cdocs, (a_old, valid_c) = _pad_chunk(cdocs, (a_old, valid_c), bs)
    c = cdocs.ids.shape[0]
    nb = c // bs
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])

    def tile(carry, xs):
        (bids, bvals, bnnz), xs_plan = xs
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=cdocs.dim)
        sims = bk.accumulate(bdocs, index, jnp.zeros((bs,), bool),
                             mode="exact", diag=False,
                             plan=_tile_plan(plan, xs_plan))["sims"]
        return carry, jnp.argmax(sims, axis=1).astype(jnp.int32)

    _, a = lax.scan(tile, 0,
                    ((resh(cdocs.ids), resh(cdocs.vals), resh(cdocs.nnz)),
                     _plan_tiles(plan, nb, bs)))
    a = a.reshape(c)
    a = jnp.where(valid_c, a, k)            # dead rows select no centroid
    changed = jnp.sum((a != a_old) & valid_c)
    mvals = jnp.where(cdocs.row_mask(), cdocs.vals, 0.0)
    sums = bk.accumulate_means(cdocs.ids, mvals, a, k=k, dim=cdocs.dim,
                               plan=_update_plan(plan, bs))
    n_j = jnp.zeros((k,), jnp.float32).at[a].add(
        jnp.where(valid_c, 1.0, 0.0))       # a == k scatters are dropped
    new_counts = counts + n_j
    upd = (counts[:, None] * m_mean + sums) \
        / jnp.maximum(new_counts[:, None], 1.0)
    m_mean = jnp.where((n_j > 0)[:, None], upd, m_mean)
    norms = jnp.sqrt(jnp.sum(m_mean**2, axis=1, keepdims=True))
    index_new = build_mean_index(m_mean / jnp.maximum(norms, 1e-12),
                                 index.params)
    return a[:n_c], changed, m_mean, new_counts, index_new


def _stream_ckpt_save(directory, *, step, state, lam, mult, cand, changed,
                      assign_work, ub_work, m_mean, counts, cursor, history,
                      algo_mode):
    from repro.checkpoint.store import save_checkpoint

    tree = {
        "assign": state.assign, "rho_self": state.rho_self,
        "rho_prev": state.rho_self_prev, "iteration": state.iteration,
        "ub": state.ub,
        "means_t": state.index.means_t, "moving": state.index.moving,
        "t_th": state.index.params.t_th, "v_th": state.index.params.v_th,
        "lam": lam, "mult": mult, "cand": cand, "changed": changed,
        "assign_work": assign_work, "ub_work": ub_work,
        "m_mean": m_mean, "counts": counts,
    }
    save_checkpoint(directory, tree, step=step,
                    extra={"format": STREAM_CKPT_FORMAT,
                           "cursor": list(cursor), "history": history,
                           "algo_mode": algo_mode})


def _stream_ckpt_restore(directory, *, n_rows, k, dim):
    from repro.checkpoint.store import load_extra, restore_checkpoint

    extra = load_extra(directory)
    if not extra or extra.get("format") != STREAM_CKPT_FORMAT:
        raise ValueError(f"{directory} holds no {STREAM_CKPT_FORMAT} "
                         f"checkpoint (found "
                         f"{extra.get('format') if extra else None!r})")
    example = {
        "assign": np.zeros((n_rows,), np.int32),
        "rho_self": np.zeros((n_rows,), np.float32),
        "rho_prev": np.zeros((n_rows,), np.float32),
        "iteration": np.asarray(0, np.int32),
        "ub": np.zeros((n_rows, n_ub_groups(k)), np.float32),
        "means_t": np.zeros((dim, k), np.float32),
        "moving": np.zeros((k,), bool),
        "t_th": np.asarray(0, np.int32),
        "v_th": np.asarray(0.0, np.float32),
        "lam": np.zeros((k, dim), np.float32),
        "mult": np.asarray(0.0, np.float32),
        "cand": np.asarray(0, np.int32),
        "changed": np.asarray(0, np.int32),
        "assign_work": np.zeros((n_rows,), np.int32),
        "ub_work": np.zeros((n_rows, n_ub_groups(k)), np.float32),
        "m_mean": np.zeros((k, dim), np.float32),
        "counts": np.zeros((k,), np.float32),
    }
    tree, _ = restore_checkpoint(directory, example)
    tree = {name: jnp.asarray(v) for name, v in tree.items()}
    params = StructuralParams(t_th=tree["t_th"].astype(jnp.int32),
                              v_th=tree["v_th"].astype(jnp.float32))
    index = build_mean_index(tree["means_t"].T, params,
                             moving=tree["moving"])
    state = KMeansState(index=index, assign=tree["assign"],
                        rho_self=tree["rho_self"],
                        rho_self_prev=tree["rho_prev"],
                        iteration=tree["iteration"],
                        ub=tree["ub"])
    return (state, tree, tuple(extra["cursor"]), list(extra["history"]),
            extra.get("algo_mode", "full"))


def streaming_fit(store, *, k: int, algo: str = "esicp",
                  backend: str = "reference", params="auto",
                  algo_mode: str = "full", batch_size: int = 4096,
                  max_iter: int = 60, est_grid: EstGrid | None = None,
                  est_iters=(1, 2), seed: int = 0, df=None,
                  prefetch_depth: int = 2, checkpoint_dir: str | None = None,
                  checkpoint_every: int = 0,
                  resume: bool = False, tune: str = "off",
                  tune_budget=None) -> LloydResult:
    """Lloyd over an out-of-core :class:`repro.sparse.DocStore`.

    algo_mode='full': the exact chunk-scan Lloyd epoch — assignment pass
        (per-chunk `_fused_epoch` + λ accumulation) → index rebuild → ρ_self
        refresh pass.  A one-chunk store reproduces ``lloyd_fit(docs)``
        bit for bit (labels and every history diagnostic; parity-tested).
    algo_mode='minibatch': Sculley-style streaming k-means — one pass over
        the chunks per iteration, centers updated after every chunk with
        per-center counts/learning rates.  Exact nearest-centroid
        assignment (structural pruning thresholds don't apply to centers
        that move every chunk), so ``algo``/``params``/``est_iters`` are
        ignored in this mode.

    EstParams in full mode estimates (t_th, v_th) from the FULL corpus,
    chunk-streamed (:func:`repro.core.estparams.estimate_params_store`) —
    φ̃3 was an object-chunked sum already, so out-of-core costs nothing.

    Checkpointing: with ``checkpoint_dir``, a resumable snapshot commits
    every ``checkpoint_every`` chunks *inside* the epoch (0 → epoch
    boundaries only) plus one at each epoch boundary; ``resume=True``
    restores the latest snapshot — including mid-epoch ones — and
    continues to the identical final labels (tested).
    """
    from repro.sparse.store import ChunkPrefetcher

    if algo_mode not in ("full", "minibatch"):
        raise ValueError(f"algo_mode must be 'full' or 'minibatch', "
                         f"got {algo_mode!r}")
    bk_obj = resolve_backend(backend)
    backend = bk_obj.name
    est_grid = est_grid or EstGrid()
    est_iters = tuple(est_iters)
    n, c, n_rows = store.n_docs, store.chunk_size, store.n_rows
    n_chunks = store.n_chunks
    bs = _tile_bs(c, batch_size)
    valid = jnp.arange(n_rows) < n
    # df feeds EstParams only — don't trigger DocStore.df's full corpus
    # scan for modes that never estimate (minibatch / fixed thresholds).
    need_df = algo_mode == "full" and params == "auto" and bool(est_iters)
    if df is None and need_df:
        df = store.df
    df = None if df is None else jnp.asarray(df)

    minibatch = algo_mode == "minibatch"
    zeros_lam = jnp.zeros((k, store.dim), jnp.float32)
    # Per-chunk kernel plans, built once per fit on the prefetch thread and
    # carried H2D beside the raw chunks (None throughout on the reference
    # backend — nothing to cache).
    plan_cache = _ChunkPlanCache(bk_obj, bs, k=k, tune=tune,
                                 tune_budget=tune_budget)

    if resume:
        if not checkpoint_dir:
            raise ValueError("resume=True needs checkpoint_dir")
        state, tree, (start_epoch, start_chunk), history, ckpt_mode = \
            _stream_ckpt_restore(checkpoint_dir, n_rows=n_rows, k=k,
                                 dim=store.dim)
        if ckpt_mode != algo_mode:
            # Shapes alias across modes, so a silent continue would finish
            # with wrong labels — fail loudly instead.
            raise ValueError(
                f"checkpoint under {checkpoint_dir} was written by an "
                f"algo_mode={ckpt_mode!r} fit; cannot resume it with "
                f"algo_mode={algo_mode!r}")
        lam, mult, cand, changed = (tree["lam"], tree["mult"], tree["cand"],
                                    tree["changed"])
        assign_work, m_mean, counts = (tree["assign_work"], tree["m_mean"],
                                       tree["counts"])
        ub_work = tree["ub_work"]
    else:
        init_params = initial_params(None if minibatch else params,
                                     store.dim)
        state = init_state_from_store(store, k, init_params, seed=seed)
        m_mean = state.index.means_t.T      # (K, D) running means (seeds)
        counts = jnp.zeros((k,), jnp.float32)
        lam, mult, cand, changed = (zeros_lam, jnp.zeros((), jnp.float32),
                                    jnp.zeros((), jnp.int32),
                                    jnp.zeros((), jnp.int32))
        assign_work = state.assign
        ub_work = state.ub
        history = []
        start_epoch, start_chunk = 1, 0

    def maybe_ckpt(r, next_chunk, *, force=False):
        if not checkpoint_dir:
            return
        due = force or (checkpoint_every and next_chunk
                        and next_chunk % checkpoint_every == 0)
        if not due:
            return
        _stream_ckpt_save(
            checkpoint_dir, step=(r - 1) * (n_chunks + 1) + next_chunk,
            state=state, lam=lam, mult=mult, cand=cand, changed=changed,
            assign_work=assign_work, ub_work=ub_work, m_mean=m_mean,
            counts=counts, cursor=(r, next_chunk), history=history,
            algo_mode=algo_mode)

    converged = False
    r = start_epoch - 1
    for r in range(start_epoch, max_iter + 1):
        t0 = time.perf_counter()
        first = start_chunk if r == start_epoch else 0
        # Minibatch centers evolve per chunk; on a mid-epoch resume the
        # checkpointed index (saved after every chunk step) IS the current
        # center state, so picking it up here covers both cases.
        mb_index = state.index
        if first == 0:
            lam, mult, cand, changed = (zeros_lam,
                                        jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.int32),
                                        jnp.zeros((), jnp.int32))
            assign_work = state.assign
            ub_work = state.ub

        xs_full = state.xstate
        # ---- pass A: assignment (+ λ / center updates), chunk-streamed ----
        order = range(first, n_chunks)
        for ci, cdocs, cplan in ChunkPrefetcher(store, depth=prefetch_depth,
                                                order=order,
                                                prepare=plan_cache):
            s = ci * c
            sl = slice(s, s + c)
            if minibatch:
                a_new, ch, m_mean, counts, mb_index = _stream_minibatch_chunk(
                    backend, cdocs, mb_index, state.assign[sl], valid[sl],
                    m_mean, counts, bs=bs, k=k, plan=cplan)
                changed = changed + ch
                cand = cand + jnp.sum(valid[sl]).astype(jnp.int32) * k
                # keep the evolving centers checkpointable: the saved
                # means_t must be the post-chunk centers
                state = dataclasses.replace(state, index=mb_index)
            else:
                a_new, ub_new, lam, mult, cand, changed = _stream_chunk_step(
                    algo, backend, cdocs, state.index, state.assign[sl],
                    state.rho_self[sl], xs_full[sl], valid[sl],
                    state.ub[sl], lam, mult, cand, changed, bs=bs, k=k,
                    plan=cplan)
                ub_work = _set_slice(ub_work, ub_new, s)
            assign_work = _set_slice(assign_work, a_new, s)
            maybe_ckpt(r, ci + 1)

        # ---- finalize: index rebuild (full) + ρ_self refresh pass ---------
        if minibatch:
            index = mb_index
        else:
            index = _stream_update_index(lam, state.index.means_t,
                                         assign_work, state.assign,
                                         state.index.params, k=k)
        rho_parts = []
        for ci, cdocs, cplan in ChunkPrefetcher(store, depth=prefetch_depth,
                                                prepare=plan_cache):
            sl = slice(ci * c, (ci + 1) * c)
            rho_parts.append(_stream_rho_chunk(backend, cdocs,
                                               assign_work[sl],
                                               index.means_t, cplan))
        rho_new = jnp.concatenate(rho_parts)
        if minibatch:
            # Minibatch never consults the bound (exact argmax assignment);
            # carry it untouched.
            ub_full = state.ub
        else:
            # Same semantics as the resident update_step: the refreshed
            # bound holds against the OLD means, so loosen each bound group
            # by its own centroids' worst angular drift this epoch.
            ub_full = drift_loosen(
                ub_work, group_drift(index.means_t,
                                     state.index.means_t))
        state = KMeansState(index=index, assign=assign_work,
                            rho_self=rho_new,
                            rho_self_prev=state.rho_self,
                            iteration=state.iteration + 1,
                            ub=ub_full)

        if not minibatch and params == "auto" and r in est_iters:
            # Full-corpus estimate, chunk-streamed (φ̃3 is an object-chunked
            # sum already); bit-for-bit the resident estimate on a
            # one-chunk store.
            from repro.core.estparams import estimate_params_store

            new_params, _ = estimate_params_store(
                store, df, state.index.means_t, state.rho_self, k=k,
                grid=est_grid)
            state = dataclasses.replace(
                state, index=state.index.with_params(new_params))

        # ---- the ONE host sync of the epoch -------------------------------
        diag = _host_pull(
            (mult, cand, changed,
             jnp.sum(jnp.where(valid, state.rho_self, 0.0)),
             state.index.n_moving, state.index.params.t_th,
             state.index.params.v_th))
        history.append(_history_row(r, n, k, *diag,
                                    time.perf_counter() - t0))
        maybe_ckpt(r + 1, 0, force=bool(checkpoint_dir))
        if history[-1]["n_changed"] == 0:
            converged = True
            break

    state = dataclasses.replace(
        state,
        assign=state.assign[:n],
        rho_self=state.rho_self[:n],
        rho_self_prev=state.rho_self_prev[:n],
        ub=state.ub[:n],
    )
    return LloydResult(
        state=state,
        assign=np.asarray(state.assign),
        history=history,
        params=state.index.params,
        converged=converged,
        n_iter=len(history),
        cursor=None if converged else (r + 1, 0),
        tuned=plan_cache.tuned,
    )


def __getattr__(name):
    # Back-compat: the estimator moved to repro.cluster.estimator (PR 3's
    # API redesign); ``from repro.core.lloyd import SphericalKMeans`` keeps
    # resolving without dragging the cluster facade into this module's
    # import graph.
    if name == "SphericalKMeans":
        from repro.cluster.estimator import SphericalKMeans
        return SphericalKMeans
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
