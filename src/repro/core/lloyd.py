"""Lloyd-iteration driver for accelerated spherical K-means.

Runs assignment (selected algorithm) → update → [EstParams at iterations 1–2]
until no assignment changes, collecting the paper's diagnostics per iteration:
Mult (multiply-adds), CPR (complementary pruning rate, Eq. 22), #changed,
objective J (Eq. 47).  All algorithms converge to the identical fixed point
from the same seed — the acceleration contract.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse import SparseDocs
from repro.core.meanindex import StructuralParams
from repro.core.assignment import assignment_step
from repro.core.update import update_step, init_state, KMeansState
from repro.core.estparams import estimate_params, EstGrid


@dataclasses.dataclass
class LloydResult:
    state: KMeansState
    assign: np.ndarray
    history: list
    params: StructuralParams
    converged: bool
    n_iter: int

    @property
    def objective(self) -> float:
        """J = Σ_i x_i·μ_{a(i)} (Eq. 47) at the final state."""
        return float(jnp.sum(self.state.rho_self))


class SphericalKMeans:
    """sklearn-ish front-end over the core steps.

    algo: 'mivi' | 'icp' | 'es' | 'esicp' | 'ta-icp' | 'cs-icp'
    params: 'auto' (EstParams at iterations 1–2, the paper's default),
            StructuralParams for fixed thresholds, or None -> trivial.
    """

    def __init__(self, k: int, *, algo: str = "esicp", params="auto",
                 batch_size: int = 4096, max_iter: int = 60,
                 est_grid: EstGrid | None = None, est_iters=(1, 2),
                 seed: int = 0):
        self.k = k
        self.algo = algo
        self.params = params
        self.batch_size = batch_size
        self.max_iter = max_iter
        self.est_grid = est_grid or EstGrid()
        self.est_iters = tuple(est_iters)
        self.seed = seed

    def _initial_params(self, dim: int) -> StructuralParams:
        if isinstance(self.params, StructuralParams):
            return self.params
        # 'auto' / None start trivial: t_th=0, v_th=1 puts everything in
        # Region 3 under a vacuous bound, i.e. iteration 1 behaves like the
        # unfiltered baseline — exactly the paper (EstParams runs at r=1,2).
        return StructuralParams.trivial(dim)

    def fit(self, docs: SparseDocs, df: jax.Array | None = None) -> LloydResult:
        n = docs.n_docs
        params = self._initial_params(docs.dim)
        state = init_state(docs, self.k, params, seed=self.seed)
        if df is None:
            from repro.sparse import df_counts
            df = df_counts(docs)

        history = []
        converged = False
        bs = min(self.batch_size, n)
        for r in range(1, self.max_iter + 1):
            t0 = time.perf_counter()
            prev_assign = state.assign
            assigns, rhos, cands, changed = [], [], [], []
            mult = 0.0
            xstate_all = state.xstate
            for start in range(0, n - n % bs, bs):
                batch = state_batch = docs.slice_rows(start, bs)
                res = assignment_step(self.algo, batch, state.index,
                                      state.assign[start:start + bs],
                                      state.rho_self[start:start + bs],
                                      xstate_all[start:start + bs])
                assigns.append(res.assign); rhos.append(res.rho)
                cands.append(res.n_candidates); changed.append(res.changed)
                mult += float(res.mult)
            rem = n % bs
            if rem:
                start = n - rem
                batch = docs.slice_rows(start, rem)
                res = assignment_step(self.algo, batch, state.index,
                                      state.assign[start:], state.rho_self[start:],
                                      xstate_all[start:])
                assigns.append(res.assign); rhos.append(res.rho)
                cands.append(res.n_candidates); changed.append(res.changed)
                mult += float(res.mult)

            assign = jnp.concatenate(assigns)
            n_changed = int(jnp.sum(jnp.concatenate(changed)))
            cpr = float(jnp.mean(jnp.concatenate(cands).astype(jnp.float32))) / self.k

            state = update_step(docs, assign, prev_assign, state, state.index.params,
                                k=self.k)

            if self.params == "auto" and r in self.est_iters:
                new_params, _ = estimate_params(docs, df, state.index.means_t,
                                                state.rho_self, k=self.k,
                                                grid=self.est_grid)
                state = dataclasses.replace(state, index=state.index.with_params(new_params))

            history.append({
                "iteration": r,
                "mult": mult,
                "cpr": cpr,
                "n_changed": n_changed,
                "objective": float(jnp.sum(state.rho_self)),
                "n_moving": int(state.index.n_moving),
                "elapsed_s": time.perf_counter() - t0,
                "t_th": int(state.index.params.t_th),
                "v_th": float(state.index.params.v_th),
            })
            if n_changed == 0:
                converged = True
                break

        return LloydResult(
            state=state,
            assign=np.asarray(state.assign),
            history=history,
            params=state.index.params,
            converged=converged,
            n_iter=len(history),
        )
