"""Lloyd-iteration driver for accelerated spherical K-means.

Runs assignment (selected algorithm × backend) → update → [EstParams at
iterations 1–2] until no assignment changes, collecting the paper's
diagnostics per iteration: Mult (multiply-adds), CPR (complementary pruning
rate, Eq. 22), #changed, objective J (Eq. 47).  All algorithms converge to
the identical fixed point from the same seed — the acceleration contract.

The whole epoch (every batch of the assignment phase) is one jitted
``lax.map`` over reshaped batches: Mult/CPR/#changed accumulate on device
and the host sees exactly one sync per Lloyd iteration, instead of one
``float()`` round-trip per batch.  Documents are padded to a batch-size
multiple with dead rows (nnz = 0) that are masked out of every diagnostic;
the tail batch therefore runs through the identical code path as full
batches (tested in tests/test_backends.py).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.sparse import SparseDocs, pad_rows
from repro.core.meanindex import StructuralParams
from repro.core.assignment import assign_batch
from repro.core.update import update_step, init_state, KMeansState
from repro.core.estparams import estimate_params, EstGrid

# Single host-sync point per iteration — module-level so tests can wrap it
# and count device→host transfers.
_host_pull = jax.device_get


@partial(jax.jit, static_argnames=("algo", "backend", "bs"))
def _fused_epoch(algo: str, backend: str, docs: SparseDocs, index,
                 assign, rho_self, xstate, valid, bs: int):
    """One full assignment epoch, on device.

    Returns (assign (N,), mult (), cand_sum (), n_changed ()) — the
    per-batch Python loop and its per-batch host syncs collapse into a
    single ``lax.map`` whose scalar diagnostics are reduced on device.
    (Per-object ρ is not returned: the update step refreshes ρ_self against
    the *new* means anyway.)
    """
    n = docs.ids.shape[0]
    nb = n // bs
    resh = lambda a: a.reshape((nb, bs) + a.shape[1:])

    def batch_fn(args):
        bids, bvals, bnnz, bassign, brho, bxs, bvalid = args
        bdocs = SparseDocs(ids=bids, vals=bvals, nnz=bnnz, dim=docs.dim)
        res = assign_batch(algo, backend, bdocs, index, bassign, brho, bxs)
        cand = jnp.where(bvalid, res.n_candidates, 0)
        changed = res.changed & bvalid
        return (res.assign, jnp.sum(cand), jnp.sum(changed), res.mult)

    a, cand, changed, mult = lax.map(
        batch_fn, (resh(docs.ids), resh(docs.vals), resh(docs.nnz),
                   resh(assign), resh(rho_self), resh(xstate), resh(valid)))
    return a.reshape(n), jnp.sum(mult), jnp.sum(cand), jnp.sum(changed)


def _run_epoch(algo, backend, docs, index, assign, rho_self, xstate, valid, bs):
    """Indirection point for tests asserting the fused path is used."""
    return _fused_epoch(algo, backend, docs, index, assign, rho_self,
                        xstate, valid, bs)


@dataclasses.dataclass
class LloydResult:
    state: KMeansState
    assign: np.ndarray
    history: list
    params: StructuralParams
    converged: bool
    n_iter: int

    @property
    def objective(self) -> float:
        """J = Σ_i x_i·μ_{a(i)} (Eq. 47) at the final state."""
        return float(jnp.sum(self.state.rho_self))


class SphericalKMeans:
    """sklearn-ish front-end over the core steps.

    algo: 'mivi' | 'icp' | 'es' | 'esicp' | 'ta-icp' | 'cs-icp'
    backend: 'reference' | 'pallas' | 'auto' — accumulator engine for the
            assignment step (core/backends.py; 'auto' = pallas on TPU).
    params: 'auto' (EstParams at iterations 1–2, the paper's default),
            StructuralParams for fixed thresholds, or None -> trivial.
    """

    def __init__(self, k: int, *, algo: str = "esicp", params="auto",
                 backend: str = "reference", batch_size: int = 4096,
                 max_iter: int = 60, est_grid: EstGrid | None = None,
                 est_iters=(1, 2), seed: int = 0):
        self.k = k
        self.algo = algo
        self.backend = backend
        self.params = params
        self.batch_size = batch_size
        self.max_iter = max_iter
        self.est_grid = est_grid or EstGrid()
        self.est_iters = tuple(est_iters)
        self.seed = seed

    def _initial_params(self, dim: int) -> StructuralParams:
        if isinstance(self.params, StructuralParams):
            return self.params
        # 'auto' / None start trivial: t_th=0, v_th=1 puts everything in
        # Region 3 under a vacuous bound, i.e. iteration 1 behaves like the
        # unfiltered baseline — exactly the paper (EstParams runs at r=1,2).
        return StructuralParams.trivial(dim)

    def fit(self, docs: SparseDocs, df: jax.Array | None = None) -> LloydResult:
        n = docs.n_docs
        params = self._initial_params(docs.dim)
        # Seeding picks centroids among the *real* documents, before padding.
        state = init_state(docs, self.k, params, seed=self.seed)
        if df is None:
            from repro.sparse import df_counts
            df = df_counts(docs)

        bs = min(self.batch_size, n)
        pdocs = pad_rows(docs, bs)
        n_pad = pdocs.n_docs
        valid = jnp.arange(n_pad) < n
        if n_pad != n:
            pad = n_pad - n
            state = dataclasses.replace(
                state,
                assign=jnp.pad(state.assign, (0, pad)),
                rho_self=jnp.pad(state.rho_self, (0, pad),
                                 constant_values=-jnp.inf),
                rho_self_prev=jnp.pad(state.rho_self_prev, (0, pad),
                                      constant_values=-jnp.inf),
            )

        history = []
        converged = False
        for r in range(1, self.max_iter + 1):
            t0 = time.perf_counter()
            prev_assign = state.assign
            assign, mult, cand_sum, n_changed = _run_epoch(
                self.algo, self.backend, pdocs, state.index, state.assign,
                state.rho_self, state.xstate, valid, bs)

            state = update_step(pdocs, assign, prev_assign, state,
                                state.index.params, k=self.k)

            if self.params == "auto" and r in self.est_iters:
                # EstParams sees only the real rows (padding would skew the
                # Mult-estimate tables).
                new_params, _ = estimate_params(docs, df, state.index.means_t,
                                                state.rho_self[:n], k=self.k,
                                                grid=self.est_grid)
                state = dataclasses.replace(
                    state, index=state.index.with_params(new_params))

            # The one device→host sync of the iteration: every diagnostic
            # scalar crosses in a single pull.
            diag = _host_pull((mult, cand_sum, n_changed,
                               jnp.sum(state.rho_self), state.index.n_moving,
                               state.index.params.t_th,
                               state.index.params.v_th))
            mult_h, cand_h, changed_h, obj_h, nmov_h, t_th_h, v_th_h = diag

            history.append({
                "iteration": r,
                "mult": float(mult_h),
                "cpr": float(cand_h) / (n * self.k),
                "n_changed": int(changed_h),
                "objective": float(obj_h),
                "n_moving": int(nmov_h),
                "elapsed_s": time.perf_counter() - t0,
                "t_th": int(t_th_h),
                "v_th": float(v_th_h),
            })
            if int(changed_h) == 0:
                converged = True
                break

        if n_pad != n:
            # Trim the padding rows so state arrays pair with the caller's
            # docs again (padding rho_self is 0, so the objective is intact).
            state = dataclasses.replace(
                state,
                assign=state.assign[:n],
                rho_self=state.rho_self[:n],
                rho_self_prev=state.rho_self_prev[:n],
            )
        return LloydResult(
            state=state,
            assign=np.asarray(state.assign),
            history=history,
            params=state.index.params,
            converged=converged,
            n_iter=len(history),
        )
