"""Sequence-mixing blocks with linear-time state: Mamba2 (SSD), mLSTM, sLSTM.

Mamba2 and mLSTM are both gated linear recurrences

    H_t = a_t · H_{t-1} + B_t ⊗ X_t,      y_t = C_t · H_t

and share one chunked TPU realisation: intra-chunk work is a pair of batched
matmuls (MXU), inter-chunk state flows through a short lax.scan of length
S/chunk — the standard sub-quadratic layout that makes long_500k feasible.
mLSTM adds the xLSTM normaliser n_t (same recurrence with X ≡ 1) and
max-stabilised output.  sLSTM is a scalar-state LSTM with exponential gating;
it is inherently sequential, so it scans over time (xLSTM-125m carries only
a few sLSTM layers; DESIGN.md notes the recurrent-weight simplification).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import COMPUTE_DTYPE


def _chunked_glr(xv, kb, qc, log_a, chunk: int):
    """Chunked gated linear recurrence.

    xv:    (B, S, H, P)   values  (X_t)
    kb:    (B, S, H, N)   input maps (B_t)
    qc:    (B, S, H, N)   output maps (C_t)
    log_a: (B, S, H)      per-step log decay (<= 0)
    Returns y: (B, S, H, P).
    """
    b, s, h, p = xv.shape
    n = kb.shape[-1]
    nc = s // chunk
    r = lambda t: t.reshape(b, nc, chunk, *t.shape[2:])
    xv, kb, qc, log_a = r(xv), r(kb), r(qc), r(log_a)

    cum = jnp.cumsum(log_a, axis=2)                     # (B, nc, L, H)
    total = cum[:, :, -1]                               # (B, nc, H)

    # --- intra-chunk: masked decay-weighted attention-like matmuls -------
    li = cum[:, :, :, None, :]                          # (B,nc,L,1,H) query side
    lj = cum[:, :, None, :, :]                          # (B,nc,1,L,H) key side
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))      # i >= j valid
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.einsum("bcihn,bcjhn->bcijh", qc, kb,
                   preferred_element_type=jnp.float32)
    w = jnp.where(causal[None, None, :, :, None], w * decay, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(COMPUTE_DTYPE), xv,
                         preferred_element_type=jnp.float32)

    # --- chunk summaries and inter-chunk scan -----------------------------
    tail = jnp.exp(jnp.clip(total[:, :, None, :] - cum, -60.0, 0.0))  # decay to end
    state_c = jnp.einsum("bcjhn,bcjhp->bchnp", (kb * tail[..., None]).astype(COMPUTE_DTYPE),
                         xv, preferred_element_type=jnp.float32)      # (B,nc,H,N,P)

    def scan_body(hprev, inp):
        st, tot = inp                                    # (B,H,N,P), (B,H)
        out = hprev                                      # state entering chunk
        hnew = jnp.exp(tot)[..., None, None] * hprev + st
        return hnew, out

    init = jnp.zeros((b, h, n, p), jnp.float32)
    _, h_in = jax.lax.scan(scan_body,
                           init,
                           (jnp.swapaxes(state_c, 0, 1), jnp.swapaxes(total, 0, 1)))
    h_in = jnp.swapaxes(h_in, 0, 1)                      # (B,nc,H,N,P)

    head_decay = jnp.exp(jnp.clip(cum, -60.0, 0.0))      # decay from chunk start
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", (qc * head_decay[..., None]).astype(COMPUTE_DTYPE),
                         h_in.astype(COMPUTE_DTYPE), preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y


def mamba2_block(x, p, cfg: ModelConfig):
    """Mamba2 (SSD) mixer. x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    h = cfg.n_heads
    di = cfg.ssm_expand * d                              # inner width
    hd = di // h
    n = cfg.ssm_state
    cd = COMPUTE_DTYPE
    xc = x.astype(cd)

    xz = xc @ p["w_in"].astype(cd)                       # (B,S,2D)
    xv, z = jnp.split(xz, 2, axis=-1)
    bc = xc @ p["w_bc"].astype(cd)                       # (B,S,2N)
    kb, qc = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus((xc @ p["w_dt"].astype(cd)).astype(jnp.float32)
                         + p["dt_bias"])                 # (B,S,H)
    log_a = -dt * jnp.exp(p["log_A"])                    # (B,S,H), A > 0

    xv = xv.reshape(b, s, h, hd)
    kbh = jnp.broadcast_to(kb[:, :, None, :], (b, s, h, n)) * dt[..., None].astype(cd)
    qch = jnp.broadcast_to(qc[:, :, None, :], (b, s, h, n))
    y = _chunked_glr(xv, kbh.astype(cd), qch, log_a, cfg.ssm_chunk)
    y = y + xv.astype(jnp.float32) * p["D"][None, None, :, None]
    y = (y.reshape(b, s, di).astype(cd) * jax.nn.silu(z))
    return (y @ p["w_out"].astype(cd)).astype(x.dtype)


def mamba2_decode(x, p, cfg: ModelConfig, state):
    """One-step Mamba2. x: (B, 1, D); state: (B, H, N, hd) fp32."""
    b, _, d = x.shape
    h = cfg.n_heads
    di = cfg.ssm_expand * d
    hd = di // h
    n = cfg.ssm_state
    cd = COMPUTE_DTYPE
    xc = x[:, 0].astype(cd)

    xz = xc @ p["w_in"].astype(cd)
    xv, z = jnp.split(xz, 2, axis=-1)
    bc = xc @ p["w_bc"].astype(cd)
    kb, qc = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus((xc @ p["w_dt"].astype(cd)).astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-dt * jnp.exp(p["log_A"]))               # (B,H)

    xv = xv.reshape(b, h, hd).astype(jnp.float32)
    kbh = kb[:, None, :].astype(jnp.float32) * dt[..., None]          # (B,H,N)
    state = a[..., None, None] * state + kbh[..., None] * xv[:, :, None, :]
    y = jnp.einsum("bhn,bhnp->bhp", jnp.broadcast_to(qc[:, None, :], (b, h, n)).astype(jnp.float32),
                   state)
    y = y + xv * p["D"][None, :, None]
    y = (y.reshape(b, di).astype(cd) * jax.nn.silu(z))
    return (y @ p["w_out"].astype(cd)).astype(x.dtype)[:, None], state


def mlstm_block(x, p, cfg: ModelConfig):
    """xLSTM mLSTM mixer (matrix memory, exp input gate, sigmoid forget)."""
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    cd = COMPUTE_DTYPE
    xc = x.astype(cd)

    q = (xc @ p["wq"].astype(cd)).reshape(b, s, h, hd)
    k = (xc @ p["wk"].astype(cd)).reshape(b, s, h, hd) / jnp.sqrt(float(hd))
    v = (xc @ p["wv"].astype(cd)).reshape(b, s, h, hd)
    gates = (xc @ p["w_if"].astype(cd)).astype(jnp.float32)           # (B,S,2H)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)                                  # (B,S,H)
    i_gate = jnp.exp(jnp.clip(i_pre, None, 10.0))

    kv = k * i_gate[..., None].astype(cd)
    y = _chunked_glr(v, kv, q, log_f, cfg.ssm_chunk)                  # numerator
    ones = jnp.ones((b, s, h, 1), cd)
    nrm = _chunked_glr(ones, kv, q, log_f, cfg.ssm_chunk)             # normaliser
    y = y / jnp.maximum(jnp.abs(nrm), 1.0)
    y = y.reshape(b, s, d).astype(cd) * jax.nn.silu(xc @ p["w_z"].astype(cd))
    return (y @ p["w_out"].astype(cd)).astype(x.dtype)


def slstm_block(x, p, cfg: ModelConfig):
    """xLSTM sLSTM: scalar memory, exponential gating; sequential scan."""
    b, s, d = x.shape
    cd = COMPUTE_DTYPE
    gates = (x.astype(cd) @ p["w_gates"].astype(cd)).astype(jnp.float32) + p["b_gates"]
    zi, ii, fi, oi = jnp.split(gates, 4, axis=-1)        # each (B,S,D)

    def step(carry, gates_t):
        c, n, m = carry
        z_t, i_t, f_t, o_t = gates_t
        m_new = jnp.maximum(f_t + m, i_t)                # log-space stabiliser
        i_e = jnp.exp(i_t - m_new)
        f_e = jnp.exp(f_t + m - m_new)
        c = f_e * c + i_e * jnp.tanh(z_t)
        n = f_e * n + i_e
        h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new), h

    tm = lambda a: jnp.swapaxes(a, 0, 1)                 # time-major
    z0 = jnp.zeros((b, d), jnp.float32)
    (_, _, _), hs = jax.lax.scan(step, (z0, z0, z0 - 1e30),
                                 (tm(zi), tm(ii), tm(fi), tm(oi)))
    hs = jnp.swapaxes(hs, 0, 1)                          # (B,S,D)
    return (hs.astype(cd) @ p["w_out"].astype(cd)).astype(x.dtype)
