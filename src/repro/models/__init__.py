from repro.models.config import ModelConfig, Segment
from repro.models.transformer import (
    init_params,
    param_specs,
    forward,
    lm_loss,
    init_cache,
    cache_specs,
    decode_forward,
)

__all__ = [
    "ModelConfig", "Segment",
    "init_params", "param_specs", "forward", "lm_loss",
    "init_cache", "cache_specs", "decode_forward",
]
