"""Transformer building blocks: RMSNorm, RoPE, GQA attention (dynamic
sliding window), dense MLP variants, capacity-based MoE.

Conventions:
  * params live in fp32, matmuls run in bf16 with fp32 accumulation
    (``preferred_element_type``) — the v5e MXU contract;
  * the sliding window is *data*, not code: a traced per-layer scalar feeding
    a uniform band mask, so heterogeneous patterns (gemma-3 5:1) scan as one
    body — same branch-free philosophy as the k-means core;
  * attention math leaves internal sharding to the SPMD partitioner; the
    train/serve steps constrain only block boundaries and weights.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, scan_unroll

# bf16 is the TPU contract; the CPU backend cannot *execute* bf16 dots (it
# compiles them fine), so tests fall back to fp32 while the dry-run pins
# REPRO_COMPUTE_DTYPE=bfloat16 to keep roofline byte counts faithful.
_env_dt = os.environ.get("REPRO_COMPUTE_DTYPE")
if _env_dt:
    COMPUTE_DTYPE = getattr(jnp, _env_dt)
else:
    COMPUTE_DTYPE = jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32


def rms_norm(x, scale, eps: float):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(x.dtype)


def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


def _band_mask(q_pos, k_pos, window):
    """Causal band: k <= q and q - k < window (window < 0 → full causal)."""
    w = jnp.where(window < 0, jnp.iinfo(jnp.int32).max, window)
    causal = k_pos[None, :] <= q_pos[:, None]
    near = (q_pos[:, None] - k_pos[None, :]) < w
    return causal & near


ATTN_DIRECT_MAX_S = 2048   # above this, use the q-chunked (flash-style) path
ATTN_Q_CHUNK = 1024
# §Perf variant: stack q-chunk outputs in bf16 instead of f32 (the scan's
# stacked ys are the prefill memory high-water mark)
ATTN_STACK_BF16 = False


def set_attn_stack_bf16(v: bool):
    global ATTN_STACK_BF16
    ATTN_STACK_BF16 = bool(v)


# §Perf variant: shard K/V along the sequence dim over 'model' — for MQA/GQA
# archs whose few (kv-)heads cannot split over a 16-way model axis, XLA
# otherwise reshards the S×S score blocks every layer (the dominant
# collective in the train_4k baseline).  With S_k sharded, score compute
# splits |model|-ways and softmax/out contractions need only small psums.
ATTN_KV_SHARD_MESH = None


def set_attn_kv_shard(mesh):
    global ATTN_KV_SHARD_MESH
    ATTN_KV_SHARD_MESH = mesh


def _maybe_shard_kv(k, v):
    if ATTN_KV_SHARD_MESH is None:
        return k, v
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = ATTN_KV_SHARD_MESH
    dp = tuple(a for a in mesh.axis_names if a != "model")
    spec = NamedSharding(mesh, P(dp, "model", None, None))
    return (jax.lax.with_sharding_constraint(k, spec),
            jax.lax.with_sharding_constraint(v, spec))


# TPU hot path: route attention through the Pallas flash kernel
# (kernels/flash_attention.py).  Off by default: the jnp q-chunked path is
# what the dry-run lowers; on a real TPU, set_use_flash(True) swaps in the
# kernel (equivalence tested in tests/test_models.py).
USE_FLASH = False


def set_use_flash(v: bool):
    global USE_FLASH
    USE_FLASH = bool(v)


def _flash_path(qg, k, v, window, *, interpret=None):
    """qg: (B,S,Hkv,G,hd); k/v: (B,S,Hkv,hd) -> (B,S,Hkv,G,hd) f32."""
    from repro.kernels import flash_attention as fa
    b, s, hkv, g, hd = qg.shape
    qf = qg.transpose(0, 2, 3, 1, 4).reshape(b * hkv * g, s, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * hkv * g, s, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * hkv * g, s, hd)
    w = int(window) if window is not None else -1
    out = fa(qf.astype(jnp.float32), kf.astype(jnp.float32),
             vf.astype(jnp.float32), window=w,
             sq_blk=min(128, s), sk_blk=min(128, s), interpret=interpret)
    return out.reshape(b, hkv, g, s, hd).transpose(0, 3, 1, 2, 4)


def _attn_core(qg, k, v, q_pos, k_pos, window, hd):
    """scores+softmax+values for one q block. qg: (B, Sq, Hkv, G, hd)."""
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(float(hd))
    mask = _band_mask(q_pos, k_pos, window)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(qg.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v,
                      preferred_element_type=jnp.float32)


def attention(x, p, cfg: ModelConfig, window, *, pos_offset=0):
    """Training/prefill attention. x: (B, S, D); window: static per layer.

    Long sequences run a q-chunked scan (flash-style): only one
    (q_chunk × S) score block is live at a time and the chunk body is
    rematerialised in the backward pass — the S² probs tensor never exists.
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = hq // hkv
    cd = COMPUTE_DTYPE
    xc = x.astype(cd)

    q = xc @ p["wq"].astype(cd)
    k = xc @ p["wk"].astype(cd)
    v = xc @ p["wv"].astype(cd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)

    positions = pos_offset + jnp.arange(s)
    q = rope(q, positions[None, :], cfg.rope_theta)
    k = rope(k, positions[None, :], cfg.rope_theta)
    k, v = _maybe_shard_kv(k, v)
    qg = q.reshape(b, s, hkv, g, hd)

    if USE_FLASH and pos_offset == 0:
        out = _flash_path(qg, k, v, window)
    elif s <= ATTN_DIRECT_MAX_S:
        out = _attn_core(qg, k, v, positions, positions, window, hd)
    else:
        qc = ATTN_Q_CHUNK
        nc = s // qc
        assert s % qc == 0, (s, qc)
        qg_c = qg.reshape(b, nc, qc, hkv, g, hd).swapaxes(0, 1)  # (nc, B, qc, ...)
        pos_c = positions.reshape(nc, qc)

        @jax.checkpoint
        def body(_, inp):
            qb, pb = inp
            ob = _attn_core(qb, k, v, pb, positions, window, hd)
            if ATTN_STACK_BF16:
                ob = ob.astype(COMPUTE_DTYPE)
            return 0.0, ob

        _, out_c = jax.lax.scan(body, 0.0, (qg_c, pos_c), unroll=scan_unroll())
        out = out_c.swapaxes(0, 1).reshape(b, s, hkv, g, hd)

    out = out.reshape(b, s, hq * hd).astype(cd)
    return (out @ p["wo"].astype(cd)).astype(x.dtype)


def decode_attention(x, p, cfg: ModelConfig, window, cache_k, cache_v, pos):
    """Single-token decode. x: (B, 1, D); caches: (B, L_c, Hkv, hd) where
    L_c = min(window, S_max) for windowed layers (rotating cache) or S_max;
    pos: () int32 absolute position.  Returns (out, cache_k, cache_v).

    Rotating layout: slot j holds absolute position pos − ((slot − j) mod L_c)
    — for a full cache (L_c = S_max) this degenerates to k_pos = j, so one
    branch-free formula covers both.  Keys are stored RoPE'd at their
    absolute position, so rotation never re-rotates.

    The KV cache is sharded along L_c over 'model' (flash-decode layout,
    DESIGN.md §4): the score/value contractions below reduce over that axis,
    which the partitioner lowers to one small all-reduce per layer."""
    b, _, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = hq // hkv
    cd = COMPUTE_DTYPE
    quant = isinstance(cache_k, dict)          # int8 cache: {"q": int8, "s": f32}
    l_c = (cache_k["q"] if quant else cache_k).shape[1]
    slot = pos % l_c
    xc = x.astype(cd)

    q = xc @ p["wq"].astype(cd)
    k = xc @ p["wk"].astype(cd)
    v = xc @ p["wv"].astype(cd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = q.reshape(b, 1, hq, hd)
    k = k.reshape(b, 1, hkv, hd)
    v = v.reshape(b, 1, hkv, hd)
    posv = jnp.full((1, 1), pos)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)

    def _insert(cache, new):
        if not quant:
            return jax.lax.dynamic_update_slice_in_dim(
                cache, new.astype(cache.dtype), slot, 1)
        # per-(token, head) max-abs int8 quantisation (§Perf variant)
        scale = jnp.max(jnp.abs(new), axis=-1, keepdims=True).astype(jnp.float32)
        qv = jnp.round(new.astype(jnp.float32) / jnp.maximum(scale, 1e-9) * 127.0)
        return {
            "q": jax.lax.dynamic_update_slice_in_dim(
                cache["q"], qv.astype(jnp.int8), slot, 1),
            "s": jax.lax.dynamic_update_slice_in_dim(
                cache["s"], scale / 127.0, slot, 1),
        }

    def _read(cache):
        if not quant:
            return cache.astype(cd)
        return (cache["q"].astype(jnp.float32) * cache["s"]).astype(cd)

    cache_k = _insert(cache_k, k)
    cache_v = _insert(cache_v, v)

    qg = q.reshape(b, hkv, g, hd)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, _read(cache_k),
                        preferred_element_type=jnp.float32) / jnp.sqrt(float(hd))
    j = jnp.arange(l_c)
    k_pos = pos - jnp.mod(slot - j, l_c)                 # absolute positions
    w = jnp.where(window < 0, jnp.iinfo(jnp.int32).max, window)
    mask = (k_pos >= 0) & ((pos - k_pos) < w)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cd)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, _read(cache_v),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, hq * hd).astype(cd)
    return (out @ p["wo"].astype(cd)).astype(x.dtype), cache_k, cache_v


def dense_mlp(x, p, cfg: ModelConfig):
    cd = COMPUTE_DTYPE
    xc = x.astype(cd)
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(xc @ p["w_gate"].astype(cd)) * (xc @ p["w_up"].astype(cd))
    else:
        h = jax.nn.gelu(xc @ p["w_up"].astype(cd))
    return (h @ p["w_down"].astype(cd)).astype(x.dtype)


def moe_mlp(x, p, cfg: ModelConfig):
    """Capacity-based top-k MoE (GShard/Switch dispatch as MXU einsums).

    Dispatch/combine are one-hot matmuls over a (group, expert, capacity)
    layout — no scatters, expert dim shardable over 'model' (EP).  Overflow
    tokens are dropped (capacity_factor controls the rate) — the standard
    trade for static shapes on TPU.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = min(cfg.moe_group, b * s)
    t = b * s
    assert t % g == 0, (t, g)
    ng = t // g
    cap = int(g * k / e * cfg.moe_capacity) + 1
    cap = min(cap + (-cap) % 4, g)
    cd = COMPUTE_DTYPE

    xf = x.reshape(ng, g, d)
    logits = (xf.astype(cd) @ p["router"].astype(cd)).astype(jnp.float32)
    gate_vals, gate_idx = jax.lax.top_k(logits, k)            # (ng, g, k)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    sel = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)      # (ng, g, k, e)
    mask = jnp.sum(sel, axis=2)                               # (ng, g, e) ∈ {0,1}
    gates_e = jnp.einsum("ngk,ngke->nge", gates, sel)

    pos_in_e = jnp.cumsum(mask, axis=1) - mask                # arrival order
    keep = (pos_in_e < cap) * mask
    slot = jax.nn.one_hot(pos_in_e, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = slot                                            # (ng, g, e, cap)

    xin = jnp.einsum("ngec,ngd->necd", dispatch.astype(cd), xf.astype(cd),
                     preferred_element_type=jnp.float32).astype(cd)
    act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
    h = act(jnp.einsum("necd,edf->necf", xin, p["w_gate"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd))
    h = h * jnp.einsum("necd,edf->necf", xin, p["w_up"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
    out_e = jnp.einsum("necf,efd->necd", h, p["w_down"].astype(cd),
                       preferred_element_type=jnp.float32)
    combine = dispatch * gates_e[..., None]
    out = jnp.einsum("ngec,necd->ngd", combine.astype(jnp.float32), out_e,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, d).astype(x.dtype)
