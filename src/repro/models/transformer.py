"""Model assembly: segment-scanned LM covering all ten architectures.

Params are a nested dict; every segment's leaves are stacked on a leading
``reps`` axis and executed by one lax.scan (compile-time O(#segments)).
The zamba2-style shared attention block is a single unstacked parameter set
reused at each invocation.  ``param_specs``/``cache_specs`` mirror
``init_params``/``init_cache`` as ShapeDtypeStructs for the dry-run path
(no allocation ever happens for the full-size configs).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, Segment, LayerSpec, scan_unroll
from repro.models import layers as L
from repro.models import ssm as S

PDTYPE = jnp.float32   # parameter dtype (optimizer-friendly master copy)
CDTYPE = L.COMPUTE_DTYPE


# ---------------------------------------------------------------------------
# Parameter construction (shapes once, realised as zeros/random or as specs).
# ---------------------------------------------------------------------------

def _layer_shapes(cfg: ModelConfig, kind: str) -> dict:
    d, hd = cfg.d_model, cfg.hd
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    n = cfg.ssm_state
    f = cfg.d_ff
    shp: dict = {}
    if kind in ("attn", "moe", "shared_attn"):
        shp.update({
            "ln1": (d,), "ln2": (d,),
            "wq": (d, hq * hd), "wk": (d, hkv * hd), "wv": (d, hkv * hd),
            "wo": (hq * hd, d),
        })
        if cfg.qkv_bias:
            shp.update({"bq": (hq * hd,), "bk": (hkv * hd,), "bv": (hkv * hd,)})
        if kind == "moe":
            shp.update({
                "router": (d, cfg.n_experts),
                "w_gate": (cfg.n_experts, d, f),
                "w_up": (cfg.n_experts, d, f),
                "w_down": (cfg.n_experts, f, d),
            })
        else:
            if cfg.mlp in ("swiglu", "geglu"):
                shp.update({"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)})
            else:
                shp.update({"w_up": (d, f), "w_down": (f, d)})
    elif kind == "mamba2":
        hh = cfg.n_heads
        di = cfg.ssm_expand * d                  # mamba2 inner width
        shp.update({
            "ln1": (d,),
            "w_in": (d, 2 * di), "w_bc": (d, 2 * n), "w_dt": (d, hh),
            "dt_bias": (hh,), "log_A": (hh,), "D": (hh,),
            "w_out": (di, d),
        })
    elif kind == "mlstm":
        shp.update({
            "ln1": (d,),
            "wq": (d, d), "wk": (d, d), "wv": (d, d),
            "w_if": (d, 2 * cfg.n_heads), "w_z": (d, d), "w_out": (d, d),
        })
    elif kind == "slstm":
        shp.update({
            "ln1": (d,),
            "w_gates": (d, 4 * d), "b_gates": (4 * d,), "w_out": (d, d),
        })
    else:
        raise ValueError(kind)
    return shp


def _tree_shapes(cfg: ModelConfig) -> dict:
    tree: dict = {
        "embed": (cfg.padded_vocab, cfg.d_model),
        "final_norm": (cfg.d_model,),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = (cfg.padded_vocab, cfg.d_model)
    if cfg.modality != "text":
        tree["frontend_proj"] = (cfg.d_model, cfg.d_model)  # stub projection
    has_shared = False
    for si, seg in enumerate(cfg.segments):
        seg_tree = {}
        for pi, spec in enumerate(seg.layers):
            if spec.kind == "shared_attn":
                has_shared = True
                continue
            seg_tree[f"pos{pi}"] = {
                k: (seg.reps,) + v for k, v in _layer_shapes(cfg, spec.kind).items()
            }
        tree[f"seg{si}"] = seg_tree
    if has_shared:
        tree["shared"] = _layer_shapes(cfg, "shared_attn")
    return tree


def param_specs(cfg: ModelConfig, dtype=PDTYPE):
    return jax.tree_util.tree_map(
        lambda shp: jax.ShapeDtypeStruct(shp, dtype),
        _tree_shapes(cfg), is_leaf=lambda x: isinstance(x, tuple))


_ZERO_INIT = ("ln1", "ln2", "final_norm", "bq", "bk", "bv", "b_gates", "log_A")


def init_params(cfg: ModelConfig, key, dtype=PDTYPE):
    shapes = _tree_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))
    out = []
    for ((path, shp), k) in zip(flat, keys):
        name = path[-1].key
        if name in _ZERO_INIT:
            out.append(jnp.zeros(shp, dtype))
        elif name == "D":
            out.append(jnp.ones(shp, dtype))
        elif name == "dt_bias":
            out.append(jnp.full(shp, -2.0, dtype))       # small initial dt
        elif name == "embed" or name == "lm_head":
            out.append(jax.random.normal(k, shp, dtype) * 0.02)
        else:
            fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
            out.append(jax.random.normal(k, shp, dtype) / math.sqrt(fan_in))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Embedding lookup — baseline gather vs Megatron-style vocab-parallel
# (§Perf variant: the baseline's gather over a vocab-sharded table triggers
# XLA's "involuntary full rematerialization" replication; the shard_map
# version does masked local lookup + one psum over 'model').
# ---------------------------------------------------------------------------

EMBED_MODE = "gather"
_EMBED_MESH = None


def set_embed_mode(mode: str, mesh=None):
    global EMBED_MODE, _EMBED_MESH
    EMBED_MODE = mode
    _EMBED_MESH = mesh


def _embed_lookup(emb, tokens):
    if EMBED_MODE != "megatron" or _EMBED_MESH is None:
        return emb[tokens]
    from jax.sharding import PartitionSpec as P
    mesh = _EMBED_MESH
    n_model = mesh.shape["model"]
    v_loc = emb.shape[0] // n_model
    dp = tuple(a for a in mesh.axis_names if a != "model")

    def local(emb_l, tok):
        lo = jax.lax.axis_index("model") * v_loc
        t = tok - lo
        ok = (t >= 0) & (t < v_loc)
        x = emb_l[jnp.where(ok, t, 0)]
        x = jnp.where(ok[..., None], x, 0.0)
        return jax.lax.psum(x, "model")

    from repro.compat import shard_map
    f = shard_map(local, mesh=mesh,
                  in_specs=(P("model", None), P(dp, None)),
                  out_specs=P(dp, None, None))
    return f(emb, tokens)


# ---------------------------------------------------------------------------
# Forward (training / prefill).
# ---------------------------------------------------------------------------

def _apply_layer(x, p, spec: LayerSpec, cfg: ModelConfig, shared):
    eps = cfg.norm_eps
    if spec.kind == "shared_attn":
        p = shared
    if spec.kind in ("attn", "moe", "shared_attn"):
        h = L.attention(L.rms_norm(x, p["ln1"], eps), p, cfg, spec.window)
        x = x + h
        if spec.kind == "moe":
            x = x + L.moe_mlp(L.rms_norm(x, p["ln2"], eps), p, cfg)
        else:
            x = x + L.dense_mlp(L.rms_norm(x, p["ln2"], eps), p, cfg)
    elif spec.kind == "mamba2":
        x = x + S.mamba2_block(L.rms_norm(x, p["ln1"], eps), p, cfg)
    elif spec.kind == "mlstm":
        x = x + S.mlstm_block(L.rms_norm(x, p["ln1"], eps), p, cfg)
    elif spec.kind == "slstm":
        x = x + S.slstm_block(L.rms_norm(x, p["ln1"], eps), p, cfg)
    else:
        raise ValueError(spec.kind)
    return x


def forward(params, tokens, cfg: ModelConfig, *, frontend_embeds=None,
            remat: bool = True):
    """tokens: (B, S) int32 -> final hidden states (B, S, D) bf16.

    frontend_embeds: (B, S_fe, D) — modality-stub prefix (audio frames /
    image patches) replacing the first S_fe token embeddings (early fusion).
    """
    emb = params["embed"]
    x = _embed_lookup(emb, tokens).astype(CDTYPE) * math.sqrt(cfg.d_model)
    if frontend_embeds is not None:
        fe = (frontend_embeds.astype(CDTYPE) @ params["frontend_proj"].astype(CDTYPE))
        x = jnp.concatenate([fe, x[:, fe.shape[1]:]], axis=1)

    shared = params.get("shared")

    for si, seg in enumerate(cfg.segments):
        seg_params = params[f"seg{si}"]

        def body(h, lp, _seg=seg):
            for pi, spec in enumerate(_seg.layers):
                p = lp.get(f"pos{pi}") if spec.kind != "shared_attn" else None
                h = _apply_layer(h, p, spec, cfg, shared)
            return h, None

        body_fn = jax.checkpoint(body, prevent_cse=False) if remat else body
        x, _ = jax.lax.scan(body_fn, x, seg_params, length=seg.reps,
                            unroll=scan_unroll())
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def _logits(params, h, cfg: ModelConfig):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return h.astype(CDTYPE) @ head.astype(CDTYPE).T          # (B, S, Vpad)


def lm_loss(params, tokens, labels, cfg: ModelConfig, *, loss_chunk: int = 512,
            frontend_embeds=None):
    """Mean next-token cross-entropy; the (B, S, V) logits tensor is never
    materialised — the unembed+softmax runs in S-chunks (memory-roofline
    optimisation measured in §Perf)."""
    h = forward(params, tokens, cfg, frontend_embeds=frontend_embeds)
    b, s, d = h.shape
    c = min(loss_chunk, s)
    nc = s // c
    hc = h.reshape(b, nc, c, d).swapaxes(0, 1)               # (nc, B, c, D)
    lc = labels.reshape(b, nc, c).swapaxes(0, 1)

    valid_v = cfg.vocab

    def body(acc, inp):
        hh, ll = inp
        logits = _logits(params, hh, cfg).astype(jnp.float32)
        logits = jnp.where(jnp.arange(cfg.padded_vocab) < valid_v, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc),
                            unroll=scan_unroll())
    return total / (b * s)


# ---------------------------------------------------------------------------
# Serving: KV caches + single-token decode.
# ---------------------------------------------------------------------------

def _cache_len(spec: LayerSpec, s_max: int) -> int:
    if spec.kind in ("attn", "moe", "shared_attn") and spec.window > 0:
        return min(spec.window, s_max)   # rotating window cache
    return s_max


def _layer_cache_shapes(cfg: ModelConfig, spec: LayerSpec, batch: int, s_max: int):
    d, hd, hkv = cfg.d_model, cfg.hd, cfg.n_kv_heads
    h = cfg.n_heads
    if spec.kind in ("attn", "moe", "shared_attn"):
        lc = _cache_len(spec, s_max)
        if cfg.kv_dtype == "int8":     # quantized cache: values + scales
            return {"k": {"q": ((batch, lc, hkv, hd), jnp.int8),
                          "s": ((batch, lc, hkv, 1), jnp.float32)},
                    "v": {"q": ((batch, lc, hkv, hd), jnp.int8),
                          "s": ((batch, lc, hkv, 1), jnp.float32)}}
        return {"k": ((batch, lc, hkv, hd), CDTYPE),
                "v": ((batch, lc, hkv, hd), CDTYPE)}
    if spec.kind == "mamba2":
        return {"state": ((batch, h, cfg.ssm_state, cfg.ssm_expand * d // h), jnp.float32)}
    if spec.kind == "mlstm":
        p = d // h
        return {"C": ((batch, h, p, p), jnp.float32),
                "n": ((batch, h, p), jnp.float32)}
    if spec.kind == "slstm":
        return {"c": ((batch, d), jnp.float32),
                "n": ((batch, d), jnp.float32),
                "m": ((batch, d), jnp.float32)}
    raise ValueError(spec.kind)


def _cache_tree_shapes(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    """Note: shared_attn blocks share PARAMETERS, not caches — every
    invocation has its own stacked KV history (inputs differ per depth)."""
    is_sd = lambda x: (isinstance(x, tuple) and len(x) == 2
                       and isinstance(x[0], tuple))
    tree: dict = {}
    for si, seg in enumerate(cfg.segments):
        seg_tree = {}
        for pi, spec in enumerate(seg.layers):
            seg_tree[f"pos{pi}"] = jax.tree_util.tree_map(
                lambda sd, _r=seg.reps: ((_r,) + sd[0], sd[1]),
                _layer_cache_shapes(cfg, spec, batch, s_max), is_leaf=is_sd)
        tree[f"seg{si}"] = seg_tree
    return tree


def cache_specs(cfg: ModelConfig, batch: int, s_max: int):
    return jax.tree_util.tree_map(
        lambda sd: jax.ShapeDtypeStruct(*sd),
        _cache_tree_shapes(cfg, batch, s_max),
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple))


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    def make(path, sd):
        shp, dt = sd
        if path and getattr(path[-1], "key", None) == "m":
            return jnp.full(shp, -1e30, dt)   # sLSTM stabiliser: empty = -inf
        return jnp.zeros(shp, dt)

    return jax.tree_util.tree_map_with_path(
        make, _cache_tree_shapes(cfg, batch, s_max),
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple))


def _decode_layer(x, p, c, spec: LayerSpec, cfg: ModelConfig, pos, shared):
    eps = cfg.norm_eps
    if spec.kind == "shared_attn":
        p = shared            # parameters shared; cache is per invocation
    if spec.kind in ("attn", "moe", "shared_attn"):
        h, ck, cv = L.decode_attention(L.rms_norm(x, p["ln1"], eps), p, cfg,
                                       spec.window, c["k"], c["v"], pos)
        x = x + h
        if spec.kind == "moe":
            x = x + L.moe_mlp(L.rms_norm(x, p["ln2"], eps), p, cfg)
        else:
            x = x + L.dense_mlp(L.rms_norm(x, p["ln2"], eps), p, cfg)
        return x, {"k": ck, "v": cv}
    if spec.kind == "mamba2":
        h, st = S.mamba2_decode(L.rms_norm(x, p["ln1"], eps), p, cfg, c["state"])
        return x + h, {"state": st}
    if spec.kind == "mlstm":
        h, cc, nn = _mlstm_decode(L.rms_norm(x, p["ln1"], eps), p, cfg, c["C"], c["n"])
        return x + h, {"C": cc, "n": nn}
    if spec.kind == "slstm":
        h, new = _slstm_decode(L.rms_norm(x, p["ln1"], eps), p, cfg, c)
        return x + h, new
    raise ValueError(spec.kind)


def _mlstm_decode(x, p, cfg, C, n):
    b, _, d = x.shape
    h = cfg.n_heads
    hd = d // h
    cd = CDTYPE
    xc = x[:, 0].astype(cd)
    q = (xc @ p["wq"].astype(cd)).reshape(b, h, hd).astype(jnp.float32)
    k = (xc @ p["wk"].astype(cd)).reshape(b, h, hd).astype(jnp.float32) / math.sqrt(hd)
    v = (xc @ p["wv"].astype(cd)).reshape(b, h, hd).astype(jnp.float32)
    gates = (xc @ p["w_if"].astype(cd)).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)
    f = jax.nn.sigmoid(f_pre)
    i = jnp.exp(jnp.clip(i_pre, None, 10.0))
    C = f[..., None, None] * C + (i * 1.0)[..., None, None] * k[..., :, None] * v[..., None, :]
    n = f[..., None] * n + i[..., None] * k
    num = jnp.einsum("bhk,bhkp->bhp", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n))[..., None], 1.0)
    y = (num / den).reshape(b, d).astype(cd)
    y = y * jax.nn.silu(xc @ p["w_z"].astype(cd))
    return (y @ p["w_out"].astype(cd)).astype(x.dtype)[:, None], C, n


def _slstm_decode(x, p, cfg, c):
    cd = CDTYPE
    xc = x[:, 0].astype(cd)
    gates = (xc @ p["w_gates"].astype(cd)).astype(jnp.float32) + p["b_gates"]
    z_t, i_t, f_t, o_t = jnp.split(gates, 4, axis=-1)
    m_new = jnp.maximum(f_t + c["m"], i_t)
    i_e = jnp.exp(i_t - m_new)
    f_e = jnp.exp(f_t + c["m"] - m_new)
    cc = f_e * c["c"] + i_e * jnp.tanh(z_t)
    nn = f_e * c["n"] + i_e
    hh = jax.nn.sigmoid(o_t) * cc / jnp.maximum(nn, 1.0)
    y = (hh.astype(cd) @ p["w_out"].astype(cd)).astype(x.dtype)[:, None]
    return y, {"c": cc, "n": nn, "m": m_new}


# §Perf variant: thread decode caches through the scan CARRY with per-step
# dynamic-index updates instead of the xs→ys copy.  The ys path makes XLA
# double-buffer the whole cache (read stack + written stack); the carry is
# single-buffered and aliases with the donated input.
CACHE_CARRY = False


def set_cache_carry(v: bool):
    global CACHE_CARRY
    CACHE_CARRY = bool(v)


def decode_forward(params, cache, token, pos, cfg: ModelConfig):
    """token: (B, 1) int32; pos: () int32. Returns (logits (B,1,V), cache)."""
    x = params["embed"][token].astype(CDTYPE) * math.sqrt(cfg.d_model)
    shared = params.get("shared")
    new_cache: dict = {}

    for si, seg in enumerate(cfg.segments):
        seg_params = params[f"seg{si}"]
        seg_cache = cache[f"seg{si}"]

        def apply_layers(h, lp, lc, _seg=seg):
            out_c = {}
            for pi, spec in enumerate(_seg.layers):
                p = lp.get(f"pos{pi}") if spec.kind != "shared_attn" else None
                h, nc = _decode_layer(h, p, lc[f"pos{pi}"], spec, cfg, pos,
                                      shared)
                out_c[f"pos{pi}"] = nc
            return h, out_c

        if CACHE_CARRY:
            def body(carry, inp):
                h, sc = carry
                lp, i = inp
                lc = jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                           keepdims=False), sc)
                h, out_c = apply_layers(h, lp, lc)
                sc = jax.tree_util.tree_map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new.astype(full.dtype), i, 0), sc, out_c)
                return (h, sc), None

            (x, seg_cache_new), _ = jax.lax.scan(
                body, (x, seg_cache), (seg_params, jnp.arange(seg.reps)),
                length=seg.reps, unroll=scan_unroll())
        else:
            def body(h, inp):
                lp, lc = inp
                return apply_layers(h, lp, lc)

            x, seg_cache_new = jax.lax.scan(body, x, (seg_params, seg_cache),
                                            length=seg.reps,
                                            unroll=scan_unroll())
        new_cache[f"seg{si}"] = seg_cache_new
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, h, cfg)
    return logits[..., :cfg.vocab], new_cache
