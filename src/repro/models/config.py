"""Model configuration covering the ten assigned architectures.

A model is a list of *segments*; each segment is `reps` repetitions of a
homogeneous super-block executed as one lax.scan (compile time is O(#segments),
never O(#layers)).  A super-block is itself a short static list of layer
specs, so heterogeneous interleavings (gemma-3's 5 local : 1 global, zamba2's
6 mamba : 1 shared-attention) stay scannable.

Layer kinds: 'attn' (attention + dense MLP), 'moe' (attention + MoE MLP),
'mamba2', 'mlstm', 'slstm', 'shared_attn' (zamba2: one parameter set reused
at every invocation).
"""
from __future__ import annotations

import dataclasses

import jax


FULL_ATTENTION = -1  # window sentinel: full causal

# Cost-model mode: XLA's cost_analysis counts a while-loop body ONCE, not
# × trip count, so the dry-run's costing pass unrolls every flop-carrying
# scan (segments, q-chunks, loss chunks) on reduced-depth configs and
# extrapolates (launch/dryrun.py).  Flipped only under that pass.
SCAN_UNROLL = False


def set_scan_unroll(v: bool):
    global SCAN_UNROLL
    SCAN_UNROLL = bool(v)


def scan_unroll() -> bool:
    return SCAN_UNROLL


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                    # attn | moe | mamba2 | mlstm | slstm | shared_attn
    window: int = FULL_ATTENTION  # sliding-window size (attention kinds)


@dataclasses.dataclass(frozen=True)
class Segment:
    reps: int                    # scan length
    layers: tuple[LayerSpec, ...]  # unrolled inside the scan body

    @property
    def n_layers(self) -> int:
        return self.reps * len(self.layers)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    segments: tuple[Segment, ...]
    head_dim: int | None = None
    qkv_bias: bool = False       # qwen-style
    mlp: str = "swiglu"          # swiglu | geglu | gelu
    n_experts: int = 0
    top_k: int = 0
    moe_capacity: float = 1.25
    moe_group: int = 256         # routing group size (dispatch tile)
    ssm_state: int = 64
    ssm_chunk: int = 128         # chunked linear-recurrence block
    ssm_expand: int = 2          # mamba2 inner expansion (d_inner = e·d)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    vocab_pad_to: int = 2048     # Megatron-style padded vocab for sharding
    tie_embeddings: bool = True
    modality: str = "text"       # text | audio_tokens | image_tokens (stub frontends)
    max_position: int = 131_072
    kv_dtype: str = "bf16"       # | "int8" (quantized KV cache, §Perf variant)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        v = self.vocab
        return v + ((-v) % self.vocab_pad_to)

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.segments)

    def n_params(self) -> int:
        """Exact parameter count — walks the implementation's shape tree, so
        the 6ND roofline always matches the lowered program."""
        import math as _math
        from repro.models.transformer import _tree_shapes
        leaves = jax.tree_util.tree_leaves(
            _tree_shapes(self), is_leaf=lambda x: isinstance(x, tuple))
        return int(sum(_math.prod(s) for s in leaves))

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.n_params()
        dense_frac = self.top_k / self.n_experts
        d = self.d_model
        n_mlp_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        moe_total = sum(seg.reps * sum(1 for sp in seg.layers if sp.kind == "moe")
                        for seg in self.segments)
        inactive = moe_total * (1 - dense_frac) * self.n_experts * n_mlp_mats * d * self.d_ff
        return int(self.n_params() - inactive)


def uniform_segments(n_layers: int, kind: str = "attn",
                     window: int = FULL_ATTENTION) -> tuple[Segment, ...]:
    return (Segment(reps=n_layers, layers=(LayerSpec(kind, window),)),)


def pattern_segments(n_layers: int, pattern: tuple[LayerSpec, ...]) -> tuple[Segment, ...]:
    assert n_layers % len(pattern) == 0, (n_layers, len(pattern))
    return (Segment(reps=n_layers // len(pattern), layers=pattern),)
