"""Distributed spherical K-means: the paper's pipeline on a pod mesh.

Layout (DESIGN.md §4):
  objects   — sharded over the object axes ("pod","data") / ("data",);
  centroids — sharded over "model": each device owns K/|model| columns of the
              transposed mean matrix (its slice of the mean-inverted index);
  thresholds (t_th, v_th, ρ_max) — replicated; the paper's "shared with all
              objects" becomes "shared across the mesh".

One fused step = assignment + update:
  1. per (object-shard × centroid-shard): ES gathering + filter on the local
     K/|model| centroids, local top-1;
  2. (max, argmin-index) all-reduce over "model" — O(B) bytes/object batch,
     never O(B·K).  This is the only assignment-phase collective;
  3. update: local cluster sums for owned centroids produced by the pluggable
     backend accumulator (core/backends.py: reference scatter | pallas
     ``segment_update`` | xla_blocked scatter-add — any registered backend
     threads through unchanged; prepared-plan operands are built for the
     pallas engine only, the others run the exact plan-less path), psum over
     object axes (compiles to reduce-scatter + all-gather), L2 normalise;
  4. ρ_self refresh via the backend's own-centroid gather where the centroid
     shard lives, psum over "model";
  5. exact invariant-centroid (ICP) flags from membership deltas.

Every accumulator — assignment scan/kernels AND update segment reductions —
comes from the shared :mod:`repro.core.backends` protocol: the shard-local
step builds a local :class:`MeanIndex` view of its centroid slice and feeds
it to the same ``Backend.accumulate`` the single-host engine uses, so this
module owns collectives and sharding, never a private TAAT re-implementation.

Object batching inside the shard keeps the (chunk × K_loc) similarity tile
VMEM/HBM-friendly; chunk size is the software-pipelining knob measured in
EXPERIMENTS.md §Perf.

The public fitting entry point is :func:`mesh_fit` — the 'mesh' execution
strategy behind ``repro.cluster.SphericalKMeans(mesh=...)``.  The historical
``dist_fit(...)`` signature survives as a deprecation shim over it.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map


def object_axes(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes except 'model' shard the object dimension."""
    return tuple(n for n in mesh.axis_names if n != "model")


class PlanMeta(NamedTuple):
    """Static geometry of the prepared-plan operands a step function was
    built for (kernels/plan.py): occ grouping + head-cache width, plus the
    autotuned kernel config (repro.tune.TunedConfig) the geometry came
    from — carried so the reconstructed per-chunk plans launch with it."""
    b_blk: int
    d_blk: int
    n_head: int
    dim: int
    tuned: object | None = None


@dataclasses.dataclass(frozen=True)
class DistKMeansState:
    """Global jax.Arrays with the shardings described above."""
    means_t: jax.Array    # (D, K)   P(None, 'model')
    assign: jax.Array     # (N,)     P(obj)
    rho_self: jax.Array   # (N,)     P(obj)
    rho_prev: jax.Array   # (N,)     P(obj)
    moving: jax.Array     # (K,)     P('model')
    iteration: jax.Array  # ()       replicated
    ub: jax.Array         # (N, G)   P(obj, None) — drift-loosened per-bound-
    #                       group similarity upper bounds (bounds modes;
    #                       +inf = no bound known).  G = n_ub_groups(k),
    #                       replicated over 'model': groups tier the GLOBAL
    #                       centroid ids (core/update.ub_group_of), so a
    #                       shard's contiguous column slice maps to
    #                       contiguous groups.


def _local_index(means_t, moving, t_th, v_th):
    """The shard's (D, K_loc) centroid slice as a MeanIndex — the view the
    shared backend accumulators consume (thresholds are replicated, so the
    region masks are identical on every shard)."""
    from repro.core.meanindex import StructuralParams, build_mean_index

    params = StructuralParams(t_th=t_th, v_th=v_th)
    return build_mean_index(means_t.T, params, moving=moving)


def _step_local(ids, vals, valid, assign, rho_self, rho_prev, ub, means_t,
                moving, t_th, v_th, iteration, *plan_args, algo: str, axes_obj,
                k: int, obj_chunk: int, lambda_dtype=jnp.float32,
                taat_unroll: bool = False, two_phase: bool = False,
                p_block: int = 1, p_tail: int = 16,
                backend: str = "reference", plan_meta=None):
    from repro.core.assignment import SKETCH_MARGIN_BETA, _region3_bound
    from repro.core.backends import BACKENDS, gather_verify_scan
    from repro.core.meanindex import normalized_means
    from repro.core.update import drift_loosen, n_ub_groups, ub_group_size
    from repro.sparse import SparseDocs

    bk = BACKENDS[backend]
    n_loc, p = ids.shape
    d, k_loc = means_t.shape
    k0 = lax.axis_index("model") * k_loc
    xstate = (rho_self >= rho_prev) & (iteration >= 2) & valid
    index_loc = _local_index(means_t, moving, t_th, v_th)
    # Bound groups tier the GLOBAL centroid ids (static geometry; k0 is
    # traced, so the local-column → group map is a traced gather index).
    gsz = ub_group_size(k)
    n_grp = n_ub_groups(k)
    gid_loc = (k0 + jnp.arange(k_loc, dtype=jnp.int32)) // gsz   # (K_loc,)
    gmat = gid_loc[:, None] == jnp.arange(n_grp, dtype=jnp.int32)[None, :]

    # ---------------- assignment, chunked over local objects ---------------
    nc = n_loc // obj_chunk

    # Prepared-plan operands (mesh_fit builds them once per fit for the
    # pallas backend): the per-obj_chunk-tile occupancy map and, when the
    # budget allows, the cached high-df head slabs — sharded over the
    # object axes exactly like ids/vals, sliced per chunk below.
    occ = head = None
    gpt = 1
    if plan_meta is not None:
        from repro.kernels.plan import KernelPlan

        gpt = -(-obj_chunk // plan_meta.b_blk)
        occ = plan_args[0]
        if plan_meta.n_head > 0:
            head = plan_args[1]

        def _chunk_plan(o, h):
            return KernelPlan(occ=o, head=h, headc=None,
                              b_blk=plan_meta.b_blk, d_blk=plan_meta.d_blk,
                              n_head=plan_meta.n_head, dim=plan_meta.dim,
                              tuned=plan_meta.tuned)
    else:
        def _chunk_plan(o, h):
            return None

    def chunk_fn(args):
        (cids, cvals, cval, cassign, crho, cxs, cub), (cocc, chead) = args
        col_ok = moving[None, :] | ~cxs[:, None]
        cnnz = jnp.sum(cvals != 0.0, axis=1)       # tf-idf: live ⇔ val > 0
        bounded = algo in ("bounds", "sketch", "bounds-esicp")
        sk = es_ub = None
        if two_phase and algo == "esicp":
            masked, surv = gather_verify_scan(
                cids, cvals, cnnz, means_t, t_th, v_th, crho, col_ok,
                unroll=taat_unroll, p_block=p_block, p_tail=p_tail)
        else:
            # The shared backend protocol on the local tile: the reference
            # TAAT scan or the pallas kernels, exactly as the single-host
            # engine runs them (core/backends.py).
            cdocs = SparseDocs(ids=cids, vals=cvals, nnz=cnnz, dim=d)
            mode = "esicp" if algo in ("esicp", "bounds-esicp") else "exact"
            out = bk.accumulate(cdocs, index_loc, cxs, mode=mode, diag=False,
                                unroll=taat_unroll, p_block=p_block,
                                plan=_chunk_plan(cocc, chead))
            sims = out["sims"]
            if bounded:
                # The compounded modes are exact by construction: sims is
                # the full exact similarity row, selection runs unmasked;
                # the gates below drive only the candidate diagnostics and
                # the bound refresh (mirrors core/assignment.py).
                ga = (cub > crho[:, None]) & cval[:, None]   # (C, G)
                pa = jnp.take(ga, gid_loc, axis=1)           # (C, K_loc)
                rho_pos = crho > 0.0
                if algo == "bounds":
                    surv = pa
                elif algo == "sketch":
                    sk = bk.sketch_sim(cdocs, index_loc,
                                       plan=_chunk_plan(cocc, chead))
                    surv = jnp.where(rho_pos[:, None],
                                     sk > crho[:, None], True)
                else:                               # bounds-esicp
                    es_ub = out["rho12"] + out["y"] * v_th
                    gate = col_ok & pa
                    crude = (es_ub > crho[:, None]) & gate
                    r3_bound, _ = _region3_bound(cdocs, index_loc)
                    ref_ub = out["rho12"] + jnp.minimum(
                        out["y"] * v_th, r3_bound)
                    checked = crude & (
                        out["rho12"] + SKETCH_MARGIN_BETA * out["y"] * v_th
                        <= crho[:, None])
                    surv = crude & jnp.where(checked,
                                             ref_ub > crho[:, None], True)
                masked = sims
            elif algo == "esicp":
                surv = ((out["rho12"] + out["y"] * v_th)
                        > crho[:, None]) & col_ok
                masked = jnp.where(surv, sims, -jnp.inf)
            elif algo == "mivi":
                surv = jnp.ones_like(col_ok)
                masked = jnp.where(surv, sims, -jnp.inf)
            elif algo == "icp":
                surv = col_ok
                masked = jnp.where(surv, sims, -jnp.inf)
            else:
                raise ValueError(algo)
        lbest = jnp.max(masked, axis=1)
        lidx = (jnp.argmax(masked, axis=1) + k0).astype(jnp.int32)
        best = lax.pmax(lbest, "model")
        cand = jnp.where(lbest >= best, lidx, k)      # lowest global id wins
        widx = lax.pmin(cand, "model").astype(jnp.int32)
        improve = (best > crho) & cval
        na = jnp.where(improve, widx, cassign)
        n_surv = jnp.sum(jnp.where(cval[:, None], surv, False),
                         dtype=jnp.float32)
        cub_new = cub
        if bounded and algo != "sketch":
            # Refresh active groups to the global per-group second-best:
            # per local column, the tightest applicable upper bound with
            # the global winner column masked out; a local per-group max
            # (one-hot over the column→group map), then pmax over 'model'
            # completes each group's max — shards owning none of a group's
            # columns contribute -inf.
            if algo == "bounds":
                b = sims
            else:
                b = jnp.where(surv, sims, jnp.inf)
                b = jnp.minimum(b, jnp.where(checked, ref_ub, jnp.inf))
                b = jnp.minimum(b, jnp.where(gate, es_ub, jnp.inf))
                b = jnp.minimum(b, jnp.where(pa & ~col_ok,
                                             crho[:, None], jnp.inf))
            gcols = k0 + jnp.arange(k_loc, dtype=jnp.int32)[None, :]
            nb = jnp.where(gcols == na[:, None], -jnp.inf, b)
            gb = jnp.max(jnp.where(gmat[None, :, :], nb[:, :, None],
                                   -jnp.inf), axis=1)         # (C, G)
            gb = lax.pmax(gb, "model")
            cub_new = jnp.where(ga, gb, cub)
        return na, n_surv, cub_new

    resh = lambda a: a.reshape((nc, obj_chunk) + a.shape[1:])
    occ_r = None if occ is None else occ.reshape((nc, gpt) + occ.shape[1:])
    head_r = None if head is None else resh(head)
    na, n_surv, nub = lax.map(chunk_fn, ((resh(ids), resh(vals), resh(valid),
                                          resh(assign), resh(rho_self),
                                          resh(xstate), resh(ub)),
                                         (occ_r, head_r)))
    assign_new = na.reshape(n_loc)
    ub_new = nub.reshape((n_loc,) + nub.shape[2:])
    n_candidates = lax.psum(jnp.sum(n_surv), axes_obj + ("model",))

    # ---------------- update: cluster sums for owned centroids -------------
    # The backend owns the segment sums (reference scatter drops the
    # out-of-range safe_a = k_loc rows; the pallas segment_update kernel
    # never materialises them) — the psum consumes the backend accumulator.
    local_a = assign_new - k0
    in_range = (local_a >= 0) & (local_a < k_loc) & valid
    safe_a = jnp.where(in_range, local_a, k_loc)

    # Cached slabs stay exact under the in_range masking: rows outside this
    # shard's centroid range carry safe_a = k_loc, whose one-hot selection
    # row is all zero — the slab value never reaches the accumulator.
    def _upd_plan(ci):
        o = None if occ is None else lax.dynamic_slice_in_dim(
            occ, ci * gpt, gpt, 0)
        h = None if head is None else lax.dynamic_slice_in_dim(
            head, ci * obj_chunk, obj_chunk, 0)
        return _chunk_plan(o, h)

    def acc_body(ci, lam):
        sl = lambda a: lax.dynamic_slice_in_dim(a, ci * obj_chunk, obj_chunk, 0)
        cvals = jnp.where(sl(in_range)[:, None], sl(vals), 0.0)
        return bk.accumulate_means(sl(ids), cvals, sl(safe_a),
                                   k=k_loc, dim=d, init=lam,
                                   plan=_upd_plan(ci))

    lam = lax.fori_loop(0, nc, acc_body, jnp.zeros((k_loc, d), jnp.float32))
    # §Perf variant: compress the cluster-sum all-reduce (the step's dominant
    # collective) to bf16 — the k-means analogue of gradient compression.
    # Not bit-exact vs Lloyd; f32 default preserves the acceleration contract.
    lam = lax.psum(lam.astype(lambda_dtype), axes_obj).astype(jnp.float32)
    means_new = normalized_means(lam, means_t)
    means_new_t = means_new.T.astype(means_t.dtype)             # (D, K_loc)

    # ---------------- ρ_self refresh (Alg. 6 lines 6–7) --------------------
    def rho_body(ci, out):
        sl = lambda a: lax.dynamic_slice_in_dim(a, ci * obj_chunk, obj_chunk, 0)
        cvals = jnp.where(sl(in_range)[:, None], sl(vals), 0.0)
        r = bk.self_sims(sl(ids), cvals, sl(safe_a), means_new_t,
                         plan=_upd_plan(ci))
        return lax.dynamic_update_slice_in_dim(out, r, ci * obj_chunk, 0)

    rho_new = lax.fori_loop(0, nc, rho_body, jnp.zeros((n_loc,), jnp.float32))
    rho_new = lax.psum(rho_new, "model")    # exactly one shard contributes

    # ---------------- exact ICP flags from membership deltas ---------------
    changed = (assign_new != assign) & valid
    old_local = jnp.where((assign - k0 >= 0) & (assign - k0 < k_loc),
                          assign - k0, k_loc)
    mv = jnp.zeros((k_loc + 1,), jnp.int32)
    mv = mv.at[safe_a].max(changed.astype(jnp.int32))
    mv = mv.at[old_local].max(changed.astype(jnp.int32))
    moving_new = lax.psum(mv[:k_loc], axes_obj) > 0

    n_changed = lax.psum(jnp.sum(changed, dtype=jnp.float32), axes_obj)
    objective = lax.psum(jnp.sum(jnp.where(valid, rho_new, 0.0)), axes_obj)

    # Bound maintenance against the means THIS step just produced: each
    # bound group's worst per-center angular drift (local columns scattered
    # into their global groups, zero for unowned groups), pmax'ed over the
    # centroid shards ('model'), loosens every object's refreshed bounds
    # (core/update.drift_loosen) — the mesh twin of update_step's
    # group_drift pass.
    dots = jnp.sum(means_new_t * means_t, axis=0)
    d_loc = jnp.arccos(jnp.clip(dots, -1.0, 1.0))             # (K_loc,)
    delta = lax.pmax(
        jnp.max(jnp.where(gmat, d_loc[:, None], 0.0), axis=0), "model")
    ub_new = drift_loosen(ub_new, delta)

    return (means_new_t, assign_new, rho_new, rho_self, ub_new, moving_new,
            n_changed, n_candidates, objective)


def make_step_fn(mesh: Mesh, *, algo: str = "esicp", k: int,
                 obj_chunk: int = 2048, lambda_dtype=jnp.float32,
                 taat_unroll: bool = False, two_phase: bool = False,
                 p_block: int = 1, p_tail: int = 16,
                 backend: str = "reference", plan_meta: PlanMeta | None = None):
    """Builds the jitted fused assignment+update step for `mesh`.

    taat_unroll: dry-run costing mode — unrolls the P-step TAAT scan so
    XLA's cost model counts every multiply (launch/dryrun.py pass B).
    backend: 'reference' (TAAT scan) | 'pallas' (kernels on the local tile)
    | 'auto' — see core/backends.py for selection semantics.
    plan_meta: when set, the step takes the prepared-plan operands (the
    per-obj_chunk occupancy map and, if ``plan_meta.n_head > 0``, the
    cached head slabs) as trailing arguments, sharded like ids/vals —
    ``mesh_fit`` builds both once per fit (see :func:`build_plan_operands`).
    """
    from repro.core.backends import resolve_backend
    backend = resolve_backend(backend).name
    if two_phase and backend != "reference":
        raise ValueError("two_phase is a reference-backend scan variant; "
                         "use backend='reference' with it")
    axes_obj = object_axes(mesh)
    po = P(axes_obj)
    specs_in = (
        P(axes_obj, None), P(axes_obj, None), po,       # ids, vals, valid
        po, po, po,                                     # assign, rho_self, rho_prev
        P(axes_obj, None),                              # ub (N, G)
        P(None, "model"), P("model"),                   # means_t, moving
        P(), P(), P(),                                  # t_th, v_th, iteration
    )
    if plan_meta is not None:
        specs_in += (P(axes_obj, None),)                # occ
        if plan_meta.n_head > 0:
            specs_in += (P(axes_obj, None),)            # head slabs
    specs_out = (
        P(None, "model"), po, po, po, P(axes_obj, None), P("model"),
        P(), P(), P(),
    )
    fn = shard_map(
        partial(_step_local, algo=algo, axes_obj=axes_obj, k=k,
                obj_chunk=obj_chunk, lambda_dtype=lambda_dtype,
                taat_unroll=taat_unroll, two_phase=two_phase,
                p_block=p_block, p_tail=p_tail, backend=backend,
                plan_meta=plan_meta),
        mesh=mesh, in_specs=specs_in, out_specs=specs_out)
    return jax.jit(fn)


def build_plan_operands(ids, vals, valid, *, dim: int, obj_chunk: int,
                        mesh: Mesh, head_bytes: int | None = None,
                        tuned=None):
    """Once-per-fit prepared-plan operands for the pallas mesh step.

    Returns ``(plan_meta, operands)``: the per-obj_chunk-tile occupancy map
    and (budget permitting) the densified high-df head slabs, device_put
    with the same object-axis sharding as ids/vals.  Dead/padding rows are
    never occupied and densify to zero, so the global padded arrays are
    used as-is.

    ``tuned`` (repro.tune.TunedConfig) supplies the block geometry and head
    budget when set — the distributed analogue of ``prepare_plan(tuned=)``;
    an explicit ``head_bytes`` still wins over the tuned budget.
    """
    from repro.kernels import plan as kplan

    b_blk = kplan.DEFAULT_B_BLK if tuned is None else tuned.b_blk
    d_blk = kplan.DEFAULT_D_BLK if tuned is None else tuned.d_blk
    if head_bytes is None and tuned is not None:
        head_bytes = tuned.head_bytes
    axes_obj = object_axes(mesh)
    sh = NamedSharding(mesh, P(axes_obj, None))
    mvals = jnp.where(valid[:, None], vals, 0.0)
    occ = kplan.occupancy_map(ids, mvals, dim=dim, b_blk=b_blk, d_blk=d_blk,
                              tile_rows=obj_chunk)
    kw = {} if head_bytes is None else {"head_bytes": head_bytes}
    n_head = kplan.pick_n_head(ids.shape[0], dim, d_blk=d_blk,
                               with_counts=False, **kw)
    head, _ = kplan.head_slabs(ids, mvals, dim=dim, d_blk=d_blk,
                               n_head=n_head, with_counts=False)
    meta = PlanMeta(b_blk=b_blk, d_blk=d_blk,
                    n_head=0 if head is None else n_head, dim=dim,
                    tuned=tuned)
    operands = (jax.device_put(occ, sh),)
    if head is not None:
        operands += (jax.device_put(head, sh),)
    return meta, operands


# ---------------------------------------------------------------------------
# Drivers.
# ---------------------------------------------------------------------------

def dist_init_state(docs, k: int, mesh: Mesh, *, seed: int = 0) -> DistKMeansState:
    """Seed K centroids from random documents, shard everything onto `mesh`.

    ``docs`` may be a resident SparseDocs or an out-of-core
    :class:`repro.sparse.DocStore` — seeding gathers only the K picked
    rows from their chunks (the same PRNG draw and centroid construction
    as the single-host path, so runtimes agree from iteration 0).
    """
    import numpy as np

    from repro.core.meanindex import StructuralParams, build_mean_index
    from repro.core.update import init_state, seed_centroids, seed_rows
    from repro.sparse.store import DocStore

    n_model = mesh.shape["model"]
    if k % n_model:
        raise ValueError(f"K={k} must divide over the model axis ({n_model})")
    if isinstance(docs, DocStore):
        pick = seed_rows(docs.n_docs, k, seed=seed)
        sel = docs.gather_rows(np.asarray(pick))
        index = build_mean_index(seed_centroids(sel, k),
                                 StructuralParams.trivial(docs.dim))
        n = docs.n_docs
        means_t = index.means_t
        assign = jnp.zeros((n,), jnp.int32)
        rho_self = jnp.full((n,), -jnp.inf, jnp.float32)
        rho_prev = jnp.full((n,), -jnp.inf, jnp.float32)
        from repro.core.update import n_ub_groups
        ub = jnp.full((n, n_ub_groups(k)), jnp.inf, jnp.float32)
    else:
        core = init_state(docs, k, StructuralParams.trivial(docs.dim),
                          seed=seed)
        means_t, assign = core.index.means_t, core.assign
        rho_self, rho_prev = core.rho_self, core.rho_self_prev
        ub = core.ub
    axes_obj = object_axes(mesh)
    sh = lambda spec: NamedSharding(mesh, spec)
    return DistKMeansState(
        means_t=jax.device_put(means_t, sh(P(None, "model"))),
        assign=jax.device_put(assign, sh(P(axes_obj))),
        rho_self=jax.device_put(rho_self, sh(P(axes_obj))),
        rho_prev=jax.device_put(rho_prev, sh(P(axes_obj))),
        moving=jax.device_put(jnp.ones((k,), bool), sh(P("model"))),
        iteration=jnp.asarray(0, jnp.int32),
        ub=jax.device_put(ub, sh(P(axes_obj, None))),
    )


@functools.lru_cache(maxsize=None)
def _fill_rows_fn():
    """One jitted slice-writer per dtype trace: fills a sharded object
    buffer chunk by chunk, so a DocStore streams host→devices without the
    corpus ever being resident on the host as one block.  The buffer is
    DONATED — the whole point is an in-place fill of a corpus-sized array;
    without aliasing every chunk would copy the full buffer and double the
    peak (no-op on CPU, where XLA has no donation support)."""
    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(
        lambda buf, chunk, start: lax.dynamic_update_slice_in_dim(
            buf, chunk, start, 0),
        donate_argnums=donate)


def _place_store_sharded(store, mesh: Mesh, multiple: int):
    """Stream a DocStore's chunks into mesh-sharded (ids, vals, valid)
    object arrays padded to a ``multiple`` of rows (the per-host shard view
    the shard-local step consumes)."""
    from repro.sparse.store import ChunkPrefetcher

    axes_obj = object_axes(mesh)
    n, p, c = store.n_docs, store.pad_width, store.chunk_size
    pad = (-n) % multiple
    n_pad = n + pad
    sh = lambda spec: NamedSharding(mesh, spec)
    ids = jax.device_put(jnp.zeros((n_pad, p), jnp.int32),
                         sh(P(axes_obj, None)))
    vals = jax.device_put(jnp.zeros((n_pad, p), jnp.float32),
                          sh(P(axes_obj, None)))
    fill = _fill_rows_fn()
    for ci, cdocs in ChunkPrefetcher(store):
        start = ci * c
        if start >= n_pad:
            break
        m = min(c, n_pad - start)
        cid, cval = (cdocs.ids, cdocs.vals) if m == c else \
            (cdocs.ids[:m], cdocs.vals[:m])
        ids = fill(ids, cid, start)
        vals = fill(vals, cval, start)
    valid = jax.device_put(jnp.arange(n_pad) < n, sh(P(axes_obj)))
    return ids, vals, valid, pad


def dist_assignment_update(step_fn, state: DistKMeansState, ids, vals, valid,
                           t_th, v_th, plan_operands=()):
    """One fused step; returns (new_state, diag dict).  ``plan_operands``
    are the once-per-fit prepared-plan arrays a ``plan_meta``-built step
    expects (see :func:`build_plan_operands`)."""
    (means_t, assign, rho_self, rho_prev, ub, moving,
     n_changed, n_cand, objective) = step_fn(
        ids, vals, valid, state.assign, state.rho_self, state.rho_prev,
        state.ub, state.means_t, state.moving,
        jnp.asarray(t_th, jnp.int32), jnp.asarray(v_th, jnp.float32),
        state.iteration, *plan_operands)
    new = DistKMeansState(means_t=means_t, assign=assign, rho_self=rho_self,
                          rho_prev=rho_prev, moving=moving,
                          iteration=state.iteration + 1, ub=ub)
    diag = {"n_changed": n_changed, "n_candidates": n_cand,
            "objective": objective}
    return new, diag


def mesh_fit(docs, k: int, mesh: Mesh, *, algo: str = "esicp",
             backend: str = "reference", max_iter: int = 40,
             obj_chunk: int = 1024, seed: int = 0,
             est_iters=(1, 2), df=None, checkpoint_dir: str | None = None,
             checkpoint_every: int = 5, tune: str = "off", **step_kw):
    """Full distributed Lloyd loop with EstParams and optional checkpointing.

    ``docs`` may be a resident SparseDocs or an out-of-core
    :class:`repro.sparse.DocStore` whose chunks are streamed into the
    sharded object arrays (per-host shards of the data plane; see
    :func:`_place_store_sharded`).

    Returns ``(state, history, converged, params)`` — the final sharded
    :class:`DistKMeansState` (object arrays still carry the shard-multiple
    tail padding; rows ``[:docs.n_docs]`` are the real ones), the diagnostic
    history, the convergence flag, and the final StructuralParams.

    This is the 'mesh' execution strategy behind
    ``repro.cluster.SphericalKMeans(mesh=...)`` — prefer the estimator,
    which trims padding and wraps the result in a FittedModel.
    """
    import numpy as np
    from repro.cluster.config import ClusterConfig
    from repro.core.estparams import estimate_params
    from repro.core.meanindex import StructuralParams
    from repro.sparse.store import DocStore

    # Front-door validation (the same fail-fast contract as the estimator
    # and resolve_strategy): unknown algo/backend/tune, a K that doesn't
    # divide over 'model' — all rejected before any sharded work starts.
    ClusterConfig(k=k, algo=algo, backend=backend, max_iter=max_iter,
                  chunk_size=obj_chunk, mesh=mesh, est_iters=est_iters,
                  checkpoint_dir=checkpoint_dir,
                  checkpoint_every=checkpoint_every, tune=tune).validate()

    store = docs if isinstance(docs, DocStore) else None
    n = docs.n_docs
    axes_obj = object_axes(mesh)
    n_obj_shards = int(np.prod([mesh.shape[a] for a in axes_obj]))
    multiple = n_obj_shards * obj_chunk
    sh = lambda spec: NamedSharding(mesh, spec)

    if store is not None:
        # Out-of-core ingest: chunks stream host→devices into the sharded
        # object arrays — the aggregate device memory of the mesh holds the
        # corpus, the host only ever one chunk (+ the prefetched next).
        ids, vals, valid, pad = _place_store_sharded(store, mesh, multiple)
    else:
        pad = (-n) % multiple
        ids = jnp.pad(docs.ids, ((0, pad), (0, 0)))
        vals = jnp.pad(docs.vals, ((0, pad), (0, 0)))
        valid = jnp.arange(n + pad) < n
        ids = jax.device_put(ids, sh(P(axes_obj, None)))
        vals = jax.device_put(vals, sh(P(axes_obj, None)))
        valid = jax.device_put(valid, sh(P(axes_obj)))

    state = dist_init_state(docs, k, mesh, seed=seed)
    if pad:
        # Dead tail rows carry ρ_self = 0, matching the single-host padding
        # convention (core/lloyd.py): the refresh recomputes 0 for them every
        # iteration (no live tuples ⇒ zero similarity) and the objective
        # reduction masks on `valid` regardless, so the pad value never leaks
        # into diagnostics — unlike the previous -inf sentinel, which leaked
        # NaN-prone -inf arithmetic into any unmasked consumer.
        state = dataclasses.replace(
            state,
            assign=jax.device_put(jnp.pad(state.assign, (0, pad)), sh(P(axes_obj))),
            rho_self=jax.device_put(jnp.pad(state.rho_self, (0, pad)),
                                    sh(P(axes_obj))),
            rho_prev=jax.device_put(jnp.pad(state.rho_prev, (0, pad)),
                                    sh(P(axes_obj))),
            # Dead tail rows get ub = 0 — the ρ_self pad convention's twin
            # (see core/update.init_state_from_store).
            ub=jax.device_put(jnp.pad(state.ub, ((0, pad), (0, 0))),
                              sh(P(axes_obj, None))),
        )
    from repro.core.backends import resolve_backend

    two_phase = step_kw.pop("two_phase", False)
    if two_phase:
        if resolve_backend(backend).name != "reference":
            # Fail fast: the rebuild at r == max(est_iters) would otherwise
            # raise after iterations of completed clustering work.
            raise ValueError("two_phase is a reference-backend scan variant; "
                             "use backend='reference' with it")
    # Once-per-fit prepared-plan operands for the kernel backend: the
    # occupancy map + cached head slabs every iteration's step reuses
    # (documents are constant across Lloyd iterations).
    plan_meta, plan_ops = None, ()
    if resolve_backend(backend).name == "pallas":
        # Tuned-config resolution is cache-only here: the sharded step is
        # compiled once per fit, so the mesh path never runs the autotuner
        # itself — a prior single-host/streaming fit (or an explicit
        # ``search_tuned_config`` run) populates the process cache, and
        # 'search' degrades to a cache lookup.  Signature is probed on the
        # first chunk / the resident corpus, matching what those paths key.
        tuned = None
        if tune not in ("off", "cached", "search"):
            raise ValueError(f"tune must be 'off', 'cached' or 'search', "
                             f"got {tune!r}")
        if tune != "off":
            from repro.tune import TUNED_CACHE, corpus_signature

            probe = store.chunk(0) if store is not None else docs
            sig = corpus_signature(probe.ids, probe.vals, dim=docs.dim, k=k)
            tuned = TUNED_CACHE.get(sig)
        plan_meta, plan_ops = build_plan_operands(
            ids, vals, valid, dim=docs.dim, obj_chunk=obj_chunk, mesh=mesh,
            tuned=tuned)
    # iterations 1–2 run trivial params (t_th=0): everything is Region 3, so
    # the windowed verification can't bound ntH — run single-phase until
    # EstParams fixes t_th, then rebuild the step (paper Alg. 6 does the same
    # index restructuring at that moment).
    step_fn = make_step_fn(mesh, algo=algo, k=k, obj_chunk=obj_chunk,
                           backend=backend, plan_meta=plan_meta, **step_kw)
    params = StructuralParams.trivial(docs.dim)

    if df is None:
        df = docs.df            # cached on the corpus (sparse/matrix.py)

    history = []
    converged = False
    for r in range(1, max_iter + 1):
        state, diag = dist_assignment_update(step_fn, state, ids, vals, valid,
                                             params.t_th, params.v_th,
                                             plan_ops)
        if algo == "esicp" and r in est_iters:
            if store is not None:
                # Full-corpus estimate, chunk-streamed (the same path the
                # streaming strategy uses); ρ rows beyond the store's tail
                # are the dead-row 0 convention and contribute nothing.
                from repro.core.estparams import estimate_params_store

                rho_rows = state.rho_self[:n]
                rho_rows = jnp.pad(rho_rows, (0, store.n_rows - n))
                params, _ = estimate_params_store(
                    store, df, state.means_t[:, :k], rho_rows, k=k)
            else:
                params, _ = estimate_params(docs, df, state.means_t[:, :k],
                                            state.rho_self[:n], k=k)
            if two_phase and r == max(est_iters):
                if store is not None:
                    t = int(params.t_th)
                    slots = np.arange(store.pad_width)[None, :]
                    nt_h = 0
                    for j in range(store.n_chunks):
                        cid, _, cnnz = store.host_chunk(j)
                        tail = (np.asarray(cid) >= t) \
                            & (slots < np.asarray(cnnz)[:, None])
                        nt_h = max(nt_h, int(tail.sum(axis=1).max(initial=0)))
                else:
                    nt_h = int(jnp.max(jnp.sum(
                        (docs.ids >= params.t_th) & docs.row_mask(), axis=1)))
                pb = step_kw.get("p_block", 1)
                p_tail = max(nt_h + ((-nt_h) % max(pb, 1)), pb)
                step_fn = make_step_fn(mesh, algo=algo, k=k,
                                       obj_chunk=obj_chunk, two_phase=True,
                                       p_tail=p_tail, backend=backend,
                                       **step_kw)
        history.append({"iteration": r,
                        "n_changed": float(diag["n_changed"]),
                        "cpr": float(diag["n_candidates"]) / (n * k),
                        "objective": float(diag["objective"]),
                        "t_th": int(params.t_th), "v_th": float(params.v_th)})
        if checkpoint_dir and r % checkpoint_every == 0:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(checkpoint_dir, {
                "means_t": state.means_t, "assign": state.assign,
                "rho_self": state.rho_self, "rho_prev": state.rho_prev,
                "moving": state.moving, "iteration": state.iteration,
                "ub": state.ub,
                "t_th": params.t_th, "v_th": params.v_th}, step=r)
        if history[-1]["n_changed"] == 0:
            converged = True
            break
    return state, history, converged, params


def dist_fit(docs, k: int, mesh: Mesh, **kw):
    """Deprecated pre-redesign entry point; use
    ``repro.cluster.SphericalKMeans(k, mesh=mesh, ...)`` (or :func:`mesh_fit`
    for the raw sharded state).  Same kwargs, same ``(state, history,
    converged)`` return value."""
    warnings.warn(
        "dist_fit is deprecated: construct repro.cluster.SphericalKMeans("
        "k, mesh=mesh, chunk_size=...) and call fit(), or use "
        "distributed.kmeans.mesh_fit for the raw sharded state.",
        DeprecationWarning, stacklevel=2)
    state, history, converged, _ = mesh_fit(docs, k, mesh, **kw)
    return state, history, converged


def make_assign_fn(mesh: Mesh, *, k: int, obj_chunk: int = 2048,
                   backend: str = "reference"):
    """Serving mode: classify new documents against a FROZEN mean index.

    The paper's engine as a lookup service — the assignment phase only
    (ES gathering + filter + (max, argmin-id) reduction over 'model'),
    no update step, no ICP state.  Returns assign (N,), sims (N,).
    """
    from repro.core.backends import resolve_backend
    backend = resolve_backend(backend).name
    axes_obj = object_axes(mesh)
    po = P(axes_obj)

    def _local(ids, vals, valid, means_t, t_th, v_th):
        from repro.core.backends import BACKENDS
        from repro.sparse import SparseDocs

        bk = BACKENDS[backend]
        n_loc, p = ids.shape
        d, k_loc = means_t.shape
        k0 = lax.axis_index("model") * k_loc
        nc = n_loc // obj_chunk
        index_loc = _local_index(means_t, jnp.ones((k_loc,), bool), t_th, v_th)

        def chunk_fn(args):
            cids, cvals, cval = args
            cdocs = SparseDocs(ids=cids, vals=cvals,
                               nnz=jnp.sum(cvals != 0.0, axis=1), dim=d)
            sims = bk.accumulate(cdocs, index_loc,
                                 jnp.zeros((obj_chunk,), bool),
                                 mode="exact", diag=False)["sims"]
            # serving has no previous similarity: bound via running best —
            # one exact pass, filter diagnostics only
            masked = jnp.where(jnp.ones_like(sims, bool), sims, -jnp.inf)
            lbest = jnp.max(masked, axis=1)
            lidx = (jnp.argmax(masked, axis=1) + k0).astype(jnp.int32)
            best = lax.pmax(lbest, "model")
            cand = jnp.where(lbest >= best, lidx, k)
            widx = lax.pmin(cand, "model").astype(jnp.int32)
            return jnp.where(cval, widx, 0), jnp.where(cval, best, 0.0)

        resh = lambda a: a.reshape((nc, obj_chunk) + a.shape[1:])
        aa, ss = lax.map(chunk_fn, (resh(ids), resh(vals), resh(valid)))
        return aa.reshape(n_loc), ss.reshape(n_loc)

    fn = shard_map(_local, mesh=mesh,
                   in_specs=(P(axes_obj, None), P(axes_obj, None), po,
                             P(None, "model"), P(), P()),
                   out_specs=(po, po))
    return jax.jit(fn)
