"""Elastic re-meshing + straggler mitigation.

Node-failure posture for 1000+-node runs (DESIGN.md §4):

* All object-axis state (assign / ρ_self / ρ_prev) is a pure function of the
  object shard, so losing a data-parallel slice only loses objects that will
  be re-assigned next iteration anyway — the recovery path is: shrink the
  mesh, re-shard from the last checkpoint, continue.  Centroid state
  (means_t / moving) is the only state that must survive; it is sharded over
  "model" and checkpointed every few iterations.

* `reshard_state` moves a checkpointed state onto a *different* mesh (fewer
  or more hosts, different data-axis width).  Only the object axis changes;
  "model" layout is preserved so no centroid shuffling happens on recovery.

* `StepWatchdog` implements deterministic straggler detection: the step-time
  budget is a multiple of the trailing-median step time; a breach raises the
  checkpoint-restart path rather than letting one slow host serialise the
  pod (the classic straggler mitigation for synchronous data-parallel jobs).
"""
from __future__ import annotations

import time

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.kmeans import DistKMeansState, object_axes


def reshard_state(state: DistKMeansState, new_mesh: Mesh) -> DistKMeansState:
    """Re-place every array of `state` onto `new_mesh` (elastic rescale)."""
    axes_obj = object_axes(new_mesh)
    sh = lambda spec: NamedSharding(new_mesh, spec)
    return DistKMeansState(
        means_t=jax.device_put(state.means_t, sh(P(None, "model"))),
        assign=jax.device_put(state.assign, sh(P(axes_obj))),
        rho_self=jax.device_put(state.rho_self, sh(P(axes_obj))),
        rho_prev=jax.device_put(state.rho_prev, sh(P(axes_obj))),
        moving=jax.device_put(state.moving, sh(P("model"))),
        iteration=state.iteration,
        ub=jax.device_put(state.ub, sh(P(axes_obj, None))),
    )


class StepWatchdog:
    """Flags straggling steps against a trailing-median budget."""

    def __init__(self, factor: float = 3.0, warmup: int = 3):
        self.factor = factor
        self.warmup = warmup
        self.times: list[float] = []
        self._t0: float | None = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Returns True if this step breached the straggler budget."""
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        breach = False
        if len(self.times) >= self.warmup:
            med = sorted(self.times)[len(self.times) // 2]
            breach = dt > self.factor * med
        self.times.append(dt)
        if len(self.times) > 64:
            self.times.pop(0)
        return breach

    @property
    def budget(self) -> float | None:
        if len(self.times) < self.warmup:
            return None
        return self.factor * sorted(self.times)[len(self.times) // 2]
