from repro.distributed.kmeans import (
    DistKMeansState,
    dist_init_state,
    dist_assignment_update,
    dist_fit,
    mesh_fit,
)
from repro.distributed.elastic import reshard_state, StepWatchdog

__all__ = [
    "DistKMeansState", "dist_init_state", "dist_assignment_update",
    "dist_fit", "mesh_fit",
    "reshard_state", "StepWatchdog",
]
