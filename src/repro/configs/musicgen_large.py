"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens; the EnCodec frontend is a
stub: input_specs() provides precomputed frame embeddings (B, S_fe, D).
[arXiv:2306.05284; hf]"""
from repro.models.config import ModelConfig, uniform_segments

FRONTEND_FRAMES = 256   # stub conditioning prefix length


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192, vocab=2048,
        segments=uniform_segments(48),
        mlp="gelu", tie_embeddings=False, modality="audio_tokens",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=64,
        segments=uniform_segments(2),
        mlp="gelu", tie_embeddings=False, modality="audio_tokens",
        vocab_pad_to=64,
    )
