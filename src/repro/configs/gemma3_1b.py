"""gemma3-1b [dense] — 26L d_model=1152 4H (MQA kv=1, head_dim=256)
d_ff=6912 vocab=262144, 5:1 local:global sliding-window pattern, 128k ctx.
[hf:google/gemma-3-1b-pt; unverified]

26 layers = 4 × (5 local + 1 global) + 2 trailing local.
"""
from repro.models.config import ModelConfig, LayerSpec, Segment, FULL_ATTENTION

LOCAL_WINDOW = 512


def _segments(local: int, full: int) -> tuple[Segment, ...]:
    pat = tuple([LayerSpec("attn", window=local)] * 5 +
                [LayerSpec("attn", window=full)])
    return (
        Segment(reps=4, layers=pat),
        Segment(reps=1, layers=(LayerSpec("attn", window=local),
                                LayerSpec("attn", window=local))),
    )


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab=262144,
        segments=_segments(LOCAL_WINDOW, FULL_ATTENTION),
        mlp="geglu", tie_embeddings=True, rope_theta=1e6,
        max_position=131_072,
    )


def long_context_config() -> ModelConfig:
    """long_500k variant: global layers fall back to a 32k window so the
    whole stack stays sub-quadratic (documented in DESIGN.md §5)."""
    return ModelConfig(
        name="gemma3-1b-long", family="dense",
        d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab=262144,
        segments=_segments(LOCAL_WINDOW, 32_768),
        mlp="geglu", tie_embeddings=True, rope_theta=1e6,
        max_position=600_000,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense",
        d_model=48, n_heads=2, n_kv_heads=1, head_dim=16, d_ff=96, vocab=128,
        segments=(Segment(reps=1, layers=(LayerSpec("attn", window=8),
                                          LayerSpec("attn", window=FULL_ATTENTION))),),
        mlp="geglu", tie_embeddings=True, vocab_pad_to=64,
    )
