"""qwen1.5-32b [dense] — 64L d_model=5120 40H (MHA kv=40) d_ff=27392
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.models.config import ModelConfig, uniform_segments


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense",
        d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392, vocab=152064,
        segments=uniform_segments(64),
        qkv_bias=True, mlp="swiglu", tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", family="dense",
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=128,
        segments=uniform_segments(2),
        qkv_bias=True, mlp="swiglu", tie_embeddings=False, vocab_pad_to=64,
    )
