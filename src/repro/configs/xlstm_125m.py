"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304,
sLSTM + mLSTM blocks (xLSTM[5:1]-style interleave: one sLSTM per 6 layers).
[arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig, LayerSpec, Segment


def _segments(reps: int) -> tuple[Segment, ...]:
    pattern = tuple([LayerSpec("mlstm")] * 5 + [LayerSpec("slstm")])
    return (Segment(reps=reps, layers=pattern),)


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        d_model=768, n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
        segments=_segments(2),                    # 12 layers
        tie_embeddings=True, ssm_chunk=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        d_model=64, n_heads=2, n_kv_heads=2, d_ff=0, vocab=128,
        segments=(Segment(reps=1, layers=(LayerSpec("mlstm"), LayerSpec("slstm"))),),
        tie_embeddings=True, vocab_pad_to=64, ssm_chunk=16,
    )
