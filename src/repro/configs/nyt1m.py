"""The paper's second workload: 1M-sized NYT, K = 10 000 (§VI-A)."""
from repro.configs.pubmed8m import KMeansJob
from repro.data.synthetic import CorpusSpec


def config() -> KMeansJob:
    return KMeansJob(name="nyt1m", n_docs=1_285_944, vocab=495_126,
                     k=10_000, nt_mean=225.76)


def reduced(seed: int = 0) -> KMeansJob:
    spec = CorpusSpec(n_docs=10_000, vocab=16_384, nt_mean=120.0,
                      n_topics=100, seed=seed)
    return KMeansJob(name="nyt60k-reduced", n_docs=spec.n_docs,
                     vocab=spec.vocab, k=100, nt_mean=spec.nt_mean,
                     corpus=spec, max_iter=40, obj_chunk=1024)
