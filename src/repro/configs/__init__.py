from repro.configs.registry import get_config, list_archs, smoke_config, ARCHS

__all__ = ["get_config", "list_archs", "smoke_config", "ARCHS"]
