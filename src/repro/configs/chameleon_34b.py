"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion over VQ image tokens; the VQ tokenizer frontend
is a stub: input_specs() provides precomputed patch embeddings.
[arXiv:2405.09818; unverified]"""
from repro.models.config import ModelConfig, uniform_segments

FRONTEND_PATCHES = 1024   # stub image-token prefix length


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536,
        segments=uniform_segments(48),
        mlp="swiglu", tie_embeddings=False, modality="image_tokens",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-smoke", family="vlm",
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
        segments=uniform_segments(2),
        mlp="swiglu", tie_embeddings=False, modality="image_tokens",
        vocab_pad_to=64,
    )
