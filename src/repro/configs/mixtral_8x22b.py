"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig, uniform_segments

SWA_WINDOW = 4096


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768,
        segments=uniform_segments(56, kind="moe", window=SWA_WINDOW),
        n_experts=8, top_k=2, mlp="swiglu", tie_embeddings=False,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        segments=uniform_segments(2, kind="moe", window=16),
        n_experts=4, top_k=2, mlp="swiglu", tie_embeddings=False,
        vocab_pad_to=64, moe_group=32, moe_capacity=8.0,
    )
