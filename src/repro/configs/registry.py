"""Architecture registry: ``--arch <id>`` resolves here.

Every assigned architecture has its own module exporting ``config()`` (the
exact published shape) and ``smoke_config()`` (a reduced same-family config
for CPU tests).  The paper's own workloads (pubmed8m / nyt1m spherical
K-means jobs) live in ``pubmed8m.py`` / ``nyt1m.py``.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "mixtral-8x22b",
    "granite-moe-3b-a800m",
    "xlstm-125m",
    "qwen1.5-32b",
    "gemma3-1b",
    "gemma-2b",
    "qwen2.5-32b",
    "zamba2-2.7b",
    "musicgen-large",
    "chameleon-34b",
]


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; one of {ARCHS}")
    return _module(name).config()


def smoke_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; one of {ARCHS}")
    return _module(name).smoke_config()


def list_archs() -> list[str]:
    return list(ARCHS)
