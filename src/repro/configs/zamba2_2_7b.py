"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + one SHARED attention block
invoked every 6 layers (9 superblocks × (5 mamba2 + shared attn)).
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig, LayerSpec, Segment


def _segments(reps: int) -> tuple[Segment, ...]:
    pat = tuple([LayerSpec("mamba2")] * 5 + [LayerSpec("shared_attn")])
    return (Segment(reps=reps, layers=pat),)


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000,
        segments=_segments(9),                    # 54 layers
        ssm_state=64, ssm_chunk=128, mlp="gelu", tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
        segments=(Segment(reps=2, layers=(LayerSpec("mamba2"),
                                          LayerSpec("shared_attn"))),),
        ssm_state=16, ssm_chunk=16, mlp="gelu", tie_embeddings=True,
        vocab_pad_to=64,
    )
