"""The paper's own workload: 8.2M-sized PubMed, K = 80 000 (§VI-A).

Full-scale shapes drive the spherical-K-means dry-run; the reduced config
(`reduced()`) powers CPU benchmarks with the same universal characteristics.
"""
import dataclasses

from repro.data.synthetic import CorpusSpec


@dataclasses.dataclass(frozen=True)
class KMeansJob:
    name: str
    n_docs: int
    vocab: int
    k: int
    nt_mean: float
    corpus: CorpusSpec | None = None   # None → full scale (dry-run only)
    max_iter: int = 64
    obj_chunk: int = 4096


def config() -> KMeansJob:
    return KMeansJob(name="pubmed8m", n_docs=8_200_000, vocab=141_043,
                     k=80_000, nt_mean=58.96)


def reduced(seed: int = 0) -> KMeansJob:
    spec = CorpusSpec(n_docs=20_000, vocab=8_192, nt_mean=60.0,
                      n_topics=200, seed=seed)
    return KMeansJob(name="pubmed120k-reduced", n_docs=spec.n_docs,
                     vocab=spec.vocab, k=200, nt_mean=spec.nt_mean,
                     corpus=spec, max_iter=40, obj_chunk=1024)
