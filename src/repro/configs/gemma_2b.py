"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=256000, GeGLU, head_dim=256.  [arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig, uniform_segments


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b", family="dense",
        d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab=256000,
        segments=uniform_segments(18),
        mlp="geglu", tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-smoke", family="dense",
        d_model=48, n_heads=2, n_kv_heads=1, head_dim=32, d_ff=96, vocab=128,
        segments=uniform_segments(2),
        mlp="geglu", tie_embeddings=True, vocab_pad_to=64,
    )
