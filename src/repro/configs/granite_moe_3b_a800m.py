"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.models.config import ModelConfig, uniform_segments


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155,
        segments=uniform_segments(32, kind="moe"),
        n_experts=40, top_k=8, mlp="swiglu", tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", family="moe",
        d_model=48, n_heads=4, n_kv_heads=2, d_ff=32, vocab=128,
        segments=uniform_segments(2, kind="moe"),
        n_experts=8, top_k=4, mlp="swiglu", tie_embeddings=True,
        vocab_pad_to=64, moe_group=32, moe_capacity=8.0,
    )
