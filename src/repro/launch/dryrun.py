import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# keep bf16 in the lowered programs (CPU backend compiles bf16 fine; it just
# cannot execute it — the dry-run never executes)
os.environ.setdefault("REPRO_COMPUTE_DTYPE", "bfloat16")

"""Multi-pod dry-run driver.

For every (architecture × input shape × mesh) cell:
  jit(step).lower(*specs).compile()  →  memory_analysis + cost_analysis +
  collective-bytes parse  →  results/dryrun/<cell>.json

Nothing full-size is ever allocated: params/caches/tokens enter as
ShapeDtypeStructs.  Results are cached per cell so the sweep is resumable.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod-only --force
    PYTHONPATH=src python -m repro.launch.dryrun --kmeans        # paper's job
"""
import argparse
import json
import time
import traceback

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_enabled
from repro.launch.steps import build_cell, reduced_depth_config, VARIANTS
from repro.roofline.analysis import collective_bytes, cost_dict, roofline_terms, model_flops, HW

COST_KEYS = ("flops", "bytes accessed", "transcendentals")


def _measure_cost(cfg, mesh, shape, pv):
    """One unrolled compile -> (cost dict, collective dict)."""
    cell = build_cell(cfg, mesh, shape, microbatches=1, variant=pv)
    with mesh:
        compiled = cell.fn.lower(*cell.args).compile()
        cost = cost_dict(compiled.cost_analysis())
        coll = collective_bytes(compiled.as_text())
    return cost, coll


def cost_extrapolated(cfg, mesh, shape, pv) -> dict:
    """XLA counts while bodies once, so FLOPs/bytes/collectives come from two
    reduced-depth compiles with every scan UNROLLED, linearly extrapolated in
    layer count (exact for layer-homogeneous stacks; see launch/steps.py)."""
    from repro.models.config import set_scan_unroll
    set_scan_unroll(True)
    try:
        meas = {}
        for m in (1, 2):
            rcfg = reduced_depth_config(cfg, m)
            cost, coll = _measure_cost(rcfg, mesh, shape, pv)
            meas[m] = (cost, coll, rcfg.n_layers)
    finally:
        set_scan_unroll(False)
    (c1, l1_coll, n1), (c2, l2_coll, n2) = meas[1], meas[2]
    full_l = cfg.n_layers

    def extra(v1, v2):
        per_layer = (v2 - v1) / max(n2 - n1, 1)
        base = v1 - per_layer * n1
        return base + per_layer * full_l

    cost = {k: extra(float(c1.get(k, 0.0)), float(c2.get(k, 0.0)))
            for k in COST_KEYS}
    kinds = set(l1_coll) | set(l2_coll)
    coll = {k: extra(float(l1_coll.get(k, 0)), float(l2_coll.get(k, 0)))
            for k in kinds}
    return {"cost": cost, "collectives": coll,
            "depths_measured": [n1, n2], "layers_full": full_l}

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _mem_dict(mem) -> dict:
    return {k: getattr(mem, k) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes")}


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             out_dir: str = RESULTS_DIR, force: bool = False,
             variant: str = "baseline") -> dict:
    from repro.configs import get_config

    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"{arch}__{shape_name}__{mesh_tag}__{variant}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    shape = SHAPES[shape_name]
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
              "variant": variant, "status": "skip"}
    if not cell_enabled(arch, shape_name):
        record["reason"] = "long_500k requires a sub-quadratic stack"
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        return record

    if arch == "gemma3-1b" and shape_name == "long_500k":
        from repro.configs.gemma3_1b import long_context_config
        cfg = long_context_config()
    else:
        cfg = get_config(arch)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    pv = VARIANTS[variant]
    t0 = time.time()
    try:
        cell = build_cell(cfg, mesh, shape, variant=pv)
        with mesh:
            lowered = cell.fn.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost_raw = cost_dict(compiled.cost_analysis())
            coll_raw = collective_bytes(compiled.as_text())
        # correct trip-count undercounting via the unrolled reduced-depth pass
        cx = cost_extrapolated(cfg, mesh, shape, pv)
        terms = roofline_terms(cx["cost"], cx["collectives"])
        mf = model_flops(cfg, shape, n_chips)
        per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        record.update({
            "status": "ok",
            "n_chips": n_chips,
            "meta": cell.meta,
            "memory": _mem_dict(mem),
            "per_device_bytes": per_dev_bytes,
            "fits_hbm_16g": bool(per_dev_bytes < 16e9),
            "cost": {k: float(v) for k, v in cx["cost"].items()},
            "cost_scanned_raw": {k: float(v) for k, v in cost_raw.items()
                                 if isinstance(v, (int, float)) and k in COST_KEYS},
            "collectives": cx["collectives"],
            "collectives_scanned_raw": coll_raw,
            "cost_extrapolation": {k: cx[k] for k in
                                   ("depths_measured", "layers_full")},
            "roofline": terms,
            "model_flops": mf,
            "useful_flops_ratio": (mf["model_flops_per_dev"] /
                                   terms["flops_per_dev"]
                                   if terms["flops_per_dev"] else 0.0),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
        })
    except Exception as e:  # a failing cell is a bug — record it loudly
        record.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def run_kmeans_dryrun(multi_pod: bool, *, out_dir: str = RESULTS_DIR,
                      force: bool = False, variant: str = "baseline",
                      obj_chunk: int = 4096, tag: str | None = None) -> dict:
    """The paper's own workload: 8.2M PubMed, K=80 000, fused ES-ICP step.

    Two passes (same trick as the LM cells): pass A (chunked) for the memory
    analysis; pass B (single chunk, TAAT scan unrolled) for exact
    FLOPs/bytes/collectives — all loops become trip-1 so XLA's once-per-while
    counting is correct without extrapolation.
    """
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.pubmed8m import config as pubmed_config
    from repro.distributed.kmeans import make_step_fn, object_axes

    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"kmeans-pubmed8m__esicp__{mesh_tag}__{tag or variant}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    # kmeans variant grammar: flags joined by '+', e.g. "two-phase+pblock8"
    lambda_dtype = jnp.bfloat16 if "lambda-bf16" in variant else jnp.float32
    two_phase = "two-phase" in variant
    p_block = 8 if "pblock8" in variant else (4 if "pblock4" in variant else 1)
    means_dtype = jnp.bfloat16 if "means-bf16" in variant else jnp.float32
    job = pubmed_config()
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes_obj = object_axes(mesh)
    n_obj = 1
    for a in axes_obj:
        n_obj *= mesh.shape[a]
    n = job.n_docs + ((-job.n_docs) % (n_obj * obj_chunk))
    d = job.vocab + ((-job.vocab) % 256)
    k = job.k                        # 80 000 % 16 == 0
    p = 128                          # padded tuple width (nt̂ ≈ 59)

    sds = jax.ShapeDtypeStruct
    po = P(axes_obj)
    sh = lambda spec: NamedSharding(mesh, spec)
    args = (
        sds((n, p), jnp.int32), sds((n, p), jnp.float32), sds((n,), bool),
        sds((n,), jnp.int32), sds((n,), jnp.float32), sds((n,), jnp.float32),
        sds((d, k), means_dtype), sds((k,), bool),
        sds((), jnp.int32), sds((), jnp.float32), sds((), jnp.int32),
    )
    in_sh = (sh(P(axes_obj, None)), sh(P(axes_obj, None)), sh(po),
             sh(po), sh(po), sh(po),
             sh(P(None, "model")), sh(P("model")),
             sh(P()), sh(P()), sh(P()))

    def compile_pass(chunk, unroll):
        step = make_step_fn(mesh, algo="esicp", k=k, obj_chunk=chunk,
                            lambda_dtype=lambda_dtype, taat_unroll=unroll,
                            two_phase=two_phase, p_block=p_block)
        fn = jax.jit(step.__wrapped__ if hasattr(step, "__wrapped__") else step,
                     in_shardings=in_sh)
        with mesh:
            compiled = fn.lower(*args).compile()
            return (compiled.memory_analysis(),
                    cost_dict(compiled.cost_analysis()),
                    collective_bytes(compiled.as_text()))

    record = {"arch": "kmeans-pubmed8m", "shape": "esicp_step",
              "mesh": mesh_tag, "variant": variant, "status": "skip"}
    t0 = time.time()
    try:
        mem, _, _ = compile_pass(obj_chunk, False)        # pass A: memory
        _, cost, coll = compile_pass(n // n_obj, True)    # pass B: exact cost
        terms = roofline_terms(cost, coll)
        per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        record.update({
            "status": "ok", "n_chips": mesh.devices.size,
            "memory": _mem_dict(mem), "per_device_bytes": per_dev_bytes,
            "fits_hbm_16g": bool(per_dev_bytes < 16e9),
            "cost": {kk: float(v) for kk, v in cost.items()
                     if isinstance(v, (int, float)) and kk in
                     ("flops", "bytes accessed")},
            "collectives": coll, "roofline": terms,
            "compile_s": round(time.time() - t0, 2),
            "shapes": {"n": n, "d": d, "k": k, "p": p,
                       "obj_chunk": obj_chunk},
        })
    except Exception as e:
        record.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--kmeans", action="store_true",
                    help="dry-run the paper's pubmed8m ES-ICP step")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    if args.kmeans:
        for mp in meshes:
            rec = run_kmeans_dryrun(mp, out_dir=args.out_dir, force=args.force)
            print(f"kmeans-pubmed8m {'2x16x16' if mp else '16x16'}: "
                  f"{rec['status']} "
                  + (f"bottleneck={rec['roofline']['bottleneck']}"
                     if rec["status"] == "ok" else rec.get("error", "")))
        return

    from repro.configs import list_archs
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)

    n_ok = n_skip = n_err = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mp, out_dir=args.out_dir,
                               force=args.force, variant=args.variant)
                tag = f"{arch:22s} {shape:12s} {'2x16x16' if mp else '16x16 '}"
                if rec["status"] == "ok":
                    n_ok += 1
                    r = rec["roofline"]
                    print(f"OK   {tag} dom={r['bottleneck']:10s} "
                          f"tc={r['t_compute_s']:.3e} tm={r['t_memory_s']:.3e} "
                          f"tl={r['t_collective_s']:.3e} "
                          f"fit={rec['fits_hbm_16g']} "
                          f"compile={rec['compile_s']}s", flush=True)
                elif rec["status"] == "skip":
                    n_skip += 1
                    print(f"SKIP {tag} ({rec.get('reason','')})", flush=True)
                else:
                    n_err += 1
                    print(f"ERR  {tag} {rec['error'][:140]}", flush=True)
    print(f"\ndone: ok={n_ok} skip={n_skip} err={n_err}")


if __name__ == "__main__":
    main()
