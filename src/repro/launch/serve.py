"""Serving launcher: batched greedy generation on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --batch 4
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    from repro.configs import smoke_config
    from repro.models import init_params
    from repro.serve import ServeLoop

    cfg = smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, params, max_len=args.prompt_len + args.new_tokens)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = loop.generate(prompts, n_new=args.new_tokens)
    dt = time.time() - t0
    print(f"{cfg.name}: generated {args.batch}x{args.new_tokens} tokens "
          f"in {dt:.2f}s ({args.batch*args.new_tokens/dt:.1f} tok/s)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
