"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism across the inter-pod (DCN-ish) links;
weights are replicated per pod, gradients all-reduce over it.

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before anything initialises a
backend).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType as _AxisType
except ImportError:  # older jax: meshes are implicitly Auto on every axis
    _AxisType = None


def _make_mesh(shape, axes):
    if _AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(_AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many (host) devices tests have."""
    return _make_mesh(shape, axes)
