"""Sharding rules: FSDP(+TP) parameter placement, batch and cache specs.

Rules are divisibility-driven so every assigned architecture (including the
awkward ones — granite's 49 155 vocab, 40-head attention over a 16-way model
axis) gets a *valid* sharding; vocab is Megatron-padded in the configs so
embeddings always split over 'model'.

Baseline layout (the hillclimbs in EXPERIMENTS.md §Perf move these knobs):
  weights (…, A, B): B over 'model' if divisible (TP), then a remaining dim
  over 'data' (FSDP/ZeRO-3); the 'pod' axis replicates weights and carries
  gradient all-reduce only.
  activations/tokens: batch over ('pod','data').
  KV caches: batch over data axes, cache length over 'model' (flash-decode).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def obj_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n != "model")


def _axis(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def batch_spec(mesh: Mesh, batch: int) -> P:
    """Shard the batch over as many object axes as divide it (outer first)."""
    axes = []
    rem = batch
    for a in obj_axes(mesh):
        if rem % _axis(mesh, a) == 0:
            axes.append(a)
            rem //= _axis(mesh, a)
    return P(tuple(axes)) if axes else P()


def param_spec(path, shape, mesh: Mesh, embed_mode: str = "gather") -> P:
    name = path[-1].key if path else ""
    model = _axis(mesh, "model")
    data = _axis(mesh, "data")
    nd = len(shape)
    if name in ("embed", "lm_head"):
        if embed_mode == "megatron":
            # shard_map lookup wants P('model', None) exactly
            return P("model" if shape[0] % model == 0 else None, None)
        dims = ["model" if shape[0] % model == 0 else None,
                "data" if shape[1] % data == 0 else None]
        return P(*dims)
    if nd < 2:
        return P()
    dims: list = [None] * nd
    # TP: last dim over model, else second-to-last
    if shape[-1] % model == 0:
        dims[-1] = "model"
    elif shape[-2] % model == 0:
        dims[-2] = "model"
    # FSDP: a remaining trailing dim over data
    for cand in (-2, -1):
        if dims[cand] is None and shape[cand] % data == 0:
            dims[cand] = "data"
            break
    return P(*dims)


def param_shardings(cfg, mesh: Mesh, specs_tree, embed_mode: str = "gather"):
    """specs_tree: pytree of ShapeDtypeStructs -> tree of NamedSharding."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs_tree)
    out = [NamedSharding(mesh, param_spec(path, leaf.shape, mesh, embed_mode))
           for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_shardings(param_sh, mesh: Mesh):
    """AdamW state mirrors parameter placement; count is replicated."""
    return {
        "mu": param_sh,
        "nu": param_sh,
        "count": NamedSharding(mesh, P()),
    }


def cache_spec(path, shape, mesh: Mesh, batch: int) -> P:
    """KV caches (reps, B, L_c, Hkv, hd): B over data axes, L_c over model.
    SSM states (reps, B, H, N, Pd): B over data axes, then the widest
    trailing dim that divides over model."""
    name = path[-1].key
    if name in ("q", "s"):            # int8 cache leaves live under k/v
        name = path[-2].key
    model = _axis(mesh, "model")
    nd = len(shape)
    stacked = nd >= 4  # (reps, B, ...) vs shared-block caches (B, ...)
    b_idx = 1 if stacked else 0
    dims: list = [None] * nd
    bspec = batch_spec(mesh, batch)
    if bspec != P() and shape[b_idx] == batch:
        dims[b_idx] = bspec[0]
    if name in ("k", "v"):
        lc_idx = b_idx + 1
        if shape[lc_idx] % model == 0:
            dims[lc_idx] = "model"
    else:  # ssm states
        for i in range(nd - 1, b_idx, -1):
            if shape[i] % model == 0:
                dims[i] = "model"
                break
    return P(*dims)


def cache_shardings(mesh: Mesh, cache_specs_tree, batch: int):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_specs_tree)
    out = [NamedSharding(mesh, cache_spec(path, leaf.shape, mesh, batch))
           for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
