"""Production training launcher (single- or multi-host).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 30 --checkpoint-dir /tmp/ck

Multi-host posture: call jax.distributed.initialize() when COORDINATOR_ADDR
is set; every host runs the same program, the mesh spans all devices, and
the data pipeline shards by host id.  On this box it degrades to host
devices.  Fault tolerance: auto-resume from the newest checkpoint; the
StepWatchdog flags stragglers (checkpoint-restart is the recovery path).
"""
from __future__ import annotations

import argparse
import os

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 (data x model); default: all devices x1")
    args = ap.parse_args()

    if os.environ.get("COORDINATOR_ADDR"):
        import jax
        jax.distributed.initialize()  # multi-host bootstrap

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, smoke_config
    from repro.models import init_params, param_specs
    from repro.train import make_train_step, TrainConfig, adamw_init
    from repro.launch.mesh import make_test_mesh
    from repro.launch import sharding as shd
    from repro.checkpoint import AsyncCheckpointer, restore_checkpoint, latest_step
    from repro.distributed import StepWatchdog

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    if args.mesh:
        dshape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        dshape = (n_dev, 1)
    mesh = make_test_mesh(dshape, ("data", "model"))
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"arch {cfg.name} ({cfg.n_params():,} params)")

    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    p_sh = shd.param_shardings(cfg, mesh, param_specs(cfg))
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(opt, shd.opt_shardings(p_sh, mesh))

    start = 0
    if args.checkpoint_dir and latest_step(args.checkpoint_dir) is not None:
        (params, opt), start = restore_checkpoint(
            args.checkpoint_dir, (params, opt))
        params = jax.device_put(params, p_sh)
        opt = jax.device_put(opt, shd.opt_shardings(p_sh, mesh))
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(
        cfg, TrainConfig(microbatches=args.microbatches)),
        donate_argnums=(0, 1))

    ck = AsyncCheckpointer(args.checkpoint_dir) if args.checkpoint_dir else None
    wd = StepWatchdog()
    tok_sh = NamedSharding(mesh, P(("data",), None))
    rng = np.random.default_rng(0)
    with mesh:
        for i in range(start, args.steps):
            toks = jax.device_put(
                rng.integers(0, cfg.vocab, (args.batch, args.seq)).astype(np.int32),
                tok_sh)
            labels = jnp.roll(toks, -1, axis=1)
            wd.start()
            params, opt, m = step_fn(params, opt, toks, labels)
            straggle = wd.stop()
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f}"
                      + ("  [straggler-budget breach]" if straggle else ""))
            if ck and (i + 1) % args.checkpoint_every == 0:
                ck.save((params, opt), step=i + 1)
        if ck:
            ck.wait()
    print("done")


if __name__ == "__main__":
    main()
