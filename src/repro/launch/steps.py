"""Step builders for the dry-run and the real launchers.

`build_cell(cfg, mesh, shape)` returns everything `.lower().compile()` needs:
the jitted step, its argument ShapeDtypeStructs, and the sharding/donation
story.  Full-size tensors only ever exist as specs.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import param_specs, cache_specs
from repro.models.config import ModelConfig
from repro.train import make_train_step, TrainConfig, AdamWConfig
from repro.serve import make_prefill_fn, make_decode_fn
from repro.launch.shapes import ShapeSpec, FRONTEND_LEN
from repro.launch import sharding as shd

SERVE_DTYPE = jnp.bfloat16
ACT_BUDGET_BYTES = 4e9   # per-device activation-checkpoint budget (heuristic)


def pick_microbatches(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    """Choose grad-accumulation depth so the per-device scan-carry stack of
    layer-boundary activations stays under ACT_BUDGET_BYTES."""
    n_obj = math.prod(mesh.shape[a] for a in shd.obj_axes(mesh))
    b_dev = max(shape.batch // n_obj, 1)
    bytes_per_b = cfg.n_layers * shape.seq * cfg.d_model * 2  # bf16 boundaries
    b_mb = max(1, int(ACT_BUDGET_BYTES // max(bytes_per_b, 1)))
    mb = max(1, -(-b_dev // b_mb))
    while b_dev % mb and mb < b_dev:   # must divide the per-device batch
        mb += 1
    return min(mb, b_dev)


@dataclasses.dataclass(frozen=True)
class PerfVariant:
    """§Perf hillclimb knobs (baseline keeps all defaults)."""
    name: str = "baseline"
    embed_mode: str = "gather"       # | "megatron" (shard_map vocab-parallel)
    kv_dtype: str = "bf16"           # | "int8" (quantized KV cache)
    attn_stack_bf16: bool = False    # q-chunk ys in bf16
    attn_kv_shard: bool = False      # K/V sequence-sharded over 'model'
    cache_carry: bool = False        # decode caches in scan carry (in-place)
    moe_group: int | None = None     # MoE routing-group override
    microbatches: int | None = None  # override the heuristic


VARIANTS = {
    "baseline": PerfVariant(),
    "megatron-embed": PerfVariant(name="megatron-embed",
                                  embed_mode="megatron"),
    "kv-int8": PerfVariant(name="kv-int8", kv_dtype="int8"),
    "attn-bf16-stack": PerfVariant(name="attn-bf16-stack",
                                   attn_stack_bf16=True),
    "kv-seq-shard": PerfVariant(name="kv-seq-shard", attn_kv_shard=True),
    "cache-carry": PerfVariant(name="cache-carry", cache_carry=True),
    "cache-carry-int8": PerfVariant(name="cache-carry-int8",
                                    cache_carry=True, kv_dtype="int8"),
    "combo-train": PerfVariant(name="combo-train", embed_mode="megatron",
                               attn_kv_shard=True, attn_stack_bf16=True),
    "moe-group128": PerfVariant(name="moe-group128", moe_group=128),
    "moe-group128-kvshard": PerfVariant(name="moe-group128-kvshard",
                                        moe_group=128, attn_kv_shard=True),
}


def apply_variant(variant: PerfVariant, cfg: ModelConfig, mesh):
    """Set trace-time globals + return the (possibly) modified config."""
    from repro.models import transformer as T
    from repro.models import layers as L
    T.set_embed_mode(variant.embed_mode,
                     mesh if variant.embed_mode == "megatron" else None)
    T.set_cache_carry(variant.cache_carry)
    L.set_attn_stack_bf16(variant.attn_stack_bf16)
    L.set_attn_kv_shard(mesh if variant.attn_kv_shard else None)
    if variant.kv_dtype != cfg.kv_dtype:
        cfg = dataclasses.replace(cfg, kv_dtype=variant.kv_dtype)
    if variant.moe_group is not None:
        cfg = dataclasses.replace(cfg, moe_group=variant.moe_group)
    return cfg


@dataclasses.dataclass
class Cell:
    fn: object            # jitted step
    args: tuple           # ShapeDtypeStructs (lower(*args))
    meta: dict


def _extend(spec: P, ndim: int) -> P:
    parts = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
    return P(*parts)


def _token_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    tok = jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32)
    tok_sh = NamedSharding(mesh, _extend(shd.batch_spec(mesh, shape.batch), 2))
    return tok, tok_sh


def _frontend(cfg: ModelConfig, shape: ShapeSpec, mesh):
    n_fe = FRONTEND_LEN.get(cfg.name)
    if n_fe is None or shape.kind == "decode":
        return None, None
    spec = jax.ShapeDtypeStruct((shape.batch, n_fe, cfg.d_model), SERVE_DTYPE)
    sh = NamedSharding(mesh, _extend(shd.batch_spec(mesh, shape.batch), 3))
    return spec, sh


def reduced_depth_config(cfg: ModelConfig, m: int) -> ModelConfig:
    """Same architecture, every segment at reps=m (cost-extrapolation pass)."""
    segs = tuple(dataclasses.replace(s, reps=m) for s in cfg.segments)
    return dataclasses.replace(cfg, segments=segs)


def build_cell(cfg: ModelConfig, mesh, shape: ShapeSpec, *,
               microbatches: int | None = None,
               variant: PerfVariant = VARIANTS["baseline"]) -> Cell:
    cfg = apply_variant(variant, cfg, mesh)
    if variant.microbatches is not None and microbatches is None:
        microbatches = variant.microbatches
    if shape.kind == "train":
        return _build_train(cfg, mesh, shape, microbatches, variant)
    if shape.kind == "prefill":
        return _build_prefill(cfg, mesh, shape, variant)
    if shape.kind == "decode":
        return _build_decode(cfg, mesh, shape, variant)
    raise ValueError(shape.kind)


def _build_train(cfg, mesh, shape, microbatches, variant):
    mb = microbatches or pick_microbatches(cfg, shape, mesh)
    tcfg = TrainConfig(microbatches=mb, optimizer=AdamWConfig())
    step = make_train_step(cfg, tcfg)

    p_specs = param_specs(cfg, jnp.float32)
    p_sh = shd.param_shardings(cfg, mesh, p_specs,
                               embed_mode=variant.embed_mode)
    opt_specs = {
        "mu": p_specs, "nu": p_specs,
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
    opt_sh = shd.opt_shardings(p_sh, mesh)
    tok, tok_sh = _token_specs(cfg, shape, mesh)
    fe, fe_sh = _frontend(cfg, shape, mesh)

    args = (p_specs, opt_specs, tok, tok) + ((fe,) if fe is not None else ())
    in_sh = (p_sh, opt_sh, tok_sh, tok_sh) + ((fe_sh,) if fe is not None else ())
    out_sh = (p_sh, opt_sh, None)

    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1))
    return Cell(fn=fn, args=args, meta={"microbatches": mb, "kind": "train"})


def _build_prefill(cfg, mesh, shape, variant):
    prefill = make_prefill_fn(cfg)
    p_specs = param_specs(cfg, SERVE_DTYPE)
    p_sh = shd.param_shardings(cfg, mesh, p_specs,
                               embed_mode=variant.embed_mode)
    tok, tok_sh = _token_specs(cfg, shape, mesh)
    fe, fe_sh = _frontend(cfg, shape, mesh)
    args = (p_specs, tok) + ((fe,) if fe is not None else ())
    in_sh = (p_sh, tok_sh) + ((fe_sh,) if fe is not None else ())
    fn = jax.jit(prefill, in_shardings=in_sh)
    return Cell(fn=fn, args=args, meta={"kind": "prefill"})


def _build_decode(cfg, mesh, shape, variant):
    decode = make_decode_fn(cfg)
    p_specs = param_specs(cfg, SERVE_DTYPE)
    p_sh = shd.param_shardings(cfg, mesh, p_specs,
                               embed_mode=variant.embed_mode)
    c_specs = cache_specs(cfg, shape.batch, shape.seq)
    c_sh = shd.cache_shardings(mesh, c_specs, shape.batch)
    tok = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, _extend(shd.batch_spec(mesh, shape.batch), 2))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = shd.replicated(mesh)

    args = (p_specs, c_specs, tok, pos)
    in_sh = (p_sh, c_sh, tok_sh, pos_sh)
    out_sh = (None, c_sh)
    fn = jax.jit(decode, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(1,))
    return Cell(fn=fn, args=args, meta={"kind": "decode"})
