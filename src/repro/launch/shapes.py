"""Assigned input-shape grid + per-cell eligibility.

Shapes (identical for all ten LM archs):
    train_4k     seq 4 096   global batch 256   -> train_step
    prefill_32k  seq 32 768  global batch 32    -> prefill_step
    decode_32k   seq 32 768  global batch 128   -> serve (decode) step
    long_500k    seq 524 288 global batch 1     -> serve (decode) step

long_500k needs a sub-quadratic stack: it runs for SSM/hybrid/linear
(xlstm, zamba2), sliding-window (mixtral), and gemma3 (5:1 local pattern;
global layers fall back to a 32k window — DESIGN.md §5).  Pure
full-attention archs skip it; the skip is recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# archs whose stack is sub-quadratic (or windowed) end-to-end at 500k
LONG_OK = {"xlstm-125m", "zamba2-2.7b", "gemma3-1b", "mixtral-8x22b"}

FRONTEND_LEN = {"musicgen-large": 256, "chameleon-34b": 1024}


def cell_enabled(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch in LONG_OK
    return True


def all_cells() -> list[tuple[str, str]]:
    from repro.configs import list_archs
    return [(a, s) for a in list_archs() for s in SHAPES]
