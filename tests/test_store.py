"""Out-of-core data plane: DocStore, streaming builder, prefetcher, and the
chunk-scan / minibatch streaming fits (DESIGN.md §10).

Acceptance criteria under test:

  * a one-chunk DocStore fit is bitwise-identical to the resident
    ``fit(docs)`` (labels AND every deterministic history diagnostic);
  * a ≥ 4-chunk store completes in both full-batch (chunk-scan) and
    minibatch modes; full-batch matches the resident clustering;
  * minibatch monotonically improves the valid-masked objective;
  * a fit is resumable from a MID-EPOCH checkpoint with identical final
    labels and history;
  * the seeded ``SparseDocs.df`` survives a jit round-trip (it is an
    explicit pytree leaf now, not a silently-dropped property cache);
  * classify/predict over a store equals the resident path on every
    runtime surface (FittedModel, ClusterEngine, mesh).
"""
import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.cluster import ClusterConfig, ClusterEngine, SphericalKMeans
from repro.core.lloyd import streaming_fit
from repro.data import make_corpus, CorpusSpec
from repro.sparse import (ChunkPrefetcher, DocStore, DocStoreBuilder,
                          SparseDocs, df_counts, from_dense,
                          l2_normalize_rows, remap_terms_by_df, tf_idf,
                          with_df)


@pytest.fixture(scope="module")
def tiny_corpus():
    return make_corpus(CorpusSpec(n_docs=400, vocab=512, nt_mean=20,
                                  n_topics=8, seed=0))


@pytest.fixture(scope="module")
def resident_fit(tiny_corpus):
    docs, df, perm, topics = tiny_corpus
    km = SphericalKMeans(k=8, algo="esicp", max_iter=20, batch_size=100,
                         seed=1).fit(docs, df=df)
    assert km.converged_
    return km


# ---------------------------------------------------------------------------
# SparseDocs.df as an explicit leaf.
# ---------------------------------------------------------------------------

def test_df_survives_jit_roundtrip(tiny_corpus):
    """Regression: the seeded df used to live in a cached_property's
    instance __dict__, which every tree_unflatten (jit boundaries,
    donation) silently dropped.  As an explicit optional leaf it must come
    back from a jit round-trip carried, not recounted."""
    docs, df, perm, topics = tiny_corpus
    seeded = with_df(docs, df)
    assert seeded._df is not None

    out = jax.jit(lambda d: d)(seeded)
    assert out._df is not None                       # survived unflatten
    np.testing.assert_array_equal(np.asarray(out.df), np.asarray(df))

    # the leaf also survives being a scan carry / closure constant
    out2 = jax.jit(lambda d: d.slice_rows(0, 8) and d)(seeded)
    assert out2._df is not None

    # None stays None (no phantom leaf), and .df still counts on demand
    bare = SparseDocs(ids=docs.ids, vals=docs.vals, nnz=docs.nnz,
                      dim=docs.dim)
    bare_out = jax.jit(lambda d: d)(bare)
    assert bare_out._df is None
    np.testing.assert_array_equal(np.asarray(bare_out.df),
                                  np.asarray(df_counts(docs)))


def test_remap_carries_permuted_df():
    docs = from_dense(np.eye(6, dtype=np.float32) * 2.0)
    df = df_counts(docs)
    remapped, perm = remap_terms_by_df(docs, df=df)
    assert remapped._df is not None
    np.testing.assert_array_equal(np.asarray(remapped.df),
                                  np.asarray(df)[np.asarray(perm)])


# ---------------------------------------------------------------------------
# DocStore + builder.
# ---------------------------------------------------------------------------

def test_builder_matches_resident_preprocessing(tmp_path):
    """Streaming ingest (spill + finalize) reproduces the jnp pipeline:
    tf-idf → df-rank remap → L2, with the final chunk tail-padded dead."""
    rng = np.random.default_rng(3)
    n, d, p = 230, 64, 12
    dense = np.zeros((n, d), np.float32)
    for i in range(n):
        cols = rng.choice(d, size=int(rng.integers(3, p)), replace=False)
        dense[i, cols] = rng.integers(1, 5, size=len(cols)).astype(np.float32)

    raw = from_dense(dense, pad_to=p)
    df = df_counts(raw)
    ref = l2_normalize_rows(tf_idf(raw, df=df))
    ref, perm = remap_terms_by_df(ref, df=df)

    builder = DocStoreBuilder(str(tmp_path / "store"), dim=d, chunk_size=64,
                              pad_width=p)
    for s in range(0, n, 37):                       # uneven append batches
        e = min(s + 37, n)
        builder.append(np.asarray(raw.ids[s:e]), np.asarray(raw.vals[s:e]),
                       np.asarray(raw.nnz[s:e]))
    store = builder.finalize()

    assert store.n_docs == n and store.n_chunks == 4
    assert store.n_rows == 4 * 64                   # uniform chunk shapes
    out = store.to_docs()
    np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(ref.ids))
    np.testing.assert_allclose(np.asarray(out.vals), np.asarray(ref.vals),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(out.nnz), np.asarray(ref.nnz))
    np.testing.assert_array_equal(np.asarray(store.df),
                                  np.asarray(df)[np.asarray(perm)])
    # tail padding: dead rows, ρ_self = 0 convention (no live tuples)
    _, _, last_nnz = store.host_chunk(store.n_chunks - 1)
    assert (np.asarray(last_nnz)[n - 3 * 64:] == 0).all()
    # raw spill files were cleaned up
    assert not [f for f in os.listdir(store.directory)
                if f.startswith("raw_")]

    # save/open round-trip of the in-memory wrapper too
    wrapped = DocStore.from_docs(out, chunk_size=100)
    reopened = DocStore.open(wrapped.save(str(tmp_path / "resaved")).directory)
    np.testing.assert_array_equal(np.asarray(reopened.host_chunk(0)[0]),
                                  np.asarray(wrapped.host_chunk(0)[0]))


def test_prefetcher_orders_and_propagates_errors(tiny_corpus):
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs, chunk_size=100)
    assert [ci for ci, _ in ChunkPrefetcher(store)] == [0, 1, 2, 3]
    assert [ci for ci, _ in ChunkPrefetcher(store, order=[2, 0])] == [2, 0]
    with pytest.raises(IndexError):
        list(ChunkPrefetcher(store, order=[0, 99]))


def test_prefetcher_abandoned_consumer_unblocks_producer(tiny_corpus):
    """Breaking out of the chunk loop (a failed per-chunk step) must not
    leave the producer thread parked on the full queue forever."""
    import threading
    import time

    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs, chunk_size=50)     # 8 chunks, depth 2
    before = threading.active_count()
    for ci, cdocs in ChunkPrefetcher(store):
        break                                           # consumer bails
    deadline = time.time() + 5.0
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.01)
    assert threading.active_count() <= before


def test_gather_rows_matches_fancy_indexing(tiny_corpus):
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs, chunk_size=128)
    pick = np.asarray([399, 0, 130, 130, 77])
    sel = store.gather_rows(pick)
    np.testing.assert_array_equal(np.asarray(sel.ids),
                                  np.asarray(docs.ids)[pick])
    np.testing.assert_array_equal(np.asarray(sel.vals),
                                  np.asarray(docs.vals)[pick])


# ---------------------------------------------------------------------------
# Chunked-vs-resident parity.
# ---------------------------------------------------------------------------

def _assert_history_parity(h_ref, h_new, *, exact_floats=True):
    assert len(h_ref) == len(h_new)
    for hr, hn in zip(h_ref, h_new):
        for key in ("iteration", "n_changed", "n_moving", "t_th"):
            assert hr[key] == hn[key], key
        for key in ("mult", "cpr", "objective", "v_th"):
            if exact_floats:
                assert hr[key] == hn[key], key
            elif key in ("mult", "cpr"):
                # Pruning diagnostics: chunked λ accumulation shifts the
                # means by last-bit rounding, which can flip a marginal
                # ES-filter survivor — assignments stay identical (asserted
                # above), the visited-pair counts may jitter slightly.
                np.testing.assert_allclose(hr[key], hn[key], rtol=1e-2,
                                           err_msg=key)
            else:
                np.testing.assert_allclose(hr[key], hn[key], rtol=1e-6,
                                           err_msg=key)


def test_one_chunk_store_is_bitwise_identical(tiny_corpus, resident_fit):
    """fit(one-chunk store) == fit(docs): labels bitwise, every
    deterministic history field bitwise (elapsed_s is wall time)."""
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs)                # ONE chunk
    assert store.n_chunks == 1
    km = SphericalKMeans(k=8, algo="esicp", max_iter=20, batch_size=100,
                         seed=1).fit(store, df=df)
    assert km.model_.strategy == "streaming"
    assert km.n_iter_ == resident_fit.n_iter_
    assert (km.labels_ == resident_fit.labels_).all()
    np.testing.assert_array_equal(np.asarray(km.model_.rho_self),
                                  np.asarray(resident_fit.model_.rho_self))
    _assert_history_parity(resident_fit.history_, km.history_)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_multichunk_full_batch_matches_resident(tiny_corpus, backend):
    """≥ 4 chunks, full-batch chunk-scan mode: the out-of-core epoch is
    mathematically the resident epoch (same assignments, same means), so
    the clustering must agree across chunkings and backends."""
    docs, df, perm, topics = tiny_corpus
    ref = SphericalKMeans(k=8, algo="esicp", max_iter=20, batch_size=100,
                          seed=1, backend=backend).fit(docs, df=df)
    store = DocStore.from_docs(docs, chunk_size=100)
    assert store.n_chunks >= 4
    km = SphericalKMeans(k=8, algo="esicp", max_iter=20, batch_size=100,
                         seed=1, backend=backend).fit(store, df=df)
    assert km.converged_
    assert len(km.labels_) == docs.n_docs
    assert (km.labels_ == ref.labels_).all()
    _assert_history_parity(ref.history_, km.history_, exact_floats=False)


def test_multichunk_tail_padding_is_inert(tiny_corpus):
    """n % chunk_size != 0: the dead tail rows of the final chunk change
    nothing (the store-side mirror of the resident tail-batch test)."""
    docs, df, perm, topics = tiny_corpus           # n = 400
    even = DocStore.from_docs(docs, chunk_size=100)     # 400 % 100 == 0
    ragged = DocStore.from_docs(docs, chunk_size=150)   # 400 % 150 == 100
    a = SphericalKMeans(k=8, max_iter=20, batch_size=50,
                        seed=1).fit(even, df=df)
    b = SphericalKMeans(k=8, max_iter=20, batch_size=50,
                        seed=1).fit(ragged, df=df)
    assert (a.labels_ == b.labels_).all()
    for h in b.history_:
        assert np.isfinite(h["objective"])
    np.testing.assert_allclose(a.objective_, b.objective_, rtol=1e-5)


def test_minibatch_monotone_objective(tiny_corpus):
    """Sculley-style minibatch: the valid-masked objective J must improve
    monotonically across passes on the well-separated synthetic corpus."""
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs, chunk_size=100)
    km = SphericalKMeans(k=8, algo_mode="minibatch", max_iter=15,
                         batch_size=100, chunk_size=100,
                         seed=1).fit(store, df=df)
    obj = [h["objective"] for h in km.history_]
    assert len(obj) >= 2
    for prev, nxt in zip(obj, obj[1:]):
        assert nxt >= prev - 1e-4 * abs(prev)      # monotone (float tol)
    assert obj[-1] > obj[0]
    # minibatch is exact-assignment: history mult is 0, cpr saturated
    assert all(h["mult"] == 0 for h in km.history_)
    # resident docs route through the same strategy via config.algo_mode
    km2 = SphericalKMeans(k=8, algo_mode="minibatch", max_iter=15,
                          batch_size=100, chunk_size=100,
                          seed=1).fit(docs, df=df)
    assert km2.model_.strategy == "streaming"
    assert (km2.labels_ == km.labels_).all()


# ---------------------------------------------------------------------------
# Mid-epoch checkpoint / resume.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo_mode", ["full", "minibatch"])
def test_resume_from_mid_epoch_checkpoint(tiny_corpus, tmp_path, algo_mode):
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs, chunk_size=100)
    ckpt = str(tmp_path / "ckpt")
    full = streaming_fit(store, k=8, algo_mode=algo_mode, max_iter=20,
                         batch_size=100, seed=1, df=df,
                         checkpoint_dir=ckpt, checkpoint_every=3)
    assert full.converged and full.cursor is None

    from repro.checkpoint.store import all_steps
    steps = all_steps(ckpt)
    mid = [s for s in steps if s % (store.n_chunks + 1) != 0]
    assert mid, "expected a surviving mid-epoch checkpoint"
    target = mid[-1]
    for s in steps:                    # rewind the run to the mid-epoch cut
        if s > target:
            shutil.rmtree(os.path.join(ckpt, f"step_{s:08d}"))

    resumed = streaming_fit(store, k=8, algo_mode=algo_mode, max_iter=20,
                            batch_size=100, seed=1, df=df,
                            checkpoint_dir=ckpt, resume=True)
    assert (resumed.assign == full.assign).all()
    assert resumed.n_iter == full.n_iter
    _assert_history_parity(full.history, resumed.history)


def test_resume_requires_checkpoint_dir(tiny_corpus):
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        streaming_fit(store, k=4, resume=True)


def test_resume_rejects_algo_mode_mismatch(tiny_corpus, tmp_path):
    """A minibatch checkpoint resumed in full mode (shapes alias!) must
    fail loudly, not finish with silently wrong labels."""
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs, chunk_size=100)
    ckpt = str(tmp_path / "ckpt")
    streaming_fit(store, k=8, algo_mode="minibatch", max_iter=2,
                  batch_size=100, seed=1, df=df, checkpoint_dir=ckpt,
                  checkpoint_every=2)
    with pytest.raises(ValueError, match="algo_mode"):
        streaming_fit(store, k=8, algo_mode="full", max_iter=2,
                      batch_size=100, seed=1, df=df, checkpoint_dir=ckpt,
                      resume=True)


def test_prime_chunk_size_pads_instead_of_degrading(tiny_corpus):
    """chunk_size sharing no divisor with batch_size (e.g. a prime): the
    chunk steps pad to the tile multiple with dead rows — same clustering,
    no silent per-row-scan degradation."""
    docs, df, perm, topics = tiny_corpus                # n = 400
    ref = SphericalKMeans(k=8, max_iter=20, batch_size=100,
                          seed=1).fit(docs, df=df)
    store = DocStore.from_docs(docs, chunk_size=149)    # prime, 3 chunks
    km = SphericalKMeans(k=8, max_iter=20, batch_size=100,
                         seed=1).fit(store, df=df)
    assert (km.labels_ == ref.labels_).all()
    model = ref.model_
    assert (model.predict(store) == model.predict(docs)).all()


def test_uncoverged_streaming_fit_reports_cursor(tiny_corpus):
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs, chunk_size=100)
    km = SphericalKMeans(k=8, max_iter=2, batch_size=100,
                         seed=1).fit(store, df=df)
    assert not km.converged_
    assert km.model_.cursor == (3, 0)              # resume at epoch 3


# ---------------------------------------------------------------------------
# Serving / artifact over a store.
# ---------------------------------------------------------------------------

def test_classify_and_predict_over_store(tiny_corpus, resident_fit,
                                         tmp_path):
    docs, df, perm, topics = tiny_corpus
    model = resident_fit.model_
    store = DocStore.from_docs(docs, chunk_size=150)

    a_res = model.predict(docs)
    a_store = model.predict(store)
    assert (a_store == a_res).all()
    np.testing.assert_allclose(model.transform(store), model.transform(docs),
                               rtol=1e-5, atol=1e-6)

    engine = ClusterEngine.from_model(model)
    ea, es = engine.classify(store)
    ra, rs = engine.classify(docs)
    assert (ea == ra).all()
    np.testing.assert_allclose(es, rs, rtol=1e-5, atol=1e-6)

    # the artifact round-trips its cursor field
    path = str(tmp_path / "model")
    model.save(path)
    from repro.cluster import FittedModel
    assert FittedModel.load(path).cursor is None


def test_mesh_fit_over_store_matches_mesh_fit_over_docs():
    from repro.launch.mesh import make_test_mesh

    docs, df, perm, topics = make_corpus(
        CorpusSpec(n_docs=300, vocab=256, nt_mean=20, n_topics=6, seed=13))
    mesh = make_test_mesh((2, 2), ("data", "model"))
    ref = SphericalKMeans(k=8, algo="esicp", max_iter=15, chunk_size=64,
                          mesh=mesh, seed=1).fit(docs, df=df)
    store = DocStore.from_docs(docs, chunk_size=80)
    km = SphericalKMeans(k=8, algo="esicp", max_iter=15, chunk_size=64,
                         mesh=mesh, seed=1).fit(store)
    assert km.model_.strategy == "mesh"
    assert (km.labels_ == ref.labels_).all()
    np.testing.assert_allclose(km.model_.rho_self, ref.model_.rho_self,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# SubsetStore / partition_store (two-level IVF data plane, DESIGN.md §13).
# ---------------------------------------------------------------------------

from repro.sparse.store import SubsetStore, partition_store  # noqa: E402

# The subset/partition invariants are property tests: hypothesis explores
# the (chunking × row-set) space when installed; otherwise a seeded
# deterministic sweep over the same space keeps the invariants enforced
# (the container may not ship hypothesis, and silently skipping the whole
# data-plane contract would be worse than a fixed sample).
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


def _subset_cases(n_cases=25):
    rng = np.random.default_rng(0)
    for _ in range(n_cases):
        yield (int(rng.choice([64, 100, 128, 149, 400])),
               None if rng.random() < 0.3 else int(rng.integers(1, 91)),
               rng.integers(0, 400, size=int(rng.integers(1, 61))).tolist())


def _check_subset_gather_parity(tiny_corpus, parent_chunk, sub_chunk, rows):
    """A SubsetStore view over ANY (duplicated, unordered, non-chunk-
    aligned) row set reproduces fancy indexing into the resident corpus,
    chunk by uniform chunk, with the dead-row tail fully inert."""
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs, chunk_size=parent_chunk)
    rows = np.asarray(rows)
    sub = store.subset(rows, chunk_size=sub_chunk)
    assert sub.n_docs == len(rows)
    assert sub.n_chunks == -(-sub.n_docs // sub.chunk_size)

    ids_ref = np.asarray(docs.ids)[rows]
    vals_ref = np.asarray(docs.vals)[rows]
    nnz_ref = np.asarray(docs.nnz)[rows]
    out = sub.to_docs()
    np.testing.assert_array_equal(np.asarray(out.ids), ids_ref)
    np.testing.assert_array_equal(np.asarray(out.vals), vals_ref)
    np.testing.assert_array_equal(np.asarray(out.nnz), nnz_ref)

    # uniform chunk shapes; the final chunk's tail rows are DEAD (nnz = 0
    # with zeroed tuples — the ρ_self = 0 inert-row convention)
    c = sub.chunk_size
    ids_l, vals_l, nnz_l = sub.host_chunk(sub.n_chunks - 1)
    assert ids_l.shape == (c, store.pad_width)
    tail = sub.n_docs - (sub.n_chunks - 1) * c
    assert (nnz_l[tail:] == 0).all()
    assert (ids_l[tail:] == 0).all() and (vals_l[tail:] == 0).all()


def _check_partition_covers_once(tiny_corpus, n_cells, seed):
    """partition_store: every corpus row lands in exactly one cell view,
    views keep corpus order, empty cells come back as None."""
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs, chunk_size=149)      # non-aligned
    labels = np.random.default_rng(seed).integers(0, n_cells,
                                                  size=store.n_docs)
    views = partition_store(store, labels, n_cells)
    assert len(views) == n_cells
    seen = []
    for c, v in enumerate(views):
        if (labels == c).sum() == 0:
            assert v is None
            continue
        assert isinstance(v, SubsetStore)
        assert (labels[v.rows] == c).all()
        assert (np.diff(v.rows) > 0).all()                # corpus order
        seen.append(v.rows)
    np.testing.assert_array_equal(np.sort(np.concatenate(seen)),
                                  np.arange(store.n_docs))


if HAS_HYPOTHESIS:
    @settings(deadline=None, max_examples=25)
    @given(parent_chunk=st.sampled_from([64, 100, 128, 149, 400]),
           sub_chunk=st.one_of(st.none(), st.integers(1, 90)),
           rows=st.lists(st.integers(0, 399), min_size=1, max_size=60))
    def test_subset_store_gather_parity(tiny_corpus, parent_chunk, sub_chunk,
                                        rows):
        _check_subset_gather_parity(tiny_corpus, parent_chunk, sub_chunk,
                                    rows)

    @settings(deadline=None, max_examples=25)
    @given(n_cells=st.integers(1, 9), seed=st.integers(0, 2**16))
    def test_partition_store_covers_rows_exactly_once(tiny_corpus, n_cells,
                                                      seed):
        _check_partition_covers_once(tiny_corpus, n_cells, seed)
else:
    @pytest.mark.parametrize("case", list(_subset_cases()))
    def test_subset_store_gather_parity(tiny_corpus, case):
        _check_subset_gather_parity(tiny_corpus, *case)

    @pytest.mark.parametrize("n_cells,seed",
                             [(c, s) for c in (1, 2, 5, 9)
                              for s in (0, 7, 4242)])
    def test_partition_store_covers_rows_exactly_once(tiny_corpus, n_cells,
                                                      seed):
        _check_partition_covers_once(tiny_corpus, n_cells, seed)


def test_subset_store_validation_and_df(tiny_corpus):
    docs, df, perm, topics = tiny_corpus
    store = DocStore.from_docs(docs, chunk_size=128)
    with pytest.raises(IndexError, match="out of range"):
        store.subset([0, 400])
    with pytest.raises(ValueError, match="at least one row"):
        store.subset([])
    sub = store.subset([3, 1, 250])
    with pytest.raises(NotImplementedError, match="transient"):
        sub.save("/tmp/nope")
    # df is NOT inherited from the parent: it counts the subset lazily
    # (two-level fits pass the global df explicitly instead)
    np.testing.assert_array_equal(
        np.asarray(sub.df), np.asarray(df_counts(sub.to_docs())))
    # the prefetcher runs over a view like over any store
    assert [ci for ci, _ in ChunkPrefetcher(sub)] == [0]


# ---------------------------------------------------------------------------
# Config / strategy routing.
# ---------------------------------------------------------------------------

def test_config_validates_algo_mode():
    with pytest.raises(ValueError, match="algo_mode"):
        ClusterConfig(k=4, algo_mode="bogus").validate()
    assert ClusterConfig(k=4, algo_mode="minibatch").strategy == "streaming"
    assert ClusterConfig(k=4).strategy == "single_host"

    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="streaming"):
        ClusterConfig(k=4, algo_mode="minibatch", mesh=mesh).validate()


def test_docstore_input_promotes_to_streaming(tiny_corpus):
    from repro.cluster import resolve_strategy

    docs, df, perm, topics = tiny_corpus
    cfg = ClusterConfig(k=8)
    assert resolve_strategy(cfg).name == "single_host"
    assert resolve_strategy(cfg, docs).name == "single_host"
    assert resolve_strategy(cfg, DocStore.from_docs(docs)).name == "streaming"
