"""Test process setup.

8 host devices for the distributed tests (NOT 512 — that is dry-run-only,
set inside launch/dryrun.py).  Must run before anything imports jax.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np      # noqa: E402
import pytest           # noqa: E402


@pytest.fixture(scope="session")
def small_corpus():
    from repro.data import make_corpus, CorpusSpec
    return make_corpus(CorpusSpec(n_docs=1500, vocab=1024, nt_mean=35,
                                  n_topics=16, seed=7))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
