"""EstParams: the estimator's J approximates measured Mult and the
structural parameters land where the paper says (t_th near D, small v_th)."""
import numpy as np
import jax.numpy as jnp

from repro.core import SphericalKMeans, StructuralParams
from repro.core.assignment import assignment_step
from repro.core.estparams import estimate_params, EstGrid


def test_estimator_tracks_actual(small_corpus):
    docs, df, perm, topics = small_corpus
    warm = SphericalKMeans(k=24, algo="mivi", max_iter=3, batch_size=750,
                           seed=0).fit(docs, df=df)
    state = warm.state_
    grid = EstGrid(n_v=6, n_s=12)
    est, aux = estimate_params(docs, df, state.index.means_t, state.rho_self,
                               k=24, grid=grid)
    j_tab = np.asarray(aux["J"])
    s_grid = np.asarray(aux["s_grid"])
    v_grid = np.asarray(aux["v_grid"])

    approx, actual = [], []
    for hi in range(len(v_grid)):
        si = int(np.argmin(j_tab[:, hi]))
        params = StructuralParams(
            t_th=jnp.asarray(int(s_grid[si]), jnp.int32),
            v_th=jnp.asarray(float(v_grid[hi]), jnp.float32))
        idx = state.index.with_params(params)
        r = assignment_step("es", docs, idx, state.assign, state.rho_self,
                            jnp.zeros((docs.n_docs,), bool))
        approx.append(j_tab[si, hi])
        actual.append(float(r.mult))
    corr = np.corrcoef(approx, actual)[0, 1]
    assert corr > 0.6, (corr, approx, actual)


def test_structural_params_regime(small_corpus):
    docs, df, perm, topics = small_corpus
    warm = SphericalKMeans(k=24, algo="mivi", max_iter=3, batch_size=750,
                           seed=0).fit(docs, df=df)
    est, aux = estimate_params(docs, df, warm.state_.index.means_t,
                               warm.state_.rho_self, k=24)
    assert int(est.t_th) >= int(0.8 * docs.dim)     # grid floor = int(0.80·D)
    vals = warm.state_.index.means_t[warm.state_.index.means_t > 0]
    assert float(est.v_th) <= float(jnp.max(vals))
    assert float(est.v_th) > 0


def test_j_table_components_nonnegative(small_corpus):
    docs, df, perm, topics = small_corpus
    warm = SphericalKMeans(k=24, algo="mivi", max_iter=2, batch_size=750,
                           seed=0).fit(docs, df=df)
    _, aux = estimate_params(docs, df, warm.state_.index.means_t,
                             warm.state_.rho_self, k=24,
                             grid=EstGrid(n_v=5, n_s=8))
    assert (np.asarray(aux["phi1"]) >= 0).all()
    assert (np.asarray(aux["phi2"]) >= 0).all()
    assert (np.asarray(aux["phi3"]) >= 0).all()
    # φ1 grows with s' (more Region-1 terms), φ2 shrinks
    assert (np.diff(np.asarray(aux["phi1"])) >= 0).all()
    assert (np.diff(np.asarray(aux["phi2"]), axis=0) <= 1e-6).all()
