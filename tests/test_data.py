"""Data pipeline: UC-faithfulness of the synthetic corpus, deterministic
resumable batches, UCI loader round-trip."""
import io
import os

import numpy as np
import jax.numpy as jnp

from repro.core import metrics
from repro.data import ShardedBatches, load_uci_bow
from repro.sparse import to_dense


def test_corpus_matches_ucs(small_corpus):
    docs, df, perm, topics = small_corpus
    # Zipf body on df (paper Fig. 2a): positive exponent in a sane band
    alpha = metrics.zipf_fit(np.asarray(df))
    assert 0.4 < alpha < 2.5, alpha
    # unit sphere
    norms = np.asarray(jnp.sum(docs.vals**2, axis=1))
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)
    # sparsity regime
    nt_hat = float(jnp.mean(docs.nnz))
    assert nt_hat / docs.dim < 0.1


def test_batches_deterministic_and_resumable(small_corpus):
    docs, df, perm, topics = small_corpus
    it = ShardedBatches(docs, batch=256, seed=11)
    a = [np.asarray(b.ids[0]) for b in it.epoch(epoch=2)]
    b = [np.asarray(b.ids[0]) for b in it.epoch(epoch=2)]
    assert all((x == y).all() for x, y in zip(a, b))
    # resume mid-epoch at batch 3
    c = [np.asarray(b.ids[0]) for b in it.epoch(epoch=2, start_batch=3)]
    assert all((x == y).all() for x, y in zip(a[3:], c))


def test_uci_loader(tmp_path):
    txt = "3\n4\n5\n1 1 2\n1 3 1\n2 2 4\n3 1 1\n3 4 2\n"
    p = os.path.join(str(tmp_path), "docword.test.txt")
    with open(p, "w") as f:
        f.write(txt)
    docs, df, perm = load_uci_bow(p)
    assert docs.n_docs == 3 and docs.dim == 4
    dense = np.asarray(to_dense(docs))
    norms = (dense ** 2).sum(1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)
    assert (np.asarray(df) >= 0).all()


def test_df_cached_on_docs(small_corpus):
    """df is computed once per corpus instance and shared by consumers;
    corpus builders pre-seed the cache with the counts they already hold."""
    from repro.sparse import df_counts

    docs, df, perm, topics = small_corpus
    assert docs.df is df                      # builder-seeded cache
    assert docs.df is docs.df                 # cached_property: same object
    np.testing.assert_array_equal(np.asarray(docs.df),
                                  np.asarray(df_counts(docs)))
