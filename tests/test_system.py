"""End-to-end behaviour: the paper's acceleration contract.

Every accelerated algorithm must return the *identical* clustering to the
MIVI baseline from the same initial state (the paper's definition of
"acceleration", §I), while reducing the Mult/CPR diagnostics.
"""
import numpy as np
import pytest

from repro.core import SphericalKMeans

ALGOS = ["icp", "es", "esicp", "ta-icp", "cs-icp"]


@pytest.fixture(scope="module")
def fitted(small_corpus):
    docs, df, perm, topics = small_corpus
    ref = SphericalKMeans(k=24, algo="mivi", max_iter=25, batch_size=750,
                          seed=3).fit(docs, df=df)
    return docs, df, ref


@pytest.mark.parametrize("algo", ALGOS)
def test_exactness(fitted, algo):
    docs, df, ref = fitted
    r = SphericalKMeans(k=24, algo=algo, max_iter=25, batch_size=750,
                        seed=3).fit(docs, df=df)
    assert r.n_iter_ == ref.n_iter_
    assert (r.labels_ == ref.labels_).all()
    assert abs(r.objective_ - ref.objective_) < 1e-3 * abs(ref.objective_)


def test_esicp_reduces_mult(fitted):
    docs, df, ref = fitted
    r = SphericalKMeans(k=24, algo="esicp", max_iter=25, batch_size=750,
                        seed=3).fit(docs, df=df)
    total = lambda res: sum(h["mult"] for h in res.history_)
    assert total(r) < 0.7 * total(ref)
    assert r.history_[-1]["cpr"] < 0.25


def test_objective_monotone(fitted):
    docs, df, ref = fitted
    objs = [h["objective"] for h in ref.history_]
    diffs = np.diff(objs)
    assert (diffs >= -1e-3 * abs(objs[0])).all(), "Lloyd objective decreased"


def test_convergence_reached(fitted):
    _, _, ref = fitted
    assert ref.converged_
    assert ref.history_[-1]["n_changed"] == 0


def test_estparams_lands_in_tail(fitted):
    docs, df, ref = fitted
    r = SphericalKMeans(k=24, algo="esicp", max_iter=6, batch_size=750,
                        seed=3).fit(docs, df=df)
    # paper: t_th close to D (≈ 0.9 D); our grid floor is 0.80 D
    assert int(r.params_.t_th) >= 0.5 * docs.dim
    assert 0.0 < float(r.params_.v_th) < 1.0
