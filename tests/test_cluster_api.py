"""The unified ``repro.cluster`` API: one estimator, one artifact.

Acceptance criteria of the API redesign:

  * ``FittedModel`` round-trips through ``save``/``load`` with predict
    parity on both backends;
  * one artifact drives all three runtimes — ``SphericalKMeans.predict``,
    ``ClusterEngine.from_model(...).classify``, and the mesh assign path
    agree exactly on the same corpus;
  * ``mesh=`` routes the *same* estimator through the distributed loop,
    including when N is not a shard×chunk multiple (the ρ_self tail-padding
    regression, mirroring the single-host test in test_backends.py);
  * every legacy entry point still works and fires a DeprecationWarning.
"""
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.cluster import (ClusterConfig, ClusterEngine, FittedModel,
                           SphericalKMeans, fit, load_model)
from repro.core.lloyd import LloydResult
from repro.data import make_corpus, CorpusSpec
from repro.launch.mesh import make_test_mesh


@pytest.fixture(scope="module")
def fitted(small_corpus):
    docs, df, perm, topics = small_corpus
    km = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=500,
                         seed=4).fit(docs, df=df)
    assert km.converged_
    return docs, df, km


# ---------------------------------------------------------------------------
# FittedModel round-trip.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_fitted_model_roundtrip(tmp_path, small_corpus, backend):
    """fit → save → load → predict parity, on both backends."""
    docs, df, perm, topics = small_corpus
    km = SphericalKMeans(k=10, algo="esicp", max_iter=12, batch_size=500,
                         seed=7, backend=backend).fit(docs, df=df)
    model = km.model_
    path = str(tmp_path / "model")
    model.save(path)
    loaded = load_model(path)

    assert loaded.backend == backend
    assert loaded.algo == "esicp"
    assert loaded.k == model.k and loaded.dim == model.dim
    assert loaded.n_iter == model.n_iter
    assert loaded.converged == model.converged
    assert loaded.history == model.history
    assert (loaded.labels == model.labels).all()
    np.testing.assert_array_equal(np.asarray(loaded.index.means_t),
                                  np.asarray(model.index.means_t))
    assert (np.asarray(loaded.index.moving)
            == np.asarray(model.index.moving)).all()
    assert int(loaded.params.t_th) == int(model.params.t_th)
    assert (loaded.predict(docs) == model.predict(docs)).all()


def test_model_load_rejects_non_model_checkpoint(tmp_path):
    from repro.checkpoint import save_checkpoint
    d = str(tmp_path)
    save_checkpoint(d, {"w": jnp.zeros((3,))}, step=0)
    with pytest.raises(ValueError, match="fitted-model"):
        FittedModel.load(d)


# ---------------------------------------------------------------------------
# One artifact, three runtimes.
# ---------------------------------------------------------------------------

def test_cross_runtime_parity(fitted):
    """model.predict == ClusterEngine.from_model(model).classify ==
    the distributed assign path on a 1-device mesh — one artifact, three
    runtimes, identical assignments."""
    from repro.distributed.kmeans import make_assign_fn

    docs, df, km = fitted
    model = km.model_

    pred = model.predict(docs)
    assert (pred == km.labels_).all()          # converged fixed point

    engine = ClusterEngine.from_model(model)
    served, sims = engine.classify(docs)
    assert (served == pred).all()

    mesh = make_test_mesh((1, 1), ("data", "model"))
    n = docs.n_docs
    chunk = 250
    pad = (-n) % chunk
    sh = lambda s: NamedSharding(mesh, s)
    ids = jax.device_put(jnp.pad(docs.ids, ((0, pad), (0, 0))),
                         sh(P(("data",), None)))
    vals = jax.device_put(jnp.pad(docs.vals, ((0, pad), (0, 0))),
                          sh(P(("data",), None)))
    valid = jax.device_put(jnp.arange(n + pad) < n, sh(P(("data",))))
    means_t = jax.device_put(model.index.means_t, sh(P(None, "model")))
    assign_fn = make_assign_fn(mesh, k=model.k, obj_chunk=chunk)
    mesh_assign, mesh_sims = assign_fn(ids, vals, valid, means_t,
                                       model.params.t_th, model.params.v_th)
    assert (np.asarray(mesh_assign)[:n] == pred).all()
    np.testing.assert_allclose(np.asarray(mesh_sims)[:n], sims,
                               rtol=1e-5, atol=1e-5)


def test_mesh_strategy_produces_same_artifact(small_corpus):
    """ClusterConfig(mesh=...) drives the same estimator through the
    distributed loop and yields an equivalent FittedModel."""
    docs, df, perm, topics = small_corpus
    single = SphericalKMeans(k=12, algo="esicp", max_iter=25, batch_size=500,
                             seed=3).fit(docs, df=df)
    mesh = make_test_mesh((4, 2), ("data", "model"))
    dist = SphericalKMeans(k=12, algo="esicp", max_iter=25, chunk_size=125,
                           mesh=mesh, seed=3).fit(docs, df=df)
    assert dist.model_.strategy == "mesh"
    assert single.model_.strategy == "single_host"
    assert (dist.labels_ == single.labels_).all()
    np.testing.assert_allclose(dist.model_.rho_self, single.model_.rho_self,
                               rtol=1e-5, atol=1e-5)
    # the artifacts are interchangeable across runtimes
    assert (dist.model_.predict(docs) == single.model_.predict(docs)).all()


def test_mesh_tail_padding_regression():
    """N not a shard×chunk multiple: the distributed fit pads the object
    arrays (ρ_self pad = 0, matching the core convention — not the old
    -inf) and still reproduces the single-host clustering exactly, with a
    finite valid-masked objective.  Mirrors the core tail-batch test."""
    docs, df, perm, topics = make_corpus(
        CorpusSpec(n_docs=300, vocab=256, nt_mean=20, n_topics=6, seed=13))
    ref = SphericalKMeans(k=8, algo="mivi", max_iter=15, batch_size=128,
                          seed=1).fit(docs, df=df)
    mesh = make_test_mesh((2, 2), ("data", "model"))
    # 2 data shards × chunk 64 → multiple 128; 300 % 128 = 44 → padded tail
    km = SphericalKMeans(k=8, algo="esicp", max_iter=15, chunk_size=64,
                         mesh=mesh, seed=1).fit(docs, df=df)
    assert km.converged_
    assert len(km.labels_) == docs.n_docs
    assert (km.labels_ == ref.labels_).all()
    for h in km.history_:
        assert np.isfinite(h["objective"])
    np.testing.assert_allclose(km.history_[-1]["objective"],
                               ref.history_[-1]["objective"], rtol=1e-5)


# ---------------------------------------------------------------------------
# Deprecation shims: old paths keep working and warn.
# ---------------------------------------------------------------------------

def test_fit_returns_estimator_and_legacy_result_attrs_warn(fitted):
    docs, df, km = fitted
    assert isinstance(km, SphericalKMeans)     # fit returned self

    with pytest.warns(DeprecationWarning):
        res = km.fit_result()
    assert isinstance(res, LloydResult)
    assert (res.assign == km.labels_).all()

    with pytest.warns(DeprecationWarning):
        legacy_assign = km.assign
    assert (legacy_assign == km.labels_).all()
    with pytest.warns(DeprecationWarning):
        assert km.history == km.history_
    with pytest.warns(DeprecationWarning):
        assert km.n_iter == km.n_iter_
    with pytest.warns(DeprecationWarning):
        assert km.converged == km.converged_
    with pytest.warns(DeprecationWarning):
        assert km.objective == km.objective_
    # ctor attrs are NOT shadowed by the legacy forwarding
    assert km.params == "auto"
    with pytest.raises(AttributeError):
        km.no_such_attribute


def test_dist_fit_shim_warns_and_matches(small_corpus):
    from repro.distributed import dist_fit

    docs, df, perm, topics = small_corpus
    sub = docs.slice_rows(0, 512)
    mesh = make_test_mesh((2, 2), ("data", "model"))
    km = SphericalKMeans(k=8, algo="esicp", max_iter=10, chunk_size=128,
                         mesh=mesh, seed=2).fit(sub, df=df)
    with pytest.warns(DeprecationWarning):
        state, hist, conv = dist_fit(sub, 8, mesh, algo="esicp", max_iter=10,
                                     obj_chunk=128, seed=2, df=df)
    assert (np.asarray(state.assign)[:sub.n_docs] == km.labels_).all()


def test_cluster_engine_index_ctor_warns_and_matches(fitted):
    docs, df, km = fitted
    model = km.model_
    with pytest.warns(DeprecationWarning):
        legacy = ClusterEngine(model.index, backend=model.backend)
    modern = ClusterEngine.from_model(model)
    a_legacy, _ = legacy.classify(docs)
    a_modern, _ = modern.classify(docs)
    assert (a_legacy == a_modern).all()


def test_make_kmeans_shim_warns():
    from benchmarks.common import make_kmeans

    with pytest.warns(DeprecationWarning):
        km = make_kmeans(4, max_iter=2)
    assert isinstance(km, SphericalKMeans)


# ---------------------------------------------------------------------------
# Engine round trip + config validation.
# ---------------------------------------------------------------------------

def test_engine_to_model_closes_refit_loop(tmp_path, fitted):
    """train → serve → refit → artifact → serve again, one noun throughout."""
    docs, df, km = fitted
    engine = ClusterEngine.from_model(km.model_)
    assign, rho = engine.refit(docs)
    model2 = engine.to_model()
    assert (model2.labels == assign).all()
    np.testing.assert_allclose(model2.rho_self, rho, rtol=1e-6)
    path = str(tmp_path / "refit-model")
    model2.save(path)
    reloaded = FittedModel.load(path)
    assert (ClusterEngine.from_model(reloaded).classify(docs)[0]
            == assign).all()


def test_facade_fit_and_config_validation(small_corpus):
    docs, df, perm, topics = small_corpus
    model = fit(docs, ClusterConfig(k=8, max_iter=8, batch_size=500, seed=1),
                df=df)
    assert isinstance(model, FittedModel)
    assert model.k == 8
    with pytest.raises(ValueError, match="algorithm"):
        ClusterConfig(k=8, algo="nope").validate()
    with pytest.raises(ValueError):
        ClusterConfig(k=0).validate()
    with pytest.raises(ValueError):
        ClusterConfig(k=8, backend="cuda").validate()
    assert ClusterConfig(k=8).strategy == "single_host"
