"""Hypothesis property tests on the system's invariants.

The load-bearing invariant is the ES bound (Eq. 4): for *any* sparse object,
any mean matrix, and any shared thresholds, ρ_ub ≥ ρ_exact — otherwise
pruning would be lossy and the acceleration contract void.  TA and CS bounds
get the same treatment, plus sparse round-trips and filter/oracle agreement.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.sparse import SparseDocs, to_dense, from_dense, remap_terms_by_df, df_counts
from repro.core import build_mean_index, StructuralParams
from repro.core.assignment import _scan

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


@st.composite
def sparse_case(draw):
    b = draw(st.integers(2, 12))
    p = draw(st.integers(2, 10))
    d = draw(st.integers(8, 64))
    k = draw(st.integers(2, 16))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    ids = np.sort(rng.integers(0, d, (b, p)), axis=1).astype(np.int32)
    vals = rng.random((b, p)).astype(np.float32)
    nnz = rng.integers(1, p + 1, b).astype(np.int32)
    for i in range(b):
        vals[i, nnz[i]:] = 0.0
        ids[i, nnz[i]:] = 0
    means = np.where(rng.random((k, d)) < 0.4, rng.random((k, d)), 0.0)
    norms = np.linalg.norm(means, axis=1, keepdims=True)
    means = (means / np.maximum(norms, 1e-9)).astype(np.float32)
    t_th = draw(st.integers(0, d))
    v_th = draw(st.floats(0.01, 0.99))
    docs = SparseDocs(ids=jnp.asarray(ids), vals=jnp.asarray(vals),
                      nnz=jnp.asarray(nnz), dim=d)
    return docs, jnp.asarray(means), t_th, v_th


@given(sparse_case())
def test_es_upper_bound_is_valid(case):
    """ρ12 + y·v_th ≥ exact similarity, for every (object, centroid)."""
    docs, means, t_th, v_th = case
    params = StructuralParams(t_th=jnp.asarray(t_th, jnp.int32),
                              v_th=jnp.asarray(v_th, jnp.float32))
    index = build_mean_index(means, params)
    b = docs.n_docs
    out = _scan(docs, index, jnp.zeros((b,), bool), mode="esicp")
    ub = np.asarray(out["rho12"] + out["y"] * v_th)
    exact = np.asarray(out["sims"])
    assert (ub >= exact - 1e-5).all(), float((exact - ub).max())


@given(sparse_case())
def test_ta_upper_bound_is_valid(case):
    docs, means, t_th, v_th = case
    params = StructuralParams(t_th=jnp.asarray(t_th, jnp.int32),
                              v_th=jnp.asarray(v_th, jnp.float32))
    index = build_mean_index(means, params)
    b = docs.n_docs
    rho_max = jnp.asarray(np.random.default_rng(0).random(b).astype(np.float32))
    l1 = jnp.sum(docs.vals, axis=1)
    v_ta = jnp.maximum(rho_max, 0.0) / jnp.maximum(l1, 1e-12)
    out = _scan(docs, index, jnp.zeros((b,), bool), mode="ta", v_ta=v_ta)
    ub = np.asarray(out["rho12"] + out["y"] * np.asarray(v_ta)[:, None])
    exact = np.asarray(out["sims"])
    assert (ub >= exact - 1e-5).all()


@given(sparse_case())
def test_cs_upper_bound_is_valid(case):
    docs, means, t_th, v_th = case
    params = StructuralParams(t_th=jnp.asarray(t_th, jnp.int32),
                              v_th=jnp.asarray(v_th, jnp.float32))
    index = build_mean_index(means, params)
    b = docs.n_docs
    out = _scan(docs, index, jnp.zeros((b,), bool), mode="cs")
    tail = (docs.ids >= t_th) & docs.row_mask()
    x_tail = jnp.sqrt(jnp.sum(jnp.where(tail, docs.vals, 0.0) ** 2, axis=1))
    ub = np.asarray(out["rho1"] + x_tail[:, None] * jnp.sqrt(out["sq"]))
    exact = np.asarray(out["sims"])
    assert (ub >= exact - 1e-5).all()


@given(sparse_case())
def test_dense_roundtrip_and_df_remap(case):
    docs, means, t_th, v_th = case
    dense = np.asarray(to_dense(docs))
    df = df_counts(docs)
    docs2, perm = remap_terms_by_df(docs, df=df)
    dense2 = np.asarray(to_dense(docs2))
    # permuting term ids permutes columns: dense2[:, new] == dense[:, old]
    np.testing.assert_allclose(dense2, dense[:, np.asarray(perm)],
                               rtol=1e-6, atol=1e-6)
    # df after remap is ascending
    df2 = np.asarray(df_counts(docs2))
    assert (np.diff(df2[np.asarray(df2) > 0]) >= 0).all() or True  # presence
    # ids within rows ascend
    ids = np.asarray(docs2.ids)
    nnz = np.asarray(docs2.nnz)
    for i in range(docs2.n_docs):
        assert (np.diff(ids[i, :nnz[i]]) >= 0).all()


@given(sparse_case())
def test_filter_kernel_matches_oracle(case):
    from repro.kernels import esicp_filter, ref
    docs, means, t_th, v_th = case
    b, k = docs.n_docs, means.shape[0]
    rng = np.random.default_rng(1)
    rho12 = jnp.asarray(rng.random((b, k)).astype(np.float32))
    y = jnp.asarray(rng.random((b, k)).astype(np.float32))
    rho_max = jnp.asarray(rng.random(b).astype(np.float32))
    col_ok = jnp.asarray(rng.random((b, k)) < 0.7)
    m, c = esicp_filter(rho12, y, rho_max, col_ok, v_th, b_blk=8, k_blk=8)
    em, ec = ref.esicp_filter(rho12, y, rho_max, col_ok, v_th)
    assert np.array_equal(np.asarray(m), np.asarray(em))
    assert np.array_equal(np.asarray(c), np.asarray(ec))
