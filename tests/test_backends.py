"""Backend-pluggable assignment engine: parity matrix + fused-epoch contract.

The acceptance criteria of the backend refactor:

  * for every algorithm, ``assignment_step(..., backend="pallas")`` (interpret
    mode on CPU) returns assignments identical to ``backend="reference"`` —
    and here we hold the stronger line: candidate counts and the Mult
    diagnostic match too;
  * ``SphericalKMeans.fit`` runs the whole epoch as one jitted call and
    performs exactly one device→host pull per Lloyd iteration;
  * the tail batch (n % batch_size != 0) rides the identical padded code
    path and changes nothing.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import SphericalKMeans, StructuralParams
from repro.core.assignment import ALGORITHMS, assignment_step
from repro.core.backends import BACKENDS, resolve_backend
from repro.core import lloyd


BACKEND_NAMES = sorted(BACKENDS)          # ["pallas", "reference"]


@pytest.fixture(scope="module")
def mid_state(small_corpus):
    """A realistic mid-clustering state with nontrivial shared thresholds."""
    docs, df, perm, topics = small_corpus
    res = SphericalKMeans(k=16, algo="mivi", max_iter=3, batch_size=1500,
                          seed=11).fit(docs, df=df)
    params = StructuralParams(t_th=jnp.asarray(int(0.8 * docs.dim), jnp.int32),
                              v_th=jnp.asarray(0.05, jnp.float32))
    state = res.state
    return docs, state.index.with_params(params), state


@pytest.mark.parametrize("algo", sorted(ALGORITHMS))
def test_backend_parity_matrix(mid_state, algo):
    """reference × pallas produce identical assignments (and diagnostics)."""
    docs, index, state = mid_state
    outs = {}
    for backend in BACKEND_NAMES:
        outs[backend] = assignment_step(algo, docs, index, state.assign,
                                        state.rho_self, state.xstate,
                                        backend=backend)
    ref, pal = outs["reference"], outs["pallas"]
    assert (np.asarray(ref.assign) == np.asarray(pal.assign)).all()
    assert (np.asarray(ref.n_candidates) == np.asarray(pal.n_candidates)).all()
    # Mult counts integers, so the kernels' binarised matmuls are exact.
    assert float(ref.mult) == float(pal.mult)
    np.testing.assert_allclose(np.asarray(ref.rho), np.asarray(pal.rho),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_fit_exactness_across_backends(small_corpus, backend):
    """Full Lloyd runs converge to the identical clustering per backend."""
    docs, df, perm, topics = small_corpus
    ref = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=500,
                          seed=4).fit(docs, df=df)
    r = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=500,
                        seed=4, backend=backend).fit(docs, df=df)
    assert r.n_iter == ref.n_iter
    assert (r.assign == ref.assign).all()


def test_tail_batch_identical_assignments(small_corpus):
    """n % batch_size != 0: the padded tail batch changes nothing."""
    docs, df, perm, topics = small_corpus          # n = 1500
    full = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=1500,
                           seed=4).fit(docs, df=df)
    tail = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=400,
                           seed=4).fit(docs, df=df)     # 1500 % 400 = 300
    assert tail.n_iter == full.n_iter
    assert (tail.assign == full.assign).all()
    np.testing.assert_allclose([h["mult"] for h in tail.history],
                               [h["mult"] for h in full.history], rtol=1e-6)
    assert len(tail.assign) == docs.n_docs


def test_fused_epoch_one_call_and_one_sync_per_iteration(small_corpus,
                                                         monkeypatch):
    """The epoch is one jitted call; the host syncs once per iteration."""
    docs, df, perm, topics = small_corpus
    epoch_calls, pulls = [], []
    real_epoch, real_pull = lloyd._run_epoch, lloyd._host_pull

    def counting_epoch(*a, **kw):
        epoch_calls.append(1)
        return real_epoch(*a, **kw)

    def counting_pull(x):
        pulls.append(1)
        return real_pull(x)

    monkeypatch.setattr(lloyd, "_run_epoch", counting_epoch)
    monkeypatch.setattr(lloyd, "_host_pull", counting_pull)
    # 4 batches per epoch: the per-batch loop would count 4× per iteration.
    res = SphericalKMeans(k=12, algo="esicp", max_iter=8, batch_size=375,
                          seed=4).fit(docs, df=df)
    assert len(epoch_calls) == res.n_iter
    assert len(pulls) == res.n_iter


def test_resolve_backend():
    assert resolve_backend("reference").name == "reference"
    assert resolve_backend("pallas").name == "pallas"
    assert resolve_backend("auto").name in ("reference", "pallas")
    assert resolve_backend(BACKENDS["pallas"]).name == "pallas"
    with pytest.raises(ValueError):
        resolve_backend("no-such-backend")


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_cluster_engine_parity(small_corpus, backend):
    """Serving layer: frozen-index classification agrees with the fit."""
    from repro.serve import ClusterEngine

    docs, df, perm, topics = small_corpus
    res = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=1500,
                          seed=4).fit(docs, df=df)
    assert res.converged
    eng = ClusterEngine(res.state.index, backend=backend, batch_size=700)
    assign, sims = eng.classify(docs)          # 1500 % 700 != 0 — tail path
    assert (assign == res.assign).all()
    np.testing.assert_allclose(sims, np.asarray(res.state.rho_self)[:docs.n_docs],
                               rtol=1e-5, atol=1e-5)


def test_distributed_backend_pallas_smoke():
    """shard_map step with the kernel backend matches the reference backend."""
    from repro.data import make_corpus, CorpusSpec
    from repro.launch.mesh import make_test_mesh
    from repro.distributed import dist_fit

    docs, df, perm, topics = make_corpus(CorpusSpec(n_docs=256, vocab=256,
                                                    nt_mean=20, n_topics=6,
                                                    seed=13))
    mesh = make_test_mesh((2, 2), ("data", "model"))
    ref, _, _ = dist_fit(docs, 8, mesh, algo="esicp", max_iter=4,
                         obj_chunk=64, seed=1, df=df)
    pal, _, _ = dist_fit(docs, 8, mesh, algo="esicp", max_iter=4,
                         obj_chunk=64, seed=1, df=df, backend="pallas")
    assert (np.asarray(ref.assign)[:docs.n_docs]
            == np.asarray(pal.assign)[:docs.n_docs]).all()
