"""Backend-pluggable clustering engine: parity matrix + fused-fit contract.

The acceptance criteria of the backend refactor:

  * for every algorithm, ``assignment_step(..., backend="pallas")`` (interpret
    mode on CPU) returns assignments identical to ``backend="reference"`` —
    and here we hold the stronger line: candidate counts and the Mult
    diagnostic match too;
  * the update phase is backend-owned: ``update_step(..., backend="pallas")``
    exercises ``kernels.ops.segment_update`` / ``rho_gather`` and produces
    identical moving flags and assignments (means/ρ_self to f32
    reduction-order tolerance) for all six algorithms;
  * ``SphericalKMeans.fit`` performs O(1) host syncs per *fit* — one per
    EstParams prologue iteration plus one for the entire fused
    ``lax.while_loop`` remainder — not one per iteration;
  * the tail batch (n % batch_size != 0) rides the identical padded code
    path and changes nothing: assignments, objective, and history.
"""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import SphericalKMeans, StructuralParams
from repro.core.assignment import ALGORITHMS, assignment_step
from repro.core.backends import BACKENDS, resolve_backend
from repro.core.update import update_step
from repro.core import lloyd
from repro.kernels import ref as kref


BACKEND_NAMES = sorted(BACKENDS)    # ["pallas", "reference", "xla_blocked"]
ACCEL_NAMES = [b for b in BACKEND_NAMES if b != "reference"]


@pytest.fixture(scope="module")
def mid_state(small_corpus):
    """A realistic mid-clustering state with nontrivial shared thresholds."""
    docs, df, perm, topics = small_corpus
    km = SphericalKMeans(k=16, algo="mivi", max_iter=3, batch_size=1500,
                         seed=11).fit(docs, df=df)
    params = StructuralParams(t_th=jnp.asarray(int(0.8 * docs.dim), jnp.int32),
                              v_th=jnp.asarray(0.05, jnp.float32))
    state = km.state_
    return docs, state.index.with_params(params), state


@pytest.mark.parametrize("algo", sorted(ALGORITHMS))
def test_backend_parity_matrix(mid_state, algo):
    """Every accelerated backend (pallas, xla_blocked) produces identical
    assignments (and diagnostics) to the reference scan."""
    docs, index, state = mid_state
    outs = {}
    for backend in BACKEND_NAMES:
        outs[backend] = assignment_step(algo, docs, index, state.assign,
                                        state.rho_self, state.xstate,
                                        backend=backend)
    ref = outs["reference"]
    for name in ACCEL_NAMES:
        acc = outs[name]
        assert (np.asarray(ref.assign) == np.asarray(acc.assign)).all(), name
        assert (np.asarray(ref.n_candidates)
                == np.asarray(acc.n_candidates)).all(), name
        # Mult counts integers, so the kernels' binarised matmuls are exact.
        assert float(ref.mult) == float(acc.mult), name
        np.testing.assert_allclose(np.asarray(ref.rho), np.asarray(acc.rho),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"backend={name}")


@pytest.mark.parametrize("algo", sorted(ALGORITHMS))
def test_update_phase_parity_matrix(mid_state, algo):
    """Full iteration (assignment × algo → backend-owned update) per backend:
    identical assignments and moving flags; means/ρ_self agree to f32
    reduction-order tolerance; and the *next* assignment step from each
    backend's updated state is again identical — the acceleration contract
    survives the pallas update path (segment_update + rho_gather)."""
    docs, index, state = mid_state
    st = dataclasses.replace(state, index=index)
    outs = {}
    for backend in BACKEND_NAMES:
        res = assignment_step(algo, docs, index, st.assign, st.rho_self,
                              st.xstate, backend=backend)
        new = update_step(docs, res.assign, st.assign, st, index.params,
                          k=index.k, backend=backend)
        nxt = assignment_step(algo, docs, new.index, new.assign,
                              new.rho_self, new.xstate, backend=backend)
        outs[backend] = (new, nxt)
    ref_s = outs["reference"][0]
    ref_n = outs["reference"][1]
    for name in ACCEL_NAMES:
        acc_s, acc_n = outs[name]
        assert (np.asarray(ref_s.assign) == np.asarray(acc_s.assign)).all(), \
            name
        assert (np.asarray(ref_s.index.moving)
                == np.asarray(acc_s.index.moving)).all(), name
        assert (np.asarray(ref_s.index.mf)
                == np.asarray(acc_s.index.mf)).all(), name
        np.testing.assert_allclose(np.asarray(ref_s.index.means_t),
                                   np.asarray(acc_s.index.means_t),
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=f"backend={name}")
        np.testing.assert_allclose(np.asarray(ref_s.rho_self),
                                   np.asarray(acc_s.rho_self),
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=f"backend={name}")
        assert (np.asarray(ref_n.assign) == np.asarray(acc_n.assign)).all(), \
            name


def test_pallas_diag_is_fused_no_extra_launch(mid_state, monkeypatch):
    """ISSUE 5 acceptance: ``diag=True`` issues NO extra kernel launch —
    the Mult count rides the main kernels as a fused accumulator, and the
    ES mode pulls bound operands + exact sims + counts out of ONE
    ``esicp_gather`` launch (no separate ``sparse_sim`` pass)."""
    from repro.kernels import ops

    docs, index, state = mid_state
    calls = []
    for name in ("sparse_sim", "esicp_gather", "segment_update",
                 "rho_gather", "esicp_filter"):
        real = getattr(ops, name)

        def wrapped(*a, _real=real, _name=name, **kw):
            calls.append(_name)
            return _real(*a, **kw)

        monkeypatch.setattr(ops, name, wrapped)

    bk = BACKENDS["pallas"]
    out = bk.accumulate(docs, index, state.xstate, mode="esicp", diag=True)
    assert calls == ["esicp_gather"]
    assert {"sims", "rho12", "y", "mult"} <= set(out)

    calls.clear()
    out = bk.accumulate(docs, index, state.xstate, mode="exact", diag=True)
    assert calls == ["sparse_sim"]
    assert {"sims", "mult"} <= set(out)

    calls.clear()
    nodiag = bk.accumulate(docs, index, state.xstate, mode="exact",
                           diag=False)
    assert calls == ["sparse_sim"]          # same launch count without diag
    assert float(nodiag["mult"]) == 0.0


def test_pallas_prepare_plan_keeps_exactness(mid_state):
    """A prepared plan (occupancy + cached head slabs) changes nothing:
    accumulators and the Mult count are identical with and without it."""
    from repro.kernels.plan import KernelPlan

    docs, index, state = mid_state
    bk = BACKENDS["pallas"]
    plan = bk.prepare(docs)
    assert isinstance(plan, KernelPlan) and plan.occ is not None
    assert BACKENDS["reference"].prepare(docs) is None
    for mode in ("exact", "esicp"):
        base = bk.accumulate(docs, index, state.xstate, mode=mode, diag=True)
        planned = bk.accumulate(docs, index, state.xstate, mode=mode,
                                diag=True, plan=plan)
        assert float(base["mult"]) == float(planned["mult"])
        for key in ("sims", "rho12", "y"):
            if key in base:
                np.testing.assert_array_equal(np.asarray(base[key]),
                                              np.asarray(planned[key]))


def test_xla_diag_is_fused_no_extra_launch(mid_state, monkeypatch):
    """The xla_blocked engine keeps (and extends) the fused-diagnostic
    contract: ``diag=True`` adds no extra op call, and the CS mode — three
    ``sparse_sim`` launches on the Pallas backend — is ONE ``cs_gather``."""
    from repro.kernels import xla_blocked as xb

    docs, index, state = mid_state
    calls = []
    for name in ("sparse_sim", "esicp_gather", "cs_gather",
                 "segment_update", "rho_gather"):
        real = getattr(xb, name)

        def wrapped(*a, _real=real, _name=name, **kw):
            calls.append(_name)
            return _real(*a, **kw)

        monkeypatch.setattr(xb, name, wrapped)

    bk = BACKENDS["xla_blocked"]
    out = bk.accumulate(docs, index, state.xstate, mode="esicp", diag=True)
    assert calls == ["esicp_gather"]
    assert {"sims", "rho12", "y", "mult"} <= set(out)

    calls.clear()
    out = bk.accumulate(docs, index, state.xstate, mode="exact", diag=True)
    assert calls == ["sparse_sim"]
    assert {"sims", "mult"} <= set(out)

    calls.clear()
    out = bk.accumulate(docs, index, state.xstate, mode="cs", diag=True)
    assert calls == ["cs_gather"]
    assert {"sims", "rho1", "sq", "mult"} <= set(out)

    calls.clear()
    v_ta = state.rho_self * jnp.asarray(0.5, jnp.float32)
    out = bk.accumulate(docs, index, state.xstate, mode="ta", v_ta=v_ta,
                        diag=True)
    assert calls == ["esicp_gather"]          # TA compiles natively here
    assert {"sims", "rho12", "y", "mult"} <= set(out)

    calls.clear()
    nodiag = bk.accumulate(docs, index, state.xstate, mode="exact",
                           diag=False)
    assert calls == ["sparse_sim"]          # same launch count without diag
    assert float(nodiag["mult"]) == 0.0


def test_xla_prepare_plan_keeps_exactness(mid_state):
    """xla_blocked plans: the engine-default prepare (head-less — plans are
    a tuner opt-in for this engine) is bit-identical with and without the
    plan; an explicit head-slab plan keeps integer accumulators exact and
    float sums to reduction-order tolerance (the head split reorders the
    additions of the similarity sums, by design)."""
    from repro.kernels.plan import KernelPlan, prepare_plan

    docs, index, state = mid_state
    bk = BACKENDS["xla_blocked"]
    plan = bk.prepare(docs)
    assert isinstance(plan, KernelPlan) and plan.n_head == 0
    for mode in ("exact", "esicp", "cs"):
        base = bk.accumulate(docs, index, state.xstate, mode=mode, diag=True)
        planned = bk.accumulate(docs, index, state.xstate, mode=mode,
                                diag=True, plan=plan)
        for key in sorted(base):
            np.testing.assert_array_equal(np.asarray(base[key]),
                                          np.asarray(planned[key]),
                                          err_msg=f"{mode}/{key}")

    hplan = prepare_plan(docs.ids, docs.vals, dim=docs.dim,
                         head_bytes=1 << 30, with_counts=True)
    assert hplan.n_head > 0
    for mode in ("exact", "esicp"):
        base = bk.accumulate(docs, index, state.xstate, mode=mode, diag=True)
        headed = bk.accumulate(docs, index, state.xstate, mode=mode,
                               diag=True, plan=hplan)
        assert float(base["mult"]) == float(headed["mult"]), mode
        for key in ("sims", "rho12", "y"):
            if key in base:
                np.testing.assert_allclose(np.asarray(base[key]),
                                           np.asarray(headed[key]),
                                           rtol=1e-5, atol=1e-5,
                                           err_msg=f"{mode}/{key}")


def test_streaming_resume_xla_blocked_parity(small_corpus, tmp_path):
    """Streaming fit + mid-run checkpoint resume under the xla_blocked
    backend lands on the same clustering as the reference backend."""
    from repro.core.lloyd import streaming_fit
    from repro.sparse import DocStore

    docs, df, perm, topics = small_corpus
    store = DocStore.from_docs(docs, chunk_size=375)       # 4 chunks
    ref = streaming_fit(store, k=8, algo="esicp", max_iter=12,
                        batch_size=375, seed=1, df=df)
    ckpt = str(tmp_path / "ckpt")
    part = streaming_fit(store, k=8, algo="esicp", max_iter=3,
                         batch_size=375, seed=1, df=df,
                         backend="xla_blocked", checkpoint_dir=ckpt,
                         checkpoint_every=1)
    assert not part.converged
    resumed = streaming_fit(store, k=8, algo="esicp", max_iter=12,
                            batch_size=375, seed=1, df=df,
                            backend="xla_blocked", checkpoint_dir=ckpt,
                            resume=True)
    assert (np.asarray(resumed.assign) == np.asarray(ref.assign)).all()
    assert resumed.n_iter == ref.n_iter


def _update_case(rng, b, p, d, k, assign):
    ids = np.sort(rng.integers(0, d, (b, p)), axis=1).astype(np.int32)
    vals = rng.random((b, p)).astype(np.float32)
    nnz = rng.integers(1, p + 1, b)
    for i in range(b):
        vals[i, nnz[i]:] = 0
    means_t = np.where(rng.random((d, k)) < 0.3,
                       rng.random((d, k)), 0).astype(np.float32)
    return (jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(means_t),
            jnp.asarray(assign.astype(np.int32)))


@pytest.mark.parametrize("backend", BACKEND_NAMES)
@pytest.mark.parametrize("case", ["empty_clusters", "collapse", "tail"])
def test_update_accumulators_vs_oracle(rng, backend, case):
    """Backend update accumulators == the pure-jnp kernel oracles, across
    empty clusters, single-cluster collapse, and non-block-multiple tails."""
    b, p, d, k = {"empty_clusters": (96, 12, 200, 11),
                  "collapse": (64, 8, 128, 9),
                  "tail": (130, 12, 260, 33)}[case]
    if case == "empty_clusters":
        assign = rng.choice([0, 3, k - 1], b)      # most clusters stay empty
    elif case == "collapse":
        assign = np.full(b, 2)                     # every object in one cluster
    else:
        assign = rng.integers(0, k, b)
    ids, vals, means_t, assign = _update_case(rng, b, p, d, k, assign)
    bk = BACKENDS[backend]

    lam = bk.accumulate_means(ids, vals, assign, k=k, dim=d)
    np.testing.assert_allclose(
        np.asarray(lam), np.asarray(kref.segment_update(assign, ids, vals, k, d)),
        rtol=1e-5, atol=1e-5)
    if case == "empty_clusters":
        used = set(np.asarray(assign).tolist())
        for j in range(k):
            if j not in used:
                assert (np.asarray(lam)[j] == 0.0).all()

    rho = bk.self_sims(ids, vals, assign, means_t)
    np.testing.assert_allclose(
        np.asarray(rho), np.asarray(kref.rho_gather(assign, ids, vals, means_t)),
        rtol=1e-5, atol=1e-5)

    # Chunked accumulation (the distributed step's fori_loop contract):
    # folding two halves through init= equals the one-shot sum.
    h = (b // 2 // 8) * 8 or b // 2
    lam2 = bk.accumulate_means(ids[:h], vals[:h], assign[:h], k=k, dim=d)
    lam2 = bk.accumulate_means(ids[h:], vals[h:], assign[h:], k=k, dim=d,
                               init=lam2)
    np.testing.assert_allclose(np.asarray(lam2), np.asarray(lam),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_fit_exactness_across_backends(small_corpus, backend):
    """Full Lloyd runs converge to the identical clustering per backend."""
    docs, df, perm, topics = small_corpus
    ref = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=500,
                          seed=4).fit(docs, df=df)
    r = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=500,
                        seed=4, backend=backend).fit(docs, df=df)
    assert r.n_iter_ == ref.n_iter_
    assert (r.labels_ == ref.labels_).all()


def test_tail_batch_padding_regression(small_corpus):
    """n % batch_size != 0: the padded tail batch changes nothing — the
    regression companion to the ρ_self pad-value fix: assignments, objective,
    and the entire diagnostic history are identical with and without tail
    padding (dead rows carry ρ_self = 0 and are masked out of the objective
    reduction)."""
    docs, df, perm, topics = small_corpus          # n = 1500
    full = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=1500,
                           seed=4).fit(docs, df=df)
    tail = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=400,
                           seed=4).fit(docs, df=df)     # 1500 % 400 = 300
    assert tail.n_iter_ == full.n_iter_
    assert tail.converged_ == full.converged_
    assert (tail.labels_ == full.labels_).all()
    np.testing.assert_allclose(tail.objective_, full.objective_, rtol=1e-6)
    for ht, hf in zip(tail.history_, full.history_):
        assert ht["n_changed"] == hf["n_changed"]
        assert ht["n_moving"] == hf["n_moving"]
        assert ht["t_th"] == hf["t_th"]
        np.testing.assert_allclose(ht["mult"], hf["mult"], rtol=1e-6)
        np.testing.assert_allclose(ht["cpr"], hf["cpr"], rtol=1e-6)
        np.testing.assert_allclose(ht["objective"], hf["objective"],
                                   rtol=1e-6)
    assert len(tail.labels_) == docs.n_docs


def test_fit_host_syncs_o1_per_fit(small_corpus, monkeypatch):
    """O(1) host syncs per *fit*: one pull per EstParams prologue iteration
    (≤ 2) plus exactly one for the entire fused while_loop remainder — and
    the remainder is a single call, however many iterations it runs."""
    docs, df, perm, topics = small_corpus
    fused_calls, pulls = [], []
    real_fused, real_pull = lloyd._run_fused, lloyd._host_pull

    def counting_fused(*a, **kw):
        fused_calls.append(1)
        return real_fused(*a, **kw)

    def counting_pull(x):
        pulls.append(1)
        return real_pull(x)

    monkeypatch.setattr(lloyd, "_run_fused", counting_fused)
    monkeypatch.setattr(lloyd, "_host_pull", counting_pull)
    res = SphericalKMeans(k=12, algo="esicp", max_iter=8, batch_size=375,
                          seed=4).fit(docs, df=df)
    assert res.n_iter_ > 3                 # more iterations than host syncs
    assert len(fused_calls) == 1           # iterations 3.. are one call
    assert len(pulls) == 3                 # 2 prologue + 1 fused remainder


def test_streaming_fit_host_syncs_o1_per_epoch(small_corpus, monkeypatch):
    """The chunk-scan extension of the host-sync discipline: a streaming
    fit over a multi-chunk DocStore pulls EXACTLY once per epoch — the
    convergence/diagnostics read — however many chunks stream through
    (per-chunk steps are async dispatches, never device_get)."""
    from repro.sparse import DocStore

    docs, df, perm, topics = small_corpus
    store = DocStore.from_docs(docs, chunk_size=375)      # 4 chunks
    assert store.n_chunks >= 4
    pulls = []
    real_pull = lloyd._host_pull

    def counting_pull(x):
        pulls.append(1)
        return real_pull(x)

    monkeypatch.setattr(lloyd, "_host_pull", counting_pull)
    res = SphericalKMeans(k=12, algo="esicp", max_iter=12, batch_size=375,
                          seed=4).fit(store, df=df)
    assert res.n_iter_ >= 3
    assert len(pulls) == res.n_iter_       # one sync per epoch, O(1)/epoch


def test_fused_fit_matches_per_iteration_loop(small_corpus):
    """Converged results of the fused while_loop fit are identical to a
    host-stepped per-iteration loop over the same building blocks."""
    docs, df, perm, topics = small_corpus
    res = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=500,
                          seed=4).fit(docs, df=df)
    assert res.converged_

    # Reconstruct the pre-refactor loop: epoch + update stepped from the
    # host, EstParams at iterations 1-2, stop at the first 0-change epoch.
    km = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=500,
                         seed=4)
    from repro.core.update import init_state
    from repro.core.estparams import estimate_params
    from repro.sparse import pad_rows

    n = docs.n_docs
    state = init_state(docs, 12, lloyd.initial_params(km.params, docs.dim),
                       seed=4)
    bs = 500
    pdocs = pad_rows(docs, bs)
    valid = jnp.arange(pdocs.n_docs) < n
    history = []
    for r in range(1, 21):
        state, (mult, cand, changed, obj) = lloyd._device_iteration(
            "esicp", "reference", pdocs, state, valid, bs=bs, k=12)
        if r in (1, 2):
            new_params, _ = estimate_params(docs, df, state.index.means_t,
                                            state.rho_self[:n], k=12,
                                            grid=km.est_grid)
            state = dataclasses.replace(
                state, index=state.index.with_params(new_params))
        history.append((int(changed), float(obj)))
        if int(changed) == 0:
            break

    assert res.n_iter_ == len(history)
    assert (res.labels_ == np.asarray(state.assign)[:n]).all()
    np.testing.assert_allclose(
        [h["objective"] for h in res.history_], [h[1] for h in history],
        rtol=1e-6)
    assert [h["n_changed"] for h in res.history_] == [h[0] for h in history]


def test_resolve_backend():
    import jax

    assert resolve_backend("reference").name == "reference"
    assert resolve_backend("pallas").name == "pallas"
    assert resolve_backend("xla_blocked").name == "xla_blocked"
    # 'auto' = compiled engine for the platform: pallas only where it
    # lowers natively (TPU), the XLA-blocked twins everywhere else.
    expect = "pallas" if jax.default_backend() == "tpu" else "xla_blocked"
    assert resolve_backend("auto").name == expect
    assert resolve_backend(BACKENDS["pallas"]).name == "pallas"
    with pytest.raises(ValueError):
        resolve_backend("no-such-backend")


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_cluster_engine_parity(small_corpus, backend):
    """Serving layer: frozen-index classification agrees with the fit."""
    from repro.serve import ClusterEngine

    docs, df, perm, topics = small_corpus
    res = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=1500,
                          seed=4).fit(docs, df=df)
    assert res.converged_
    eng = ClusterEngine.from_model(res.model_, backend=backend,
                                   batch_size=700)
    assign, sims = eng.classify(docs)          # 1500 % 700 != 0 — tail path
    assert (assign == res.labels_).all()
    np.testing.assert_allclose(sims, res.model_.rho_self, rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_cluster_engine_refit_rebuilds_index(small_corpus, backend):
    """Serving-layer index rebuild: refit from a converged fit's own corpus
    reproduces the fit's index (same update phase, backend-owned); a partial
    corpus keeps the untouched clusters' previous centroids alive."""
    from repro.sparse import SparseDocs
    from repro.serve import ClusterEngine

    docs, df, perm, topics = small_corpus
    res = SphericalKMeans(k=12, algo="esicp", max_iter=20, batch_size=1500,
                          seed=4).fit(docs, df=df)
    assert res.converged_
    eng = ClusterEngine.from_model(res.model_, backend=backend,
                                   batch_size=700)
    assign, rho = eng.refit(docs)              # tail path: 1500 % 700 != 0
    assert (assign == res.labels_).all()
    np.testing.assert_allclose(np.asarray(eng.index.means_t),
                               np.asarray(res.state_.index.means_t),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(rho, np.asarray(res.state_.rho_self),
                               rtol=1e-5, atol=1e-5)
    # refit on a small slice: empty clusters keep their previous centroid
    # (unit columns, no NaNs), so serving survives partial refreshes.
    sub = SparseDocs(ids=docs.ids[:64], vals=docs.vals[:64],
                     nnz=docs.nnz[:64], dim=docs.dim)
    eng.refit(sub)
    norms = np.asarray(jnp.sum(eng.index.means_t ** 2, axis=0))
    assert np.isfinite(np.asarray(eng.index.means_t)).all()
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)


def test_distributed_backend_pallas_smoke():
    """shard_map step with each kernel backend matches the reference one."""
    from repro.data import make_corpus, CorpusSpec
    from repro.launch.mesh import make_test_mesh
    from repro.distributed import mesh_fit

    docs, df, perm, topics = make_corpus(CorpusSpec(n_docs=256, vocab=256,
                                                    nt_mean=20, n_topics=6,
                                                    seed=13))
    mesh = make_test_mesh((2, 2), ("data", "model"))
    ref, _, _, _ = mesh_fit(docs, 8, mesh, algo="esicp", max_iter=4,
                            obj_chunk=64, seed=1, df=df)
    for backend in ACCEL_NAMES:
        acc, _, _, _ = mesh_fit(docs, 8, mesh, algo="esicp", max_iter=4,
                                obj_chunk=64, seed=1, df=df, backend=backend)
        assert (np.asarray(ref.assign)[:docs.n_docs]
                == np.asarray(acc.assign)[:docs.n_docs]).all(), backend
