"""Serving-plane tests: buckets, batching, hot-swap, parity (DESIGN.md §12).

Covers the continuous-batching service over FittedModel artifacts:

  * bucket selection picks the smallest padded size >= the request;
  * server-path results are bit-identical to ``ClusterEngine.classify``;
  * hot-swap atomicity — no request observes a torn index, in-flight
    batches complete on the pre-swap index while new traffic routes to the
    new one with zero recompiles;
  * admission control backpressures at ``max_live_batches``;
  * ``ClusterEngine.refit`` streams DocStores chunk by chunk (bitwise equal
    to the resident refit for a one-chunk store);
  * ``import repro.serve`` stays free of ``repro.models`` (lazy LM split).
"""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.cluster import ClusterConfig, ClusterEngine, fit
from repro.data import CorpusSpec, make_corpus
from repro.serve import ClusterServer, ModelRegistry, ServableClusterModel
from repro.serve.batching import ServerClosed
from repro.sparse import DocStore, SparseDocs


@pytest.fixture(scope="module")
def served():
    """(docs, df, modelA, modelB): two same-geometry artifacts with
    genuinely different means (different init seeds), so hot-swap tests can
    tell which index served a request."""
    docs, df, perm, topics = make_corpus(
        CorpusSpec(n_docs=420, vocab=256, nt_mean=15, n_topics=8, seed=3))
    model_a = fit(docs, ClusterConfig(k=8, max_iter=8, batch_size=420,
                                      seed=1), df=df)
    model_b = fit(docs, ClusterConfig(k=8, max_iter=2, batch_size=420,
                                      seed=7), df=df)
    return docs, df, model_a, model_b


def _rows(docs, lo=None, hi=None):
    ids = np.asarray(docs.ids)[lo:hi]
    vals = np.asarray(docs.vals)[lo:hi]
    nnz = np.asarray(docs.nnz)[lo:hi]
    return ids, vals, nnz


# ---------------------------------------------------------------------------
# Bucket selection (get_padded_batch_size over sorted_batch_sizes).
# ---------------------------------------------------------------------------

def test_bucket_selection_smallest_geq(served):
    _, _, model, _ = served
    sv = model.servable(batch_sizes=(64, 8, 16))     # any order in
    assert sv.sorted_batch_sizes == (8, 16, 64)
    assert sv.max_batch_size == 64
    for n, want in [(1, 8), (8, 8), (9, 16), (16, 16), (17, 64), (64, 64)]:
        assert sv.get_padded_batch_size(n) == want
    with pytest.raises(ValueError, match="largest bucket"):
        sv.get_padded_batch_size(65)
    with pytest.raises(ValueError):
        sv.get_padded_batch_size(0)
    with pytest.raises(ValueError):
        ServableClusterModel(model, batch_sizes=())


def test_pre_process_pads_with_dead_rows(served):
    docs, _, model, _ = served
    sv = model.servable(batch_sizes=(8, 32))
    batch = sv.pre_process([_rows(docs, 0, 5), _rows(docs, 5, 14)])
    assert (batch.n_rows, batch.bucket) == (14, 32)
    assert batch.occupancy == pytest.approx(14 / 32)
    assert (batch.nnz[14:] == 0).all() and (batch.vals[14:] == 0).all()
    a, s = sv.post_process(sv.device_compute(batch), batch.n_rows)
    assert a.shape == s.shape == (14,)


def test_pad_width_lock_widens_and_rejects(served):
    docs, _, model, _ = served
    p = np.asarray(docs.ids).shape[1]
    sv = model.servable(pad_width=p)
    ids, vals, nnz = _rows(docs, 0, 4)
    narrow = (ids[:, :10], vals[:, :10], np.minimum(nnz, 10))
    batch = sv.pre_process([narrow])                 # narrower rows widen
    assert batch.ids.shape[1] == p
    wide = ServableClusterModel(model, pad_width=4)  # live tuples beyond 4
    assert nnz.max() > 4
    with pytest.raises(ValueError, match="pad_width"):
        wide.pre_process([(ids, vals, nnz)])


# ---------------------------------------------------------------------------
# Server-path classify parity (bit-identical to the direct engine path).
# ---------------------------------------------------------------------------

def test_server_classify_parity_bit_identical(served):
    docs, _, model, _ = served
    a_ref, s_ref = ClusterEngine.from_model(model).classify(docs)
    with ClusterServer(max_live_batches=2) as srv:
        srv.load("m", model, batch_sizes=(16, 64, 128))
        # Whole corpus: 420 rows > max bucket 128 → split into one future's
        # parts, reassembled in request order.
        a, s = srv.classify("m", _rows(docs))
        assert (a == a_ref).all()
        np.testing.assert_allclose(s, s_ref, rtol=1e-6, atol=1e-6)
        # Odd-sized slices exercise every bucket.
        for lo, hi in [(0, 1), (3, 20), (17, 130), (100, 101)]:
            a, s = srv.classify("m", _rows(docs, lo, hi))
            assert (a == a_ref[lo:hi]).all()


def test_server_concurrent_clients_parity_and_occupancy(served):
    docs, _, model, _ = served
    a_ref, _ = ClusterEngine.from_model(model).classify(docs)
    results = {}
    with ClusterServer(max_live_batches=3, batch_timeout_s=0.005) as srv:
        srv.load("m", model)

        def client(i):
            lo = (i * 31) % 300
            hi = lo + 1 + (i % 70)
            results[i] = (lo, hi, srv.classify("m", _rows(docs, lo, hi)))

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = srv.stats("m")
    assert all((r[2][0] == a_ref[r[0]:r[1]]).all() for r in results.values())
    assert stats["n_failures"] == 0
    assert stats["n_requests"] == 16
    assert stats["peak_live_batches"] <= 3
    for row in stats["occupancy"].values():
        assert 0.0 < row["mean_occupancy"] <= 1.0


def test_compile_counts_no_steady_state_recompilation(served):
    docs, _, model, _ = served
    with ClusterServer() as srv:
        srv.load("m", model, batch_sizes=(32,))
        for _ in range(5):
            srv.classify("m", _rows(docs, 0, 20))
        counts = srv.stats("m")["compile_counts"]
    # One trace on first use, then cache hits forever.
    assert counts == {"32": 1}


# ---------------------------------------------------------------------------
# Hot-swap atomicity and zero-downtime.
# ---------------------------------------------------------------------------

class _SlowPost(ServableClusterModel):
    """Servable whose post-processing blocks until released — pins a batch
    in flight so tests can interleave a swap deterministically."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.entered = threading.Event()
        self.release = threading.Event()

    def post_process(self, out, n_rows):
        self.entered.set()
        assert self.release.wait(30), "test never released the slow batch"
        return super().post_process(out, n_rows)


def test_hot_swap_in_flight_completes_on_old_index(served):
    docs, _, model_a, model_b = served
    a_old, _ = ClusterEngine.from_model(model_a).classify(docs)
    a_new, _ = ClusterEngine.from_model(model_b).classify(docs)
    assert (a_old != a_new).any(), "refit must move some assignment"
    slow_a = _SlowPost(model_a)
    with ClusterServer(max_live_batches=2, n_post_workers=2) as srv:
        srv.load("m", slow_a)
        fut1 = srv.submit("m", _rows(docs, 0, 50))
        assert slow_a.entered.wait(30)          # batch 1 is in flight
        old = srv.swap("m", model_b)            # atomic re-route
        assert old is slow_a
        # Zero-downtime: new traffic completes on the NEW index while the
        # old batch is still pinned in post-processing.
        a2, _ = srv.submit("m", _rows(docs, 0, 50)).result(timeout=60)
        assert (a2 == a_new[:50]).all()
        assert not fut1.done()
        slow_a.release.set()
        a1, _ = fut1.result(timeout=60)
        assert (a1 == a_old[:50]).all()         # pre-swap index, untorn
        assert srv.stats("m")["n_failures"] == 0


def test_hot_swap_same_geometry_zero_recompiles(served):
    docs, _, model_a, model_b = served
    import repro.serve.servable as sv_mod

    with ClusterServer() as srv:
        srv.load("m", model_a, batch_sizes=(64,))
        srv.classify("m", _rows(docs, 0, 40))   # compile the one bucket
        before = dict(sv_mod.TRACE_COUNTS)
        srv.swap("m", model_b, batch_sizes=(64,))
        srv.classify("m", _rows(docs, 0, 40))
        after = dict(sv_mod.TRACE_COUNTS)
    assert after == before, "same-geometry hot-swap must not recompile"


def test_swap_during_traffic_no_torn_results(served):
    """Every response under a mid-stream swap equals full-A or full-B —
    never a mix (the registry read is one atomic reference)."""
    docs, _, model_a, model_b = served
    a_old, _ = ClusterEngine.from_model(model_a).classify(docs)
    a_new, _ = ClusterEngine.from_model(model_b).classify(docs)
    failures, torn = [], []
    with ClusterServer(max_live_batches=2, batch_timeout_s=0.001) as srv:
        srv.load("m", model_a)

        def client(i):
            lo = (i * 13) % 350
            hi = lo + 1 + (i % 60)
            try:
                a, _ = srv.classify("m", _rows(docs, lo, hi))
            except BaseException as e:          # hot-swap must not fail reqs
                failures.append(e)
                return
            if not ((a == a_old[lo:hi]).all() or (a == a_new[lo:hi]).all()):
                torn.append(i)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(24)]
        for t in threads[:12]:
            t.start()
        srv.swap("m", model_b)
        for t in threads[12:]:
            t.start()
        for t in threads:
            t.join()
    assert not failures and not torn


# ---------------------------------------------------------------------------
# Admission control / backpressure.
# ---------------------------------------------------------------------------

def test_admission_control_backpressure(served):
    docs, _, model, _ = served
    # 5-row requests against an 8-row bucket: no two coalesce, so every
    # request is its own batch and the single live slot throttles them.
    slow = _SlowPost(model, batch_sizes=(8,))
    with ClusterServer(max_live_batches=1, queue_depth=1,
                       batch_timeout_s=0.0, n_post_workers=1) as srv:
        srv.load("m", slow)
        futs = [srv.submit("m", _rows(docs, 0, 5))]
        assert slow.entered.wait(30)            # batch 1 holds the one slot
        # The batcher can absorb at most one assembled-but-slotless batch
        # plus one carried request; after that the depth-1 queue stays full
        # and non-blocking admission must reject.
        rejected = False
        for _ in range(20):
            try:
                futs.append(srv.submit("m", _rows(docs, 0, 5), block=False))
            except ServerClosed as e:
                assert "queue full" in str(e)
                rejected = True
                break
            time.sleep(0.02)
        assert rejected, "full queue never backpressured a submit"
        assert srv.stats("m")["live_batches"] == 1
        slow.release.set()
        for f in futs:                          # backlog drains completely
            f.result(timeout=120)
        stats = srv.stats("m")
    assert stats["peak_live_batches"] == 1
    assert stats["n_failures"] == 0


class _SlowPre(ServableClusterModel):
    """Servable whose pre-processing blocks — pins the BATCHING thread so
    later requests provably sit in the queue when the model unloads."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.entered = threading.Event()
        self.release = threading.Event()

    def pre_process(self, rows):
        self.entered.set()
        assert self.release.wait(30), "test never released the slow batch"
        return super().pre_process(rows)


def test_unload_fails_queued_requests_and_close_is_idempotent(served):
    docs, _, model, _ = served
    slow = _SlowPre(model)
    srv = ClusterServer(batch_timeout_s=0.0)
    try:
        srv.load("m", slow)
        batcher = srv._batchers["m"]
        inflight = srv.submit("m", _rows(docs, 0, 4))
        assert slow.entered.wait(30)            # batching thread is pinned
        queued = [srv.submit("m", _rows(docs, 0, 4)) for _ in range(3)]
        un = threading.Thread(target=srv.unload, args=("m",))
        un.start()
        assert batcher._stopped.wait(30)        # unload reached the batcher
        slow.release.set()                      # let the pinned batch go
        un.join(60)
        assert not un.is_alive()
        inflight.result(timeout=120)            # in-flight batch completed
        for f in queued:                        # never-batched ones fail
            with pytest.raises(ServerClosed, match="unloaded"):
                f.result(timeout=120)
        with pytest.raises(KeyError, match="no model"):
            srv.classify("m", _rows(docs, 0, 4))
    finally:
        slow.release.set()
        srv.close()
    srv.close()                                 # idempotent


def test_registry_errors_name_loaded_models(served):
    _, _, model, _ = served
    reg = ModelRegistry()
    sv = model.servable()
    reg.load("alpha", sv)
    with pytest.raises(ValueError, match="already loaded"):
        reg.load("alpha", sv)
    with pytest.raises(KeyError, match="alpha"):
        reg.get("beta")
    with pytest.raises(KeyError):
        reg.swap("beta", sv)
    assert reg.unload("alpha") is sv
    assert reg.names() == []


# ---------------------------------------------------------------------------
# Streaming refit over a DocStore.
# ---------------------------------------------------------------------------

def test_refit_streams_docstore_parity(served):
    docs, _, model, _ = served
    e_res = ClusterEngine.from_model(model, batch_size=200)
    a_res, r_res = e_res.refit(docs, n_iter=2)
    e_str = ClusterEngine.from_model(model, batch_size=200)
    store = DocStore.from_docs(docs, chunk_size=128)    # ragged tail chunk
    assert store.n_chunks > 1
    a_str, r_str = e_str.refit(store, n_iter=2)
    assert (a_res == a_str).all()
    np.testing.assert_allclose(r_res, r_str, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(e_res.index.means_t),
                               np.asarray(e_str.index.means_t),
                               rtol=1e-5, atol=1e-5)


def test_refit_one_chunk_store_bitwise(served):
    docs, _, model, _ = served
    e_res = ClusterEngine.from_model(model, batch_size=420)
    a_res, r_res = e_res.refit(docs)
    e_str = ClusterEngine.from_model(model, batch_size=420)
    a_str, r_str = e_str.refit(DocStore.from_docs(docs))
    assert (a_res == a_str).all()
    assert (r_res == r_str).all()
    assert (np.asarray(e_res.index.means_t)
            == np.asarray(e_str.index.means_t)).all()


# ---------------------------------------------------------------------------
# Lazy LM split: repro.serve must not import repro.models.
# ---------------------------------------------------------------------------

def test_import_serve_does_not_import_models():
    code = (
        "import sys\n"
        "import repro.serve\n"
        "assert 'repro.models' not in sys.modules, 'models imported eagerly'\n"
        "repro.serve.ServeLoop                    # lazy surface still works\n"
        "assert 'repro.models' in sys.modules\n"
    )
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
