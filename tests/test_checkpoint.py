"""Checkpoint store: atomic commit, retention, async, restore validation."""
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              latest_step, AsyncCheckpointer)


def _tree(step):
    return {"w": jnp.arange(12.0).reshape(3, 4) * step,
            "state": {"mu": jnp.ones((5,)) * step, "count": jnp.asarray(step)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, _tree(3), step=3)
    restored, step = restore_checkpoint(d, _tree(0))
    assert step == 3
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(_tree(3)["w"]))


def test_retention_keeps_last_k(tmp_path):
    d = str(tmp_path)
    for s in range(1, 7):
        save_checkpoint(d, _tree(s), step=s, keep=3)
    from repro.checkpoint.store import all_steps
    assert all_steps(d) == [4, 5, 6]
    assert latest_step(d) == 6


def test_restore_latest_after_crash_like_tmp(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, _tree(1), step=1)
    # simulate a crashed writer: stale tmp dir must be ignored
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert latest_step(d) == 1
    restored, step = restore_checkpoint(d, _tree(0))
    assert step == 1


def test_shape_validation(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, _tree(1), step=1)
    bad = {"w": jnp.zeros((2, 2)), "state": {"mu": jnp.zeros((5,)),
                                             "count": jnp.asarray(0)}}
    with pytest.raises(ValueError):
        restore_checkpoint(d, bad)


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ck = AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3):
        ck.save(_tree(s), step=s)
    ck.wait()
    assert latest_step(d) == 3
    restored, _ = restore_checkpoint(d, _tree(0))
    np.testing.assert_allclose(np.asarray(restored["state"]["mu"]),
                               np.ones(5) * 3)


def test_async_checkpointer_extra_sidecar(tmp_path):
    """The async saver commits the JSON sidecar atomically with the payload
    (the streaming fit's resume cursor rides this)."""
    from repro.checkpoint.store import load_extra

    d = str(tmp_path)
    ck = AsyncCheckpointer(d, keep=2)
    extra = {"cursor": [2, 3], "note": "mid-epoch"}
    ck.save(_tree(1), step=1, extra=extra)
    extra["cursor"] = [9, 9]          # caller mutation must not tear the save
    ck.wait()
    assert load_extra(d, step=1)["cursor"] == [2, 3]


def test_mesh_fit_resume_from_checkpoint(tmp_path, small_corpus):
    """Fault-tolerance loop: checkpoint mid-run, restore, verify payload —
    driven through the unified estimator (mesh strategy + checkpoint_dir)."""
    from repro.launch.mesh import make_test_mesh
    from repro.cluster import SphericalKMeans
    docs, df, perm, topics = small_corpus
    sub = docs.slice_rows(0, 512)
    mesh = make_test_mesh((2, 2), ("data", "model"))
    d = str(tmp_path)
    km = SphericalKMeans(k=8, algo="esicp", max_iter=6, chunk_size=128,
                         mesh=mesh, seed=1, checkpoint_dir=d,
                         checkpoint_every=2).fit(sub, df=df)
    assert latest_step(d) is not None
    k, dim, n_pad = 8, sub.dim, 512
    from repro.core.update import n_ub_groups
    example = {"means_t": jnp.zeros((dim, k)),
               "assign": jnp.zeros((n_pad,), jnp.int32),
               "rho_self": jnp.zeros((n_pad,)),
               "rho_prev": jnp.zeros((n_pad,)),
               "moving": jnp.zeros((k,), bool),
               "iteration": jnp.asarray(0),
               "ub": jnp.zeros((n_pad, n_ub_groups(k))),
               "t_th": jnp.asarray(0), "v_th": jnp.asarray(0.0)}
    restored, step = restore_checkpoint(d, example)
    assert restored["means_t"].shape == (dim, k)
    assert int(restored["iteration"]) == step
