"""Benchmark harness plumbing (no timing): the machine-readable perf
trajectory emitted for the fused-iteration suite."""
import json

from benchmarks.run import JSON_SUITES, SUITES, write_bench_json


def test_fused_suite_registered():
    names = [n for n, _ in SUITES]
    assert "fused" in names
    assert JSON_SUITES["fused"] == "BENCH_fused_iteration.json"


def test_kernel_suite_registered():
    names = [n for n, _ in SUITES]
    assert "kernels" in names
    assert JSON_SUITES["kernels"] == "BENCH_kernels.json"


def test_bench_row_carries_execution_metadata():
    """Dict rows record jax.default_backend() and the interpret flag, so an
    interpret-mode Pallas timing can never be read as a TPU number — while
    reference rows (plain XLA, no Pallas dispatch) are never flagged."""
    import jax

    from benchmarks.common import bench_row

    row = bench_row("kernel_suite/sparse_sim_pallas", 12.345, "pallas",
                    warmup_us=99.9, speedup=2.5)
    assert row["name"] == "kernel_suite/sparse_sim_pallas"
    assert row["us_per_call"] == 12.35 and row["warmup_us"] == 99.9
    assert row["backend"] == "pallas" and row["speedup"] == 2.5
    assert row["platform"] == jax.default_backend()
    assert row["interpret"] == (jax.default_backend() != "tpu")
    ref_row = bench_row("kernel_suite/sparse_sim_reference", 5.0, "reference")
    assert ref_row["interpret"] is False


def test_write_bench_json_dict_rows(tmp_path):
    """Dict rows pass through verbatim (metadata preserved) and mix with
    legacy CSV-string rows."""
    from benchmarks.common import bench_row
    from benchmarks.run import _as_csv

    rows = [bench_row("kernel_suite/rho_gather_pallas", 8.0, "pallas",
                      warmup_us=20.0, speedup=1.5),
            "fused_iteration/fit_per_iter,100.00,reference"]
    path = write_bench_json(rows, str(tmp_path / "BENCH_kernels.json"))
    data = json.loads(open(path).read())
    assert data[0]["speedup"] == 1.5 and "interpret" in data[0]
    assert data[1] == {"name": "fused_iteration/fit_per_iter",
                       "us_per_call": 100.0, "backend": "reference"}
    assert _as_csv(rows[0]) == "kernel_suite/rho_gather_pallas,8.00,pallas,20.00"
    assert _as_csv(rows[1]) == rows[1]


def test_write_bench_json(tmp_path):
    rows = ["fused_iteration/update_reference,12.50,reference",
            "fused_iteration/update_pallas,8.00,pallas",
            "fused_iteration/fit_per_iter,100.00,reference"]
    path = write_bench_json(rows, str(tmp_path / "BENCH_fused_iteration.json"))
    data = json.loads(open(path).read())
    assert data[0] == {"name": "fused_iteration/update_reference",
                       "us_per_call": 12.5, "backend": "reference"}
    assert {e["backend"] for e in data} == {"reference", "pallas"}
    assert all(e["us_per_call"] > 0 for e in data)


def test_write_bench_json_warmup_column(tmp_path):
    """The optional 4th CSV column becomes a ``warmup_us`` field, keeping
    steady-state us_per_call separate from one-off compile time."""
    rows = ["fused_iteration/update_pallas,8.00,pallas,12825990.89",
            "fused_iteration/fit_per_iter,100.00,reference"]
    path = write_bench_json(rows, str(tmp_path / "bench.json"))
    data = json.loads(open(path).read())
    assert data[0] == {"name": "fused_iteration/update_pallas",
                       "us_per_call": 8.0, "backend": "pallas",
                       "warmup_us": 12825990.89}
    assert "warmup_us" not in data[1]          # 3-column rows stay as-is


def test_time_call_warm_excludes_first_call():
    from benchmarks.common import time_call_warm

    calls = []

    def fn():
        import time
        calls.append(1)
        if len(calls) == 1:
            time.sleep(0.05)           # "compile" on the first call only
        return len(calls)

    out, best, warmup = time_call_warm(fn, repeat=2)
    assert len(calls) == 3             # 1 warmup + 2 timed
    assert out == 3
    assert warmup >= 0.05
    assert best < warmup               # steady-state excludes the warmup


def test_serving_suite_registered():
    names = [n for n, _ in SUITES]
    assert "serving" in names
    assert JSON_SUITES["serving"] == "BENCH_serving.json"


def test_check_serving_gates():
    """The serving ratchet passes a healthy artifact and fails each broken
    invariant: dropped requests, parity break, implausible percentiles,
    zero throughput, admission breach, occupancy > 1, steady-state
    recompilation, post-swap recompiles."""
    from benchmarks.ratchet import check_serving

    good = [
        {"name": "serving/latency", "qps": 100.0, "p50_ms": 1.0,
         "p99_ms": 5.0, "n_failures": 0, "parity": True,
         "peak_live_batches": 2, "max_live_batches": 4, "n_requests": 10},
        {"name": "serving/bucket32", "mean_occupancy": 0.8, "compiles": 1},
        {"name": "serving/swap", "recompiles_after_warm": 0},
    ]
    assert check_serving([dict(r) for r in good]) == 0
    breakages = [
        lambda r: r[0].update(n_failures=1),
        lambda r: r[0].update(parity=False),
        lambda r: r[0].update(p99_ms=0.5),
        lambda r: r[0].update(qps=0.0),
        lambda r: r[0].update(peak_live_batches=9),
        lambda r: r[1].update(mean_occupancy=1.2),
        lambda r: r[1].update(compiles=2),
        lambda r: r[2].update(recompiles_after_warm=3),
    ]
    for mutate in breakages:
        rows = [dict(r) for r in good]
        mutate(rows)
        assert check_serving(rows) == 1
    assert check_serving([dict(r) for r in good[:1]]) == 1  # no bucket rows


def test_ivf_suite_registered():
    names = [n for n, _ in SUITES]
    assert "ivf" in names
    assert JSON_SUITES["ivf"] == "BENCH_ivf.json"


def test_check_ivf_gates():
    """The IVF ratchet passes a healthy artifact and fails each broken
    invariant: routed Mult not below flat at gated scale, wall-clock loss,
    silently dropped recall, candidate-bound breach, non-bit-identical
    delegation, unresolvable/cross-backend speedups, missing rows."""
    from benchmarks.ratchet import check_ivf

    good = [
        {"name": "ivf/K4096/flat_classify", "k_eff": 4096, "k_c": 64,
         "mult_per_doc": 2.0e5, "backend": "reference"},
        {"name": "ivf/K4096/routed_p1", "k_eff": 4096, "k_c": 64,
         "n_probe": 1, "mult_per_doc": 5.0e3, "recall_at1": 0.99,
         "scored_max": 150, "scored_bound": 160, "backend": "reference",
         "vs": "ivf/K4096/flat_classify", "speedup": 5.0,
         "comparable": True},
        {"name": "ivf/K4096/routed_exact", "k_eff": 4096, "k_c": 64,
         "n_probe": 64, "mult_per_doc": 2.0e5, "exact_match": True,
         "backend": "reference", "vs": "ivf/K4096/flat_classify",
         "speedup": 1.0, "comparable": True},
    ]
    assert check_ivf([dict(r) for r in good]) == 0

    breakages = [
        lambda r: r[1].update(mult_per_doc=3.0e5),        # lost the Mult race
        lambda r: r[1].update(speedup=0.5),               # lost the wall race
        lambda r: r[1].pop("recall_at1"),                 # dropped accuracy
        lambda r: r[1].update(scored_max=170),            # bound breached
        lambda r: r[2].update(exact_match=False),         # delegation not exact
        lambda r: r[1].update(vs="ivf/K4096/nope"),       # dangling vs
        lambda r: r[1].update(backend="pallas"),          # cross-backend ratio
        lambda r: r.pop(2),                               # no exact row
        lambda r: r.pop(1),                               # no routed_p1 row
    ]
    for mutate in breakages:
        rows = [dict(r) for r in good]
        mutate(rows)
        assert check_ivf(rows) == 1

    # below the 4096 gate the Mult/wall ratchets do not apply (the routed
    # path is allowed to lose at toy scale), but honesty gates still do
    small = [dict(r) for r in good]
    for r in small:
        r["name"] = r["name"].replace("K4096", "K1024")
        r["k_eff"] = 1024
        if "vs" in r:
            r["vs"] = "ivf/K1024/flat_classify"
    small[1].update(mult_per_doc=3.0e5, speedup=0.5)
    assert check_ivf(small) == 0
    small[1].pop("recall_at1")
    assert check_ivf(small) == 1
