"""Benchmark harness plumbing (no timing): the machine-readable perf
trajectory emitted for the fused-iteration suite."""
import json

from benchmarks.run import JSON_SUITES, SUITES, write_bench_json


def test_fused_suite_registered():
    names = [n for n, _ in SUITES]
    assert "fused" in names
    assert JSON_SUITES["fused"] == "BENCH_fused_iteration.json"


def test_write_bench_json(tmp_path):
    rows = ["fused_iteration/update_reference,12.50,reference",
            "fused_iteration/update_pallas,8.00,pallas",
            "fused_iteration/fit_per_iter,100.00,reference"]
    path = write_bench_json(rows, str(tmp_path / "BENCH_fused_iteration.json"))
    data = json.loads(open(path).read())
    assert data[0] == {"name": "fused_iteration/update_reference",
                       "us_per_call": 12.5, "backend": "reference"}
    assert {e["backend"] for e in data} == {"reference", "pallas"}
    assert all(e["us_per_call"] > 0 for e in data)


def test_write_bench_json_warmup_column(tmp_path):
    """The optional 4th CSV column becomes a ``warmup_us`` field, keeping
    steady-state us_per_call separate from one-off compile time."""
    rows = ["fused_iteration/update_pallas,8.00,pallas,12825990.89",
            "fused_iteration/fit_per_iter,100.00,reference"]
    path = write_bench_json(rows, str(tmp_path / "bench.json"))
    data = json.loads(open(path).read())
    assert data[0] == {"name": "fused_iteration/update_pallas",
                       "us_per_call": 8.0, "backend": "pallas",
                       "warmup_us": 12825990.89}
    assert "warmup_us" not in data[1]          # 3-column rows stay as-is


def test_time_call_warm_excludes_first_call():
    from benchmarks.common import time_call_warm

    calls = []

    def fn():
        import time
        calls.append(1)
        if len(calls) == 1:
            time.sleep(0.05)           # "compile" on the first call only
        return len(calls)

    out, best, warmup = time_call_warm(fn, repeat=2)
    assert len(calls) == 3             # 1 warmup + 2 timed
    assert out == 3
    assert warmup >= 0.05
    assert best < warmup               # steady-state excludes the warmup
