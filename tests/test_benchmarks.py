"""Benchmark harness plumbing (no timing): the machine-readable perf
trajectory emitted for the fused-iteration suite."""
import json

from benchmarks.run import JSON_SUITES, SUITES, write_bench_json


def test_fused_suite_registered():
    names = [n for n, _ in SUITES]
    assert "fused" in names
    assert JSON_SUITES["fused"] == "BENCH_fused_iteration.json"


def test_write_bench_json(tmp_path):
    rows = ["fused_iteration/update_reference,12.50,reference",
            "fused_iteration/update_pallas,8.00,pallas",
            "fused_iteration/fit_per_iter,100.00,reference"]
    path = write_bench_json(rows, str(tmp_path / "BENCH_fused_iteration.json"))
    data = json.loads(open(path).read())
    assert data[0] == {"name": "fused_iteration/update_reference",
                       "us_per_call": 12.5, "backend": "reference"}
    assert {e["backend"] for e in data} == {"reference", "pallas"}
    assert all(e["us_per_call"] > 0 for e in data)
