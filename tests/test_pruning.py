"""Compounded pruning modes (DESIGN.md §11): bound soundness + exactness.

The load-bearing invariant is the group-bound bracket: the maintained
``(N, G)`` upper bound — refreshed from exact similarities, then loosened
by per-group center drift — must stay >= the true best non-assigned
similarity of every (object, group) pair, for any means perturbation and
any number of consecutive loosen steps (the streaming-resume situation:
bounds can drift-loosen many times between exact refreshes).  A single
inversion makes the ``bounds`` family lossy and voids the exactness
contract.

Also under test: padding rows are inert under the ρ_self = 0 / ub = 0 pad
convention; the three new modes are bit-identical to ``mivi`` over full
fits on both backends, through mesh runs, and across a mid-fit streaming
checkpoint/resume; and ``ClusterConfig.validate()`` fires from every
front door (estimator fit, resolve_strategy, mesh_fit, serving engine).
"""
import os
import shutil

import numpy as np
import pytest

try:                            # hypothesis: CI-installed, optional locally —
    import hypothesis           # the deterministic sweep below always runs
    from hypothesis import given, strategies as st
    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=25,
        suppress_health_check=[hypothesis.HealthCheck.too_slow])
    hypothesis.settings.load_profile("ci")
except ImportError:             # pragma: no cover
    hypothesis = None

import jax.numpy as jnp

from repro.cluster import ClusterConfig, ClusterEngine, SphericalKMeans
from repro.core import StructuralParams, build_mean_index
from repro.core.assignment import assignment_step, _scan
from repro.core.lloyd import streaming_fit
from repro.core.update import (UB_DRIFT_EPS, drift_loosen, group_drift,
                               n_ub_groups, ub_group_size)
from repro.data import CorpusSpec, make_corpus
from repro.launch.mesh import make_test_mesh
from repro.sparse import DocStore, SparseDocs

NEW_MODES = ("bounds", "sketch", "bounds-esicp")


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(CorpusSpec(n_docs=400, vocab=512, nt_mean=20,
                                  n_topics=8, seed=0))


@pytest.fixture(scope="module")
def mesh_corpus():
    return make_corpus(CorpusSpec(n_docs=1024, vocab=768, nt_mean=30,
                                  n_topics=12, seed=9))


# ---------------------------------------------------------------------------
# Group-bound bracket (hypothesis).
# ---------------------------------------------------------------------------

def _make_case(b, p, d, k, t_th, n_drifts, scale, seed):
    rng = np.random.default_rng(seed)
    ids = np.sort(rng.integers(0, d, (b, p)), axis=1).astype(np.int32)
    vals = rng.random((b, p)).astype(np.float32)
    nnz = rng.integers(1, p + 1, b).astype(np.int32)
    for i in range(b):
        vals[i, nnz[i]:] = 0.0
        ids[i, nnz[i]:] = 0
    # Unit-norm docs (the production tf-idf → L2 pipeline guarantee): the
    # spherical bound math is about cosines, so similarities must BE
    # cosines.  Norm over the DENSE vector — duplicate ids accumulate.
    for i in range(b):
        dense = np.zeros(d)
        np.add.at(dense, ids[i, :nnz[i]], vals[i, :nnz[i]])
        vals[i] /= max(np.linalg.norm(dense), 1e-9)
    means = np.where(rng.random((k, d)) < 0.4, rng.random((k, d)), 0.0)
    means += 1e-3                                       # no zero rows
    means /= np.linalg.norm(means, axis=1, keepdims=True)
    docs = SparseDocs(ids=jnp.asarray(ids), vals=jnp.asarray(vals),
                      nnz=jnp.asarray(nnz), dim=d)
    return docs, means.astype(np.float32), t_th, n_drifts, scale, seed


if hypothesis is not None:
    @st.composite
    def bound_case(draw):
        b = draw(st.integers(2, 12))
        p = draw(st.integers(2, 10))
        d = draw(st.integers(8, 48))
        k = draw(st.integers(2, 24))      # crosses the UB_GROUPS=16 tier edge
        return _make_case(b, p, d, k, t_th=draw(st.integers(0, d)),
                          n_drifts=draw(st.integers(1, 3)),
                          scale=draw(st.floats(0.0, 1.5)),
                          seed=draw(st.integers(0, 2**31 - 1)))


def _true_group_max(sims, assign, k):
    """Per-group max of the non-assigned exact similarities, in numpy —
    independent of the production ``_group_bounds`` it checks."""
    sims = np.array(sims, np.float64)
    b = sims.shape[0]
    sims[np.arange(b), assign] = -np.inf
    gsz, g = ub_group_size(k), n_ub_groups(k)
    sims = np.pad(sims, ((0, 0), (0, g * gsz - k)), constant_values=-np.inf)
    return sims.reshape(b, g, gsz).max(axis=2)


def _check_bracket(case):
    """Refreshed bounds, drift-loosened through 1..3 consecutive center
    perturbations WITHOUT re-tightening, still bracket the true per-group
    best non-assigned similarity against the final means."""
    docs, means, t_th, n_drifts, scale, seed = case
    k = means.shape[0]
    params = StructuralParams(t_th=jnp.asarray(t_th, jnp.int32),
                              v_th=jnp.asarray(0.1, jnp.float32))
    index = build_mean_index(jnp.asarray(means), params)
    b = docs.n_docs
    sims0 = np.asarray(
        _scan(docs, index, jnp.zeros((b,), bool), mode="esicp")["sims"])
    assign = sims0.argmax(axis=1).astype(np.int32)
    rho_self = jnp.asarray(sims0.max(axis=1))
    res = assignment_step("bounds", docs, index, jnp.asarray(assign),
                          rho_self, jnp.zeros((b,), bool))
    assert (np.asarray(res.assign) == assign).all()     # already optimal

    ub = res.ub
    rng = np.random.default_rng(seed + 1)
    cur = means
    for _ in range(n_drifts):
        new = cur + scale * rng.normal(size=cur.shape).astype(np.float32) \
            * rng.random(k).astype(np.float32)[:, None]   # uneven per-center
        new /= np.maximum(np.linalg.norm(new, axis=1, keepdims=True), 1e-9)
        delta = group_drift(jnp.asarray(new.T), jnp.asarray(cur.T))
        ub = drift_loosen(ub, delta)
        cur = new

    index2 = build_mean_index(jnp.asarray(cur), params)
    sims2 = _scan(docs, index2, jnp.zeros((b,), bool), mode="esicp")["sims"]
    true = _true_group_max(sims2, assign, k)
    loose = np.asarray(ub)
    # The bracket: every loosened bound >= the true group max (the
    # UB_DRIFT_EPS slack absorbs the f32 arccos/cos round trip; direct
    # comparison, not subtraction — -inf - -inf would NaN on the singleton
    # assigned-only groups, where both sides are legitimately -inf).
    viol = true > loose + (n_drifts * UB_DRIFT_EPS + 1e-5)
    assert not viol.any(), float((true - loose)[viol].max())


@pytest.mark.parametrize("sweep_seed", range(12))
def test_group_bounds_bracket_seeded_sweep(sweep_seed):
    """Deterministic bracket sweep — runs with or without hypothesis."""
    rng = np.random.default_rng(1000 + sweep_seed)
    d = int(rng.integers(8, 48))
    _check_bracket(_make_case(
        b=int(rng.integers(2, 12)), p=int(rng.integers(2, 10)), d=d,
        k=int(rng.integers(2, 24)), t_th=int(rng.integers(0, d)),
        n_drifts=int(rng.integers(1, 4)), scale=float(rng.random() * 1.5),
        seed=int(rng.integers(0, 2**31 - 1))))


@pytest.mark.skipif(hypothesis is None, reason="hypothesis not installed")
def test_group_bounds_bracket_hypothesis():
    given(bound_case())(_check_bracket)()


def test_drift_loosen_passthrough_and_monotone():
    ub = jnp.asarray([[jnp.inf, 0.9, -jnp.inf, 0.0]], jnp.float32)
    delta = jnp.asarray([0.0, 0.3, 0.3, 0.3], jnp.float32)
    out = np.asarray(drift_loosen(ub, delta))
    assert np.isposinf(out[0, 0]) and np.isneginf(out[0, 2])
    assert out[0, 1] >= 0.9 and out[0, 3] >= 0.0    # loosening only


# ---------------------------------------------------------------------------
# Padding rows are inert.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["bounds", "bounds-esicp"])
def test_dead_rows_never_activate_bounds(algo):
    """The store/pad convention (ρ_self = 0, ub = 0) makes a dead row's
    group test 0 > 0 = False: zero candidates, zero Mult contribution."""
    rng = np.random.default_rng(4)
    b, p, d, k = 6, 8, 64, 12
    ids = np.sort(rng.integers(0, d, (b, p)), axis=1).astype(np.int32)
    vals = rng.random((b, p)).astype(np.float32)
    nnz = np.full(b, p, np.int32)
    nnz[4:] = 0                                        # two dead tail rows
    ids[4:] = 0
    vals[4:] = 0.0
    docs = SparseDocs(ids=jnp.asarray(ids), vals=jnp.asarray(vals),
                      nnz=jnp.asarray(nnz), dim=d)
    means = rng.random((k, d)).astype(np.float32)
    means /= np.linalg.norm(means, axis=1, keepdims=True)
    params = StructuralParams(t_th=jnp.asarray(d // 2, jnp.int32),
                              v_th=jnp.asarray(0.1, jnp.float32))
    index = build_mean_index(jnp.asarray(means), params)
    rho = jnp.where(jnp.arange(b) < 4, 0.5, 0.0).astype(jnp.float32)
    ub = jnp.where(jnp.arange(b)[:, None] < 4, jnp.inf, 0.0).astype(
        jnp.float32) * jnp.ones((1, n_ub_groups(k)))
    res = assignment_step(algo, docs, index, jnp.zeros((b,), jnp.int32),
                          rho, jnp.zeros((b,), bool), ub=ub)
    assert (np.asarray(res.n_candidates)[4:] == 0).all()
    assert not np.asarray(res.changed)[4:].any()
    live = SparseDocs(ids=docs.ids[:4], vals=docs.vals[:4], nnz=docs.nnz[:4],
                      dim=d)
    res_live = assignment_step(algo, live, index,
                               jnp.zeros((4,), jnp.int32), rho[:4],
                               jnp.zeros((4,), bool), ub=ub[:4])
    assert float(res.mult) == float(res_live.mult)


# ---------------------------------------------------------------------------
# Full-fit bit-identity to mivi, both backends.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("algo", NEW_MODES)
def test_full_fit_identical_to_mivi(corpus, backend, algo):
    docs, df, perm, topics = corpus
    iters = 20 if backend == "reference" else 6
    ref = SphericalKMeans(k=8, algo="mivi", max_iter=iters, batch_size=100,
                          seed=1, backend=backend).fit(docs, df=df)
    km = SphericalKMeans(k=8, algo=algo, max_iter=iters, batch_size=100,
                         seed=1, backend=backend).fit(docs, df=df)
    assert (km.labels_ == ref.labels_).all()
    assert km.n_iter_ == ref.n_iter_
    # Structural accounting guarantee: the bounds gate is free (it reads
    # the carried ub), so its Mult can never exceed the exhaustive scan.
    # sketch/bounds-esicp pay for their own pre-passes, which only win on
    # realistic corpora — that economics is the benchmark ratchet's job
    # (benchmarks/ratchet.py check_pruning), not a tiny-corpus invariant.
    if algo == "bounds":
        for h, hr in zip(km.history_, ref.history_):
            assert h["mult"] <= hr["mult"] * (1 + 1e-6), h["iteration"]


# ---------------------------------------------------------------------------
# Streaming: mid-fit checkpoint/resume with a bounded mode.
# ---------------------------------------------------------------------------

def test_streaming_resume_bounded_mode(corpus, tmp_path):
    docs, df, perm, topics = corpus
    store = DocStore.from_docs(docs, chunk_size=100)
    ckpt = str(tmp_path / "ckpt")
    full = streaming_fit(store, k=8, algo="bounds-esicp", max_iter=20,
                         batch_size=100, seed=1, df=df,
                         checkpoint_dir=ckpt, checkpoint_every=3)
    assert full.converged

    from repro.checkpoint.store import all_steps
    steps = all_steps(ckpt)
    mid = [s for s in steps if s % (store.n_chunks + 1) != 0]
    assert mid, "expected a surviving mid-epoch checkpoint"
    for s in steps:                    # rewind the run to the mid-epoch cut
        if s > mid[-1]:
            shutil.rmtree(os.path.join(ckpt, f"step_{s:08d}"))
    resumed = streaming_fit(store, k=8, algo="bounds-esicp", max_iter=20,
                            batch_size=100, seed=1, df=df,
                            checkpoint_dir=ckpt, resume=True)
    assert (resumed.assign == full.assign).all()
    assert resumed.n_iter == full.n_iter
    for hr, hn in zip(full.history, resumed.history):
        assert hr["mult"] == hn["mult"] and hr["n_changed"] == hn["n_changed"]

    # and streaming == resident for the same mode (exactness through the
    # chunked ub work-buffer + finalize drift-loosening)
    resident = SphericalKMeans(k=8, algo="bounds-esicp", max_iter=20,
                               batch_size=100, seed=1).fit(docs, df=df)
    assert (full.assign == np.asarray(resident.labels_)).all()


# ---------------------------------------------------------------------------
# Mesh runs stay exact.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", NEW_MODES)
def test_mesh_new_modes_match_single_device(mesh_corpus, algo):
    docs, df, perm, topics = mesh_corpus
    mesh = make_test_mesh((4, 2), ("data", "model"))
    ref = SphericalKMeans(k=16, algo="mivi", max_iter=12, batch_size=512,
                          seed=5).fit(docs, df=df)
    km = SphericalKMeans(k=16, algo=algo, max_iter=12, chunk_size=128,
                         mesh=mesh, seed=5).fit(docs, df=df)
    assert km.model_.strategy == "mesh"
    assert (km.labels_ == ref.labels_).all()


# ---------------------------------------------------------------------------
# ClusterConfig.validate() fires from every front door.
# ---------------------------------------------------------------------------

def test_validate_admits_new_modes():
    for algo in NEW_MODES:
        cfg = ClusterConfig(k=8, algo=algo).validate()
        assert cfg.algo == algo


def test_validate_from_estimator_fit(corpus):
    docs, df, perm, topics = corpus
    with pytest.raises(ValueError, match="unknown algorithm"):
        SphericalKMeans(k=8, algo="hamerly").fit(docs, df=df)


def test_validate_from_resolve_strategy():
    from repro.cluster.strategies import resolve_strategy
    with pytest.raises(ValueError, match="k must be"):
        resolve_strategy(ClusterConfig(k=0))


def test_validate_from_mesh_fit(mesh_corpus):
    from repro.distributed import mesh_fit
    docs, df, perm, topics = mesh_corpus
    mesh = make_test_mesh((4, 2), ("data", "model"))
    with pytest.raises(ValueError, match="mesh strategy"):
        mesh_fit(docs, 16, mesh, algo="ta-icp", max_iter=1, df=df)


def test_validate_from_serving_engine(corpus):
    docs, df, perm, topics = corpus
    km = SphericalKMeans(k=8, algo="bounds", max_iter=5, batch_size=100,
                         seed=1).fit(docs, df=df)
    with pytest.raises(ValueError, match="unknown backend"):
        ClusterEngine.from_model(km.model_, backend="vector-db")
    with pytest.raises(ValueError, match="batch_size"):
        ClusterEngine.from_model(km.model_, batch_size=0)
