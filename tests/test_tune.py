"""repro.tune — the cost-model-pruned kernel autotuner (ISSUE 6).

Covers the TunedConfig knob vector, the exact ``_pick_k_sup`` selection,
analytic pruning (>= 50% of candidates never timed), search determinism
under a fixed seed/budget, the TUNED_CACHE / FittedModel round-trip, and
the parity contract: tuned configs change launch geometry, never results —
bit-identical assignments tuned vs default across all six algorithms on
both backends.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import K_SUP_CAP, _pick_k_sup
from repro.sparse import SparseDocs
from repro.tune import TUNED_CACHE, DEFAULT_TUNED, TunedConfig, corpus_signature
from repro.tune.cost import KernelShape
from repro.tune.search import (SearchBudget, candidate_space,
                               search_tuned_config)


def _zipf_docs(n=256, p=16, d=256, seed=0):
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(1.3, size=(n, p)), d)
    ids = np.sort((d - ranks).astype(np.int32), axis=1)
    vals = rng.random((n, p)).astype(np.float32)
    return SparseDocs(ids=jnp.asarray(ids), vals=jnp.asarray(vals),
                      nnz=jnp.full((n,), p, jnp.int32), dim=d)


@pytest.fixture(autouse=True)
def _clean_cache():
    TUNED_CACHE.clear()
    yield
    TUNED_CACHE.clear()


# ---------------------------------------------------------------------------
# _pick_k_sup exactness (ISSUE 6 satellite: largest k_blk multiple <= cap).
# ---------------------------------------------------------------------------

def _exact_k_sup(kp, k_blk, cap):
    """Brute-force oracle: largest multiple of k_blk <= cap dividing kp,
    else gcd(kp, k_blk)."""
    best = [m for m in range(k_blk, cap + 1, k_blk) if kp % m == 0]
    return max(best) if best else (math.gcd(kp, k_blk) or k_blk)


@pytest.mark.parametrize("kp,k_blk,cap", [
    (1152, 128, 1024),   # 1152 = 9*128: 1024 doesn't divide, 384 does
    (2560, 128, 1024),   # largest divisor multiple is 640, not 512
    (3200, 64, 1024),    # 640 again, from a 64 ladder
    (2304, 768, 1024),   # k_blk itself > half the cap
    (4096, 2048, 1024),  # no multiple fits the cap -> gcd fallback
    (1024, 128, 1024),   # fits entirely
    (1920, 128, 96),     # cap below k_blk -> gcd fallback
    (1280, 256, 1000),   # awkward cap residue (1000 % 256 != 0)
    (896, 128, 512),     # 896 = 7*128: 512/384/256 don't divide, 448 does
])
def test_pick_k_sup_exact(kp, k_blk, cap):
    got = _pick_k_sup(kp, k_blk, None, cap=cap)
    want = _exact_k_sup(kp, k_blk, cap)
    assert got == want
    assert kp % got == 0


def test_pick_k_sup_explicit_and_default_cap():
    assert _pick_k_sup(1024, 128, 256) == 256          # explicit wins
    with pytest.raises(AssertionError):
        _pick_k_sup(1024, 128, 300)                    # must divide
    assert _pick_k_sup(512, 128, None) == 512          # <= K_SUP_CAP: whole K
    assert K_SUP_CAP == DEFAULT_TUNED.k_sup_cap


# ---------------------------------------------------------------------------
# TunedConfig + cache basics.
# ---------------------------------------------------------------------------

def test_tuned_config_validates_and_roundtrips():
    with pytest.raises(ValueError):
        TunedConfig(b_blk=12)
    with pytest.raises(ValueError):
        TunedConfig(d_blk=64)
    with pytest.raises(ValueError):
        TunedConfig(k_blk=128, k_sup_cap=64)
    cfg = TunedConfig(b_blk=64, d_blk=512, head_bytes=0, source="search")
    assert TunedConfig.from_dict(cfg.to_dict()) == cfg
    assert hash(cfg) == hash(cfg.replace())             # jit-static viable


def test_corpus_signature_buckets_regime():
    docs = _zipf_docs()
    sig = corpus_signature(docs.ids, docs.vals, dim=docs.dim, k=8)
    assert f"/d{docs.dim}/k8/" in sig
    # Same regime, slightly different row count in the same pow2 bucket.
    again = corpus_signature(docs.ids[:250], docs.vals[:250], dim=docs.dim,
                             k=8)
    assert sig == again
    cfg = TUNED_CACHE.put(sig, TunedConfig(b_blk=64, source="search"))
    assert cfg.signature == sig
    assert TUNED_CACHE.get(sig) == cfg


# ---------------------------------------------------------------------------
# The search: pruning fraction, determinism, budget accounting.
# ---------------------------------------------------------------------------

def test_search_prunes_majority_analytically():
    docs = _zipf_docs()
    timed = []

    def counting_measure(cfg):
        timed.append(cfg)
        return 1.0   # every survivor "measures" equal -> bound breaks ties

    budget = SearchBudget(max_timed=4, repeat=1, probe_rows=256)
    winner, stats = search_tuned_config(
        docs.ids, docs.vals, dim=docs.dim, k=16, budget=budget,
        measure=counting_measure)
    space = candidate_space(KernelShape(b=256, p=16, d=docs.dim, k=16))
    assert stats.n_candidates == len(space) > 8
    # The acceptance bar: at least half the space is discarded on the cost
    # model alone — only the budgeted head ever reaches wall-clock timing.
    assert stats.pruned_fraction >= 0.5
    assert stats.n_timed == len(timed) <= budget.max_timed
    assert stats.n_pruned == stats.n_candidates - stats.n_timed
    # The incumbent default is always among the timed candidates.
    assert any(c.source == "default" for c in timed)
    assert isinstance(winner, TunedConfig)


def test_search_deterministic_under_fixed_seed_and_budget():
    docs = _zipf_docs(seed=3)

    def analytic_measure(cfg):
        # Pure function of the candidate -> any wall-clock noise removed;
        # determinism of enumeration/pruning/tie-breaking is what's tested.
        return 1.0 / (cfg.b_blk * cfg.d_blk) + cfg.head_bytes * 1e-12

    budget = SearchBudget(max_timed=5, repeat=1, probe_rows=256)
    out = [search_tuned_config(docs.ids, docs.vals, dim=docs.dim, k=16,
                               budget=budget, seed=7,
                               measure=analytic_measure)
           for _ in range(2)]
    (w1, s1), (w2, s2) = out
    assert w1 == w2
    assert s1.to_dict() == s2.to_dict()
    assert [c for c, _ in s1.timed] == [c for c, _ in s2.timed]


def test_search_winner_beats_or_matches_default():
    docs = _zipf_docs()

    def analytic_measure(cfg):
        return 1.0 / (cfg.b_blk * cfg.d_blk)

    winner, stats = search_tuned_config(
        docs.ids, docs.vals, dim=docs.dim, k=16,
        budget=SearchBudget(max_timed=4, repeat=1, probe_rows=256),
        measure=analytic_measure)
    assert stats.best_measured_s <= stats.default_measured_s
    if winner != DEFAULT_TUNED.replace(source="default"):
        assert winner.source == "search"


# ---------------------------------------------------------------------------
# ensure_tuned / Backend.prepare / estimator threading.
# ---------------------------------------------------------------------------

def test_ensure_tuned_modes():
    from repro.tune.search import ensure_tuned

    docs = _zipf_docs()
    with pytest.raises(ValueError):
        ensure_tuned(docs, k=8, mode="always")
    assert ensure_tuned(docs, k=None, mode="search") is None
    assert ensure_tuned(docs, k=8, mode="cached") is None      # cold miss
    sig = corpus_signature(docs.ids, docs.vals, dim=docs.dim, k=8)
    seeded = TUNED_CACHE.put(sig, TunedConfig(b_blk=64, source="search"))
    assert ensure_tuned(docs, k=8, mode="cached") == seeded
    assert ensure_tuned(docs, k=8, mode="search") == seeded    # hit, no search


def test_prepare_carries_tuned_into_plan():
    from repro.core.backends import BACKENDS

    docs = _zipf_docs()
    sig = corpus_signature(docs.ids, docs.vals, dim=docs.dim, k=8)
    seeded = TUNED_CACHE.put(
        sig, TunedConfig(b_blk=64, d_blk=128, source="search"))
    plan = BACKENDS["pallas"].prepare(docs, k=8, tune="cached")
    assert plan.tuned == seeded
    assert plan.b_blk == 64 and plan.d_blk == 128
    # Reference backend: tuning is a no-op, never an error.
    assert BACKENDS["reference"].prepare(docs, k=8, tune="cached") is None
    # Off: plan built on defaults, no tuned payload.
    plain = BACKENDS["pallas"].prepare(docs)
    assert plain.tuned is None


def test_cluster_config_validates_tune():
    from repro.cluster import ClusterConfig

    ClusterConfig(k=4, tune="search").validate()
    with pytest.raises(ValueError):
        ClusterConfig(k=4, tune="aggressive").validate()


# ---------------------------------------------------------------------------
# Parity: tuned configs change launch geometry, never assignments.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["mivi", "icp", "es", "esicp", "ta-icp",
                                  "cs-icp"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_fit_parity_tuned_vs_default(algo, backend):
    from repro.core.lloyd import lloyd_fit

    docs = _zipf_docs(n=192, p=16, d=256, seed=1)
    k = 8
    base = lloyd_fit(docs, k=k, algo=algo, backend=backend, batch_size=192,
                     max_iter=3)
    # A decidedly non-default geometry, seeded as this corpus's winner.
    sig = corpus_signature(docs.ids, docs.vals, dim=docs.dim, k=k)
    TUNED_CACHE.put(sig, TunedConfig(b_blk=64, d_blk=128, k_sup_cap=128,
                                     head_bytes=1 << 20, source="search"))
    tuned = lloyd_fit(docs, k=k, algo=algo, backend=backend, batch_size=192,
                      max_iter=3, tune="cached")
    np.testing.assert_array_equal(base.assign, tuned.assign)
    if backend == "pallas":
        assert tuned.tuned is not None and tuned.tuned.b_blk == 64
    else:
        assert tuned.tuned is None


def test_fitted_model_roundtrips_tuned_config(tmp_path):
    from repro.cluster import SphericalKMeans
    from repro.cluster.model import FittedModel

    docs = _zipf_docs(n=192, p=16, d=256, seed=2)
    est = SphericalKMeans(
        8, algo="esicp", backend="pallas", max_iter=3, batch_size=192,
        tune="search",
        tune_budget=SearchBudget(max_timed=2, repeat=1, probe_rows=128))
    est.fit(docs)
    model = est.model_
    assert model.tuned is not None and model.tuned["signature"]
    model.save(str(tmp_path))

    TUNED_CACHE.clear()
    loaded = FittedModel.load(str(tmp_path))
    assert loaded.tuned == model.tuned
    # load reseeds the process cache: the next cached-mode fit reuses the
    # artifact's winner without searching.
    sig = model.tuned["signature"]
    assert TUNED_CACHE.get(sig) == TunedConfig.from_dict(model.tuned)
    again = SphericalKMeans(8, algo="esicp", backend="pallas", max_iter=3,
                            batch_size=192, tune="cached").fit(docs)
    np.testing.assert_array_equal(loaded.labels, again.labels_)


# ---------------------------------------------------------------------------
# The engine axis (ISSUE 10): per-engine knob spaces, cache regimes and
# search dispatch — a Pallas winner must never poison an XLA-blocked fit.
# ---------------------------------------------------------------------------

def test_tuned_config_engine_validates_and_roundtrips():
    from repro.tune import DEFAULT_XLA_TUNED, ENGINES, default_tuned

    with pytest.raises(ValueError):
        TunedConfig(engine="cuda")
    assert DEFAULT_TUNED.engine == "pallas"
    assert DEFAULT_XLA_TUNED.engine == "xla_blocked"
    assert DEFAULT_XLA_TUNED.head_bytes == 0      # head is a tuner opt-in
    for engine in ENGINES:
        cfg = default_tuned(engine)
        assert cfg.engine == engine
        assert TunedConfig.from_dict(cfg.to_dict()) == cfg
    # Pre-engine artifacts (no 'engine' key) load as the Pallas default.
    legacy = DEFAULT_TUNED.to_dict()
    legacy.pop("engine", None)
    assert TunedConfig.from_dict(legacy).engine == "pallas"


def test_engine_qualified_signature_isolates_cache_regimes():
    docs = _zipf_docs()
    sig_p = corpus_signature(docs.ids, docs.vals, dim=docs.dim, k=8)
    sig_x = corpus_signature(docs.ids, docs.vals, dim=docs.dim, k=8,
                             engine="xla_blocked")
    assert sig_p.endswith("/pallas")
    assert sig_x.endswith("/xla_blocked")
    assert sig_p != sig_x
    TUNED_CACHE.put(sig_p, TunedConfig(b_blk=64, source="search"))
    assert TUNED_CACHE.get(sig_x) is None


def test_candidate_space_xla_collapses_grid_knobs():
    """The XLA engine has no launch grid: its geometry key drops the
    B/K-block knobs, so the deduplicated space is the head-split points —
    far smaller than the Pallas grid, every candidate engine-tagged."""
    shape = KernelShape(b=256, p=16, d=1024, k=16)
    pal = candidate_space(shape)
    xla = candidate_space(shape, engine="xla_blocked")
    assert all(c.engine == "pallas" for c in pal)
    assert all(c.engine == "xla_blocked" for c in xla)
    assert xla[0] == TunedConfig(engine="xla_blocked", head_bytes=0)
    assert len(xla) < len(pal)


def test_ensure_tuned_engine_axis():
    from repro.tune.search import ensure_tuned

    docs = _zipf_docs()
    sig_p = corpus_signature(docs.ids, docs.vals, dim=docs.dim, k=8)
    seeded = TUNED_CACHE.put(sig_p, TunedConfig(b_blk=64, source="search"))
    # The pallas regime hits; the xla regime stays a cold miss.
    assert ensure_tuned(docs, k=8, mode="cached") == seeded
    assert ensure_tuned(docs, k=8, mode="cached",
                        engine="xla_blocked") is None
    # A searched xla winner is engine-tagged and cached under its own key.
    budget = SearchBudget(max_timed=2, repeat=1, probe_rows=128)
    win = ensure_tuned(docs, k=8, mode="search", budget=budget,
                       engine="xla_blocked")
    assert win.engine == "xla_blocked"
    assert win.signature.endswith("/xla_blocked")
    assert ensure_tuned(docs, k=8, mode="cached") == seeded   # undisturbed


def test_xla_search_times_xla_ops():
    """search_tuned_config(engine='xla_blocked') measures the XLA twins and
    returns an engine-tagged winner deterministically."""
    docs = _zipf_docs()
    budget = SearchBudget(max_timed=2, repeat=1, probe_rows=128)
    win, stats = search_tuned_config(docs.ids, docs.vals, dim=docs.dim,
                                     k=16, budget=budget,
                                     engine="xla_blocked")
    assert win.engine == "xla_blocked"
    assert stats.n_timed <= budget.max_timed
    assert stats.n_pruned == stats.n_candidates - stats.n_timed
    assert stats.best_measured_s > 0.0


def test_xla_prepare_plan_headless_by_default():
    """XlaBlockedBackend.prepare: engine default is a head-less plan (the
    head-slab GEMM must be earned through the measured search), while a
    cached engine winner with a head budget flows into the plan."""
    from repro.core.backends import BACKENDS

    docs = _zipf_docs()
    plain = BACKENDS["xla_blocked"].prepare(docs)
    assert plain.n_head == 0 and plain.tuned is None
    sig = corpus_signature(docs.ids, docs.vals, dim=docs.dim, k=8,
                           engine="xla_blocked")
    seeded = TUNED_CACHE.put(
        sig, TunedConfig(engine="xla_blocked", head_bytes=1 << 30,
                         source="search"))
    plan = BACKENDS["xla_blocked"].prepare(docs, k=8, tune="cached")
    assert plan.tuned == seeded
    assert plan.n_head > 0
