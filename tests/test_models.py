"""Per-architecture smoke tests (reduced configs) + decode/forward parity.

Each assigned architecture instantiates its reduced same-family config and
runs one forward + one train step on CPU, asserting output shapes and
finiteness.  For every family the KV-cache/SSM-state decode path must agree
with the teacher-forced forward pass token by token — the serving-corruption
canary.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import smoke_config, list_archs
from repro.models import (init_params, forward, lm_loss, init_cache,
                          decode_forward)
from repro.models.transformer import _logits
from repro.train import make_train_step, TrainConfig, adamw_init


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    b, s = 2, 32
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    fe = (jax.random.normal(key, (b, 8, cfg.d_model), jnp.float32)
          if cfg.modality != "text" else None)

    h = forward(params, toks, cfg, frontend_embeds=fe)
    assert h.shape == (b, s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))

    step = make_train_step(cfg, TrainConfig(microbatches=1))
    opt = adamw_init(params)
    p2, opt2, m = jax.jit(step)(params, opt, toks, jnp.roll(toks, -1, 1), fe)
    assert bool(jnp.isfinite(m["loss"]))
    assert float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["gemma-2b", "qwen2.5-32b", "xlstm-125m",
                                  "zamba2-2.7b", "mixtral-8x22b", "gemma3-1b"])
def test_decode_matches_forward(arch):
    """Greedy decode with caches == teacher-forced forward logits."""
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    b, s = 2, 16
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)

    h = forward(params, toks, cfg, remat=False)
    ref_logits = np.asarray(_logits(params, h, cfg)[..., :cfg.vocab])

    cache = init_cache(cfg, b, s)
    outs = []
    for pos in range(s):
        lg, cache = decode_forward(params, cache, toks[:, pos:pos + 1],
                                   jnp.asarray(pos), cfg)
        outs.append(np.asarray(lg[:, 0]))
    dec_logits = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec_logits, ref_logits, rtol=2e-3, atol=2e-3)


def test_rotating_window_cache_matches_full():
    """SWA rotating cache (L_c = window) == full cache with band mask."""
    import dataclasses
    from repro.models.config import ModelConfig, uniform_segments
    cfg = ModelConfig(name="swa-test", family="dense", d_model=32, n_heads=2,
                      n_kv_heads=1, d_ff=64, vocab=64,
                      segments=uniform_segments(2, window=6),
                      vocab_pad_to=64)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    b, s = 2, 24
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    h = forward(params, toks, cfg, remat=False)
    ref_logits = np.asarray(_logits(params, h, cfg)[..., :cfg.vocab])

    cache = init_cache(cfg, b, s)    # rotating: L_c = min(6, 24) = 6
    assert cache["seg0"]["pos0"]["k"].shape[2] == 6
    outs = []
    for pos in range(s):
        lg, cache = decode_forward(params, cache, toks[:, pos:pos + 1],
                                   jnp.asarray(pos), cfg)
        outs.append(np.asarray(lg[:, 0]))
    np.testing.assert_allclose(np.stack(outs, 1), ref_logits,
                               rtol=2e-3, atol=2e-3)


def test_microbatch_equivalence():
    """mb=1 and mb=4 produce the same update (grad accumulation exactness)."""
    cfg = smoke_config("gemma-2b")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    b, s = 8, 16
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    labels = jnp.roll(toks, -1, 1)
    outs = []
    for mb in (1, 4):
        step = make_train_step(cfg, TrainConfig(microbatches=mb))
        opt = adamw_init(params)
        p2, _, m = jax.jit(step)(params, opt, toks, labels)
        outs.append((p2, float(m["loss"])))
    (pa, la), (pb, lb) = outs
    assert abs(la - lb) < 1e-4
    for xa, xb in zip(jax.tree_util.tree_leaves(pa),
                      jax.tree_util.tree_leaves(pb)):
        np.testing.assert_allclose(np.asarray(xa), np.asarray(xb),
                                   rtol=1e-4, atol=1e-5)


def test_loss_decreases():
    cfg = smoke_config("musicgen-large")
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key)
    b, s = 4, 32
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    labels = jnp.roll(toks, -1, 1)
    fe = jax.random.normal(key, (b, 8, cfg.d_model), jnp.float32)
    step = jax.jit(make_train_step(cfg, TrainConfig()))
    opt = adamw_init(params)
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, toks, labels, fe)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_int8_kv_cache_close_to_forward():
    """Quantized KV cache (§Perf variant) stays within quantization error."""
    import dataclasses
    cfg = dataclasses.replace(smoke_config("qwen2.5-32b"), kv_dtype="int8")
    key = jax.random.PRNGKey(5)
    params = init_params(cfg, key)
    b, s = 2, 12
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    h = forward(params, toks, cfg, remat=False)
    from repro.models.transformer import _logits as _lg
    ref = np.asarray(_lg(params, h, cfg)[..., :cfg.vocab])
    cache = init_cache(cfg, b, s)
    outs = []
    for pos in range(s):
        lg, cache = decode_forward(params, cache, toks[:, pos:pos + 1],
                                   jnp.asarray(pos), cfg)
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, 1)
    # int8 quantization error ~1%: logits agree loosely but argmax agrees
    assert np.abs(dec - ref).max() < 0.15
    assert (dec.argmax(-1) == ref.argmax(-1)).mean() > 0.95


def test_flash_path_matches_jnp_attention():
    """The Pallas flash route (TPU hot path) == the jnp attention path."""
    from repro.models.layers import set_use_flash
    cfg = smoke_config("qwen2.5-32b")      # GQA: exercises the kv repeat
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)
    b, s = 2, 64
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    ref_h = forward(params, toks, cfg, remat=False)
    set_use_flash(True)
    try:
        flash_h = forward(params, toks, cfg, remat=False)
    finally:
        set_use_flash(False)
    np.testing.assert_allclose(np.asarray(flash_h), np.asarray(ref_h),
                               rtol=2e-3, atol=2e-3)
