"""Snapshot of the ``repro.cluster`` public surface.

Future PRs that change ``__all__``, a public signature, or the config/model
field sets must edit this file in the same commit — the API changes
deliberately, never accidentally.  (DESIGN.md §9 documents the surface and
the deprecation policy these snapshots enforce.)
"""
import dataclasses
import inspect

import repro.cluster as rc

EXPECTED_ALL = [
    "ClusterConfig",
    "ClusterEngine",
    "FittedModel",
    "MeshStrategy",
    "STRATEGIES",
    "SingleHostStrategy",
    "SphericalKMeans",
    "StreamingStrategy",
    "TwoLevelFittedModel",
    "TwoLevelStrategy",
    "classify_docs",
    "classify_docs_routed",
    "fit",
    "load_model",
    "resolve_strategy",
    "transform_docs",
    "two_level_from_means",
]

# The execution-strategy registry (satellite of the out-of-core PR): the
# streaming runtime is a first-class strategy, and unknown names fail with
# the full valid list.  The two-level IVF fit (million-cluster PR) rides
# the same registry.
EXPECTED_STRATEGIES = ["mesh", "single_host", "streaming", "two_level"]

EXPECTED_SIGNATURES = {
    "SphericalKMeans.__init__":
        "(self, k: 'int', *, algo: 'str' = 'esicp', params='auto', "
        "backend: 'str' = 'reference', batch_size: 'int' = 4096, "
        "max_iter: 'int' = 60, est_grid: 'EstGrid | None' = None, "
        "est_iters=(1, 2), seed: 'int' = 0, mesh=None, "
        "chunk_size: 'int' = 1024, algo_mode: 'str' = 'full', "
        "checkpoint_dir: 'str | None' = None, "
        "checkpoint_every: 'int' = 5, tune: 'str' = 'off', "
        "tune_budget=None, coarse_k: 'int | None' = None, "
        "n_probe: 'int' = 1)",
    "SphericalKMeans.fit": "(self, docs, df=None) -> 'SphericalKMeans'",
    "SphericalKMeans.predict": "(self, docs) -> 'np.ndarray'",
    "SphericalKMeans.transform": "(self, docs) -> 'np.ndarray'",
    "SphericalKMeans.score": "(self, docs) -> 'float'",
    "SphericalKMeans.fit_predict": "(self, docs, df=None) -> 'np.ndarray'",
    "SphericalKMeans.fit_result": "(self) -> 'LloydResult'",
    "SphericalKMeans.from_config":
        "(cls, config: 'ClusterConfig') -> 'SphericalKMeans'",
    "FittedModel.save": "(self, directory: 'str', *, step: 'int' = 0) -> 'str'",
    "FittedModel.load":
        "(cls, directory: 'str', *, step: 'int | None' = None) "
        "-> 'FittedModel'",
    "FittedModel.predict":
        "(self, docs, *, batch_size: 'int' = 4096) -> 'np.ndarray'",
    "FittedModel.transform":
        "(self, docs, *, batch_size: 'int' = 4096) -> 'np.ndarray'",
    "FittedModel.score":
        "(self, docs, *, batch_size: 'int' = 4096) -> 'float'",
    "ClusterEngine.__init__":
        "(self, index=None, *, model=None, backend: 'str | None' = None, "
        "batch_size: 'int' = 4096)",
    "ClusterEngine.from_model":
        "(cls, model, *, backend: 'str | None' = None, "
        "batch_size: 'int' = 4096) -> 'ClusterEngine'",
    "ClusterEngine.to_model": "(self)",
    "ClusterEngine.classify":
        "(self, docs, *, n_probe: 'int | None' = None)",
    "ClusterEngine.refit": "(self, docs, *, n_iter: 'int' = 1)",
    "fit": "(docs, config: 'ClusterConfig', *, df=None) -> 'FittedModel'",
    "load_model":
        "(directory: 'str', *, step: 'int | None' = None) -> 'FittedModel'",
    "classify_docs":
        "(index, docs, *, backend: 'str' = 'auto', "
        "batch_size: 'int' = 4096)",
    "transform_docs":
        "(index, docs, *, backend: 'str' = 'auto', "
        "batch_size: 'int' = 4096)",
}

EXPECTED_CONFIG_FIELDS = [
    "k", "algo", "backend", "params", "batch_size", "chunk_size", "max_iter",
    "est_grid", "est_iters", "seed", "mesh", "algo_mode", "checkpoint_dir",
    "checkpoint_every", "tune", "tune_budget", "coarse_k", "n_probe",
]

EXPECTED_MODEL_FIELDS = [
    "index", "labels", "rho_self", "history", "converged", "n_iter", "algo",
    "backend", "strategy", "cursor", "tuned",
]


def _resolve(dotted):
    obj = rc
    owner = None
    for part in dotted.split("."):
        owner, obj = obj, inspect.getattr_static(obj, part)
    return owner, obj


def test_public_all_snapshot():
    assert rc.__all__ == EXPECTED_ALL
    for name in rc.__all__:
        assert hasattr(rc, name)


def test_public_signatures_snapshot():
    for dotted, expected in EXPECTED_SIGNATURES.items():
        owner, obj = _resolve(dotted)
        if isinstance(obj, classmethod):
            obj = obj.__func__
        assert str(inspect.signature(obj)) == expected, dotted


def test_config_and_model_fields_snapshot():
    assert [f.name for f in dataclasses.fields(rc.ClusterConfig)] \
        == EXPECTED_CONFIG_FIELDS
    assert [f.name for f in dataclasses.fields(rc.FittedModel)] \
        == EXPECTED_MODEL_FIELDS


def test_strategy_registry_snapshot_and_error_lists_valid_names():
    """The registry holds exactly the four runtimes, and resolving an
    unknown strategy names every valid one in the error (deprecation
    hygiene: callers learn the streaming runtime exists)."""
    import pytest

    assert sorted(rc.STRATEGIES) == EXPECTED_STRATEGIES
    for name, strategy in rc.STRATEGIES.items():
        assert strategy.name == name

    class _BogusConfig:          # e.g. a subclass overriding .strategy
        strategy = "async-parameter-server"

    with pytest.raises(ValueError) as ei:
        rc.resolve_strategy(_BogusConfig())
    for name in EXPECTED_STRATEGIES:
        assert name in str(ei.value)


def test_core_reexport_is_the_same_estimator():
    """The historical import path stays the canonical class."""
    import repro.core
    from repro.core.lloyd import SphericalKMeans as via_lloyd

    assert repro.core.SphericalKMeans is rc.SphericalKMeans
    assert via_lloyd is rc.SphericalKMeans
