"""Hypothesis sweep of NON-block-aligned shapes through every clustering
wrapper in kernels/ops.py (ISSUE 5 satellite) AND their compiled
kernels/xla_blocked.py twins (ISSUE 10 satellite).

The wrappers promise: pad to block multiples, launch, slice back — for ANY
logical (B, K, D, P), including P that is not an 8-multiple (the kernels'
one hard alignment) and B/K/D that straddle block boundaries, with or
without a prepared plan, with or without the fused diagnostics.  This file
pins that padding/slicing contract against the pure-jnp oracles so a grid
or BlockSpec change can never silently narrow it.  The xla_blocked twins
ride the same ragged cases (their internal padding is the P-chunk split +
the head-plan D padding) and accept the Pallas geometry kwargs as inert
compatibility arguments — asserted here by passing them.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

import jax.numpy as jnp

from repro.kernels import (sparse_sim, esicp_gather, esicp_filter,
                           segment_update, rho_gather, ref)
from repro.kernels import xla_blocked as xb
from repro.kernels.plan import prepare_plan

hypothesis.settings.register_profile(
    "kernel-pad", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernel-pad")

# Small blocks so modest shapes straddle many block boundaries.
BLK = dict(b_blk=32, k_blk=32, d_blk=64)


@st.composite
def ragged_case(draw):
    b = draw(st.integers(1, 70))
    p = draw(st.integers(1, 19))           # includes every P % 8 residue
    d = draw(st.integers(3, 200))
    k = draw(st.integers(1, 70))
    seed = draw(st.integers(0, 2**31 - 1))
    use_plan = draw(st.booleans())
    rng = np.random.default_rng(seed)
    ids = np.sort(rng.integers(0, d, (b, p)), axis=1).astype(np.int32)
    vals = rng.random((b, p)).astype(np.float32)
    nnz = rng.integers(1, p + 1, b)
    for i in range(b):
        vals[i, nnz[i]:] = 0.0
        ids[i, nnz[i]:] = 0
    means_t = np.where(rng.random((d, k)) < 0.3,
                       rng.random((d, k)), 0.0).astype(np.float32)
    # includes the out-of-range padding convention assign == k
    assign = rng.integers(0, k + 1, b).astype(np.int32)
    t_th = draw(st.integers(0, d))
    v_th = draw(st.floats(0.05, 0.95))
    plan = None
    if use_plan:
        plan = prepare_plan(ids, vals, dim=d, b_blk=BLK["b_blk"],
                            d_blk=BLK["d_blk"], head_bytes=1 << 30)
    return (jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(means_t),
            jnp.asarray(assign), t_th, v_th, plan)


@given(ragged_case())
def test_sparse_sim_any_shape(case):
    ids, vals, means_t, assign, t_th, v_th, plan = case
    sims, counts = sparse_sim(ids, vals, means_t, plan=plan, diag=True, **BLK)
    assert sims.shape == (ids.shape[0], means_t.shape[1])
    np.testing.assert_allclose(np.asarray(sims),
                               np.asarray(ref.sparse_sim(ids, vals, means_t)),
                               rtol=1e-4, atol=1e-4)
    live01 = (np.asarray(vals) != 0).astype(np.float32)
    expc = ref.sparse_sim(ids, jnp.asarray(live01),
                          (means_t > 0).astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(counts), np.asarray(expc),
                               rtol=1e-4, atol=1e-4)


@given(ragged_case())
def test_esicp_gather_any_shape(case):
    ids, vals, means_t, assign, t_th, v_th, plan = case
    r12, y, sims = esicp_gather(ids, vals, means_t, t_th, v_th, plan=plan,
                                with_sims=True, **BLK)
    e12, ey = ref.esicp_gather(ids, vals, means_t, t_th, v_th)
    np.testing.assert_allclose(np.asarray(r12), np.asarray(e12),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ey),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sims),
                               np.asarray(ref.sparse_sim(ids, vals, means_t)),
                               rtol=1e-4, atol=1e-4)


@given(ragged_case())
def test_esicp_filter_any_shape(case):
    ids, vals, means_t, assign, t_th, v_th, plan = case
    b, k = ids.shape[0], means_t.shape[1]
    rng = np.random.default_rng(0)
    rho12 = jnp.asarray(rng.random((b, k)).astype(np.float32))
    y = jnp.asarray(rng.random((b, k)).astype(np.float32))
    rho_max = jnp.asarray(rng.random(b).astype(np.float32))
    col_ok = jnp.asarray(rng.random((b, k)) < 0.7)
    m, c = esicp_filter(rho12, y, rho_max, col_ok, v_th,
                        b_blk=BLK["b_blk"], k_blk=BLK["k_blk"])
    em, ec = ref.esicp_filter(rho12, y, rho_max, col_ok, v_th)
    assert np.array_equal(np.asarray(m), np.asarray(em))
    assert np.array_equal(np.asarray(c), np.asarray(ec))


@given(ragged_case())
def test_segment_update_any_shape(case):
    ids, vals, means_t, assign, t_th, v_th, plan = case
    k, d = means_t.shape[1], means_t.shape[0]
    lam = segment_update(assign, ids, vals, k=k, d=d, plan=plan, **BLK)
    assert lam.shape == (k, d)
    x = np.asarray(ref.densify(ids, vals, d))
    exp = np.zeros((k, d), np.float32)
    for i, a in enumerate(np.asarray(assign)):
        if a < k:                       # assign == k rows contribute nothing
            exp[a] += x[i]
    np.testing.assert_allclose(np.asarray(lam), exp, rtol=1e-4, atol=1e-4)


@given(ragged_case())
def test_rho_gather_any_shape(case):
    ids, vals, means_t, assign, t_th, v_th, plan = case
    rho = rho_gather(assign, ids, vals, means_t, plan=plan, **BLK)
    exp = ref.rho_gather(assign, ids, vals, means_t)
    np.testing.assert_allclose(np.asarray(rho), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(rho)[np.asarray(assign) == means_t.shape[1]]
            == 0.0).all()


# ---------------------------------------------------------------------------
# xla_blocked twins: same ragged cases, same oracles, compiled XLA engine.
# The ragged plans carry head slabs (head_bytes=1<<30) but no count twins,
# so diag calls exercise the layout-mismatch fallback too.
# ---------------------------------------------------------------------------

@given(ragged_case())
def test_xla_sparse_sim_any_shape(case):
    ids, vals, means_t, assign, t_th, v_th, plan = case
    sims, counts = xb.sparse_sim(ids, vals, means_t, plan=plan, diag=True,
                                 **BLK)
    assert sims.shape == (ids.shape[0], means_t.shape[1])
    np.testing.assert_allclose(np.asarray(sims),
                               np.asarray(ref.sparse_sim(ids, vals, means_t)),
                               rtol=1e-4, atol=1e-4)
    live01 = (np.asarray(vals) != 0).astype(np.float32)
    expc = ref.sparse_sim(ids, jnp.asarray(live01),
                          (means_t > 0).astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(counts), np.asarray(expc),
                               rtol=1e-4, atol=1e-4)


@given(ragged_case())
def test_xla_esicp_gather_any_shape(case):
    ids, vals, means_t, assign, t_th, v_th, plan = case
    r12, y, sims = xb.esicp_gather(ids, vals, means_t, t_th, v_th, plan=plan,
                                   with_sims=True, **BLK)
    e12, ey = ref.esicp_gather(ids, vals, means_t, t_th, v_th)
    np.testing.assert_allclose(np.asarray(r12), np.asarray(e12),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ey),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sims),
                               np.asarray(ref.sparse_sim(ids, vals, means_t)),
                               rtol=1e-4, atol=1e-4)


@given(ragged_case())
def test_xla_esicp_gather_per_object_threshold(case):
    """The TA form (v_ta per object) — natively compiled in this engine;
    the head path must stay disengaged (asserted via exactness alone)."""
    ids, vals, means_t, assign, t_th, v_th, plan = case
    rng = np.random.default_rng(7)
    v_ta = rng.random(ids.shape[0]).astype(np.float32)
    r12, y = xb.esicp_gather(ids, vals, means_t, t_th, v_th,
                             v_ta=jnp.asarray(v_ta), plan=plan, **BLK)
    idn, vn, mt = np.asarray(ids), np.asarray(vals), np.asarray(means_t)
    rows = mt[idn]                                    # (B, P, K)
    tail = (idn >= t_th)[..., None]
    hi = rows >= v_ta[:, None, None]
    exact = np.where(tail, hi, True)
    e12 = np.sum(np.where(exact, vn[..., None] * rows, 0.0), axis=1)
    ey = np.sum(np.where(tail & ~hi, vn[..., None], 0.0), axis=1)
    np.testing.assert_allclose(np.asarray(r12), e12, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), ey, rtol=1e-4, atol=1e-4)


@given(ragged_case())
def test_xla_cs_gather_any_shape(case):
    """The fused CS op vs slot-semantics oracles: rho1 drops tail-slot
    contributions, sq sums means² over every slot with id >= t_th — live
    or dead (the reference scan's dead-slot quirk, which the op's internal
    chunk padding must NOT add to)."""
    ids, vals, means_t, assign, t_th, v_th, plan = case
    sims, rho1, sq, counts = xb.cs_gather(ids, vals, means_t, t_th,
                                          plan=plan, diag=True)
    np.testing.assert_allclose(np.asarray(sims),
                               np.asarray(ref.sparse_sim(ids, vals, means_t)),
                               rtol=1e-4, atol=1e-4)
    head_vals = jnp.where(ids >= t_th, 0.0, vals)
    np.testing.assert_allclose(
        np.asarray(rho1),
        np.asarray(ref.sparse_sim(ids, head_vals, means_t)),
        rtol=1e-4, atol=1e-4)
    tail01 = (np.asarray(ids) >= t_th).astype(np.float32)  # per SLOT, not live
    np.testing.assert_allclose(
        np.asarray(sq),
        np.asarray(ref.sparse_sim(ids, jnp.asarray(tail01), means_t ** 2)),
        rtol=1e-4, atol=1e-4)
    live01 = (np.asarray(vals) != 0).astype(np.float32)
    expc = ref.sparse_sim(ids, jnp.asarray(live01),
                          (means_t > 0).astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(counts), np.asarray(expc),
                               rtol=1e-4, atol=1e-4)


@given(ragged_case())
def test_xla_segment_update_any_shape(case):
    ids, vals, means_t, assign, t_th, v_th, plan = case
    k, d = means_t.shape[1], means_t.shape[0]
    lam = xb.segment_update(assign, ids, vals, k=k, d=d, plan=plan, **BLK)
    assert lam.shape == (k, d)
    np.testing.assert_allclose(
        np.asarray(lam), np.asarray(ref.segment_update(assign, ids, vals,
                                                       k, d)),
        rtol=1e-4, atol=1e-4)


@given(ragged_case())
def test_xla_rho_gather_any_shape(case):
    ids, vals, means_t, assign, t_th, v_th, plan = case
    rho = xb.rho_gather(assign, ids, vals, means_t, plan=plan, **BLK)
    exp = ref.rho_gather(assign, ids, vals, means_t)
    np.testing.assert_allclose(np.asarray(rho), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(rho)[np.asarray(assign) == means_t.shape[1]]
            == 0.0).all()
