"""Two-level IVF fit + coarse-routed classify (DESIGN.md §13).

Acceptance criteria under test (million-cluster PR):

  * ``ClusterConfig(coarse_k=K_c)`` routes through the ``two_level``
    strategy and yields a nested :class:`TwoLevelFittedModel` whose fine
    index concatenates per-cell blocks (Σ cell_sizes = K_eff, every cell
    >= 1) with global labels;
  * the routed classify at ``n_probe=1`` scores at most K_c + max-cell-size
    centroids per object — asserted via the ``scored`` Mult counters, not
    assumed;
  * ``n_probe = K_c`` is bit-identical to the flat scan over ``model.index``
    on BOTH backends (it delegates to the flat path);
  * on the general (gather-TAAT) path, every winning similarity is bitwise
    equal to the flat scan's — the routed epoch runs the same float32
    additions in the same order, so approximation lives only in the
    candidate set, never in the arithmetic;
  * the nested artifact save/loads through the checkpoint store (format
    dispatch in ``FittedModel.load``) and serves through ClusterServer with
    results bit-identical to the direct routed classify;
  * every front door (ClusterConfig, SphericalKMeans, module ``fit``)
    rejects malformed two-level knobs with actionable errors.
"""
import numpy as np
import pytest

from repro.cluster import (ClusterConfig, ClusterEngine, FittedModel,
                           SphericalKMeans, TwoLevelFittedModel, classify_docs,
                           classify_docs_routed, fit, load_model,
                           resolve_strategy, two_level_from_means)
from repro.cluster.two_level import _allocate_fine_k
from repro.data import CorpusSpec, make_corpus
from repro.sparse import DocStore

K, K_C = 24, 4


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(CorpusSpec(n_docs=600, vocab=512, nt_mean=20,
                                  n_topics=12, seed=0))


@pytest.fixture(scope="module")
def two_level(corpus):
    docs, df, perm, topics = corpus
    model = fit(docs, ClusterConfig(k=K, coarse_k=K_C, n_probe=1, max_iter=12,
                                    batch_size=200, seed=1), df=df)
    return docs, df, model


# ---------------------------------------------------------------------------
# Fit: nested artifact shape and label invariants.
# ---------------------------------------------------------------------------

def test_two_level_fit_builds_nested_model(two_level):
    docs, df, model = two_level
    assert isinstance(model, TwoLevelFittedModel)
    assert model.strategy == "two_level"
    assert model.coarse_k == K_C
    assert model.coarse_index.k == K_C
    # fine blocks: one per cell, every cell holds >= 1 centroid, and the
    # concatenated index is exactly the sum of the blocks
    assert model.cell_sizes.shape == (K_C,)
    assert (model.cell_sizes >= 1).all()
    assert int(model.cell_sizes.sum()) == model.index.k
    assert len(model.cell_meta) == K_C
    assert sum(m["n_docs"] for m in model.cell_meta) == docs.n_docs
    # labels live in the GLOBAL fine space and each row's label falls in
    # its own cell's block [start, start + size)
    labels = model.labels
    assert labels.shape == (docs.n_docs,)
    assert labels.min() >= 0 and labels.max() < model.index.k
    starts = model.cell_starts
    a_coarse, _ = classify_docs(model.coarse_index, docs,
                                backend=model.backend)
    cell_of_label = np.searchsorted(starts, labels, side="right") - 1
    assert (cell_of_label == a_coarse).all()


def test_allocate_fine_k_invariants():
    sizes = np.asarray([0, 1, 7, 100, 3])
    alloc = _allocate_fine_k(sizes, 50)
    assert (alloc >= 1).all()                       # empty cells keep 1
    assert (alloc <= np.maximum(sizes, 1)).all()    # never over population
    assert int(alloc.sum()) == min(50, int(np.maximum(sizes, 1).sum()))
    # deterministic
    assert (alloc == _allocate_fine_k(sizes, 50)).all()
    # k below the cell count still gives every cell its floor of 1
    tiny = _allocate_fine_k(np.asarray([5, 5, 5]), 2)
    assert (tiny == 1).all()


# ---------------------------------------------------------------------------
# Routed classify: exactness, bitwise identity, Mult counters.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_n_probe_all_is_bitwise_flat(two_level, backend):
    """n_probe = K_c probes every cell — it IS the flat scan (delegation),
    so assign AND sims are bitwise equal on every backend."""
    docs, df, model = two_level
    a_flat, s_flat = classify_docs(model.index, docs, backend=backend,
                                   batch_size=200)
    a, s = classify_docs_routed(model, docs, n_probe=K_C, backend=backend,
                                batch_size=200)
    assert (a == a_flat).all()
    assert (s == s_flat).all()


def test_routed_winning_sims_are_bitwise_flat(two_level):
    """General path (n_probe < K_c): whenever the routed argmax agrees with
    the flat one, the winning similarity is BITWISE equal — the gather-TAAT
    epoch adds the same float32 terms in the same order as the flat scan."""
    docs, df, model = two_level
    a_flat, s_flat = classify_docs(model.index, docs, backend="reference",
                                   batch_size=200)
    a, s = classify_docs_routed(model, docs, n_probe=1, backend="reference",
                                batch_size=200)
    hit = a == a_flat
    assert hit.mean() > 0.9                        # sharp-topic corpus
    assert (s[hit] == s_flat[hit]).all()
    # misses can only score LOWER than the true winner (candidate set
    # misses the argmax, never mis-scores it)
    assert (s[~hit] <= s_flat[~hit]).all()


def test_scored_counter_respects_candidate_bound(two_level):
    """The Mult accounting hook: scored[i] = K_c + Σ probed cell sizes,
    bounded by K_c + max cell size at n_probe=1 — far below K_eff."""
    docs, df, model = two_level
    _, _, scored = classify_docs_routed(model, docs, n_probe=1,
                                        backend="reference", batch_size=200,
                                        with_stats=True)
    cmax = int(model.cell_sizes.max())
    assert scored.max() <= K_C + cmax
    assert scored.min() >= K_C + int(model.cell_sizes.min())
    # delegation reports the honest exhaustive count
    _, _, sc_all = classify_docs_routed(model, docs, n_probe=K_C,
                                        backend="reference", batch_size=200,
                                        with_stats=True)
    assert (sc_all == model.index.k).all()


def test_predict_uses_model_default_n_probe(two_level):
    docs, df, model = two_level
    a_routed, _ = classify_docs_routed(model, docs, n_probe=1, batch_size=200)
    assert (model.predict(docs, batch_size=200) == a_routed).all()
    assert np.isfinite(model.score(docs, batch_size=200))


def test_n_probe_validation(two_level):
    docs, df, model = two_level
    for bad in (0, K_C + 1, -3):
        with pytest.raises(ValueError, match="n_probe"):
            classify_docs_routed(model, docs, n_probe=bad)


# ---------------------------------------------------------------------------
# DocStore: two-level fit and routed classify over chunks.
# ---------------------------------------------------------------------------

def test_two_level_fit_over_store_matches_resident(two_level):
    """A non-chunk-aligned DocStore fit routes coarse+fine levels through
    the streaming runtime and lands on the resident clustering; the routed
    classify over the store equals the resident routed classify."""
    docs, df, model = two_level
    store = DocStore.from_docs(docs, chunk_size=144)     # 600 % 144 != 0
    km = SphericalKMeans(k=K, coarse_k=K_C, max_iter=12, batch_size=200,
                         seed=1).fit(store, df=df)
    smodel = km.model_
    assert isinstance(smodel, TwoLevelFittedModel)
    assert (smodel.labels == model.labels).all()
    a_res, s_res = classify_docs_routed(smodel, docs, batch_size=200)
    a_st, s_st = classify_docs_routed(smodel, store, batch_size=200)
    assert (a_st == a_res).all()
    assert (s_st == s_res).all()


# ---------------------------------------------------------------------------
# Artifact: save/load round-trip through the checkpoint store.
# ---------------------------------------------------------------------------

def test_save_load_round_trip(two_level, tmp_path):
    docs, df, model = two_level
    path = str(tmp_path / "nested")
    model.save(path)
    loaded = load_model(path)                      # format dispatch
    assert type(loaded) is TwoLevelFittedModel
    assert loaded.coarse_k == K_C and loaded.n_probe == model.n_probe
    assert (loaded.cell_sizes == model.cell_sizes).all()
    assert loaded.cell_meta == model.cell_meta
    np.testing.assert_array_equal(
        np.asarray(loaded.index.means_t), np.asarray(model.index.means_t))
    np.testing.assert_array_equal(
        np.asarray(loaded.coarse_index.means_t),
        np.asarray(model.coarse_index.means_t))
    a0, s0 = classify_docs_routed(model, docs, batch_size=200)
    a1, s1 = classify_docs_routed(loaded, docs, batch_size=200)
    assert (a0 == a1).all() and (s0 == s1).all()
    # FittedModel.load dispatches too (cls is FittedModel)
    assert type(FittedModel.load(path)) is TwoLevelFittedModel


# ---------------------------------------------------------------------------
# Engine + serving plane.
# ---------------------------------------------------------------------------

def test_engine_routes_and_guards_refit(two_level):
    docs, df, model = two_level
    engine = ClusterEngine.from_model(model)
    a_ref, s_ref = classify_docs_routed(model, docs, batch_size=4096)
    a, s = engine.classify(docs)
    assert (a == a_ref).all() and (s == s_ref).all()
    # per-call n_probe override; K_c == flat
    a_flat, s_flat = classify_docs(model.index, docs)
    a2, s2 = engine.classify(docs, n_probe=K_C)
    assert (a2 == a_flat).all() and (s2 == s_flat).all()
    with pytest.raises(NotImplementedError, match="coarse"):
        engine.refit(docs)
    # flat engines reject the two-level-only knob instead of ignoring it
    flat = fit(docs, ClusterConfig(k=8, max_iter=4, batch_size=200, seed=1),
               df=df)
    with pytest.raises(ValueError, match="n_probe"):
        ClusterEngine.from_model(flat).classify(docs, n_probe=2)


def test_served_routed_classify_is_bit_identical(two_level):
    from repro.serve import ClusterServer

    docs, df, model = two_level
    a_ref, s_ref = classify_docs_routed(model, docs, batch_size=4096)
    rows = (np.asarray(docs.ids), np.asarray(docs.vals), np.asarray(docs.nnz))
    with ClusterServer(max_live_batches=2) as srv:
        srv.load("ivf", model, batch_sizes=(64, 256))
        a, s = srv.classify("ivf", rows)
    assert (a == a_ref).all()
    assert (s == s_ref).all()


# ---------------------------------------------------------------------------
# two_level_from_means (the benchmark/warm-start entry point).
# ---------------------------------------------------------------------------

def test_from_means_wraps_vectors_as_fine_level(corpus):
    docs, df, perm, topics = corpus
    model = two_level_from_means(docs, 6, n_probe=1, max_iter=5)
    assert isinstance(model, TwoLevelFittedModel)
    assert model.coarse_k == 6
    assert model.index.k >= docs.n_docs            # + one per empty cell
    assert int(model.cell_sizes.sum()) == model.index.k
    # every supplied vector IS a fine centroid: self-classification at
    # n_probe=K_c finds a unit-similarity winner
    _, s = classify_docs_routed(model, docs, n_probe=6)
    np.testing.assert_allclose(s, 1.0, atol=1e-5)


# ---------------------------------------------------------------------------
# Validation at every front door.
# ---------------------------------------------------------------------------

def test_config_validates_two_level_knobs():
    with pytest.raises(ValueError, match="coarse_k must be >= 2"):
        ClusterConfig(k=8, coarse_k=1).validate()
    with pytest.raises(ValueError, match="coarse_k must be < k"):
        ClusterConfig(k=8, coarse_k=8).validate()
    with pytest.raises(ValueError, match="n_probe"):
        ClusterConfig(k=8, coarse_k=4, n_probe=0).validate()
    with pytest.raises(ValueError, match="n_probe"):
        ClusterConfig(k=8, coarse_k=4, n_probe=5).validate()
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="mesh"):
        ClusterConfig(k=8, coarse_k=4, mesh=mesh).validate()
    assert ClusterConfig(k=8, coarse_k=4).strategy == "two_level"
    assert ClusterConfig(k=8).strategy == "single_host"


def test_estimator_and_module_front_doors_validate(corpus):
    docs, df, perm, topics = corpus
    with pytest.raises(ValueError, match="coarse_k"):
        SphericalKMeans(k=8, coarse_k=1).fit(docs, df=df)
    with pytest.raises(ValueError, match="n_probe"):
        fit(docs, ClusterConfig(k=8, coarse_k=4, n_probe=9), df=df)
    with pytest.raises(ValueError, match="coarse_k"):
        resolve_strategy(ClusterConfig(k=8, coarse_k=4, n_probe=1)
                         ).fit(docs, ClusterConfig(k=8))
