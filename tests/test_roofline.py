"""Roofline plumbing: HLO collective parser + per-device cost semantics."""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.roofline.analysis import collective_bytes, cost_dict, roofline_terms, HW
from repro.launch.mesh import make_test_mesh


def test_collective_parser_on_crafted_hlo():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[64]{0} all-gather(%y), dimensions={0}
  %rs = (f32[32,32]{1,0}, f32[32,32]{1,0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u8[1024]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %done = f32[128,256]{1,0} all-reduce-done(%ar)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 64 * 2
    assert out["reduce-scatter"] == 2 * 32 * 32 * 4
    assert out["collective-permute"] == 1024
    assert out["total"] == sum(v for k, v in out.items()
                               if k not in ("total", "count"))
    assert out["count"] == 4      # -done not double counted


def test_cost_analysis_is_per_device():
    """2·M·N·K flops split across the model axis -> per-device count."""
    mesh = make_test_mesh((2, 4), ("data", "model"))
    m, n, k = 64, 256, 512

    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    with mesh:
        compiled = jax.jit(
            f,
            in_shardings=(NamedSharding(mesh, P("data", None)),
                          NamedSharding(mesh, P(None, "model"))),
        ).lower(a, b).compile()
    flops = cost_dict(compiled.cost_analysis())["flops"]
    total = 2 * m * n * k
    assert abs(flops - total / 8) / (total / 8) < 0.05


def test_roofline_terms_and_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 / 2}
    coll = {"total": 50e9 * 2}
    t = roofline_terms(cost, coll, HW())
    assert abs(t["t_compute_s"] - 1.0) < 1e-9
    assert abs(t["t_memory_s"] - 0.5) < 1e-9
    assert abs(t["t_collective_s"] - 2.0) < 1e-9
    assert t["bottleneck"] == "collective"
    assert abs(t["roofline_frac_compute"] - 0.5) < 1e-9


def test_scan_undercount_is_corrected_by_unroll():
    """The reason the dry-run costing pass exists (launch/dryrun.py)."""
    from repro.models.config import set_scan_unroll, scan_unroll

    def scanned(ws, x):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws,
                            unroll=scan_unroll())
        return y.sum()

    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    f_loop = cost_dict(jax.jit(scanned).lower(ws, x).compile()
                       .cost_analysis())["flops"]
    set_scan_unroll(True)
    try:
        # fresh trace — the flag is read at trace time, so the cached
        # unroll=False trace must not be reused (the dry-run rebuilds its
        # step closures per pass for exactly this reason)
        jax.clear_caches()
        f_unroll = cost_dict(jax.jit(scanned).lower(ws, x).compile()
                             .cost_analysis())["flops"]
    finally:
        set_scan_unroll(False)
        jax.clear_caches()
    assert f_unroll > 3.5 * f_loop   # 4 bodies vs 1
