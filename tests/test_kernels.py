"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import (sparse_sim, esicp_gather, esicp_filter,
                           segment_update, rho_gather, ref)


def _case(rng, b, p, d, k, dtype=np.float32):
    ids = np.sort(rng.integers(0, d, (b, p)), axis=1).astype(np.int32)
    vals = rng.random((b, p)).astype(dtype)
    nnz = rng.integers(1, p + 1, b)
    for i in range(b):
        vals[i, nnz[i]:] = 0
    means_t = np.where(rng.random((d, k)) < 0.25,
                       rng.random((d, k)), 0).astype(dtype)
    return jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(means_t)


SHAPES = [
    (8, 8, 64, 32),       # tiny
    (96, 20, 300, 150),   # unaligned everything
    (128, 32, 512, 128),  # exactly aligned
    (130, 17, 260, 129),  # off-by-one vs blocks
]


@pytest.mark.parametrize("b,p,d,k", SHAPES)
def test_sparse_sim(rng, b, p, d, k):
    ids, vals, means_t = _case(rng, b, p, d, k)
    out = sparse_sim(ids, vals, means_t, b_blk=64, k_blk=64, d_blk=128)
    exp = ref.sparse_sim(ids, vals, means_t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,p,d,k", SHAPES)
@pytest.mark.parametrize("v_th", [0.2, 0.7])
def test_esicp_gather(rng, b, p, d, k, v_th):
    ids, vals, means_t = _case(rng, b, p, d, k)
    t_th = int(0.8 * d)
    r12, y = esicp_gather(ids, vals, means_t, t_th, v_th,
                          b_blk=64, k_blk=64, d_blk=128)
    e12, ey = ref.esicp_gather(ids, vals, means_t, t_th, v_th)
    np.testing.assert_allclose(np.asarray(r12), np.asarray(e12),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ey),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,k", [(8, 32), (96, 150), (128, 256), (70, 129)])
def test_esicp_filter(rng, b, k):
    rho12 = rng.random((b, k)).astype(np.float32)
    y = rng.random((b, k)).astype(np.float32)
    rho_max = rng.random(b).astype(np.float32)
    col_ok = rng.random((b, k)) < 0.8
    v_th = 0.35
    m, c = esicp_filter(jnp.asarray(rho12), jnp.asarray(y),
                        jnp.asarray(rho_max), jnp.asarray(col_ok), v_th,
                        b_blk=64, k_blk=64)
    em, ec = ref.esicp_filter(jnp.asarray(rho12), jnp.asarray(y),
                              jnp.asarray(rho_max), jnp.asarray(col_ok), v_th)
    assert np.array_equal(np.asarray(m), np.asarray(em))
    assert np.array_equal(np.asarray(c), np.asarray(ec))


@pytest.mark.parametrize("b,p,d,k", SHAPES)
def test_segment_update(rng, b, p, d, k):
    ids, vals, means_t = _case(rng, b, p, d, k)
    assign = jnp.asarray(rng.integers(0, k, b).astype(np.int32))
    out = segment_update(assign, ids, vals, k=k, d=d,
                         b_blk=64, k_blk=64, d_blk=128)
    exp = ref.segment_update(assign, ids, vals, k, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,p,d,k", SHAPES)
def test_rho_gather(rng, b, p, d, k):
    ids, vals, means_t = _case(rng, b, p, d, k)
    # Includes out-of-range assign == k (the padding-row convention): ρ = 0.
    assign = jnp.asarray(rng.integers(0, k + 1, b).astype(np.int32))
    out = rho_gather(assign, ids, vals, means_t,
                     b_blk=64, k_blk=64, d_blk=128)
    exp = ref.rho_gather(assign, ids, vals, means_t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(out)[np.asarray(assign) == k] == 0.0).all()


def _count_oracle(ids, vals, w):
    """Visited-pair count oracle: Σ_p live[b,p] · w[ids[b,p], k] — per SLOT,
    not per distinct term (duplicate ids count twice, like the TAAT scan)."""
    live01 = (np.asarray(vals) != 0).astype(np.float32)
    return np.asarray(ref.sparse_sim(ids, jnp.asarray(live01),
                                     jnp.asarray(w.astype(np.float32))))


@pytest.mark.parametrize("b,p,d,k", SHAPES)
def test_sparse_sim_fused_diag(rng, b, p, d, k):
    """diag=True returns the visited-pair counts from the same launch."""
    ids, vals, means_t = _case(rng, b, p, d, k)
    sims, counts = sparse_sim(ids, vals, means_t, diag=True,
                              b_blk=64, k_blk=64, d_blk=128)
    np.testing.assert_allclose(np.asarray(sims),
                               np.asarray(ref.sparse_sim(ids, vals, means_t)),
                               rtol=1e-5, atol=1e-5)
    exp = _count_oracle(ids, vals, np.asarray(means_t) > 0)
    np.testing.assert_allclose(np.asarray(counts), exp, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,p,d,k", SHAPES)
def test_esicp_gather_fused_sims_and_diag(rng, b, p, d, k):
    """with_sims/diag pull exact sims + exact-region counts out of the ONE
    gather launch; rho12/y stay oracle-exact."""
    ids, vals, means_t = _case(rng, b, p, d, k)
    t_th, v_th = int(0.8 * d), 0.3
    r12, y, sims, counts = esicp_gather(ids, vals, means_t, t_th, v_th,
                                        with_sims=True, diag=True,
                                        b_blk=64, k_blk=64, d_blk=128)
    e12, ey = ref.esicp_gather(ids, vals, means_t, t_th, v_th)
    np.testing.assert_allclose(np.asarray(r12), np.asarray(e12),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ey),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sims),
                               np.asarray(ref.sparse_sim(ids, vals, means_t)),
                               rtol=1e-5, atol=1e-5)
    m = np.asarray(means_t)
    tail = np.arange(d)[:, None] >= t_th
    w = (m > 0) & np.where(tail, m >= v_th, True)
    np.testing.assert_allclose(np.asarray(counts), _count_oracle(ids, vals, w),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,p,d,k", SHAPES)
def test_kernels_with_prepared_plan_match_unplanned(rng, b, p, d, k):
    """A prepared plan (precomputed occupancy + cached head slabs) is pure
    optimisation: every kernel's output is unchanged when it is supplied."""
    from repro.kernels.plan import prepare_plan

    ids, vals, means_t = _case(rng, b, p, d, k)
    plan = prepare_plan(ids, vals, dim=d, b_blk=64, d_blk=128,
                        head_bytes=1 << 30)
    assert plan.n_head > 0              # budget covers every block here
    assign = jnp.asarray(rng.integers(0, k + 1, b).astype(np.int32))
    kw = dict(b_blk=64, k_blk=64, d_blk=128)

    np.testing.assert_array_equal(
        np.asarray(sparse_sim(ids, vals, means_t, **kw)),
        np.asarray(sparse_sim(ids, vals, means_t, plan=plan, **kw)))
    base = esicp_gather(ids, vals, means_t, int(0.8 * d), 0.3,
                        with_sims=True, diag=True, **kw)
    planned = esicp_gather(ids, vals, means_t, int(0.8 * d), 0.3, plan=plan,
                           with_sims=True, diag=True, **kw)
    for a, e in zip(planned, base):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(e))
    np.testing.assert_array_equal(
        np.asarray(segment_update(assign, ids, vals, k=k, d=d, **kw)),
        np.asarray(segment_update(assign, ids, vals, k=k, d=d, plan=plan,
                                  **kw)))
    np.testing.assert_array_equal(
        np.asarray(rho_gather(assign, ids, vals, means_t, **kw)),
        np.asarray(rho_gather(assign, ids, vals, means_t, plan=plan, **kw)))

    # A plan whose geometry does not match the call is ignored, not wrong.
    stale = prepare_plan(ids, vals, dim=d, b_blk=32, d_blk=64)
    np.testing.assert_allclose(
        np.asarray(sparse_sim(ids, vals, means_t, plan=stale, **kw)),
        np.asarray(ref.sparse_sim(ids, vals, means_t)),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,p,d,k", [(96, 20, 300, 150), (130, 17, 260, 129)])
def test_multi_superblock_k_grid(rng, b, p, d, k):
    """k_sup < padded K exercises the K-superblock grid dimension (j > 0)
    and the k0 offset math in every kernel — the production path for
    K > K_SUP_CAP that default test shapes never reach."""
    from repro.kernels.plan import prepare_plan

    ids, vals, means_t = _case(rng, b, p, d, k)
    plan = prepare_plan(ids, vals, dim=d, b_blk=64, d_blk=128,
                        head_bytes=1 << 30)
    assign = jnp.asarray(rng.integers(0, k + 1, b).astype(np.int32))
    kw = dict(b_blk=64, k_blk=32, d_blk=128, k_sup=32)   # padded K / 32 > 1

    sims, cnt = sparse_sim(ids, vals, means_t, plan=plan, diag=True, **kw)
    np.testing.assert_allclose(np.asarray(sims),
                               np.asarray(ref.sparse_sim(ids, vals, means_t)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cnt),
                               _count_oracle(ids, vals,
                                             np.asarray(means_t) > 0),
                               rtol=1e-5, atol=1e-5)
    r12, y = esicp_gather(ids, vals, means_t, int(0.8 * d), 0.3, plan=plan,
                          **kw)
    e12, ey = ref.esicp_gather(ids, vals, means_t, int(0.8 * d), 0.3)
    np.testing.assert_allclose(np.asarray(r12), np.asarray(e12),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ey),
                               rtol=1e-5, atol=1e-5)
    lam = segment_update(assign, ids, vals, k=k, d=d, plan=plan, **kw)
    x = np.asarray(ref.densify(ids, vals, d))
    exp = np.zeros((k, d), np.float32)
    for i, a in enumerate(np.asarray(assign)):
        if a < k:
            exp[a] += x[i]
    np.testing.assert_allclose(np.asarray(lam), exp, rtol=1e-4, atol=1e-4)
    rho = rho_gather(assign, ids, vals, means_t, plan=plan, **kw)
    np.testing.assert_allclose(
        np.asarray(rho), np.asarray(ref.rho_gather(assign, ids, vals,
                                                   means_t)),
        rtol=1e-5, atol=1e-5)


def test_pick_k_sup_divides_padded_k():
    """The auto policy returns a k_blk multiple that divides padded K and
    respects the VMEM cap — including awkward padded sizes."""
    from repro.kernels.ops import K_SUP_CAP, _pick_k_sup

    for kp, k_blk in [(128, 128), (2048, 128), (2560, 128), (1152, 128),
                      (3200, 64), (96, 32), (4096, 128)]:
        ks = _pick_k_sup(kp, k_blk, None)
        assert kp % ks == 0 and ks % k_blk == 0
        assert ks <= max(K_SUP_CAP, k_blk) or ks == kp <= K_SUP_CAP


def test_occupancy_map_marks_exactly_live_cells(rng):
    """Occupancy: a cell is marked iff some row of its b_blk group holds a
    LIVE (val != 0) tuple in that D-block — padding/dead slots never count."""
    from repro.kernels.plan import occupancy_map

    b, p, d, d_blk, b_blk = 96, 12, 256, 64, 32
    ids, vals, _ = _case(rng, b, p, d, 8)
    occ = np.asarray(occupancy_map(ids, vals, dim=d, b_blk=b_blk,
                                   d_blk=d_blk))
    assert occ.shape == (b // b_blk, d // d_blk)
    iid, val = np.asarray(ids), np.asarray(vals)
    for t in range(b // b_blk):
        rows = slice(t * b_blk, (t + 1) * b_blk)
        for l in range(d // d_blk):
            in_blk = (iid[rows] // d_blk == l) & (val[rows] != 0)
            assert bool(occ[t, l]) == bool(in_blk.any())


def test_occupancy_tiled_layout_matches_per_tile(rng):
    """tile_rows groups rows per tile (the epoch's slicing contract): the
    tiled map equals independently computed per-tile maps, including a tile
    size that is NOT a b_blk multiple (per-tile padding)."""
    from repro.kernels.plan import occupancy_map

    b, p, d = 120, 10, 128
    ids, vals, _ = _case(rng, b, p, d, 8)
    tiled = np.asarray(occupancy_map(ids, vals, dim=d, b_blk=16, d_blk=64,
                                     tile_rows=40))
    per_tile = [np.asarray(occupancy_map(ids[s:s + 40], vals[s:s + 40],
                                         dim=d, b_blk=16, d_blk=64))
                for s in range(0, b, 40)]
    np.testing.assert_array_equal(tiled, np.concatenate(per_tile))


def test_gather_matches_scan_core(rng):
    """Kernel path == the core's TAAT scan accumulators (integration)."""
    from repro.core import build_mean_index, StructuralParams
    from repro.core.assignment import _scan
    from repro.sparse import SparseDocs

    b, p, d, k = 64, 16, 256, 64
    ids, vals, means_t = _case(rng, b, p, d, k)
    nnz = jnp.asarray((np.asarray(vals) != 0).sum(1).astype(np.int32))
    docs = SparseDocs(ids=ids, vals=vals, nnz=nnz, dim=d)
    params = StructuralParams(t_th=jnp.asarray(int(0.8 * d), jnp.int32),
                              v_th=jnp.asarray(0.3, jnp.float32))
    index = build_mean_index(jnp.asarray(means_t).T, params)
    out = _scan(docs, index, jnp.zeros((b,), bool), mode="esicp")
    r12, y = esicp_gather(ids, vals, index.means_t, params.t_th, params.v_th,
                          b_blk=64, k_blk=64, d_blk=128)
    np.testing.assert_allclose(np.asarray(out["rho12"]), np.asarray(r12),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out["y"]), np.asarray(y),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bh,sq,sk,hd", [(2, 64, 64, 32), (3, 200, 136, 64),
                                         (4, 256, 256, 128)])
@pytest.mark.parametrize("window", [-1, 48])
def test_flash_attention(rng, bh, sq, sk, hd, window):
    from repro.kernels import flash_attention
    q = jnp.asarray(rng.standard_normal((bh, sq, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((bh, sk, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((bh, sk, hd)).astype(np.float32))
    out = flash_attention(q, k, v, window=window, sq_blk=64, sk_blk=64)
    exp = ref.flash_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16_inputs(rng):
    """bf16 storage dtypes lower correctly (values checked in f32)."""
    from repro.kernels import flash_attention
    q = jnp.asarray(rng.standard_normal((2, 128, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((2, 128, 64)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((2, 128, 64)).astype(np.float32))
    out = flash_attention(q, k, v, window=-1, sq_blk=64, sk_blk=64)
    exp = ref.flash_attention(q, k, v, -1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)
