"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import (sparse_sim, esicp_gather, esicp_filter,
                           segment_update, rho_gather, ref)


def _case(rng, b, p, d, k, dtype=np.float32):
    ids = np.sort(rng.integers(0, d, (b, p)), axis=1).astype(np.int32)
    vals = rng.random((b, p)).astype(dtype)
    nnz = rng.integers(1, p + 1, b)
    for i in range(b):
        vals[i, nnz[i]:] = 0
    means_t = np.where(rng.random((d, k)) < 0.25,
                       rng.random((d, k)), 0).astype(dtype)
    return jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(means_t)


SHAPES = [
    (8, 8, 64, 32),       # tiny
    (96, 20, 300, 150),   # unaligned everything
    (128, 32, 512, 128),  # exactly aligned
    (130, 17, 260, 129),  # off-by-one vs blocks
]


@pytest.mark.parametrize("b,p,d,k", SHAPES)
def test_sparse_sim(rng, b, p, d, k):
    ids, vals, means_t = _case(rng, b, p, d, k)
    out = sparse_sim(ids, vals, means_t, b_blk=64, k_blk=64, d_blk=128)
    exp = ref.sparse_sim(ids, vals, means_t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,p,d,k", SHAPES)
@pytest.mark.parametrize("v_th", [0.2, 0.7])
def test_esicp_gather(rng, b, p, d, k, v_th):
    ids, vals, means_t = _case(rng, b, p, d, k)
    t_th = int(0.8 * d)
    r12, y = esicp_gather(ids, vals, means_t, t_th, v_th,
                          b_blk=64, k_blk=64, d_blk=128)
    e12, ey = ref.esicp_gather(ids, vals, means_t, t_th, v_th)
    np.testing.assert_allclose(np.asarray(r12), np.asarray(e12),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ey),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,k", [(8, 32), (96, 150), (128, 256), (70, 129)])
def test_esicp_filter(rng, b, k):
    rho12 = rng.random((b, k)).astype(np.float32)
    y = rng.random((b, k)).astype(np.float32)
    rho_max = rng.random(b).astype(np.float32)
    col_ok = rng.random((b, k)) < 0.8
    v_th = 0.35
    m, c = esicp_filter(jnp.asarray(rho12), jnp.asarray(y),
                        jnp.asarray(rho_max), jnp.asarray(col_ok), v_th,
                        b_blk=64, k_blk=64)
    em, ec = ref.esicp_filter(jnp.asarray(rho12), jnp.asarray(y),
                              jnp.asarray(rho_max), jnp.asarray(col_ok), v_th)
    assert np.array_equal(np.asarray(m), np.asarray(em))
    assert np.array_equal(np.asarray(c), np.asarray(ec))


@pytest.mark.parametrize("b,p,d,k", SHAPES)
def test_segment_update(rng, b, p, d, k):
    ids, vals, means_t = _case(rng, b, p, d, k)
    assign = jnp.asarray(rng.integers(0, k, b).astype(np.int32))
    out = segment_update(assign, ids, vals, k=k, d=d,
                         b_blk=64, k_blk=64, d_blk=128)
    exp = ref.segment_update(assign, ids, vals, k, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,p,d,k", SHAPES)
def test_rho_gather(rng, b, p, d, k):
    ids, vals, means_t = _case(rng, b, p, d, k)
    # Includes out-of-range assign == k (the padding-row convention): ρ = 0.
    assign = jnp.asarray(rng.integers(0, k + 1, b).astype(np.int32))
    out = rho_gather(assign, ids, vals, means_t,
                     b_blk=64, k_blk=64, d_blk=128)
    exp = ref.rho_gather(assign, ids, vals, means_t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(out)[np.asarray(assign) == k] == 0.0).all()


def test_gather_matches_scan_core(rng):
    """Kernel path == the core's TAAT scan accumulators (integration)."""
    from repro.core import build_mean_index, StructuralParams
    from repro.core.assignment import _scan
    from repro.sparse import SparseDocs

    b, p, d, k = 64, 16, 256, 64
    ids, vals, means_t = _case(rng, b, p, d, k)
    nnz = jnp.asarray((np.asarray(vals) != 0).sum(1).astype(np.int32))
    docs = SparseDocs(ids=ids, vals=vals, nnz=nnz, dim=d)
    params = StructuralParams(t_th=jnp.asarray(int(0.8 * d), jnp.int32),
                              v_th=jnp.asarray(0.3, jnp.float32))
    index = build_mean_index(jnp.asarray(means_t).T, params)
    out = _scan(docs, index, jnp.zeros((b,), bool), mode="esicp")
    r12, y = esicp_gather(ids, vals, index.means_t, params.t_th, params.v_th,
                          b_blk=64, k_blk=64, d_blk=128)
    np.testing.assert_allclose(np.asarray(out["rho12"]), np.asarray(r12),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out["y"]), np.asarray(y),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bh,sq,sk,hd", [(2, 64, 64, 32), (3, 200, 136, 64),
                                         (4, 256, 256, 128)])
@pytest.mark.parametrize("window", [-1, 48])
def test_flash_attention(rng, bh, sq, sk, hd, window):
    from repro.kernels import flash_attention
    q = jnp.asarray(rng.standard_normal((bh, sq, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((bh, sk, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((bh, sk, hd)).astype(np.float32))
    out = flash_attention(q, k, v, window=window, sq_blk=64, sk_blk=64)
    exp = ref.flash_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16_inputs(rng):
    """bf16 storage dtypes lower correctly (values checked in f32)."""
    from repro.kernels import flash_attention
    q = jnp.asarray(rng.standard_normal((2, 128, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((2, 128, 64)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((2, 128, 64)).astype(np.float32))
    out = flash_attention(q, k, v, window=-1, sq_blk=64, sk_blk=64)
    exp = ref.flash_attention(q, k, v, -1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)
