"""Distributed runtime: shard_map k-means equivalence, elastic reshard,
LM train-step cross-mesh lowering (8 host devices)."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.mesh import make_test_mesh
from repro.cluster import SphericalKMeans
from repro.distributed import mesh_fit, reshard_state, StepWatchdog


@pytest.fixture(scope="module")
def corpus_small():
    from repro.data import make_corpus, CorpusSpec
    return make_corpus(CorpusSpec(n_docs=1024, vocab=768, nt_mean=30,
                                  n_topics=12, seed=9))


def test_dist_matches_single_device(corpus_small):
    """mesh= routes the *same* estimator through the distributed loop."""
    docs, df, perm, topics = corpus_small
    mesh = make_test_mesh((4, 2), ("data", "model"))
    ref = SphericalKMeans(k=16, algo="mivi", max_iter=25, batch_size=512,
                          seed=5).fit(docs, df=df)
    km = SphericalKMeans(k=16, algo="esicp", max_iter=25, chunk_size=128,
                         mesh=mesh, seed=5).fit(docs, df=df)
    assert km.converged_
    assert km.model_.strategy == "mesh"
    assert len(km.labels_) == docs.n_docs
    assert (km.labels_ == ref.labels_).all()


def test_dist_multipod_axes(corpus_small):
    docs, df, perm, topics = corpus_small
    mesh3 = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    ref = SphericalKMeans(k=16, algo="mivi", max_iter=20, batch_size=512,
                          seed=2).fit(docs, df=df)
    state, hist, conv, params = mesh_fit(docs, 16, mesh3, algo="esicp",
                                         max_iter=20, obj_chunk=128, seed=2,
                                         df=df)
    assign = np.asarray(state.assign)[:docs.n_docs]
    assert (assign == ref.labels_).all()


def test_elastic_reshard(corpus_small):
    docs, df, perm, topics = corpus_small
    mesh_a = make_test_mesh((4, 2), ("data", "model"))
    state, hist, _, _ = mesh_fit(docs, 16, mesh_a, algo="esicp", max_iter=3,
                                 obj_chunk=128, seed=5, df=df)
    # node failure: continue on a smaller mesh (2×2), same model axis width
    mesh_b = make_test_mesh((2, 2), ("data", "model"))
    state_b = reshard_state(state, mesh_b)
    assert np.allclose(np.asarray(state_b.means_t), np.asarray(state.means_t))
    # and the resharded state keeps iterating
    from repro.distributed.kmeans import make_step_fn, dist_assignment_update
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = docs.n_docs
    pad = (-n) % (2 * 128)
    sh = lambda s: NamedSharding(mesh_b, s)
    ids = jax.device_put(jnp.pad(docs.ids, ((0, pad), (0, 0))), sh(P(("data",), None)))
    vals = jax.device_put(jnp.pad(docs.vals, ((0, pad), (0, 0))), sh(P(("data",), None)))
    valid = jax.device_put(jnp.arange(n + pad) < n, sh(P(("data",))))
    step = make_step_fn(mesh_b, algo="esicp", k=16, obj_chunk=128)
    state_b = dataclasses.replace(
        state_b,
        assign=jax.device_put(state_b.assign, sh(P(("data",)))),
        rho_self=jax.device_put(state_b.rho_self, sh(P(("data",)))),
        rho_prev=jax.device_put(state_b.rho_prev, sh(P(("data",)))))
    new, diag = dist_assignment_update(step, state_b, ids, vals, valid,
                                       jnp.asarray(0), jnp.asarray(1.0))
    assert np.isfinite(float(diag["objective"]))


def test_watchdog():
    wd = StepWatchdog(factor=3.0, warmup=2)
    import time
    for _ in range(3):
        wd.start(); time.sleep(0.01); assert wd.stop() is False
    wd.start(); time.sleep(0.08)
    assert wd.stop() is True          # 8x the median -> straggler


def test_lm_train_step_lowers_on_mesh():
    """Reduced-arch train step lowers+compiles on a 2x4 mesh with the
    production sharding rules (mini dry-run executed in-process)."""
    from repro.configs import smoke_config
    from repro.launch.steps import build_cell
    from repro.launch.shapes import ShapeSpec
    cfg = smoke_config("qwen2.5-32b")
    mesh = make_test_mesh((2, 4), ("data", "model"))
    shape = ShapeSpec("train_tiny", "train", 64, 8)
    cell = build_cell(cfg, mesh, shape, microbatches=2)
    with mesh:
        compiled = cell.fn.lower(*cell.args).compile()
    from repro.roofline.analysis import cost_dict
    assert cost_dict(compiled.cost_analysis()).get("flops", 0) > 0


def test_assign_service_matches_core(corpus_small):
    """Serving mode (frozen index lookup) == core exact assignment."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import SphericalKMeans
    from repro.core.assignment import assignment_step
    from repro.distributed.kmeans import make_assign_fn

    docs, df, perm, topics = corpus_small
    fit = SphericalKMeans(k=16, algo="esicp", max_iter=8, batch_size=512,
                          seed=5).fit(docs, df=df)
    idx = fit.state_.index
    mesh = make_test_mesh((4, 2), ("data", "model"))
    n = docs.n_docs
    pad = (-n) % (4 * 128)
    sh = lambda s: NamedSharding(mesh, s)
    ids = jax.device_put(jnp.pad(docs.ids, ((0, pad), (0, 0))),
                         sh(P(("data",), None)))
    vals = jax.device_put(jnp.pad(docs.vals, ((0, pad), (0, 0))),
                          sh(P(("data",), None)))
    valid = jax.device_put(jnp.arange(n + pad) < n, sh(P(("data",))))
    means_t = jax.device_put(idx.means_t, sh(P(None, "model")))
    fn = make_assign_fn(mesh, k=16, obj_chunk=128)
    assign, sims = fn(ids, vals, valid, means_t,
                      idx.params.t_th, idx.params.v_th)
    ref = assignment_step("mivi", docs, idx,
                          jnp.zeros((n,), jnp.int32),
                          jnp.full((n,), -jnp.inf),
                          jnp.zeros((n,), bool))
    assert (np.asarray(assign)[:n] == np.asarray(ref.assign)).all()
    np.testing.assert_allclose(np.asarray(sims)[:n], np.asarray(ref.rho),
                               rtol=1e-5, atol=1e-5)
