"""Two-level IVF clustering: coarse+fine fit → nested artifact → routed
classify → serving (DESIGN.md §13).

Demonstrates the million-cluster regime machinery end to end:

  1. ``ClusterConfig(coarse_k=K_c)`` routes the fit through the
     ``two_level`` strategy: a coarse spherical k-means over K_c cells,
     the corpus partitioned by coarse assignment, and one flat fine fit
     per cell — yielding a nested :class:`TwoLevelFittedModel`;
  2. the artifact save/loads through the same checkpoint store as flat
     models (``load_model`` dispatches on the stored format);
  3. ``classify_docs_routed`` scores K_c coarse means plus only the probed
     cells' fine means per object — the ``scored`` counters prove it —
     with measured recall@1 at n_probe=1 and bit-identical results to the
     flat scan at n_probe=K_c;
  4. the SAME artifact serves through :class:`ClusterServer`, responses
     bit-identical to the direct routed classify.

    PYTHONPATH=src python examples/ivf_clustering.py
    PYTHONPATH=src python examples/ivf_clustering.py --smoke   # tiny (CI)
"""
import argparse
import os
import shutil
import tempfile

import numpy as np

from repro.cluster import (ClusterConfig, classify_docs, classify_docs_routed,
                           fit, load_model)
from repro.data import make_corpus, CorpusSpec
from repro.serve import ClusterServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic corpus so CI can smoke-run the "
                         "example end to end in seconds")
    args = ap.parse_args()

    if args.smoke:
        spec = CorpusSpec(n_docs=800, vocab=512, nt_mean=20, n_topics=12,
                          seed=0)
        k, k_c = 24, 4
    else:
        spec = CorpusSpec(n_docs=20_000, vocab=4_096, nt_mean=60,
                          n_topics=128, seed=0)
        k, k_c = 512, 16

    # ---- two-level fit ---------------------------------------------------
    docs, df, perm, topics = make_corpus(spec)
    model = fit(docs, ClusterConfig(k=k, coarse_k=k_c, n_probe=1,
                                    algo="esicp", max_iter=10, seed=0),
                df=df)
    print(f"[fit]   K_c={model.coarse_k} cells over K_eff={model.index.k} "
          f"fine clusters, cell sizes {model.cell_sizes.min()}"
          f"..{model.cell_sizes.max()}, converged={model.converged}")

    # ---- nested artifact round-trip --------------------------------------
    workdir = tempfile.mkdtemp(prefix="ivf_clustering_")
    model.save(os.path.join(workdir, "model"))
    served = load_model(os.path.join(workdir, "model"))
    assert type(served) is type(model)
    print(f"[save]  nested artifact round-tripped via {workdir}/model")

    # ---- routed classify: cost, recall, exactness ------------------------
    a_flat, s_flat = classify_docs(model.index, docs)
    a1, _, scored = classify_docs_routed(served, docs, n_probe=1,
                                         with_stats=True)
    cmax = int(model.cell_sizes.max())
    assert scored.max() <= model.coarse_k + cmax, "candidate bound broke!"
    recall = float(np.mean(a1 == a_flat))
    print(f"[route] n_probe=1 scored {scored.mean():.0f} of "
          f"{model.index.k} centroids/doc (bound K_c+cmax="
          f"{model.coarse_k + cmax}), recall@1 {recall:.3f}")
    a_all, s_all = classify_docs_routed(served, docs, n_probe=model.coarse_k)
    assert (a_all == a_flat).all() and (s_all == s_flat).all()
    print(f"[route] n_probe=K_c is bit-identical to the flat scan ✓")

    # ---- serving: routed epoch behind the continuous batcher -------------
    a_ref, s_ref = classify_docs_routed(served, docs)
    ids, vals, nnz = (np.asarray(docs.ids), np.asarray(docs.vals),
                      np.asarray(docs.nnz))
    with ClusterServer(max_live_batches=4) as server:
        server.load("ivf", served)
        a, s = server.classify("ivf", (ids, vals, nnz))
        assert (a == a_ref).all() and (s == s_ref).all(), \
            "served routed classify diverged from the direct path!"
        stats = server.stats("ivf")
        print(f"[serve] {stats['n_requests']} request(s) "
              f"({stats['n_rows']} rows) served bit-identical to the "
              f"direct routed classify ✓")

    shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
