"""Train a reduced LM (any assigned arch) for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm.py --arch gemma-2b --steps 50
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import init_params
from repro.train import make_train_step, TrainConfig, adamw_init, AdamWConfig


def synth_tokens(key, b, s, vocab):
    """Markov-ish synthetic stream so the loss has learnable structure."""
    base = jax.random.randint(key, (b, s), 0, vocab)
    return jnp.where(jnp.arange(s) % 2 == 1, jnp.roll(base, 1, axis=1), base)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, TrainConfig(microbatches=2, optimizer=AdamWConfig(lr=1e-3))))

    toks = synth_tokens(key, args.batch, args.seq, cfg.vocab)
    labels = jnp.roll(toks, -1, axis=1)
    fe = (jax.random.normal(key, (args.batch, 8, cfg.d_model))
          if cfg.modality != "text" else None)

    t0 = time.time()
    for i in range(args.steps):
        params, opt, m = step(params, opt, toks, labels, fe)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} lr={float(m['lr']):.2e}")
    print(f"{args.steps} steps in {time.time()-t0:.1f}s "
          f"({cfg.name}, {cfg.n_params():,} params)")


if __name__ == "__main__":
    main()
