"""Out-of-core clustering: build an on-disk DocStore, fit it streaming,
and resume from a mid-fit checkpoint.

Demonstrates the chunked data plane (DESIGN.md §10):

  1. :class:`DocStoreBuilder` streams raw (term-id, value) rows to disk in
     batches — computing df on the fly, then applying tf-idf, the df-rank
     remap, and L2 normalisation chunk by chunk at finalize — so the corpus
     is never resident in memory;
  2. ``SphericalKMeans.fit(store)`` routes through the streaming strategy:
     chunks prefetch host→device double-buffered, one host sync per epoch;
  3. ``algo_mode='minibatch'`` runs Sculley-style streaming updates over
     the same store;
  4. a mid-fit checkpoint is restored with ``streaming_fit(...,
     resume=True)`` and reproduces the uninterrupted fit's labels exactly.

    PYTHONPATH=src python examples/stream_clustering.py
    PYTHONPATH=src python examples/stream_clustering.py --smoke   # tiny (CI)
"""
import argparse
import os
import shutil
import tempfile

import numpy as np

from repro.core.lloyd import streaming_fit
from repro.cluster import ClusterConfig, SphericalKMeans, fit
from repro.data import make_corpus, CorpusSpec
from repro.sparse import DocStoreBuilder


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic corpus so CI can smoke-run the "
                         "example end to end in seconds")
    args = ap.parse_args()

    if args.smoke:
        spec = CorpusSpec(n_docs=600, vocab=512, nt_mean=20, n_topics=8,
                          seed=0)
        k, chunk, max_iter = 8, 128, 12
    else:
        spec = CorpusSpec(n_docs=20_000, vocab=4_096, nt_mean=60,
                          n_topics=64, seed=0)
        k, chunk, max_iter = 64, 4_096, 25

    # Generate raw rows once (stand-in for a real tokenised corpus), then
    # STREAM them into the on-disk store in small batches — the store ends
    # up several chunks larger than its configured chunk size.
    print("generating a UC-faithful corpus and streaming it to disk…")
    docs, df, perm, topics = make_corpus(spec)
    workdir = tempfile.mkdtemp(prefix="stream_clustering_")
    builder = DocStoreBuilder(os.path.join(workdir, "store"), dim=docs.dim,
                              chunk_size=chunk, pad_width=docs.pad_width)
    ids, vals, nnz = (np.asarray(docs.ids), np.asarray(docs.vals),
                      np.asarray(docs.nnz))
    for start in range(0, spec.n_docs, 200):
        end = min(start + 200, spec.n_docs)
        builder.append(ids[start:end], vals[start:end], nnz[start:end])
    # The corpus arrived already preprocessed, so only the dead-row tail
    # padding of finalize applies here; raw pipelines keep all three stages.
    store = builder.finalize(tf_idf=False, normalize=False, remap=False)
    print(f"store: {store.n_docs} docs in {store.n_chunks} chunks of "
          f"{store.chunk_size} rows ({os.path.abspath(store.directory)})")
    assert store.n_chunks >= 4, "store should exceed the chunk size"

    # ---- full-batch chunk-scan Lloyd over the store ----------------------
    model = fit(store, ClusterConfig(k=k, algo="esicp", batch_size=chunk,
                                     max_iter=max_iter, seed=0), df=df)
    print(f"[full]      converged={model.converged} n_iter={model.n_iter} "
          f"J={model.objective:.2f} strategy={model.strategy}")

    # ---- Sculley-style minibatch over the same store ---------------------
    mb = SphericalKMeans(k=k, algo_mode="minibatch", batch_size=chunk,
                         chunk_size=chunk, max_iter=max_iter,
                         seed=0).fit(store, df=df)
    print(f"[minibatch] converged={mb.converged_} n_iter={mb.n_iter_} "
          f"J={mb.objective_:.2f} "
          f"(full-batch J={model.objective:.2f})")

    # ---- resume from a mid-fit checkpoint --------------------------------
    ckpt = os.path.join(workdir, "ckpt")
    full = streaming_fit(store, k=k, batch_size=chunk, max_iter=max_iter,
                         seed=0, df=df, checkpoint_dir=ckpt,
                         checkpoint_every=2)
    from repro.checkpoint.store import all_steps
    steps = all_steps(ckpt)
    mid = [s for s in steps if s % (store.n_chunks + 1) != 0]
    target = mid[-1] if mid else steps[0]
    for s in steps:                      # rewind history to the chosen step
        if s > target:
            shutil.rmtree(os.path.join(ckpt, f"step_{s:08d}"))
    resumed = streaming_fit(store, k=k, batch_size=chunk, max_iter=max_iter,
                            seed=0, df=df, checkpoint_dir=ckpt, resume=True)
    assert (resumed.assign == full.assign).all(), \
        "resumed fit diverged from the uninterrupted fit!"
    print(f"[resume]    restarted from step {target} "
          f"({'mid-epoch' if mid else 'epoch boundary'}) → identical "
          f"final labels on {store.n_docs} docs ✓")

    shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
