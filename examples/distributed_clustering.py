"""Distributed ES-ICP on a (data × model) mesh with checkpoint/restart.

The unified API makes distribution a config field: the *same*
``SphericalKMeans`` estimator, handed a ``mesh=``, routes the fit through
the pod layout — objects sharded over 'data', the mean-inverted index over
'model', the (max, argmin-id) assignment all-reduce — and still yields the
one FittedModel artifact that serving consumes.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_clustering.py
    PYTHONPATH=src python examples/distributed_clustering.py --smoke  # (CI)
"""
import argparse
import os
import tempfile

import jax

from repro.data import make_corpus, CorpusSpec
from repro.cluster import ClusterEngine, SphericalKMeans
from repro.launch.mesh import make_test_mesh
from repro.checkpoint import latest_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus + whatever mesh the host devices "
                         "allow, so CI can smoke-run this in seconds")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    dm = max(n_dev // 2, 1)
    mesh = make_test_mesh((n_dev // dm, dm), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    if args.smoke:
        spec = CorpusSpec(n_docs=512, vocab=512, nt_mean=25, n_topics=8,
                          seed=1)
        k, chunk, max_iter = 8, 64, 12
    else:
        spec = CorpusSpec(n_docs=4_096, vocab=2_048, nt_mean=50, n_topics=32,
                          seed=1)
        k, chunk, max_iter = 32, 256, 25
    docs, df, perm, topics = make_corpus(spec)

    ckdir = os.path.join(tempfile.mkdtemp(), "ckpt")
    km = SphericalKMeans(k=k, algo="esicp", max_iter=max_iter, mesh=mesh,
                         chunk_size=chunk, seed=0, checkpoint_dir=ckdir,
                         checkpoint_every=2 if args.smoke else 5)
    km.fit(docs, df=df)
    hist = km.history_
    print(f"converged={km.converged_} iters={km.n_iter_} "
          f"objective={hist[-1]['objective']:.2f}")
    print(f"CPR trace: {[round(h['cpr'], 4) for h in hist[:8]]}…")
    print(f"checkpoints: latest step {latest_step(ckdir)} under {ckdir}")

    # The mesh fit yields the same artifact as a single-host fit: save it,
    # reload it, serve it.
    mdir = os.path.join(tempfile.mkdtemp(), "model")
    km.model_.save(mdir)
    from repro.cluster import FittedModel
    engine = ClusterEngine.from_model(FittedModel.load(mdir))
    served, _ = engine.classify(docs)
    assert (served == km.labels_).all(), "mesh-train/serve disagreement!"
    print(f"mesh-trained artifact served single-host: parity on "
          f"{docs.n_docs} docs ✓")


if __name__ == "__main__":
    main()
