"""Distributed ES-ICP on a (data × model) mesh with checkpoint/restart.

Runs on host devices (set XLA_FLAGS for more), demonstrates the pod layout:
objects sharded over 'data', the mean-inverted index over 'model', the
(max, argmin-id) assignment all-reduce, and fault-tolerant resume.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_clustering.py
"""
import os
import tempfile

import numpy as np
import jax

from repro.data import make_corpus, CorpusSpec
from repro.distributed import dist_fit
from repro.launch.mesh import make_test_mesh
from repro.checkpoint import latest_step


def main():
    n_dev = len(jax.devices())
    dm = max(n_dev // 2, 1)
    mesh = make_test_mesh((n_dev // dm, dm), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    docs, df, perm, topics = make_corpus(
        CorpusSpec(n_docs=4_096, vocab=2_048, nt_mean=50, n_topics=32, seed=1))

    ckdir = os.path.join(tempfile.mkdtemp(), "ckpt")
    state, hist, conv = dist_fit(docs, k=32, mesh=mesh, algo="esicp",
                                 max_iter=25, obj_chunk=256, seed=0, df=df,
                                 checkpoint_dir=ckdir, checkpoint_every=5)
    print(f"converged={conv} iters={len(hist)} "
          f"objective={hist[-1]['objective']:.2f}")
    print(f"CPR trace: {[round(h['cpr'], 4) for h in hist[:8]]}…")
    print(f"checkpoints: latest step {latest_step(ckdir)} under {ckdir}")


if __name__ == "__main__":
    main()
