"""End-to-end driver: the paper's experiment at reduced scale.

Runs ES-ICP against the MIVI / ICP / TA-ICP / CS-ICP baselines on the
pubmed-reduced corpus, verifies the acceleration contract (identical
clusterings), and prints the paper-style comparison table.

    PYTHONPATH=src python examples/cluster_documents.py [--dataset nyt]
    PYTHONPATH=src python examples/cluster_documents.py --smoke   # tiny (CI)
"""
import argparse
import dataclasses

import numpy as np

from repro.configs.pubmed8m import reduced as pubmed_reduced
from repro.configs.nyt1m import reduced as nyt_reduced
from repro.data import make_corpus
from repro.cluster import ClusterConfig, fit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="pubmed", choices=["pubmed", "nyt"])
    ap.add_argument("--algos", default="mivi,icp,cs-icp,ta-icp,esicp")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic corpus so CI can smoke-run the "
                         "example end to end in seconds")
    args = ap.parse_args()

    job = pubmed_reduced() if args.dataset == "pubmed" else nyt_reduced()
    if args.smoke:
        from repro.data import CorpusSpec
        spec = CorpusSpec(n_docs=400, vocab=512, nt_mean=20, n_topics=8,
                          seed=0)
        job = dataclasses.replace(job, name=job.name + "-smoke",
                                  n_docs=spec.n_docs, vocab=spec.vocab, k=8,
                                  corpus=spec, max_iter=10)
    print(f"corpus {job.name}: N={job.n_docs} D={job.vocab} K={job.k}")
    docs, df, perm, topics = make_corpus(job.corpus)

    results = {}
    for algo in args.algos.split(","):
        cfg = ClusterConfig(k=job.k, algo=algo, max_iter=job.max_iter,
                            batch_size=4096, seed=0)
        results[algo] = r = fit(docs, cfg, df=df)
        mult = np.mean([h["mult"] for h in r.history])
        t = np.mean([h["elapsed_s"] for h in r.history])
        print(f"{algo:8s} iters={r.n_iter:3d} avg_mult={mult:.4g} "
              f"avg_time={t:.2f}s cpr_last={r.history[-1]['cpr']:.4g}")

    ref = next(iter(results.values()))
    same = all((r.labels == ref.labels).all() for r in results.values())
    print(f"\nacceleration contract (identical clusterings): {same}")


if __name__ == "__main__":
    main()
