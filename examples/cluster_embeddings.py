"""ES-ICP applied to an LM's vocabulary embeddings (DESIGN.md §5).

The assigned dense transformers have no use for inverted-index pruning in
the backbone, but their *embedding tables* are exactly the paper's regime:
N = padded vocab rows, K large, cosine geometry after L2-normalisation.
Sparsify by keeping the top-t components per row (embeddings are near-sparse
after normalisation) and cluster with ES-ICP vs MIVI.

    PYTHONPATH=src python examples/cluster_embeddings.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import init_params
from repro.sparse import from_dense, l2_normalize_rows, remap_terms_by_df, df_counts
from repro.cluster import ClusterConfig, fit


def main():
    import dataclasses
    # reduced qwen config but with a vocabulary large enough for the paper's
    # regime (the technique needs K and N in the thousands to bite)
    cfg = dataclasses.replace(smoke_config("qwen2.5-32b"), vocab=4096)
    params = init_params(cfg, jax.random.PRNGKey(0))
    emb = np.asarray(params["embed"])           # (Vpad, D)
    emb = emb[:cfg.vocab]

    # top-t sparsification (keeps the cosine structure, paper-style sparsity)
    t = 16
    idx = np.argpartition(-np.abs(emb), t, axis=1)[:, :t]
    sparse = np.zeros_like(emb)
    np.put_along_axis(sparse, idx, np.take_along_axis(emb, idx, axis=1), axis=1)
    sparse = np.abs(sparse)                      # similarity weights >= 0

    docs = l2_normalize_rows(from_dense(sparse))
    df = df_counts(docs)
    docs, perm = remap_terms_by_df(docs, df=df)

    results = {}
    for algo in ("mivi", "esicp"):
        cfg = ClusterConfig(k=64, algo=algo, max_iter=25, batch_size=1024)
        r = fit(docs, cfg, df=df[perm])
        results[algo] = r
        mult = np.mean([h["mult"] for h in r.history])
        print(f"{algo:6s}: iters={r.n_iter} avg_mult={mult:.4g} "
              f"J={r.objective:.2f}")
    same = bool((results["mivi"].labels == results["esicp"].labels).all())
    ratio = (np.mean([h["mult"] for h in results["esicp"].history])
             / np.mean([h["mult"] for h in results["mivi"].history]))
    print(f"identical clusterings: {same}; ES-ICP mult ratio: {ratio:.3f}")


if __name__ == "__main__":
    main()
