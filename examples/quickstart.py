"""Quickstart: cluster a synthetic document corpus with ES-ICP.

Uses the unified ``repro.cluster`` facade: one declarative ClusterConfig in,
one serializable FittedModel out — the same artifact the serving engine and
the mesh runtime consume.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --smoke   # tiny corpus (CI)
"""
import argparse
import os
import tempfile

import numpy as np

from repro.data import make_corpus, CorpusSpec
from repro.cluster import ClusterConfig, ClusterEngine, FittedModel, fit
from repro.core import metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic corpus so CI can smoke-run the "
                         "example end to end in seconds")
    args = ap.parse_args()

    if args.smoke:
        spec = CorpusSpec(n_docs=400, vocab=512, nt_mean=20, n_topics=8,
                          seed=0)
        cfg = ClusterConfig(k=8, algo="esicp", batch_size=128, max_iter=12)
    else:
        spec = CorpusSpec(n_docs=8_000, vocab=4_096, nt_mean=60, n_topics=64,
                          seed=0)
        cfg = ClusterConfig(k=64, algo="esicp", batch_size=2048, max_iter=30)

    print("generating a UC-faithful corpus (Zipf df, tf-idf, unit sphere)…")
    docs, df, perm, topics = make_corpus(spec)

    model = fit(docs, cfg, df=df)

    print(f"converged={model.converged} after {model.n_iter} iterations")
    print(f"objective J = {model.objective:.2f}")
    print(f"structural parameters: t_th={int(model.params.t_th)} "
          f"({int(model.params.t_th)/docs.dim:.2f}·D), "
          f"v_th={float(model.params.v_th):.4f}")
    h0, hl = model.history[1], model.history[-1]
    print(f"Mult/iteration: {h0['mult']:.3g} → {hl['mult']:.3g}; "
          f"CPR: {h0['cpr']:.4f} → {hl['cpr']:.4f}")
    print(f"NMI vs generating topics: "
          f"{metrics.nmi(model.labels, np.asarray(topics)):.3f}")

    # One artifact, three runtimes: save → load → serve.
    path = os.path.join(tempfile.mkdtemp(), "model")
    model.save(path)
    reloaded = FittedModel.load(path)
    engine = ClusterEngine.from_model(reloaded)
    served, _ = engine.classify(docs)
    assert (served == model.labels).all(), "serve/train disagreement!"
    print(f"saved → loaded → served: {path} "
          f"(classify parity on {docs.n_docs} docs ✓)")


if __name__ == "__main__":
    main()
