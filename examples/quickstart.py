"""Quickstart: cluster a synthetic document corpus with ES-ICP.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --smoke   # tiny corpus (CI)
"""
import argparse

import numpy as np

from repro.data import make_corpus, CorpusSpec
from repro.core import SphericalKMeans, metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic corpus so CI can smoke-run the "
                         "example end to end in seconds")
    args = ap.parse_args()

    if args.smoke:
        spec = CorpusSpec(n_docs=400, vocab=512, nt_mean=20, n_topics=8,
                          seed=0)
        k, batch_size, max_iter = 8, 128, 12
    else:
        spec = CorpusSpec(n_docs=8_000, vocab=4_096, nt_mean=60, n_topics=64,
                          seed=0)
        k, batch_size, max_iter = 64, 2048, 30

    print("generating a UC-faithful corpus (Zipf df, tf-idf, unit sphere)…")
    docs, df, perm, topics = make_corpus(spec)

    km = SphericalKMeans(k=k, algo="esicp", max_iter=max_iter,
                         batch_size=batch_size)
    res = km.fit(docs, df=df)

    print(f"converged={res.converged} after {res.n_iter} iterations")
    print(f"objective J = {res.objective:.2f}")
    print(f"structural parameters: t_th={int(res.params.t_th)} "
          f"({int(res.params.t_th)/docs.dim:.2f}·D), "
          f"v_th={float(res.params.v_th):.4f}")
    h0, hl = res.history[1], res.history[-1]
    print(f"Mult/iteration: {h0['mult']:.3g} → {hl['mult']:.3g}; "
          f"CPR: {h0['cpr']:.4f} → {hl['cpr']:.4f}")
    print(f"NMI vs generating topics: "
          f"{metrics.nmi(res.assign, np.asarray(topics)):.3f}")


if __name__ == "__main__":
    main()
