"""Quickstart: cluster a synthetic document corpus with ES-ICP.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.data import make_corpus, CorpusSpec
from repro.core import SphericalKMeans, metrics


def main():
    print("generating a UC-faithful corpus (Zipf df, tf-idf, unit sphere)…")
    docs, df, perm, topics = make_corpus(
        CorpusSpec(n_docs=8_000, vocab=4_096, nt_mean=60, n_topics=64, seed=0))

    km = SphericalKMeans(k=64, algo="esicp", max_iter=30, batch_size=2048)
    res = km.fit(docs, df=df)

    print(f"converged={res.converged} after {res.n_iter} iterations")
    print(f"objective J = {res.objective:.2f}")
    print(f"structural parameters: t_th={int(res.params.t_th)} "
          f"({int(res.params.t_th)/docs.dim:.2f}·D), "
          f"v_th={float(res.params.v_th):.4f}")
    h0, hl = res.history[1], res.history[-1]
    print(f"Mult/iteration: {h0['mult']:.3g} → {hl['mult']:.3g}; "
          f"CPR: {h0['cpr']:.4f} → {hl['cpr']:.4f}")
    print(f"NMI vs generating topics: "
          f"{metrics.nmi(res.assign, np.asarray(topics)):.3f}")


if __name__ == "__main__":
    main()
