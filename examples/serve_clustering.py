"""Serving a fitted clustering model: fit → save → load into a live
continuous-batching server → concurrent clients → refit → zero-downtime
hot-swap.

Demonstrates the serving plane (DESIGN.md §12):

  1. ``fit`` produces a :class:`FittedModel` artifact, saved and re-loaded
     exactly as a production pipeline would hand it from trainer to server;
  2. :class:`ClusterServer` hosts the artifact behind per-model request
     queues, a continuous batcher with padded batch-size buckets (every
     device launch hits an already-compiled shape), ``max_live_batches``
     admission control and an async device thread;
  3. concurrent client threads classify random slices and every response
     is checked bit-identical to the direct ``ClusterEngine.classify``;
  4. ``ClusterEngine.refit`` rebuilds the index from a fresh corpus
     (streamed chunk by chunk when given a DocStore) and ``server.swap``
     reroutes traffic atomically — in-flight batches finish on the old
     index, no request fails, and a same-geometry swap costs zero
     recompiles.

    PYTHONPATH=src python examples/serve_clustering.py
    PYTHONPATH=src python examples/serve_clustering.py --smoke   # tiny (CI)
"""
import argparse
import os
import shutil
import tempfile
import threading

import numpy as np

from repro.cluster import ClusterConfig, FittedModel, fit
from repro.data import make_corpus, CorpusSpec
from repro.serve import ClusterEngine, ClusterServer
from repro.sparse import DocStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic corpus so CI can smoke-run the "
                         "example end to end in seconds")
    args = ap.parse_args()

    if args.smoke:
        spec = CorpusSpec(n_docs=800, vocab=512, nt_mean=20, n_topics=8,
                          seed=0)
        k, n_clients, n_req = 8, 4, 10
    else:
        spec = CorpusSpec(n_docs=20_000, vocab=4_096, nt_mean=60,
                          n_topics=64, seed=0)
        k, n_clients, n_req = 64, 8, 50

    # ---- trainer side: fit and persist the artifact ----------------------
    docs, df, perm, topics = make_corpus(spec)
    model = fit(docs, ClusterConfig(k=k, algo="esicp", max_iter=10, seed=0),
                df=df)
    workdir = tempfile.mkdtemp(prefix="serve_clustering_")
    model.save(os.path.join(workdir, "model"))
    print(f"[fit]   k={k} n_iter={model.n_iter} J={model.objective:.2f} "
          f"→ saved to {workdir}/model")

    # ---- server side: load the artifact into a live server ---------------
    served = FittedModel.load(os.path.join(workdir, "model"))
    a_ref, _ = ClusterEngine.from_model(served).classify(docs)
    ids, vals, nnz = (np.asarray(docs.ids), np.asarray(docs.vals),
                      np.asarray(docs.nnz))

    with ClusterServer(max_live_batches=4) as server:
        server.load("news", served)
        print(f"[serve] hosting {server.registry.names()} with buckets "
              f"{server.stats('news')['buckets']}")

        # ---- concurrent clients ------------------------------------------
        bad = []

        def client(ci):
            rng = np.random.RandomState(100 + ci)
            for _ in range(n_req):
                size = int(rng.randint(1, 200))
                lo = int(rng.randint(0, spec.n_docs - size + 1))
                a, _ = server.classify(
                    "news", (ids[lo:lo + size], vals[lo:lo + size],
                             nnz[lo:lo + size]))
                if not (a == a_ref[lo:lo + size]).all():
                    bad.append(ci)

        threads = [threading.Thread(target=client, args=(ci,))
                   for ci in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # Warm the exact bucket the post-swap probe will use, so the
        # compile-count comparison below is deterministic.
        server.classify("news", (ids[:128], vals[:128], nnz[:128]))
        stats = server.stats("news")
        assert not bad and stats["n_failures"] == 0, "serving parity broke!"
        occ = {b: round(v["mean_occupancy"], 2)
               for b, v in stats["occupancy"].items()}
        print(f"[load]  {stats['n_requests']} requests "
              f"({stats['n_rows']} rows) in {stats['n_batches']} batches, "
              f"mean latency {stats['mean_server_latency_ms']:.2f} ms, "
              f"occupancy {occ}, compiles {stats['compile_counts']} ✓")

        # ---- refit on fresh data, hot-swap with zero downtime ------------
        engine = ClusterEngine.from_model(served)
        store = DocStore.from_docs(docs, chunk_size=max(spec.n_docs // 4, 1))
        engine.refit(store, n_iter=2)        # streams chunk by chunk
        a_new, _ = engine.classify(docs)
        server.swap("news", engine.to_model())
        a_post, _ = server.classify("news", (ids[:128], vals[:128],
                                             nnz[:128]))
        assert (a_post == a_new[:128]).all(), "post-swap routing broke!"
        compiles_after = server.stats("news")["compile_counts"]
        assert compiles_after == stats["compile_counts"], \
            "same-geometry hot-swap must not recompile!"
        print(f"[swap]  refit on a {store.n_chunks}-chunk store, hot-swapped "
              f"atomically; compiles unchanged {compiles_after} ✓")

    shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
