"""Paper App. D — ablation: ES (both thresholds) vs ThV (v_th only) vs
ThT (t_th only) vs MIVI.

Paper's finding: v_th carries the pruning power (ThV ≈ ES on Mult), t_th
carries the memory bound (ThT prunes barely but keeps M^p small).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import corpus, csv_row, make_estimator
from repro.core import StructuralParams
from repro.core.estparams import estimate_params, EstGrid


def run():
    job, docs, df, perm, topics = corpus("pubmed")

    # ES: both estimated.  ThV: t_th = 0.  ThT: v_th = max (vacuous bound).
    warm = make_estimator(k=job.k, algo="mivi", max_iter=2, batch_size=4096,
                           seed=0).fit(docs, df=df)
    est, _ = estimate_params(docs, df, warm.state_.index.means_t,
                             warm.state_.rho_self, k=job.k)
    vmax = float(warm.state_.index.means_t.max())
    variants = {
        "mivi": ("mivi", None),
        "es": ("es", est),
        "thv": ("es", StructuralParams(t_th=jnp.asarray(0, jnp.int32),
                                       v_th=est.v_th)),
        "tht": ("es", StructuralParams(t_th=est.t_th,
                                       v_th=jnp.asarray(vmax, jnp.float32))),
    }
    stats = {}
    ref = None
    for name, (algo, params) in variants.items():
        r = make_estimator(k=job.k, algo=algo,
                            params=params if params is not None else "auto",
                            max_iter=10, batch_size=4096, seed=0).fit(docs, df=df)
        if ref is None:
            ref = r
        assert (r.labels_ == ref.labels_).all(), f"{name} broke exactness"
        stats[name] = (np.mean([h["mult"] for h in r.history_]),
                       r.history_[-1]["cpr"],
                       int(params.t_th) if params is not None else 0)
    base = stats["mivi"][0]
    rows = []
    for name, (m, cpr, t_th) in stats.items():
        mem_tail = job.k * (docs.dim - t_th)     # M^p memory proxy
        rows.append(csv_row(f"ablation/{name}", 0,
                            f"mult_ratio={m / base:.4f};cpr={cpr:.4g};"
                            f"mp_mem={mem_tail:.3g}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
