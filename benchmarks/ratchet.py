"""Perf ratchet over the machine-readable bench artifacts (the CI bench
jobs' gate): ``BENCH_kernels.json`` (kernel checks below),
``BENCH_pruning.json`` (the compounded-pruning invariants of
:mod:`benchmarks.pruning_suite` — see :func:`check_pruning`),
``BENCH_serving.json`` (:func:`check_serving`) and ``BENCH_ivf.json``
(the two-level routed-classify invariants — see :func:`check_ivf`).
``main`` dispatches on the rows' names, so every file runs through the
same entry point: ``python -m benchmarks.ratchet <file.json>``.

Kernel checks:

1. **XLA-blocked compiled ratchet — enforced everywhere.**  The
   ``xla_blocked`` engine (kernels/xla_blocked.py) always compiles, so its
   rows are ``comparable: true`` on every platform — including the stock
   CPU CI runner.  For each of the four kernels the suite MUST emit
   comparable xla_blocked rows, and the best such ``speedup`` vs the jnp
   reference must be >= 1.0: a compiled engine that loses to the oracle it
   replaced is a regression.  Missing rows are a failure, not a skip —
   this is the gate ISSUE 10 turns on.

2. **Pallas compiled-mode ratchet** — on platforms where the Pallas
   kernels compile (pallas rows with ``comparable: true``), every kernel's
   best pallas-variant ``speedup`` vs the XLA reference must be >= 1.0.
   On interpret-only platforms (CPU runners) this engine's check is
   *skipped with a visible annotation* — an interpreter timing says
   nothing about kernel performance, and fabricating a ratchet from it
   would be worse than no ratchet.  (The xla_blocked gate above still
   runs; CPU is no longer ratchet-free.)

3. **Honesty invariants** — always enforced, every platform: interpret-mode
   rows must carry ``comparable: false`` and a null ``speedup``
   (cross-engine ratios are suppressed, never fabricated), and the
   ``speedup_vs_default`` tuned-vs-default ratio (same engine, same mode —
   valid everywhere) must be present on every ``*_tuned`` row of either
   engine.

Exit 0 = pass/skip, 1 = ratchet or honesty failure.  The ``::notice``/
``::error`` lines render as GitHub Actions annotations.
"""
from __future__ import annotations

import json
import sys

KERNELS = ("sparse_sim", "esicp_gather", "segment_update", "rho_gather")


def _kernel_of(name: str) -> str | None:
    for k in KERNELS:
        if name.startswith(f"kernel_suite/{k}_"):
            return k
    return None


def check(rows: list[dict]) -> int:
    engines = [r for r in rows
               if r.get("backend") in ("pallas", "xla_blocked")
               and _kernel_of(r["name"])]
    pallas = [r for r in engines if r["backend"] == "pallas"]
    xla = [r for r in engines if r["backend"] == "xla_blocked"]
    if not pallas:
        print("::error::BENCH_kernels.json holds no pallas kernel rows")
        return 1

    failures = []

    # -- honesty invariants (every platform, both engines) -----------------
    for r in engines:
        if r.get("interpret") and (r.get("comparable") or
                                   r.get("speedup") is not None):
            failures.append(
                f"{r['name']}: interpret-mode row claims a cross-engine "
                f"speedup (comparable={r.get('comparable')}, "
                f"speedup={r.get('speedup')})")
    tuned_rows = [r for r in engines if r["name"].endswith("_tuned")]
    for r in tuned_rows:
        if "speedup_vs_default" not in r:
            failures.append(f"{r['name']}: tuned row missing the same-mode "
                            f"speedup_vs_default ratio")

    # -- tuned-vs-default report (same-mode, valid everywhere) -------------
    for r in tuned_rows:
        sv = r.get("speedup_vs_default")
        if sv is not None:
            print(f"{r['name']}: tuned vs default {sv:.4f}x "
                  f"({r.get('mode', '?')} mode)")

    # -- xla_blocked compiled ratchet (enforced on EVERY platform) ---------
    xla_comparable = [r for r in xla if r.get("comparable")]
    for k in KERNELS:
        krows = [r for r in xla_comparable if _kernel_of(r["name"]) == k]
        if not krows:
            failures.append(
                f"{k}: no comparable xla_blocked rows — the compiled-engine "
                f"ratchet has nothing to gate on (the suite must emit them "
                f"on every platform)")
            continue
        best = max((r.get("speedup") or 0.0) for r in krows)
        print(f"{k}: best xla_blocked speedup vs reference {best:.4f}x")
        if best < 1.0:
            failures.append(f"{k}: xla_blocked speedup {best:.4f} < 1.0 — "
                            f"the compiled engine lost to the jnp reference "
                            f"it replaces")

    # -- pallas compiled-mode ratchet (TPU; skip-with-notice elsewhere) ----
    comparable = [r for r in pallas if r.get("comparable")]
    if not comparable:
        plat = pallas[0].get("platform", "?")
        print(f"::notice title=pallas ratchet skipped::compiled Pallas is "
              f"unavailable on platform={plat!r} (interpret-only); the "
              f"pallas speedup-vs-reference ratchet needs compiled kernels "
              f"and was not evaluated (the xla_blocked ratchet above still "
              f"gates this platform)")
    else:
        for k in KERNELS:
            best = max((r.get("speedup") or 0.0) for r in comparable
                       if _kernel_of(r["name"]) == k)
            print(f"{k}: best compiled pallas speedup vs reference "
                  f"{best:.4f}x")
            if best < 1.0:
                failures.append(f"{k}: compiled-mode pallas speedup "
                                f"{best:.4f} < 1.0 — the kernel lost to the "
                                f"XLA reference it replaces")

    for msg in failures:
        print(f"::error title=kernel ratchet::{msg}")
    return 1 if failures else 0


SINGLE_TECHNIQUES = ("mivi", "icp", "es", "esicp", "bounds", "sketch")
COMBINED = "bounds-esicp"


def check_pruning(rows: list[dict]) -> int:
    """Compounded-pruning invariants over ``BENCH_pruning.json``.

    1. **Bounded/sketch Mult ratchet** — at every iteration, the ``bounds``
       and ``sketch`` rows must report Mult <= the matched ``mivi`` row:
       a pruning mode whose honest cost accounting exceeds the exhaustive
       scan it replaces is a regression, whatever the wall clock says.
    2. **Compounding ratchet** — on iterations >= 2 the combined
       ``bounds-esicp`` row must be *strictly* below every single
       technique's row: the whole point of stacking the three filter
       families is that none of them alone reaches the compound's Mult.
       (Iteration 1 is exempt by construction: no ρ history exists, so
       every bound degenerates and the ES-family modes pay the one-time
       region-accumulation premium.)
    3. **Honesty invariants** — a ``speedup`` is only admissible against
       the row named by ``vs`` when both ran the same execution mode and
       backend (``comparable`` must say false otherwise): an interpret-mode
       fit against a compiled one measures the interpreter, and a
       cross-backend ratio measures the engine swap, not the pruning.
    """
    failures = []
    by_name = {r["name"]: r for r in rows}
    iters: dict[int, dict[str, float]] = {}
    for r in rows:
        if "iteration" in r and "mult" in r:
            iters.setdefault(int(r["iteration"]), {})[r["algo"]] = r["mult"]
    if not iters:
        print("::error::no pruning iteration rows found")
        return 1

    for it in sorted(iters):
        v = iters[it]
        if "mivi" not in v:
            failures.append(f"iteration {it}: no mivi baseline row")
            continue
        for m in ("bounds", "sketch"):
            if m in v and v[m] > v["mivi"]:
                failures.append(
                    f"iteration {it}: {m} Mult {v[m]:.0f} > mivi "
                    f"{v['mivi']:.0f} — the bounded mode lost to the "
                    f"exhaustive scan")
        if it >= 2 and COMBINED in v:
            for m in SINGLE_TECHNIQUES:
                if m in v and not v[COMBINED] < v[m]:
                    failures.append(
                        f"iteration {it}: {COMBINED} Mult {v[COMBINED]:.0f} "
                        f">= {m} {v[m]:.0f} — compounding failed to beat "
                        f"the single technique")
    for it in sorted(iters):
        v = iters[it]
        if COMBINED in v and it >= 2:
            best_single = min(v[m] for m in SINGLE_TECHNIQUES if m in v)
            print(f"pruning iter {it}: combined {v[COMBINED]:.3e} vs best "
                  f"single {best_single:.3e} "
                  f"({v[COMBINED] / best_single:.3f}x)")

    for r in rows:
        if r.get("speedup") is None and not r.get("comparable"):
            continue
        ref = by_name.get(r.get("vs", ""))
        if ref is None:
            failures.append(f"{r['name']}: speedup with no resolvable "
                            f"vs={r.get('vs')!r} row")
        elif (r.get("mode"), r.get("backend")) != (ref.get("mode"),
                                                  ref.get("backend")):
            failures.append(
                f"{r['name']}: marked comparable across execution modes "
                f"({r.get('backend')}/{r.get('mode')} vs {ref['name']}'s "
                f"{ref.get('backend')}/{ref.get('mode')})")

    for msg in failures:
        print(f"::error title=pruning ratchet::{msg}")
    if not failures:
        print(f"pruning ratchet: {len(iters)} iterations checked, "
              f"all invariants hold")
    return 1 if failures else 0


def check_serving(rows: list[dict]) -> int:
    """Serving-plane invariants over ``BENCH_serving.json``
    (:mod:`benchmarks.serving_suite`).

    1. **Liveness** — the load run completed traffic: ``qps > 0`` and the
       latency distribution is sane (``p99_ms >= p50_ms > 0``).
    2. **Zero failed requests** — the mid-run hot-swap is zero-downtime by
       contract; a single failed request (including a torn-index parity
       mismatch, ``parity: false``) fails the gate.
    3. **Occupancy honesty** — every bucket row reports
       ``0 < mean_occupancy <= 1``: dead-row padding can dilute a batch but
       a bucket can never run more live rows than its padded size.
    4. **No steady-state recompilation** — every bucket compiles at most
       ONCE across the whole run (first use), and the post-swap warm-bucket
       probe adds ZERO traces (``recompiles_after_warm == 0``): the index
       is a traced argument, so a same-geometry hot-swap is free.
    5. **Admission control held** — ``peak_live_batches`` never exceeded
       the configured ``max_live_batches``.
    """
    failures = []
    lat = next((r for r in rows if r["name"] == "serving/latency"), None)
    if lat is None:
        print("::error::BENCH_serving.json holds no serving/latency row")
        return 1
    if not lat.get("qps", 0) > 0:
        failures.append(f"serving/latency: qps {lat.get('qps')} — the load "
                        f"run completed no traffic")
    p50, p99 = lat.get("p50_ms", 0), lat.get("p99_ms", 0)
    if not 0 < p50 <= p99:
        failures.append(f"serving/latency: implausible percentiles "
                        f"p50={p50}ms p99={p99}ms")
    if lat.get("n_failures", 1) != 0:
        failures.append(f"serving/latency: {lat.get('n_failures')} failed "
                        f"requests — hot-swap/admission must not drop traffic")
    if not lat.get("parity", False):
        failures.append("serving/latency: parity false — some response "
                        "matched neither live index (torn or wrong results)")
    if lat.get("peak_live_batches", 0) > lat.get("max_live_batches", 0):
        failures.append(
            f"serving/latency: peak_live_batches "
            f"{lat.get('peak_live_batches')} > max_live_batches "
            f"{lat.get('max_live_batches')} — admission control breached")

    buckets = [r for r in rows if r["name"].startswith("serving/bucket")]
    if not buckets:
        failures.append("no serving/bucket rows — the run served no batches")
    for r in buckets:
        occ = r.get("mean_occupancy", -1)
        if not 0 < occ <= 1:
            failures.append(f"{r['name']}: mean_occupancy {occ} outside "
                            f"(0, 1]")
        if r.get("compiles", 99) > 1:
            failures.append(f"{r['name']}: {r.get('compiles')} compiles — "
                            f"steady-state serving recompiled a bucket")

    swap = next((r for r in rows if r["name"] == "serving/swap"), None)
    if swap is None:
        failures.append("no serving/swap row — the mid-run hot-swap did "
                        "not happen")
    elif swap.get("recompiles_after_warm", 99) != 0:
        failures.append(
            f"serving/swap: {swap.get('recompiles_after_warm')} traces on "
            f"warm buckets after the swap — a same-geometry hot-swap must "
            f"cost zero recompiles")

    for msg in failures:
        print(f"::error title=serving ratchet::{msg}")
    if not failures:
        print(f"serving ratchet: p50 {p50}ms / p99 {p99}ms at "
              f"{lat['qps']} qps over {lat['n_requests']} requests, "
              f"{len(buckets)} buckets, 0 failures — all invariants hold")
    return 1 if failures else 0


def check_ivf(rows: list[dict]) -> int:
    """Two-level IVF invariants over ``BENCH_ivf.json``
    (:mod:`benchmarks.ivf_suite`).

    1. **Mult ratchet** — at every scale point with effective K >= 4096,
       the ``routed_p1`` row's ``mult_per_doc`` must be strictly below the
       flat row's: the routed classify scores K_c + Σ probed cell sizes
       centroids per object, and if that honest count does not beat the
       exhaustive scan the two-level structure earned nothing.
    2. **Wall ratchet** — same scale points: ``routed_p1`` wall-clock
       ``speedup`` vs the flat scan must be >= 1.0 (same backend, same
       mode — the ``vs`` honesty check below makes that comparison valid).
    3. **Recall honesty** — every routed row probing fewer than all K_c
       cells MUST report ``recall_at1`` vs the flat argmax.  Approximate
       settings are allowed; silently dropping the accuracy number is not.
    4. **Scored-count contract** — ``scored_max <= scored_bound``
       (= K_c + max cell size at n_probe=1): the per-object candidate
       count the Mult accounting is built on, asserted, not assumed.
    5. **Exactness** — the ``routed_exact`` (n_probe = K_c) row must be
       bit-identical to the flat scan (``exact_match: true``): probing
       every cell IS the exhaustive algorithm, not an approximation of it.
    6. **Speedup honesty** — as in the other suites, every ``speedup``
       must name a resolvable ``vs`` row with the same backend and
       execution mode.
    """
    failures = []
    by_name = {r["name"]: r for r in rows}
    scale_points = sorted({r["name"].split("/")[1] for r in rows
                           if r["name"].startswith("ivf/K")})
    if not scale_points:
        print("::error::BENCH_ivf.json holds no ivf/K* rows")
        return 1

    for kp in scale_points:
        flat = by_name.get(f"ivf/{kp}/flat_classify")
        p1 = by_name.get(f"ivf/{kp}/routed_p1")
        exact = by_name.get(f"ivf/{kp}/routed_exact")
        if flat is None:
            failures.append(f"{kp}: no flat_classify baseline row")
            continue
        k_eff = int(flat.get("k_eff", 0))
        gate = k_eff >= 4096

        if p1 is None:
            failures.append(f"{kp}: no routed_p1 row")
        else:
            if gate and not p1["mult_per_doc"] < flat["mult_per_doc"]:
                failures.append(
                    f"{kp}: routed_p1 mult_per_doc {p1['mult_per_doc']:.0f} "
                    f">= flat {flat['mult_per_doc']:.0f} — routing failed "
                    f"to prune the scan at K_eff={k_eff}")
            if gate and not (p1.get("speedup") or 0.0) >= 1.0:
                failures.append(
                    f"{kp}: routed_p1 speedup {p1.get('speedup')} < 1.0 — "
                    f"routed classify lost to the flat scan it replaces at "
                    f"K_eff={k_eff}")
            if p1.get("scored_max", 0) > p1.get("scored_bound", 0):
                failures.append(
                    f"{kp}: scored_max {p1.get('scored_max')} > bound "
                    f"K_c + cmax = {p1.get('scored_bound')} — the routed "
                    f"candidate-count contract is broken")

        for r in rows:
            if (r["name"].startswith(f"ivf/{kp}/routed_p")
                    and r.get("n_probe", 0) < r.get("k_c", 0)
                    and "recall_at1" not in r):
                failures.append(f"{r['name']}: approximate routed row "
                                f"without recall_at1 — the accuracy cost "
                                f"must never be silently dropped")

        if exact is None:
            failures.append(f"{kp}: no routed_exact (n_probe=K_c) row")
        elif not exact.get("exact_match", False):
            failures.append(
                f"{kp}: routed_exact is not bit-identical to the flat scan "
                f"— n_probe=K_c must BE the exhaustive algorithm")

    for r in rows:
        if r.get("speedup") is None and not r.get("comparable"):
            continue
        if "speedup" not in r:
            continue
        ref = by_name.get(r.get("vs", ""))
        if ref is None:
            failures.append(f"{r['name']}: speedup with no resolvable "
                            f"vs={r.get('vs')!r} row")
        elif (r.get("mode"), r.get("backend")) != (ref.get("mode"),
                                                  ref.get("backend")):
            failures.append(
                f"{r['name']}: marked comparable across execution modes "
                f"({r.get('backend')}/{r.get('mode')} vs {ref['name']}'s "
                f"{ref.get('backend')}/{ref.get('mode')})")

    for kp in scale_points:
        flat, p1 = by_name.get(f"ivf/{kp}/flat_classify"), \
            by_name.get(f"ivf/{kp}/routed_p1")
        if flat and p1:
            print(f"ivf {kp}: routed_p1 mult {p1['mult_per_doc']:.3e} vs "
                  f"flat {flat['mult_per_doc']:.3e} "
                  f"({flat['mult_per_doc'] / p1['mult_per_doc']:.1f}x "
                  f"fewer), wall speedup {p1.get('speedup')}x, "
                  f"recall@1 {p1.get('recall_at1')}")

    for msg in failures:
        print(f"::error title=ivf ratchet::{msg}")
    if not failures:
        print(f"ivf ratchet: {len(scale_points)} scale points checked, "
              f"all invariants hold")
    return 1 if failures else 0


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_kernels.json"
    with open(path) as f:
        rows = json.load(f)
    if any(str(r.get("name", "")).startswith("serving/") for r in rows):
        return check_serving(rows)
    if any(str(r.get("name", "")).startswith("ivf/") for r in rows):
        return check_ivf(rows)
    if any(str(r.get("name", "")).startswith("pruning/") for r in rows):
        return check_pruning(rows)
    return check(rows)


if __name__ == "__main__":
    sys.exit(main())
