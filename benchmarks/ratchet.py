"""Kernel perf ratchet over ``BENCH_kernels.json`` (the CI bench-kernels
job's gate).

Two checks:

1. **Compiled-mode ratchet** — on platforms where the Pallas kernels
   compile (rows with ``comparable: true``), every kernel's best
   pallas-variant ``speedup`` vs the XLA reference must be >= 1.0: a
   compiled kernel that loses to the oracle it replaced is a regression,
   and the whole point of the engine.  On interpret-only platforms (CPU
   runners) the check is *skipped with a visible annotation* — an
   interpreter timing says nothing about kernel performance, and
   fabricating a ratchet from it would be worse than no ratchet.

2. **Honesty invariants** — always enforced, every platform: interpret-mode
   pallas rows must carry ``comparable: false`` and a null ``speedup``
   (cross-engine ratios are suppressed, never fabricated), and the
   ``speedup_vs_default`` tuned-vs-default ratio (same engine, same mode —
   valid everywhere) must be present on every tuned row.

Exit 0 = pass/skip, 1 = ratchet or honesty failure.  The ``::notice``/
``::error`` lines render as GitHub Actions annotations.
"""
from __future__ import annotations

import json
import sys

KERNELS = ("sparse_sim", "esicp_gather", "segment_update", "rho_gather")


def _kernel_of(name: str) -> str | None:
    for k in KERNELS:
        if name.startswith(f"kernel_suite/{k}_"):
            return k
    return None


def check(rows: list[dict]) -> int:
    pallas = [r for r in rows
              if r.get("backend") == "pallas" and _kernel_of(r["name"])]
    if not pallas:
        print("::error::BENCH_kernels.json holds no pallas kernel rows")
        return 1

    failures = []

    # -- honesty invariants (every platform) -------------------------------
    for r in pallas:
        if r.get("interpret") and (r.get("comparable") or
                                   r.get("speedup") is not None):
            failures.append(
                f"{r['name']}: interpret-mode row claims a cross-engine "
                f"speedup (comparable={r.get('comparable')}, "
                f"speedup={r.get('speedup')})")
    tuned_rows = [r for r in pallas if r["name"].endswith("_pallas_tuned")]
    for r in tuned_rows:
        if "speedup_vs_default" not in r:
            failures.append(f"{r['name']}: tuned row missing the same-mode "
                            f"speedup_vs_default ratio")

    # -- tuned-vs-default report (same-mode, valid everywhere) -------------
    for r in tuned_rows:
        sv = r.get("speedup_vs_default")
        if sv is not None:
            print(f"{r['name']}: tuned vs default {sv:.4f}x "
                  f"({r.get('mode', '?')} mode)")

    # -- compiled-mode ratchet ---------------------------------------------
    comparable = [r for r in pallas if r.get("comparable")]
    if not comparable:
        plat = pallas[0].get("platform", "?")
        print(f"::notice title=kernel ratchet skipped::compiled Pallas is "
              f"unavailable on platform={plat!r} (interpret-only); the "
              f"speedup-vs-reference ratchet needs compiled kernels and "
              f"was not evaluated")
    else:
        for k in KERNELS:
            best = max((r.get("speedup") or 0.0) for r in comparable
                       if _kernel_of(r["name"]) == k)
            print(f"{k}: best compiled speedup vs reference {best:.4f}x")
            if best < 1.0:
                failures.append(f"{k}: compiled-mode speedup {best:.4f} < "
                                f"1.0 — the kernel lost to the XLA "
                                f"reference it replaces")

    for msg in failures:
        print(f"::error title=kernel ratchet::{msg}")
    return 1 if failures else 0


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_kernels.json"
    with open(path) as f:
        rows = json.load(f)
    return check(rows)


if __name__ == "__main__":
    sys.exit(main())
