"""Paper Tables IV/VI — algorithm comparison on (reduced) PubMed / NYT.

Columns mirror the paper: Avg Mult (per iteration), Avg time, final CPR,
max memory proxy (index + verification structures), as RATIOS to ES-ICP —
the paper's Table IV normalisation.  Exactness (identical assignments) is
asserted, because acceleration without exactness is a different paper.
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import corpus, csv_row, make_estimator

ALGOS = ["mivi", "icp", "cs-icp", "ta-icp", "esicp"]


def _mem_proxy(algo: str, d: int, k: int, t_th: int) -> float:
    """Paper's Max MEM driver: the partial mean-inverted index M^p (§IV-A).
    mivi/icp: none; esicp: K*(D - t_th); ta/cs: K*(D - t_th) + extra arrays."""
    tail = max(d - t_th, 0)
    return {
        "mivi": d * k, "icp": d * k,
        "esicp": d * k + k * tail,
        "cs-icp": d * k + 2 * k * tail,
        "ta-icp": d * k + 2 * k * tail,
    }[algo]


def run(dataset: str = "pubmed"):
    job, docs, df, perm, topics = corpus(dataset)
    results = {}
    for algo in ALGOS:
        r = make_estimator(k=job.k, algo=algo, max_iter=job.max_iter,
                            batch_size=4096, seed=0).fit(docs, df=df)
        results[algo] = r
    ref = results["mivi"]
    es = results["esicp"]
    for algo, r in results.items():
        assert (r.labels_ == ref.labels_).all(), f"{algo} broke exactness!"

    def stats(r):
        mult = np.mean([h["mult"] for h in r.history_])
        t = np.mean([h["elapsed_s"] for h in r.history_])
        cpr = r.history_[-1]["cpr"]
        mem = _mem_proxy_for(r)
        return mult, t, cpr, mem

    def _mem_proxy_for(r):
        return _mem_proxy(r_algo[id(r)], docs.dim, job.k, int(r.params_.t_th))

    r_algo = {id(r): a for a, r in results.items()}
    es_stats = stats(es)
    rows = []
    for algo in ALGOS:
        m, t, cpr, mem = stats(results[algo])
        rows.append(csv_row(
            f"table4[{dataset}]/{algo}", t * 1e6,
            f"mult_ratio={m / es_stats[0]:.4g};time_ratio={t / es_stats[1]:.3g};"
            f"cpr={cpr:.4g};mem_ratio={mem / es_stats[3]:.3g};"
            f"iters={results[algo].n_iter_}"))
    return rows


if __name__ == "__main__":
    ds = sys.argv[sys.argv.index("--dataset") + 1] if "--dataset" in sys.argv else "pubmed"
    print("\n".join(run(ds)))
