"""Serving-plane load benchmark (DESIGN.md §12): a closed-loop generator
against a live :class:`repro.serve.ClusterServer`, machine-readable as
``BENCH_serving.json``.

N client threads issue classify requests of random sizes against one hosted
FittedModel; halfway through, the model hot-swaps to a refreshed index
(different init) while traffic keeps flowing.  Rows:

  ``serving/latency``    — the headline row: ``us_per_call`` = mean
      end-to-end request latency, plus ``p50_ms``/``p99_ms``, ``qps``
      (completed requests / wall), request/row/failure counts and the
      ``parity`` verdict (every response bit-identical to the direct
      ``ClusterEngine.classify`` on one of the two live indices — a
      response matching neither would be a torn index).
  ``serving/bucket<B>``  — per padded batch-size bucket: ``batches``,
      ``mean_occupancy`` (live rows / bucket — must sit in (0, 1]) and
      ``compiles`` (jit traces charged to the bucket during the run,
      measured as a ``servable.compile_counts`` delta — at most ONE, the
      no-steady-state-recompilation invariant).
  ``serving/swap``       — ``us_per_call`` = hot-swap wall time;
      ``recompiles_after_warm`` counts traces added by post-swap requests
      on already-compiled buckets (must be 0: the index is a traced
      argument, so a same-geometry swap never recompiles).

``benchmarks/ratchet.py check_serving`` gates all of the above.
``REPRO_BENCH_SMOKE=1`` shrinks the corpus and the client budget (the
invariants are structural, not scale statements).
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np

from benchmarks.common import bench_row, default_backend
from repro.cluster import ClusterConfig, fit
from repro.data import make_corpus
from repro.data.synthetic import CorpusSpec
from repro.serve import ClusterEngine, ClusterServer

K = 16
BATCH_SIZES = (16, 32, 64, 128)
SEED = 0


def _sizing(smoke: bool):
    if smoke:
        spec = CorpusSpec(n_docs=2000, vocab=1024, nt_mean=30.0,
                          n_topics=16, seed=3)
        return spec, 4, 25          # clients, requests per client
    spec = CorpusSpec(n_docs=12000, vocab=8192, nt_mean=60.0,
                      n_topics=48, seed=3)
    return spec, 8, 150


def run():
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    backend = default_backend()
    spec, n_clients, n_req = _sizing(smoke)
    docs, df, _, _ = make_corpus(spec)
    ids = np.asarray(docs.ids)
    vals = np.asarray(docs.vals)
    nnz = np.asarray(docs.nnz)

    cfg = dict(k=K, max_iter=4, batch_size=4096, backend=backend)
    model_a = fit(docs, ClusterConfig(seed=1, **cfg), df=df)
    model_b = fit(docs, ClusterConfig(seed=7, **cfg), df=df)
    # Direct-path ground truth for BOTH live indices: under a mid-run swap
    # every response must match one of them exactly (parity), whichever
    # index its batch was assembled against (atomicity).
    a_ref_a, _ = ClusterEngine.from_model(model_a).classify(docs)
    a_ref_b, _ = ClusterEngine.from_model(model_b).classify(docs)

    lock = threading.Lock()
    latencies: list[float] = []
    n_done = [0]
    n_parity_bad = [0]
    n_errors = [0]
    max_rows = BATCH_SIZES[-1]

    with ClusterServer(max_live_batches=4, batch_timeout_s=0.002) as srv:
        servable = srv.load("bench", model_a, batch_sizes=BATCH_SIZES,
                            backend=backend)
        compiles_before = servable.compile_counts()

        def client(ci: int):
            rng = np.random.RandomState(1000 + ci)
            for _ in range(n_req):
                size = int(rng.randint(1, max_rows + 1))
                lo = int(rng.randint(0, spec.n_docs - size + 1))
                hi = lo + size
                t0 = time.perf_counter()
                try:
                    a, _ = srv.classify(
                        "bench", (ids[lo:hi], vals[lo:hi], nnz[lo:hi]),
                        timeout=600)
                except Exception:
                    with lock:
                        n_errors[0] += 1
                        n_done[0] += 1
                    continue
                dt = time.perf_counter() - t0
                ok = ((a == a_ref_a[lo:hi]).all()
                      or (a == a_ref_b[lo:hi]).all())
                with lock:
                    latencies.append(dt)
                    n_done[0] += 1
                    if not ok:
                        n_parity_bad[0] += 1

        threads = [threading.Thread(target=client, args=(ci,))
                   for ci in range(n_clients)]
        t_start = time.perf_counter()
        for t in threads:
            t.start()

        # Mid-run zero-downtime hot-swap: wait for half the traffic, then
        # atomically reroute to the refreshed index while clients keep going.
        total = n_clients * n_req
        while True:
            with lock:
                if n_done[0] >= total // 2:
                    break
            time.sleep(0.002)
        t0 = time.perf_counter()
        srv.swap("bench", model_b, batch_sizes=BATCH_SIZES, backend=backend)
        swap_s = time.perf_counter() - t0

        for t in threads:
            t.join()
        wall = time.perf_counter() - t_start
        stats = srv.stats("bench")
        swapped = srv.registry.get("bench")

        # Deterministic recompile probe: every bucket the run already
        # compiled must serve the swapped index with ZERO new traces.
        warm = [b for b, c in swapped.compile_counts().items() if c > 0]
        probe_before = swapped.compile_counts()
        for b in warm:
            srv.classify("bench", (ids[:b], vals[:b], nnz[:b]), timeout=600)
        probe_after = swapped.compile_counts()
        recompiles_after_warm = sum(probe_after[b] - probe_before[b]
                                    for b in warm)
        compiles_after = swapped.compile_counts()

    lat = np.asarray(sorted(latencies), np.float64)
    n_failures = n_errors[0] + int(stats["n_failures"])
    parity = n_parity_bad[0] == 0 and lat.size > 0
    rows = [bench_row(
        "serving/latency", float(lat.mean() * 1e6) if lat.size else 0.0,
        backend,
        p50_ms=round(float(np.percentile(lat, 50)) * 1e3, 3) if lat.size else 0.0,
        p99_ms=round(float(np.percentile(lat, 99)) * 1e3, 3) if lat.size else 0.0,
        qps=round(len(latencies) / wall, 2),
        n_clients=n_clients, n_requests=int(stats["n_requests"]),
        n_rows=int(stats["n_rows"]), n_batches=int(stats["n_batches"]),
        n_failures=n_failures, parity=bool(parity),
        peak_live_batches=int(stats["peak_live_batches"]),
        max_live_batches=int(stats["max_live_batches"]))]
    for b_str, occ in stats["occupancy"].items():
        b = int(b_str)
        rows.append(bench_row(
            f"serving/bucket{b}", 0.0, backend, bucket=b,
            batches=int(occ["batches"]),
            mean_occupancy=round(float(occ["mean_occupancy"]), 4),
            compiles=int(compiles_after[b] - compiles_before[b])))
    rows.append(bench_row(
        "serving/swap", swap_s * 1e6, backend,
        recompiles_after_warm=int(recompiles_after_warm),
        warm_buckets=len(warm)))
    return rows
