"""Paper Fig. 4(b) / App. I — cumulative partial similarity (Pareto curve).

Paper: CPS(0.1) ≈ 0.92 on PubMed (10% of multiply-adds give 92% of the
similarity).  Synthetic corpora reproduce the shape; the exact level depends
on the tf-idf skew.
"""
from __future__ import annotations

from benchmarks.common import corpus, csv_row, make_estimator
from repro.core import metrics


def run():
    job, docs, df, perm, topics = corpus("pubmed")
    res = make_estimator(k=job.k, algo="esicp", max_iter=4,
                          batch_size=4096, seed=0).fit(docs, df=df)
    nr, cps, std = metrics.cps_curve(docs, res.state_.index.means_t, res.labels_)
    i10 = int(0.1 * (len(nr) - 1))
    i25 = int(0.25 * (len(nr) - 1))
    return [
        csv_row("fig4b/cps_at_0.1", 0, f"cps={cps[i10]:.3f};std={std[i10]:.3f}"),
        csv_row("fig4b/cps_at_0.25", 0, f"cps={cps[i25]:.3f}"),
        csv_row("fig4b/pareto_like", 0, f"cps01_ge_0.5={bool(cps[i10] >= 0.5)}"),
    ]


if __name__ == "__main__":
    print("\n".join(run()))
