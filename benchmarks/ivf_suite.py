"""Two-level IVF suite (ISSUE 9; DESIGN.md §13): routed vs flat classify at
large effective K, machine-readable as ``BENCH_ivf.json``.

The routed classify's claim is asymptotic: per object it scores
K_c + Σ probed cell sizes centroids instead of all K_eff, so it must beat
the flat scan on BOTH axes — the Mult counters (scored-centroid
multiply-adds, the paper's currency) and the wall clock — once K_eff is
large (the ratchet gates both at K_eff >= 4k).  To measure the classify
asymptotics without paying a K-cluster corpus *fit* per point, each scale
point samples K documents as stand-in fine centroids and wraps them with
:func:`repro.cluster.two_level_from_means` (coarse-clustering the means
themselves into K_c ≈ √K cells) — the routed/flat comparison only needs a
valid nested index, not a converged one.

Rows per scale point (names carry the *effective* K of the built model):

  ``ivf/K<k>/flat_classify``  — the flat exhaustive scan over all K_eff
      means (the baseline every routed row names via ``vs``).
  ``ivf/K<k>/routed_p1``      — n_probe=1: the fast ANN setting.  Carries
      ``mult_per_doc``, measured ``recall_at1`` vs the flat argmax (never
      silently dropped — the ratchet fails if absent), ``scored_max`` and
      its contract bound ``scored_bound`` = K_c + max cell size.
  ``ivf/K<k>/routed_p4``      — a wider probe (recall vs cost trade).
  ``ivf/K<k>/routed_exact``   — n_probe=K_c: probes every cell, delegates
      to the flat path, and must be bit-identical to it (``exact_match``).

All rows run the same backend and execution mode, so the wall-clock
``speedup`` ratios are honest same-mode comparisons (``comparable: true``).
``REPRO_BENCH_SMOKE=1`` trims the scale sweep to CI-sized points; the full
sweep reaches the 100k+ regime.
"""
from __future__ import annotations

import math
import os

import numpy as np

from benchmarks.common import (bench_row, default_backend, speedup_fields,
                               time_call_warm)
from repro.cluster import classify_docs, classify_docs_routed, two_level_from_means
from repro.data import make_corpus
from repro.data.synthetic import CorpusSpec
from repro.sparse import SparseDocs

# Effective-K sweep: the donor corpus supplies K docs as fine centroids
# plus N_QUERY held-out query docs.  Vocab is kept moderate so the FLAT
# baseline's dense (D, K) index stays materialisable at the top point —
# which is exactly the regime statement: the routed path's operands scale
# with one cell, the flat scan's with K.
KS_SMOKE = (4096, 16384)
KS_FULL = (4096, 16384, 131072)
N_QUERY = 2048
VOCAB = 2048
N_TOPICS = 128
QUERY_BATCH = 512


def _slice_docs(docs: SparseDocs, start: int, stop: int) -> SparseDocs:
    return SparseDocs(ids=docs.ids[start:stop], vals=docs.vals[start:stop],
                      nnz=docs.nnz[start:stop], dim=docs.dim)


def _scale_point(k: int, backend: str, smoke: bool) -> list:
    docs, _, _, _ = make_corpus(CorpusSpec(
        n_docs=k + N_QUERY, vocab=VOCAB, nt_mean=64.0, n_topics=N_TOPICS,
        topic_sharpness=500.0, seed=k))
    mean_docs = _slice_docs(docs, 0, k)
    queries = _slice_docs(docs, k, k + N_QUERY)
    k_c = int(round(math.sqrt(k)))
    model = two_level_from_means(mean_docs, k_c, n_probe=1, backend=backend,
                                 algo="mivi", seed=0,
                                 max_iter=3 if smoke else 6)
    k_eff = model.index.k
    cmax = int(np.max(model.cell_sizes))
    nnz_q = np.asarray(queries.nnz, np.float64)

    (a_flat, s_flat), flat_s, flat_w = time_call_warm(
        classify_docs, model.index, queries, backend=backend,
        batch_size=QUERY_BATCH)
    flat_name = f"ivf/K{k_eff}/flat_classify"
    rows = [bench_row(
        flat_name, flat_s * 1e6, backend, warmup_us=flat_w * 1e6,
        k_eff=k_eff, k_c=k_c, n_query=N_QUERY,
        mult_per_doc=float(np.mean(nnz_q) * k_eff))]

    for n_probe in (1, 4):
        if n_probe >= k_c:
            continue
        (a_r, s_r), r_s, r_w = time_call_warm(
            classify_docs_routed, model, queries, n_probe=n_probe,
            backend=backend, batch_size=QUERY_BATCH)
        _, _, scored = classify_docs_routed(
            model, queries, n_probe=n_probe, backend=backend,
            batch_size=QUERY_BATCH, with_stats=True)
        rows.append(bench_row(
            f"ivf/K{k_eff}/routed_p{n_probe}", r_s * 1e6, backend,
            warmup_us=r_w * 1e6, k_eff=k_eff, k_c=k_c, n_probe=n_probe,
            n_query=N_QUERY,
            mult_per_doc=float(np.mean(nnz_q * scored)),
            recall_at1=float(np.mean(a_r == a_flat)),
            scored_max=int(scored.max()),
            scored_bound=k_c + cmax,
            vs=flat_name,
            **speedup_fields(flat_s, r_s, comparable=True)))

    (a_e, s_e), e_s, e_w = time_call_warm(
        classify_docs_routed, model, queries, n_probe=k_c, backend=backend,
        batch_size=QUERY_BATCH)
    rows.append(bench_row(
        f"ivf/K{k_eff}/routed_exact", e_s * 1e6, backend,
        warmup_us=e_w * 1e6, k_eff=k_eff, k_c=k_c, n_probe=k_c,
        n_query=N_QUERY, mult_per_doc=float(np.mean(nnz_q) * k_eff),
        exact_match=bool(np.array_equal(a_e, a_flat)
                         and np.array_equal(s_e, s_flat)),
        vs=flat_name,
        **speedup_fields(flat_s, e_s, comparable=True)))
    return rows


def run():
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    backend = default_backend()
    rows = []
    for k in (KS_SMOKE if smoke else KS_FULL):
        rows.extend(_scale_point(k, backend, smoke))
    return rows
