"""Paper Fig. 13 — EstParams approximate Mult vs actual Mult.

The estimator's J(s', v_h) (approximate multiply-adds) is compared against
the *measured* multiply-adds of one real ES assignment pass at the same
(t_th, v_h) points, across the v_th candidate grid.  The paper's claim:
the curves agree and share their minimiser.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import default_backend, corpus, csv_row, make_estimator
from repro.core import StructuralParams
from repro.core.assignment import assignment_step
from repro.core.estparams import estimate_params, EstGrid


def run():
    job, docs, df, perm, topics = corpus("pubmed")
    warm = make_estimator(k=job.k, algo="mivi", max_iter=3, batch_size=4096,
                           seed=0).fit(docs, df=df)
    state = warm.state_
    grid = EstGrid(n_v=8, n_s=24)
    est, aux = estimate_params(docs, df, state.index.means_t, state.rho_self,
                               k=job.k, grid=grid)
    j_tab = np.asarray(aux["J"])
    s_grid = np.asarray(aux["s_grid"])
    v_grid = np.asarray(aux["v_grid"])

    n_eval = min(docs.n_docs, 8192)
    sub = docs.slice_rows(0, n_eval)
    approx, actual = [], []
    for hi, v in enumerate(v_grid):
        si = int(np.argmin(j_tab[:, hi]))
        params = StructuralParams(t_th=jnp.asarray(int(s_grid[si]), jnp.int32),
                                  v_th=jnp.asarray(float(v), jnp.float32))
        idx = state.index.with_params(params)
        r = assignment_step("es", sub, idx, state.assign[:n_eval],
                            state.rho_self[:n_eval], jnp.zeros((n_eval,), bool),
                            backend=default_backend())
        approx.append(j_tab[si, hi] * n_eval / docs.n_docs)
        actual.append(float(r.mult))
    approx = np.array(approx); actual = np.array(actual)
    corr = float(np.corrcoef(approx, actual)[0, 1])
    same_min = int(np.argmin(approx)) == int(np.argmin(actual))
    ratio = float(np.median(approx / np.maximum(actual, 1)))
    return [
        csv_row("fig13/approx_vs_actual", 0,
                f"corr={corr:.3f};same_minimiser={same_min};median_ratio={ratio:.3f}"),
        csv_row("fig13/picked", 0,
                f"t_th={int(est.t_th)}({int(est.t_th)/docs.dim:.3f}D);v_th={float(est.v_th):.4f}"),
    ]


if __name__ == "__main__":
    print("\n".join(run()))
