"""Shared benchmark substrate: reduced paper corpora + timing helpers.

PubMed/NYT are not shipped offline; all benchmarks run on the UC-faithful
synthetic corpora from configs/pubmed8m.py::reduced() (DESIGN.md §7) and
validate the paper's *relative* claims (speedups, CPR curves, filter
exactness), not absolute wall-times.

Backend selection: every suite builds its clusterers through
:func:`make_kmeans`, so one env var flips the whole harness onto a kernel
engine — 'pallas', 'xla_blocked', or 'auto' (resolves per-platform; see
core/backends.py):

    REPRO_BACKEND=xla_blocked PYTHONPATH=src python -m benchmarks.run --only table4
"""
from __future__ import annotations

import functools
import os
import time
import warnings

import numpy as np

from repro.configs.pubmed8m import reduced as pubmed_reduced
from repro.configs.nyt1m import reduced as nyt_reduced
from repro.cluster import SphericalKMeans
from repro.data import make_corpus


def default_backend() -> str:
    """Assignment-engine backend for every suite (env: REPRO_BACKEND)."""
    return os.environ.get("REPRO_BACKEND", "reference")


def make_estimator(k: int, **kw) -> SphericalKMeans:
    """repro.cluster.SphericalKMeans with the harness-wide backend default
    threaded in (the estimator's fit returns itself; read history_/model_)."""
    kw.setdefault("backend", default_backend())
    return SphericalKMeans(k=k, **kw)


def make_kmeans(k: int, **kw) -> SphericalKMeans:
    """Deprecated pre-redesign name; use :func:`make_estimator`."""
    warnings.warn(
        "benchmarks.common.make_kmeans is deprecated; use make_estimator "
        "(same semantics — fit() now returns the estimator, not a "
        "LloydResult).", DeprecationWarning, stacklevel=2)
    return make_estimator(k, **kw)


@functools.lru_cache(maxsize=4)
def corpus(dataset: str = "pubmed", seed: int = 0):
    job = pubmed_reduced(seed) if dataset == "pubmed" else nyt_reduced(seed)
    docs, df, perm, topics = make_corpus(job.corpus)
    return job, docs, df, perm, topics


def time_call(fn, *args, repeat: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def time_call_warm(fn, *args, repeat: int = 3, **kw):
    """Like :func:`time_call` but measures — and excludes — warmup.

    The first call (compile + trace + cache population) is timed separately
    and NOT eligible as the reported best, so per-case JSON rows record
    steady-state kernel time with the one-off cost in a ``warmup`` field
    instead of polluting ``us_per_call`` (the update_pallas 12.8 s/call vs
    47 ms regression this fixes was exactly that pollution).

    Returns (out, best_steady_seconds, warmup_seconds).
    """
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    warmup = time.perf_counter() - t0
    out, best = time_call(fn, *args, repeat=repeat, **kw)
    return out, best, warmup


def csv_row(name: str, us_per_call: float, derived: str = "",
            warmup_us: float | None = None) -> str:
    """``name,us_per_call,derived[,warmup_us]`` — the optional 4th column
    carries the per-case warmup (compile) time for JSON-emitting suites."""
    row = f"{name},{us_per_call:.2f},{derived}"
    return row if warmup_us is None else f"{row},{warmup_us:.2f}"


def exec_meta(backend: str = "") -> dict:
    """Execution metadata every machine-readable bench row must carry.

    ``platform`` is the live ``jax.default_backend()``; ``interpret`` flags
    whether the timed path dispatched Pallas kernels in interpret mode (the
    off-TPU default in kernels/ops.py) — true only for pallas-backend rows
    off TPU, never for reference rows, which run plain XLA and remain valid
    CPU baselines.  An interpret-mode timing is a *correctness-path*
    measurement orders of magnitude off real kernel time — rows wear the
    flag precisely so a multi-second interpreted ``update_pallas`` can
    never be misread as a TPU regression.
    """
    import jax

    platform = jax.default_backend()
    interpret = backend == "pallas" and platform != "tpu"
    # mode names the timed execution path explicitly: 'xla' (reference jnp
    # ops AND the always-compiled xla_blocked engine), 'compiled' (lowered
    # Pallas kernels), 'interpret' (the Pallas interpreter).  Suites that
    # probe the live mode (kernel_suite) override it per row; this default
    # matches the kernels/ops.py dispatch rule.
    mode = ("xla" if backend != "pallas"
            else ("interpret" if interpret else "compiled"))
    return {"platform": platform, "interpret": interpret, "mode": mode}


def speedup_fields(ref_best_s: float, best_s: float, *,
                   comparable: bool) -> dict:
    """The ``speedup``/``comparable`` field pair for a bench row.

    A speedup ratio is only meaningful when numerator and denominator ran
    the same execution mode — an interpret-mode Pallas timing against a
    compiled XLA reference measures the interpreter, not the kernel, so the
    ratio is suppressed (``speedup: null``) and the row says why
    (``comparable: false``).  Same-mode ratios (e.g. tuned-vs-default, both
    interpret or both compiled) stay valid everywhere.
    """
    return {"comparable": bool(comparable),
            "speedup": (round(float(ref_best_s) / float(best_s), 4)
                        if comparable else None)}


def bench_row(name: str, us_per_call: float, backend: str = "", *,
              warmup_us: float | None = None, **extra) -> dict:
    """Dict bench row for JSON-emitting suites: name/us_per_call/backend +
    the execution metadata from :func:`exec_meta` + any suite-specific
    fields (e.g. per-kernel ``speedup`` ratios)."""
    row = {"name": name, "us_per_call": round(float(us_per_call), 2),
           "backend": backend}
    if warmup_us is not None:
        row["warmup_us"] = round(float(warmup_us), 2)
    row.update(exec_meta(backend))
    row.update(extra)
    return row
