"""EXPERIMENTS.md §Roofline table generator — reads results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(pattern: str = "*.json") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_table(records: list[dict], mesh: str = "pod16x16",
              variant: str = "baseline") -> str:
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bottleneck "
           "| 6ND/HLO | fit 16G |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in records:
        if r.get("mesh") != mesh or r.get("variant", "baseline") != variant:
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped ({r.get('reason','')[:40]}) | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                         f"{r.get('error','')[:60]} | | | | | |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['t_compute_s']:.3e} | "
            f"{t['t_memory_s']:.3e} | {t['t_collective_s']:.3e} | "
            f"{t['bottleneck']} | {r.get('useful_flops_ratio', 0):.2f} | "
            f"{'y' if r.get('fits_hbm_16g') else 'N'} |")
    return "\n".join(lines)


def run():
    recs = load_records()
    ok = sum(1 for r in recs if r["status"] == "ok")
    err = sum(1 for r in recs if r["status"] == "error")
    skip = sum(1 for r in recs if r["status"] == "skip")
    return [f"roofline/cells,0,ok={ok};skip={skip};err={err}"]


if __name__ == "__main__":
    recs = load_records()
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n### {mesh}\n")
        print(fmt_table(recs, mesh))
