"""Paper Table VI — the NYT dataset comparison (same harness as Table IV)."""
from benchmarks.table4_compare import run as _run


def run():
    return _run("nyt")


if __name__ == "__main__":
    print("\n".join(run()))
