"""Benchmark harness — one module per paper table/figure (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV rows.  Run everything:

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --only table4,fig7

The ``fused`` and ``kernels`` suites additionally write
``BENCH_fused_iteration.json`` / ``BENCH_kernels.json`` so the update-phase
and per-kernel perf trajectories are machine-readable across PRs; their
rows carry ``platform``/``interpret`` execution metadata (and the kernel
suite per-kernel ``speedup`` ratios) so interpret-mode Pallas timings are
flagged as such.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

SUITES = [
    ("fig2", "benchmarks.fig2_ucs"),
    ("fig4", "benchmarks.fig4_cps"),
    ("table2", "benchmarks.table2_loop_order"),
    ("table4", "benchmarks.table4_compare"),
    ("table6", "benchmarks.table6_nyt"),
    ("fig7", "benchmarks.fig7_iterations"),
    ("fig10", "benchmarks.fig10_threshold"),
    ("fig13", "benchmarks.fig13_estparams"),
    ("ablation", "benchmarks.ablation_thresholds"),
    ("apph", "benchmarks.apph_seeding"),
    ("roofline", "benchmarks.roofline_report"),
    ("fused", "benchmarks.fused_iteration"),
    ("kernels", "benchmarks.kernel_suite"),
    ("pruning", "benchmarks.pruning_suite"),
    ("serving", "benchmarks.serving_suite"),
    ("ivf", "benchmarks.ivf_suite"),
]

JSON_SUITES = {"fused": "BENCH_fused_iteration.json",
               "kernels": "BENCH_kernels.json",
               "pruning": "BENCH_pruning.json",
               "serving": "BENCH_serving.json",
               "ivf": "BENCH_ivf.json"}


def _as_csv(row) -> str:
    """Printable CSV line for a row — dict rows render their core columns
    (full metadata lives in the JSON artifact).  ``us_per_call`` is
    optional: rows that cannot honestly report a wall time omit it."""
    if isinstance(row, str):
        return row
    us = row.get("us_per_call")
    line = (f"{row['name']},{'' if us is None else f'{us:.2f}'},"
            f"{row.get('backend', '')}")
    if "warmup_us" in row:
        line += f",{row['warmup_us']:.2f}"
    return line


def write_bench_json(rows, path: str) -> str:
    """Bench rows -> JSON file.

    Rows are either dicts (``benchmarks.common.bench_row`` — carry the
    execution metadata ``platform``/``interpret`` and any suite extras such
    as per-kernel ``speedup``) or legacy ``name,us_per_call,derived
    [,warmup_us]`` CSV strings.  The derived column of CSV rows carries the
    backend name; the optional 4th column is the per-case warmup
    (compile/trace) time, recorded as a ``warmup_us`` field so steady-state
    ``us_per_call`` is never conflated with one-off compilation again.
    """
    entries = []
    for row in rows:
        if isinstance(row, dict):
            entries.append(dict(row))
            continue
        name, us, rest = row.split(",", 2)
        derived, _, warmup = rest.partition(",")
        entry = {"name": name, "us_per_call": float(us), "backend": derived}
        if warmup:
            entry["warmup_us"] = float(warmup)
        entries.append(entry)
    with open(path, "w") as f:
        json.dump(entries, f, indent=2)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite prefixes")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, module in SUITES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            rows = mod.run()
            for row in rows:
                print(_as_csv(row), flush=True)
            if name in JSON_SUITES:
                write_bench_json(rows, JSON_SUITES[name])
            print(f"{name}/_suite,{(time.time() - t0) * 1e6:.0f},elapsed",
                  flush=True)
        except Exception as e:
            failures += 1
            print(f"{name}/_suite_FAILED,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
