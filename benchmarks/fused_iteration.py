"""Fused-iteration suite (DESIGN.md §8): backend-owned update phase + the
fully on-device Lloyd fit.

Times (a) one complete update phase — cluster-sum accumulation, mean
normalisation, index rebuild, ρ_self refresh — under the ``reference``
scatter/gather vs the ``pallas`` ``segment_update``/``rho_gather`` kernels,
and (b) the per-iteration cost of the fused ``lax.while_loop`` fit.  The
``derived`` CSV column carries the backend name so :mod:`benchmarks.run`
can emit the machine-readable ``BENCH_fused_iteration.json`` trajectory.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import corpus, csv_row, default_backend, make_estimator, time_call
from repro.core.update import update_step
from repro.sparse import SparseDocs


_N_SUB = 2048        # update-phase timing slice (interpret-mode friendly)


def run():
    job, docs, df, perm, topics = corpus("pubmed")
    rows = []

    # Mid-clustering state: real means, real moving flags, real thresholds.
    km = make_estimator(job.k, algo="esicp", max_iter=3, batch_size=4096, seed=0)
    state = km.fit(docs, df=df).state_

    sub = SparseDocs(ids=docs.ids[:_N_SUB], vals=docs.vals[:_N_SUB],
                     nnz=docs.nnz[:_N_SUB], dim=docs.dim)
    assign = state.assign[:_N_SUB]
    prev = jnp.roll(assign, 1)
    state_sub = dataclasses.replace(
        state, assign=assign, rho_self=state.rho_self[:_N_SUB],
        rho_self_prev=state.rho_self_prev[:_N_SUB])

    for backend in ("reference", "pallas"):
        def one_update(b=backend):
            out = update_step(sub, assign, prev, state_sub,
                              state.index.params, k=job.k, backend=b)
            jax.block_until_ready(out.rho_self)
            return out

        one_update()                                     # compile
        _, best = time_call(one_update)
        rows.append(csv_row(f"fused_iteration/update_{backend}",
                            best * 1e6, backend))

    # Fused fit: wall-time per Lloyd iteration with O(1) host syncs.
    backend = default_backend()
    km = make_estimator(job.k, algo="esicp", max_iter=8, batch_size=4096, seed=0)
    km.fit(docs, df=df)                                  # compile
    res, best = time_call(lambda: km.fit(docs, df=df), repeat=1)
    rows.append(csv_row("fused_iteration/fit_per_iter",
                        best * 1e6 / max(res.n_iter_, 1), backend))
    return rows
