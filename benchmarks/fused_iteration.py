"""Fused-iteration suite (DESIGN.md §8/§10): backend-owned update phase,
the fully on-device Lloyd fit, and the streaming chunk-scan fit.

Times (a) one complete update phase — cluster-sum accumulation, mean
normalisation, index rebuild, ρ_self refresh — under the ``reference``
scatter/gather vs the ``pallas`` ``segment_update``/``rho_gather`` kernels,
(b) the per-iteration cost of the fused ``lax.while_loop`` fit, and (c) the
per-iteration cost of the out-of-core streaming fit over a 4-chunk DocStore.

Per-case timing discipline: every case is measured with
:func:`benchmarks.common.time_call_warm` — the first call (compile + trace)
is recorded as the row's ``warmup`` column and EXCLUDED from
``us_per_call``, so the machine-readable ``BENCH_fused_iteration.json``
trajectory reports steady-state time only (the previously recorded
``update_pallas`` 12.8 s/call vs the 47 ms reference was dominated by that
one-off cost, not kernel time).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import (bench_row, corpus, default_backend,
                               make_estimator, time_call_warm)
from repro.core.update import update_step
from repro.sparse import DocStore, SparseDocs


_N_SUB = 2048        # update-phase timing slice (interpret-mode friendly)


def run():
    job, docs, df, perm, topics = corpus("pubmed")
    rows = []

    # Mid-clustering state: real means, real moving flags, real thresholds.
    km = make_estimator(job.k, algo="esicp", max_iter=3, batch_size=4096, seed=0)
    state = km.fit(docs, df=df).state_

    sub = SparseDocs(ids=docs.ids[:_N_SUB], vals=docs.vals[:_N_SUB],
                     nnz=docs.nnz[:_N_SUB], dim=docs.dim)
    assign = state.assign[:_N_SUB]
    prev = jnp.roll(assign, 1)
    state_sub = dataclasses.replace(
        state, assign=assign, rho_self=state.rho_self[:_N_SUB],
        rho_self_prev=state.rho_self_prev[:_N_SUB], ub=state.ub[:_N_SUB])

    # Always compare all three registered engines, plus whatever
    # REPRO_BACKEND names — deduped so the env default doesn't double a row.
    for backend in dict.fromkeys(
            ("reference", "pallas", "xla_blocked", default_backend())):
        def one_update(b=backend):
            out = update_step(sub, assign, prev, state_sub,
                              state.index.params, k=job.k, backend=b)
            jax.block_until_ready(out.rho_self)
            return out

        _, best, warm = time_call_warm(one_update)
        rows.append(bench_row(f"fused_iteration/update_{backend}",
                              best * 1e6, backend, warmup_us=warm * 1e6))

    # Fused fit: wall-time per Lloyd iteration with O(1) host syncs.
    backend = default_backend()
    km = make_estimator(job.k, algo="esicp", max_iter=8, batch_size=4096, seed=0)
    res, best, warm = time_call_warm(lambda: km.fit(docs, df=df), repeat=1)
    rows.append(bench_row("fused_iteration/fit_per_iter",
                          best * 1e6 / max(res.n_iter_, 1), backend,
                          warmup_us=warm * 1e6))

    # Streaming chunk-scan fit: the same epoch over a 4-chunk DocStore —
    # measures the out-of-core overhead (prefetch + per-chunk dispatch) vs
    # the resident while_loop above.
    store = DocStore.from_docs(docs, chunk_size=-(-docs.n_docs // 4))
    skm = make_estimator(job.k, algo="esicp", max_iter=3, batch_size=4096,
                         seed=0)
    sres, sbest, swarm = time_call_warm(lambda: skm.fit(store, df=df),
                                        repeat=1)
    rows.append(bench_row("fused_iteration/stream_fit_per_iter",
                          sbest * 1e6 / max(sres.n_iter_, 1), backend,
                          warmup_us=swarm * 1e6))
    return rows
