"""Paper Figs. 10/12 — Mult before/after ES filtering vs threshold v_th.

Curve (a): cost of *constructing* the filter (Region-1/2 exact partials) —
falls as v_th rises (fewer Region-2 entries).  Curve (b): cost of verifying
survivors — rises as v_th rises (looser bound, more survivors).  The
EstParams pick should sit near the joint minimum (vertical dashed line in
the paper); we report the measured curves and the distance of the EstParams
pick from the empirical argmin.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import default_backend, corpus, csv_row, make_estimator
from repro.core import StructuralParams
from repro.core.assignment import assignment_step
from repro.core.estparams import estimate_params


def run():
    job, docs, df, perm, topics = corpus("pubmed")
    warm = make_estimator(k=job.k, algo="mivi", max_iter=3, batch_size=4096,
                           seed=0).fit(docs, df=df)
    state = warm.state_
    est, aux = estimate_params(docs, df, state.index.means_t, state.rho_self,
                               k=job.k)

    sub = docs.slice_rows(0, 4096)
    t_th = jnp.asarray(0, jnp.int32)   # paper Fig. 10 isolates v_th at t_th=0
    v_grid = np.quantile(np.asarray(state.index.means_t[state.index.means_t > 0]),
                         np.linspace(0.3, 0.995, 12))
    before, after = [], []
    for v in v_grid:
        idx = state.index.with_params(StructuralParams(
            t_th=t_th, v_th=jnp.asarray(v, jnp.float32)))
        r = assignment_step("es", sub, idx, state.assign[:4096],
                            state.rho_self[:4096],
                            jnp.zeros((4096,), bool),
                            backend=default_backend())
        ntail = jnp.sum(sub.row_mask(), axis=1).astype(jnp.float32)
        verify = float(jnp.sum(r.n_candidates * ntail))
        before.append(float(r.mult) - verify)
        after.append(verify)
    total = np.array(before) + np.array(after)
    best_v = float(v_grid[int(np.argmin(total))])
    rows = [
        csv_row("fig10/curves", 0,
                ";".join(f"v={v:.3f}:pre={b:.3g}:post={a:.3g}"
                         for v, b, a in zip(v_grid[::3], before[::3], after[::3]))),
        csv_row("fig10/empirical_best_v", 0, f"v={best_v:.4f}"),
        csv_row("fig10/estparams_pick", 0,
                f"v={float(est.v_th):.4f};t={int(est.t_th)}"),
    ]
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
