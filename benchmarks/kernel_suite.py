"""Kernel microbenchmark suite: each clustering kernel vs its pure-jnp
reference op at matched shapes, across BOTH engines — Pallas and the
compiled XLA-blocked twins — tuned vs default vs reference (ISSUE 5
satellite; compiled-mode + autotuner rows from ISSUE 6; xla_blocked rows
and the enforced CPU ratchet from ISSUE 10).

For every kernel — ``sparse_sim``, ``esicp_gather``, ``segment_update``,
``rho_gather`` — seven rows:

    kernel_suite/<name>_reference           the jnp oracle (kernels/ref.py)
    kernel_suite/<name>_pallas              the wrapper, inline occupancy
    kernel_suite/<name>_pallas_planned      the wrapper fed a prepared
                                            KernelPlan (cached head slabs +
                                            precomputed occupancy)
    kernel_suite/<name>_pallas_tuned        the wrapper under the pallas
                                            autotuner winner + matching plan
    kernel_suite/<name>_xla_blocked         kernels/xla_blocked.py, plan-less
                                            gather formulation (the engine
                                            default: head-less)
    kernel_suite/<name>_xla_blocked_planned the XLA twin fed the default-
                                            geometry plan (head slabs ride a
                                            dense GEMM)
    kernel_suite/<name>_xla_blocked_tuned   the XLA twin under its own
                                            engine's autotuner winner

plus ``kernel_suite/autotuner`` / ``kernel_suite/autotuner_xla`` meta-rows
recording what each engine's roofline-pruned search did, and
``kernel_suite/plan_build_*`` rows timing KernelPlan construction
*separately* from the steady-state kernel calls it feeds (plan build is
host-side, once-per-fit work — folding it into a per-call timing would
misprice both).

Execution-mode honesty: the suite *attempts* compiled (non-interpret)
Pallas first and falls back to interpret mode only when the platform
refuses to lower it (CPU backends); ``REPRO_KERNEL_MODE=interpret|compiled``
overrides the probe (DESIGN.md §7).  Every pallas row carries the live
``interpret``/``mode`` flags, and cross-mode ratios are suppressed:
``speedup`` (vs the compiled-XLA reference) is null with
``comparable: false`` whenever the Pallas kernels ran interpreted.  The
``xla_blocked`` rows always compile — same mode as the reference on every
platform — so they are ``comparable: true`` everywhere, which is what lets
benchmarks/ratchet.py enforce the compiled speedup gate on the stock CPU
runner.  The ``speedup_vs_default`` field on tuned rows compares two
same-engine, same-mode timings and is therefore always valid (the XLA
engine's default is the plan-less gather row).

Shapes follow the reduced-PubMed regime (Zipf-skewed synthetic corpus →
realistic occupancy); ``REPRO_BENCH_SMOKE=1`` shrinks the shapes AND the
autotuner budget (repro.tune.SearchBudget.default) for CI.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_row, speedup_fields, time_call,
                               time_call_warm)
from repro.kernels import ops, ref
from repro.kernels import xla_blocked as xb
from repro.kernels.plan import prepare_plan
from repro.tune.search import SearchBudget, search_tuned_config


def _shapes():
    if os.environ.get("REPRO_BENCH_SMOKE"):
        return dict(b=256, p=32, d=1024, k=128, repeat=2)
    return dict(b=512, p=64, d=2048, k=256, repeat=3)


def _corpus(b: int, p: int, d: int, k: int, seed: int = 0):
    """Zipf-skewed synthetic tuples in df-rank order: high-df terms at the
    HIGH ids (ascending-df layout), so the occupancy/head machinery sees
    the skew it was built for."""
    rng = np.random.default_rng(seed)
    # Zipf ranks over [1, d]; rank 1 = most frequent → highest df-rank id.
    ranks = np.minimum(rng.zipf(1.3, size=(b, p)), d)
    ids = np.sort((d - ranks).astype(np.int32), axis=1)
    vals = rng.random((b, p)).astype(np.float32)
    nnz = rng.integers(p // 2, p + 1, b)
    for i in range(b):
        vals[i, nnz[i]:] = 0.0
    means_t = np.where(rng.random((d, k)) < 0.15,
                       rng.random((d, k)), 0.0).astype(np.float32)
    assign = rng.integers(0, k, b).astype(np.int32)
    return (jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(means_t),
            jnp.asarray(assign))


def _timed(fn, repeat):
    def call():
        return jax.block_until_ready(fn())

    return time_call_warm(call, repeat=repeat)


def _probe_compiled(ids, vals, means_t) -> bool:
    """Resolve whether the Pallas rows time compiled kernels.

    ``REPRO_KERNEL_MODE`` short-circuits the probe — ``compiled`` forces
    non-interpret launches (the honest setting on TPU-class runners where
    probing wastes a compile), ``interpret`` forces the interpreter (useful
    for exercising the fallback path on any platform).  On ``auto`` (the
    default) the suite *attempts* one compiled launch: True → the platform
    lowers Pallas natively (TPU) and the whole suite times compiled
    kernels; False → only the interpreter is available and every pallas
    row says so (``mode: interpret``, ``comparable: false``) instead of
    dressing interpreter dispatch up as kernel time.
    """
    mode = os.environ.get("REPRO_KERNEL_MODE", "auto").strip().lower()
    if mode == "compiled":
        return True
    if mode == "interpret":
        return False
    try:
        jax.block_until_ready(
            ops.sparse_sim(ids[:8], vals[:8], means_t, interpret=False))
        return True
    except Exception:
        return False


def run():
    cfg = _shapes()
    b, p, d, k, repeat = cfg["b"], cfg["p"], cfg["d"], cfg["k"], cfg["repeat"]
    ids, vals, means_t, assign = _corpus(b, p, d, k)
    t_th = jnp.asarray(int(0.8 * d), jnp.int32)
    v_th = jnp.asarray(0.1, jnp.float32)
    shape_meta = {"B": b, "P": p, "D": d, "K": k}

    compiled = _probe_compiled(ids, vals, means_t)
    interpret = not compiled
    mode = "compiled" if compiled else "interpret"

    # Roofline-pruned autotune at the suite's own regime, once per engine
    # (budget shrinks under REPRO_BENCH_SMOKE with the shapes).  The engines
    # search disjoint candidate spaces and cache regimes (tune/config.py).
    budget = SearchBudget.default()
    t0 = time.perf_counter()
    tuned, stats = search_tuned_config(ids, vals, dim=d, k=k, budget=budget)
    search_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    xtuned, xstats = search_tuned_config(ids, vals, dim=d, k=k,
                                         budget=budget, engine="xla_blocked")
    xsearch_s = time.perf_counter() - t0

    # Plan construction is host-side, once-per-fit work; time it in its own
    # rows so it never pollutes (nor hides inside) the per-call kernel rows.
    plan, plan_s = time_call(
        lambda: prepare_plan(ids, vals, dim=d), repeat=repeat)
    tplan, tplan_s = time_call(
        lambda: prepare_plan(ids, vals, dim=d, tuned=tuned), repeat=repeat)
    xtplan, xtplan_s = time_call(
        lambda: prepare_plan(ids, vals, dim=d, tuned=xtuned), repeat=repeat)

    def variants(ref_fn, pal, xla):
        return (
            ("reference", "reference", ref_fn, None),
            ("pallas", "pallas",
             lambda: pal(plan=None, tuned=None), False),
            ("pallas_planned", "pallas",
             lambda: pal(plan=plan, tuned=None), False),
            ("pallas_tuned", "pallas",
             lambda: pal(plan=tplan, tuned=tuned), True),
            ("xla_blocked", "xla_blocked",
             lambda: xla(plan=None, tuned=None), False),
            ("xla_blocked_planned", "xla_blocked",
             lambda: xla(plan=plan, tuned=None), False),
            ("xla_blocked_tuned", "xla_blocked",
             lambda: xla(plan=xtplan, tuned=xtuned), True),
        )

    cases = {
        "sparse_sim": variants(
            lambda: ref.sparse_sim(ids, vals, means_t),
            lambda **kw: ops.sparse_sim(ids, vals, means_t,
                                        interpret=interpret, **kw),
            lambda **kw: xb.sparse_sim(ids, vals, means_t, **kw)),
        "esicp_gather": variants(
            lambda: ref.esicp_gather(ids, vals, means_t, t_th, v_th),
            lambda **kw: ops.esicp_gather(ids, vals, means_t, t_th, v_th,
                                          interpret=interpret, **kw),
            lambda **kw: xb.esicp_gather(ids, vals, means_t, t_th, v_th,
                                         **kw)),
        "segment_update": variants(
            lambda: ref.segment_update(assign, ids, vals, k, d),
            lambda **kw: ops.segment_update(assign, ids, vals, k=k, d=d,
                                            interpret=interpret, **kw),
            lambda **kw: xb.segment_update(assign, ids, vals, k=k, d=d,
                                           **kw)),
        "rho_gather": variants(
            lambda: ref.rho_gather(assign, ids, vals, means_t),
            lambda **kw: ops.rho_gather(assign, ids, vals, means_t,
                                        interpret=interpret, **kw),
            lambda **kw: xb.rho_gather(assign, ids, vals, means_t, **kw)),
    }

    rows = []
    for name, var in cases.items():
        ref_best = None
        default_best = {}                    # engine -> its default's best
        for suffix, backend, fn, is_tuned in var:
            if suffix == "reference":
                _, ref_best, warm = _timed(jax.jit(fn), repeat)
                rows.append(bench_row(f"kernel_suite/{name}_reference",
                                      ref_best * 1e6, "reference",
                                      warmup_us=warm * 1e6, **shape_meta))
                continue
            _, best, warm = _timed(fn, repeat)
            is_xla = backend == "xla_blocked"
            extra = dict(shape_meta)
            # xla_blocked always compiles — same execution mode as the
            # reference on every platform, so the cross-engine ratio is a
            # kernel measurement everywhere; pallas rows are only
            # comparable when the kernels actually compiled.
            extra.update(interpret=False if is_xla else interpret,
                         mode="xla" if is_xla else mode, tuned=is_tuned)
            extra.update(speedup_fields(ref_best, best,
                                        comparable=is_xla or compiled))
            if suffix in ("pallas_planned", "xla_blocked"):
                # Each engine's tuned row is judged against that engine's
                # default configuration: planned default geometry for
                # pallas, the plan-less gather for xla_blocked.
                default_best[backend] = best
            if is_tuned and backend in default_best:
                # Same engine, same mode, tuned vs default — valid on every
                # platform, including interpret-only ones.
                extra["speedup_vs_default"] = round(
                    default_best[backend] / best, 4)
            rows.append(bench_row(f"kernel_suite/{name}_{suffix}",
                                  best * 1e6, backend,
                                  warmup_us=warm * 1e6, **extra))

    for pname, pbackend, secs in (
            ("plan_build_default", "pallas", plan_s),
            ("plan_build_tuned", "pallas", tplan_s),
            ("plan_build_xla_tuned", "xla_blocked", xtplan_s)):
        rows.append(bench_row(
            f"kernel_suite/{pname}", secs * 1e6, pbackend,
            interpret=False, mode="host", comparable=False, speedup=None,
            **shape_meta))

    rows.append(bench_row(
        "kernel_suite/autotuner", search_s * 1e6, "pallas",
        interpret=interpret, mode=mode, tuned=True,
        comparable=False, speedup=None,
        winner=tuned.to_dict(), **stats.to_dict(), **shape_meta))
    rows.append(bench_row(
        "kernel_suite/autotuner_xla", xsearch_s * 1e6, "xla_blocked",
        interpret=False, mode="xla", tuned=True,
        comparable=False, speedup=None,
        winner=xtuned.to_dict(), **xstats.to_dict(), **shape_meta))
    return rows
